"""Roofline HLO parser unit tests + cell-builder coverage (no mesh —
single-device SDS construction only; full lowering is the dry-run's job)."""

import numpy as np
import pytest

from repro.configs.registry import ARCHS
from repro.launch import roofline as RL
from repro.launch.cells import all_cells, build_cell, lm_param_flops


HLO = """
  %ar = f32[1024,512]{1,0} all-reduce(f32[1024,512]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag.1 = bf16[2048,128]{1,0} all-gather(bf16[1024,128]{1,0} %y), replica_groups=[2,2]<=[4], dimensions={0}
  %rs = f32[256]{0} reduce-scatter(f32[1024]{0} %z), replica_groups={{0,1,2,3}}, to_apply=%add
  %cp = s32[64]{0} collective-permute(s32[64]{0} %w), source_target_pairs={{0,1}}
  %fusion.1 = f32[8,8] fusion(%a), kind=kLoop
"""


def test_collective_parser():
    c = RL.collective_bytes(HLO)
    assert c["n_ops"] == 4
    # all-reduce: 2 * 1024*512*4 * 3/4
    np.testing.assert_allclose(c["all-reduce"], 2 * 1024 * 512 * 4 * 0.75)
    # all-gather: result 2048*128*2 bytes * (2-1)/2
    np.testing.assert_allclose(c["all-gather"], 2048 * 128 * 2 * 0.5)
    # reduce-scatter: result 256*4 * (n-1)
    np.testing.assert_allclose(c["reduce-scatter"], 256 * 4 * 3)
    assert c["collective-permute"] == 64 * 4
    assert c["total"] == sum(
        c[k] for k in ("all-reduce", "all-gather", "reduce-scatter",
                       "all-to-all", "collective-permute"))


def test_roofline_terms():
    cost = {"flops": 197e12, "bytes accessed": 819e9}
    coll = {"total": 50e9}
    t = RL.roofline_terms(cost, coll, n_chips=4, model_flops=4 * 197e12)
    np.testing.assert_allclose(t["compute_s"], 1.0)
    np.testing.assert_allclose(t["memory_s"], 1.0)
    np.testing.assert_allclose(t["collective_s"], 1.0)
    np.testing.assert_allclose(t["useful_flops_ratio"], 1.0)


def test_all_cells_enumerates_40():
    cells = all_cells()
    assert len(cells) == 40
    assert len({a for a, _ in cells}) == 10


def test_lm_param_counts_match_published_scale():
    """Total parameter counts should land near the models' nameplates."""
    expect = {
        "deepseek-coder-33b": 33e9,
        "qwen3-14b": 14e9,
        "internlm2-20b": 20e9,
        "arctic-480b": 480e9,
        "grok-1-314b": 314e9,
    }
    for aid, nominal in expect.items():
        total, active = lm_param_flops(ARCHS[aid].config)
        assert 0.55 * nominal < total < 1.45 * nominal, (aid, total)
        assert active <= total


@pytest.mark.parametrize("arch_id,shape", [
    ("deepseek-coder-33b", "train_4k"),
    ("arctic-480b", "decode_32k"),
    ("nequip", "molecule"),
    ("pna", "minibatch_lg"),
    ("wide-deep", "retrieval_cand"),
])
def test_build_cell_without_mesh(arch_id, shape):
    """Cells construct ShapeDtypeStruct args without any device allocation."""
    cell = build_cell(arch_id, shape, mesh=None)
    import jax
    for leaf in jax.tree.leaves(cell.args):
        assert hasattr(leaf, "shape") and hasattr(leaf, "dtype")
        assert not hasattr(leaf, "addressable_data")  # no real arrays
    assert cell.meta.get("model_flops", 0) > 0
