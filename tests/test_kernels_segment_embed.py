"""segment_reduce and embedding_bag Pallas kernels vs pure-jnp oracles."""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.kernels.embedding_bag import ops as eb_ops
from repro.kernels.embedding_bag import ref as eb_ref
from repro.kernels.segment_reduce import ops as sr_ops
from repro.kernels.segment_reduce import ref as sr_ref


@pytest.mark.parametrize("e,n,d,dtype", [
    (64, 16, 8, np.float32),
    (1024, 256, 128, np.float32),
    (700, 100, 32, np.float32),
    (512, 512, 16, "bfloat16"),
    (1, 5, 4, np.float32),
])
def test_segment_sum_kernel_vs_ref(e, n, d, dtype):
    rng = np.random.default_rng(e + n)
    dst = rng.integers(0, n, e).astype(np.int32)
    dst[rng.random(e) < 0.1] = -1  # dropped edges
    msg = jnp.asarray(rng.standard_normal((e, d)), dtype=jnp.dtype(dtype) if
                      dtype != "bfloat16" else jnp.bfloat16)
    # reference accumulates in fp32 (the kernel's accumulator dtype)
    want = sr_ref.segment_sum(jnp.asarray(dst), msg.astype(jnp.float32), n)
    got = sr_ops.segment_sum(jnp.asarray(dst), msg, n,
                             backend="pallas_interpret")
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        rtol=1e-5 if dtype != "bfloat16" else 1e-2,
        atol=1e-4 if dtype != "bfloat16" else 2e-2)


def test_segment_mean_kernel_vs_ref():
    rng = np.random.default_rng(0)
    e, n, d = 300, 40, 12
    dst = rng.integers(0, n, e).astype(np.int32)
    msg = rng.standard_normal((e, d)).astype(np.float32)
    want = sr_ref.segment_mean(jnp.asarray(dst), jnp.asarray(msg), n)
    got = sr_ops.segment_mean(jnp.asarray(dst), jnp.asarray(msg), n,
                              backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("n_bags,per_bag,v,d", [
    (8, 4, 50, 16),
    (32, 1, 1000, 32),   # single-hot (wide&deep fields)
    (16, 7, 200, 64),
])
def test_embedding_bag_kernel_vs_ref(n_bags, per_bag, v, d):
    rng = np.random.default_rng(n_bags * v)
    t = n_bags * per_bag
    ids = rng.integers(0, v, t).astype(np.int32)
    ids[rng.random(t) < 0.15] = -1  # padding entries
    bags = np.repeat(np.arange(n_bags, dtype=np.int32), per_bag)
    table = rng.standard_normal((v, d)).astype(np.float32)
    want = eb_ref.embedding_bag(jnp.asarray(ids), jnp.asarray(bags),
                                jnp.asarray(table), n_bags)
    got = eb_ops.embedding_bag(jnp.asarray(ids), jnp.asarray(bags),
                               jnp.asarray(table), n_bags,
                               backend="pallas_interpret")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_embedding_bag_xla_backend():
    rng = np.random.default_rng(1)
    ids = rng.integers(0, 30, 24).astype(np.int32)
    bags = np.repeat(np.arange(8, dtype=np.int32), 3)
    table = rng.standard_normal((30, 8)).astype(np.float32)
    got = eb_ops.embedding_bag(jnp.asarray(ids), jnp.asarray(bags),
                               jnp.asarray(table), 8, backend="xla")
    want = np.zeros((8, 8), np.float32)
    for i, b in zip(ids, bags):
        if i >= 0:
            want[b] += table[i]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)
