"""Unit tests for query-graph canonicalization (repro.core.canon).

The planner relies on three properties: invariance (any authoring of an
isomorphic query canonicalizes identically), idempotence (canonical form
is a fixed point), and structure-first ordering (label changes never
perturb the canonical edge ordering, so same-structure queries share one
``plan_signature`` and therefore one compiled slot tick).
"""

from repro.core.canon import canonical_form, canonical_key
from repro.core.plan import compile_plan
from repro.core.query import QueryGraph, example_paper_query
from repro.core.registry import plan_signature


def chain(vlabels=(0, 1, 2)):
    return QueryGraph(3, vlabels, ((0, 1), (1, 2)), prec=frozenset({(0, 1)}))


def test_authoring_variants_canonicalize_identically():
    q1 = chain()
    # vertex ids permuted (2,1,0 carry the labels so the labeled graph
    # is the same), edges listed in the same relative order
    q2 = QueryGraph(3, (2, 1, 0), ((2, 1), (1, 0)), prec=frozenset({(0, 1)}))
    # edge order flipped, prec restated over the flipped ids
    q3 = QueryGraph(3, (0, 1, 2), ((1, 2), (0, 1)), prec=frozenset({(1, 0)}))
    c1, c2, c3 = (canonical_form(q).query for q in (q1, q2, q3))
    assert c1 == c2 == c3
    assert canonical_key(q1) == canonical_key(q2) == canonical_key(q3)


def test_maps_are_consistent_relabelings():
    q = QueryGraph(3, (5, 7, 9), ((2, 1), (1, 0)), prec=frozenset({(1, 0)}))
    c = canonical_form(q)
    # vertex_map carries labels and edge endpoints into the canonical graph
    for v in range(q.n_vertices):
        assert c.query.vertex_labels[c.vertex_map[v]] == q.vertex_labels[v]
    for e, (u, v) in enumerate(q.edges):
        cu, cv = c.query.edges[c.edge_map[e]]
        assert (cu, cv) == (c.vertex_map[u], c.vertex_map[v])
        assert c.query.edge_labels[c.edge_map[e]] == q.edge_labels[e]
    # prec maps through edge_map
    assert c.query.prec == frozenset(
        (c.edge_map[i], c.edge_map[j]) for i, j in q.prec)


def test_idempotent_on_canonical_form():
    for q in (chain(), example_paper_query()):
        c = canonical_form(q).query
        again = canonical_form(c)
        assert again.query == c
        assert again.vertex_map == tuple(range(c.n_vertices))
        assert again.edge_map == tuple(range(c.n_edges))


def test_labels_never_perturb_canonical_structure():
    """Different labelings of one structure must produce the same
    canonical edges/prec (labels are runtime slot data — if they steered
    the edge ordering, same-structure tenants would stop sharing ticks)."""
    variants = [chain((0, 1, 2)), chain((1, 0, 1)), chain((9, 9, 9))]
    forms = [canonical_form(q).query for q in variants]
    assert len({(f.edges, tuple(sorted(f.prec))) for f in forms}) == 1
    # and the compiled plans share one structural signature
    sigs = {plan_signature(compile_plan(f, 30)) for f in forms}
    assert len(sigs) == 1


def test_isomorphic_authorings_share_plan_signature():
    """The end goal: differently-authored isomorphic queries compile to
    ONE plan signature after canonicalization (they would NOT without:
    the decomposition consumes edge ids directly)."""
    tri_a = QueryGraph(3, (0, 1, 2), ((0, 1), (1, 2), (2, 0)),
                       prec=frozenset({(0, 1), (1, 2)}))
    # rotated vertex ids + reshuffled edge list + prec over the new ids
    tri_b = QueryGraph(3, (1, 2, 0), ((2, 0), (1, 2), (0, 1)),
                       prec=frozenset({(2, 1), (1, 0)}))
    ca, cb = canonical_form(tri_a).query, canonical_form(tri_b).query
    assert (ca.edges, tuple(sorted(ca.prec))) == \
        (cb.edges, tuple(sorted(cb.prec)))
    assert plan_signature(compile_plan(ca, 30)) == \
        plan_signature(compile_plan(cb, 30))


def test_paper_query_roundtrip():
    q = example_paper_query()
    c = canonical_form(q)
    assert c.query.n_edges == q.n_edges
    assert len(c.query.prec) == len(q.prec)
    assert sorted(c.query.vertex_labels) == sorted(q.vertex_labels)
    # canonical form still a valid strict partial order / TC query
    assert c.query.is_tc_query() == q.is_tc_query()
