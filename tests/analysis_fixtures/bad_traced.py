"""Linter fixture: known-bad traced-scope patterns.

Never imported — only parsed by ``tests/test_analysis.py`` to pin the
golden findings of ``repro.analysis.ast_lint``.
"""

import jax
import jax.numpy as jnp
import numpy as np


@jax.jit
def bad_cast(x):
    return int(x) + 1                       # TRC101


@jax.jit
def bad_numpy(x):
    return np.sum(x)                        # TRC102


@jax.jit
def bad_sync(x):
    return x.tolist()                       # TRC103


@jax.jit
def bad_branch(x):
    if x > 0:                               # TRC104
        return x
    return -x


@jax.jit
def suppressed_cast(x):
    return int(x)  # analysis: ignore[TRC101]


@jax.jit
def ok_none_check(x, y=None):
    if y is None:                           # identity test: exempt
        return x
    return x + y


@jax.jit
def ok_shape_kills_taint(x):
    n = x.shape[0]
    if n > 4:                               # static under jit: no finding
        return jnp.sum(x[:4])
    return jnp.sum(x)


def host_helper(v):
    # untraced host code: np/int/if are all fine here
    arr = np.asarray(v)
    if arr.size > 3:
        return int(arr.sum())
    return 0
