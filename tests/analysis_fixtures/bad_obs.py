"""Linter fixture: repro.obs emission inside traced scope (TRC107).

Never imported — only parsed by ``tests/test_analysis.py`` to pin the
golden findings of ``repro.analysis.ast_lint``.
"""

import jax

from repro.obs import MetricsRegistry, Tracer

REG = MetricsRegistry()
TR = Tracer("/dev/null")


@jax.jit
def bad_obs_emit(state, x):
    REG.counter("tick.n_ticks").inc()       # TRC107: host cb in jit
    return state + x


def ok_obs_host(reg: MetricsRegistry, lat_ms: float):
    # untraced host code: emission is exactly where it belongs
    reg.histogram("tick.latency_ms").observe(lat_ms)
    TR.record("tick.barrier", lat_ms)
