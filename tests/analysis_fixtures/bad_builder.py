"""Linter fixture: builder closure + donation hazards (TRC105/TRC106).

Never imported — only parsed by ``tests/test_analysis.py``.
"""

import jax
import jax.numpy as jnp


def build_leaky_tick(plan, window):
    """Closes the dynamic ``window`` over the returned traced closure —
    the exact bug class the PR-2 traced-window work fixed by hand."""

    def tick(state, batch):
        return state + jnp.minimum(batch, window)   # TRC105

    return tick


def serve(plan, window):
    tick = build_leaky_tick(plan, window)
    return jax.jit(tick)                            # TRC106: no donate


def serve_donating(plan, window):
    tick = build_leaky_tick(plan, window)
    return jax.jit(tick, donate_argnums=(0,))       # ok


def build_clean_tick(plan):
    """Only the structural ``plan`` is captured: no findings."""

    def tick(state, batch, window):
        return state + jnp.minimum(batch, window)

    return tick
