"""Property test (hypothesis): ``plan_check`` accepts every plan the
planner compiles from a random connected query — the verifier must never
reject legitimate planner output, only hand-built violations."""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (see requirements-dev.txt)")
from hypothesis import assume, given, settings, strategies as st

from test_api_props import abstract_queries, make_query

from repro.analysis import ERROR
from repro.analysis.plan_check import verify_plan
from repro.core.plan import compile_plan


@settings(max_examples=80, deadline=None)
@given(spec=abstract_queries(), window=st.integers(1, 1000))
def test_plan_check_accepts_every_planner_plan(spec, window):
    q = make_query(spec)
    assume(q.is_connected())
    plan = compile_plan(q, window)
    findings = verify_plan(plan, raise_on_error=True)  # raises on ERROR
    assert all(f.severity != ERROR for f in findings)
