"""Substrate tests: optimizer modes, checkpoint roundtrip/reshard/async,
fault-tolerant restart determinism, gradient compression, coalescer."""

import os

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint import (
    AsyncCheckpointer,
    CheckpointError,
    checkpoint_steps,
    latest_step,
    load_manifest,
    restore_checkpoint,
    save_checkpoint,
    validate_checkpoint,
)
from repro.optim import AdamWConfig, adamw_init, adamw_update, cosine_with_warmup
from repro.optim.compress import dequantize_tree, quantize_tree
from repro.runtime.fault import FaultTolerantLoop, SimulatedFailure
from repro.runtime.straggler import TickCoalescer


def toy_problem():
    rng = np.random.default_rng(0)
    w_true = rng.standard_normal((8, 4)).astype(np.float32)
    x = rng.standard_normal((64, 8)).astype(np.float32)
    y = x @ w_true

    def loss(params):
        pred = x @ params["w"] + params["b"]
        return jnp.mean((pred - y) ** 2)

    params = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
    return loss, params


@pytest.mark.parametrize("mode", ["fp32", "factored", "int8"])
def test_adamw_modes_converge(mode):
    loss, params = toy_problem()
    cfg = AdamWConfig(state_mode=mode, weight_decay=0.0)
    state = adamw_init(params, cfg)
    l0 = float(loss(params))
    step = jax.jit(lambda p, s: adamw_update(jax.grad(loss)(p), s, p, 0.05, cfg))
    for _ in range(150):
        params, state, _ = step(params, state)
    l1 = float(loss(params))
    assert l1 < l0 * 0.05, (l0, l1)


def test_factored_state_is_smaller():
    _, params = toy_problem()
    big = {"w": jnp.zeros((256, 128))}
    full = adamw_init(big, AdamWConfig(state_mode="fp32"))
    fact = adamw_init(big, AdamWConfig(state_mode="factored"))
    size = lambda t: sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(t))
    assert size(fact) < size(full) * 0.6


def test_schedule():
    lr = cosine_with_warmup(1e-3, warmup=10, total=100)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1e-3) < 1e-9
    assert float(lr(100)) < float(lr(50)) < float(lr(10))


def test_checkpoint_roundtrip(tmp_path):
    tree = {"a": jnp.arange(12.0).reshape(3, 4),
            "b": {"c": jnp.array(3), "d": [jnp.ones(2), jnp.zeros(1)]}}
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    got = restore_checkpoint(str(tmp_path), 7, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_save_hashes_while_writing_no_reread(tmp_path):
    """``save_checkpoint`` must compute the manifest's npz hash WHILE
    streaming the file out — not by re-reading what it just wrote
    (ROADMAP: zipfile backpatches local headers on close, which is why
    the writer wrapper must refuse to be seekable).  Proof: poison the
    re-read hasher; the save must still succeed, and the recorded hash
    must equal an independent full re-read of the published file."""
    import hashlib

    from repro.checkpoint import ckpt as ckpt_mod

    def _boom(path):
        raise AssertionError(f"save re-read {path} to hash it")

    orig = ckpt_mod._sha256
    ckpt_mod._sha256 = _boom
    try:
        tree = {"w": jnp.arange(4096.0).reshape(64, 64),
                "b": {"c": jnp.ones(7, jnp.int32)}}
        save_checkpoint(str(tmp_path), 9, tree, extra={"tag": "hw"})
    finally:
        ckpt_mod._sha256 = orig
    want = load_manifest(str(tmp_path), 9)["npz_sha256"]
    got = hashlib.sha256((tmp_path / "step_9.npz").read_bytes()).hexdigest()
    assert want == got
    # the hash still ties the pair together: validation + restore work
    validate_checkpoint(str(tmp_path), 9)
    back = restore_checkpoint(str(tmp_path), 9, tree)
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))


def test_hashing_writer_sequential_digest(tmp_path):
    """The wrapper's running digest equals sha256 of the bytes written,
    and it refuses the seek/read operations zipfile would need to
    backpatch (that refusal is what keeps the stream sequential)."""
    import hashlib

    from repro.checkpoint.ckpt import _HashingWriter

    path = tmp_path / "blob"
    with open(path, "wb") as f:
        hw = _HashingWriter(f)
        for chunk in (b"alpha", b"", b"beta" * 1000, bytes(range(256))):
            hw.write(chunk)
        hw.flush()
        assert not hw.seekable()
        with pytest.raises(OSError):
            hw.tell()
        with pytest.raises(OSError):
            hw.read()
    assert hw.hexdigest() == hashlib.sha256(path.read_bytes()).hexdigest()


def test_latest_step_skips_torn_checkpoint(tmp_path):
    """A truncated npz (crash mid-write / bad disk) must be invisible to
    latest_step and raise CheckpointError — not crash — on restore."""
    tree = {"w": jnp.arange(6.0)}
    save_checkpoint(str(tmp_path), 3, tree)
    save_checkpoint(str(tmp_path), 7, tree)
    torn = tmp_path / "step_7.npz"
    torn.write_bytes(torn.read_bytes()[:40])
    assert checkpoint_steps(str(tmp_path)) == [3, 7]
    assert latest_step(str(tmp_path)) == 3
    with pytest.raises(CheckpointError):
        restore_checkpoint(str(tmp_path), 7, tree)
    got = restore_checkpoint(str(tmp_path), 3, tree)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.arange(6.0))


def test_bad_or_missing_manifest_is_torn(tmp_path):
    tree = {"w": jnp.ones(2)}
    save_checkpoint(str(tmp_path), 5, tree, extra={"tag": "svc"})
    assert load_manifest(str(tmp_path), 5)["tag"] == "svc"
    (tmp_path / "step_5.json").write_text("{not json")
    assert latest_step(str(tmp_path)) is None
    with pytest.raises(CheckpointError):
        load_manifest(str(tmp_path), 5)
    os.remove(tmp_path / "step_5.json")
    assert latest_step(str(tmp_path)) is None      # manifest is mandatory


def test_crash_mid_step_overwrite_is_torn(tmp_path):
    """Overwriting an existing step is two os.replace calls; a crash in
    between leaves a NEW manifest paired with the OLD npz — both
    individually valid.  The manifest's npz hash must expose the torn
    pair."""
    save_checkpoint(str(tmp_path), 5, {"w": jnp.zeros(3)}, extra={"gen": 1})
    old_npz = (tmp_path / "step_5.npz").read_bytes()
    save_checkpoint(str(tmp_path), 5, {"w": jnp.ones(3)}, extra={"gen": 2})
    assert latest_step(str(tmp_path)) == 5
    # simulate the crash: gen-2 manifest published, npz still gen-1
    (tmp_path / "step_5.npz").write_bytes(old_npz)
    with pytest.raises(CheckpointError, match="does not match"):
        validate_checkpoint(str(tmp_path), 5)
    assert latest_step(str(tmp_path)) is None


def test_missing_arrays_are_loud_schema_drift(tmp_path):
    """The npz publishes atomically, so a missing array can only mean
    the caller's state schema drifted — that must raise ValueError
    (loud), NOT CheckpointError, lest recovery silently skip every
    checkpoint and restart from scratch."""
    save_checkpoint(str(tmp_path), 1, {"a": jnp.ones(3)})
    with pytest.raises(ValueError, match="missing"):
        restore_checkpoint(str(tmp_path), 1,
                           {"a": jnp.ones(3), "b": jnp.ones(2)})


def test_fault_loop_resumes_past_torn_checkpoint(tmp_path):
    """FaultTolerantLoop restore falls back to the newest USABLE step."""
    make_init = lambda: {"x": jnp.zeros((), jnp.int32)}
    step_fn = lambda state, i: {"x": state["x"] + 1}
    loop = FaultTolerantLoop(str(tmp_path), step_fn, make_init, ckpt_every=5)
    final = loop.run(20)                       # ckpts at 5, 10, 15, 20
    assert int(final["x"]) == 20
    torn = tmp_path / "step_20.npz"
    torn.write_bytes(torn.read_bytes()[:32])
    loop2 = FaultTolerantLoop(str(tmp_path), step_fn, make_init, ckpt_every=5)
    state, start = loop2._resume()
    assert start == 15 and int(state["x"]) == 15


def test_async_checkpointer(tmp_path):
    ck = AsyncCheckpointer(str(tmp_path))
    tree = {"w": jnp.ones((16, 16))}
    for s in (10, 20):
        ck.save(s, tree)
    ck.wait()
    assert latest_step(str(tmp_path)) == 20


def test_fault_tolerant_loop_determinism(tmp_path):
    """A crash mid-run + restart must reproduce the uninterrupted result."""
    loss, params0 = toy_problem()
    cfg = AdamWConfig(weight_decay=0.0)

    def make_state():
        p = {"w": jnp.zeros((8, 4)), "b": jnp.zeros((4,))}
        return {"params": p, "opt": adamw_init(p, cfg)}

    @jax.jit
    def train_step(state):
        g = jax.grad(loss)(state["params"])
        p, o, _ = adamw_update(g, state["opt"], state["params"], 0.05, cfg)
        return {"params": p, "opt": o}

    # reference: uninterrupted
    ref = make_state()
    for _ in range(40):
        ref = train_step(ref)

    crashed = {"done": False}

    def step_fn(state, i):
        if i == 23 and not crashed["done"]:
            crashed["done"] = True
            raise SimulatedFailure("injected")
        return train_step(state)

    loop = FaultTolerantLoop(
        str(tmp_path), step_fn, make_state, ckpt_every=10)
    final = loop.run(40)
    assert crashed["done"] and loop.restarts == 1
    np.testing.assert_allclose(
        np.asarray(final["params"]["w"]), np.asarray(ref["params"]["w"]),
        rtol=1e-6, atol=1e-7)


def test_quantize_error_feedback():
    rng = np.random.default_rng(0)
    g = {"w": jnp.asarray(rng.standard_normal((64, 64)), jnp.float32)}
    q, s, res = quantize_tree(g)
    deq = dequantize_tree(q, s)
    err = float(jnp.abs(deq["w"] - g["w"]).max())
    scale = float(s["w"])
    assert err <= scale * 0.5 + 1e-6
    # residual carries exactly the quantization error
    np.testing.assert_allclose(
        np.asarray(res["w"]), np.asarray(g["w"] - deq["w"]),
        rtol=1e-5, atol=1e-6)
    # int8 payload is 4x smaller than fp32
    assert q["w"].dtype == jnp.int8


def test_tick_coalescer_adapts():
    c = TickCoalescer(batch=256, target_latency_ms=50)
    # fast ticks + growing queue -> batch grows
    for _ in range(5):
        b = c.record(tick_latency_ms=5.0, queue_depth=10_000)
    assert b > 256
    peak = b
    # slow ticks -> batch shrinks
    for _ in range(10):
        b = c.record(tick_latency_ms=200.0, queue_depth=0)
    assert b < peak * 0.5
