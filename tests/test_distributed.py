"""Multi-device engine parity: run the shard_map tick on 4 virtual CPU
devices in a subprocess (device count must be set before jax init, and
the main test process must keep seeing exactly 1 device)."""

import os
import pathlib
import subprocess
import sys


def test_sharded_engine_matches_single_device():
    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    env["PYTHONPATH"] = str(root / "src")
    proc = subprocess.run(
        [sys.executable, str(root / "tests" / "_dist_engine_check.py")],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "DIST-OK" in proc.stdout
