"""Property-based tests (hypothesis) for the engine's core invariants:

1. engine state == brute-force oracle after every tick (exactness);
2. batch-size invariance (streaming consistency, Definition 13);
3. SJ-tree baseline + timing post-filter finds the same matches;
4. random-walk-generated queries (paper §6.2) admit their own embedding.
"""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

import jax

from repro.core import compile_plan
from repro.core.engine import build_tick, current_matches
from repro.core.oracle import DataEdge, OracleEngine
from repro.core.query import QueryGraph
from repro.core.sjtree import compile_sjtree_plan, timing_postfilter
from repro.core.state import init_state, make_batch
from repro.stream.generator import (
    StreamConfig,
    random_walk_query,
    synth_traffic_stream,
    to_batches,
)

# A small catalog of structurally distinct queries (compiled once).
CATALOG = [
    # chain with full timing order (TC)
    QueryGraph(3, (0, 1, 0), ((0, 1), (1, 2)), prec=frozenset({(0, 1)})),
    # chain, no timing (2 singletons)
    QueryGraph(3, (0, 1, 0), ((0, 1), (1, 2))),
    # fork: two out-edges, one timing constraint
    QueryGraph(3, (0, 1, 1), ((0, 1), (0, 2)), prec=frozenset({(1, 0)})),
    # triangle with partial timing
    QueryGraph(3, (0, 0, 1), ((0, 1), (1, 2), (2, 0)),
               prec=frozenset({(0, 2)})),
]

_PLANS = {}


def get_plan_tick(qi, window):
    key = (qi, window)
    if key not in _PLANS:
        plan = compile_plan(CATALOG[qi], window, level_capacity=2048,
                            l0_capacity=2048, max_new=1024)
        _PLANS[key] = (plan, jax.jit(build_tick(plan)))
    return _PLANS[key]


def run_stream(plan, tick, stream, batch_size):
    state = init_state(plan)
    for b in to_batches(stream, batch_size):
        state, _ = tick(state, make_batch(**b))
    assert int(state.stats.n_overflow) == 0
    return state


@st.composite
def small_streams(draw):
    n = draw(st.integers(20, 60))
    nv = draw(st.integers(4, 8))
    seed = draw(st.integers(0, 10_000))
    return synth_traffic_stream(StreamConfig(
        n_edges=n, n_vertices=nv, n_vertex_labels=2, n_edge_labels=2,
        seed=seed, ts_step_max=2))


@settings(max_examples=10, deadline=None)
@given(stream=small_streams(), qi=st.integers(0, len(CATALOG) - 1))
def test_engine_matches_oracle(stream, qi):
    window = 15
    plan, tick = get_plan_tick(qi, window)
    state = run_stream(plan, tick, stream, batch_size=8)
    oracle = OracleEngine(CATALOG[qi], window)
    for e in stream:
        oracle.insert(e)
    assert current_matches(plan, state) == oracle.matches()


@settings(max_examples=8, deadline=None)
@given(stream=small_streams(), qi=st.integers(0, len(CATALOG) - 1),
       bs=st.sampled_from([3, 7, 16]))
def test_batch_size_invariance(stream, qi, bs):
    window = 12
    plan, tick = get_plan_tick(qi, window)
    s1 = run_stream(plan, tick, stream, batch_size=1)
    s2 = run_stream(plan, tick, stream, batch_size=bs)
    assert current_matches(plan, s1) == current_matches(plan, s2)
    assert int(s1.stats.n_matches_total) == int(s2.stats.n_matches_total)


@settings(max_examples=6, deadline=None)
@given(stream=small_streams())
def test_sjtree_postfilter_equals_engine(stream):
    q = CATALOG[0]
    window = 15
    plan, tick = get_plan_tick(0, window)
    state = run_stream(plan, tick, stream, batch_size=8)
    want = current_matches(plan, state)

    sj_plan, trel = compile_sjtree_plan(q, window, level_capacity=2048,
                                        l0_capacity=2048, max_new=1024)
    sj_tick = jax.jit(build_tick(sj_plan))
    sj_state = run_stream(sj_plan, sj_tick, stream, batch_size=8)
    # post-filter SJ-tree's final table by the original timing order
    tbl = sj_state.l0[-1] if sj_plan.l0_joins else None
    assert tbl is not None
    ets = np.asarray(tbl.ets)
    ok = timing_postfilter(ets, np.asarray(tbl.valid), trel)
    # canonicalize through current_matches on a patched state
    patched = sj_state._replace(
        l0=sj_state.l0[:-1] + (tbl._replace(valid=jax.numpy.asarray(ok)),))
    got = current_matches(sj_plan, patched)

    def canon(ms):
        return {frozenset((e, t) for e, t in m) for m in ms}

    assert canon(got) == canon(want)


def test_random_walk_query_has_embedding():
    stream = synth_traffic_stream(StreamConfig(
        n_edges=300, n_vertices=40, n_vertex_labels=3, n_edge_labels=3,
        seed=7, ts_step_max=2))
    made = 0
    for seed in range(40):
        q = random_walk_query(stream, n_query_edges=3, seed=seed, window=40)
        if q is None:
            continue
        made += 1
        # the walked subgraph itself is an embedding: the full stream
        # (window = whole span) must contain >= 1 match
        window = int(stream[-1].ts) + 1
        plan = compile_plan(q, window, level_capacity=8192, l0_capacity=8192,
                            max_new=4096)
        tick = jax.jit(build_tick(plan))
        state = init_state(plan)
        for b in to_batches(stream, 64):
            state, _ = tick(state, make_batch(**b))
        if int(state.stats.n_overflow) == 0:
            assert int(state.stats.n_matches_total) >= 1
        if made >= 5:
            break
    assert made >= 3, "query generator too flaky"
