"""GNN model tests: shapes/NaNs, aggregation semantics, NequIP rotation
equivariance + force consistency, neighbor sampler invariants."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models.gnn import models as M
from repro.models.gnn import nequip as NQ
from repro.models.gnn.message import degrees, gather_scatter, segment_softmax
from repro.models.gnn.sampler import CSRGraph, sample_subgraph, subgraph_shapes


def rand_graph(rng, n=20, e=60, f=16, classes=5, pad_e=8):
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    src = np.concatenate([src, np.full(pad_e, -1, np.int32)])
    dst = np.concatenate([dst, np.full(pad_e, -1, np.int32)])
    return {
        "x": jnp.asarray(rng.standard_normal((n, f)), jnp.float32),
        "edge_src": jnp.asarray(src),
        "edge_dst": jnp.asarray(dst),
        "labels": jnp.asarray(rng.integers(0, classes, n).astype(np.int32)),
    }


@pytest.mark.parametrize("arch", ["gat", "gin", "pna"])
def test_forward_shapes_nans(arch):
    rng = np.random.default_rng(0)
    cfg = M.GNNConfig(arch=arch, n_layers=2, d_in=16, d_hidden=12,
                      n_heads=4, n_classes=5)
    g = rand_graph(rng)
    params = M.INITS[arch](jax.random.PRNGKey(0), cfg)
    out = M.FORWARDS[arch](params, g, cfg)
    assert out.shape == (20, 5)
    assert np.isfinite(np.asarray(out)).all()
    loss, _ = M.node_classification_loss(params, g, cfg)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: M.node_classification_loss(p, g, cfg)[0])(params)
    for gl in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(gl)).all()


def test_gather_scatter_against_numpy():
    rng = np.random.default_rng(1)
    n, e, d = 10, 40, 6
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    x = rng.standard_normal((n, d)).astype(np.float32)
    got = gather_scatter(jnp.asarray(x), jnp.asarray(src), jnp.asarray(dst), n)
    want = np.zeros((n, d), np.float32)
    for s, t in zip(src, dst):
        want[t] += x[s]
    np.testing.assert_allclose(np.asarray(got), want, rtol=1e-5, atol=1e-5)


def test_segment_softmax_sums_to_one():
    rng = np.random.default_rng(2)
    e, n, h = 50, 8, 3
    seg = rng.integers(0, n, e).astype(np.int32)
    sc = rng.standard_normal((e, h)).astype(np.float32)
    alpha = segment_softmax(jnp.asarray(sc), jnp.asarray(seg), n)
    sums = np.zeros((n, h))
    for i, s in enumerate(seg):
        sums[s] += np.asarray(alpha)[i]
    present = np.isin(np.arange(n), seg)
    np.testing.assert_allclose(sums[present], 1.0, rtol=1e-5)


def _mol_graph(rng, n=12, e=40):
    pos = rng.standard_normal((n, 3)).astype(np.float32) * 2
    src = rng.integers(0, n, e).astype(np.int32)
    dst = rng.integers(0, n, e).astype(np.int32)
    keep = src != dst
    src, dst = src[keep], dst[keep]
    return {
        "species": jnp.asarray(rng.integers(0, 4, n).astype(np.int32)),
        "pos": jnp.asarray(pos),
        "edge_src": jnp.asarray(src),
        "edge_dst": jnp.asarray(dst),
    }


def test_nequip_equivariance():
    """Energy invariant under global rotation; forces rotate covariantly."""
    rng = np.random.default_rng(3)
    cfg = NQ.NequIPConfig(n_layers=2, channels=8, n_rbf=4)
    params = NQ.init(jax.random.PRNGKey(0), cfg)
    g = _mol_graph(rng)

    # random rotation via QR
    a = rng.standard_normal((3, 3))
    q, r = np.linalg.qr(a)
    q = q * np.sign(np.diag(r))
    if np.linalg.det(q) < 0:
        q[:, 0] *= -1
    R = jnp.asarray(q.astype(np.float32))

    e1, f1 = NQ.energy_and_forces(params, g, cfg)
    g_rot = {**g, "pos": g["pos"] @ R.T}
    e2, f2 = NQ.energy_and_forces(params, g_rot, cfg)

    np.testing.assert_allclose(float(e1), float(e2), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(f1 @ R.T), np.asarray(f2),
                               rtol=2e-3, atol=2e-4)


def test_nequip_translation_invariance():
    rng = np.random.default_rng(4)
    cfg = NQ.NequIPConfig(n_layers=2, channels=8, n_rbf=4)
    params = NQ.init(jax.random.PRNGKey(0), cfg)
    g = _mol_graph(rng)
    e1 = NQ.forward(params, g, cfg)
    g2 = {**g, "pos": g["pos"] + jnp.asarray([1.7, -0.3, 2.2])}
    e2 = NQ.forward(params, g2, cfg)
    np.testing.assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-5)


def test_sampler_invariants():
    rng = np.random.default_rng(5)
    n, e = 200, 2000
    src = rng.integers(0, n, e).astype(np.int64)
    dst = rng.integers(0, n, e).astype(np.int64)
    g = CSRGraph(n, src, dst)
    seeds = rng.choice(n, 16, replace=False)
    fanouts = (5, 3)
    sub = sample_subgraph(g, seeds, fanouts, rng)
    n_max, e_max = subgraph_shapes(16, fanouts)
    assert sub["nodes"].shape == (n_max,)
    assert sub["edge_src"].shape == (e_max,)
    # seeds come first in node list
    np.testing.assert_array_equal(sub["nodes"][:16], seeds)
    # every sampled edge exists in the original graph
    edge_set = set(zip(src.tolist(), dst.tolist()))
    for s_l, d_l in zip(sub["edge_src"], sub["edge_dst"]):
        if s_l < 0:
            continue
        u, v = int(sub["nodes"][s_l]), int(sub["nodes"][d_l])
        assert (u, v) in edge_set
