"""Cross-tenant prefix sharing (repro.core.share): differential proofs.

The SharedPrefixForest must be INVISIBLE in results and very visible in
cost:

* per-tenant match multisets with sharing enabled are exactly equal to
  sharing-disabled runs and to the brute-force oracle (REF and
  PALLAS_INTERPRET), including across unregister-then-reregister churn
  (epoch semantics: a mid-stream tenant gets fresh nodes, never
  inherited history) and crash/restore;
* K tenants sharing one prefix build the prefix tables ONCE — one
  forest node chain, leaf refcount K — and partial overlap (a 3-chain
  tenant over a 2-chain tenant's pattern) shares the common nodes and
  diverges after;
* register/unregister storms leave no orphaned prefix tables and no
  orphaned slot groups;
* checkpoints snapshot the forest (tables + refcounts + signatures) and
  restore resumes sharing with zero warm recompiles.
"""

from collections import Counter

import pytest

from repro.api import Pattern, StreamSession
from repro.core.join import JoinBackend
from repro.core.multi import SlotTickCache
from repro.core.query import QueryGraph
from repro.core.share import prefix_chain
from repro.runtime.fault import SimulatedFailure
from repro.runtime.service import ContinuousSearchService

from test_engine_oracle import small_stream
from test_service_restore import EventLog, oracle_reported

CAP = dict(level_capacity=512, l0_capacity=512, max_new=256)
SERVE = dict(batch_size=16, min_batch=16, max_batch=16)
W = 50          # one window for all patterns: the prefix signature
                # includes the window span, so sharing requires equality


def chain3():
    """3-chain whose first two edges are exactly ``chain2()``."""
    return QueryGraph(4, (0, 1, 2, 0), ((0, 1), (1, 2), (2, 3)),
                      prec=frozenset({(0, 1), (1, 2)}))


def chain2():
    return QueryGraph(3, (0, 1, 2), ((0, 1), (1, 2)),
                      prec=frozenset({(0, 1)}))


def chain2_other_labels():
    return QueryGraph(3, (1, 2, 0), ((0, 1), (1, 2)),
                      prec=frozenset({(0, 1)}))


def fork():
    """Two TC-subqueries (fork with e1 ≺ e0): exercises the
    L0-delta-join path downstream of a shared prefix."""
    return QueryGraph(3, (0, 1, 2), ((0, 1), (0, 2)),
                      prec=frozenset({(1, 0)}))


def tri():
    """Timing-chained triangle: the depth-3 node's edge binds BOTH
    endpoints to already-known prefix vertices (no new columns)."""
    return QueryGraph(3, (0, 1, 2), ((0, 1), (1, 2), (2, 0)),
                      prec=frozenset({(0, 1), (1, 2)}))


def stream160(seed=5):
    return small_stream(160, n_vertices=8, n_vertex_labels=3, seed=seed)


def svc_pair(tc, backend=JoinBackend.REF, **kw):
    """(sharing-enabled, sharing-disabled) twin services."""
    mk = lambda share: ContinuousSearchService(
        slots_per_group=4, tick_cache=tc, backend=backend,
        enable_sharing=share, **CAP, **kw)
    return mk(True), mk(False)


def reported(svc, stream, **serve):
    """serve the stream, returning the Counter of (qid, match-key)
    reports plus per-tick ServeInfo records."""
    from test_service_restore import event_key
    events, infos = [], []

    def on_match(qid, bindings, ets):
        plan = svc.registry.get(qid).plan
        for b, t in zip(bindings, ets):
            events.append((qid, event_key(plan, b, t)))

    svc.serve_stream(stream, on_match=on_match, on_tick=infos.append,
                     **SERVE, **serve)
    return Counter(events), infos


# --------------------------------------------------------------------- #
# differential: shared == unshared == oracle
# --------------------------------------------------------------------- #
@pytest.mark.parametrize(
    "backend", [JoinBackend.REF, JoinBackend.PALLAS_INTERPRET])
def test_sharing_differential_oracle(backend):
    tc = SlotTickCache()
    stream = stream160()
    shared, plain = svc_pair(tc, backend)
    queries = [chain3(), chain2(), chain2(), chain2_other_labels(), fork(),
               tri()]
    qs = [shared.register(q, W) for q in queries]
    qp = [plain.register(q, W) for q in queries]
    assert qs == qp

    # trie shape: chain3 shares depth-1/2 with both chain2 tenants and
    # owns depth 3; the triangle shares depth-1/2 with them too (its
    # first two chain edges ARE a 2-chain) and owns its closing depth-3
    # node; the relabeled chain2 and the fork get their own chains
    # (labels are part of the prefix signature)
    fs = shared.forest_stats()
    assert fs.n_tenants == 6
    leaf2 = shared.shared_prefix(qs[1])
    assert leaf2.depth == 2                  # chain3 + 2x chain2 + tri
    assert leaf2.n_tenants == 4
    assert shared.shared_prefix(qs[0]).depth == 3
    assert shared.shared_prefix(qs[0]).n_tenants == 1
    assert shared.shared_prefix(qs[5]).depth == 3
    assert shared.shared_prefix(qs[5]).n_tenants == 1
    assert plain.forest_stats() is None
    assert plain.shared_prefix(qp[0]) is None

    count_s, infos_s = reported(shared, stream)
    count_p, infos_p = reported(plain, stream)
    assert count_s and count_s == count_p      # exact multiset equality
    assert all(i.n_shared_prefix_ticks == len(shared.forest)
               for i in infos_s)
    assert all(i.n_shared_prefix_ticks == 0 for i in infos_p)

    for qid, q in zip(qs, queries):
        want_reported, want_window = oracle_reported(q, W, stream)
        got = {k for (qq, k) in count_s if qq == qid}
        assert got == want_reported
        assert shared.matches(qid) == want_window == plain.matches(qid)
        assert shared.tenant_overflow(qid) == 0
    # non-vacuous: the window and the reports both carry matches
    assert sum(count_s.values()) > 50
    assert any(shared.matches(qid) for qid in qs)


def test_sharing_differential_under_overflow():
    """Saturated tables drop appends deterministically, and a shared
    node drops exactly the appends each aliasing tenant's own table
    would have dropped — reports stay multiset-identical even past
    capacity, and the pressure is visible through the tenant's
    overflow counters either way."""
    tiny = dict(level_capacity=16, l0_capacity=16, max_new=4)
    tc = SlotTickCache()
    mk = lambda share: ContinuousSearchService(
        slots_per_group=4, tick_cache=tc, enable_sharing=share, **tiny)
    shared, plain = mk(True), mk(False)
    queries = [chain3(), chain2(), chain2()]
    qs = [shared.register(q, W) for q in queries]
    qp = [plain.register(q, W) for q in queries]

    stream = stream160()
    count_s, infos_s = reported(shared, stream)
    count_p, infos_p = reported(plain, stream)
    assert count_s == count_p
    assert sum(shared.tenant_overflow(q) for q in qs) > 0
    for q_s, q_p in zip(qs, qp):
        assert shared.matches(q_s) == plain.matches(q_p)
        assert shared.tenant_overflow(q_s) == plain.tenant_overflow(q_p) > 0
    # per-tenant attribution of shared-node drops makes the serve loop's
    # overflow trace IDENTICAL to the unshared run's, tick by tick
    assert [i.n_overflow for i in infos_s] == \
        [i.n_overflow for i in infos_p]
    assert any(i.n_overflow > 0 for i in infos_s)


# --------------------------------------------------------------------- #
# scale: K tenants sharing one prefix build its tables once
# --------------------------------------------------------------------- #
def test_k_tenants_one_prefix_chain():
    K = 12
    tc = SlotTickCache()
    svc = ContinuousSearchService(slots_per_group=16, tick_cache=tc,
                                  enable_sharing=True, **CAP)
    qids = [svc.register(chain2(), W) for _ in range(K)]
    fs = svc.forest_stats()
    assert fs.n_nodes == 2                    # depth-1 + depth-2, ONCE
    assert fs.n_shared_nodes == 2
    assert fs.n_tenants == K
    leaves = {svc.shared_prefix(q) for q in qids}
    assert len(leaves) == 1                   # every tenant: same leaf
    assert leaves.pop().n_tenants == K        # refcount K
    # one slot group, one suffix tick build, two node-tick builds
    assert len(svc._iter_groups()) == 1
    assert svc.n_compiles == 1
    assert tc.n_builds == 3

    # adding a chain3 tenant reuses the chain, adds ONE node + one group
    q3 = svc.register(chain3(), W)
    fs = svc.forest_stats()
    assert fs.n_nodes == 3 and fs.n_tenants == K + 1
    assert svc.shared_prefix(q3).depth == 3
    assert svc.shared_prefix(qids[0]).n_tenants == K + 1

    # serving works and the tables really are shared: every chain2
    # tenant reports identical per-tick results
    from repro.stream.generator import to_batches
    for b in to_batches(stream160(), 16):
        out = svc.ingest(b)
        assert len({int(out[q].n_new_matches) for q in qids}) == 1


# --------------------------------------------------------------------- #
# churn: epochs isolate history; storms leave no orphans
# --------------------------------------------------------------------- #
def test_churn_epochs_match_unshared_and_oracle():
    tc = SlotTickCache()
    stream = stream160(seed=5)
    half = 80
    shared, plain = svc_pair(tc)
    a_s, a_p = shared.register(chain3(), W), plain.register(chain3(), W)
    b_s, b_p = shared.register(chain2(), W), plain.register(chain2(), W)

    count1_s, _ = reported(shared, stream[:half])
    count1_p, _ = reported(plain, stream[:half])
    assert count1_s == count1_p

    # B leaves; a NEW chain2 tenant arrives mid-stream.  Its prefix is
    # signature-equal to A's depth-2 node but epoch-separated: sharing
    # A's table would hand it pre-registration history.
    shared.unregister(b_s)
    plain.unregister(b_p)
    c_s, c_p = shared.register(chain2(), W), plain.register(chain2(), W)
    assert shared.shared_prefix(c_s).epoch == half
    assert shared.shared_prefix(c_s).n_tenants == 1
    assert shared.forest_stats().n_nodes == 5      # A's 3 + C's fresh 2

    count2_s, _ = reported(shared, stream[half:])
    count2_p, _ = reported(plain, stream[half:])
    assert count2_s == count2_p
    assert shared.matches(a_s) == plain.matches(a_p)
    assert shared.matches(c_s) == plain.matches(c_p)

    # C is oracle-exact over exactly the suffix it was registered for
    want_reported, want_window = oracle_reported(chain2(), W, stream[half:])
    assert {k for (q, k) in count2_s if q == c_s} == want_reported
    assert shared.matches(c_s) == want_window

    # full storm: everyone leaves -> no orphaned tables, no orphan groups
    shared.unregister(a_s)
    shared.unregister(c_s)
    assert len(shared.forest) == 0
    assert shared.forest_stats() == (0, 0, 0, 0)
    assert not shared._groups


def test_failed_registration_rolls_back_chain_and_qid():
    """A failure after chain acquisition (e.g. the suffix tick compile)
    must leave NO trace: no half-registered qid, no phantom forest
    handle, no empty group entry — and a clean retry must work."""
    tc = SlotTickCache()
    svc = ContinuousSearchService(slots_per_group=2, tick_cache=tc,
                                  enable_sharing=True, **CAP)
    q0 = svc.register(chain2(), W)
    orig = svc._new_group
    svc._new_group = lambda template, leaf=None: (_ for _ in ()).throw(
        RuntimeError("injected compile failure"))
    with pytest.raises(RuntimeError, match="injected"):
        svc.register(tri(), W)
    svc._new_group = orig
    assert svc.n_active == 1 and len(svc.registry) == 1
    assert svc.forest_stats().n_tenants == 1
    assert len(svc.forest) == 2           # only q0's chain survives
    assert len(svc._groups) == 1          # no empty group-key entry
    qt = svc.register(tri(), W)           # clean retry
    assert svc.n_active == 2
    svc.unregister(q0)
    svc.unregister(qt)
    assert len(svc.forest) == 0 and not svc._groups


def test_register_unregister_storm_no_orphans():
    tc = SlotTickCache()
    svc = ContinuousSearchService(slots_per_group=2, tick_cache=tc,
                                  enable_sharing=True, **CAP)
    queries = [chain3(), chain2(), chain2_other_labels(), fork()]
    live = {}
    from repro.stream.generator import to_batches
    batches = list(to_batches(stream160(seed=9), 16))
    for i in range(30):
        q = queries[i % len(queries)]
        qid = svc.register(q, W)
        live[qid] = q
        if i % 3 == 2:                      # drop the oldest two
            for drop in sorted(live)[:2]:
                svc.unregister(drop)
                del live[drop]
        if i % 5 == 4:
            svc.ingest(batches[(i // 5) % len(batches)])
    # refcount bookkeeping exact: tenants in == handles held, and a
    # leaf's co-tenant count never exceeds the live population
    assert svc.forest_stats().n_tenants == len(live)
    for qid in live:
        info = svc.shared_prefix(qid)
        assert 1 <= info.n_tenants <= len(live)
    for qid in list(live):
        svc.unregister(qid)
    assert len(svc.forest) == 0 and not svc._groups
    assert svc.forest_stats().n_tenants == 0


# --------------------------------------------------------------------- #
# crash/restore: the differential harness with sharing enabled
# --------------------------------------------------------------------- #
@pytest.mark.parametrize(
    "backend", [JoinBackend.REF, JoinBackend.PALLAS_INTERPRET])
def test_crash_restore_differential_with_sharing(tmp_path, backend):
    tc = SlotTickCache()
    stream = stream160(seed=5)
    queries = [chain3(), chain2(), fork()]

    def fresh(d):
        svc = ContinuousSearchService(
            slots_per_group=2, backend=backend, tick_cache=tc,
            enable_sharing=True, ckpt_dir=str(d), **CAP)
        return svc, [svc.register(q, W) for q in queries]

    # run A: uninterrupted reference (itself oracle-exact per tenant)
    svc_a, qids = fresh(tmp_path / "a")
    log_a = EventLog(svc_a)
    svc_a.serve_stream(stream, on_match=log_a.on_match,
                       on_tick=log_a.on_tick, ckpt_every=3, **SERVE)
    # NOTE: the stream may contain duplicate identical edges, so one
    # match KEY can be reported by several distinct row instances —
    # the differential below is on the full multiset either way
    count_a = Counter((qid, k) for qid, k, _ in log_a.events)
    assert count_a
    for qid, q in zip(qids, queries):
        want_reported, want_window = oracle_reported(q, W, stream)
        assert {k for qq, k, _ in log_a.events if qq == qid} == want_reported
        assert svc_a.matches(qid) == want_window
    builds_a = tc.n_builds

    # run B: crash at tick 5 (newest durable checkpoint: tick 3)
    svc_b, qids_b = fresh(tmp_path / "b")
    assert qids_b == qids
    assert svc_b.n_compiles == 0          # warm cache from run A
    log_b = EventLog(svc_b, crash_at_tick=5)
    with pytest.raises(SimulatedFailure):
        svc_b.serve_stream(stream, on_match=log_b.on_match,
                           on_tick=log_b.on_tick, ckpt_every=3, **SERVE)
    svc_b.ckpt.wait()

    svc_r = ContinuousSearchService.restore(str(tmp_path / "b"),
                                            tick_cache=tc)
    assert tc.n_builds == builds_a        # zero warm recompiles
    assert svc_r.forest is not None
    assert svc_r.forest_stats() == svc_b.forest_stats()
    assert [(n.pid, n.depth, n.epoch, n.refcount)
            for n in svc_r.forest.nodes()] == \
        [(n.pid, n.depth, n.epoch, n.refcount)
         for n in svc_b.forest.nodes()]
    assert svc_r.n_ticks == 3

    kept = [(qid, k, off) for qid, k, off in log_b.events
            if off <= svc_r.n_edges_ingested]
    log_r = EventLog(svc_r)
    svc_r.serve_stream(stream[svc_r.n_edges_ingested:],
                       on_match=log_r.on_match, on_tick=log_r.on_tick,
                       ckpt_every=3, **SERVE)
    count_b = Counter((qid, k) for qid, k, _ in kept + log_r.events)
    assert count_b == count_a             # exactly-once, nothing missed
    for qid in qids:
        assert svc_r.matches(qid) == svc_a.matches(qid)


def test_restore_into_cold_cache_rebuilds_forest(tmp_path):
    """A restore in a fresh process (cold SlotTickCache) rebuilds node
    and suffix ticks once each and reproduces the same state."""
    tc = SlotTickCache()
    svc = ContinuousSearchService(slots_per_group=2, tick_cache=tc,
                                  enable_sharing=True,
                                  ckpt_dir=str(tmp_path), **CAP)
    qids = [svc.register(q, W) for q in (chain3(), chain2())]
    svc.serve_stream(stream160(), ckpt_every=4, **SERVE)

    cold = SlotTickCache()
    svc2 = ContinuousSearchService.restore(str(tmp_path), tick_cache=cold)
    assert cold.n_builds > 0
    assert svc2.forest_stats() == svc.forest_stats()
    for qid in qids:
        assert svc2.matches(qid) == svc.matches(qid)


# --------------------------------------------------------------------- #
# api surface: share_prefixes sessions
# --------------------------------------------------------------------- #
def overlapping_patterns():
    """Two DSL patterns (differently authored) whose canonical plans
    share a 2-edge prefix chain."""
    p3 = (Pattern("exfil")
          .vertex("a", label=0).vertex("b", label=1)
          .vertex("c", label=2).vertex("d", label=0)
          .edge("a", "b").edge("b", "c").edge("c", "d")
          .before(0, 1).before(1, 2).window(W))
    p2 = (Pattern("staging")
          .vertex("x", label=0).vertex("y", label=1).vertex("z", label=2)
          .edge("y", "z", name="hop2").edge("x", "y", name="hop1")
          .before("hop1", "hop2").window(W))
    return p3, p2


def test_api_session_shares_prefixes_and_reports_stats(tmp_path):
    tc = SlotTickCache()
    sess = StreamSession(tick_cache=tc, share_prefixes=True,
                         ckpt_dir=str(tmp_path), **CAP)
    plain = StreamSession(tick_cache=tc, **CAP)
    p3, p2 = overlapping_patterns()
    s3, s2 = sess.register(p3), sess.register(p2)
    u3, u2 = plain.register(p3), plain.register(p2)

    assert s2.shared_prefix.depth == 2
    assert s2.shared_prefix.n_tenants == 2     # p3 aliases p2's chain
    assert s3.shared_prefix.depth == 3
    assert u3.shared_prefix is None

    stream = stream160()
    infos = []
    sess.serve(stream, on_tick=infos.append, **SERVE)
    plain.serve(stream, **SERVE)
    assert infos and all(i.n_shared_prefix_ticks == 3 for i in infos)

    for shared_sub, plain_sub in ((s3, u3), (s2, u2)):
        got = Counter(shared_sub.drain())
        want = Counter(plain_sub.drain())
        assert got == want and want               # typed-match multisets
        assert shared_sub.matches() == plain_sub.matches()

    # sharing survives session checkpoint/restore with original handles
    sess.checkpoint()
    sess.close()
    sess2 = StreamSession.restore(str(tmp_path), tick_cache=tc)
    assert sess2.service.forest is not None
    subs = {s.name: s for s in sess2.subscriptions()}
    assert subs["staging"].shared_prefix.n_tenants == 2
    assert subs["exfil"].matches() == s3.matches()


def test_prefix_chain_is_relabeling_invariant():
    """The prefix signature must dedup label-renamed / vertex-relabeled
    tenants: differently-authored isomorphic plans produce identical
    chain signatures (the canonical_key contract on prefix slices)."""
    from repro.core.registry import QueryRegistry

    reg = QueryRegistry(**CAP)
    a = reg.compile(chain2(), W)
    # same chain authored with permuted vertex ids and reversed edges
    b_query = QueryGraph(3, (2, 0, 1), ((1, 2), (2, 0)),
                         prec=frozenset({(0, 1)}))
    b = reg.compile(b_query, W)
    assert prefix_chain(a).sigs == prefix_chain(b).sigs
    # different labels -> different signatures at every depth
    c = reg.compile(chain2_other_labels(), W)
    assert prefix_chain(a).sigs[0] != prefix_chain(c).sigs[0]
    # different window -> different signatures (expiry is part of the
    # shared table's semantics)
    d = reg.compile(chain2(), W + 1)
    assert prefix_chain(a).sigs != prefix_chain(d).sigs
