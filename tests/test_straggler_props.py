"""Property tests (hypothesis) for the serving loop's batching controls:

1. ``TickCoalescer.record`` keeps the batch inside [min_batch, max_batch]
   under ANY latency/queue trace, and each step moves it by at most the
   AIMD factors (×2 up, ×0.8 down);
2. single-step monotonicity: an overloaded tick never grows the batch,
   a fast tick with a deep queue never shrinks it;
3. sustained extremes converge: persistent overload drives the batch to
   ``min_batch``, persistent headroom with a deep queue to ``max_batch``;
4. ``quantize_pow2`` (the serve-loop's jit-specialization bound) returns
   a power of two ≥ the chunk length, within 2x of it.
"""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.runtime.straggler import TickCoalescer, quantize_pow2

latencies = st.floats(0.0, 10_000.0, allow_nan=False, allow_infinity=False)
depths = st.integers(0, 10**9)
traces = st.lists(st.tuples(latencies, depths), min_size=1, max_size=200)


@settings(max_examples=200, deadline=None)
@given(trace=traces)
def test_batch_always_bounded(trace):
    c = TickCoalescer()
    for lat, depth in trace:
        b = c.record(lat, depth)
        assert c.min_batch <= b <= c.max_batch
        assert b == c.batch


@settings(max_examples=200, deadline=None)
@given(trace=traces)
def test_step_change_is_aimd_bounded(trace):
    """One record() moves the batch by at most ×2 up / ×0.8 down."""
    c = TickCoalescer()
    for lat, depth in trace:
        before = c.batch
        after = c.record(lat, depth)
        assert after <= max(2 * before, c.min_batch)
        assert after >= min(int(0.8 * before), c.max_batch)


@settings(max_examples=200, deadline=None)
@given(batch=st.integers(32, 4096), ema=latencies, lat=latencies,
       depth=depths)
def test_single_step_monotone(batch, ema, lat, depth):
    c = TickCoalescer(batch=batch, _ema_latency=ema)
    before = c.batch                 # post-init clamped
    a = 0.3                          # same float expression as record()
    new_ema = (1 - a) * ema + a * lat
    after = c.record(lat, depth)
    if new_ema > c.target_latency_ms:
        assert after <= before       # overloaded: never grow
    elif depth > 2 * before:
        assert after >= before       # headroom + backlog: never shrink
    else:
        assert after == before       # on target, shallow queue: hold


@settings(max_examples=50, deadline=None)
@given(lats=st.lists(st.floats(200.0, 10_000.0, allow_nan=False,
                               allow_infinity=False),
                     min_size=60, max_size=60))
def test_sustained_overload_reaches_min_batch(lats):
    c = TickCoalescer()              # target 50ms; every tick ≥ 200ms
    for lat in lats:
        b = c.record(lat, queue_depth=0)
    assert b == c.min_batch


@settings(max_examples=50, deadline=None)
@given(lats=st.lists(st.floats(0.0, 10.0, allow_nan=False,
                               allow_infinity=False),
                     min_size=60, max_size=60))
def test_sustained_headroom_reaches_max_batch(lats):
    c = TickCoalescer()              # target 50ms; every tick ≤ 10ms
    for lat in lats:
        b = c.record(lat, queue_depth=10**9)
    assert b == c.max_batch


def test_invalid_bounds_rejected():
    with pytest.raises(ValueError, match="min_batch"):
        TickCoalescer(min_batch=64, max_batch=32)


# --------------------------------------------------------------------- #
# overflow throttling (ServeInfo.n_overflow -> capacity MD)
# --------------------------------------------------------------------- #
@settings(max_examples=200, deadline=None)
@given(batch=st.integers(32, 4096), ema=latencies, lat=latencies,
       depth=depths, n_overflow=st.integers(1, 10**6))
def test_overflow_always_shrinks(batch, ema, lat, depth, n_overflow):
    """A tick that dropped appends must never grow the batch — the
    capacity signal halves it regardless of latency headroom or queue
    depth (fast ticks overflow small tables cheaply)."""
    c = TickCoalescer(batch=batch, _ema_latency=ema)
    before = c.batch
    after = c.record(lat, depth, n_overflow)
    assert after == max(c.min_batch, before // 2)
    assert c.min_batch <= after <= c.max_batch


@settings(max_examples=100, deadline=None)
@given(trace=st.lists(st.tuples(latencies, depths,
                                st.integers(0, 100)), min_size=1,
                      max_size=200))
def test_batch_bounded_with_overflow(trace):
    c = TickCoalescer()
    for lat, depth, n_overflow in trace:
        b = c.record(lat, depth, n_overflow)
        assert c.min_batch <= b <= c.max_batch


# Deterministic overflow-throttle tests (incl. the serve_stream
# integration) live in tests/test_straggler_overflow.py: they need no
# hypothesis and must not skip with it.


@settings(max_examples=300, deadline=None)
@given(n=st.integers(1, 1 << 20), lo=st.sampled_from([1, 8, 16]))
def test_quantize_pow2(n, lo):
    p = quantize_pow2(n, lo)
    assert p >= n and p >= lo
    assert p & (p - 1) == 0          # a power of two
    assert p <= max(lo, 2 * n)       # never more than 2x padding