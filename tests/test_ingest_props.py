"""Property tests (hypothesis) for the deterministic k-way merge.

The merge ladder (event_time -> received_time -> edge payload -> source
order -> seq) must make the engine-facing order a pure function of the
events, never of delivery accidents:

1. permutation invariance — listing the per-source streams in any order
   yields the identical merged sequence;
2. tie-break determinism — repeated merges agree exactly, equal-ts runs
   are payload-ordered, output is event-time sorted and
   multiset-preserving;
3. ``strict_event_time_monotonic`` raises on any per-source event-time
   regression.
"""

from collections import Counter

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.core.oracle import DataEdge
from repro.stream.ingest import MonotonicityError, merge_event_streams


def edge(ts, src=0, dst=1, lab=0):
    return DataEdge(src=src, dst=dst, ts=ts, src_label=0, dst_label=0,
                    edge_label=lab)


edges_st = st.builds(
    edge,
    ts=st.integers(0, 30),
    src=st.integers(0, 4),
    dst=st.integers(0, 4),
    lab=st.integers(0, 2),
)
# per-source lists must be event-time ordered (what adapters deliver
# after the reorder buffer); sort each generated list to enforce it
streams_st = st.lists(
    st.lists(edges_st, max_size=12).map(
        lambda s: sorted(s, key=lambda e: e.ts)),
    min_size=1, max_size=5)


@settings(deadline=None, max_examples=80)
@given(streams=streams_st, seed=st.integers(0, 2**16))
def test_merge_permutation_invariant(streams, seed):
    rng = np.random.default_rng(seed)
    perm = list(rng.permutation(len(streams)))
    merged = merge_event_streams(streams)
    assert merge_event_streams([streams[i] for i in perm]) == merged
    # merged output is event-time ordered and multiset-preserving
    assert all(a.ts <= b.ts for a, b in zip(merged, merged[1:]))
    assert Counter(merged) == sum((Counter(s) for s in streams), Counter())


@settings(deadline=None, max_examples=60)
@given(streams=streams_st)
def test_merge_tiebreak_deterministic(streams):
    merged = merge_event_streams(streams)
    assert merge_event_streams(streams) == merged
    # within an equal-ts run the ladder's payload level sorts it
    i = 0
    while i < len(merged):
        j = i
        while j < len(merged) and merged[j].ts == merged[i].ts:
            j += 1
        run = [(e.src, e.dst, e.edge_label, e.src_label, e.dst_label)
               for e in merged[i:j]]
        assert run == sorted(run)
        i = j


@settings(deadline=None, max_examples=60)
@given(stream=st.lists(edges_st, min_size=2, max_size=12),
       flip=st.integers(1, 11))
def test_merge_strict_raises_on_any_regression(stream, flip):
    ordered = sorted(stream, key=lambda e: e.ts)
    merge_event_streams([ordered], strict_event_time_monotonic=True)
    k = min(flip, len(ordered) - 1)
    flipped = ordered[:k] + [edge(ordered[k - 1].ts - 31)] + ordered[k:]
    with pytest.raises(MonotonicityError):
        merge_event_streams([flipped], strict_event_time_monotonic=True)
