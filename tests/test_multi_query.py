"""Multi-query engine vs per-query oracle: N queries fused into one tick
(build_multi_tick / the service's padded slot groups) must report exactly
the same matches as N independent build_tick runs over the same stream.
Reuses the stream/query harness of tests/test_engine_oracle.py."""

import jax
import pytest

from repro.core import compile_plan
from repro.core.engine import build_tick, current_matches
from repro.core.multi import (
    SlotTickCache,
    build_multi_tick,
    init_multi_state,
    set_active,
)
from repro.core.oracle import OracleEngine
from repro.core.query import QueryGraph
from repro.core.registry import plan_signature
from repro.core.state import init_state, make_batch
from repro.runtime.service import ContinuousSearchService
from repro.stream.generator import to_batches

from test_engine_oracle import small_stream, star_query, tri_query, two_chain_query

CAP = dict(level_capacity=1024, l0_capacity=1024, max_new=512)


def chain_query():
    return QueryGraph(3, (0, 1, 2), ((0, 1), (1, 2)), prec=frozenset({(0, 1)}))


def chain_query_relabeled():
    """Same structure/timing as chain_query, different vertex labels."""
    return QueryGraph(3, (1, 2, 0), ((0, 1), (1, 2)), prec=frozenset({(0, 1)}))


def _queries_and_windows():
    return (
        [chain_query(), tri_query(), star_query(), two_chain_query()],
        [20, 25, 15, 20],
    )


# --------------------------------------------------------------------- #
@pytest.mark.parametrize("batch_size", [1, 8])
def test_multi_tick_equals_independent_ticks(batch_size):
    """N>=3 fused queries == N independent single-query engines, per tick."""
    queries, windows = _queries_and_windows()
    stream = small_stream(150, n_vertices=9, seed=21)
    plans = [compile_plan(q, w, **CAP) for q, w in zip(queries, windows)]

    mtick = jax.jit(build_multi_tick(plans))
    mstate = init_multi_state(plans)
    sticks = [jax.jit(build_tick(p)) for p in plans]
    sstates = [init_state(p) for p in plans]

    for b in to_batches(stream, batch_size):
        batch = make_batch(**b)
        mstate, results = mtick(mstate, batch)
        for i, p in enumerate(plans):
            sstates[i], r1 = sticks[i](sstates[i], batch)
            assert int(results[i].n_new_matches) == int(r1.n_new_matches)
            assert int(results[i].n_overflow) == 0

    for i, p in enumerate(plans):
        assert current_matches(p, mstate.queries[i]) == \
            current_matches(p, sstates[i])
        assert int(mstate.queries[i].stats.n_matches_total) == \
            int(sstates[i].stats.n_matches_total)


def test_multi_tick_matches_bruteforce_oracle():
    """Fused tick vs the exact pure-Python oracle, per query."""
    queries, windows = _queries_and_windows()
    stream = small_stream(120, n_vertices=8, seed=22)
    plans = [compile_plan(q, w, **CAP) for q, w in zip(queries, windows)]
    mtick = jax.jit(build_multi_tick(plans))
    mstate = init_multi_state(plans)
    oracles = [OracleEngine(q, w) for q, w in zip(queries, windows)]
    for b in to_batches(stream, 8):
        mstate, _ = mtick(mstate, make_batch(**b))
    for e in stream:
        for o in oracles:
            o.insert(e)
    for i, p in enumerate(plans):
        assert current_matches(p, mstate.queries[i]) == oracles[i].matches()


def test_multi_tick_active_flag_freezes_query():
    """Deactivating a query stops its tables from growing; others proceed."""
    queries, windows = _queries_and_windows()
    stream = small_stream(100, n_vertices=8, seed=23)
    plans = [compile_plan(q, w, **CAP) for q, w in zip(queries, windows)]
    mtick = jax.jit(build_multi_tick(plans))
    mstate = init_multi_state(plans)
    batches = [make_batch(**b) for b in to_batches(stream, 8)]
    half = len(batches) // 2
    for b in batches[:half]:
        mstate, _ = mtick(mstate, b)
    mstate = set_active(mstate, 0, False)
    frozen = int(mstate.queries[0].stats.n_matches_total)
    frozen_stats = jax.device_get(mstate.queries[0].stats)
    got_other = 0
    for b in batches[half:]:
        mstate, results = mtick(mstate, b)
        assert int(results[0].n_new_matches) == 0
        got_other += int(results[1].n_new_matches)
    assert int(mstate.queries[0].stats.n_matches_total) == frozen
    # stats don't drift while paused (edges neither processed nor discarded)
    assert jax.device_get(mstate.queries[0].stats) == frozen_stats
    # sanity: the still-active queries kept processing the stream
    assert int(mstate.queries[1].stats.n_edges_processed) == len(stream)


# --------------------------------------------------------------------- #
def test_service_add_remove_mid_stream():
    """Registry add/remove mid-stream: every query's matches equal a
    dedicated single-query engine fed exactly the batches the query was
    registered for."""
    stream = small_stream(160, n_vertices=9, seed=24)
    batches = list(to_batches(stream, 8))
    half = len(batches) // 2

    svc = ContinuousSearchService(slots_per_group=2, **CAP)
    q1 = svc.register(chain_query(), window=20)
    q2 = svc.register(tri_query(), window=25)
    for b in batches[:half]:
        res = svc.ingest(b)
        assert set(res) == {q1, q2}
    svc.unregister(q2)
    q3 = svc.register(chain_query_relabeled(), window=30)
    for b in batches[half:]:
        res = svc.ingest(b)
        assert set(res) == {q1, q3}
    assert q2 not in svc.registry

    # q1: full stream reference
    p1 = compile_plan(chain_query(), 20, **CAP)
    t1, s1 = jax.jit(build_tick(p1)), init_state(p1)
    for b in batches:
        s1, _ = t1(s1, make_batch(**b))
    assert svc.matches(q1) == current_matches(p1, s1)

    # q3: registered at the midpoint == fresh engine over the suffix
    p3 = compile_plan(chain_query_relabeled(), 30, **CAP)
    t3, s3 = jax.jit(build_tick(p3)), init_state(p3)
    for b in batches[half:]:
        s3, _ = t3(s3, make_batch(**b))
    assert svc.matches(q3) == current_matches(p3, s3)


def test_service_same_structure_does_not_recompile():
    """Padded slots + the process-wide SlotTickCache: a second query of an
    already-seen structural signature is a pure data write, and even a
    group OVERFLOW reuses the cached compiled tick — only a never-seen
    structure builds."""
    tc = SlotTickCache()
    svc = ContinuousSearchService(slots_per_group=4, tick_cache=tc, **CAP)
    qa = svc.register(chain_query(), window=20)
    assert svc.n_compiles == 1
    qb = svc.register(chain_query_relabeled(), window=35)
    assert svc.n_compiles == 1          # same structure: slot reuse
    qc = svc.register(star_query(), window=15)
    assert svc.n_compiles == 2          # new structure: one new group
    # group overflow allocates a new group but REUSES the cached tick
    for _ in range(4):
        svc.register(chain_query(), window=20)
    assert svc.n_compiles == tc.n_builds == 2
    assert svc.n_active == 7
    # group key gained a prefix dimension (sharing off -> None)
    assert len(svc._groups[(svc.registry.get(qa).signature, None)]) == 2

    # slots are reusable after unregister, again without compiling
    svc.unregister(qb)
    svc.register(chain_query_relabeled(), window=35)
    assert svc.n_compiles == 2

    p_chain = compile_plan(chain_query(), 20, **CAP)
    p_rel = compile_plan(chain_query_relabeled(), 35, **CAP)
    assert plan_signature(p_chain) == plan_signature(p_rel)


def test_service_idle_group_retention():
    """Fully-empty groups release their device tables, keeping one warm
    per signature; compiled ticks outlive every group in the
    SlotTickCache, so churn never rebuilds one."""
    tc = SlotTickCache()
    svc = ContinuousSearchService(slots_per_group=1, tick_cache=tc, **CAP)
    a = svc.register(chain_query(), window=20)
    sig = (svc.registry.get(a).signature, None)   # key: sig x prefix
    b = svc.register(chain_query(), window=20)   # same sig, second group
    assert svc.n_compiles == tc.n_builds == 1    # one build serves both
    assert len(svc._groups[sig]) == 2
    svc.unregister(a)                            # first idle group: kept warm
    svc.unregister(b)                            # second idle group: released
    assert len(svc._groups[sig]) == 1
    c = svc.register(chain_query(), window=20)
    assert len(svc._groups[sig]) == 1            # warm group re-armed
    svc.unregister(c)
    assert svc.drop_idle_groups() == 1
    assert sig not in svc._groups
    svc.register(chain_query(), window=20)       # tables re-allocated ...
    assert len(svc._groups[sig]) == 1
    assert svc.n_compiles == tc.n_builds == 1    # ... but never recompiled


def test_slot_tick_cache_lru_eviction():
    """The tick cache is LRU-bounded; eviction never breaks live groups
    (they hold their own tick references) — only a NEW group of an
    evicted structure rebuilds."""
    tc = SlotTickCache(max_entries=1)
    svc = ContinuousSearchService(slots_per_group=2, tick_cache=tc, **CAP)
    qa = svc.register(chain_query(), window=20)
    svc.register(star_query(), window=15)     # evicts the chain tick
    assert len(tc) == 1 and tc.n_builds == 2
    stream = small_stream(40, n_vertices=8, seed=30)
    for b in to_batches(stream, 8):
        svc.ingest(b)                         # both groups still serve
    assert int(svc.stats(qa).n_edges_processed) == len(stream)
    svc.register(chain_query(), window=25)    # free slot: no cache lookup
    assert tc.n_builds == 2
    svc.register(chain_query(), window=30)    # overflow: rebuild evicted
    assert tc.n_builds == 3 and len(tc) == 1


def test_service_results_match_single_engines():
    """Service ingest results (per-tick counts and final window matches)
    equal dedicated per-query engines."""
    stream = small_stream(120, n_vertices=8, seed=25)
    queries = [chain_query(), chain_query_relabeled(), star_query()]
    windows = [20, 30, 15]

    svc = ContinuousSearchService(slots_per_group=2, **CAP)
    qids = [svc.register(q, w) for q, w in zip(queries, windows)]
    plans = [compile_plan(q, w, **CAP) for q, w in zip(queries, windows)]
    ticks = [jax.jit(build_tick(p)) for p in plans]
    states = [init_state(p) for p in plans]

    for b in to_batches(stream, 8):
        res = svc.ingest(b)
        batch = make_batch(**b)
        for i, qid in enumerate(qids):
            states[i], r1 = ticks[i](states[i], batch)
            assert int(res[qid].n_new_matches) == int(r1.n_new_matches)
    for i, qid in enumerate(qids):
        assert svc.matches(qid) == current_matches(plans[i], states[i])
        assert int(svc.stats(qid).n_overflow) == 0
