"""Tests for the ``repro.analysis`` static-analysis gate.

Covers the three passes (golden fixture findings for the linter, lattice
+ agreement proofs for the kernel checker, accept/reject behavior for
the plan verifier), the baseline contract, the CLI exit codes, and the
acceptance criterion: ``QueryRegistry.register`` rejects a hand-built
timing-violating decomposition with ``PlanInvariantError`` on both the
REF and PALLAS_INTERPRET backends, leaving the service untouched.
"""

import json
import os

import pytest

from repro.analysis import (
    ERROR, WARNING, PlanInvariantError, load_baseline, verify_plan)
from repro.analysis import kernel_check as KC
from repro.analysis.ast_lint import lint_tree
from repro.analysis.cli import main as cli_main
from repro.analysis.plan_check import check_plan, verify_corpus
from repro.core.decompose import TCSubquery
from repro.core.join import JoinBackend
from repro.core.plan import compile_plan
from repro.core.query import example_paper_query
from repro.runtime.service import ContinuousSearchService

HERE = os.path.dirname(os.path.abspath(__file__))
REPO_ROOT = os.path.dirname(HERE)
FIXTURES = os.path.join(HERE, "analysis_fixtures")
SRC_REPRO = os.path.join(REPO_ROOT, "src", "repro")
BASELINE = os.path.join(REPO_ROOT, "analysis_baseline.json")


# --------------------------------------------------------------------- #
# ast_lint: golden fixture findings
# --------------------------------------------------------------------- #
def test_lint_fixture_golden_findings():
    findings, stats = lint_tree(FIXTURES)
    got = {(f.rule, f.symbol.rsplit(".", 1)[-1]) for f in findings}
    assert got == {
        ("TRC101", "bad_cast"),
        ("TRC102", "bad_numpy"),
        ("TRC103", "bad_sync"),
        ("TRC104", "bad_branch"),
        ("TRC105", "tick"),
        ("TRC106", "serve"),
        ("TRC107", "bad_obs_emit"),
    }
    sev = {f.rule: f.severity for f in findings}
    assert sev["TRC101"] == sev["TRC104"] == sev["TRC107"] == ERROR
    assert sev["TRC105"] == sev["TRC106"] == WARNING
    # the inline-suppressed cast and every ok_* pattern stay silent
    assert not any("suppressed" in f.symbol or "ok_" in f.symbol
                   or "host_helper" in f.symbol or "clean" in f.symbol
                   or "donating" in f.symbol for f in findings)
    assert stats["n_traced_functions"] >= 6
    # the census sees both the traced (bad) and host (ok) emission sites
    assert stats["n_obs_sites"] >= 3


def test_lint_obs_sites_census_and_clean_tree():
    """The real tree: every repro.obs emission site is host-side (zero
    TRC107 findings), and the census proves the linter actually sees
    the instrumented serve loop (service/session/ingest/benches)."""
    findings, stats = lint_tree(SRC_REPRO)
    assert not [f for f in findings if f.rule == "TRC107"]
    assert stats["n_obs_sites"] >= 10


def test_lint_recognizes_aliased_shard_map_roots(tmp_path):
    """The compat shim imports ``shard_map as _shard_map``; functions
    handed to the alias must still become traced roots (TRC-checked)
    and be counted in the ``n_shard_map_roots`` census."""
    (tmp_path / "m.py").write_text(
        "from repro.core.compat import shard_map as _shard_map\n"
        "def serve(mesh):\n"
        "    def body(x):\n"
        "        return int(x) + 1\n"
        "    return _shard_map(body, mesh=mesh, in_specs=(None,),\n"
        "                      out_specs=None)\n")
    findings, stats = lint_tree(str(tmp_path))
    assert stats["n_shard_map_roots"] == 1
    assert any(f.rule == "TRC101" and "body" in f.symbol
               for f in findings)


def test_mesh_tick_builder_is_trc_covered():
    """The mesh subsystem's shard_map-wrapped tick builder is inside the
    linted tree's traced-root census — the TRC rules see it."""
    _, stats = lint_tree(SRC_REPRO)
    assert stats["n_shard_map_roots"] >= 1


def test_lint_tree_clean_at_error_severity():
    """Satellite contract: the real tree has zero error findings and
    every warning is covered by the shipped baseline."""
    findings, _ = lint_tree(SRC_REPRO)
    assert [f.format() for f in findings if f.severity == ERROR] == []
    baseline = load_baseline(BASELINE)
    not_covered = [f.format() for f in findings
                   if f.severity == WARNING and not baseline.suppresses(f)]
    assert not_covered == []


# --------------------------------------------------------------------- #
# kernel_check
# --------------------------------------------------------------------- #
def test_kernel_contracts_prove_clean():
    findings, stats = KC.check_kernels(fast=True)
    assert [f.format() for f in findings] == []
    assert stats["n_pallas_sites"] == 6


def test_bounds_checker_catches_non_divisible_blockspec():
    # 96 rows tiled at 64: the second block covers [64, 128) > 96
    bad = KC._bounds_ok((2,), [("x", (96,), (64,), lambda i: (i,))])
    assert bad and bad[0][0] == "x"
    # and a correct tiling proves clean
    assert KC._bounds_ok((2,), [("x", (128,), (64,), lambda i: (i,))]) == []


def test_unmodeled_pallas_call_flagged(tmp_path):
    kdir = tmp_path / "kernels" / "newk"
    kdir.mkdir(parents=True)
    (kdir / "kernel.py").write_text(
        "from jax.experimental import pallas as pl\n"
        "def mystery_kernel(x):\n"
        "    return pl.pallas_call(lambda i, o: None, grid=(1,))(x)\n")
    findings, stats = KC.check_kernels(
        kernels_root=str(tmp_path / "kernels"), fast=True)
    assert stats["n_pallas_sites"] == 1
    assert any(f.rule == "KC100" and f.severity == WARNING
               and f.symbol == "mystery_kernel" for f in findings)


def test_smem_cursor_proof_requires_the_clamp(monkeypatch):
    """The KC104 proof is conditional on the emit clamp being present in
    the kernel source; if the clamp expression disappears, the pass must
    fail loudly instead of vacuously passing."""
    monkeypatch.setattr(KC, "_CLAMP_EXPR", "jnp.some_other_clamp(")
    findings = KC.check_smem_cursor(fast=True)
    assert any(f.rule == "KC104" and f.severity == ERROR for f in findings)


# --------------------------------------------------------------------- #
# plan_check + registry wiring (acceptance criterion)
# --------------------------------------------------------------------- #
def _timing_violating_plan(caps):
    """A hand-built decomposition whose first 'timing sequence' pairs
    two adjacent edges that ≺ does NOT order (violates Definition 10)."""
    q = example_paper_query()
    bad = next((x, y) for x in range(q.n_edges) for y in range(q.n_edges)
               if x != y and q.edges_adjacent(x, y)
               and not q.precedes(x, y))
    rest = [e for e in range(q.n_edges) if e not in bad]
    dec = [TCSubquery(frozenset(bad), tuple(bad))] + \
        [TCSubquery(frozenset({e}), (e,)) for e in rest]
    return q, compile_plan(q, 25, decomposition=dec, **caps)


@pytest.mark.parametrize(
    "backend", [JoinBackend.REF, JoinBackend.PALLAS_INTERPRET])
def test_register_rejects_timing_violating_plan(backend):
    caps = dict(level_capacity=256, l0_capacity=256, max_new=64)
    q, plan = _timing_violating_plan(caps)
    svc = ContinuousSearchService(slots_per_group=2, backend=backend,
                                  **caps)
    with pytest.raises(PlanInvariantError) as exc:
        svc.register(q, 25, plan=plan)
    assert any(f.rule == "PC102" for f in exc.value.findings)
    # fail-fast BEFORE any state mutation: nothing half-registered
    assert len(svc.registry) == 0
    assert svc.registry.next_qid == 0


def test_adopt_rejects_corrupted_manifest_decomposition():
    from repro.core.registry import QueryRegistry
    q = example_paper_query()
    reg = QueryRegistry()
    bad = next((x, y) for x in range(q.n_edges) for y in range(q.n_edges)
               if x != y and q.edges_adjacent(x, y)
               and not q.precedes(x, y))
    rest = [(e,) for e in range(q.n_edges) if e not in bad]
    with pytest.raises(PlanInvariantError):
        reg.adopt(7, q, 25, decomposition=[tuple(bad)] + rest)
    assert 7 not in reg


def test_verify_plan_accepts_planner_output_and_custom_singletons():
    from repro.core.query import QueryGraph
    q = example_paper_query()
    verify_plan(compile_plan(q, 25))
    # the all-singletons custom decomposition used by the restore tests
    tri = QueryGraph(3, (0, 1, 2), ((0, 1), (1, 2), (2, 0)), (0, 0, 0),
                     frozenset({(0, 1), (1, 2), (0, 2)}))
    custom = [TCSubquery(frozenset({e}), (e,)) for e in range(3)]
    verify_plan(compile_plan(tri, 25, decomposition=custom))


def test_check_plan_flags_each_broken_invariant():
    q = example_paper_query()
    plan = compile_plan(q, 25)
    # PC101: drop an edge from the cover
    import copy
    p = copy.deepcopy(plan)
    p.subqueries = p.subqueries[1:]
    assert any(f.rule == "PC101" for f in check_plan(p))
    # PC107: corrupt a label table
    p = copy.deepcopy(plan)
    p.edge_src_label = p.edge_src_label + 1
    assert any(f.rule == "PC107" for f in check_plan(p))
    # PC108: non-positive window
    p = copy.deepcopy(plan)
    p.window = 0
    assert any(f.rule == "PC108" for f in check_plan(p))
    # PC106: orphan edge_site entry
    p = copy.deepcopy(plan)
    p.edge_site[99] = (0, 0)
    assert any(f.rule == "PC106" for f in check_plan(p))


def test_corpus_sweep_is_error_free():
    findings, stats = verify_corpus()
    assert stats["n_plans_verified"] >= 10
    assert [f.format() for f in findings if f.severity == ERROR] == []


# --------------------------------------------------------------------- #
# baseline contract
# --------------------------------------------------------------------- #
def test_shipped_baseline_loads_and_has_no_error_entries():
    baseline = load_baseline(BASELINE)
    assert baseline.entries          # the known warnings are listed
    # load_baseline would have raised on error-severity suppressions;
    # double-check the raw file anyway
    doc = json.load(open(BASELINE))
    assert all(e.get("severity") != ERROR for e in doc["suppressions"])


def test_baseline_requires_justification(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"suppressions": [
        {"pass": "lint", "rule": "TRC105", "path": "x.py", "symbol": "f",
         "justification": "   "}]}))
    with pytest.raises(ValueError, match="justification"):
        load_baseline(str(p))


def test_baseline_rejects_error_severity_suppression(tmp_path):
    p = tmp_path / "b.json"
    p.write_text(json.dumps({"suppressions": [
        {"pass": "lint", "rule": "TRC101", "path": "x.py", "symbol": "f",
         "severity": "error", "justification": "because"}]}))
    with pytest.raises(ValueError, match="errors must be fixed"):
        load_baseline(str(p))


def test_missing_baseline_is_empty(tmp_path):
    b = load_baseline(str(tmp_path / "nope.json"))
    assert b.entries == {}


# --------------------------------------------------------------------- #
# CLI
# --------------------------------------------------------------------- #
def test_cli_green_on_tree_and_writes_report(tmp_path, capsys):
    out = tmp_path / "report.json"
    rc = cli_main(["--fast", "--json", str(out)])
    assert rc == 0
    doc = json.loads(out.read_text())
    assert doc["schema"] == "repro_analysis/v1"
    assert doc["findings_by_severity"]["error"] == 0
    assert doc["findings_by_severity"]["warning"] == 0
    assert doc["stats"]["n_pallas_sites"] == 6
    assert doc["stats"]["n_plans_verified"] >= 10
    assert len(doc["suppressed"]) >= 4
    assert "repro.analysis:" in capsys.readouterr().out


def test_cli_fails_on_error_findings(capsys):
    rc = cli_main(["--root", FIXTURES, "--pass", "lint"])
    assert rc == 1
    assert "TRC101" in capsys.readouterr().out


def test_cli_error_on_findings_promotes_warnings(tmp_path, capsys):
    # with an empty baseline the tree's warnings become failures under
    # --error-on-findings, but not without it
    empty = tmp_path / "empty.json"
    empty.write_text(json.dumps({"suppressions": []}))
    argv = ["--pass", "lint", "--baseline", str(empty)]
    assert cli_main(argv) == 0
    assert cli_main(argv + ["--error-on-findings"]) == 1
    capsys.readouterr()
