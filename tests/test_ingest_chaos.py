"""Differential chaos harness for the fault-tolerant ingestion frontier.

The whole point of ``repro.stream.ingest`` + ``repro.stream.chaos``:
transport faults must be INVISIBLE to the match stream, and anything
that cannot be delivered must be counted, never silently lost.  Proof by
differential execution, on REF and PALLAS_INTERPRET:

* run A — the pre-ordered single-stream reference: ``serve_stream`` over
  the canonical edge list (itself oracle-cross-checked in
  tests/test_service_restore.py);
* run B — the same traffic split across sources, deliveries reordered
  and duplicated (seeded ``disordered_sources`` scripts), each source
  wrapped in ``ChaosSource`` injecting disconnects-with-rewind,
  duplicate delivery, reordering, stalls, and torn batches; served
  through ``serve_frontier``.

Run B must report EXACTLY run A's match multiset, and the frontier's
accounting must reconcile: every delivery is emitted once, suppressed as
a counted duplicate, or dropped as a counted late event.

Plus the crash/restore differential THROUGH the ingest layer: kill the
serving loop mid-stream (``SimulatedFailure``), restore from the newest
checkpoint, rebuild the frontier from the checkpointed ingest manifest
(``IngestFrontier.resume``) over fresh chaos-wrapped sources, replay —
the exactly-once multiset again, now across a process boundary.
"""

from collections import Counter

import pytest

from repro.core.join import JoinBackend
from repro.core.multi import SlotTickCache
from repro.core.oracle import DataEdge
from repro.runtime.fault import RetryPolicy, SimulatedFailure
from repro.runtime.service import ContinuousSearchService
from repro.stream.chaos import ChaosConfig, ChaosSource
from repro.stream.generator import DisorderConfig, disordered_sources
from repro.stream.ingest import IngestFrontier, ListSource, ScriptedSource

from test_engine_oracle import small_stream, tri_query
from test_service_restore import CAP, SERVE, EventLog, chain_query

QUERIES = [(chain_query(), 20), (tri_query(), 25)]
NO_SLEEP = dict(sleep=lambda d: None)
RETRY = RetryPolicy(max_attempts=8, base_delay_s=0.0, jitter_frac=0.0)


def _fresh(backend, tc, ckpt_dir=None):
    svc = ContinuousSearchService(
        slots_per_group=2, backend=backend, tick_cache=tc,
        ckpt_dir=None if ckpt_dir is None else str(ckpt_dir), **CAP)
    qids = [svc.register(q, w) for q, w in QUERIES]
    return svc, qids


def _chaos_sources(stream, lateness_safe=True, seed=0):
    """The stream as 3 disordered/duplicated delivery scripts, each
    behind a fault-injecting transport."""
    scripts = disordered_sources(stream, DisorderConfig(
        n_sources=3, disorder_frac=0.3, max_delay=6, duplicate_rate=0.1,
        seed=seed + 1))
    cfg = ChaosConfig(seed=seed + 2, p_disconnect=0.08, rewind=4,
                      p_duplicate=0.05, reorder_span=3, p_reorder=0.2,
                      p_stall=0.05, stall_len=2, p_torn=0.05)
    return [ChaosSource(ScriptedSource(f"s{i}", sc),
                        ChaosConfig(**{**cfg.__dict__, "seed": seed + 2 + i}))
            for i, sc in enumerate(scripts)]


@pytest.mark.parametrize(
    "backend", [JoinBackend.REF, JoinBackend.PALLAS_INTERPRET])
def test_chaos_differential(backend):
    """Chaos-wrapped multi-source serving == pre-ordered serving, exactly
    (match multisets AND window contents), with full delivery
    accounting."""
    tc = SlotTickCache()
    stream = small_stream(160, n_vertices=9, seed=61)

    svc_a, qids = _fresh(backend, tc)
    log_a = EventLog(svc_a)
    svc_a.serve_stream(stream, on_match=log_a.on_match,
                       on_tick=log_a.on_tick, **SERVE)
    count_a = Counter((qid, k) for qid, k, _ in log_a.events)
    assert count_a and max(count_a.values()) == 1

    srcs = _chaos_sources(stream, seed=7)
    fr = IngestFrontier(srcs, allowed_lateness=80, stall_patience=4,
                        retry=RETRY, **NO_SLEEP)
    svc_b, qids_b = _fresh(backend, tc)
    assert qids_b == qids
    log_b = EventLog(svc_b)
    infos = []
    svc_b.serve_frontier(fr, on_match=log_b.on_match,
                         on_tick=lambda i: (infos.append(i),
                                            log_b.on_tick(i)), **SERVE)

    # the differential: transport faults never perturb the match stream
    count_b = Counter((qid, k) for qid, k, _ in log_b.events)
    assert count_b == count_a
    for qid in qids:
        assert svc_b.matches(qid) == svc_a.matches(qid)

    # accounting: every delivery emitted exactly once or counted
    s = fr.stats()
    assert s.n_emitted == len(stream) and s.n_late_dropped == 0
    assert s.n_duplicates > 0                 # scripts + chaos injected
    assert s.n_reconnects > 0                 # disconnects were survived
    assert sum(c.n_injected_disconnects for c in srcs) > 0
    assert sum(c.n_injected_duplicates for c in srcs) > 0
    # per-tick ServeInfo deltas reconcile with the frontier totals
    assert sum(i.n_duplicates for i in infos) == s.n_duplicates
    assert sum(i.n_reconnects for i in infos) == s.n_reconnects
    assert sum(i.n_late_dropped for i in infos) == 0
    assert any(i.watermark is not None for i in infos)
    assert svc_b.n_edges_ingested == len(stream)


def test_chaos_source_default_config_is_passthrough():
    stream = small_stream(50, seed=62)
    plain = ListSource("s", stream)
    plain.connect()
    want = []
    while not plain.exhausted:
        want.extend(plain.poll(7))
    wrapped = ChaosSource(ListSource("s", stream))
    wrapped.connect()
    got = []
    while not wrapped.exhausted:
        got.extend(wrapped.poll(7))
    assert got == want
    assert wrapped.name == "s"
    assert wrapped.n_injected_disconnects == 0
    assert wrapped.n_injected_duplicates == 0


def test_chaos_with_tight_lateness_drops_are_counted_not_silent():
    """Under a tight lateness bound some deliveries DO die — but the
    accounting invariant must still reconcile every single one:
    Counter(emitted) + Counter(dropped) == Counter(original)."""
    stream = small_stream(200, n_vertices=9, seed=63)
    scripts = disordered_sources(stream, DisorderConfig(
        n_sources=3, disorder_frac=0.5, max_delay=10, seed=17))
    fr = IngestFrontier(
        [ScriptedSource(f"s{i}", sc) for i, sc in enumerate(scripts)],
        allowed_lateness=0, retry=RETRY, **NO_SLEEP)
    dropped = []
    fr.on("drop_late", lambda name, e, seq: dropped.append(e))
    out = []
    while not fr.exhausted:
        out.extend(fr.drain())
    s = fr.stats()
    assert s.n_late_dropped == len(dropped) > 0
    assert Counter(out) + Counter(dropped) == Counter(stream)
    assert s.n_emitted + s.n_late_dropped == len(stream)
    assert all(a.ts <= b.ts for a, b in zip(out, out[1:]))


@pytest.mark.parametrize(
    "backend", [JoinBackend.REF, JoinBackend.PALLAS_INTERPRET])
def test_crash_restore_through_ingest(tmp_path, backend):
    """Kill the frontier-driven loop mid-stream, restore, rebuild the
    frontier from the checkpointed cursors over FRESH chaos-wrapped
    sources, replay: the match multiset is exactly the uninterrupted
    run's — nothing lost to the crash, nothing double-reported despite
    the at-least-once replay."""
    tc = SlotTickCache()
    stream = small_stream(160, n_vertices=9, seed=64)

    # run A: uninterrupted pre-ordered reference
    svc_a, qids = _fresh(backend, tc)
    log_a = EventLog(svc_a)
    svc_a.serve_stream(stream, on_match=log_a.on_match,
                       on_tick=log_a.on_tick, **SERVE)
    count_a = Counter((qid, k) for qid, k, _ in log_a.events)

    # run B: chaos frontier, crash at tick 5 (checkpoints every 3)
    fr_b = IngestFrontier(_chaos_sources(stream, seed=31),
                          allowed_lateness=80, stall_patience=4,
                          retry=RETRY, **NO_SLEEP)
    svc_b, _ = _fresh(backend, tc, ckpt_dir=tmp_path)
    log_b = EventLog(svc_b, crash_at_tick=5)
    with pytest.raises(SimulatedFailure):
        svc_b.serve_frontier(fr_b, on_match=log_b.on_match,
                             on_tick=log_b.on_tick, ckpt_every=3, **SERVE)
    svc_b.ckpt.wait()

    # restore: the checkpoint carries the ingest cursors
    svc_r = ContinuousSearchService.restore(str(tmp_path), tick_cache=tc)
    man = svc_r.restored_ingest
    assert man is not None
    assert {s["name"] for s in man["sources"]} == {"s0", "s1", "s2"}
    assert svc_r.n_edges_ingested == man["counters"]["n_emitted"]

    # exactly-once consumer: roll back reports newer than the checkpoint
    kept = [(qid, k) for qid, k, off in log_b.events
            if off <= svc_r.n_edges_ingested]

    # resume over FRESH sources (same seeded scripts + chaos): replayed
    # already-acked deliveries are suppressed by the restored trackers
    fr_r = IngestFrontier.resume(
        man, _chaos_sources(stream, seed=31), allowed_lateness=80,
        stall_patience=4, retry=RETRY, **NO_SLEEP)
    log_r = EventLog(svc_r)
    svc_r.serve_frontier(fr_r, on_match=log_r.on_match,
                         on_tick=log_r.on_tick, **SERVE)

    count_b = Counter(kept) + Counter(
        (qid, k) for qid, k, _ in log_r.events)
    assert count_b == count_a
    for qid in qids:
        assert svc_r.matches(qid) == svc_a.matches(qid)
    s = fr_r.stats()
    assert s.n_emitted == len(stream)         # counters resumed, total exact
    assert s.n_late_dropped == 0
    assert svc_r.n_edges_ingested == len(stream)


def test_frontier_manifest_rejects_unknown_sources():
    fr = IngestFrontier([ListSource("a", [DataEdge(0, 1, 1, 0, 0, 0)])],
                        **NO_SLEEP)
    while not fr.exhausted:
        fr.drain()
    man = fr.to_manifest()
    from repro.stream.ingest import IngestError
    with pytest.raises(IngestError, match="not provided"):
        IngestFrontier.resume(man, [ListSource("b", [])], **NO_SLEEP)


def test_session_health_degrades_on_late_drops():
    """Satellite (b): drop accounting surfaces end-to-end — SessionStatus
    carries the frontier counters and health flips to DEGRADED when the
    late-drop rate crosses the session threshold."""
    from repro.api import ACTIVE, DEGRADED, StreamSession

    def edge(ts):
        return DataEdge(src=0, dst=1, ts=ts, src_label=0, dst_label=0,
                        edge_label=0)

    # source "b" delivers an ancient event on its SECOND pump round
    # (scripts longer than one 64-event poll), long after the merged
    # floor passed it: a guaranteed late drop under zero lateness
    a_src = ListSource("a", [edge(t) for t in range(50, 56)])
    b_script = [(i, edge(50 + i)) for i in range(64)] + [(64, edge(1))]

    sess = StreamSession(slots_per_group=2, late_drop_threshold=0.01, **CAP)
    sess.register_query(chain_query(), 20)
    fr = sess.sources(
        {"a": a_src, "b": ScriptedSource("b", b_script)},
        allowed_lateness=0, retry=RETRY, **NO_SLEEP)
    sess.serve_frontier(fr, batch_size=16)
    st = sess.status()
    assert st.n_late_dropped == 1
    assert st.health == DEGRADED
    assert st.ingest["n_emitted"] + st.n_late_dropped == 6 + 65

    stream = small_stream(200, n_vertices=9, seed=65)
    scripts = disordered_sources(stream, DisorderConfig(
        n_sources=3, disorder_frac=0.5, max_delay=10, seed=19))

    # generous lateness: same traffic, zero drops, healthy
    sess2 = StreamSession(slots_per_group=2, **CAP)
    sess2.register_query(chain_query(), 20)
    fr2 = sess2.sources(
        {f"s{i}": ScriptedSource(f"s{i}", sc)
         for i, sc in enumerate(scripts)},
        allowed_lateness=100, retry=RETRY, **NO_SLEEP)
    sess2.serve_frontier(fr2, batch_size=16)
    st2 = sess2.status()
    assert st2.n_late_dropped == 0 and st2.health == ACTIVE
    assert st2.n_duplicates == 0 and st2.n_reconnects == 0
