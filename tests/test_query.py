"""Query model + decomposition unit tests (paper §2, §5.5, §5.6)."""

import pytest

from repro.core.query import QueryGraph, example_paper_query
from repro.core.decompose import (
    decompose,
    expected_join_ops,
    join_order,
    joint_number,
    tc_subqueries,
)


def chain_query(n=3):
    """Path u0->u1->...->un with full timing chain e0 ≺ e1 ≺ ... (a TC-query)."""
    edges = tuple((i, i + 1) for i in range(n))
    prec = frozenset((i, i + 1) for i in range(n - 1))
    return QueryGraph(n + 1, tuple(range(n + 1)), edges, prec=prec)


def test_transitive_closure_and_validation():
    q = chain_query(3)
    assert q.precedes(0, 2)  # closure
    assert not q.precedes(2, 0)
    with pytest.raises(ValueError):
        QueryGraph(2, (0, 1), ((0, 1),), prec=frozenset({(0, 0)}))
    with pytest.raises(ValueError):
        QueryGraph(2, (0, 1), ((0, 0),))  # self loop
    with pytest.raises(ValueError):
        QueryGraph(
            3, (0, 1, 2), ((0, 1), (1, 2)), prec=frozenset({(0, 1), (1, 0)})
        )  # cycle


def test_preq():
    q = chain_query(3)
    assert q.preq(2) == {0, 1, 2}
    assert q.preq(0) == {0}


def test_tc_query_detection():
    q = chain_query(4)
    assert q.is_tc_query()
    # no timing order at all on >1 edges -> not TC
    q2 = QueryGraph(3, (0, 1, 2), ((0, 1), (1, 2)))
    assert not q2.is_tc_query()
    assert not example_paper_query().is_tc_query()


def test_example_paper_decomposition():
    q = example_paper_query()
    subs = tc_subqueries(q)
    sets = {t.edge_ids for t in subs}
    # the paper's §5.5 example: TCsub(Q) contains {e6,e5,e4} and {e3,e1}
    assert frozenset({5, 4, 3}) in sets
    assert frozenset({2, 0}) in sets
    d = decompose(q)
    sizes = sorted((len(t) for t in d), reverse=True)
    assert sizes == [3, 2, 1]
    ordered = join_order(q, d)
    # prefix-connectivity of the chosen order
    edges_so_far = set(ordered[0].edge_ids)
    for t in ordered[1:]:
        vs = set(q.vertices_of(edges_so_far))
        assert vs & set(q.vertices_of(t.edge_ids))
        edges_so_far |= t.edge_ids


def test_chain_decomposes_to_single_subquery():
    q = chain_query(4)
    d = decompose(q)
    assert len(d) == 1
    assert d[0].edge_ids == frozenset(range(4))


def test_cost_model_monotone_in_k():
    q = example_paper_query()
    assert expected_join_ops(q, 1) < expected_join_ops(q, 3) < expected_join_ops(q, 6)


def test_joint_number():
    q = example_paper_query()
    # {e6,e5,e4} and {e3,e1}: share vertex 3 (v3 in e6/e5 and e3) + timing pairs
    a, b = frozenset({5, 4, 3}), frozenset({2, 0})
    jn = joint_number(q, a, b)
    assert jn >= 1


def test_timing_sequence_checks():
    q = chain_query(3)
    assert q.is_timing_sequence((0, 1, 2))
    assert not q.is_timing_sequence((1, 0, 2))
    assert q.is_prefix_connected((0, 1, 2))
    assert not q.is_prefix_connected((2, 0, 1)) or True  # (2,0): share v2? e2=(2,3), e0=(0,1) -> no
    assert not q.is_prefix_connected((0, 2, 1))


def test_tc_subquery_enumeration_deterministic():
    """The Algorithm-5 traversal is an iterative DFS (explicit LIFO
    stack) and its first-witness enumeration order is LOAD-BEARING: it
    flows into ``plan_signature`` (slot-group sharing) and checkpoint
    manifests, so this test pins the exact order for the paper's
    Figure-2 query.  If it ever changes (e.g. a switch to BFS), bump
    checkpoint compatibility deliberately — don't let it drift."""
    q = example_paper_query()
    golden = [(5,), (5, 4), (5, 4, 3), (4,), (4, 3), (3,),
              (2,), (2, 0), (1,), (0,)]
    for _ in range(3):  # stable across repeated enumeration
        assert [s.timing_sequence for s in tc_subqueries(q)] == golden
    # downstream: the decomposition/join-order pipeline is pinned too
    dec = join_order(q, decompose(q))
    assert [s.timing_sequence for s in dec] == [(5, 4, 3), (2, 0), (1,)]
