"""Per-architecture smoke tests: instantiate a REDUCED config of the same
family and run one forward/train step on CPU, asserting output shapes and
no NaNs.  The FULL configs are exercised only via the dry-run."""

import importlib

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.registry import ARCHS
from repro.launch.cells import (
    make_gnn_train_step,
    make_lm_train_step,
    make_recsys_train_step,
)
from repro.models import transformer as tfm
from repro.models.gnn import models as gnn
from repro.models.gnn import nequip as nq
from repro.models.recsys import wide_deep as wd
from repro.optim import AdamWConfig, adamw_init


def test_registry_has_all_ten():
    assert len(ARCHS) == 10
    for aid, arch in ARCHS.items():
        assert len(arch.shapes) == 4


LM_ARCHS = ["deepseek_coder_33b", "qwen3_14b", "internlm2_20b",
            "arctic_480b", "grok1_314b"]


@pytest.mark.parametrize("mod_name", LM_ARCHS)
def test_lm_smoke(mod_name):
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.smoke_config()
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    ocfg = AdamWConfig()
    opt = adamw_init(params, ocfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, cfg.vocab)
    step = jax.jit(make_lm_train_step(cfg, ocfg, microbatches=2))
    params, opt, loss, gn = step(params, opt, tokens)
    assert np.isfinite(float(loss)) and np.isfinite(float(gn))
    # one decode step too
    b, smax = 2, 8
    kc = jnp.zeros((cfg.n_layers, b, smax, cfg.n_kv_heads, cfg.head_dim))
    cache = (kc, jnp.zeros_like(kc), jnp.zeros((b,), jnp.int32))
    logits, cache = tfm.serve_step(
        params, jnp.zeros((b, 1), jnp.int32), cache, cfg)
    assert logits.shape == (b, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    # full-config sanity: the registry entry matches the published shape
    full = mod.ARCH.config
    assert full.n_heads * full.head_dim == full.d_model


GNN_ARCHS = ["gat_cora", "gin_tu", "pna"]


@pytest.mark.parametrize("mod_name", GNN_ARCHS)
def test_gnn_smoke(mod_name):
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    cfg = mod.smoke_config()
    rng = np.random.default_rng(0)
    n, e = 24, 80
    g = {
        "x": jnp.asarray(rng.standard_normal((n, cfg.d_in)), jnp.float32),
        "edge_src": jnp.asarray(rng.integers(0, n, e).astype(np.int32)),
        "edge_dst": jnp.asarray(rng.integers(0, n, e).astype(np.int32)),
        "labels": jnp.asarray(rng.integers(0, cfg.n_classes, n).astype(np.int32)),
    }
    params = gnn.INITS[cfg.arch](jax.random.PRNGKey(0), cfg)
    ocfg = AdamWConfig()
    opt = adamw_init(params, ocfg)
    step = jax.jit(make_gnn_train_step(
        cfg, lambda p, gg, c: gnn.node_classification_loss(p, gg, c), ocfg))
    params, opt, loss, gn = step(params, opt, g)
    assert np.isfinite(float(loss)) and np.isfinite(float(gn))
    out = gnn.FORWARDS[cfg.arch](params, g, cfg)
    assert out.shape == (n, cfg.n_classes)
    assert np.isfinite(np.asarray(out)).all()


def test_nequip_smoke():
    mod = importlib.import_module("repro.configs.nequip")
    cfg = mod.smoke_config()
    rng = np.random.default_rng(0)
    n, e = 16, 48
    g = {
        "species": jnp.asarray(rng.integers(0, cfg.n_species, n).astype(np.int32)),
        "pos": jnp.asarray(rng.standard_normal((n, 3)), jnp.float32),
        "edge_src": jnp.asarray(rng.integers(0, n, e).astype(np.int32)),
        "edge_dst": jnp.asarray(rng.integers(0, n, e).astype(np.int32)),
        "energy": jnp.zeros((1,), jnp.float32),
    }
    params = nq.init(jax.random.PRNGKey(0), cfg)
    ocfg = AdamWConfig()
    opt = adamw_init(params, ocfg)
    step = jax.jit(make_gnn_train_step(
        cfg, lambda p, gg, c: nq.mse_loss(p, gg, c), ocfg))
    params, opt, loss, gn = step(params, opt, g)
    assert np.isfinite(float(loss)) and np.isfinite(float(gn))
    e_out = nq.forward(params, g, cfg)
    assert e_out.shape == (1,)


def test_wide_deep_smoke():
    mod = importlib.import_module("repro.configs.wide_deep")
    cfg = mod.smoke_config()
    rng = np.random.default_rng(0)
    b = 8
    wide = rng.integers(0, cfg.wide_vocab, (b, cfg.n_wide_crosses))
    batch = {
        "sparse_ids": jnp.asarray(
            rng.integers(0, cfg.vocab_per_field, (b, cfg.n_sparse)),
            jnp.int32),
        "dense": jnp.asarray(rng.standard_normal((b, cfg.n_dense)),
                             jnp.float32),
        "wide_ids": jnp.asarray(wide.astype(np.int32)),
        "labels": jnp.asarray(rng.integers(0, 2, b).astype(np.int32)),
    }
    params = wd.init(jax.random.PRNGKey(0), cfg)
    ocfg = AdamWConfig(state_mode="factored")
    opt = adamw_init(params, ocfg)
    step = jax.jit(make_recsys_train_step(cfg, ocfg))
    params, opt, loss, gn = step(params, opt, batch)
    assert np.isfinite(float(loss)) and np.isfinite(float(gn))
    logits = wd.forward(params, batch, cfg)
    assert logits.shape == (b,)


def test_molecule_batched_graph_smoke():
    """The molecule shape path: batched small graphs with graph pooling."""
    mod = importlib.import_module("repro.configs.gin_tu")
    cfg = mod.smoke_config()
    rng = np.random.default_rng(1)
    bsz, npg, epg = 4, 6, 10
    n, e = bsz * npg, bsz * epg
    gid = np.repeat(np.arange(bsz), npg).astype(np.int32)
    src = (rng.integers(0, npg, e) + gid[rng.integers(0, n, e)] * 0).astype(np.int32)
    # keep edges within their graph
    src = np.concatenate([rng.integers(0, npg, epg) + i * npg
                          for i in range(bsz)]).astype(np.int32)
    dst = np.concatenate([rng.integers(0, npg, epg) + i * npg
                          for i in range(bsz)]).astype(np.int32)
    g = {
        "x": jnp.asarray(rng.standard_normal((n, cfg.d_in)), jnp.float32),
        "edge_src": jnp.asarray(src), "edge_dst": jnp.asarray(dst),
        "labels": jnp.asarray(np.zeros(n, np.int32)),
        "graph_ids": jnp.asarray(gid),
        "n_graphs": bsz,
        "graph_labels": jnp.asarray(
            rng.integers(0, cfg.n_classes, bsz).astype(np.int32)),
    }
    params = gnn.gin_init(jax.random.PRNGKey(0), cfg)
    loss, _ = gnn.node_classification_loss(params, g, cfg)
    assert np.isfinite(float(loss))
