"""Scale/churn test: ~200 standing queries across several structural
groups, with interleaved unregister/re-register churn mid-stream.

Checks, per ROADMAP's "service at 100s-1000s of slots" item:

* per-qid oracle parity — every live tenant's window matches equal the
  brute-force oracle over exactly the stream suffix it was registered
  for (oracles deduped by (structure, labels, window, start) since
  identically-parameterized tenants must agree);
* a HARD no-recompile bound: 200 registrations across 3 structural
  signatures cost exactly 3 ``build_slot_tick`` builds (SlotTickCache
  misses), and every shared jitted tick holds exactly ONE trace — jit
  cache misses are counted via ``_cache_size()``, so slot churn, group
  overflow, and re-registration are all proven to be pure data writes.
"""

from repro.core.multi import SlotTickCache
from repro.core.oracle import OracleEngine
from repro.core.query import QueryGraph
from repro.runtime.service import ContinuousSearchService
from repro.stream.generator import to_batches

from test_engine_oracle import small_stream

CAP = dict(level_capacity=512, l0_capacity=512, max_new=256)
VARIANTS = [(0, 1, 0), (1, 0, 1), (0, 0, 1), (1, 1, 0)]
WINDOWS = [12, 18]
N_PHASE1 = 120          # registered up-front
N_PHASE2 = 80           # re-registered mid-stream (after churn)


def make_query(kind: int, labels) -> QueryGraph:
    a, b, c = labels
    if kind == 0:       # timing-ordered 2-chain
        return QueryGraph(3, (a, b, c), ((0, 1), (1, 2)),
                          prec=frozenset({(0, 1)}))
    if kind == 1:       # triangle with a timing chain
        return QueryGraph(3, (a, b, c), ((0, 1), (1, 2), (2, 0)),
                          prec=frozenset({(0, 1), (1, 2)}))
    return QueryGraph(3, (a, b, c), ((0, 1), (0, 2)),   # fork, e1 ≺ e0
                      prec=frozenset({(1, 0)}))


def params(i: int):
    """Deterministic (kind, labels, window) assignment for tenant #i."""
    return (i % 3, VARIANTS[(i // 3) % len(VARIANTS)],
            WINDOWS[(i // 12) % len(WINDOWS)])


def test_scale_churn_oracle_parity_and_no_recompiles():
    tc = SlotTickCache()
    svc = ContinuousSearchService(slots_per_group=16, tick_cache=tc, **CAP)
    stream = small_stream(128, n_vertices=10, n_vertex_labels=2,
                          n_edge_labels=2, seed=51)
    batches = list(to_batches(stream, 16))
    half_ticks = len(batches) // 2
    half_edges = half_ticks * 16

    # ---- phase 1: 120 tenants across 3 structural signatures -----------
    meta = {}                                  # qid -> (kind, labels, w, start)
    for i in range(N_PHASE1):
        kind, labels, w = params(i)
        qid = svc.register(make_query(kind, labels), w)
        meta[qid] = (kind, labels, w, 0)
    assert svc.n_active == N_PHASE1
    assert svc.n_compiles == tc.n_builds == 3   # one build per signature

    for b in batches[:half_ticks]:
        out = svc.ingest(b)
        assert set(out) == set(meta)

    # ---- churn: every 3rd tenant leaves, 80 new ones arrive ------------
    dropped = [qid for qid in list(meta) if qid % 3 == 0]
    for qid in dropped:
        svc.unregister(qid)
        del meta[qid]
    for i in range(N_PHASE2):
        kind, labels, w = params(7 * i + 1)     # different mix than phase 1
        qid = svc.register(make_query(kind, labels), w)
        meta[qid] = (kind, labels, w, half_edges)
    assert svc.n_active == N_PHASE1 - len(dropped) + N_PHASE2
    assert max(meta) == N_PHASE1 + N_PHASE2 - 1   # 200 registrations total

    for b in batches[half_ticks:]:
        out = svc.ingest(b)
        assert set(out) == set(meta)

    # ---- hard no-recompile bound ---------------------------------------
    # 200 registrations, group overflow, churn, slot reuse: still exactly
    # one build and ONE XLA trace per structural signature.
    assert svc.n_compiles == tc.n_builds == 3
    assert [t._cache_size() for t in tc.ticks()] == [1, 1, 1]
    n_groups = len(svc._iter_groups())
    assert n_groups * svc.slots_per_group >= svc.n_active
    assert n_groups <= 16           # grouping actually packs the tenants

    # ---- per-qid oracle parity (oracles deduped by parameterization) ---
    expected = {}
    for qid, (kind, labels, w, start) in meta.items():
        key = (kind, labels, w, start)
        if key not in expected:
            oracle = OracleEngine(make_query(kind, labels), w)
            for e in stream[start:]:
                oracle.insert(e)
            expected[key] = oracle.matches()
        assert svc.matches(qid) == expected[key], (qid, key)
        assert int(svc.stats(qid).n_overflow) == 0
    # not vacuous: matches WERE found during the run (the end-of-stream
    # windows may legitimately be empty under small window spans)
    assert sum(int(svc.stats(qid).n_matches_total) for qid in meta) > 0
    # dropped tenants are really gone
    assert all(qid not in svc.registry for qid in dropped)
