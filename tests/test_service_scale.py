"""Scale/churn test: ~200 standing queries across several structural
groups, with interleaved unregister/re-register churn mid-stream.

Checks, per ROADMAP's "service at 100s-1000s of slots" item:

* per-qid oracle parity — every live tenant's window matches equal the
  brute-force oracle over exactly the stream suffix it was registered
  for (oracles deduped by (structure, labels, window, start) since
  identically-parameterized tenants must agree);
* a HARD no-recompile bound: 200 registrations across 3 structural
  signatures cost exactly 3 ``build_slot_tick`` builds (SlotTickCache
  misses), and every shared jitted tick holds exactly ONE trace — jit
  cache misses are counted via ``_cache_size()``, so slot churn, group
  overflow, and re-registration are all proven to be pure data writes.
"""

from repro.api import Pattern, StreamSession
from repro.core.multi import SlotTickCache
from repro.core.oracle import OracleEngine
from repro.core.query import QueryGraph
from repro.runtime.service import ContinuousSearchService
from repro.stream.generator import to_batches

from test_engine_oracle import small_stream

CAP = dict(level_capacity=512, l0_capacity=512, max_new=256)
VARIANTS = [(0, 1, 0), (1, 0, 1), (0, 0, 1), (1, 1, 0)]
WINDOWS = [12, 18]
N_PHASE1 = 120          # registered up-front
N_PHASE2 = 80           # re-registered mid-stream (after churn)


def make_query(kind: int, labels) -> QueryGraph:
    a, b, c = labels
    if kind == 0:       # timing-ordered 2-chain
        return QueryGraph(3, (a, b, c), ((0, 1), (1, 2)),
                          prec=frozenset({(0, 1)}))
    if kind == 1:       # triangle with a timing chain
        return QueryGraph(3, (a, b, c), ((0, 1), (1, 2), (2, 0)),
                          prec=frozenset({(0, 1), (1, 2)}))
    return QueryGraph(3, (a, b, c), ((0, 1), (0, 2)),   # fork, e1 ≺ e0
                      prec=frozenset({(1, 0)}))


def params(i: int):
    """Deterministic (kind, labels, window) assignment for tenant #i."""
    return (i % 3, VARIANTS[(i // 3) % len(VARIANTS)],
            WINDOWS[(i // 12) % len(WINDOWS)])


def test_scale_churn_oracle_parity_and_no_recompiles():
    tc = SlotTickCache()
    svc = ContinuousSearchService(slots_per_group=16, tick_cache=tc, **CAP)
    stream = small_stream(128, n_vertices=10, n_vertex_labels=2,
                          n_edge_labels=2, seed=51)
    batches = list(to_batches(stream, 16))
    half_ticks = len(batches) // 2
    half_edges = half_ticks * 16

    # ---- phase 1: 120 tenants across 3 structural signatures -----------
    meta = {}                                  # qid -> (kind, labels, w, start)
    for i in range(N_PHASE1):
        kind, labels, w = params(i)
        qid = svc.register(make_query(kind, labels), w)
        meta[qid] = (kind, labels, w, 0)
    assert svc.n_active == N_PHASE1
    assert svc.n_compiles == tc.n_builds == 3   # one build per signature

    for b in batches[:half_ticks]:
        out = svc.ingest(b)
        assert set(out) == set(meta)

    # ---- churn: every 3rd tenant leaves, 80 new ones arrive ------------
    dropped = [qid for qid in list(meta) if qid % 3 == 0]
    for qid in dropped:
        svc.unregister(qid)
        del meta[qid]
    for i in range(N_PHASE2):
        kind, labels, w = params(7 * i + 1)     # different mix than phase 1
        qid = svc.register(make_query(kind, labels), w)
        meta[qid] = (kind, labels, w, half_edges)
    assert svc.n_active == N_PHASE1 - len(dropped) + N_PHASE2
    assert max(meta) == N_PHASE1 + N_PHASE2 - 1   # 200 registrations total

    for b in batches[half_ticks:]:
        out = svc.ingest(b)
        assert set(out) == set(meta)

    # ---- hard no-recompile bound ---------------------------------------
    # 200 registrations, group overflow, churn, slot reuse: still exactly
    # one build and ONE XLA trace per structural signature.
    assert svc.n_compiles == tc.n_builds == 3
    assert [t._cache_size() for t in tc.ticks()] == [1, 1, 1]
    n_groups = len(svc._iter_groups())
    assert n_groups * svc.slots_per_group >= svc.n_active
    assert n_groups <= 16           # grouping actually packs the tenants

    # ---- per-qid oracle parity (oracles deduped by parameterization) ---
    expected = {}
    for qid, (kind, labels, w, start) in meta.items():
        key = (kind, labels, w, start)
        if key not in expected:
            oracle = OracleEngine(make_query(kind, labels), w)
            for e in stream[start:]:
                oracle.insert(e)
            expected[key] = oracle.matches()
        assert svc.matches(qid) == expected[key], (qid, key)
        assert int(svc.stats(qid).n_overflow) == 0
    # not vacuous: matches WERE found during the run (the end-of-stream
    # windows may legitimately be empty under small window spans)
    assert sum(int(svc.stats(qid).n_matches_total) for qid in meta) > 0
    # dropped tenants are really gone
    assert all(qid not in svc.registry for qid in dropped)


# --------------------------------------------------------------------- #
# canonicalization-powered compile-budget sharing (repro.api planner)
# --------------------------------------------------------------------- #
def chain_authorings(n: int):
    """``n`` syntactically different authorings of ONE abstract pattern:
    a timing-ordered 2-chain with labels (0, 1, 2).  Vertex names, edge
    statement order, edge names, and before-references all vary — only
    the isomorphism class is constant."""
    out = []
    for i in range(n):
        a, b, c = f"h{i}", f"m{i}", f"t{i}"
        p = Pattern(f"variant-{i}")
        p.vertex(a, label=0).vertex(b, label=1).vertex(c, label=2)
        if i % 2 == 0:              # forward authoring, index-based before
            p.edge(a, b).edge(b, c).before(0, 1)
        else:                       # reversed authoring, name-based before
            p.edge(b, c, name="late").edge(a, b, name="early")
            p.before("early", "late")
        out.append(p.window(16))
    return out


def test_isomorphic_authorings_share_one_build_and_group():
    """N syntactically different but isomorphic-modulo-relabeling
    patterns must cost exactly ONE SlotTickCache build and ONE slot
    group — the canonicalizing planner maps them to one plan_signature
    (without it, the two authoring shapes compile to different edge
    orderings and fragment into separate groups/compiles)."""
    N = 8
    tc = SlotTickCache()
    sess = StreamSession(slots_per_group=N, tick_cache=tc, **CAP)
    subs = [sess.register(p) for p in chain_authorings(N)]
    assert len(subs) == N
    assert tc.n_builds == 1                       # ONE SlotTickCache build
    assert sess.service.n_compiles == 1
    assert len(sess.service._iter_groups()) == 1  # ONE slot group
    # and every tenant's canonical query is literally identical
    assert len({s.query for s in subs}) == 1

    # serving proves the shared tick really serves all variants: one XLA
    # trace total, per-variant results oracle-consistent with each other
    stream = small_stream(128, n_vertices=10, n_vertex_labels=3,
                          n_edge_labels=2, seed=54)
    delivered = sess.ingest(stream, batch_size=16)
    assert delivered > 0 and delivered % N == 0   # every variant reported
    assert tc.n_builds == 1
    assert [t._cache_size() for t in tc.ticks()] == [1]
    # identical parameterization -> identical matches (vertex names
    # differ per variant; compare the name-free binding/time multisets)
    stripped = {
        frozenset((tuple(dv for _, dv in m.vertices),
                   frozenset(ts for _, ts in m.edges))
                  for m in s.drain())
        for s in subs}
    assert len(stripped) == 1
    assert stripped.pop()           # non-degenerate: matches were found
