"""Event-time window semantics: the watermark-driven engine clock.

The bug this file pins down: the engine clock was pure processing time
(``t_now = max(t_now, max batch ts)``), so one force-evicted straggler
released by the ingestion frontier slammed the clock forward and expired
window content that was still inside ``allowed_lateness`` — while the
frontier's own bookkeeping (late drops, checkpoint cursors) ran on the
watermark clock.  The fix threads the frontier watermark into the tick
as a traced scalar:

* clock:      ``t_now' = max(t_now, min(watermark, max batch ts))``
* admission:  an edge at-or-below the released floor
              (``ts <= t_now - window``, judged pre-advance) is
              rejected-and-counted (``EngineStats.n_edges_rejected``),
              never joined, never written to a table;
* expiry:     unchanged, but keyed off the bounded clock.

Proofs, on REF and PALLAS_INTERPRET:

1. engine units — a straggler inside the lateness bound still joins
   after a future-ts edge arrived first (the legacy max-ts clock
   provably loses that match); a strictly-late edge is rejected,
   counted, and never resurrects anything;
2. hypothesis property — the engine under any frontier-produced release
   order mirrors the event-time oracle replay edge-for-edge (matches
   AND rejection counts), and when nothing is dropped the final match
   set is invariant to the arrival permutation;
3. the service differential — ``serve_frontier`` under chaos equals the
   event-time oracle replay of the emitted stream *including expiry
   decisions*, with ``Counter(emitted) + Counter(dropped) ==
   Counter(stream)`` accounting;
4. the satellite regressions — FAILED-source exhaustion (the
   busy-loop deadlock), the drain-sentinel leak, forced-gap vs
   late-drop attribution, and the watermark checkpoint round-trip.
"""

from collections import Counter

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import compile_plan
from repro.core.engine import NO_WATERMARK, build_tick, current_matches
from repro.core.join import JoinBackend
from repro.core.multi import SlotTickCache
from repro.core.oracle import DataEdge, OracleEngine
from repro.core.state import init_state, make_batch
from repro.runtime.fault import RetryPolicy
from repro.stream.generator import DisorderConfig, disordered_sources, \
    to_batches
from repro.stream.ingest import IngestError, IngestFrontier, ListSource, \
    ScriptedSource, Source, SourceDisconnected

from test_engine_oracle import small_stream
from test_ingest_chaos import NO_SLEEP, QUERIES, RETRY, _chaos_sources, \
    _fresh
from test_service_restore import CAP, SERVE, EventLog, chain_query

BACKENDS = [JoinBackend.REF, JoinBackend.PALLAS_INTERPRET]

I32 = jnp.int32


def edge(ts, src=0, dst=1, lab=0):
    return DataEdge(src=src, dst=dst, ts=ts, src_label=0, dst_label=0,
                    edge_label=lab)


def c_edge(eid, src, dst, ts):
    """An edge matching ``chain_query``'s query edge ``eid`` (vertex
    labels run 0 -> 1 -> 2 along the chain)."""
    return DataEdge(src=src, dst=dst, ts=ts, src_label=eid,
                    dst_label=eid + 1, edge_label=0)


def _ticker(backend, window=20):
    plan = compile_plan(chain_query(), window, level_capacity=64,
                        l0_capacity=64, max_new=64)
    return plan, jax.jit(build_tick(plan, backend=backend))


def _one(tick, state, e, watermark):
    b = to_batches([e], 4)[0]
    wm = None if watermark is None else jnp.asarray(watermark, I32)
    return tick(state, make_batch(**b), wm)


# --------------------------------------------------------------------- #
# engine units: the clock-drift bugfix, admission, rejection accounting
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
def test_watermark_bounds_clock_so_straggler_still_joins(backend):
    """The fix itself: a future-ts edge (force-evicted past the
    watermark) must NOT jump the window clock; a straggler inside the
    lateness bound still finds its join partner.  The legacy max-ts
    clock provably loses the match on the same traffic."""
    plan, tick = _ticker(backend)
    a = c_edge(0, 1, 2, ts=5)       # partial: chain edge 0
    x = c_edge(0, 7, 8, ts=100)     # evicted straggler, far future ts
    b = c_edge(1, 2, 3, ts=9)       # completes the chain with ``a``

    # event-time run: watermark trails at 6..9 (allowed lateness)
    st = init_state(plan)
    st, _ = _one(tick, st, a, 5)
    st, _ = _one(tick, st, x, 6)    # clock advances to 6, NOT 100
    st, _ = _one(tick, st, b, 9)
    assert len(current_matches(plan, st)) == 1
    assert int(st.stats.n_edges_rejected) == 0

    # same traffic on the legacy processing-time clock: ``x`` jumps the
    # clock to 100, expires ``a``, and the match is lost — the drift bug
    st = init_state(plan)
    for e in (a, x, b):
        st, _ = _one(tick, st, e, None)
    assert current_matches(plan, st) == set()
    assert int(st.stats.n_edges_rejected) == 0   # legacy mode never rejects

    # oracle mirror of the event-time run
    oracle = OracleEngine(chain_query(), 20)
    for e, wm in ((a, 5), (x, 6), (b, 9)):
        oracle.insert(e, watermark=wm)
    assert len(oracle.matches()) == 1


@pytest.mark.parametrize("backend", BACKENDS)
def test_strictly_late_edge_rejected_counted_never_joined(backend):
    """An edge at-or-below the released event-time floor is rejected
    and counted BEFORE the clock moves — it never joins, never touches
    a table; an edge inside the lateness bound on the same traffic does
    join."""
    plan, tick = _ticker(backend)
    st = init_state(plan)
    p = c_edge(0, 1, 2, ts=32)          # chain edge 0: the live partial
    st, _ = _one(tick, st, p, 32)
    # another edge-0 partial at ts=50 advances the clock: floor -> 30
    st, _ = _one(tick, st, c_edge(0, 7, 8, ts=50), 50)
    assert int(st.t_now) == 50

    # strictly late (ts=25 <= 30): rejected, counted, clock unmoved.
    # Same vertices as ``p`` — if it were wrongly admitted, the
    # successor below would find TWO partials to complete.
    st, res = _one(tick, st, c_edge(0, 1, 2, ts=25), 50)
    assert int(res.n_new_matches) == 0
    assert int(st.stats.n_edges_rejected) == 1
    assert int(st.t_now) == 50              # rejection judged pre-advance

    # in-window successor (ts=35 > 30): admitted, joins the still-live
    # partial exactly once — the rejected edge never reached a table
    st, res = _one(tick, st, c_edge(1, 2, 3, ts=35), 50)
    assert int(res.n_new_matches) == 1
    assert len(current_matches(plan, st)) == 1
    assert int(st.stats.n_edges_rejected) == 1

    # oracle mirrors every decision, including the rejection counter
    oracle = OracleEngine(chain_query(), 20)
    oracle.insert(p, watermark=32)
    oracle.insert(c_edge(0, 7, 8, ts=50), watermark=50)
    oracle.insert(c_edge(0, 1, 2, ts=25), watermark=50)
    assert oracle.n_rejected == 1 and oracle.matches() == set()
    oracle.insert(c_edge(1, 2, 3, ts=35), watermark=50)
    assert oracle.n_rejected == 1 and len(oracle.matches()) == 1


# --------------------------------------------------------------------- #
# hypothesis: permutation invariance of watermark-driven expiry
# --------------------------------------------------------------------- #
_PROP_CACHE = {}


def _prop_ticker():
    if "tick" not in _PROP_CACHE:
        plan = compile_plan(chain_query(), 20, level_capacity=128,
                            l0_capacity=128, max_new=128)
        _PROP_CACHE["plan"] = plan
        _PROP_CACHE["tick"] = jax.jit(build_tick(plan))
    return _PROP_CACHE["plan"], _PROP_CACHE["tick"]


def _run_chunks(plan, tick, chunks):
    """Drive the engine one tick per (edges, watermark) chunk, padded to
    a fixed batch width (single trace)."""
    state = init_state(plan)
    rejected = 0
    for edges, wm in chunks:
        for b in to_batches(edges, 8):
            state, _ = tick(state, make_batch(**b),
                            jnp.asarray(NO_WATERMARK if wm is None else wm,
                                        I32))
        rejected = int(state.stats.n_edges_rejected)
    assert int(state.stats.n_overflow) == 0
    return state, rejected


try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:                             # optional dev dependency
    HAVE_HYPOTHESIS = False


def _check_reorder_invariance(raw, frac, delay, seed):
    """The property: for any release order the frontier may legally
    produce, the engine under the per-chunk watermark mirrors the
    event-time oracle replay edge-for-edge (final matches AND rejection
    counts), accounting reconciles every delivery, and when nothing is
    dropped the final match set equals the canonically-ordered legacy
    run."""
    stream = sorted(
        (c_edge(eid, s, d + 4, ts) for eid, s, d, ts in raw),
        key=lambda e: (e.ts, e.src, e.dst, e.src_label))
    scripts = disordered_sources(stream, DisorderConfig(
        n_sources=2, disorder_frac=frac, max_delay=delay, seed=seed))
    fr = IngestFrontier(
        [ScriptedSource(f"s{i}", sc) for i, sc in enumerate(scripts)],
        allowed_lateness=12, **NO_SLEEP)
    dropped = []
    fr.on("drop_late", lambda name, e, seq: dropped.append(e))
    fr.on("drop_forced_gap", lambda name, e, seq: dropped.append(e))
    chunks = []
    while not fr.exhausted:
        fr.pump()
        got = fr.take_ready(limit=8)
        while got:
            chunks.append((got, fr.watermark()))
            got = fr.take_ready(limit=8)
    emitted = [e for es, _ in chunks for e in es]
    assert Counter(emitted) + Counter(dropped) == Counter(stream)

    plan, tick = _prop_ticker()
    state, rejected = _run_chunks(plan, tick, chunks)

    oracle = OracleEngine(chain_query(), 20)
    for edges, wm in chunks:
        for e in edges:
            oracle.insert(e, watermark=wm)
    assert current_matches(plan, state) == oracle.matches()
    assert rejected == oracle.n_rejected

    if not dropped:      # permutation invariance when everything arrives
        ref = init_state(plan)
        for b in to_batches(stream, 8):
            ref, _ = tick(ref, make_batch(**b), None)
        assert current_matches(plan, state) == current_matches(plan, ref)


if HAVE_HYPOTHESIS:
    @settings(deadline=None, max_examples=15)
    @given(
        raw=st.lists(
            st.tuples(st.integers(0, 1),          # which chain edge
                      st.integers(0, 3), st.integers(0, 3),  # vertices
                      st.integers(0, 60)),        # event time
            min_size=4, max_size=12),
        frac=st.floats(0.0, 1.0),
        delay=st.integers(0, 10),
        seed=st.integers(0, 2 ** 16),
    )
    def test_watermark_expiry_invariant_to_frontier_reorder(
            raw, frac, delay, seed):
        _check_reorder_invariance(raw, frac, delay, seed)


def test_watermark_expiry_reorder_invariance_seeded():
    """Deterministic sweep of the reorder-invariance property — always
    runs; the hypothesis wrapper above widens the search when the
    optional dev dependency is installed."""
    rng = np.random.default_rng(5)
    for seed in range(6):
        raw = [(int(rng.integers(0, 2)), int(rng.integers(0, 4)),
                int(rng.integers(0, 4)), int(rng.integers(0, 61)))
               for _ in range(int(rng.integers(4, 13)))]
        _check_reorder_invariance(
            raw, float(rng.random()), int(rng.integers(0, 11)), seed)


# --------------------------------------------------------------------- #
# the service differential: serve_frontier == event-time oracle replay
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("backend", BACKENDS)
def test_serve_frontier_equals_event_time_oracle_replay(backend):
    """Acceptance: under the chaos harness, ``serve_frontier`` produces
    the exact oracle match set of the event-time replay of the emitted
    stream — including expiry decisions — with every delivery emitted or
    counted."""
    tc = SlotTickCache()
    stream = small_stream(120, n_vertices=9, seed=71)
    fr = IngestFrontier(_chaos_sources(stream, seed=23),
                        allowed_lateness=80, stall_patience=4,
                        retry=RETRY, **NO_SLEEP)
    emitted, dropped = [], []
    fr.on("event", lambda e: emitted.append(e))
    fr.on("drop_late", lambda name, e, seq: dropped.append(e))
    fr.on("drop_forced_gap", lambda name, e, seq: dropped.append(e))

    svc, qids = _fresh(backend, tc)
    log = EventLog(svc)
    infos = []
    svc.serve_frontier(fr, on_match=log.on_match,
                       on_tick=lambda i: (infos.append(i),
                                          log.on_tick(i)), **SERVE)

    assert Counter(emitted) + Counter(dropped) == Counter(stream)
    assert fr.stats().n_late_dropped == 0     # lateness=80 covers disorder

    # per-edge watermark = the watermark of the tick that consumed it
    wm_per_edge, prev = [], 0
    for i in infos:
        wm_per_edge.extend([i.watermark] * (i.n_edges_ingested - prev))
        prev = i.n_edges_ingested
    assert len(wm_per_edge) == len(emitted)

    for (q, window), qid in zip(QUERIES, qids):
        oracle = OracleEngine(q, window)
        for e, wm in zip(emitted, wm_per_edge):
            oracle.insert(e, watermark=wm)
        # expiry decisions included: the final windows agree exactly
        assert svc.matches(qid) == oracle.matches()
        assert oracle.n_rejected == 0


# --------------------------------------------------------------------- #
# satellite regressions
# --------------------------------------------------------------------- #
def test_failed_source_is_terminal_for_exhaustion():
    """The busy-loop deadlock: a source whose retry budget is spent used
    to hold ``exhausted`` open forever, spinning any caller that
    swallowed the IngestError.  FAILED is now terminal-for-exhaustion
    and loud in ``stats()``."""
    class DeadSource(Source):
        name = "dead"

        def connect(self, resume_from=0):
            pass

        def poll(self, max_events=64):
            raise SourceDisconnected("dead")

    fr = IngestFrontier(
        [ListSource("ok", [edge(1), edge(2), edge(3)]), DeadSource()],
        retry=RetryPolicy(max_attempts=2, base_delay_s=0.0), **NO_SLEEP)
    with pytest.raises(IngestError, match="retry budget exhausted"):
        fr.drain()
    # bounded loop: without the fix this never reaches exhaustion
    out = []
    for _ in range(50):
        if fr.exhausted:
            break
        out.extend(fr.drain())
    assert fr.exhausted, "FAILED source held the frontier open"
    assert [e.ts for e in out] == [1, 2, 3]
    s = fr.stats()
    assert s.n_failed_sources == 1            # terminal, but never silent
    assert s.by_source["dead"]["state"] == "failed"
    assert s.watermark == 3                   # survivors still drained


def test_watermark_never_surfaces_drain_sentinel():
    """The sentinel leak: once every source drained, ``watermark()``
    used to surface the internal ``2**63 - 1`` release bound.  It must
    return real event timestamps (int32-safe) or None — never the
    sentinel."""
    fr = IngestFrontier([ListSource("a", [edge(3), edge(7)])], **NO_SLEEP)
    assert fr.watermark() is None             # nothing observed yet
    while not fr.exhausted:
        fr.drain()
    wm = fr.watermark()
    assert wm == 7 != 2 ** 63 - 1
    assert np.iinfo(np.int32).min <= wm <= np.iinfo(np.int32).max
    assert fr.stats().watermark == 7
    assert fr.to_manifest()["watermark"] == 7

    # an empty stream drains to "no event time observed", not a sentinel
    fr2 = IngestFrontier([ListSource("a", [])], **NO_SLEEP)
    assert fr2.exhausted
    assert fr2.watermark() is None
    assert fr2.stats().watermark is None


class _OpenSource(Source):
    """A connected source that never produces and never exhausts."""

    name = "open"

    def connect(self, resume_from=0):
        pass

    def poll(self, max_events=64):
        return []


def _forced_gap_frontier(fr):
    """Drive ``fr`` (script of 12 ordered events + one ancient straggler,
    capacity 4) into a forced-eviction gap, then deliver the straggler."""
    gap, late = [], []
    fr.on("drop_forced_gap", lambda name, e, seq: gap.append(e))
    fr.on("drop_late", lambda name, e, seq: late.append(e))
    for _ in range(3):
        fr.pump(max_per_source=4)             # buffer 12 ordered events
    released = fr.take_ready()                # capacity 4: 8 forced out
    fr.pump(max_per_source=4)                 # the ts=0 straggler arrives
    return gap, late, released


def test_forced_gap_drops_attributed_to_capacity_not_lateness():
    """Misattribution fix: a drop caused by forced evictions advancing
    the emit floor past the (unknown) watermark is capacity pressure,
    not user-visible lateness — it must land in ``n_dropped_forced_gap``
    and leave ``n_late_dropped`` untouched."""
    script = [(i, edge(t)) for i, t in enumerate(range(12))] + \
        [(12, edge(0))]
    fr = IngestFrontier([ScriptedSource("full", script), _OpenSource()],
                        reorder_capacity=4, stall_patience=10 ** 9,
                        **NO_SLEEP)
    gap, late, released = _forced_gap_frontier(fr)
    assert len(released) == 12 - 4 and fr.stats().n_forced == 8
    assert [e.ts for e in gap] == [0] and late == []
    s = fr.stats()
    assert s.n_dropped_forced_gap == 1 and s.n_late_dropped == 0
    assert s.watermark is None                # cause: wm was never known
    # accounting: every delivery emitted, buffered, or counted-dropped
    assert s.n_emitted + s.n_dropped_forced_gap + s.buffered == 13
    # the counter rides in the manifest
    assert fr.to_manifest()["counters"]["n_dropped_forced_gap"] == 1


def test_session_health_degrades_on_forced_gap_drops():
    """End-to-end surfacing: ANY capacity-pressure drop turns
    ``SessionStatus.health`` DEGRADED — unlike user lateness, no
    threshold makes silently widening the gap acceptable."""
    from repro.api import DEGRADED, StreamSession

    script = [(i, edge(t)) for i, t in enumerate(range(12))] + \
        [(12, edge(0))]
    sess = StreamSession(slots_per_group=2, **CAP)
    sess.register_query(chain_query(), 20)
    fr = sess.sources(
        {"full": ScriptedSource("full", script), "open": _OpenSource()},
        reorder_capacity=4, stall_patience=10 ** 9, **NO_SLEEP)
    _forced_gap_frontier(fr)
    sess.serve_frontier(fr, batch_size=16, max_idle_rounds=2)
    st = sess.status()
    assert st.n_dropped_forced_gap == 1
    assert st.n_late_dropped == 0             # not misattributed
    assert st.health == DEGRADED


def test_watermark_survives_manifest_roundtrip():
    """The event-time clock rides in checkpoints: a restored frontier
    resumes at (or above) the checkpointed watermark BEFORE any source
    produces — no re-expiry, no resurrection — and the stream completes
    exactly-once."""
    stream = [edge(t) for t in range(8)]
    fr = IngestFrontier([ListSource("a", stream)], allowed_lateness=2,
                        **NO_SLEEP)
    fr.pump(max_per_source=4)
    got = fr.take_ready()                     # partial consumption
    assert got and not fr.exhausted
    man = fr.to_manifest()
    assert man["watermark"] == fr.watermark() is not None

    fr2 = IngestFrontier.resume(man, [ListSource("a", stream)],
                                allowed_lateness=2, **NO_SLEEP)
    # the clock survives the restart even before the first pump
    assert fr2.watermark() == man["watermark"]
    rest = []
    while not fr2.exhausted:
        rest.extend(fr2.drain())
    assert Counter(got) + Counter(rest) == Counter(stream)
    assert fr2.watermark() == 7               # drained: clock at max ts
    assert fr2.watermark() >= man["watermark"]
