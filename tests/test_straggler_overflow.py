"""Deterministic tests for the coalescer's overflow throttle.

Separate from tests/test_straggler_props.py on purpose: that module is
gated on the optional ``hypothesis`` dependency, and none of these need
it — a regression in the capacity-MD path must fail even in
environments without dev extras.  (The hypothesis module holds the
randomized bound/monotonicity properties for the same path.)
"""

from repro.core.query import QueryGraph
from repro.runtime.service import ContinuousSearchService
from repro.runtime.straggler import TickCoalescer

from test_engine_oracle import small_stream


def test_overflow_halves_batch_immediately():
    c = TickCoalescer(batch=256)
    assert c.record(1.0, 10**9, n_overflow=5) == 128   # despite MI headroom
    assert c.record(1.0, 10**9, n_overflow=5) == 64


def test_sustained_overflow_reaches_min_batch():
    c = TickCoalescer()            # fast ticks, deep queue: would grow
    for _ in range(20):
        b = c.record(1.0, queue_depth=10**9, n_overflow=5)
    assert b == c.min_batch


def test_overflow_clears_then_recovers():
    """After the overflow pressure clears, MI growth resumes."""
    c = TickCoalescer()
    c.record(1.0, queue_depth=10**9, n_overflow=1)
    shrunk = c.batch
    for _ in range(10):
        b = c.record(1.0, queue_depth=10**9, n_overflow=0)
    assert b > shrunk


def test_serve_stream_throttles_chunks_on_engine_overflow():
    """End-to-end: a service whose tiny tables overflow must shrink the
    served chunk sizes (ServeInfo.n_overflow feeds the coalescer), not
    keep hammering full-size ticks into saturated tables."""
    svc = ContinuousSearchService(
        slots_per_group=2, level_capacity=16, l0_capacity=16, max_new=4)
    svc.register(QueryGraph(3, (0, 0, 0), ((0, 1), (1, 2)),
                            prec=frozenset({(0, 1)})), 60)
    stream = small_stream(512, n_vertices=6, n_vertex_labels=1, seed=3)
    infos = []
    svc.serve_stream(stream, on_tick=infos.append, batch_size=64,
                     min_batch=8, max_batch=64)
    overflowed = [i for i, inf in enumerate(infos) if inf.n_overflow > 0]
    assert overflowed, "stream failed to saturate the tiny tables"
    first = overflowed[0]
    assert first + 1 < len(infos)
    # the very next tick is at most half the overflowing one (modulo the
    # stream tail), and the loop reaches the floor under sustained load
    assert infos[first + 1].chunk <= max(8, infos[first].chunk // 2)
    assert min(inf.chunk for inf in infos[first:]) == 8
