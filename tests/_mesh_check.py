"""Subprocess helper: replica-sharded serving parity on 8 virtual devices.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=8 (the parent
test sets this; tests/test_mesh.py asserts the MESH-OK sentinel).

Proves, end to end on a real multi-device mesh:

* sharded service == single-device service == brute-force oracle, as
  exact per-tenant match multisets, on 1-, 2- and 8-replica meshes,
  with prefix sharing enabled and tenant churn mid-stream;
* crash + restore through SHARDED checkpoints reports exactly the
  uninterrupted run's multiset — restoring onto the same mesh (zero
  warm rebuilds) and onto a DIFFERENT mesh size (8 -> 2 repack);
* placement policies put tenants where they claim to;
* the engine-level composition: capacity-axis ``build_sharded_tick``
  with a replicated shared-prefix view matches the unsharded prefix
  tick (full and partial prefix depths).
"""

import os

os.environ.setdefault("XLA_FLAGS",
                      "--xla_force_host_platform_device_count=8")

from collections import Counter  # noqa: E402

import jax  # noqa: E402

from repro.core import compile_plan  # noqa: E402
from repro.core.distributed import build_sharded_tick  # noqa: E402
from repro.core.engine import build_tick  # noqa: E402
from repro.core.join import JoinBackend  # noqa: E402
from repro.core.multi import SlotTickCache  # noqa: E402
from repro.core.share import (  # noqa: E402
    SharedPrefixForest,
    shared_current_matches,
)
from repro.core.state import init_state, make_batch  # noqa: E402
from repro.runtime import (  # noqa: E402
    ContinuousSearchService,
    ShardedSearchService,
)
from repro.stream.generator import to_batches  # noqa: E402

from test_engine_oracle import small_stream  # noqa: E402
from test_service_restore import event_key, oracle_reported  # noqa: E402
from test_share import (  # noqa: E402
    CAP,
    SERVE,
    W,
    chain2,
    chain2_other_labels,
    chain3,
    fork,
    tri,
)

QUERIES = [chain3(), chain2(), chain2(), chain2_other_labels(), fork(),
           tri()]


def stream160(seed=5):
    return small_stream(160, n_vertices=8, n_vertex_labels=3, seed=seed)


def reported(svc, stream, **serve):
    events = []

    def on_match(qid, bindings, ets):
        plan = svc.registry.get(qid).plan
        for b, t in zip(bindings, ets):
            events.append((qid, event_key(plan, b, t)))

    svc.serve_stream(stream, on_match=on_match, **SERVE, **serve)
    return Counter(events)


def drive_with_churn(svc, stream):
    """Register all queries, serve half, churn (2 leave, 1 arrives),
    serve the rest.  Returns (multiset of reports, final qids)."""
    qids = [svc.register(q, W) for q in QUERIES]
    half = 80
    count = reported(svc, stream[:half])
    svc.unregister(qids[1])          # a chain2 tenant leaves
    svc.unregister(qids[4])          # the fork tenant leaves
    late = svc.register(chain2(), W)   # fresh epoch mid-stream
    count += reported(svc, stream[half:])
    live = [qids[0], qids[2], qids[3], qids[5], late]
    return count, live, half


def check_mesh_differential():
    stream = stream160()
    ref = ContinuousSearchService(
        slots_per_group=4, tick_cache=SlotTickCache(),
        enable_sharing=True, **CAP)
    count_ref, live_ref, half = drive_with_churn(ref, stream)

    for n_replicas, spr in ((1, 8), (2, 4), (8, 1)):
        svc = ShardedSearchService(
            n_replicas=n_replicas, slots_per_replica=spr,
            tick_cache=SlotTickCache(), enable_sharing=True, **CAP)
        count, live, _ = drive_with_churn(svc, stream)
        assert count and count == count_ref, (
            n_replicas, len(count), len(count_ref))
        for qid_m, qid_r in zip(live, live_ref):
            assert svc.matches(qid_m) == ref.matches(qid_r), (
                n_replicas, qid_m)
        # oracle anchor for the mid-stream tenant: exactly the suffix
        want_reported, want_window = oracle_reported(
            chain2(), W, stream[half:])
        got = {k for (q, k) in count if q == live[-1]}
        assert got == want_reported, n_replicas
        assert svc.matches(live[-1]) == want_window
        # every replica really advanced the shared clock
        stats = svc.last_mesh_stats()
        assert stats and all(s["t_clock"] > 0 for s in stats.values())
    print("mesh differential ok", sum(count_ref.values()))


def check_crash_restore_and_reshard(tmpdir):
    stream = stream160(seed=7)
    tc = SlotTickCache()

    # uninterrupted sharded reference
    svc_a = ShardedSearchService(
        n_replicas=8, slots_per_replica=1, tick_cache=tc,
        enable_sharing=True, compact_every=4, **CAP)
    qids = [svc_a.register(q, W) for q in QUERIES]
    count_a = reported(svc_a, stream)

    def interrupted(restore_kwargs, sub):
        ckpt = os.path.join(tmpdir, sub)
        svc_b = ShardedSearchService(
            n_replicas=8, slots_per_replica=1, tick_cache=tc,
            enable_sharing=True, ckpt_dir=ckpt, compact_every=4, **CAP)
        for q in QUERIES:
            svc_b.register(q, W)
        count = reported(svc_b, stream[:96], ckpt_every=2)
        del svc_b                                   # "crash"
        before = tc.n_builds
        svc_c = ShardedSearchService.restore(ckpt, tick_cache=tc,
                                             **restore_kwargs)
        rebuilds = tc.n_builds - before
        count += reported(svc_c, stream[svc_c.n_edges_ingested:])
        return count, svc_c, rebuilds

    # same mesh size: exact layout, zero warm rebuilds
    count_same, svc_same, rebuilds = interrupted({}, "same")
    assert rebuilds == 0, rebuilds
    assert svc_same.n_replicas == 8
    assert count_same == count_a, (len(count_same), len(count_a))

    # resharded restore: 8-replica checkpoint onto a 2-replica mesh
    count_re, svc_re, _ = interrupted({"n_replicas": 2}, "reshard")
    assert svc_re.n_replicas == 2
    assert svc_re.slots_per_replica == 1
    assert count_re == count_a, (len(count_re), len(count_a))
    for qid, q in zip(qids, QUERIES):
        assert svc_re.matches(qid) == svc_a.matches(qid), qid
    # the repack respected the new mesh: every slot index < 2*spr
    assert all(k < 2 * svc_re.slots_per_replica
               for _, k in svc_re._location.values())
    print("crash/restore + reshard ok", sum(count_a.values()))


def check_placement():
    svc = ShardedSearchService(
        n_replicas=8, slots_per_replica=2, tick_cache=SlotTickCache(),
        **CAP)
    for _ in range(8):
        svc.register(chain2(), W)
    assert svc.replica_load() == [1] * 8          # round-robin spread
    svc.register(chain2(), W)
    assert sorted(svc.replica_load()) == [1] * 7 + [2]

    lb = ShardedSearchService(
        n_replicas=4, slots_per_replica=4, tick_cache=SlotTickCache(),
        placement="load_balanced", **CAP)
    for _ in range(6):
        lb.register(chain2(), W)
    # zero pressure everywhere -> pure tenant-count balancing
    assert sorted(lb.replica_load()) == [1, 1, 2, 2]
    try:
        ShardedSearchService(placement="nope", tick_cache=SlotTickCache())
        raise AssertionError("unknown placement accepted")
    except ValueError:
        pass
    print("placement ok")


def _prefix_lift_one(stream, plan, mesh, use_parent):
    """One fresh forest, one depth: unsharded vs capacity-sharded tick
    consuming the SAME replicated prefix view."""
    tc = SlotTickCache()
    forest = SharedPrefixForest(tc, backend=JoinBackend.REF, jit=True,
                                donate=False)
    leaf = forest.acquire(plan, epoch=0)
    node = leaf.parent if use_parent else leaf
    depth = node.depth
    tick1 = jax.jit(build_tick(plan, prefix_depth=depth))
    s1 = init_state(plan, depth)
    tickN, sN = build_sharded_tick(plan, mesh, axes=("data",),
                                   extract_matches=True,
                                   prefix_depth=depth)
    total1 = totalN = 0
    for b in to_batches(stream, 16):
        batch = make_batch(**b)
        views, _ = forest.advance(batch)
        view = views[node.pid]
        s1, r1 = tick1(s1, batch, view)
        sN, rN = tickN(sN, batch, view)
        total1 += int(r1.n_new_matches)
        totalN += int(rN.n_new_matches)
    assert total1 == totalN > 0, (depth, total1, totalN)
    assert int(s1.stats.n_overflow) == int(sN.stats.n_overflow)
    m1 = shared_current_matches(plan, node, forest, jax.device_get(s1))
    mN = shared_current_matches(plan, node, forest, jax.device_get(sN))
    assert m1 == mN, depth


def check_capacity_sharded_prefix():
    """Engine-level lift: capacity-axis shard_map x shared prefix view.

    Full prefix (whole subquery-0 chain shared -> the replicated-table
    ownership path through L0/emission) and partial prefix (suffix
    joins against a replicated parent view) both lift.
    """
    stream = stream160(seed=5)
    plan = compile_plan(chain3(), W, **CAP)
    mesh = jax.make_mesh((4,), ("data",), devices=jax.devices()[:4])
    _prefix_lift_one(stream, plan, mesh, use_parent=False)
    _prefix_lift_one(stream, plan, mesh, use_parent=True)
    print("capacity-sharded prefix ok")


def main():
    import tempfile

    assert len(jax.devices()) == 8, jax.devices()
    check_mesh_differential()
    with tempfile.TemporaryDirectory() as tmpdir:
        check_crash_restore_and_reshard(tmpdir)
    check_placement()
    check_capacity_sharded_prefix()
    print("MESH-OK")


if __name__ == "__main__":
    main()
