"""Subprocess helper: multi-device engine parity check.

Run with XLA_FLAGS=--xla_force_host_platform_device_count=4 (the parent
test sets this). Exits 0 on success.
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")

import jax  # noqa: E402
import numpy as np  # noqa: E402

from repro.core import compile_plan  # noqa: E402
from repro.core.distributed import build_sharded_tick  # noqa: E402
from repro.core.engine import build_tick, current_matches  # noqa: E402
from repro.core.query import QueryGraph  # noqa: E402
from repro.core.state import init_state, make_batch  # noqa: E402
from repro.stream.generator import StreamConfig, synth_traffic_stream, to_batches  # noqa: E402


def main():
    assert len(jax.devices()) == 4, jax.devices()
    q = QueryGraph(3, (0, 1, 0), ((0, 1), (1, 2)), prec=frozenset({(0, 1)}))
    q2 = QueryGraph(3, (0, 0, 1), ((0, 1), (1, 2), (2, 0)),
                    prec=frozenset({(0, 2)}))
    stream = synth_traffic_stream(StreamConfig(
        n_edges=200, n_vertices=10, n_vertex_labels=2, n_edge_labels=2,
        seed=11, ts_step_max=2))

    for query in (q, q2):
        window = 20
        plan = compile_plan(query, window, level_capacity=2048,
                            l0_capacity=2048, max_new=512)

        # single device reference
        tick1 = jax.jit(build_tick(plan))
        s1 = init_state(plan)
        total1 = 0
        for b in to_batches(stream, 16):
            s1, r = tick1(s1, make_batch(**b))
            total1 += int(r.n_new_matches)
        assert int(s1.stats.n_overflow) == 0

        # 4-way sharded
        mesh = jax.make_mesh((4,), ("data",))
        tickN, sN = build_sharded_tick(plan, mesh, axes=("data",))
        totalN = 0
        for b in to_batches(stream, 16):
            sN, r = tickN(sN, make_batch(**b))
            totalN += int(r.n_new_matches)
        assert int(sN.stats.n_overflow) == 0, "sharded overflow"

        m1 = current_matches(plan, jax.device_get(s1))
        mN = current_matches(plan, jax.device_get(sN))
        assert total1 == totalN, (total1, totalN)
        assert m1 == mN, (len(m1), len(mN))

    print("DIST-OK")


if __name__ == "__main__":
    main()
