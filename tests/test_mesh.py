"""Replica-sharded serving (repro.runtime.mesh) + incremental/sharded
checkpoints (repro.checkpoint).

The multi-device half runs in a subprocess (device count must be set
before jax initializes; the main process keeps seeing 1 device):
tests/_mesh_check.py proves sharded == single-device == oracle on 1-,
2- and 8-replica meshes with prefix sharing and churn, crash/restore
through sharded checkpoints (same mesh and 8 -> 2 reshard, zero warm
rebuilds on same-mesh restore), placement policies, and the engine-level
capacity-sharding x prefix-sharing lift.

The in-process half covers the mesh-independent substrate on one
device: manifest patch algebra, O(churn) incremental manifests, the
torn-delta-chain fallback (loud, counted), per-replica npz shard
write/validate/reassembly, delta-chain-aware pruning, and single-replica
service parity.
"""

import json
import os
import pathlib
import subprocess
import sys

import numpy as np
import pytest

from repro.checkpoint import (
    CheckpointError,
    apply_patch,
    checkpoint_steps,
    dict_diff,
    load_resolved_manifest,
    prune_checkpoints,
    restore_checkpoint,
    save_checkpoint,
    validate_checkpoint,
)
from repro.checkpoint import ckpt as ckpt_mod
from repro.core import compile_plan
from repro.core.multi import SlotTickCache
from repro.core.share import SharedPrefixForest
from repro.runtime import ContinuousSearchService, ShardedSearchService
from repro.stream.generator import to_batches

from test_engine_oracle import small_stream
from test_share import CAP, W, chain2, chain3


# --------------------------------------------------------------------- #
# multi-device differential (subprocess: 8 virtual CPU devices)
# --------------------------------------------------------------------- #
def test_mesh_parity_multi_device():
    root = pathlib.Path(__file__).resolve().parents[1]
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.pathsep.join(
        [str(root / "src"), str(root / "tests")])
    proc = subprocess.run(
        [sys.executable, str(root / "tests" / "_mesh_check.py")],
        env=env, capture_output=True, text=True, timeout=900,
    )
    assert proc.returncode == 0, proc.stdout + "\n" + proc.stderr
    assert "MESH-OK" in proc.stdout


# --------------------------------------------------------------------- #
# manifest patch algebra
# --------------------------------------------------------------------- #
def test_dict_diff_apply_patch_roundtrip():
    cases = [
        ({}, {}),
        ({"a": 1}, {"a": 1}),
        ({"a": 1}, {"a": 2}),
        ({"a": 1}, {}),                           # delete
        ({}, {"a": 1}),                           # insert
        ({"a": {"b": 1, "c": 2}}, {"a": {"b": 1, "c": 3}}),   # nested
        ({"a": {"b": 1}}, {"a": 5}),              # dict -> scalar
        ({"a": 5}, {"a": {"b": 1}}),              # scalar -> dict
        ({"a": {"b": 1}}, {"a": {"c": 2}}),       # key swap inside
        ({"q": {"1": {"w": 5}, "2": {"w": 6}}},
         {"q": {"1": {"w": 5}, "3": {"w": 7}}}),  # churn shape
        ({"x": [1, 2]}, {"x": [1, 2, 3]}),        # lists are atomic
        ({"x": None}, {"x": {"y": False}}),
    ]
    for old, new in cases:
        patch = dict_diff(old, new)
        assert apply_patch(old, patch) == new, (old, new, patch)
        # JSON round-trip safety: the patch format must survive the
        # manifest serialization it rides in
        assert apply_patch(old, json.loads(json.dumps(patch))) == new
    assert dict_diff({"a": 1, "b": {"c": 2}}, {"a": 1, "b": {"c": 2}}) == {}


# --------------------------------------------------------------------- #
# incremental manifests: O(churn) bytes, resolved == full
# --------------------------------------------------------------------- #
def _tenant_service(tmp_path, n_tenants, compact_every, tc=None):
    svc = ContinuousSearchService(
        slots_per_group=8, tick_cache=tc or SlotTickCache(),
        ckpt_dir=str(tmp_path), compact_every=compact_every,
        level_capacity=64, l0_capacity=64, max_new=32)
    qids = [svc.register(chain2(), W) for _ in range(n_tenants)]
    return svc, qids


def test_incremental_manifest_is_o_churn(tmp_path):
    svc, qids = _tenant_service(tmp_path, 40, compact_every=16)
    svc.checkpoint()                       # step 1: compacted base
    svc.ckpt.wait()
    base_size = os.path.getsize(tmp_path / "step_1.json")
    man1 = json.load(open(tmp_path / "step_1.json"))
    assert "service" in man1 and "service_delta" not in man1

    # one churn event per step: delta bytes track the CHURN, not the
    # 40-tenant registry
    live = list(qids)
    delta_sizes = []
    for step in (2, 3, 4):
        svc.unregister(live.pop(step))
        live.append(svc.register(chain2(), W))
        svc.checkpoint()
        svc.ckpt.wait()
        man = json.load(open(tmp_path / f"step_{step}.json"))
        assert "service" not in man
        assert man["service_delta"]["prev"] == step - 1
        delta_sizes.append(os.path.getsize(tmp_path / f"step_{step}.json"))
    assert max(delta_sizes) * 5 < base_size, (delta_sizes, base_size)

    # the replayed chain resolves to exactly the live manifest
    assert load_resolved_manifest(str(tmp_path), 4, "service") == \
        svc._manifest()

    # restore from the delta head round-trips the registry
    svc2 = ContinuousSearchService.restore(
        str(tmp_path), tick_cache=svc.tick_cache)
    assert sorted(svc2.registry.qids()) == sorted(live)
    assert svc2.compact_every == 16
    assert {q: svc2._location[q][1] for q in live} == \
        {q: svc._location[q][1] for q in live}


def test_compaction_restarts_the_chain(tmp_path):
    svc, qids = _tenant_service(tmp_path, 4, compact_every=3)
    for _ in range(7):
        svc.checkpoint()
    svc.ckpt.wait()
    kinds = ["service" if "service" in json.load(
        open(tmp_path / f"step_{s}.json")) else "delta"
        for s in checkpoint_steps(str(tmp_path))]
    # K=3: base, 2 deltas, base, 2 deltas, base
    assert kinds == ["service", "delta", "delta"] * 2 + ["service"]


def test_torn_delta_chain_falls_back_loudly(tmp_path):
    svc, qids = _tenant_service(tmp_path, 6, compact_every=3)
    svc.checkpoint()                        # 1: base
    for step in (2, 3):                     # 2,3: deltas on 1
        svc.unregister(qids[step])
        svc.checkpoint()
    svc.checkpoint()                        # 4: base (chain restarts)
    svc.unregister(qids[4])
    svc.checkpoint()                        # 5: delta on 4
    svc.ckpt.wait()

    os.remove(tmp_path / "step_4.json")     # tear the newest chain's base
    before = ckpt_mod.N_DELTA_FALLBACKS
    with pytest.warns(UserWarning, match="delta chain torn"):
        svc2 = ContinuousSearchService.restore(
            str(tmp_path), tick_cache=svc.tick_cache)
    assert ckpt_mod.N_DELTA_FALLBACKS == before + 1
    # steps 5 and 4 are unusable; 3 resolves through its intact chain
    assert svc2._ckpt_step == 3
    assert sorted(svc2.registry.qids()) == sorted(
        q for q in qids if q not in (qids[2], qids[3]))


# --------------------------------------------------------------------- #
# sharded npz substrate
# --------------------------------------------------------------------- #
def _toy_tree():
    return {
        "0": {"table": np.arange(24, dtype=np.int32).reshape(8, 3),
              "clock": np.int32(7)},
        "prefix0": {"bind": np.full((5, 2), 3, np.int32)},
    }


def test_sharded_checkpoint_roundtrip(tmp_path):
    tree = _toy_tree()
    save_checkpoint(str(tmp_path), 3, tree, extra={"tag": "mesh"},
                    n_shards=4, replicated=("prefix0",))
    assert not (tmp_path / "step_3.npz").exists()
    for r in range(4):
        assert (tmp_path / f"step_3.shard{r}of4.npz").exists()
    assert checkpoint_steps(str(tmp_path)) == [3]
    validate_checkpoint(str(tmp_path), 3)

    # sharded keys split along axis 0; replicated + scalars sit in shard 0
    shard0 = np.load(tmp_path / "step_3.shard0of4.npz")
    shard1 = np.load(tmp_path / "step_3.shard1of4.npz")
    assert shard0["0::table"].shape == (2, 3)
    assert "prefix0::bind" in shard0.files
    assert "prefix0::bind" not in shard1.files
    assert "0::clock" in shard0.files and "0::clock" not in shard1.files

    like = jax_zeros_like(tree)
    restored = restore_checkpoint(str(tmp_path), 3, like)
    np.testing.assert_array_equal(restored["0"]["table"], tree["0"]["table"])
    np.testing.assert_array_equal(restored["prefix0"]["bind"],
                                  tree["prefix0"]["bind"])
    assert int(restored["0"]["clock"]) == 7


def jax_zeros_like(tree):
    import jax

    return jax.tree.map(np.zeros_like, tree)


def test_sharded_checkpoint_detects_torn_shard(tmp_path):
    save_checkpoint(str(tmp_path), 1, _toy_tree(), n_shards=2,
                    replicated=("prefix0",))
    validate_checkpoint(str(tmp_path), 1)
    path = tmp_path / "step_1.shard1of2.npz"
    path.write_bytes(path.read_bytes()[:-7])        # torn tail
    with pytest.raises(CheckpointError, match="shard"):
        validate_checkpoint(str(tmp_path), 1)
    os.remove(path)
    with pytest.raises(CheckpointError, match="missing shard"):
        validate_checkpoint(str(tmp_path), 1)


def test_sharded_checkpoint_rejects_indivisible_axis(tmp_path):
    with pytest.raises(ValueError, match="not divisible"):
        save_checkpoint(str(tmp_path), 1,
                        {"a": np.zeros((5, 2), np.int32)}, n_shards=2)


def test_prune_keeps_referenced_delta_manifests(tmp_path):
    arrs = {"a": np.zeros((4,), np.int32)}
    save_checkpoint(str(tmp_path), 1, arrs, extra={"svc": {"x": 1}})
    for s in (2, 3, 4):
        save_checkpoint(
            str(tmp_path), s, arrs,
            extra={"svc_delta": {"prev": s - 1, "patch": {"x": s}}})
    pruned = prune_checkpoints(str(tmp_path), keep_last=1)
    assert pruned == [1, 2, 3]
    # arrays of pruned steps are gone, but the kept step's delta chain
    # still resolves through the surviving manifests
    for s in (1, 2, 3):
        assert not (tmp_path / f"step_{s}.npz").exists()
        assert (tmp_path / f"step_{s}.json").exists()
    assert load_resolved_manifest(str(tmp_path), 4, "svc") == {"x": 4}
    # once nothing references them, a later prune drops the manifests
    save_checkpoint(str(tmp_path), 5, arrs, extra={"svc": {"x": 5}})
    prune_checkpoints(str(tmp_path), keep_last=1)
    for s in (1, 2, 3, 4):
        assert not (tmp_path / f"step_{s}.json").exists()


# --------------------------------------------------------------------- #
# single-replica mesh service (in-process: 1 CPU device)
# --------------------------------------------------------------------- #
def test_single_replica_service_matches_base():
    stream = small_stream(120, n_vertices=8, n_vertex_labels=3, seed=5)
    base = ContinuousSearchService(
        slots_per_group=2, tick_cache=SlotTickCache(), **CAP)
    mesh = ShardedSearchService(
        n_replicas=1, slots_per_replica=2, tick_cache=SlotTickCache(),
        **CAP)
    queries = [chain3(), chain2(), chain2()]
    qb = [base.register(q, W) for q in queries]
    qm = [mesh.register(q, W) for q in queries]
    assert qb == qm
    totals_b = {q: 0 for q in qb}
    totals_m = dict(totals_b)
    for b in to_batches(stream, 16):
        out_b, out_m = base.ingest(b), mesh.ingest(b)
        for q in qb:
            totals_b[q] += int(out_b[q].n_new_matches)
            totals_m[q] += int(out_m[q].n_new_matches)
    assert totals_b == totals_m
    for q in qb:
        assert base.matches(q) == mesh.matches(q)
    assert mesh.replica_load() == [3]
    assert mesh.replica_pressure() == [0]
    stats = mesh.last_mesh_stats()
    assert set(stats) == {g.gid for g in mesh._iter_groups()}
    assert all(s["t_clock"] > 0 for s in stats.values())
    # mesh config replaces slots_per_group in the manifest
    cfg = mesh._manifest()["config"]
    assert "slots_per_group" not in cfg
    assert cfg["mesh"] == {"n_replicas": 1, "slots_per_replica": 2,
                           "placement": "round_robin"}


def test_mesh_service_rejects_bad_config():
    with pytest.raises(ValueError, match="n_replicas"):
        ShardedSearchService(n_replicas=99, tick_cache=SlotTickCache())
    with pytest.raises(ValueError, match="placement"):
        ShardedSearchService(n_replicas=1, placement="nope",
                             tick_cache=SlotTickCache())


# --------------------------------------------------------------------- #
# replica-aware forest refcount partition
# --------------------------------------------------------------------- #
def test_replica_refcounts_partition():
    tc = SlotTickCache()
    forest = SharedPrefixForest(tc, jit=False, donate=False)
    p3 = compile_plan(chain3(), W, **CAP)
    p2 = compile_plan(chain2(), W, **CAP)
    a = forest.acquire(p3, epoch=0)     # depth-3 leaf
    b = forest.acquire(p2, epoch=0)     # depth-2 leaf, shares a's chain
    c = forest.acquire(p2, epoch=0)     # second tenant on b's leaf
    assert b is c
    parts = forest.replica_refcounts([(a, 0), (b, 1), (c, 1)], 2)
    for node in forest.nodes():
        assert sum(parts[node.pid]) == node.refcount, node.pid
    assert parts[a.pid] == [1, 0]                  # depth-3: only a
    assert parts[b.pid] == [1, 2]                  # depth<=2: a + b + c
