"""Device engine vs exact oracle: after every tick the engine's window
matches must equal the brute-force enumeration (streaming consistency +
correctness of expansion lists, MS-tree reconstruction, and L0 joins)."""

import numpy as np
import pytest

import jax

from repro.core import compile_plan
from repro.core.engine import build_tick, current_matches
from repro.core.oracle import DataEdge, OracleEngine
from repro.core.query import QueryGraph, example_paper_query
from repro.core.state import init_state, make_batch
from repro.stream.generator import StreamConfig, synth_traffic_stream, to_batches


def run_engine_vs_oracle(q, stream, window, batch_size, level_capacity=512,
                         max_new=256, check_every=1):
    plan = compile_plan(q, window, level_capacity=level_capacity,
                        l0_capacity=level_capacity, max_new=max_new)
    tick = jax.jit(build_tick(plan))
    state = init_state(plan)
    oracle = OracleEngine(q, window)
    total_new = 0
    prev = set()
    for bi, b in enumerate(to_batches(stream, batch_size)):
        state, res = tick(state, make_batch(**b))
        for e in [e for e in stream[bi * batch_size:(bi + 1) * batch_size]]:
            oracle.insert(e)
        assert int(state.stats.n_overflow) == 0, "test capacity too small"
        total_new += int(res.n_new_matches)
        if bi % check_every == 0:
            got = current_matches(plan, state)
            want = oracle.matches()
            assert got == want, (
                f"tick {bi}: engine {len(got)} vs oracle {len(want)} matches"
            )
            # every new match reported exactly once
            assert total_new >= len(want - prev)
            prev = want
    return total_new


def tri_query():
    """Triangle a->b->c->a with timing chain — a TC-query."""
    return QueryGraph(
        3, (0, 1, 2), ((0, 1), (1, 2), (2, 0)),
        prec=frozenset({(0, 1), (1, 2)}),
    )


def star_query():
    """Out-star with no timing order: decomposes into singleton subqueries."""
    return QueryGraph(4, (0, 1, 1, 1), ((0, 1), (0, 2), (0, 3)))


def two_chain_query():
    """Two 2-chains joined at a vertex, chains internally ≺-ordered."""
    return QueryGraph(
        5, (0, 1, 2, 1, 2),
        ((0, 1), (1, 2), (0, 3), (3, 4)),
        prec=frozenset({(0, 1), (2, 3)}),
    )


def small_stream(n_edges, n_vertices=12, n_vertex_labels=3, n_edge_labels=2,
                 seed=0):
    return synth_traffic_stream(StreamConfig(
        n_edges=n_edges, n_vertices=n_vertices,
        n_vertex_labels=n_vertex_labels, n_edge_labels=n_edge_labels,
        seed=seed, ts_step_max=2))


@pytest.mark.parametrize("batch_size", [1, 4, 16])
def test_tc_chain_query_vs_oracle(batch_size):
    q = QueryGraph(3, (0, 1, 2), ((0, 1), (1, 2)), prec=frozenset({(0, 1)}))
    stream = small_stream(120, seed=1)
    run_engine_vs_oracle(q, stream, window=20, batch_size=batch_size)


@pytest.mark.parametrize("batch_size", [1, 8])
def test_triangle_vs_oracle(batch_size):
    stream = small_stream(150, n_vertices=8, seed=2)
    run_engine_vs_oracle(tri_query(), stream, window=25, batch_size=batch_size)


@pytest.mark.parametrize("batch_size", [1, 8])
def test_star_no_timing_vs_oracle(batch_size):
    stream = small_stream(100, n_vertices=10, n_vertex_labels=2, seed=3)
    run_engine_vs_oracle(star_query(), stream, window=15,
                         batch_size=batch_size, level_capacity=1024)


@pytest.mark.parametrize("batch_size", [1, 8])
def test_two_chains_vs_oracle(batch_size):
    stream = small_stream(150, n_vertices=10, seed=4)
    run_engine_vs_oracle(two_chain_query(), stream, window=20,
                         batch_size=batch_size)


def test_example_paper_query_vs_oracle():
    stream = small_stream(150, n_vertices=10, n_vertex_labels=5, seed=5)
    run_engine_vsoracle = run_engine_vs_oracle(
        example_paper_query(), stream, window=30, batch_size=8,
        level_capacity=1024)


def test_batched_equals_sequential():
    """Streaming consistency: batch sizes must not change results."""
    q = tri_query()
    stream = small_stream(200, n_vertices=8, seed=6)
    window = 30
    finals = []
    for bs in (1, 5, 16):
        plan = compile_plan(q, window, level_capacity=1024, max_new=512)
        tick = jax.jit(build_tick(plan))
        state = init_state(plan)
        for b in to_batches(stream, bs):
            state, _ = tick(state, make_batch(**b))
        finals.append((current_matches(plan, state),
                       int(state.stats.n_matches_total)))
    assert finals[0] == finals[1] == finals[2]


def test_expiry_removes_matches():
    q = QueryGraph(2, (0, 1), ((0, 1),))
    plan = compile_plan(q, window=5)
    tick = jax.jit(build_tick(plan))
    state = init_state(plan)
    state, res = tick(state, make_batch([0], [1], [10], [0], [1], [0]))
    assert int(res.n_new_matches) == 1
    assert len(current_matches(plan, state)) == 1
    # an edge far in the future expires the old one
    state, res = tick(state, make_batch([5], [6], [100], [0], [1], [0]))
    assert len(current_matches(plan, state)) == 1  # only the new edge's match


def test_discardable_edge_pruned():
    """Lemma 1: an edge matching ε2 with no ε1-match in window joins nothing
    and occupies no space beyond its own level."""
    q = QueryGraph(3, (0, 1, 2), ((0, 1), (1, 2)), prec=frozenset({(0, 1)}))
    plan = compile_plan(q, window=50)
    tick = jax.jit(build_tick(plan))
    state = init_state(plan)
    # edge matching ε2 (labels 1->2) arrives first: discardable for level 2
    state, res = tick(state, make_batch([7], [8], [1], [1], [2], [0]))
    assert int(res.n_new_matches) == 0
    assert not bool(state.levels[0][1].valid.any())
