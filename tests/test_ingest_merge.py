"""Deterministic k-way merge + ingest-adapter unit/property tests.

The merge is the part of the ingestion frontier a fault can never be
allowed to perturb: whatever order deliveries arrive in, the sequence
handed to the engine must be a pure function of the events themselves.
Deterministic unit coverage here of the pieces the chaos differential
(tests/test_ingest_chaos.py) composes: the merge ladder, ``SeqTracker``
cursors, ``RetryPolicy`` backoff, ``SourceAdapter`` reconnect/dedup
accounting, ``ScriptedSource`` resume, the watermark/late-drop/forced-
eviction paths, and the generator's seeded disorder model.  The
randomized-properties companion (permutation invariance, tie-break
determinism, strict-monotonic fail-fast) is tests/test_ingest_props.py.
"""

from collections import Counter

import numpy as np
import pytest

from repro.core.oracle import DataEdge
from repro.runtime.fault import FaultTolerantLoop, RetryPolicy
from repro.stream.generator import (
    DisorderConfig, disordered_sources, split_stream)
from repro.stream.ingest import (
    IngestError, IngestFrontier, ListSource, MonotonicityError,
    ScriptedSource, SeqTracker, Source, SourceAdapter, SourceDisconnected,
    SourceEvent, merge_event_streams)

from test_engine_oracle import small_stream


def edge(ts, src=0, dst=1, lab=0):
    return DataEdge(src=src, dst=dst, ts=ts, src_label=0, dst_label=0,
                    edge_label=lab)


NO_SLEEP = dict(sleep=lambda d: None)


# --------------------------------------------------------------------- #
# merge: deterministic unit coverage
# --------------------------------------------------------------------- #
def test_merge_orders_by_event_time_across_sources():
    a = [edge(1), edge(4), edge(9)]
    b = [edge(2), edge(3), edge(8)]
    merged = merge_event_streams([a, b])
    assert [e.ts for e in merged] == [1, 2, 3, 4, 8, 9]
    assert Counter(merged) == Counter(a) + Counter(b)


def test_merge_equal_ts_breaks_by_payload_then_stable():
    # same ts, distinct payloads: the ladder's metadata level orders them
    lo, hi = edge(5, src=1, dst=2), edge(5, src=3, dst=4)
    assert merge_event_streams([[hi], [lo]]) == [lo, hi]
    assert merge_event_streams([[lo], [hi]]) == [lo, hi]
    # payload-identical ties are interchangeable: both orders are the
    # same value sequence
    assert merge_event_streams([[lo], [lo]]) == [lo, lo]


def test_merge_strict_raises_on_regression():
    bad = [edge(5), edge(3)]
    with pytest.raises(MonotonicityError, match="regressed"):
        merge_event_streams([[edge(1)], bad],
                            strict_event_time_monotonic=True)
    # non-strict tolerates it (heap semantics), and plateaus never raise
    merge_event_streams([[edge(1)], bad])
    merge_event_streams([[edge(2), edge(2)]],
                        strict_event_time_monotonic=True)


# --------------------------------------------------------------------- #
# SeqTracker / RetryPolicy
# --------------------------------------------------------------------- #
def test_seq_tracker_floor_extras_and_duplicates():
    t = SeqTracker()
    assert t.add(0) and t.add(1)
    assert t.floor == 2 and not t.extras
    assert t.add(5) and t.add(3)
    assert t.floor == 2 and t.extras == {3, 5}
    assert not t.add(1) and not t.add(5)          # duplicates
    assert t.add(2)                               # compacts through 3
    assert t.floor == 4 and t.extras == {5}
    assert 5 in t and 0 in t and 4 not in t
    rt = SeqTracker.from_manifest(t.to_manifest())
    assert (rt.floor, rt.extras) == (t.floor, t.extras)


def test_retry_policy_backoff_cap_and_exhaustion():
    p = RetryPolicy(max_attempts=3, base_delay_s=0.1, max_delay_s=0.35,
                    multiplier=2.0, jitter_frac=0.0)
    assert [p.delay(a) for a in (1, 2, 3, 4)] == [0.1, 0.2, 0.35, 0.35]
    assert not p.exhausted(3) and p.exhausted(4)
    # jitter is bounded and seeded-deterministic
    j = RetryPolicy(base_delay_s=1.0, max_delay_s=1.0, jitter_frac=0.5)
    d = j.delay(1, np.random.default_rng(7))
    assert 1.0 <= d <= 1.5
    assert d == j.delay(1, np.random.default_rng(7))
    with pytest.raises(ValueError, match="multiplier"):
        RetryPolicy(multiplier=0.5)


def test_fault_tolerant_loop_shares_retry_policy(tmp_path):
    # legacy max_restarts maps onto a zero-delay RetryPolicy; the loop
    # and the ingest adapters consume the SAME policy object type
    step = lambda state, i: state
    init = lambda: 0
    loop = FaultTolerantLoop(str(tmp_path), step, init, max_restarts=7)
    assert loop.retry.max_attempts == 7
    assert loop.retry.base_delay_s == 0.0
    assert loop.max_restarts == 7
    pol = RetryPolicy(max_attempts=2, base_delay_s=0.25, jitter_frac=0.0)
    loop2 = FaultTolerantLoop(str(tmp_path), step, init, retry=pol,
                              sleep=lambda d: None)
    assert loop2.retry is pol


# --------------------------------------------------------------------- #
# sources / adapter
# --------------------------------------------------------------------- #
class FlakySource(Source):
    """Dies every ``fail_every``-th poll; resumable via seq cursor."""

    def __init__(self, edges, fail_every=3):
        self.name = "flaky"
        self._inner = ListSource("flaky", edges)
        self.fail_every = fail_every
        self.polls = 0

    def connect(self, resume_from=0):
        self._inner.connect(resume_from)

    def poll(self, max_events=64):
        self.polls += 1
        if self.polls % self.fail_every == 0:
            raise SourceDisconnected("flaky: scripted failure")
        return self._inner.poll(max_events)

    @property
    def exhausted(self):
        return self._inner.exhausted


def test_scripted_source_resume_and_duplicate_scripts():
    s = ScriptedSource("s", [(0, edge(1)), (2, edge(3)), (1, edge(2)),
                             (1, edge(2)), (3, edge(4))])
    s.connect(resume_from=0)
    assert [e.seq for e in s.poll(10)] == [0, 2, 1, 1, 3]
    # resume lands on the earliest position holding seq >= cursor; the
    # out-of-order seq-1 redeliveries after it are at-least-once noise
    s.connect(resume_from=2)
    assert [e.seq for e in s.poll(10)] == [2, 1, 1, 3]


def test_adapter_dedups_counts_and_reconnects():
    stream = [edge(t) for t in range(10)]
    a = SourceAdapter(FlakySource(stream, fail_every=3),
                      retry=RetryPolicy(max_attempts=5, base_delay_s=0.0),
                      **NO_SLEEP)
    got = []
    while not a.exhausted:
        got.extend(ev.edge for ev in a.pull(4))
    # reconnect resumes from the seen-floor: despite redelivery, every
    # event arrives exactly once downstream and dups are counted
    assert got == stream
    assert a.n_reconnects >= 1
    assert a.n_duplicates == 0 or a.n_duplicates > 0  # counted, maybe 0
    assert a.n_events == len(stream)


def test_adapter_raises_when_retry_budget_exhausted():
    class DeadSource(Source):
        name = "dead"

        def connect(self, resume_from=0):
            pass

        def poll(self, max_events=64):
            raise SourceDisconnected("dead")

    a = SourceAdapter(DeadSource(),
                      retry=RetryPolicy(max_attempts=2, base_delay_s=0.0),
                      **NO_SLEEP)
    with pytest.raises(IngestError, match="retry budget exhausted"):
        a.pull()
    with pytest.raises(IngestError, match="failed"):
        a.pull()                        # a dead source stays loudly dead


# --------------------------------------------------------------------- #
# frontier: watermark, late drops, forced eviction, callbacks
# --------------------------------------------------------------------- #
def test_frontier_watermark_holds_until_every_source_produces():
    class SlowSource(Source):
        """Silent for the first two polls, then one event; live (not
        exhausted) throughout the silence."""

        name = "slow"

        def __init__(self):
            self.polls = 0

        def connect(self, resume_from=0):
            pass

        def poll(self, max_events=64):
            self.polls += 1
            return [] if self.polls <= 2 else [SourceEvent(edge(100), 0)]

        @property
        def exhausted(self):
            return self.polls > 3

    fast = ListSource("fast", [edge(t) for t in (1, 2, 3)])
    fr = IngestFrontier([fast, SlowSource()], **NO_SLEEP)
    fr.pump()
    assert fr.watermark() is None       # slow has produced nothing: hold
    assert fr.take_ready() == []
    out = []
    while not fr.exhausted:
        out.extend(fr.drain())
    assert [e.ts for e in out] == [1, 2, 3, 100]


def test_frontier_drops_and_counts_late_events():
    # src "b" delivers ts=1 after the merged floor passed ts=5
    a = ListSource("a", [edge(5), edge(6), edge(7)])
    b = ScriptedSource("b", [(0, edge(5)), (1, edge(1)), (2, edge(8))])
    fr = IngestFrontier([a, b], allowed_lateness=0, **NO_SLEEP)
    dropped = []
    fr.on("drop_late", lambda name, e, seq: dropped.append((name, e.ts)))
    fr.pump(max_per_source=1)
    fr.take_ready()                     # emits ts=5s, floor -> 5
    out = []
    while not fr.exhausted:
        out.extend(fr.drain(max_per_source=1))
    assert dropped == [("b", 1)]
    assert fr.stats().n_late_dropped == 1
    # accounting invariant: emitted + dropped == everything delivered
    assert fr.stats().n_emitted + fr.stats().n_late_dropped == 6


def test_frontier_forced_eviction_bounds_the_buffer():
    # "open" never exhausts and never produces => watermark stays None;
    # capacity forces the oldest buffered events out anyway, counted
    class OpenSource(Source):
        name = "open"

        def connect(self, resume_from=0):
            pass

        def poll(self, max_events=64):
            return []

    full = ListSource("full", [edge(t) for t in range(12)])
    fr = IngestFrontier([full, OpenSource()], reorder_capacity=4,
                        stall_patience=10 ** 9, **NO_SLEEP)
    for _ in range(4):
        fr.pump(max_per_source=4)
    assert fr.watermark() is None
    out = fr.take_ready()
    assert len(out) == 12 - 4           # evicted down to capacity
    assert fr.stats().n_forced == len(out)
    assert [e.ts for e in out] == sorted(e.ts for e in out)


def test_frontier_stalled_source_stops_holding_watermark():
    class StallingSource(Source):
        """One event, then silence — but never 'exhausted'."""

        name = "stall"

        def __init__(self):
            self._sent = False

        def connect(self, resume_from=0):
            pass

        def poll(self, max_events=64):
            if self._sent:
                return []
            self._sent = True
            return [SourceEvent(edge(0), 0)]

    live = ListSource("live", [edge(t) for t in (1, 5, 9)])
    fr = IngestFrontier([live, StallingSource()], stall_patience=2,
                        **NO_SLEEP)
    stalls = []
    fr.on("stall", lambda name, rounds: stalls.append(name))
    out = []
    for _ in range(10):
        out.extend(fr.drain(max_per_source=2))
    assert [e.ts for e in out] == [0, 1, 5, 9]   # stall-out released them
    assert stalls == ["stall"]
    assert fr.stats().n_stalled_rounds > 0


def test_frontier_unknown_callback_and_duplicate_names_rejected():
    fr = IngestFrontier([ListSource("a", [edge(1)])], **NO_SLEEP)
    with pytest.raises(ValueError, match="unknown callback kind"):
        fr.on("typo", lambda *a: None)
    with pytest.raises(IngestError, match="unique"):
        IngestFrontier([ListSource("x", []), ListSource("x", [])],
                       **NO_SLEEP)


def test_frontier_strict_mode_raises_on_regression():
    src = ScriptedSource("s", [(0, edge(5)), (1, edge(2))])
    fr = IngestFrontier([src], strict_event_time_monotonic=True,
                        **NO_SLEEP)
    with pytest.raises(MonotonicityError, match="regressed"):
        while not fr.exhausted:
            fr.drain()


# --------------------------------------------------------------------- #
# generator disorder model
# --------------------------------------------------------------------- #
def test_disordered_sources_default_is_identity():
    stream = small_stream(40, seed=3)
    (script,) = disordered_sources(stream)
    assert script == list(enumerate(stream))


def test_split_stream_partitions_and_preserves_order():
    stream = small_stream(60, seed=5)
    parts = split_stream(stream, 3, seed=9)
    assert sum((Counter(p) for p in parts), Counter()) == Counter(stream)
    pos = {id(e): i for i, e in enumerate(stream)}
    for p in parts:
        idx = [pos[id(e)] for e in p]
        assert idx == sorted(idx)


def test_disordered_sources_reconcile_with_original_stream():
    stream = small_stream(80, seed=6)
    cfg = DisorderConfig(n_sources=3, disorder_frac=0.4, max_delay=5,
                         duplicate_rate=0.2, seed=11)
    scripts = disordered_sources(stream, cfg)
    assert disordered_sources(stream, cfg) == scripts     # seeded
    # per source: unique seqs recover the canonical per-source order,
    # and the union of all unique deliveries is exactly the stream
    recovered = []
    n_dup = 0
    for script in scripts:
        seen = {}
        for seq, e in script:
            if seq in seen:
                n_dup += 1
                assert seen[seq] == e      # dups are redeliveries
            else:
                seen[seq] = e
        assert sorted(seen) == list(range(len(seen)))
        recovered.extend(seen[s] for s in sorted(seen))
        # displacement is bounded: a delivery leaves at most max_delay
        # positions after its canonical slot
        first_pos = {}
        for pos_i, (seq, _) in enumerate(script):
            first_pos.setdefault(seq, pos_i)
    assert Counter(recovered) == Counter(stream)
    assert n_dup > 0


def test_frontier_end_to_end_recovers_canonical_order():
    stream = small_stream(120, seed=7)
    scripts = disordered_sources(stream, DisorderConfig(
        n_sources=3, disorder_frac=0.3, max_delay=6, duplicate_rate=0.1,
        seed=13))
    fr = IngestFrontier(
        [ScriptedSource(f"s{i}", sc) for i, sc in enumerate(scripts)],
        allowed_lateness=30, **NO_SLEEP)
    out = []
    while not fr.exhausted:
        out.extend(fr.drain())
    s = fr.stats()
    assert Counter(out) == Counter(stream)
    assert all(a.ts <= b.ts for a, b in zip(out, out[1:]))
    assert s.n_duplicates > 0 and s.n_late_dropped == 0
    assert s.n_emitted == len(stream)
