"""The observability layer's contract, proved differentially.

Four claims, each load-bearing for the tentpole:

1. ``repro.obs.percentile`` is byte-identical to the nearest-rank
   formulas the benches used inline before the layer existed — the
   dedupe (bench_ingest/bench_mesh now import it) changed no numbers.
2. ``Histogram`` percentiles are EXACT while the ring holds every
   sample, and degrade to one-bucket-bound estimates after a
   manifest-only restore — never silently wrong.
3. Instrumentation is free when off and inert when on: serving the same
   stream with and without obs+tracer yields identical match multisets
   and ZERO additional jit builds or per-tick compile-cache entries —
   metrics never reach traced code (the TRC107 lint proves the static
   side; this proves the dynamic side).
4. The trace JSONL round-trips through the ``python -m repro.obs``
   summarize CLI, and drop-driven DEGRADED session health survives
   checkpoint/restore via the registry's counter history.
"""

from __future__ import annotations

import json
from collections import Counter as MultiSet

import numpy as np
import pytest

from repro.core.join import JoinBackend
from repro.core.multi import SlotTickCache
from repro.core.oracle import DataEdge
from repro.core.query import QueryGraph
from repro.obs import (
    DEFAULT_LATENCY_BUCKETS_MS, Histogram, MetricsRegistry, Tracer,
    memory_tracer, percentile, summarize_trace, to_prometheus)
from repro.obs.summarize import main as summarize_main
from repro.runtime.service import ContinuousSearchService
from repro.stream.generator import StreamConfig, synth_traffic_stream

CAP = dict(level_capacity=256, l0_capacity=256, max_new=64)


def _chain():
    return QueryGraph(3, (0, 1, 2), ((0, 1), (1, 2)),
                      prec=frozenset({(0, 1)}))


def _stream(n=400, seed=11):
    return synth_traffic_stream(StreamConfig(
        n_edges=n, n_vertices=50, n_vertex_labels=3, n_edge_labels=4,
        seed=seed, ts_step_max=2))


# ------------------------------------------------------------------ #
# 1. the shared percentile formula IS the old inline bench math
# ------------------------------------------------------------------ #
def test_percentile_matches_inline_bench_formulas():
    rng = np.random.default_rng(5)
    for n in (1, 2, 3, 10, 101, 256):
        lat = rng.exponential(10.0, n).tolist()
        srt = sorted(lat)
        for q in (0.0, 0.5, 0.9, 0.99, 1.0):
            # bench_ingest's inline pick() before the dedupe
            assert percentile(lat, q) == float(srt[min(n - 1, int(q * n))])
        # bench_mesh's inline median before the dedupe
        assert percentile(lat, 0.5) == float(srt[n // 2])
    assert percentile([], 0.5) == 0.0
    with pytest.raises(ValueError):
        percentile([1.0], 1.5)


# ------------------------------------------------------------------ #
# 2. histogram: exact while ring-complete, bounded after restore
# ------------------------------------------------------------------ #
def test_histogram_exact_then_bucket_fallback_after_restore():
    rng = np.random.default_rng(9)
    lats = rng.exponential(8.0, 500).tolist()
    reg = MetricsRegistry()
    h = reg.histogram("tick.latency_ms")
    for v in lats:
        h.observe(v)
    assert h.exact and h.count == len(lats)
    for q in (0.5, 0.9, 0.99):
        assert h.quantile(q) == percentile(lats, q)
    assert h.mean == pytest.approx(sum(lats) / len(lats))

    # manifest round-trip: counts/buckets survive, raw samples do not —
    # quantiles become bucket UPPER bounds, within one bucket step
    # (10^(1/4) ~ 1.79x) above the exact value
    reg2 = MetricsRegistry()
    reg2.load_manifest(reg.to_manifest())
    h2 = reg2.histogram("tick.latency_ms")
    assert h2.count == len(lats) and not h2.exact
    assert np.array_equal(h2.counts, h.counts)
    step = 10 ** 0.25
    for q in (0.5, 0.9, 0.99):
        exact = percentile(lats, q)
        est = h2.quantile(q)
        assert exact <= est <= exact * step * 1.001

    # counters restore monotonically (set_total never double-counts)
    reg.counter("ingest.n_late_dropped").inc(7)
    reg2.load_manifest(reg.to_manifest())
    reg2.load_manifest(reg.to_manifest())
    assert reg2.counter("ingest.n_late_dropped").value == 7


def test_histogram_ring_eviction_flips_to_estimate():
    h = Histogram("x", ring_size=8)
    for v in range(20):
        h.observe(float(v) + 0.5)
    assert not h.exact and h.count == 20
    # estimate is a valid bucket upper bound for the true p50 (9.5)
    est = h.quantile(0.5)
    assert est in DEFAULT_LATENCY_BUCKETS_MS and est >= 9.5


# ------------------------------------------------------------------ #
# 3. the on/off differential: same matches, zero extra compiles
# ------------------------------------------------------------------ #
def _serve(tc, obs=None, tracer=None):
    svc = ContinuousSearchService(
        slots_per_group=2, backend=JoinBackend.REF, tick_cache=tc,
        obs=obs, tracer=tracer, **CAP)
    svc.register(_chain(), 20)
    svc.register(_chain(), 20)
    matches = MultiSet()

    def on_match(qid, bindings, ets):
        for row, et in zip(np.asarray(bindings), np.asarray(ets)):
            matches[(qid, tuple(int(b) for b in row),
                     tuple(int(t) for t in et))] += 1

    svc.serve_stream(_stream(), on_match=on_match, batch_size=32,
                     min_batch=32, max_batch=32)
    return svc, matches


def test_instrumentation_differential_on_vs_off():
    tc = SlotTickCache()
    _serve(tc)                                   # compile + warm
    builds_warm = tc.n_builds
    cache_sizes_warm = [t._cache_size() for t in tc.ticks()]

    _, matches_off = _serve(tc)                  # bare, fully warm
    obs = MetricsRegistry()
    tracer, sink = memory_tracer()
    svc_on, matches_on = _serve(tc, obs=obs, tracer=tracer)
    tracer.flush()

    # oracle identity: instrumentation changed no match, no multiplicity
    assert matches_on == matches_off and sum(matches_on.values()) > 0
    # zero additional XLA work: no new builds, no new per-tick
    # compile-cache entries anywhere in the shared cache
    assert tc.n_builds == builds_warm
    assert [t._cache_size() for t in tc.ticks()] == cache_sizes_warm

    # the histogram saw exactly the served ticks, and its percentiles
    # are the exact nearest-rank numbers
    h = obs.histogram("tick.latency_ms")
    assert h.count == svc_on.n_ticks > 0 and h.exact
    assert h.quantile(0.5) == percentile(h.samples().tolist(), 0.5)
    snap = obs.snapshot()
    assert snap["tick.n_ticks"] == svc_on.n_ticks
    assert snap["tick.n_edges"] == svc_on.n_edges_ingested
    assert snap["tick.n_matches"] == sum(matches_on.values())

    # every span carries a tick correlation id covering all ticks
    lines = [json.loads(ln) for ln in sink.getvalue().splitlines()]
    assert {ln["span"] for ln in lines} >= {
        "tick.forest", "tick.slot_dispatch", "tick.barrier",
        "tick.deliver", "coalescer.decision"}
    assert max(ln["tick"] for ln in lines) == svc_on.n_ticks


# ------------------------------------------------------------------ #
# 4a. trace JSONL -> summarize CLI round-trip
# ------------------------------------------------------------------ #
def test_trace_summarize_cli_roundtrip(tmp_path, capsys):
    path = tmp_path / "trace.jsonl"
    with Tracer(str(path)) as tr:
        for _ in range(3):
            tr.next_tick()
            tr.record("tick.forest", 1.0)
            tr.record("tick.barrier", 2.0, n_groups=2)
        tr.event("mesh.collectives", gid=0)

    s = summarize_trace(str(path))
    assert s["n_ticks"] == 3 and s["n_bad_lines"] == 0
    assert s["spans"]["tick.barrier"]["count"] == 3
    assert s["spans"]["tick.barrier"]["p50_ms"] == 2.0

    assert summarize_main(["summarize", str(path)]) == 0
    out = capsys.readouterr().out
    assert "tick.barrier" in out
    assert f"{s['n_spans']} spans over 3 ticks" in out
    assert summarize_main([]) == 2          # usage error is loud


def test_tracer_off_costs_nothing_and_memory_sink():
    tr, sink = memory_tracer()
    tr.record("a", 1.5, k=1)
    tr.close()
    (line,) = sink.getvalue().splitlines()
    d = json.loads(line)
    assert d["span"] == "a" and d["ms"] == 1.5 and d["k"] == 1


# ------------------------------------------------------------------ #
# 4b. prometheus exposition smoke
# ------------------------------------------------------------------ #
def test_prometheus_export_shapes():
    reg = MetricsRegistry()
    reg.counter("tick.n_ticks").inc(4)
    reg.gauge("ingest.watermark").set(17)
    reg.register_gauge("share.n_nodes", lambda: 3)
    h = reg.histogram("tick.latency_ms")
    for v in (1.0, 2.0, 3.0):
        h.observe(v)
    text = to_prometheus(reg)
    assert "repro_tick_n_ticks 4" in text
    assert "repro_ingest_watermark 17" in text
    assert "repro_share_n_nodes 3" in text
    assert 'repro_tick_latency_ms_bucket{le="+Inf"} 3' in text
    assert "repro_tick_latency_ms_count 3" in text
    assert 'repro_tick_latency_ms{quantile="0.5"} 2.0' in text


# ------------------------------------------------------------------ #
# 4c. DEGRADED health attribution survives checkpoint/restore
# ------------------------------------------------------------------ #
def test_session_degraded_health_survives_restore(tmp_path):
    from repro.api.session import ACTIVE, DEGRADED, StreamSession

    tc = SlotTickCache()
    sess = StreamSession(backend=JoinBackend.REF, tick_cache=tc,
                         ckpt_dir=str(tmp_path), **CAP)
    sess.register_query(_chain(), window=20)
    assert sess.status().health == ACTIVE

    # a script longer than one 64-event poll whose last event is ancient:
    # it surfaces on the SECOND pump round, after the merged emit floor
    # passed it — a guaranteed late drop under zero allowed lateness
    from repro.stream.ingest import ScriptedSource
    script = [(i, DataEdge(i % 7, i % 7 + 1, 10 + i, 0, 1, 0))
              for i in range(64)] + [(64, DataEdge(0, 1, 1, 0, 1, 0))]
    fr = sess.sources({"s": ScriptedSource("s", script)},
                      allowed_lateness=0, sleep=lambda d: None)
    sess.serve_frontier(fr, batch_size=8)
    st = sess.status()
    assert st.n_late_dropped >= 1 and st.health == DEGRADED

    sess.checkpoint()
    sess.close()

    restored = StreamSession.restore(str(tmp_path), tick_cache=tc)
    st2 = restored.status()
    # no frontier is bound yet the restored registry still attributes
    # the drops — health must NOT reset to ACTIVE
    assert st2.n_late_dropped == st.n_late_dropped
    assert st2.health == DEGRADED
    assert restored.metrics()["ingest.n_late_dropped"] >= 1
    assert "repro_ingest_n_late_dropped" in restored.prometheus()
