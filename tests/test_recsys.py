"""Wide&Deep + retrieval tests."""

import numpy as np

import jax
import jax.numpy as jnp

from repro.models.recsys import wide_deep as WD


def small_cfg():
    return WD.WideDeepConfig(
        n_sparse=6, vocab_per_field=50, embed_dim=8, n_dense=4,
        mlp=(32, 16), wide_vocab=100, n_wide_crosses=5)


def rand_batch(rng, cfg, b=16):
    wide = rng.integers(0, cfg.wide_vocab, (b, cfg.n_wide_crosses))
    wide[rng.random(wide.shape) < 0.3] = -1
    return {
        "sparse_ids": jnp.asarray(
            rng.integers(0, cfg.vocab_per_field, (b, cfg.n_sparse)), jnp.int32),
        "dense": jnp.asarray(rng.standard_normal((b, cfg.n_dense)), jnp.float32),
        "wide_ids": jnp.asarray(wide.astype(np.int32)),
        "labels": jnp.asarray(rng.integers(0, 2, b).astype(np.int32)),
    }


def test_forward_and_loss_finite():
    rng = np.random.default_rng(0)
    cfg = small_cfg()
    params = WD.init(jax.random.PRNGKey(0), cfg)
    batch = rand_batch(rng, cfg)
    logit = WD.forward(params, batch, cfg)
    assert logit.shape == (16,)
    assert np.isfinite(np.asarray(logit)).all()
    loss, _ = WD.bce_loss(params, batch, cfg)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: WD.bce_loss(p, batch, cfg)[0])(params)
    for g in jax.tree.leaves(grads):
        assert np.isfinite(np.asarray(g)).all()


def test_wide_bag_matches_manual():
    rng = np.random.default_rng(1)
    cfg = small_cfg()
    params = WD.init(jax.random.PRNGKey(0), cfg)
    batch = rand_batch(rng, cfg, b=8)
    logit = np.asarray(WD.forward(params, batch, cfg))
    # recompute the wide contribution by hand
    wide = np.asarray(params["wide"])
    wid = np.asarray(batch["wide_ids"])
    manual = np.array([
        sum(wide[i] for i in row if i >= 0) for row in wid
    ])
    # deep part from forward with zeroed wide table
    params2 = dict(params)
    params2["wide"] = jnp.zeros_like(params["wide"])
    deep_only = np.asarray(WD.forward(params2, batch, cfg))
    np.testing.assert_allclose(logit - deep_only, manual, rtol=1e-4, atol=1e-5)


def test_retrieval_topk():
    rng = np.random.default_rng(2)
    cands = jnp.asarray(rng.standard_normal((1000, 16)), jnp.float32)
    u = jnp.asarray(rng.standard_normal(16), jnp.float32)
    vals, idx = WD.retrieval_score(u, cands, top_k=10)
    scores = np.asarray(cands @ u)
    np.testing.assert_array_equal(np.asarray(idx), np.argsort(-scores)[:10])
