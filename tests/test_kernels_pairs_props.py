"""Property-based test (hypothesis): the fused ``compat_join_pairs``
kernel equals ``compat_mask`` + ``extract_pairs`` — same pair set when
nothing overflows, exact ``n_dropped`` always, and a valid keep-subset
of the true pairs under overflow.

Lives in its own module because the module-level importorskip skips the
whole file when the optional dev dep is absent (same pattern as
tests/test_engine_props.py)."""

import numpy as np
import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st  # noqa: E402

from test_kernels_compat_join import _check_pairs_vs_oracle, rand_case  # noqa: E402


@settings(max_examples=25, deadline=None)
@given(
    seed=st.integers(0, 2**31 - 1),
    ca=st.integers(1, 90),
    cb=st.integers(1, 90),
    nva=st.integers(1, 4),
    nvb=st.integers(1, 3),
    nea=st.integers(1, 3),
    neb=st.integers(1, 2),
    window=st.one_of(st.none(), st.integers(1, 40)),
    max_new=st.sampled_from([1, 8, 33, 512]),
)
def test_fused_pairs_property(seed, ca, cb, nva, nvb, nea, neb, window,
                              max_new):
    rng = np.random.default_rng(seed)
    args = rand_case(rng, ca, cb, nva, nvb, nea, neb, window)
    _check_pairs_vs_oracle(args, max_new)
