"""Crash/restore differential test for the multi-tenant service.

The paper's timing-order semantics demand that a restarted server misses
nothing still inside the window.  Proof by differential execution:

* run A: a multi-tenant ``ContinuousSearchService`` serves a synthetic
  stream to completion, checkpointing as it goes, and every reported
  match is logged with the edge offset of the tick that produced it;
* run B: an identical service crashes mid-stream (``SimulatedFailure``
  injected from the ``on_tick`` hook), is restored from the newest
  usable checkpoint, and replays the remaining edges.

A consumer that rolls back reports newer than the last durable
checkpoint (standard at-least-once -> exactly-once downgrade) must see
EXACTLY run A's match multiset: nothing within the window missed,
nothing duplicated.  Run A itself is cross-checked against the
brute-force oracle's incremental match union, and the restore must hit
the process-wide compiled-tick cache: zero recompiles, zero retraces
for previously-seen structures.
"""

from collections import Counter

import numpy as np
import pytest

from repro.checkpoint import checkpoint_steps
from repro.core import compile_plan
from repro.core.join import JoinBackend
from repro.core.multi import SlotTickCache
from repro.core.oracle import OracleEngine
from repro.core.query import QueryGraph
from repro.launch.stream_serve import StreamServer
from repro.runtime.fault import SimulatedFailure
from repro.runtime.service import ContinuousSearchService

from test_engine_oracle import small_stream, star_query, tri_query

CAP = dict(level_capacity=512, l0_capacity=512, max_new=256)
# pinned chunk size: deterministic tick/checkpoint boundaries and a
# single trace shape per compiled tick (the no-retrace assertions)
SERVE = dict(batch_size=16, min_batch=16, max_batch=16)


def chain_query():
    return QueryGraph(3, (0, 1, 2), ((0, 1), (1, 2)), prec=frozenset({(0, 1)}))


def chain_query_relabeled():
    return QueryGraph(3, (1, 2, 0), ((0, 1), (1, 2)), prec=frozenset({(0, 1)}))


def event_key(plan, bindings_row, ets_row):
    """One reported match -> the canonical frozenset of
    ``(query_edge_id, (src, dst, ts))`` used by ``current_matches`` and
    the oracle."""
    q = plan.query
    vslot = {v: s for s, v in enumerate(plan.final_vertex_layout)}
    epos = {e: s for s, e in enumerate(plan.final_edge_layout)}
    return frozenset(
        (eid, (int(bindings_row[vslot[q.edges[eid][0]]]),
               int(bindings_row[vslot[q.edges[eid][1]]]),
               int(ets_row[epos[eid]])))
        for eid in range(q.n_edges))


class EventLog:
    """Log (qid, match) events tagged with the END offset of their tick,
    optionally injecting a crash at a given tick."""

    def __init__(self, svc, crash_at_tick=None):
        self.svc = svc
        self.crash_at_tick = crash_at_tick
        self.events = []      # (qid, match_key, end_of_tick_edge_offset)
        self._pending = []

    def on_match(self, qid, bindings, ets):
        plan = self.svc.registry.get(qid).plan
        for b, t in zip(bindings, ets):
            self._pending.append((qid, event_key(plan, b, t)))

    def on_tick(self, info):
        self.events += [(qid, k, info.n_edges_ingested)
                        for qid, k in self._pending]
        self._pending.clear()
        if self.crash_at_tick is not None and info.tick == self.crash_at_tick:
            raise SimulatedFailure(f"injected at tick {info.tick}")


def oracle_reported(query, window, stream):
    """Every match the engine must report over ``stream``: the union of
    the oracle's window contents after each edge insertion."""
    oracle = OracleEngine(query, window)
    seen = set()
    for e in stream:
        oracle.insert(e)
        seen |= oracle.matches()
    return seen, oracle.matches()


QUERIES = [(chain_query(), 20), (chain_query_relabeled(), 30),
           (tri_query(), 25)]


def _fresh(ckpt_dir, backend, tc):
    svc = ContinuousSearchService(
        slots_per_group=2, backend=backend, tick_cache=tc,
        ckpt_dir=str(ckpt_dir), **CAP)
    qids = [svc.register(q, w) for q, w in QUERIES]
    return svc, qids


@pytest.mark.parametrize(
    "backend", [JoinBackend.REF, JoinBackend.PALLAS_INTERPRET])
def test_crash_restore_differential(tmp_path, backend):
    tc = SlotTickCache()
    stream = small_stream(160, n_vertices=9, seed=41)

    # ---- run A: uninterrupted reference --------------------------------
    svc_a, qids = _fresh(tmp_path / "a", backend, tc)
    log_a = EventLog(svc_a)
    svc_a.serve_stream(stream, on_match=log_a.on_match,
                       on_tick=log_a.on_tick, ckpt_every=3, **SERVE)
    assert svc_a.n_edges_ingested == len(stream)
    builds_a = tc.n_builds
    assert builds_a == 2            # two structural signatures, ever
    trace_sizes_a = [t._cache_size() for t in tc.ticks()]
    assert trace_sizes_a == [1, 1]  # one chunk shape -> one trace each

    # run A is oracle-exact, per qid, and reports each match exactly once
    count_a = Counter((qid, k) for qid, k, _ in log_a.events)
    assert count_a and max(count_a.values()) == 1
    for qid, (q, w) in zip(qids, QUERIES):
        want_reported, want_window = oracle_reported(q, w, stream)
        got = {k for (qq, k, _) in log_a.events if qq == qid}
        assert got == want_reported
        assert svc_a.matches(qid) == want_window

    # ---- run B: crash at tick 5, past the tick-3 checkpoint ------------
    svc_b, qids_b = _fresh(tmp_path / "b", backend, tc)
    assert qids_b == qids
    assert svc_b.n_compiles == 0    # structures already cached by run A
    log_b = EventLog(svc_b, crash_at_tick=5)
    with pytest.raises(SimulatedFailure):
        svc_b.serve_stream(stream, on_match=log_b.on_match,
                           on_tick=log_b.on_tick, ckpt_every=3, **SERVE)
    svc_b.ckpt.wait()               # flush in-flight async writes

    # ---- restore: same tenants, same slots, zero recompiles ------------
    svc_r = ContinuousSearchService.restore(str(tmp_path / "b"),
                                            tick_cache=tc)
    assert svc_r.n_compiles == 0
    assert tc.n_builds == builds_a
    assert svc_r.registry.qids() == qids
    assert svc_r.n_ticks == 3                       # newest durable ckpt
    assert svc_r.n_edges_ingested == 3 * 16
    for qid, (q, w) in zip(qids, QUERIES):
        assert svc_r.registry.get(qid).query == q
        assert svc_r.registry.get(qid).window == w

    # exactly-once consumer: roll back reports newer than the checkpoint
    kept = [(qid, k, off) for qid, k, off in log_b.events
            if off <= svc_r.n_edges_ingested]

    # ---- replay the tail on the restored server ------------------------
    log_r = EventLog(svc_r)
    svc_r.serve_stream(stream[svc_r.n_edges_ingested:],
                       on_match=log_r.on_match, on_tick=log_r.on_tick,
                       ckpt_every=3, **SERVE)
    assert svc_r.n_edges_ingested == len(stream)

    # the shared jitted ticks saw no new shapes: zero retraces end-to-end
    assert tc.n_builds == builds_a
    assert [t._cache_size() for t in tc.ticks()] == trace_sizes_a

    # ---- differential: crash+restore == uninterrupted, exactly once ----
    count_b = Counter((qid, k) for qid, k, _ in kept + log_r.events)
    assert count_b == count_a
    for qid in qids:
        assert svc_r.matches(qid) == svc_a.matches(qid)
        assert int(svc_r.stats(qid).n_matches_total) == \
            int(svc_a.stats(qid).n_matches_total)


def test_restore_with_cold_tick_cache(tmp_path):
    """Correctness does not depend on the warm process cache: a restore
    into a fresh SlotTickCache (≈ a new process) rebuilds each structure
    once and reproduces the same final state."""
    tc = SlotTickCache()
    stream = small_stream(160, n_vertices=9, seed=42)
    svc, qids = _fresh(tmp_path, JoinBackend.REF, tc)
    svc.serve_stream(stream, ckpt_every=4, **SERVE)
    cold = SlotTickCache()
    svc2 = ContinuousSearchService.restore(str(tmp_path), tick_cache=cold)
    assert svc2.n_compiles == cold.n_builds == 2
    for qid in qids:
        assert svc2.matches(qid) == svc.matches(qid)


def test_restore_skips_torn_checkpoint(tmp_path):
    """Truncating the newest checkpoint (a torn write) must roll restore
    back to the previous one, and replaying from there still converges to
    the uninterrupted final state."""
    tc = SlotTickCache()
    stream = small_stream(160, n_vertices=9, seed=43)
    svc, qids = _fresh(tmp_path, JoinBackend.REF, tc)
    svc.serve_stream(stream, ckpt_every=2, **SERVE)   # ckpts at 2,4,6,8,10
    steps = checkpoint_steps(str(tmp_path))
    assert steps[-1] == 10
    npz = tmp_path / f"step_{steps[-1]}.npz"
    npz.write_bytes(npz.read_bytes()[:128])           # tear it

    svc2 = ContinuousSearchService.restore(str(tmp_path), tick_cache=tc)
    assert svc2.n_ticks == 8                          # fell back one step
    assert svc2.n_edges_ingested == 8 * 16
    svc2.serve_stream(stream[svc2.n_edges_ingested:], **SERVE)
    for qid in qids:
        assert svc2.matches(qid) == svc.matches(qid)
        assert int(svc2.stats(qid).n_matches_total) == \
            int(svc.stats(qid).n_matches_total)


def test_stream_server_is_a_service_wrapper(tmp_path):
    """StreamServer owns no tick machinery: it restores and serves purely
    through ContinuousSearchService, and a restarted server resumes from
    the checkpointed offset with the same window state."""
    tc = SlotTickCache()
    stream = small_stream(160, n_vertices=9, seed=44)
    plan = compile_plan(chain_query(), 20, **CAP)

    hits = []
    s1 = StreamServer(plan, ckpt_dir=str(tmp_path), tick_cache=tc)
    assert isinstance(s1.service, ContinuousSearchService)
    for attr in ("tick", "state_"):
        assert not hasattr(s1, attr)   # no tick-building logic of its own
    total = s1.ingest(stream[:80], on_match=lambda b, t: hits.append(len(b)),
                      ckpt_every=2, batch_size=16)
    aimd = s1._coalescer
    assert aimd is not None
    total += s1.ingest(stream[80:], on_match=lambda b, t: hits.append(len(b)),
                       ckpt_every=2, batch_size=16)
    assert s1._coalescer is aimd       # AIMD state persists across ingests
    assert total == sum(hits) > 0
    assert s1.resume_offset == len(stream)

    s2 = StreamServer(plan, ckpt_dir=str(tmp_path), tick_cache=tc)
    assert s2.ticks == s1.ticks
    assert s2.resume_offset == len(stream)            # nothing left to replay
    assert s2.matches() == s1.matches()
    assert s2.service.n_compiles == 0                 # warm cache restore

    # a different query cannot hijack the checkpoint
    other = compile_plan(tri_query(), 25, **CAP)
    with pytest.raises(ValueError, match="different query"):
        StreamServer(other, ckpt_dir=str(tmp_path), tick_cache=tc)


def test_custom_decomposition_plan_round_trips(tmp_path):
    """A caller-supplied plan (custom decomposition) must be served
    exactly as given AND survive checkpoint/restore — not be silently
    replaced by the decomposition heuristics."""
    from repro.core.decompose import TCSubquery
    from repro.core.registry import plan_decomposition

    q = tri_query()
    # the heuristic compiles this ≺-chain triangle to ONE TC-subquery;
    # force the all-singletons decomposition instead
    custom = [TCSubquery(frozenset({e}), (e,)) for e in range(3)]
    plan = compile_plan(q, 25, decomposition=custom, **CAP)
    assert plan_decomposition(plan) == [(0,), (1,), (2,)]
    assert plan_decomposition(compile_plan(q, 25, **CAP)) != \
        plan_decomposition(plan)

    stream = small_stream(160, n_vertices=9, seed=45)
    svc = ContinuousSearchService(slots_per_group=2,
                                  ckpt_dir=str(tmp_path), **CAP)
    qid = svc.register(q, 25, plan=plan)
    assert plan_decomposition(svc.registry.get(qid).plan) == \
        [(0,), (1,), (2,)]
    svc.serve_stream(stream[:96], ckpt_every=2, **SERVE)

    svc2 = ContinuousSearchService.restore(str(tmp_path))
    assert plan_decomposition(svc2.registry.get(qid).plan) == \
        [(0,), (1,), (2,)]
    svc2.serve_stream(stream[96:], **SERVE)
    svc.serve_stream(stream[96:], **SERVE)     # uninterrupted reference
    assert svc2.matches(qid) == svc.matches(qid)


def test_plan_with_divergent_capacities_rejected():
    """A caller plan whose capacities differ from the registry's would
    checkpoint fine but could NEVER restore (restore recompiles with the
    registry's capacities -> shape mismatch), so registration must
    reject it up front — including the case where the plan's l0 joins
    use the level capacity while the registry's l0_capacity differs."""
    q = star_query()                 # 3 singleton subqueries -> l0 joins
    plan = compile_plan(q, 15, level_capacity=512, l0_capacity=512,
                        max_new=256)
    svc = ContinuousSearchService(level_capacity=512, l0_capacity=1024,
                                  max_new=256)
    with pytest.raises(ValueError, match="capacities"):
        svc.register(q, 15, plan=plan)
    # matching capacities are accepted
    ok = ContinuousSearchService(level_capacity=512, l0_capacity=512,
                                 max_new=256)
    ok.register(q, 15, plan=plan)


def test_restore_overrides_serving_knobs(tmp_path):
    """backend / extract_matches are serving-behavior knobs: a restart
    may override the checkpointed values (e.g. re-enable match
    extraction) instead of being silently stuck with them."""
    stream = small_stream(96, n_vertices=9, seed=46)
    svc = ContinuousSearchService(slots_per_group=2, extract_matches=False,
                                  ckpt_dir=str(tmp_path), **CAP)
    qid = svc.register(chain_query(), 20)
    svc.serve_stream(stream, ckpt_every=2, **SERVE)

    svc2 = ContinuousSearchService.restore(str(tmp_path))
    assert svc2.extract_matches is False              # default: keep config
    svc3 = ContinuousSearchService.restore(
        str(tmp_path), extract_matches=True,
        backend=JoinBackend.PALLAS_INTERPRET)
    assert svc3.extract_matches is True
    assert svc3.backend == JoinBackend.PALLAS_INTERPRET
    assert svc3.registry.qids() == [qid]


def test_serve_stream_honors_small_batch_bounds():
    """batch_size below the coalescer's default min_batch must be served
    as requested (not silently clamped to 32), and a lone max_batch below
    the defaults must not crash."""
    stream = small_stream(64, n_vertices=9, seed=47)
    svc = ContinuousSearchService(slots_per_group=2, **CAP)
    svc.register(chain_query(), 20)
    chunks = []
    svc.serve_stream(stream[:32], on_tick=lambda i: chunks.append(i.chunk),
                     batch_size=8)
    assert chunks[0] == 8
    chunks.clear()
    svc.serve_stream(stream[32:], on_tick=lambda i: chunks.append(i.chunk),
                     batch_size=64, max_batch=16)    # self-consistent args
    assert chunks[0] == 16

    # an on_match that could never fire must fail loudly, not silently
    svc_nx = ContinuousSearchService(extract_matches=False, **CAP)
    svc_nx.register(chain_query(), 20)
    with pytest.raises(ValueError, match="extract_matches"):
        svc_nx.serve_stream(stream, on_match=lambda q, b, t: None)


def test_checkpoint_retention_and_loud_misconfig(tmp_path):
    """keep-last-K retention bounds ckpt_dir growth (restore still works
    from the newest kept step), and ckpt_every without ckpt_dir fails
    loudly instead of silently skipping fault tolerance."""
    stream = small_stream(160, n_vertices=9, seed=49)
    svc = ContinuousSearchService(slots_per_group=2, ckpt_dir=str(tmp_path),
                                  keep_checkpoints=3, **CAP)
    qid = svc.register(chain_query(), 20)
    svc.serve_stream(stream, ckpt_every=1, **SERVE)     # 10 ticks, 10 saves
    steps = checkpoint_steps(str(tmp_path))
    assert len(steps) == 3 and steps[-1] == 10
    svc2 = ContinuousSearchService.restore(str(tmp_path))
    assert svc2.n_edges_ingested == len(stream)
    assert svc2.matches(qid) == svc.matches(qid)

    bare = ContinuousSearchService(slots_per_group=2, **CAP)
    bare.register(chain_query(), 20)
    with pytest.raises(ValueError, match="ckpt_dir"):
        bare.serve_stream(stream, ckpt_every=5)


def test_stream_server_rejects_plan_capacity_drift(tmp_path):
    """Restarting over a checkpoint with a recompiled (bigger-capacity)
    plan must fail loudly — the restore serves the checkpointed plan, so
    silently keeping the old tables would hide the operator's fix."""
    plan = compile_plan(chain_query(), 20, **CAP)
    s1 = StreamServer(plan, ckpt_dir=str(tmp_path))
    s1.ingest(small_stream(64, n_vertices=9, seed=50), ckpt_every=1,
              batch_size=16)
    bigger = compile_plan(chain_query(), 20, level_capacity=2048,
                          l0_capacity=2048, max_new=1024)
    with pytest.raises(ValueError, match="capacities or decomposition"):
        StreamServer(bigger, ckpt_dir=str(tmp_path))


def test_stream_server_rejects_foreign_checkpoints(tmp_path):
    """A ckpt_dir holding non-service checkpoints (legacy or foreign
    writer) must fail loudly at startup, not crash obscurely or silently
    start fresh (which would break the miss-nothing guarantee)."""
    import jax.numpy as jnp
    from repro.checkpoint import save_checkpoint

    save_checkpoint(str(tmp_path), 1, {"x": jnp.ones(2)})
    plan = compile_plan(chain_query(), 20, **CAP)
    with pytest.raises(ValueError, match="service manifest"):
        StreamServer(plan, ckpt_dir=str(tmp_path))


def test_stream_server_refuses_all_torn_dir(tmp_path):
    """Checkpoints exist but every one is torn: restarting must raise,
    not silently start fresh at offset 0."""
    from repro.checkpoint import CheckpointError

    plan = compile_plan(chain_query(), 20, **CAP)
    s1 = StreamServer(plan, ckpt_dir=str(tmp_path))
    s1.ingest(small_stream(64, n_vertices=9, seed=48), ckpt_every=1,
              batch_size=16)
    assert checkpoint_steps(str(tmp_path))
    for s in checkpoint_steps(str(tmp_path)):
        p = tmp_path / f"step_{s}.npz"
        p.write_bytes(p.read_bytes()[:16])
    with pytest.raises(CheckpointError, match="none are usable"):
        StreamServer(plan, ckpt_dir=str(tmp_path))
