"""End-to-end tests for the public ``repro.api`` surface.

The acceptance bar for the API redesign:

* a DSL-authored session reports the EXACT oracle match multiset (each
  in-window match exactly once) on both REF and PALLAS_INTERPRET;
* two relabeled-isomorphic patterns provably share one compiled slot
  tick — one ``SlotTickCache`` build, one slot group, ONE XLA trace;
* overflow surfaces as API-level status and gates admission;
* ``StreamSession`` checkpoints carry the api state (vocab + pattern
  plans) and ``restore`` rebuilds the typed surface.
"""

from collections import Counter

import numpy as np
import pytest

from repro.api import (
    ACTIVE,
    AdmissionError,
    DEGRADED,
    Event,
    EventBuffer,
    LabelVocab,
    Pattern,
    PatternError,
    StreamSession,
    to_data_edge,
)
from repro.core.join import JoinBackend
from repro.core.multi import SlotTickCache
from repro.core.oracle import OracleEngine

CAP = dict(level_capacity=512, l0_capacity=512, max_new=256)


# --------------------------------------------------------------------- #
# fixtures: patterns + streams
# --------------------------------------------------------------------- #
def chain_pattern(name="lateral"):
    return (Pattern(name)
            .edge("a", "b", label="login")
            .edge("b", "c", label="xfer")
            .before(0, 1)
            .window(24))


def chain_pattern_reauthored():
    """Same abstract structure as ``chain_pattern`` — edges stated in the
    opposite order, different vertex names, named edges."""
    return (Pattern("lateral-b")
            .edge("y", "z", label="xfer", name="second")
            .edge("x", "y", label="login", name="first")
            .before("first", "second")
            .window(24))


def triangle_pattern():
    return (Pattern("beacon")
            .edge("u", "v")
            .edge("v", "w")
            .edge("w", "u")
            .before(0, 1).before(1, 2)
            .window(30))


def traffic(n_events, seed, n_hosts=9, labels=("login", "xfer", "probe")):
    rng = np.random.default_rng(seed)
    t, out, seen = 0, [], set()
    while len(out) < n_events:
        t += int(rng.integers(0, 3))
        s = int(rng.integers(0, n_hosts))
        d = int(rng.integers(0, n_hosts))
        if s == d:
            d = (d + 1) % n_hosts
        if (s, d, t) in seen:       # duplicate edge instances would make
            continue                # the exactly-once multiset ambiguous
        seen.add((s, d, t))
        out.append(Event(s, d, t, label=labels[int(rng.integers(0, 3))]))
    return out


def match_key(sub, m):
    """Lower a typed ``Match`` back to the canonical frozenset form the
    oracle and ``current_matches`` speak."""
    plan = sub.plan
    bind, when = m.bindings, m.times
    name_of = {c: n for n, c in zip(plan.vertex_names, plan.vertex_map)}
    out = []
    for j, ename in enumerate(plan.edge_names):
        ceid = plan.edge_map[j]
        u, v = plan.query.edges[ceid]
        out.append((ceid, (bind[name_of[u]], bind[name_of[v]], when[ename])))
    return frozenset(out)


def oracle_run(query, window, stream):
    """(every match ever reported, final window matches)."""
    oracle = OracleEngine(query, window)
    seen = set()
    for e in stream:
        oracle.insert(e)
        seen |= oracle.matches()
    return seen, oracle.matches()


# --------------------------------------------------------------------- #
@pytest.mark.parametrize(
    "backend", [JoinBackend.REF, JoinBackend.PALLAS_INTERPRET])
def test_dsl_session_matches_oracle_multiset(backend):
    """DSL-authored sessions are oracle-exact: the delivered Match
    multiset equals the oracle's reported set (each match exactly once),
    and the two isomorphic chain authorings share ONE compiled tick with
    ONE trace."""
    tc = SlotTickCache()
    sess = StreamSession(slots_per_group=4, backend=backend,
                         tick_cache=tc, **CAP)
    subs = [sess.register(p) for p in
            (chain_pattern(), chain_pattern_reauthored(), triangle_pattern())]
    # chain authored two ways -> one structure; triangle -> another
    assert tc.n_builds == 2
    assert sess.service.n_compiles == 2

    events = traffic(240, seed=3)
    delivered = sess.ingest(events, batch_size=16)
    assert delivered > 0

    stream = [to_data_edge(e, sess.vocab) for e in events]
    for sub in subs:
        want_reported, want_window = oracle_run(sub.query, sub.window, stream)
        got = Counter(match_key(sub, m) for m in sub.drain())
        assert got and max(got.values()) == 1       # exactly once
        assert set(got) == want_reported
        assert {match_key(sub, m) for m in sub.matches()} == want_window
        assert sub.status == ACTIVE and sub.n_overflow == 0

    # zero extra XLA traces: every batch was 16 wide -> one trace per tick
    assert [t._cache_size() for t in tc.ticks()] == [1, 1]


def test_isomorphic_patterns_share_one_group_and_tick():
    """Registration of a re-authored isomorphic pattern is a pure data
    write: same slot group, no new build, no new trace."""
    tc = SlotTickCache()
    sess = StreamSession(slots_per_group=4, tick_cache=tc, **CAP)
    s1 = sess.register(chain_pattern())
    sess.ingest(traffic(64, seed=5), batch_size=16)   # compile + trace
    builds, traces = tc.n_builds, [t._cache_size() for t in tc.ticks()]
    assert builds == 1 and traces == [1]

    s2 = sess.register(chain_pattern_reauthored())    # mid-stream arrival
    sess.ingest(traffic(64, seed=6), batch_size=16)
    assert tc.n_builds == builds
    assert [t._cache_size() for t in tc.ticks()] == traces
    assert len(sess.service._iter_groups()) == 1      # one padded group
    g, _ = sess.service._location[s1.qid]
    g2, _ = sess.service._location[s2.qid]
    assert g is g2


def test_match_translation_names_and_times():
    """Bindings come back under the pattern's own vertex/edge names, in
    authoring order, with per-edge timestamps honoring the timing order."""
    sess = StreamSession(**CAP)
    sub = sess.register(chain_pattern())
    sess.ingest([
        Event(src=7, dst=3, ts=10, label="login"),
        Event(src=3, dst=5, ts=12, label="xfer"),
    ])
    (m,) = sub.drain()
    assert m.bindings == {"a": 7, "b": 3, "c": 5}
    assert m.times == {"e0": 10, "e1": 12}
    assert m.ts == 12
    assert [n for n, _ in m.vertices] == ["a", "b", "c"]
    # timing order violated -> no match
    sub2 = sess.register(chain_pattern_reauthored())
    sess.ingest([
        Event(src=1, dst=2, ts=40, label="xfer"),
        Event(src=0, dst=1, ts=44, label="login"),   # login AFTER xfer
    ])
    assert sub2.drain() == []


def test_callbacks_and_serve_loop():
    """``serve`` (the production loop) dispatches through callbacks and
    returns per-subscription totals keyed by the handles."""
    sess = StreamSession(**CAP)
    hits = []
    sub = sess.register(chain_pattern(), on_match=hits.append)
    totals = sess.serve(traffic(200, seed=9), batch_size=16)
    assert totals.get(sub, 0) == len(hits) == sub.n_delivered
    assert hits and sub.drain() == []     # callback mode: queue stays empty
    assert all(set(m.bindings) == {"a", "b", "c"} for m in hits)


def test_overflow_degrades_status_and_gates_admission():
    """Tiny capacities + a dense stream -> engine overflow.  The api
    layer must surface it (DEGRADED status, session.status) and refuse
    to admit more tenants of that structure unless forced."""
    sess = StreamSession(slots_per_group=4, level_capacity=8,
                         l0_capacity=8, max_new=4)
    wild = (Pattern("wild")
            .edge("a", "b").edge("b", "c").before(0, 1).window(60))
    sub = sess.register(wild)
    overflow_ticks = []
    sess.serve(traffic(256, seed=11, n_hosts=5), batch_size=32,
               min_batch=32, max_batch=32,
               on_tick=lambda i: overflow_ticks.append(i.n_overflow))
    assert sub.n_overflow > 0
    assert sub.status == DEGRADED
    assert sess.status().degraded == (sub.qid,)
    assert sum(overflow_ticks) > 0        # ServeInfo surfaces it per tick

    # same structure: admission refused (would silently lose matches)
    with pytest.raises(AdmissionError, match="capacity pressure"):
        sess.register(chain_pattern())
    # explicit override and unrelated structures still admit
    forced = sess.register(chain_pattern(), force=True)
    assert forced.status == ACTIVE
    tri = sess.register(triangle_pattern())
    assert tri.status == ACTIVE


def test_session_checkpoint_restore_roundtrip(tmp_path):
    """Crash/restore on the api surface: original qids, same vocab ids,
    same pattern plans, window matches identical; replaying the tail
    converges with the uninterrupted session."""
    tc = SlotTickCache()
    events = traffic(192, seed=13)
    serve = dict(batch_size=16, min_batch=16, max_batch=16)

    sess_a = StreamSession(ckpt_dir=str(tmp_path / "a"), tick_cache=tc, **CAP)
    subs_a = [sess_a.register(p) for p in
              (chain_pattern(), chain_pattern_reauthored())]
    sess_a.serve(events, ckpt_every=3, **serve)
    sess_a.close()

    sess_b = StreamSession(ckpt_dir=str(tmp_path / "b"), tick_cache=tc, **CAP)
    subs_b = [sess_b.register(p) for p in
              (chain_pattern(), chain_pattern_reauthored())]
    sess_b.serve(events[:96], ckpt_every=3, **serve)
    sess_b.checkpoint()
    sess_b.close()
    del sess_b                                   # crash

    sess_r = StreamSession.restore(str(tmp_path / "b"), tick_cache=tc)
    assert sess_r.service.n_compiles == 0        # warm process cache
    assert [s.qid for s in sess_r.subscriptions()] == \
        [s.qid for s in subs_b]
    assert sess_r.vocab.to_json() == sess_a.vocab.to_json()
    for s in sess_r.subscriptions():
        assert s.plan.vertex_names in (("a", "b", "c"), ("y", "z", "x"))
    sess_r.serve(events[sess_r.resume_offset:], **serve)

    for sa, sr in zip(subs_a, sess_r.subscriptions()):
        assert sa.plan == sr.plan
        assert sr.matches() == sa.matches()


def test_mesh_session_matches_plain_session(tmp_path):
    """``StreamSession(mesh=...)`` serves through the replica-sharded
    service: same delivered multiset as the plain session, and a sharded
    checkpoint restores back onto the mesh path with the full typed
    surface intact."""
    from repro.runtime.mesh import ShardedSearchService

    events = traffic(160, seed=21)
    serve = dict(batch_size=16)

    plain = StreamSession(slots_per_group=4, tick_cache=SlotTickCache(),
                          **CAP)
    sub_p = plain.register(chain_pattern())
    plain.ingest(events, **serve)
    want = Counter(match_key(sub_p, m) for m in sub_p.drain())

    tc = SlotTickCache()
    sess = StreamSession(mesh={"n_replicas": 1, "slots_per_replica": 4},
                         ckpt_dir=str(tmp_path), tick_cache=tc, **CAP)
    assert isinstance(sess.service, ShardedSearchService)
    sub = sess.register(chain_pattern())
    sess.ingest(events, **serve)
    got = Counter(match_key(sub, m) for m in sub.drain())
    assert got == want and want
    sess.checkpoint()
    sess.close()
    del sess                                     # crash

    sess_r = StreamSession.restore(str(tmp_path), tick_cache=tc)
    assert isinstance(sess_r.service, ShardedSearchService)
    assert sess_r.service.n_replicas == 1
    (sub_r,) = sess_r.subscriptions()
    assert sub_r.plan == sub.plan
    assert sub_r.matches() == sub_p.matches()

    # the shorthand: an int is the replica count
    sess_i = StreamSession(mesh=1, tick_cache=SlotTickCache(), **CAP)
    assert isinstance(sess_i.service, ShardedSearchService)


def test_restore_refuses_non_session_checkpoints(tmp_path):
    """A raw service checkpoint (no api state) must not silently restore
    as an untyped session."""
    from repro.checkpoint import CheckpointError
    from repro.runtime.service import ContinuousSearchService
    from repro.core.query import QueryGraph

    svc = ContinuousSearchService(ckpt_dir=str(tmp_path), **CAP)
    svc.register(QueryGraph(3, (0, 1, 2), ((0, 1), (1, 2)),
                            prec=frozenset({(0, 1)})), 20)
    svc.checkpoint()
    svc.ckpt.wait()
    with pytest.raises(CheckpointError, match="StreamSession"):
        StreamSession.restore(str(tmp_path))


# --------------------------------------------------------------------- #
# DSL validation + event buffer
# --------------------------------------------------------------------- #
def test_pattern_validation_is_loud():
    with pytest.raises(PatternError, match="self-loop"):
        Pattern().edge("a", "a")
    with pytest.raises(PatternError, match="duplicate parallel"):
        Pattern().edge("a", "b").edge("a", "b")
    with pytest.raises(PatternError, match="unknown edge name"):
        Pattern().edge("a", "b").before("nope", 0)
    with pytest.raises(PatternError, match="out of range"):
        Pattern().edge("a", "b").before(0, 3)
    with pytest.raises(PatternError, match="relabelled"):
        Pattern().vertex("a", label="x").vertex("a", label="y")
    with pytest.raises(PatternError, match="no window"):
        Pattern().edge("a", "b").build()
    with pytest.raises(PatternError, match="no edges"):
        Pattern().window(10).build()
    # a before-cycle is not a strict partial order
    with pytest.raises(PatternError, match="strict partial order"):
        (Pattern().edge("a", "b").edge("b", "c")
         .before(0, 1).before(1, 0).window(10).build())


def test_event_buffer_pads_pow2():
    vocab = LabelVocab()
    buf = EventBuffer(vocab, batch_size=6)
    out = []
    for i in range(8):
        b = buf.push(Event(i, i + 1, i, label="x"))
        if b is not None:
            out.append(b)
    tail = buf.flush()
    assert len(out) == 1 and tail is not None
    assert out[0]["src"].shape == (8,)           # 6 -> pow2 pad to 8
    assert out[0]["valid"].sum() == 6
    assert tail["src"].shape == (8,)             # pow2 floor is 8
    assert tail["valid"].sum() == 2
    assert buf.flush() is None
    # label space is the session vocab's
    assert out[0]["edge_label"][0] == vocab.intern("x")


def test_label_vocab_roundtrip_and_type_guard():
    from repro.api.events import STR_BASE

    v = LabelVocab()
    assert v.intern("login") == v.intern("login") == STR_BASE
    assert v.intern("xfer") == STR_BASE + 1
    # int tokens are identity-mapped: raw DataEdge streams (already in
    # engine label space) stay aligned with int-labeled patterns no
    # matter what order labels are declared in
    assert v.intern(7) == 7 and v.intern(0) == 0
    assert v.token(7) == 7 and v.token(STR_BASE) == "login"
    assert LabelVocab.from_json(v.to_json()).to_json() == v.to_json()
    with pytest.raises(TypeError, match="str or int"):
        v.intern(("tuple",))
    with pytest.raises(TypeError, match="str or int"):
        v.intern(True)
    with pytest.raises(ValueError, match="int label tokens"):
        v.intern(-1)


def test_int_labels_align_with_raw_data_edges():
    """The declaration-order trap: a pattern declaring int labels out of
    order must still match raw DataEdges carrying those exact engine
    label ids (identity interning — without it label=2 could intern to
    id 0 and silently match nothing)."""
    from repro.core.oracle import DataEdge

    sess = StreamSession(**CAP)
    p = (Pattern("desc-order")
         .vertex("a", label=2).vertex("b", label=0).vertex("c", label=1)
         .edge("a", "b").edge("b", "c").before(0, 1).window(20))
    sub = sess.register(p)
    sess.ingest([
        DataEdge(src=5, dst=6, ts=1, src_label=2, dst_label=0, edge_label=0),
        DataEdge(src=6, dst=7, ts=2, src_label=0, dst_label=1, edge_label=0),
    ])
    (m,) = sub.drain()
    assert m.bindings == {"a": 5, "b": 6, "c": 7}


def test_subscription_queue_is_bounded():
    """An un-drained queue-mode subscription drops its OLDEST matches
    past MAX_PENDING (counted in n_dropped) instead of growing forever."""
    sess = StreamSession(**CAP)
    sub = sess.register(chain_pattern())
    sub.MAX_PENDING = 4                     # shrink the bound for the test
    sub._pending = __import__("collections").deque(maxlen=4)
    for k in range(7):
        sess.ingest([
            Event(src=10 + k, dst=50, ts=100 * k, label="login"),
            Event(src=50, dst=20 + k, ts=100 * k + 1, label="xfer"),
        ])
    assert sub.n_delivered == 7
    assert sub.n_dropped == 3
    kept = sub.drain()
    assert len(kept) == 4
    assert kept[-1].bindings == {"a": 16, "b": 50, "c": 26}   # newest kept
