"""Transformer family unit tests: dense/MoE forward, loss, decode-vs-
prefill consistency, MoE dispatch exactness."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.models import transformer as tfm
from repro.models.moe import moe_ffn


def tiny_cfg(**kw):
    base = dict(
        name="tiny", n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab=97, dtype=jnp.float32,
        attn_chunk=8, remat="none")
    base.update(kw)
    return tfm.LMConfig(**base)


def test_forward_shapes_and_finite():
    cfg = tiny_cfg()
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    logits, aux = tfm.forward(params, tokens, cfg)
    assert logits.shape == (2, 16, cfg.vocab)
    assert np.isfinite(np.asarray(logits)).all()
    loss, metrics = tfm.loss_fn(params, tokens, cfg)
    assert np.isfinite(float(loss))


def test_moe_forward_finite_and_aux():
    cfg = tiny_cfg(moe=True, n_experts=4, moe_topk=2, dense_residual=True,
                   residual_d_ff=64)
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
    loss, metrics = tfm.loss_fn(params, tokens, cfg)
    assert np.isfinite(float(loss))
    assert float(metrics["aux"]) > 0.0


def test_qk_norm_path():
    cfg = tiny_cfg(qk_norm=True)
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    tokens = jnp.zeros((1, 8), jnp.int32)
    logits, _ = tfm.forward(params, tokens, cfg)
    assert np.isfinite(np.asarray(logits)).all()


def test_causality():
    """Changing a future token must not affect past logits."""
    cfg = tiny_cfg()
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab)
    t2 = t1.at[0, -1].set((t1[0, -1] + 1) % cfg.vocab)
    l1, _ = tfm.forward(params, t1, cfg)
    l2, _ = tfm.forward(params, t2, cfg)
    np.testing.assert_allclose(np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]),
                               rtol=1e-5, atol=1e-5)


def test_decode_matches_prefill():
    """Greedy decode logits must equal teacher-forced forward logits."""
    cfg = tiny_cfg()
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    b, s, smax = 2, 10, 16
    tokens = jax.random.randint(jax.random.PRNGKey(2), (b, s), 0, cfg.vocab)
    full_logits, _ = tfm.forward(params, tokens, cfg)

    kc = jnp.zeros((cfg.n_layers, b, smax, cfg.n_kv_heads, cfg.head_dim))
    vc = jnp.zeros_like(kc)
    length = jnp.zeros((b,), jnp.int32)
    cache = (kc, vc, length)
    step_logits = []
    for i in range(s):
        lg, cache = tfm.serve_step(params, tokens[:, i:i + 1], cache, cfg)
        step_logits.append(lg)
    got = jnp.stack(step_logits, axis=1)        # [B, S, V]
    np.testing.assert_allclose(np.asarray(got), np.asarray(full_logits),
                               rtol=2e-4, atol=2e-4)


def test_sliding_window_attention():
    cfg = tiny_cfg(attn_window=4)
    params = tfm.init(jax.random.PRNGKey(0), cfg)
    t1 = jax.random.randint(jax.random.PRNGKey(1), (1, 12), 0, cfg.vocab)
    # token 0 is outside the window of position 11: changing it must not
    # affect the last logit... (strictly: it can via layer stacking; use
    # a 1-layer config for the strict check)
    cfg1 = tiny_cfg(attn_window=4, n_layers=1)
    p1 = tfm.init(jax.random.PRNGKey(0), cfg1)
    t2 = t1.at[0, 0].set((t1[0, 0] + 1) % cfg1.vocab)
    l1, _ = tfm.forward(p1, t1, cfg1)
    l2, _ = tfm.forward(p1, t2, cfg1)
    np.testing.assert_allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]),
                               rtol=1e-5, atol=1e-5)


def test_moe_dispatch_matches_dense_loop():
    """Sort-based capacity dispatch == explicit per-token loop (ample cap)."""
    rng = np.random.default_rng(0)
    t, d, e, f = 32, 16, 4, 24

    class C:
        n_experts = e
        moe_topk = 2
        capacity_factor = 8.0   # ample: no drops
        moe_renorm = True
        moe_lb_coef = 0.0
        moe_z_coef = 0.0

    x = jnp.asarray(rng.standard_normal((t, d)), jnp.float32)
    p = {
        "wg": jnp.asarray(rng.standard_normal((d, e)), jnp.float32) * 0.1,
        "w1": jnp.asarray(rng.standard_normal((e, d, f)), jnp.float32) * 0.1,
        "w3": jnp.asarray(rng.standard_normal((e, d, f)), jnp.float32) * 0.1,
        "w2": jnp.asarray(rng.standard_normal((e, f, d)), jnp.float32) * 0.1,
    }
    got, _ = moe_ffn(x, p, C)

    gates = jax.nn.softmax(x @ p["wg"], axis=-1)
    topw, topi = jax.lax.top_k(gates, 2)
    topw = topw / topw.sum(-1, keepdims=True)
    want = np.zeros((t, d), np.float32)
    for ti in range(t):
        for kk in range(2):
            ei = int(topi[ti, kk])
            h = jax.nn.silu(x[ti] @ p["w1"][ei]) * (x[ti] @ p["w3"][ei])
            want[ti] += float(topw[ti, kk]) * np.asarray(h @ p["w2"][ei])
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-4, atol=2e-4)
