"""Slot ticks on the Pallas backend (interpret mode): the multi-query
runtime must be oracle-exact with traced per-slot windows, batch its
vmapped joins into stacked kernels without recompiling, and keep the
service's register-is-a-data-write property.

REF is the trusted baseline (itself oracle-tested in
tests/test_multi_query.py); every check here is REF ↔ PALLAS_INTERPRET.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import compile_plan
from repro.core.engine import build_tick, current_matches
from repro.core.join import JoinBackend
from repro.core.multi import (
    SlotTickCache,
    build_slot_tick,
    init_slot_state,
    read_slot,
    write_slot,
)
from repro.core.oracle import DataEdge, OracleEngine
from repro.core.query import QueryGraph
from repro.core.state import init_state, make_batch
from repro.runtime.service import ContinuousSearchService
from repro.stream.generator import to_batches

from test_engine_oracle import small_stream, star_query, tri_query

CAP = dict(level_capacity=512, l0_capacity=512, max_new=256)


def chain_query():
    return QueryGraph(3, (0, 1, 2), ((0, 1), (1, 2)), prec=frozenset({(0, 1)}))


def chain_query_relabeled():
    return QueryGraph(3, (1, 2, 0), ((0, 1), (1, 2)), prec=frozenset({(0, 1)}))


def _run_slot_group(backend, tpl, plans_by_slot, batches, n_slots=4):
    tick = jax.jit(build_slot_tick(tpl, backend=backend))
    ss = init_slot_state(tpl, n_slots)
    for k, plan in plans_by_slot.items():
        ss = write_slot(ss, tpl, k, plan)
    for b in batches:
        ss, res = tick(ss, b)
    return tick, ss


@pytest.mark.parametrize("query_ctor,stream_kw", [
    (chain_query, dict(n_vertices=9)),
    (tri_query, dict(n_vertices=9)),
    # the star only matches on a denser label space
    (star_query, dict(n_vertices=7, n_vertex_labels=2)),
])
def test_slot_tick_pallas_interpret_matches_ref(query_ctor, stream_kw):
    """build_slot_tick(backend=PALLAS_INTERPRET) is oracle-exact: same
    per-slot matches/stats as REF, with traced per-slot windows, from a
    single jit trace (no NotImplementedError, no recompile)."""
    tpl = compile_plan(query_ctor(), 20, **CAP)
    plans = {
        0: compile_plan(query_ctor(), 20, **CAP),
        2: compile_plan(query_ctor(), 31, **CAP),   # different window
    }
    stream = small_stream(120, seed=31, **stream_kw)
    batches = [make_batch(**b) for b in to_batches(stream, 8)]

    finals = {}
    for backend in (JoinBackend.REF, JoinBackend.PALLAS_INTERPRET):
        tick, ss = _run_slot_group(backend, tpl, plans, batches)
        assert tick._cache_size() == 1
        finals[backend] = {
            k: (current_matches(tpl, read_slot(ss, k)),
                int(read_slot(ss, k).stats.n_matches_total),
                int(read_slot(ss, k).stats.n_overflow))
            for k in plans
        }
    assert finals[JoinBackend.REF] == finals[JoinBackend.PALLAS_INTERPRET]
    # the streams actually produce matches (the test isn't vacuous)
    assert any(v[1] > 0 for v in finals[JoinBackend.REF].values())


def test_slot_tick_pallas_window_crossing_expiry_mid_tick():
    """One tick whose batch straddles a partial match's expiry: the
    window-span predicate must admit the in-window continuation and
    reject the one past expiry — identically under REF and Pallas."""
    q = chain_query()
    window = 10
    # edge0 (a->b, ts0) opens a partial match; in the SAME tick edge1
    # candidates arrive at ts 9 (span 9 < 10: match) and ts 12 (span
    # 12 >= 10: the ts-0 row is already expired for it).
    edges = [
        DataEdge(0, 1, 0, 0, 1, 0),
        DataEdge(1, 2, 9, 1, 2, 0),
        DataEdge(1, 3, 12, 1, 2, 0),
    ]
    batch = make_batch(
        src=[e.src for e in edges], dst=[e.dst for e in edges],
        ts=[e.ts for e in edges],
        src_label=[e.src_label for e in edges],
        dst_label=[e.dst_label for e in edges],
        edge_label=[e.edge_label for e in edges])

    oracle = OracleEngine(q, window)
    for e in edges:
        oracle.insert(e)

    results = {}
    for backend in (JoinBackend.REF, JoinBackend.PALLAS_INTERPRET):
        tpl = compile_plan(q, window, **CAP)
        tick, ss = _run_slot_group(
            backend, tpl, {0: compile_plan(q, window, **CAP)}, [batch],
            n_slots=2)
        st = read_slot(ss, 0)
        results[backend] = (current_matches(tpl, st),
                            int(st.stats.n_matches_total))
    assert results[JoinBackend.REF] == results[JoinBackend.PALLAS_INTERPRET]
    matches, n_total = results[JoinBackend.REF]
    # exactly ONE match was reported: the ts-9 continuation joined the
    # ts-0 row before its expiry; the ts-12 one (span >= window) did not
    # — had it joined, n_total would be 2.
    assert n_total == 1
    # ... and by end of tick t_now=12 has expired the {0, 9} match, in
    # agreement with the brute-force oracle's current window.
    assert matches == oracle.matches()


def test_service_pallas_interpret_matches_ref_service():
    """End-to-end service equivalence across backends, with add/remove
    churn mid-stream."""
    stream = small_stream(140, n_vertices=9, seed=33)
    batches = list(to_batches(stream, 8))
    half = len(batches) // 2

    svcs = {}
    for backend in (JoinBackend.REF, JoinBackend.PALLAS_INTERPRET):
        svc = ContinuousSearchService(
            slots_per_group=2, backend=backend, **CAP)
        qa = svc.register(chain_query(), window=20)
        qb = svc.register(tri_query(), window=25)
        for b in batches[:half]:
            svc.ingest(b)
        svc.unregister(qb)
        qc = svc.register(chain_query_relabeled(), window=30)
        for b in batches[half:]:
            svc.ingest(b)
        svcs[backend] = (svc, qa, qc)

    ref_svc, ra, rc = svcs[JoinBackend.REF]
    pal_svc, pa, pc = svcs[JoinBackend.PALLAS_INTERPRET]
    assert ref_svc.matches(ra) == pal_svc.matches(pa)
    assert ref_svc.matches(rc) == pal_svc.matches(pc)
    assert int(ref_svc.stats(ra).n_matches_total) == \
        int(pal_svc.stats(pa).n_matches_total)
    assert int(ref_svc.stats(ra).n_matches_total) > 0   # non-vacuous


def test_service_pallas_register_does_not_recompile():
    """Registering a same-structure query under the PALLAS backend is a
    pure data write: no new build_slot_tick group, and the group's jit
    cache stays at one entry across windows and slot churn."""
    svc = ContinuousSearchService(
        slots_per_group=4, backend=JoinBackend.PALLAS_INTERPRET,
        tick_cache=SlotTickCache(), **CAP)
    qa = svc.register(chain_query(), window=20)
    assert svc.n_compiles == 1
    svc.register(chain_query_relabeled(), window=35)   # new labels+window
    svc.register(chain_query(), window=7)
    assert svc.n_compiles == 1

    stream = small_stream(40, n_vertices=8, seed=35)
    for b in to_batches(stream, 8):
        svc.ingest(b)
    (group, _) = svc._location[qa]
    assert group.tick._cache_size() == 1
    # churn a slot mid-stream: still no retrace
    svc.unregister(qa)
    svc.register(chain_query(), window=50)
    for b in to_batches(stream, 8):
        svc.ingest(b)
    assert group.tick._cache_size() == 1
    assert svc.n_compiles == 1


def test_service_rejects_unknown_backend():
    with pytest.raises(ValueError, match="unknown join backend"):
        ContinuousSearchService(backend="cuda")
