"""Property tests (hypothesis) for the public API's compilation chain:

1. ``Pattern -> QueryGraph -> to_spec -> from_spec -> canonical form``
   is idempotent (the checkpoint-manifest round-trip is a fixed point of
   canonicalization);
2. canonicalization is invariant under vertex renumbering and edge
   reordering of the authored query;
3. label-only changes never perturb the canonical *structure* (edges +
   precedence), which is what lets same-structure tenants share one
   compiled slot tick;
4. two authorings of the same abstract pattern through the DSL — edges
   stated in any order, vertices named anything — compile to the same
   canonical query under one shared vocab.
"""

import pytest

hypothesis = pytest.importorskip(
    "hypothesis", reason="optional dev dependency (see requirements-dev.txt)")
from hypothesis import given, settings, strategies as st

from repro.api import LabelVocab, Pattern
from repro.core.canon import canonical_form
from repro.core.query import QueryGraph


@st.composite
def abstract_queries(draw):
    """(n_vertices, edges, prec, vlabels, elabels) with prec drawn from a
    random total order on edges — always a strict partial order."""
    n = draw(st.integers(2, 5))
    pairs = [(u, v) for u in range(n) for v in range(n) if u != v]
    m = draw(st.integers(1, min(5, len(pairs))))
    edges = tuple(draw(st.permutations(pairs))[:m])
    order = draw(st.permutations(range(m)))
    pos = {e: i for i, e in enumerate(order)}
    chains = [(i, j) for i in range(m) for j in range(m) if pos[i] < pos[j]]
    prec = frozenset(draw(st.sets(st.sampled_from(chains)))) if chains \
        else frozenset()
    vlabels = tuple(draw(st.lists(st.integers(0, 3), min_size=n, max_size=n)))
    elabels = tuple(draw(st.lists(st.sampled_from([-1, 0, 1, 2]),
                                  min_size=m, max_size=m)))
    return n, edges, prec, vlabels, elabels


def make_query(spec) -> QueryGraph:
    n, edges, prec, vlabels, elabels = spec
    return QueryGraph(n, vlabels, edges, elabels, prec)


def relabel(spec, vperm, eorder):
    """Renumber vertices by ``vperm`` and reorder edges by ``eorder``."""
    n, edges, prec, vlabels, elabels = spec
    new_vlabels = tuple(vlabels[vperm.index(k)] for k in range(n))
    new_edges = tuple((vperm[edges[e][0]], vperm[edges[e][1]])
                      for e in eorder)
    new_elabels = tuple(elabels[e] for e in eorder)
    inv = {old: new for new, old in enumerate(eorder)}
    new_prec = frozenset((inv[i], inv[j]) for i, j in prec)
    return n, new_edges, new_prec, new_vlabels, new_elabels


@settings(max_examples=120, deadline=None)
@given(spec=abstract_queries(), data=st.data())
def test_canonicalization_invariant_under_relabeling(spec, data):
    q = make_query(spec)
    n, edges = spec[0], spec[1]
    vperm = list(data.draw(st.permutations(range(n))))
    eorder = list(data.draw(st.permutations(range(len(edges)))))
    q2 = make_query(relabel(spec, vperm, eorder))
    assert canonical_form(q).query == canonical_form(q2).query


@settings(max_examples=120, deadline=None)
@given(spec=abstract_queries())
def test_spec_roundtrip_is_canonical_fixed_point(spec):
    q = make_query(spec)
    c = canonical_form(q).query
    back = QueryGraph.from_spec(c.to_spec())
    assert back == c
    again = canonical_form(back)
    assert again.query == c
    assert again.vertex_map == tuple(range(c.n_vertices))
    assert again.edge_map == tuple(range(c.n_edges))


@settings(max_examples=120, deadline=None)
@given(spec=abstract_queries(), data=st.data())
def test_labels_only_changes_keep_canonical_structure(spec, data):
    n, edges, prec, _, _ = spec
    vl2 = tuple(data.draw(
        st.lists(st.integers(0, 3), min_size=n, max_size=n)))
    el2 = tuple(data.draw(
        st.lists(st.sampled_from([-1, 0, 1, 2]),
                 min_size=len(edges), max_size=len(edges))))
    c1 = canonical_form(make_query(spec)).query
    c2 = canonical_form(make_query((n, edges, prec, vl2, el2))).query
    assert c1.edges == c2.edges
    assert c1.prec == c2.prec


@settings(max_examples=80, deadline=None)
@given(spec=abstract_queries(), data=st.data())
def test_dsl_authoring_order_does_not_matter(spec, data):
    """Author one abstract pattern twice — edges in different orders,
    different vertex names — and get the same canonical query."""
    n, edges, prec, vlabels, elabels = spec
    eorder = list(data.draw(st.permutations(range(len(edges)))))
    vocab = LabelVocab()

    def author(names, order):
        p = Pattern()
        for v in range(n):
            p.vertex(names[v], label=f"vl{vlabels[v]}")
        for e in order:
            u, v = edges[e]
            p.edge(names[u], names[v], name=f"edge{e}",
                   label=None if elabels[e] == -1 else f"el{elabels[e]}")
        for i, j in prec:
            p.before(f"edge{i}", f"edge{j}")
        return p.window(30)

    p1 = author([f"a{v}" for v in range(n)], list(range(len(edges))))
    p2 = author([f"b{v}" for v in range(n)], eorder)
    q1, w1 = p1.build(vocab)
    q2, w2 = p2.build(vocab)
    assert w1 == w2 == 30
    assert canonical_form(q1).query == canonical_form(q2).query
