"""compat_join Pallas kernels vs pure-jnp oracle: shape/dtype/spec sweep,
traced windows, vmapped slot-group batching, and the fused pair-extraction
op (interpret mode executes the kernel bodies on CPU)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import compile_plan
from repro.core.engine import build_tick, current_matches
from repro.core.join import JoinBackend, compat_mask_ref, extract_pairs
from repro.core.query import QueryGraph
from repro.core.state import init_state, make_batch
from repro.kernels.compat_join import ops as cj_ops
from repro.kernels.compat_join import ref as cj_ref
from repro.kernels.compat_join.kernel import TILE_A, TILE_B, choose_tiles
from repro.stream.generator import StreamConfig, synth_traffic_stream, to_batches


def rand_case(rng, ca, cb, nva, nvb, nea, neb, window):
    bind_a = rng.integers(0, 6, (ca, nva)).astype(np.int32)
    bind_b = rng.integers(0, 6, (cb, nvb)).astype(np.int32)
    ets_a = rng.integers(0, 30, (ca, nea)).astype(np.int32)
    ets_b = rng.integers(0, 30, (cb, neb)).astype(np.int32)
    valid_a = rng.random(ca) < 0.8
    valid_b = rng.random(cb) < 0.8
    rel = rng.random((nva, nvb)) < 0.3
    trel = rng.integers(-1, 2, (nea, neb)).astype(np.int8)
    return (jnp.asarray(bind_a), jnp.asarray(ets_a), jnp.asarray(valid_a),
            jnp.asarray(bind_b), jnp.asarray(ets_b), jnp.asarray(valid_b),
            rel, trel, window)


SHAPES = [
    (8, 8, 1, 1, 1, 1, None),
    (17, 33, 2, 2, 2, 1, None),
    (256, 256, 3, 2, 3, 1, 12),
    (300, 130, 4, 4, 4, 4, 20),
    (1, 512, 2, 2, 1, 1, 5),
    (512, 1, 5, 2, 5, 2, None),
]


@pytest.mark.parametrize("ca,cb,nva,nvb,nea,neb,window", SHAPES)
def test_kernel_matches_ref(ca, cb, nva, nvb, nea, neb, window):
    rng = np.random.default_rng(ca * 1000 + cb)
    args = rand_case(rng, ca, cb, nva, nvb, nea, neb, window)
    want = compat_mask_ref(*args[:6], args[6], args[7], args[8])
    got = cj_ops.compat_mask(*args[:6], args[6], args[7], args[8],
                             interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_kernel_random_specs(seed):
    rng = np.random.default_rng(seed)
    for _ in range(5):
        ca, cb = int(rng.integers(1, 400)), int(rng.integers(1, 400))
        nva, nvb = int(rng.integers(1, 5)), int(rng.integers(1, 5))
        nea, neb = int(rng.integers(1, 4)), int(rng.integers(1, 4))
        window = None if rng.random() < 0.5 else int(rng.integers(3, 25))
        args = rand_case(rng, ca, cb, nva, nvb, nea, neb, window)
        want = compat_mask_ref(*args[:6], args[6], args[7], args[8])
        got = cj_ops.compat_mask(*args[:6], args[6], args[7], args[8],
                                 interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# --------------------------------------------------------------------- #
# Adaptive tiling.
# --------------------------------------------------------------------- #
def test_choose_tiles_adapts_to_shape():
    assert choose_tiles(4096, 4096) == (TILE_A, TILE_B)
    # the common small-delta join no longer pads up to 256x256
    ta, tb = choose_tiles(64, 64)
    assert ta == 64 and tb == 128
    assert choose_tiles(1, 1) == (8, 128)
    assert choose_tiles(300, 130) == (256, 256)
    ta, tb = choose_tiles(9, 129)
    assert ta % 8 == 0 and tb % 128 == 0


# --------------------------------------------------------------------- #
# Traced windows.
# --------------------------------------------------------------------- #
def test_traced_window_parity_and_no_recompile():
    """``window`` is a scalar-prefetch input: changing it between calls
    produces oracle-exact masks from ONE jit trace (no recompile)."""
    rng = np.random.default_rng(7)
    args = rand_case(rng, 64, 48, 3, 2, 2, 1, None)
    f = jax.jit(lambda w: cj_ops.compat_mask(
        *args[:6], args[6], args[7], w, interpret=True))
    for w in (1, 7, 13, 29):
        want = compat_mask_ref(*args[:6], args[6], args[7], w)
        got = f(jnp.asarray(w, jnp.int32))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    assert f._cache_size() == 1


def test_traced_window_crossing_row_expiry_mid_tick():
    """Rows near expiry must stay joinable for earlier-timestamped B rows
    and be invisible to later ones (the paper's two-phase deletion as a
    window-span predicate): B timestamps straddle the A rows' expiry."""
    window = 10
    # A rows at ts 0, 5, 9; B rows at ts 8, 9, 12, 18: the (0, 12) pair
    # crosses expiry (span 12 >= 10) while (0, 9) does not.
    ets_a = jnp.asarray([[0], [5], [9]], jnp.int32)
    ets_b = jnp.asarray([[8], [9], [12], [18]], jnp.int32)
    bind_a = jnp.asarray([[1], [2], [3]], jnp.int32)
    bind_b = jnp.asarray([[4], [5], [6], [7]], jnp.int32)
    va = jnp.ones((3,), jnp.bool_)
    vb = jnp.ones((4,), jnp.bool_)
    rel = np.zeros((1, 1), bool)               # all-distinct vertices
    trel = np.full((1, 1), -1, np.int8)        # ts_a < ts_b
    want = compat_mask_ref(bind_a, ets_a, va, bind_b, ets_b, vb,
                           rel, trel, window)
    got = cj_ops.compat_mask(bind_a, ets_a, va, bind_b, ets_b, vb,
                             rel, trel, window, interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))
    w = np.asarray(want)
    assert w[0, 1] and not w[0, 2] and not w[0, 3]   # crossing pairs drop
    assert w[2, 2] and w[2, 3]                       # late rows still join


# --------------------------------------------------------------------- #
# Batched (vmapped) slot-group joins -> stacked 3-D-grid kernel.
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("mixed", [False, True])
def test_vmapped_slot_group_mask_matches_per_slot_ref(mixed):
    """jax.vmap over stacked tables + per-slot windows lowers to ONE
    stacked kernel and equals the per-slot reference masks.  ``mixed``
    leaves the B side unbatched (the slot tick's stream-edge operand)."""
    rng = np.random.default_rng(11)
    S, ca, cb = 3, 40, 24
    args = rand_case(rng, ca, cb, 3, 2, 2, 1, None)
    ba, ea, va, bb, eb, vb, rel, trel, _ = args
    bas = jnp.stack([ba, (ba + 1) % 6, ba[::-1]])
    ebs = jnp.stack([eb, eb + 1, eb])
    ws = jnp.asarray([4, 11, 25], jnp.int32)
    if mixed:   # B side (stream edges) shared across slots, A batched
        fn = jax.jit(jax.vmap(
            lambda xa, w: cj_ops.compat_mask(
                xa, ea, va, bb, eb, vb, rel, trel, w, interpret=True),
            in_axes=(0, 0)))
        got = fn(bas, ws)
    else:       # both sides batched
        fn = jax.jit(jax.vmap(
            lambda xa, xeb, w: cj_ops.compat_mask(
                xa, ea, va, bb, xeb, vb, rel, trel, w, interpret=True),
            in_axes=(0, 0, 0)))
        got = fn(bas, ebs, ws)
    for s in range(S):
        xeb = eb if mixed else ebs[s]
        want = compat_mask_ref(bas[s], ea, va, bb, xeb, vb, rel, trel,
                               int(ws[s]))
        np.testing.assert_array_equal(np.asarray(got[s]), np.asarray(want))


# --------------------------------------------------------------------- #
# Fused pair extraction (compat_join_pairs).
# --------------------------------------------------------------------- #
def _pair_set(a_idx, b_idx, valid):
    a, b, v = (np.asarray(x) for x in (a_idx, b_idx, valid))
    return set(zip(a[v].tolist(), b[v].tolist()))


def _check_pairs_vs_oracle(args, max_new):
    want_mask = compat_mask_ref(*args[:6], args[6], args[7], args[8])
    wa, wb, wv, wd = extract_pairs(want_mask, max_new)
    ga, gb, gv, gd = cj_ops.compat_join_pairs(
        *args[:6], args[6], args[7], max_new, args[8], interpret=True)
    assert int(gd) == int(wd), "n_dropped must be exact"
    want_set = _pair_set(wa, wb, wv)
    got_set = _pair_set(ga, gb, gv)
    if int(wd) == 0:
        assert got_set == want_set
    else:
        full = set(zip(*(x.tolist() for x in np.nonzero(np.asarray(want_mask)))))
        assert len(got_set) == max_new and got_set <= full
    # invalid entries are clamped to safe indices like extract_pairs
    assert int(jnp.min(ga)) >= 0 and int(jnp.min(gb)) >= 0


@pytest.mark.parametrize("ca,cb,nva,nvb,nea,neb,window", SHAPES)
def test_fused_pairs_match_mask_plus_extract(ca, cb, nva, nvb, nea, neb,
                                             window):
    rng = np.random.default_rng(ca * 31 + cb)
    args = rand_case(rng, ca, cb, nva, nvb, nea, neb, window)
    for max_new in (4, 64, 2048):
        _check_pairs_vs_oracle(args, max_new)


def test_fused_pairs_vmapped_slot_group():
    """Vmapped fused pairs (the PALLAS slot-tick join) == per-slot
    mask + extract_pairs, including per-slot n_dropped."""
    rng = np.random.default_rng(13)
    S, ca, cb, max_new = 3, 40, 24, 16
    args = rand_case(rng, ca, cb, 2, 2, 2, 1, None)
    ba, ea, va, bb, eb, vb, rel, trel, _ = args
    bas = jnp.stack([ba % 3, ba % 4, ba % 5])
    ws = jnp.asarray([6, 12, 29], jnp.int32)
    fn = jax.jit(jax.vmap(
        lambda xa, w: cj_ops.compat_join_pairs(
            xa, ea, va, bb, eb, vb, rel, trel, max_new, w, interpret=True),
        in_axes=(0, 0)))
    ga, gb, gv, gd = fn(bas, ws)
    assert fn._cache_size() == 1
    for s in range(S):
        mask = compat_mask_ref(bas[s], ea, va, bb, eb, vb, rel, trel,
                               int(ws[s]))
        wa, wb, wv, wd = extract_pairs(mask, max_new)
        assert int(gd[s]) == int(wd)
        if int(wd) == 0:
            assert _pair_set(ga[s], gb[s], gv[s]) == _pair_set(wa, wb, wv)
        else:
            full = set(zip(*(x.tolist()
                             for x in np.nonzero(np.asarray(mask)))))
            assert _pair_set(ga[s], gb[s], gv[s]) <= full


def test_spec_normalization_is_cached():
    """Equal-content specs map to the identical cached tuple objects, so
    repeated joins reuse the same static kernel key per tick."""
    rng = np.random.default_rng(3)
    rel = rng.random((3, 2)) < 0.5
    trel = rng.integers(-1, 2, (2, 1)).astype(np.int8)
    k1 = cj_ops.normalize_spec(rel, trel)
    k2 = cj_ops.normalize_spec(rel.copy(), trel.copy())
    assert k1[0] is k2[0] and k1[1] is k2[1]
    k3 = cj_ops.normalize_spec(~rel, trel)
    assert k3[0] is not k1[0]


def test_ref_module_pairs_oracle():
    """The kernel package's own oracle (ref.py) agrees with core.join."""
    rng = np.random.default_rng(5)
    args = rand_case(rng, 30, 20, 2, 2, 2, 1, 9)
    wa, wb, wv, wd = cj_ref.compat_join_pairs(
        *args[:6], args[6], args[7], 16, args[8])
    mask = compat_mask_ref(*args[:6], args[6], args[7], args[8])
    ea_, eb_, ev_, ed_ = extract_pairs(mask, 16)
    np.testing.assert_array_equal(np.asarray(wa), np.asarray(ea_))
    np.testing.assert_array_equal(np.asarray(wv), np.asarray(ev_))
    assert int(wd) == int(ed_)


def test_engine_with_pallas_backend_matches_ref_backend():
    """Full engine equivalence with the Pallas join (interpret mode)."""
    q = QueryGraph(3, (0, 1, 0), ((0, 1), (1, 2)), prec=frozenset({(0, 1)}))
    stream = synth_traffic_stream(StreamConfig(
        n_edges=120, n_vertices=10, n_vertex_labels=2, n_edge_labels=2,
        seed=3, ts_step_max=2))
    window = 18
    finals = []
    for backend in (JoinBackend.REF, JoinBackend.PALLAS_INTERPRET):
        plan = compile_plan(q, window, level_capacity=512, max_new=256)
        tick = jax.jit(build_tick(plan, backend=backend))
        state = init_state(plan)
        for b in to_batches(stream, 16):
            state, _ = tick(state, make_batch(**b))
        finals.append((current_matches(plan, state),
                       int(state.stats.n_matches_total)))
    assert finals[0] == finals[1]
