"""compat_join Pallas kernel vs pure-jnp oracle: shape/dtype/spec sweep
(interpret mode executes the kernel body on CPU)."""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import compile_plan
from repro.core.engine import build_tick, current_matches
from repro.core.join import JoinBackend, compat_mask_ref
from repro.core.query import QueryGraph
from repro.core.state import init_state, make_batch
from repro.kernels.compat_join import ops as cj_ops
from repro.stream.generator import StreamConfig, synth_traffic_stream, to_batches


def rand_case(rng, ca, cb, nva, nvb, nea, neb, window):
    bind_a = rng.integers(0, 6, (ca, nva)).astype(np.int32)
    bind_b = rng.integers(0, 6, (cb, nvb)).astype(np.int32)
    ets_a = rng.integers(0, 30, (ca, nea)).astype(np.int32)
    ets_b = rng.integers(0, 30, (cb, neb)).astype(np.int32)
    valid_a = rng.random(ca) < 0.8
    valid_b = rng.random(cb) < 0.8
    rel = rng.random((nva, nvb)) < 0.3
    trel = rng.integers(-1, 2, (nea, neb)).astype(np.int8)
    return (jnp.asarray(bind_a), jnp.asarray(ets_a), jnp.asarray(valid_a),
            jnp.asarray(bind_b), jnp.asarray(ets_b), jnp.asarray(valid_b),
            rel, trel, window)


SHAPES = [
    (8, 8, 1, 1, 1, 1, None),
    (17, 33, 2, 2, 2, 1, None),
    (256, 256, 3, 2, 3, 1, 12),
    (300, 130, 4, 4, 4, 4, 20),
    (1, 512, 2, 2, 1, 1, 5),
    (512, 1, 5, 2, 5, 2, None),
]


@pytest.mark.parametrize("ca,cb,nva,nvb,nea,neb,window", SHAPES)
def test_kernel_matches_ref(ca, cb, nva, nvb, nea, neb, window):
    rng = np.random.default_rng(ca * 1000 + cb)
    args = rand_case(rng, ca, cb, nva, nvb, nea, neb, window)
    want = compat_mask_ref(*args[:6], args[6], args[7], args[8])
    got = cj_ops.compat_mask(*args[:6], args[6], args[7], args[8],
                             interpret=True)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_kernel_random_specs(seed):
    rng = np.random.default_rng(seed)
    for _ in range(5):
        ca, cb = int(rng.integers(1, 400)), int(rng.integers(1, 400))
        nva, nvb = int(rng.integers(1, 5)), int(rng.integers(1, 5))
        nea, neb = int(rng.integers(1, 4)), int(rng.integers(1, 4))
        window = None if rng.random() < 0.5 else int(rng.integers(3, 25))
        args = rand_case(rng, ca, cb, nva, nvb, nea, neb, window)
        want = compat_mask_ref(*args[:6], args[6], args[7], args[8])
        got = cj_ops.compat_mask(*args[:6], args[6], args[7], args[8],
                                 interpret=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_engine_with_pallas_backend_matches_ref_backend():
    """Full engine equivalence with the Pallas join (interpret mode)."""
    q = QueryGraph(3, (0, 1, 0), ((0, 1), (1, 2)), prec=frozenset({(0, 1)}))
    stream = synth_traffic_stream(StreamConfig(
        n_edges=120, n_vertices=10, n_vertex_labels=2, n_edge_labels=2,
        seed=3, ts_step_max=2))
    window = 18
    finals = []
    for backend in (JoinBackend.REF, JoinBackend.PALLAS_INTERPRET):
        plan = compile_plan(q, window, level_capacity=512, max_new=256)
        tick = jax.jit(build_tick(plan, backend=backend))
        state = init_state(plan)
        for b in to_batches(stream, 16):
            state, _ = tick(state, make_batch(**b))
        finals.append((current_matches(plan, state),
                       int(state.stats.n_matches_total)))
    assert finals[0] == finals[1]
