"""grok-1-314b [hf:xai-org/grok-1; unverified] — MoE 8 experts top-2.

64L, d_model=6144, 48 q-heads (GQA kv=8), expert d_ff=32768, vocab=131072.
Only 8 experts: TP shards each expert's d_ff (expert_shard='ffn') instead
of the expert dim.
"""

import jax.numpy as jnp

from repro.configs import registry as R
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="grok-1-314b",
    n_layers=64,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab=131072,
    moe=True,
    n_experts=8,
    moe_topk=2,
    expert_shard="ffn",          # 8 experts < 16-way TP: shard d_ff
    capacity_factor=1.25,
    rope_theta=1e4,
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,
    attn_chunk=1024,
    remat="full",
)

ARCH = R.ArchSpec(
    arch_id="grok-1-314b",
    family="lm",
    config=CONFIG,
    shapes=R.lm_shapes(microbatches_train=16),
    source="hf:xai-org/grok-1 (unverified)",
    notes="optimizer state_mode=int8; expert d_ff sharded over TP",
    opt_state_mode="int8",
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="grok-1-314b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=128, vocab=257, moe=True,
        n_experts=4, moe_topk=2, expert_shard="ffn",
        dtype=jnp.float32, param_dtype=jnp.float32, attn_chunk=32,
        remat="none")
