"""nequip [arXiv:2101.03164; paper] — O(3)-equivariant potential.

5 interaction layers, 32 channels, l_max=2, 8 radial Bessel functions,
cutoff 5 Å (Cartesian-tensor formulation; DESIGN.md §Adaptations).
"""

from repro.configs import registry as R
from repro.models.gnn.nequip import NequIPConfig

CONFIG = NequIPConfig(
    name="nequip",
    n_layers=5,
    channels=32,
    l_max=2,
    n_rbf=8,
    cutoff=5.0,
    n_species=16,
)

ARCH = R.ArchSpec(
    arch_id="nequip",
    family="nequip",
    config=CONFIG,
    shapes=R.gnn_shapes(),
    source="arXiv:2101.03164",
    notes="equivariance in Cartesian tensor basis (l<=2); positions for "
          "the non-molecular shapes are synthetic 3D embeddings",
)


def smoke_config() -> NequIPConfig:
    return NequIPConfig(name="nequip-smoke", n_layers=2, channels=8,
                        n_rbf=4, cutoff=5.0, n_species=4)
