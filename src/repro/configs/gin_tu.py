"""gin-tu [arXiv:1810.00826; paper] — 5 layers, 64 hidden, sum agg,
learnable eps."""

from repro.configs import registry as R
from repro.models.gnn.models import GNNConfig

CONFIG = GNNConfig(
    name="gin-tu",
    arch="gin",
    n_layers=5,
    d_in=64,
    d_hidden=64,
    n_classes=8,
    eps_learnable=True,
)

ARCH = R.ArchSpec(
    arch_id="gin-tu",
    family="gnn",
    config=CONFIG,
    shapes=R.gnn_shapes(),
    source="arXiv:1810.00826",
)


def smoke_config() -> GNNConfig:
    return GNNConfig(name="gin-smoke", arch="gin", n_layers=2, d_in=12,
                     d_hidden=16, n_classes=4)
