"""pna [arXiv:2004.05718; paper] — 4 layers, 75 hidden,
aggregators mean/max/min/std, scalers identity/amplification/attenuation."""

from repro.configs import registry as R
from repro.models.gnn.models import GNNConfig

CONFIG = GNNConfig(
    name="pna",
    arch="pna",
    n_layers=4,
    d_in=75,
    d_hidden=75,
    n_classes=10,
    aggregators=("mean", "max", "min", "std"),
    scalers=("identity", "amplification", "attenuation"),
)

ARCH = R.ArchSpec(
    arch_id="pna",
    family="gnn",
    config=CONFIG,
    shapes=R.gnn_shapes(),
    source="arXiv:2004.05718",
)


def smoke_config() -> GNNConfig:
    return GNNConfig(name="pna-smoke", arch="pna", n_layers=2, d_in=16,
                     d_hidden=12, n_classes=4)
