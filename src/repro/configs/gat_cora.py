"""gat-cora [arXiv:1710.10903; paper] — 2 layers, 8 hidden, 8 heads."""

from repro.configs import registry as R
from repro.models.gnn.models import GNNConfig

CONFIG = GNNConfig(
    name="gat-cora",
    arch="gat",
    n_layers=2,
    d_in=1433,
    d_hidden=8,
    n_heads=8,
    n_classes=7,
)

ARCH = R.ArchSpec(
    arch_id="gat-cora",
    family="gnn",
    config=CONFIG,
    shapes=R.gnn_shapes(),
    source="arXiv:1710.10903",
)


def smoke_config() -> GNNConfig:
    return GNNConfig(name="gat-smoke", arch="gat", n_layers=2, d_in=24,
                     d_hidden=8, n_heads=4, n_classes=5)
