"""Architecture registry: arch id -> config + per-shape cell definitions.

Each assigned architecture contributes an ``ArchSpec`` with its exact
published configuration and its shape set.  The dry-run iterates
``for arch in ARCHS: for shape in arch.shapes`` — 40 cells total.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Any


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str                    # train | prefill | decode | serve | retrieval
    global_batch: int = 1
    seq_len: int = 0
    microbatches: int = 1        # grad-accumulation splits (train)
    skip_reason: str | None = None   # e.g. long_500k on full-attention archs
    extra: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str                  # lm | gnn | nequip | recsys
    config: Any
    shapes: tuple[ShapeSpec, ...]
    source: str = ""
    notes: str = ""
    opt_state_mode: str = "fp32"   # fp32 | factored | int8 (AdamW memory)

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(name)


_MODULES = [
    "deepseek_coder_33b",
    "qwen3_14b",
    "internlm2_20b",
    "arctic_480b",
    "grok1_314b",
    "nequip",
    "gat_cora",
    "gin_tu",
    "pna",
    "wide_deep",
]

ARCHS: dict[str, ArchSpec] = {}


def _load():
    for m in _MODULES:
        mod = importlib.import_module(f"repro.configs.{m}")
        spec = mod.ARCH
        ARCHS[spec.arch_id] = spec


def get_arch(arch_id: str) -> ArchSpec:
    if not ARCHS:
        _load()
    return ARCHS[arch_id]


def lm_shapes(microbatches_train: int = 8) -> tuple[ShapeSpec, ...]:
    """The LM-family shape set (identical across the five LM archs)."""
    return (
        ShapeSpec("train_4k", "train", global_batch=256, seq_len=4096,
                  microbatches=microbatches_train),
        ShapeSpec("prefill_32k", "prefill", global_batch=32, seq_len=32768),
        ShapeSpec("decode_32k", "decode", global_batch=128, seq_len=32768),
        ShapeSpec(
            "long_500k", "decode", global_batch=1, seq_len=524288,
            skip_reason=(
                "pure full-attention arch: long-context shape requires "
                "sub-quadratic attention per the assignment spec (decode "
                "itself is O(S); we additionally report the cell as a "
                "non-required extra — see EXPERIMENTS.md §Dry-run)"),
        ),
    )


def gnn_shapes() -> tuple[ShapeSpec, ...]:
    return (
        ShapeSpec("full_graph_sm", "train",
                  extra=dict(n_nodes=2708, n_edges=10556, d_feat=1433,
                             n_classes=7)),
        ShapeSpec("minibatch_lg", "train",
                  extra=dict(n_nodes=232_965, n_edges=114_615_892,
                             batch_nodes=1024, fanout=(15, 10), d_feat=602,
                             n_classes=41)),
        ShapeSpec("ogb_products", "train",
                  extra=dict(n_nodes=2_449_029, n_edges=61_859_140,
                             d_feat=100, n_classes=47)),
        ShapeSpec("molecule", "train",
                  extra=dict(n_nodes=30, n_edges=64, batch=128,
                             d_feat=16, n_classes=8)),
    )


def recsys_shapes() -> tuple[ShapeSpec, ...]:
    return (
        ShapeSpec("train_batch", "train", global_batch=65536),
        ShapeSpec("serve_p99", "serve", global_batch=512),
        ShapeSpec("serve_bulk", "serve", global_batch=262_144),
        ShapeSpec("retrieval_cand", "retrieval", global_batch=1,
                  extra=dict(n_candidates=1_000_000)),
    )


# populate the registry once all helpers above exist (arch modules import
# this module back, so loading must be the final statement)
_load()
