"""deepseek-coder-33b [arXiv:2401.14196; hf] — dense llama-arch.

62L, d_model=7168, 56 q-heads (GQA kv=8), d_ff=19200, vocab=32256.
"""

import jax.numpy as jnp

from repro.configs import registry as R
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="deepseek-coder-33b",
    n_layers=62,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=19200,
    vocab=32256,
    rope_theta=1e5,
    dtype=jnp.bfloat16,
    param_dtype=jnp.float32,
    attn_chunk=2048,
    remat="full",
)

ARCH = R.ArchSpec(
    arch_id="deepseek-coder-33b",
    family="lm",
    config=CONFIG,
    shapes=R.lm_shapes(microbatches_train=8),
    source="arXiv:2401.14196; hf",
    notes="dense llama-arch; fp32 master + fp32 Adam state fits at 33B",
)


def smoke_config() -> LMConfig:
    """Reduced same-family config for CPU smoke tests."""
    return LMConfig(
        name="deepseek-coder-33b-smoke", n_layers=2, d_model=128,
        n_heads=8, n_kv_heads=2, head_dim=16, d_ff=256, vocab=311,
        rope_theta=1e5, dtype=jnp.float32, attn_chunk=64, remat="none")
