"""arctic-480b [hf:Snowflake/snowflake-arctic-base; hf] — dense+MoE hybrid.

35L, d_model=7168, 56 q-heads (GQA kv=8), MoE 128 experts top-2 with
d_ff=4864 per expert, PLUS a dense residual FFN in parallel, vocab=32000.

Memory note: 468B params -> int8 first moment + factored second moment +
bf16 params (~3 B/param optimizer+weights) to fit a 256-chip pod.
"""

import jax.numpy as jnp

from repro.configs import registry as R
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="arctic-480b",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab=32000,
    moe=True,
    n_experts=128,
    moe_topk=2,
    dense_residual=True,
    residual_d_ff=4864,
    expert_shard="expert",       # 128 experts / 16-way TP = 8 per shard
    capacity_factor=1.25,
    rope_theta=1e4,
    dtype=jnp.bfloat16,
    param_dtype=jnp.bfloat16,    # memory: bf16 weights + int8/factored Adam
    attn_chunk=1024,
    remat="full",
)

ARCH = R.ArchSpec(
    arch_id="arctic-480b",
    family="lm",
    config=CONFIG,
    shapes=R.lm_shapes(microbatches_train=16),
    source="hf:Snowflake/snowflake-arctic-base",
    notes="128e top-2 + dense residual; optimizer state_mode=int8",
    opt_state_mode="int8",
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="arctic-480b-smoke", n_layers=2, d_model=64, n_heads=4,
        n_kv_heads=2, head_dim=16, d_ff=96, vocab=211, moe=True,
        n_experts=8, moe_topk=2, dense_residual=True, residual_d_ff=96,
        dtype=jnp.float32, param_dtype=jnp.float32, attn_chunk=32,
        remat="none")
