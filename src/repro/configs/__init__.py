"""Assigned architecture configs (one module per arch) + registry."""

from repro.configs.registry import ARCHS, ShapeSpec, ArchSpec, get_arch
