"""internlm2-20b [arXiv:2403.17297; hf] — dense GQA.

48L, d_model=6144, 48 q-heads (GQA kv=8), d_ff=16384, vocab=92544.
"""

import jax.numpy as jnp

from repro.configs import registry as R
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="internlm2-20b",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab=92544,
    rope_theta=1e6,
    dtype=jnp.bfloat16,
    param_dtype=jnp.float32,
    attn_chunk=2048,
    remat="full",
)

ARCH = R.ArchSpec(
    arch_id="internlm2-20b",
    family="lm",
    config=CONFIG,
    shapes=R.lm_shapes(microbatches_train=8),
    source="arXiv:2403.17297; hf",
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="internlm2-20b-smoke", n_layers=2, d_model=96, n_heads=6,
        n_kv_heads=2, head_dim=16, d_ff=192, vocab=409,
        dtype=jnp.float32, attn_chunk=32, remat="none")
