"""wide-deep [arXiv:1606.07792; paper] — 40 sparse fields, embed 32,
MLP 1024-512-256, concat interaction."""

from repro.configs import registry as R
from repro.models.recsys.wide_deep import WideDeepConfig

CONFIG = WideDeepConfig(
    name="wide-deep",
    n_sparse=40,
    vocab_per_field=1_000_000,   # criteo-scale rows per field
    embed_dim=32,
    n_dense=13,
    mlp=(1024, 512, 256),
    wide_vocab=4_000_000,
    n_wide_crosses=16,
)

ARCH = R.ArchSpec(
    arch_id="wide-deep",
    family="recsys",
    config=CONFIG,
    shapes=R.recsys_shapes(),
    source="arXiv:1606.07792",
    notes="embedding tables row-sharded over the TP axis; retrieval shape "
          "scores one query against 1M candidates via sharded matvec+topk",
)


def smoke_config() -> WideDeepConfig:
    return WideDeepConfig(
        name="wide-deep-smoke", n_sparse=6, vocab_per_field=100,
        embed_dim=8, n_dense=4, mlp=(32, 16), wide_vocab=200,
        n_wide_crosses=4)
