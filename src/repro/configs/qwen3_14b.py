"""qwen3-14b [hf:Qwen/Qwen3-8B family; hf] — dense, qk_norm + GQA.

40L, d_model=5120, 40 q-heads (GQA kv=8), d_ff=17408, vocab=151936.
"""

import jax.numpy as jnp

from repro.configs import registry as R
from repro.models.transformer import LMConfig

CONFIG = LMConfig(
    name="qwen3-14b",
    n_layers=40,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=17408,
    vocab=151936,
    qk_norm=True,
    rope_theta=1e6,
    dtype=jnp.bfloat16,
    param_dtype=jnp.float32,
    attn_chunk=2048,
    remat="full",
)

ARCH = R.ArchSpec(
    arch_id="qwen3-14b",
    family="lm",
    config=CONFIG,
    shapes=R.lm_shapes(microbatches_train=4),
    source="hf:Qwen/Qwen3-8B",
    notes="qk_norm on per-head dims; large vocab (152k) -> vocab-sharded "
          "logits dominate the LM head",
)


def smoke_config() -> LMConfig:
    return LMConfig(
        name="qwen3-14b-smoke", n_layers=2, d_model=96, n_heads=4,
        n_kv_heads=2, head_dim=24, d_ff=192, vocab=509, qk_norm=True,
        rope_theta=1e6, dtype=jnp.float32, attn_chunk=32, remat="none")
