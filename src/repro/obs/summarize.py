"""Trace-summary CLI: ``python -m repro.obs summarize trace.jsonl``.

Reads a span-JSONL trace written by :class:`repro.obs.Tracer` and
prints a per-span-name table (count, total ms, mean, exact p50/p99 via
the shared nearest-rank helper) plus per-tick aggregates (ticks seen,
mean spans per tick, worst tick by total ms).  Returns the summary as a
dict so tests can round-trip it.
"""

from __future__ import annotations

import json
import sys
from collections import defaultdict

from .metrics import percentile

__all__ = ["summarize_trace", "format_summary", "main"]


def summarize_trace(path_or_lines) -> dict:
    """Aggregate a JSONL trace. Accepts a path or an iterable of lines."""
    if isinstance(path_or_lines, str):
        with open(path_or_lines) as fh:
            lines = fh.readlines()
    else:
        lines = list(path_or_lines)

    by_span: dict[str, list[float]] = defaultdict(list)
    by_tick: dict[int, float] = defaultdict(float)
    n_bad = 0
    for ln in lines:
        ln = ln.strip()
        if not ln:
            continue
        try:
            rec = json.loads(ln)
            name, ms = rec["span"], float(rec["ms"])
        except (ValueError, KeyError):
            n_bad += 1
            continue
        by_span[name].append(ms)
        by_tick[int(rec.get("tick", 0))] += ms

    spans = {
        name: {
            "count": len(ms),
            "total_ms": round(sum(ms), 4),
            "mean_ms": round(sum(ms) / len(ms), 4),
            "p50_ms": round(percentile(ms, 0.50), 4),
            "p99_ms": round(percentile(ms, 0.99), 4),
        }
        for name, ms in sorted(by_span.items())
    }
    worst = max(by_tick.items(), key=lambda kv: kv[1], default=(0, 0.0))
    return {
        "n_spans": sum(s["count"] for s in spans.values()),
        "n_ticks": len(by_tick),
        "n_bad_lines": n_bad,
        "spans": spans,
        "worst_tick": {"tick": worst[0], "total_ms": round(worst[1], 4)},
    }


def format_summary(summary: dict) -> str:
    w = max([len(n) for n in summary["spans"]] + [4])
    out = [f"{'span':<{w}}  {'count':>7} {'total_ms':>10} "
           f"{'mean_ms':>9} {'p50_ms':>8} {'p99_ms':>8}"]
    for name, s in summary["spans"].items():
        out.append(f"{name:<{w}}  {s['count']:>7} {s['total_ms']:>10.3f} "
                   f"{s['mean_ms']:>9.4f} {s['p50_ms']:>8.4f} "
                   f"{s['p99_ms']:>8.4f}")
    out.append(f"-- {summary['n_spans']} spans over {summary['n_ticks']} "
               f"ticks; worst tick #{summary['worst_tick']['tick']} "
               f"({summary['worst_tick']['total_ms']}ms)")
    if summary["n_bad_lines"]:
        out.append(f"-- WARNING: {summary['n_bad_lines']} unparseable lines")
    return "\n".join(out)


def main(argv: list[str] | None = None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 2 or argv[0] != "summarize":
        print("usage: python -m repro.obs summarize <trace.jsonl>",
              file=sys.stderr)
        return 2
    summary = summarize_trace(argv[1])
    print(format_summary(summary))
    return 0
