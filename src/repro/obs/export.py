"""Exporters: Prometheus-style text snapshot of a MetricsRegistry."""

from __future__ import annotations

from .metrics import MetricsRegistry

__all__ = ["to_prometheus"]


def _sanitize(name: str) -> str:
    """``ingest.n_late_dropped`` -> ``repro_ingest_n_late_dropped``."""
    return "repro_" + name.replace(".", "_").replace("-", "_")


def to_prometheus(reg: MetricsRegistry) -> str:
    """Render the registry in the Prometheus text exposition format.

    Counters/gauges become single samples; histograms become the
    standard ``_bucket{le=...}`` / ``_sum`` / ``_count`` triple plus
    exact-quantile gauges (``quantile="0.5"|"0.99"``) while the sample
    ring still holds every observation.
    """
    lines: list[str] = []
    snap_hists = reg.histograms()
    for name, c in sorted(reg.counters().items()):
        m = _sanitize(name)
        lines.append(f"# TYPE {m} counter")
        lines.append(f"{m} {c.value}")
    flat = reg.snapshot()
    hist_derived = {f"{n}{suffix}" for n in snap_hists
                    for suffix in (".count", ".mean", ".p50", ".p99")}
    for name in sorted(flat):
        if name in reg.counters() or name in hist_derived:
            continue
        m = _sanitize(name)
        lines.append(f"# TYPE {m} gauge")
        lines.append(f"{m} {flat[name]}")
    for name, h in sorted(snap_hists.items()):
        m = _sanitize(name)
        lines.append(f"# TYPE {m} histogram")
        cum = 0
        for ub, n in zip(h.buckets, h.counts):
            cum += int(n)
            lines.append(f'{m}_bucket{{le="{ub}"}} {cum}')
        lines.append(f'{m}_bucket{{le="+Inf"}} {h.count}')
        lines.append(f"{m}_sum {round(h.total, 6)}")
        lines.append(f"{m}_count {h.count}")
        for q in (0.5, 0.99):
            lines.append(f'{m}{{quantile="{q}"}} {round(h.quantile(q), 6)}')
    return "\n".join(lines) + "\n"
