"""``python -m repro.obs summarize trace.jsonl`` entry point."""

import sys

from .summarize import main

sys.exit(main())
