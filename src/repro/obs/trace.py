"""Structured host-side tracing: span timers emitting a JSONL trace.

A :class:`Tracer` wraps serve-loop stages (frontier poll, watermark
release, coalescer decision, slot tick incl. device sync, forest node
tick, checkpoint publish, mesh collectives) in wall-clock span timers
and appends one JSON object per span to a file::

    {"tick": 17, "span": "tick.slot", "ms": 0.42,
     "t0": 1723190400.123, "gid": 0}

``tick`` is the per-tick correlation id — every span recorded between
two ``next_tick()`` calls shares it, so the summarize CLI can
reconstruct where each tick's time went across layers.

Tracing is OFF by default and the serve loop guards every call site
with ``if tracer is not None``: when disabled, zero span objects are
allocated and zero clock reads happen.  All of this runs strictly
OUTSIDE traced/jitted code (the AST linter's TRC107 rule proves it);
a span's body may *contain* a device sync, but the timer itself is
host-only Python.
"""

from __future__ import annotations

import io
import json
import time
from typing import IO

__all__ = ["Tracer", "Span"]


class Span:
    """One timed stage.  Use via ``with tracer.span("tick.slot"): ...``."""

    __slots__ = ("tracer", "name", "fields", "t0")

    def __init__(self, tracer: "Tracer", name: str, fields: dict):
        self.tracer = tracer
        self.name = name
        self.fields = fields
        self.t0 = 0.0

    def __enter__(self) -> "Span":
        self.t0 = time.perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        ms = (time.perf_counter() - self.t0) * 1e3
        self.tracer._emit(self.name, ms, self.fields)


class Tracer:
    """JSONL span emitter with per-tick correlation ids.

    ``sink`` is a path or an open text file.  Writes are buffered by the
    underlying file object; call :meth:`flush`/:meth:`close` (the
    service does on checkpoint and shutdown) before reading the file.
    """

    def __init__(self, sink: str | IO[str]):
        if isinstance(sink, (str, bytes)):
            self._fh: IO[str] = open(sink, "w")
            self._owns = True
        else:
            self._fh = sink
            self._owns = False
        self.tick = 0
        self.n_spans = 0

    # ----------------------------------------------------------- #
    def next_tick(self) -> int:
        """Advance the correlation id; returns the new tick id."""
        self.tick += 1
        return self.tick

    def span(self, name: str, **fields) -> Span:
        return Span(self, name, fields)

    def record(self, name: str, ms: float, **fields) -> None:
        """Post-hoc span: the serve loop times stages with bare
        ``perf_counter`` reads and reports them here, so the tracer-off
        path needs no Span objects (and no allocation) at all."""
        self._emit(name, ms, fields)

    def event(self, name: str, **fields) -> None:
        """Zero-duration marker (e.g. ``coalescer.decision``)."""
        self._emit(name, 0.0, fields)

    def _emit(self, name: str, ms: float, fields: dict) -> None:
        rec = {"tick": self.tick, "span": name, "ms": round(ms, 4),
               "t0": round(time.time(), 3)}
        if fields:
            rec.update(fields)
        self._fh.write(json.dumps(rec) + "\n")
        self.n_spans += 1

    # ----------------------------------------------------------- #
    def flush(self) -> None:
        self._fh.flush()

    def close(self) -> None:
        self.flush()
        if self._owns:
            self._fh.close()

    def __enter__(self) -> "Tracer":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def memory_tracer() -> tuple[Tracer, io.StringIO]:
    """In-memory tracer for tests: (tracer, its StringIO buffer)."""
    buf = io.StringIO()
    return Tracer(buf), buf
