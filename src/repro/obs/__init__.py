"""repro.obs — unified metrics + tracing for the serve loop.

* :class:`MetricsRegistry` / :class:`Counter` / :class:`Gauge` /
  :class:`Histogram`: the namespaced instrument registry every stat
  surface (ingest, coalescer, tick, share, ckpt, mesh) reports into.
* :class:`Tracer`: host-side JSONL span timers with per-tick
  correlation ids — strictly outside traced/jitted code.
* :func:`to_prometheus`: text exposition snapshot.
* :func:`summarize_trace`: the ``python -m repro.obs summarize`` CLI.

See README "Observability" for the metric-name reference table.
"""

from .export import to_prometheus
from .metrics import (
    DEFAULT_LATENCY_BUCKETS_MS,
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    percentile,
)
from .summarize import format_summary, summarize_trace
from .trace import Span, Tracer, memory_tracer

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "percentile",
    "DEFAULT_LATENCY_BUCKETS_MS",
    "Tracer",
    "Span",
    "memory_tracer",
    "to_prometheus",
    "summarize_trace",
    "format_summary",
]
