"""Low-overhead metrics registry: counters, gauges, latency histograms.

One process-local :class:`MetricsRegistry` unifies every stat surface in
the repo (``ServeInfo``, ``EngineStats``, ``SessionStatus``,
``forest_stats()``, ``MeshTickStats``) under a namespaced scheme::

    ingest.*     frontier counters, watermark lag
    coalescer.*  AIMD batch decisions
    tick.*       slot-tick latency, matches, overflow
    share.*      prefix-forest shape
    ckpt.*       checkpoint publish latency, async stall
    mesh.*       per-replica load / pressure

Design constraints (the tentpole's "provably free" bar):

* Instruments are plain Python attribute bumps — ``Counter.inc`` is one
  int add, ``Gauge.set`` one float store.  Nothing here touches jax.
* :class:`Histogram` pre-allocates a fixed numpy sample ring at
  construction, so ``observe()`` never allocates on the hot path.  It
  keeps BOTH fixed log-scale bucket counts (Prometheus export) and the
  raw ring: percentiles are EXACT (nearest-rank over the retained
  samples) while fewer than ``ring_size`` observations have been made —
  the regime every test and benchmark here runs in — and fall back to
  bucket upper bounds beyond that.
* Expensive surfaces (forest stats, replica load) register *callback
  gauges*: a zero-cost function pointer evaluated only at snapshot
  time, never on the serve loop.

Counters and histograms survive checkpoint/restore via
``to_manifest``/``load_manifest`` (bucket counts and total counts ride
along; the raw ring does not — percentiles after a restore re-fill from
live traffic, which is the honest reading anyway).
"""

from __future__ import annotations

import math
import threading
from typing import Callable, Iterable, Mapping, Sequence

import numpy as np

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "percentile",
    "DEFAULT_LATENCY_BUCKETS_MS",
]


def percentile(samples: Sequence[float], q: float) -> float:
    """Nearest-rank percentile, the repo-wide formula.

    This is byte-for-byte the math the benches used inline before the
    obs layer existed (``sorted(x)[min(len-1, int(q*len))]``), kept as
    THE shared helper so every surface reports identical numbers.
    """
    if not 0.0 <= q <= 1.0:
        raise ValueError(f"quantile must be in [0, 1], got {q}")
    n = len(samples)
    if n == 0:
        return 0.0
    srt = sorted(samples)
    return float(srt[min(n - 1, int(q * n))])


# log-spaced upper bounds, 10us .. ~100s — fine enough that a bucket
# fallback is within ~2x of truth anywhere on the serve loop
DEFAULT_LATENCY_BUCKETS_MS: tuple[float, ...] = tuple(
    round(10 ** (e / 4), 4) for e in range(-8, 21)
)


class Counter:
    """Monotonic counter.  ``inc`` is one int add — safe on the serve
    loop at any frequency."""

    __slots__ = ("name", "value")

    def __init__(self, name: str, value: int = 0):
        self.name = name
        self.value = int(value)

    def inc(self, n: int = 1) -> None:
        self.value += n

    def set_total(self, total: int) -> None:
        """Jump to an absolute total (mirroring an external counter).

        Monotone by construction: regressions (e.g. a source object
        replaced mid-run) are ignored rather than double-counted.
        """
        if total > self.value:
            self.value = total


class Gauge:
    """Point-in-time value; last write wins."""

    __slots__ = ("name", "value")

    def __init__(self, name: str, value: float = 0.0):
        self.name = name
        self.value = float(value)

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Fixed-bucket latency histogram with an exact-percentile ring.

    ``observe`` cost: one searchsorted over a small fixed array plus two
    stores — no allocation (the ring and bucket counts are pre-allocated
    at construction).
    """

    __slots__ = ("name", "buckets", "counts", "count", "total",
                 "_ring", "_ring_n")

    def __init__(self, name: str,
                 buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_MS,
                 ring_size: int = 4096):
        self.name = name
        self.buckets = np.asarray(buckets, dtype=np.float64)
        if not np.all(np.diff(self.buckets) > 0):
            raise ValueError(f"{name}: bucket bounds must be increasing")
        # counts[i] = observations <= buckets[i]; counts[-1] = +Inf bucket
        self.counts = np.zeros(len(self.buckets) + 1, dtype=np.int64)
        self.count = 0
        self.total = 0.0
        self._ring = np.zeros(ring_size, dtype=np.float64)
        self._ring_n = 0

    def observe(self, v: float) -> None:
        i = int(np.searchsorted(self.buckets, v, side="left"))
        self.counts[i] += 1
        self.count += 1
        self.total += v
        ring = self._ring
        ring[self._ring_n % len(ring)] = v
        self._ring_n += 1

    # ----------------------------------------------------------- #
    def samples(self) -> np.ndarray:
        """Raw retained samples (ring order is irrelevant for ranks)."""
        n = min(self._ring_n, len(self._ring))
        return self._ring[:n]

    def quantile(self, q: float) -> float:
        """Exact nearest-rank percentile while the ring holds every
        observation; bucket-upper-bound estimate once samples have been
        evicted (``count > ring_size``)."""
        if self.count == 0:
            return 0.0
        if self.exact:
            return percentile(self.samples().tolist(), q)
        # bucket fallback: smallest upper bound covering rank
        rank = min(self.count - 1, int(q * self.count))
        cum = np.cumsum(self.counts)
        i = int(np.searchsorted(cum, rank + 1, side="left"))
        if i >= len(self.buckets):
            return float(self.buckets[-1])
        return float(self.buckets[i])

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    @property
    def exact(self) -> bool:
        """True while the ring still holds EVERY observation — no
        eviction, no restored bucket-only history."""
        return self._ring_n == self.count and self._ring_n <= len(self._ring)


class MetricsRegistry:
    """Create-or-get instrument registry with callback gauges.

    Thread-safe for instrument *creation* (benches and the async
    checkpointer may race); instrument *updates* are GIL-atomic plain
    stores by design.
    """

    def __init__(self):
        self._lock = threading.Lock()
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._hists: dict[str, Histogram] = {}
        self._callbacks: dict[str, Callable[[], float]] = {}

    # ------------------------------------------------ instruments -- #
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            with self._lock:
                c = self._counters.setdefault(name, Counter(name))
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            with self._lock:
                g = self._gauges.setdefault(name, Gauge(name))
        return g

    def histogram(self, name: str,
                  buckets: Sequence[float] = DEFAULT_LATENCY_BUCKETS_MS,
                  ring_size: int = 4096) -> Histogram:
        h = self._hists.get(name)
        if h is None:
            with self._lock:
                h = self._hists.setdefault(
                    name, Histogram(name, buckets, ring_size))
        return h

    def register_gauge(self, name: str, fn: Callable[[], float]) -> None:
        """Collect-time callback gauge: ``fn`` runs only at snapshot,
        never on the serve loop.  Re-registration replaces (restore)."""
        with self._lock:
            self._callbacks[name] = fn

    # -------------------------------------------------- snapshot -- #
    def snapshot(self) -> dict[str, float]:
        """Flat name -> value view: counters, gauges (incl. callbacks),
        and per-histogram count/mean/p50/p99 derived series."""
        out: dict[str, float] = {}
        for n, c in sorted(self._counters.items()):
            out[n] = c.value
        for n, g in sorted(self._gauges.items()):
            out[n] = g.value
        for n, fn in sorted(self._callbacks.items()):
            try:
                out[n] = float(fn())
            except Exception:
                out[n] = math.nan
        for n, h in sorted(self._hists.items()):
            out[f"{n}.count"] = h.count
            out[f"{n}.mean"] = h.mean
            out[f"{n}.p50"] = h.quantile(0.50)
            out[f"{n}.p99"] = h.quantile(0.99)
        return out

    def counters(self) -> Mapping[str, Counter]:
        return self._counters

    def histograms(self) -> Mapping[str, Histogram]:
        return self._hists

    # ------------------------------------------ checkpoint support -- #
    def to_manifest(self) -> dict:
        return {
            "counters": {n: c.value for n, c in self._counters.items()},
            "hists": {
                n: {
                    "buckets": h.buckets.tolist(),
                    "counts": h.counts.tolist(),
                    "count": h.count,
                    "total": h.total,
                }
                for n, h in self._hists.items()
            },
        }

    def load_manifest(self, man: Mapping) -> None:
        for n, v in man.get("counters", {}).items():
            self.counter(n).set_total(int(v))
        for n, hm in man.get("hists", {}).items():
            h = self.histogram(n, buckets=hm["buckets"])
            if h.count == 0:          # fresh instrument: adopt history
                h.counts = np.asarray(hm["counts"], dtype=np.int64)
                h.count = int(hm["count"])
                h.total = float(hm["total"])
                # bucket history arrives without raw samples, so the
                # ring no longer holds every observation: quantiles
                # fall back to bucket bounds (h.exact stays False)
