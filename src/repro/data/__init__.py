"""Data pipelines: deterministic synthetic sources for LM, GNN, recsys.

Every source is a pure function of (config, step) so fault-tolerant
replay after restart reproduces the exact same batches — the property
the recovery tests assert.
"""

from repro.data.lm import lm_batch
from repro.data.graphs import synth_cora_like, synth_products_like
from repro.data.recsys import recsys_batch
