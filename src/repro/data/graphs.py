"""Synthetic graph datasets shaped like the assigned GNN cells."""

from __future__ import annotations

import numpy as np


def synth_cora_like(n_nodes=2708, n_edges=10556, d_feat=1433, n_classes=7,
                    seed=0):
    """Citation-style graph: homophilous labels, sparse binary features."""
    rng = np.random.default_rng(seed)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    # homophilous edges: 70% same-class endpoints
    src = rng.integers(0, n_nodes, n_edges)
    dst = np.empty(n_edges, np.int64)
    same = rng.random(n_edges) < 0.7
    by_class = [np.nonzero(labels == c)[0] for c in range(n_classes)]
    for i in range(n_edges):
        if same[i] and len(by_class[labels[src[i]]]) > 0:
            dst[i] = rng.choice(by_class[labels[src[i]]])
        else:
            dst[i] = rng.integers(0, n_nodes)
    x = (rng.random((n_nodes, d_feat)) < 0.015).astype(np.float32)
    # class-correlated feature block
    for c in range(n_classes):
        cols = slice(c * 10, c * 10 + 10)
        x[labels == c, cols] += (
            rng.random((int((labels == c).sum()), 10)) < 0.3)
    return {
        "x": x, "edge_src": src.astype(np.int32),
        "edge_dst": dst.astype(np.int32), "labels": labels,
    }


def synth_products_like(n_nodes=100_000, avg_degree=25, d_feat=100,
                        n_classes=47, seed=0):
    """Power-law co-purchase-style graph (scaled-down ogbn-products)."""
    rng = np.random.default_rng(seed)
    n_edges = n_nodes * avg_degree
    pop = (rng.pareto(1.2, n_nodes) + 1)
    p = pop / pop.sum()
    src = rng.choice(n_nodes, n_edges, p=p).astype(np.int32)
    dst = rng.choice(n_nodes, n_edges, p=p).astype(np.int32)
    labels = rng.integers(0, n_classes, n_nodes).astype(np.int32)
    x = rng.standard_normal((n_nodes, d_feat)).astype(np.float32)
    x += np.eye(n_classes, d_feat, dtype=np.float32)[labels] * 2.0
    return {"x": x, "edge_src": src, "edge_dst": dst, "labels": labels}
