"""Synthetic LM token stream: Zipfian unigrams with planted bigram
structure (so a learning model's loss visibly drops below unigram
entropy within a few hundred steps — used by examples/train_lm.py)."""

from __future__ import annotations

import numpy as np


def lm_batch(step: int, batch: int, seq: int, vocab: int, seed: int = 0):
    """Deterministic [batch, seq] int32 tokens for a given step."""
    rng = np.random.default_rng(seed * 1_000_003 + step)
    ranks = np.arange(1, vocab + 1, dtype=np.float64)
    p = ranks ** -1.1
    p /= p.sum()
    toks = rng.choice(vocab, size=(batch, seq), p=p).astype(np.int32)
    # planted structure: token t is followed by (t*7+3)%vocab 50% of the time
    mask = rng.random((batch, seq - 1)) < 0.5
    nxt = (toks[:, :-1] * 7 + 3) % vocab
    toks[:, 1:] = np.where(mask, nxt, toks[:, 1:])
    return toks
