"""Synthetic CTR batches with a planted click signal."""

from __future__ import annotations

import numpy as np


def recsys_batch(step: int, batch: int, n_sparse: int, vocab: int,
                 n_dense: int, n_crosses: int, seed: int = 0):
    rng = np.random.default_rng(seed * 7_000_003 + step)
    sparse = rng.integers(0, vocab, (batch, n_sparse)).astype(np.int32)
    dense = rng.standard_normal((batch, n_dense)).astype(np.float32)
    wide = rng.integers(0, 2 * vocab, (batch, n_crosses)).astype(np.int32)
    wide[rng.random(wide.shape) < 0.25] = -1
    # planted signal: click prob depends on parity of first sparse field
    logit = (sparse[:, 0] % 2) * 1.5 - 0.75 + 0.3 * dense[:, 0]
    labels = (rng.random(batch) < 1 / (1 + np.exp(-logit))).astype(np.int32)
    return {"sparse_ids": sparse, "dense": dense, "wide_ids": wide,
            "labels": labels}
