"""Decoder-only LM family: dense and MoE, GQA + RoPE (+ qk-norm),
SwiGLU, scan-over-layers with configurable remat.

Covers the five assigned LM architectures (deepseek-coder-33b, qwen3-14b,
internlm2-20b, arctic-480b, grok-1-314b) through one config dataclass.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.attention import attention_block
from repro.models.common import constrain, dense_init, rms_norm
from repro.models.moe import moe_ffn


@dataclasses.dataclass(frozen=True)
class LMConfig:
    name: str = "lm"
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 2
    head_dim: int = 64
    d_ff: int = 512
    vocab: int = 1024
    # MoE
    moe: bool = False
    n_experts: int = 8
    moe_topk: int = 2
    moe_renorm: bool = True
    capacity_factor: float = 1.25
    dense_residual: bool = False     # Arctic: dense FFN in parallel with MoE
    residual_d_ff: int = 0           # width of that dense branch
    moe_lb_coef: float = 0.01
    moe_z_coef: float = 1e-3
    expert_shard: str = "expert"     # 'expert' | 'ffn' (TP axis placement)
    # attention
    qk_norm: bool = False
    rope_theta: float = 1e4
    attn_chunk: int = 1024
    attn_window: int | None = None   # sliding-window attention
    # numerics / memory
    dtype: Any = jnp.bfloat16
    param_dtype: Any = jnp.float32
    remat: str = "full"              # 'full' | 'none'
    z_loss: float = 1e-4
    tie_embeddings: bool = False

    @property
    def kv_cache_shape(self):
        return (self.n_layers, None, None, self.n_kv_heads, self.head_dim)


# --------------------------------------------------------------------- #
# Init
# --------------------------------------------------------------------- #
def _init_layer(rng, cfg: LMConfig):
    ks = jax.random.split(rng, 12)
    d, hq, hkv, hd, f = (cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
                         cfg.head_dim, cfg.d_ff)
    pd = cfg.param_dtype
    p = {
        "ln1": jnp.ones((d,), pd),
        "ln2": jnp.ones((d,), pd),
        "attn": {
            "wq": dense_init(ks[0], (d, hq * hd), 0, pd),
            "wk": dense_init(ks[1], (d, hkv * hd), 0, pd),
            "wv": dense_init(ks[2], (d, hkv * hd), 0, pd),
            "wo": dense_init(ks[3], (hq * hd, d), 0, pd)
            / (2 * cfg.n_layers) ** 0.5,
        },
    }
    if cfg.qk_norm:
        p["attn"]["q_norm"] = jnp.ones((hd,), pd)
        p["attn"]["k_norm"] = jnp.ones((hd,), pd)
    if cfg.moe:
        p["moe"] = {
            "wg": dense_init(ks[4], (d, cfg.n_experts), 0, pd),
            "w1": dense_init(ks[5], (cfg.n_experts, d, f), 1, pd),
            "w3": dense_init(ks[6], (cfg.n_experts, d, f), 1, pd),
            "w2": dense_init(ks[7], (cfg.n_experts, f, d), 1, pd),
        }
        if cfg.dense_residual:
            rf = cfg.residual_d_ff or f
            p["ffn"] = {
                "w1": dense_init(ks[8], (d, rf), 0, pd),
                "w3": dense_init(ks[9], (d, rf), 0, pd),
                "w2": dense_init(ks[10], (rf, d), 0, pd),
            }
    else:
        p["ffn"] = {
            "w1": dense_init(ks[8], (d, f), 0, pd),
            "w3": dense_init(ks[9], (d, f), 0, pd),
            "w2": dense_init(ks[10], (f, d), 0, pd),
        }
    return p


def init(rng, cfg: LMConfig):
    k_emb, k_head, k_layers = jax.random.split(rng, 3)
    layers = jax.vmap(lambda r: _init_layer(r, cfg))(
        jax.random.split(k_layers, cfg.n_layers))
    params = {
        "embed": dense_init(k_emb, (cfg.vocab, cfg.d_model), 1,
                            cfg.param_dtype),
        "final_norm": jnp.ones((cfg.d_model,), cfg.param_dtype),
        "layers": layers,
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(
            k_head, (cfg.d_model, cfg.vocab), 0, cfg.param_dtype)
    return params


# --------------------------------------------------------------------- #
# Forward
# --------------------------------------------------------------------- #
def _dense_ffn(x, p):
    h = jax.nn.silu(x @ p["w1"]) * (x @ p["w3"])
    return h @ p["w2"]


def _layer(x, lp, cfg: LMConfig, kv_cache=None, positions=None, axes=None):
    h, new_cache = attention_block(
        rms_norm(x, lp["ln1"]), lp["attn"], cfg,
        positions=positions, kv_cache=kv_cache, axes=axes)
    x = x + h
    xin = rms_norm(x, lp["ln2"])
    aux = jnp.zeros((), jnp.float32)
    if cfg.moe:
        b, s, d = xin.shape
        y, aux = moe_ffn(xin.reshape(b * s, d), lp["moe"], cfg, axes=axes)
        y = y.reshape(b, s, d)
        if cfg.dense_residual:
            y = y + _dense_ffn(xin, lp["ffn"])
    else:
        y = _dense_ffn(xin, lp["ffn"])
    return x + y, aux, new_cache


def forward(params, tokens, cfg: LMConfig, axes=None):
    """tokens [B, S] -> logits [B, S, V].

    ``axes`` (MeshAxes) inserts activation sharding constraints: batch
    over dp at every layer boundary, vocab over tp at the LM head.
    Without them GSPMD loses the dp sharding across the grad-accumulation
    reshape + layer scan and replicates activations (measured: 79 GB/dev
    -> fits after constraining; see EXPERIMENTS.md §Perf iteration 1).
    """
    x = jnp.take(params["embed"].astype(cfg.dtype), tokens, axis=0)
    x = constrain(x, axes, "dp", None, None)

    def body(x, lp):
        y, aux, _ = _layer(x, jax.tree.map(lambda a: a.astype(cfg.dtype), lp),
                           cfg, axes=axes)
        y = constrain(y, axes, "dp", None, None)
        return y, (aux,)

    layer_fn = body
    if cfg.remat == "full":
        layer_fn = jax.checkpoint(
            body, policy=jax.checkpoint_policies.nothing_saveable)
    x, (auxs,) = jax.lax.scan(layer_fn, x, params["layers"])
    x = rms_norm(x, params["final_norm"].astype(cfg.dtype))
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, head)
    logits = constrain(logits, axes, "dp", None, "tp")
    return logits, auxs.sum()


def loss_fn(params, tokens, cfg: LMConfig, axes=None):
    """Next-token cross entropy (+ router aux + z-loss)."""
    logits, aux = forward(params, tokens, cfg, axes)
    logits = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    ce = (lse - ll).mean()
    zl = cfg.z_loss * jnp.mean(lse ** 2)
    return ce + zl + aux, {"ce": ce, "aux": aux}


# --------------------------------------------------------------------- #
# Decode path
# --------------------------------------------------------------------- #
def serve_step(params, tokens, cache, cfg: LMConfig):
    """One decode step.

    tokens [B, 1]; cache = (k [L,B,S,Hkv,hd], v [...], length [B]).
    Returns (logits [B, V], new cache).
    """
    kc, vc, length = cache
    x = jnp.take(params["embed"].astype(cfg.dtype), tokens, axis=0)
    positions = length[:, None]

    def body(x, xs):
        lp, kl, vl = xs
        lp = jax.tree.map(lambda a: a.astype(cfg.dtype), lp)
        y, _, new_cache = _layer(
            x, lp, cfg, kv_cache=(kl, vl, length), positions=positions)
        nk, nv, _ = new_cache
        return y, (nk, nv)

    x, (nk, nv) = jax.lax.scan(body, x, (params["layers"], kc, vc))
    x = rms_norm(x, params["final_norm"].astype(cfg.dtype))
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.dtype)
    logits = jnp.einsum("bsd,dv->bsv", x[:, -1:], head)[:, 0]
    return logits, (nk, nv, length + tokens.shape[1])


def prefill(params, tokens, cfg: LMConfig, axes=None):
    """Serving prefill: one forward pass that captures the post-RoPE KV
    cache for every layer and returns only the last-position logits (the
    realistic prompt-processing step the dry-run lowers for the
    ``prefill_*`` shapes).

    Returns (logits [B, V], k [L,B,S,Hkv,hd], v [L,B,S,Hkv,hd]).
    """
    x = jnp.take(params["embed"].astype(cfg.dtype), tokens, axis=0)
    x = constrain(x, axes, "dp", None, None)

    def body(x, lp):
        lp = jax.tree.map(lambda a: a.astype(cfg.dtype), lp)
        h, (k, v, _) = attention_block(rms_norm(x, lp["ln1"]), lp["attn"],
                                       cfg, axes=axes)
        x = x + h
        xin = rms_norm(x, lp["ln2"])
        if cfg.moe:
            b, s, d = xin.shape
            y, _ = moe_ffn(xin.reshape(b * s, d), lp["moe"], cfg, axes=axes)
            y = y.reshape(b, s, d)
            if cfg.dense_residual:
                y = y + _dense_ffn(xin, lp["ffn"])
        else:
            y = _dense_ffn(xin, lp["ffn"])
        x = constrain(x + y, axes, "dp", None, None)
        return x, (k, v)

    x, (k_all, v_all) = jax.lax.scan(body, x, params["layers"])
    x = rms_norm(x[:, -1:], params["final_norm"].astype(cfg.dtype))
    head = (params["embed"].T if cfg.tie_embeddings
            else params["lm_head"]).astype(cfg.dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, head)[:, 0]
    return logits, k_all, v_all


# --------------------------------------------------------------------- #
# Sharding specs
# --------------------------------------------------------------------- #
def param_specs(cfg: LMConfig, axes) -> Any:
    """PartitionSpec pytree matching init()'s structure.

    fsdp = axes.fsdp (ZeRO-3 over data axes), tp = axes.tp.
    Layer-stacked params get a leading None for the scan dim.
    """
    fsdp, tp = axes.fsdp, axes.tp

    def L(*s):  # layer-stacked
        return P(None, *s)

    attn = {
        "wq": L(fsdp, tp),           # heads flattened: [d, Hq*hd]
        "wk": L(fsdp, tp),           # [d, Hkv*hd] (1024 divides fine)
        "wv": L(fsdp, tp),
        "wo": L(tp, fsdp),
    }
    if cfg.qk_norm:
        attn["q_norm"] = L(None)
        attn["k_norm"] = L(None)
    layer = {"ln1": L(None), "ln2": L(None), "attn": attn}
    dense_ffn = {"w1": L(fsdp, tp), "w3": L(fsdp, tp), "w2": L(tp, fsdp)}
    if cfg.moe:
        if cfg.expert_shard == "expert":
            layer["moe"] = {
                "wg": L(fsdp, None),
                "w1": L(tp, fsdp, None),
                "w3": L(tp, fsdp, None),
                "w2": L(tp, None, fsdp),
            }
        else:  # shard the ffn dim (few-expert models: grok)
            layer["moe"] = {
                "wg": L(fsdp, None),
                "w1": L(None, fsdp, tp),
                "w3": L(None, fsdp, tp),
                "w2": L(None, tp, fsdp),
            }
        if cfg.dense_residual:
            layer["ffn"] = dense_ffn
    else:
        layer["ffn"] = dense_ffn
    specs = {
        # vocab replicated over tp: keeps the token gather local (a
        # vocab-sharded gather triggers involuntary full remat in SPMD);
        # the d axis is FSDP-sharded so the table still scales.
        "embed": P(None, fsdp),
        "final_norm": P(None),
        "layers": layer,
    }
    if not cfg.tie_embeddings:
        specs["lm_head"] = P(fsdp, tp)
    return specs


def cache_specs(cfg: LMConfig, axes):
    """KV cache (k, v, length): batch over dp, seq over tp (flash-decode)."""
    dp, tp = axes.dp, axes.tp
    kv = P(None, dp, tp, None, None)
    return (kv, kv, P(dp))
