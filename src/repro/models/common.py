"""Shared model building blocks: norms, RoPE, init, sharding helpers."""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def rms_norm(x, scale, eps: float = 1e-6):
    """RMSNorm with f32 statistics WITHOUT materializing an f32 copy of x.

    The sum of squares accumulates in f32 through the einsum's
    preferred_element_type; x itself is only read in its own dtype.
    (A plain ``x.astype(f32)`` as the first op of a scanned layer body
    gets hoisted by XLA into an f32 copy of the whole remat carry stack
    — +14.6 GB/device at deepseek-33b scale; EXPERIMENTS.md §Perf.)
    """
    ss = jnp.einsum("...d,...d->...", x, x,
                    preferred_element_type=jnp.float32)
    inv = jax.lax.rsqrt(ss / x.shape[-1] + eps)
    return x * inv[..., None].astype(x.dtype) * scale


def dense_init(rng, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis]
    return jax.random.normal(rng, shape, dtype) * (fan_in ** -0.5)


# --------------------------------------------------------------------- #
# RoPE
# --------------------------------------------------------------------- #
def rope_freqs(head_dim: int, theta: float, positions):
    half = head_dim // 2
    inv = 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))
    ang = positions[..., None].astype(jnp.float32) * inv  # [..., S, half]
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x, cos, sin):
    """x: [..., S, H, hd]; cos/sin: [..., S, half] broadcast over heads.
    Rotation computed in fp32, result cast back to x's dtype."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :]
    s = sin[..., None, :]
    out = jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------- #
# Sharding helpers
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class MeshAxes:
    """Logical -> physical axis mapping for the production meshes.

    ``dp``: pure data-parallel axes (batch). ``fsdp``: parameter/optimizer
    sharding axes (ZeRO-3 style; same physical axes as dp on our meshes).
    ``tp``: tensor/expert-parallel axis. ``dp_size``/``tp_size``: device
    counts, needed by grouped-dispatch MoE.
    """

    dp: Any = ("data",)
    fsdp: Any = ("data",)
    tp: Any = "model"
    dp_size: int = 1
    tp_size: int = 1

    @staticmethod
    def for_mesh(mesh) -> "MeshAxes":
        names = mesh.axis_names
        tp_size = mesh.shape["model"]
        dp_size = mesh.devices.size // tp_size
        if "pod" in names:
            return MeshAxes(dp=("pod", "data"), fsdp=("pod", "data"),
                            tp="model", dp_size=dp_size, tp_size=tp_size)
        return MeshAxes(dp=("data",), fsdp=("data",), tp="model",
                        dp_size=dp_size, tp_size=tp_size)


def with_sharding(x, mesh, spec: P):
    return jax.lax.with_sharding_constraint(
        x, jax.sharding.NamedSharding(mesh, spec))


def constrain(x, axes: "MeshAxes | None", *entries):
    """Sharding constraint resolved against the ambient mesh context.

    ``entries`` are logical-axis names ('dp'/'tp') or None per dim; no-op
    when ``axes`` is None (single-device smoke paths)."""
    if axes is None:
        return x
    spec = P(*(getattr(axes, e) if isinstance(e, str) else e
               for e in entries))
    return jax.lax.with_sharding_constraint(x, spec)


def count_params(params) -> int:
    return sum(x.size for x in jax.tree.leaves(params))


def cast_tree(params, dtype):
    return jax.tree.map(
        lambda x: x.astype(dtype) if jnp.issubdtype(x.dtype, jnp.floating) else x,
        params)
