"""Mixture-of-Experts FFN: top-k routing with GROUPED static-shape
dispatch (capacity model), expert-parallel over the 'model' mesh axis.

Dispatch strategy (GShard-style, all static shapes):
  1. tokens reshape to [G, T/G, d] with G = the data-parallel degree, so
     every group is LOCAL to one dp shard — routing, sort-by-expert,
     rank-within-expert and the capacity scatter never cross shards;
  2. per-group expert buffers [G, E, C_g, d]; the expert einsum against
     tp-sharded expert weights is the single point where GSPMD inserts
     the dp<->tp all-to-all (the canonical MoE collective);
  3. weighted per-group segment-sum back to token order.

A single flat (ungrouped) sort is simpler but makes the dispatch gather
global: GSPMD replicates the full token buffer per device (measured
+100 GB/device at grok-prefill scale — EXPERIMENTS.md §Perf, MoE
iteration).

Aux losses: Switch load-balance + router z-loss (per-group averages).
"""

from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.models.common import constrain


def _dispatch_group(xl, p, cfg, cap: int):
    """Route one token group. xl: [Tg, d] -> (out [Tg, d], lb, z)."""
    tg, d = xl.shape
    e, k = cfg.n_experts, cfg.moe_topk

    logits = jnp.einsum("td,de->te", xl, p["wg"]).astype(jnp.float32)
    gates = jax.nn.softmax(logits, axis=-1)
    topw, topi = jax.lax.top_k(gates, k)              # [Tg, k]
    if cfg.moe_renorm:
        topw = topw / jnp.maximum(topw.sum(-1, keepdims=True), 1e-9)

    flat_e = topi.reshape(-1)                          # [Tg*k]
    flat_t = jnp.repeat(jnp.arange(tg), k)
    flat_w = topw.reshape(-1)

    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    starts = jnp.searchsorted(se, jnp.arange(e))
    pos = jnp.arange(tg * k) - starts[se]
    keep = pos < cap
    slot = jnp.where(keep, se * cap + pos, e * cap)    # sentinel = dropped

    xe = jnp.zeros((e * cap + 1, d), xl.dtype).at[slot].set(
        xl[st], mode="drop")
    xe = xe[:-1].reshape(e, cap, d)

    # aux-loss statistics
    me = gates.mean(axis=0)
    ce = jax.ops.segment_sum(
        jnp.ones_like(flat_e, jnp.float32), flat_e,
        num_segments=e) / (tg * k)
    lb = e * jnp.sum(me * ce)
    z = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return xe, (slot, st, sw), lb, z


def _combine_group(y, route, tg: int, cap: int, cfg):
    slot, st, sw = route
    e = cfg.n_experts
    d = y.shape[-1]
    y_flat = jnp.concatenate(
        [y.reshape(e * cap, d), jnp.zeros((1, d), y.dtype)], axis=0)
    contrib = y_flat[slot] * sw[:, None].astype(y.dtype)
    return jax.ops.segment_sum(contrib, st, num_segments=tg)


def moe_ffn(x, p, cfg, axes=None):
    """x: [T, d] tokens; returns ([T, d], aux_loss scalar)."""
    t, d = x.shape
    e, k = cfg.n_experts, cfg.moe_topk
    g = math.gcd(t, axes.dp_size) if axes is not None else 1
    tg = t // g
    cap = int(cfg.capacity_factor * k * tg / e)
    cap = max(4, min(cap, tg * k))

    xg = x.reshape(g, tg, d)
    xg = constrain(xg, axes, "dp", None, None)

    xe, route, lb, z = jax.vmap(
        lambda xl: _dispatch_group(xl, p, cfg, cap))(xg)
    # xe: [G, E, C, d] — G over dp; expert einsum below is where the
    # dp<->tp all-to-all happens (expert weights live on tp shards).
    expert_tp = getattr(cfg, "expert_shard", "expert") == "expert"
    e_spec = ("dp", "tp" if expert_tp else None, None, None)
    xe = constrain(xe, axes, *e_spec)

    h = jnp.einsum("gecd,edf->gecf", xe, p["w1"])
    if "w3" in p:
        h = jax.nn.silu(h) * jnp.einsum("gecd,edf->gecf", xe, p["w3"])
    else:
        h = jax.nn.silu(h)
    h = constrain(h, axes,
                  "dp", "tp" if expert_tp else None, None,
                  None if expert_tp else "tp")
    y = jnp.einsum("gecf,efd->gecd", h, p["w2"])       # [G, E, C, d]
    y = constrain(y, axes, *e_spec)

    out = jax.vmap(
        lambda yl, rt: _combine_group(yl, rt, tg, cap, cfg))(y, route)
    out = constrain(out, axes, "dp", None, None).reshape(t, d)
    aux = cfg.moe_lb_coef * lb.mean() + cfg.moe_z_coef * z.mean()
    return out.astype(x.dtype), aux
