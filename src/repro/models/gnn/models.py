"""GAT, GIN, PNA — the SpMM/SDDMM-regime GNN architectures.

Graphs are dicts:
  x [N, F] node features; edge_src/edge_dst int32 [E] (-1 = padding);
  node_mask bool [N]; optional graph_ids [N] for batched small graphs.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.models.common import dense_init
from repro.models.gnn.message import (
    degrees,
    gather_scatter,
    segment_softmax,
)


@dataclasses.dataclass(frozen=True)
class GNNConfig:
    name: str = "gnn"
    arch: str = "gat"            # gat | gin | pna | nequip
    n_layers: int = 2
    d_in: int = 16
    d_hidden: int = 8
    n_heads: int = 8             # gat
    n_classes: int = 7
    eps_learnable: bool = True   # gin
    aggregators: tuple = ("mean", "max", "min", "std")   # pna
    scalers: tuple = ("identity", "amplification", "attenuation")
    delta: float = 2.5           # pna degree normalizer (log-mean degree)
    backend: str = "xla"         # segment-reduce backend
    # nequip
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    dtype: Any = jnp.float32
    # distribution: shard node-dim tensors over these mesh axes and remat
    # per layer (required for full-batch-large graphs: unsharded per-layer
    # node activations at ogb_products scale cost 20-80 GB/device)
    mesh_axes: tuple | None = None
    remat: bool = False


def _nshard(x, cfg: GNNConfig):
    """Node-dim sharding constraint over cfg.mesh_axes (no-op if None)."""
    if cfg.mesh_axes is None:
        return x
    spec = P(tuple(cfg.mesh_axes), *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def _maybe_remat(fn, cfg: GNNConfig):
    return jax.checkpoint(fn) if cfg.remat else fn


# --------------------------------------------------------------------- #
# GAT
# --------------------------------------------------------------------- #
def gat_init(rng, cfg: GNNConfig):
    ks = jax.random.split(rng, cfg.n_layers * 3 + 1)
    layers = []
    d = cfg.d_in
    for i in range(cfg.n_layers):
        last = i == cfg.n_layers - 1
        out = cfg.n_classes if last else cfg.d_hidden
        layers.append({
            "w": dense_init(ks[3 * i], (d, cfg.n_heads, out)),
            "a_src": dense_init(ks[3 * i + 1], (cfg.n_heads, out), 1),
            "a_dst": dense_init(ks[3 * i + 2], (cfg.n_heads, out), 1),
        })
        d = out if last else out * cfg.n_heads
    return {"layers": layers}


def gat_forward(params, g, cfg: GNNConfig):
    x = g["x"].astype(cfg.dtype)
    n = x.shape[0]
    src, dst = g["edge_src"], g["edge_dst"]
    e_ok = (src >= 0) & (dst >= 0)
    s = jnp.maximum(src, 0)
    t = jnp.maximum(dst, 0)
    for i, lp in enumerate(params["layers"]):
        last = i == len(params["layers"]) - 1

        def layer(x, lp=lp, last=last):
            lp = jax.tree.map(lambda a: a.astype(cfg.dtype), lp)
            h = jnp.einsum("nf,fho->nho", x, lp["w"])        # [N, H, O]
            h = _nshard(h, cfg)
            es = jnp.einsum("eho,ho->eh", h[s], lp["a_src"])
            ed = jnp.einsum("eho,ho->eh", h[t], lp["a_dst"])
            score = jax.nn.leaky_relu(es + ed, 0.2)          # [E, H]
            score = jnp.where(e_ok[:, None], score, -jnp.inf)
            alpha = segment_softmax(score, jnp.where(e_ok, dst, -1), n)
            msg = (h[s] * alpha[..., None]).reshape(src.shape[0], -1)
            seg = jnp.where(e_ok, dst, -1)
            agg = jax.ops.segment_sum(
                jnp.where(e_ok[:, None], msg, 0),
                jnp.where(seg < 0, n, seg), num_segments=n + 1)[:n]
            agg = _nshard(agg, cfg).reshape(n, cfg.n_heads, -1)
            return (agg.mean(axis=1) if last
                    else jax.nn.elu(agg.reshape(n, -1)))

        x = _maybe_remat(layer, cfg)(x)
    return x  # [N, n_classes]


# --------------------------------------------------------------------- #
# GIN
# --------------------------------------------------------------------- #
def gin_init(rng, cfg: GNNConfig):
    ks = jax.random.split(rng, cfg.n_layers * 2 + 2)
    layers = []
    d = cfg.d_in
    for i in range(cfg.n_layers):
        layers.append({
            "w1": dense_init(ks[2 * i], (d, cfg.d_hidden)),
            "w2": dense_init(ks[2 * i + 1], (cfg.d_hidden, cfg.d_hidden)),
            "ln": jnp.ones((cfg.d_hidden,)),
            "eps": jnp.zeros(()),
        })
        d = cfg.d_hidden
    return {
        "layers": layers,
        "readout": dense_init(ks[-1], (cfg.d_hidden, cfg.n_classes)),
    }


def gin_forward(params, g, cfg: GNNConfig):
    x = g["x"].astype(cfg.dtype)
    n = x.shape[0]
    for lp in params["layers"]:
        def layer(x, lp=lp):
            lp = jax.tree.map(lambda a: a.astype(cfg.dtype), lp)
            agg = gather_scatter(x, g["edge_src"], g["edge_dst"], n,
                                 reduce="sum", backend=cfg.backend)
            h = (1.0 + lp["eps"]) * x + _nshard(agg, cfg)
            h = jax.nn.relu(h @ lp["w1"])
            h = h @ lp["w2"]
            mu = h.mean(-1, keepdims=True)
            sd = jnp.sqrt(jnp.maximum(h.var(-1, keepdims=True), 1e-6))
            return _nshard(jax.nn.relu(lp["ln"] * (h - mu) / sd), cfg)

        x = _maybe_remat(layer, cfg)(x)
    if "graph_ids" in g:
        gid = g["graph_ids"]
        n_graphs = g["n_graphs"]
        pooled = jax.ops.segment_sum(
            jnp.where((gid >= 0)[:, None], x, 0),
            jnp.where(gid < 0, n_graphs, gid),
            num_segments=n_graphs + 1)[:n_graphs]
        return pooled @ params["readout"]
    return x @ params["readout"]


# --------------------------------------------------------------------- #
# PNA
# --------------------------------------------------------------------- #
def pna_init(rng, cfg: GNNConfig):
    ks = jax.random.split(rng, cfg.n_layers * 3 + 2)
    layers = []
    d = cfg.d_in
    n_mix = len(cfg.aggregators) * len(cfg.scalers)
    for i in range(cfg.n_layers):
        layers.append({
            "pre": dense_init(ks[3 * i], (2 * d, cfg.d_hidden)),
            "post": dense_init(ks[3 * i + 1], (n_mix * cfg.d_hidden + d,
                                               cfg.d_hidden)),
            "ln": jnp.ones((cfg.d_hidden,)),
        })
        d = cfg.d_hidden
    return {
        "layers": layers,
        "readout": dense_init(ks[-1], (cfg.d_hidden, cfg.n_classes)),
    }


def pna_forward(params, g, cfg: GNNConfig):
    x = g["x"].astype(cfg.dtype)
    n = x.shape[0]
    src, dst = g["edge_src"], g["edge_dst"]
    e_ok = (src >= 0) & (dst >= 0)
    s, t = jnp.maximum(src, 0), jnp.maximum(dst, 0)
    deg = degrees(dst, n).astype(cfg.dtype)
    for lp in params["layers"]:
        def layer(x, lp=lp):
            lp = jax.tree.map(lambda a: a.astype(cfg.dtype), lp)
            msg = jnp.concatenate([x[s], x[t]], axis=-1) @ lp["pre"]  # [E, H]
            msg = jax.nn.relu(msg)
            msg = jnp.where(e_ok[:, None], msg, 0)
            seg = jnp.where(e_ok, dst, -1)
            segs = jnp.where(seg < 0, n, seg)
            aggs = []
            m_sum = jax.ops.segment_sum(msg, segs, num_segments=n + 1)[:n]
            cnt = jnp.maximum(deg[:, None], 1.0)
            m_mean = m_sum / cnt
            if "mean" in cfg.aggregators:
                aggs.append(m_mean)
            if "max" in cfg.aggregators:
                mx = jax.ops.segment_max(msg, segs, num_segments=n + 1)[:n]
                aggs.append(jnp.where(jnp.isfinite(mx), mx, 0))
            if "min" in cfg.aggregators:
                mn = jax.ops.segment_min(msg, segs, num_segments=n + 1)[:n]
                aggs.append(jnp.where(jnp.isfinite(mn), mn, 0))
            if "std" in cfg.aggregators:
                sq = jax.ops.segment_sum(msg * msg, segs,
                                         num_segments=n + 1)[:n]
                var = jnp.maximum(sq / cnt - m_mean ** 2, 0)
                aggs.append(jnp.sqrt(var + 1e-6))
            scaled = []
            logd = jnp.log1p(deg)[:, None]
            for a in aggs:
                a = _nshard(a, cfg)
                for sc in cfg.scalers:
                    if sc == "identity":
                        scaled.append(a)
                    elif sc == "amplification":
                        scaled.append(a * (logd / cfg.delta))
                    elif sc == "attenuation":
                        scaled.append(
                            a * (cfg.delta / jnp.maximum(logd, 1e-3)))
            h = jnp.concatenate(scaled + [x], axis=-1) @ lp["post"]
            mu = h.mean(-1, keepdims=True)
            sd = jnp.sqrt(jnp.maximum(h.var(-1, keepdims=True), 1e-6))
            return _nshard(jax.nn.relu(lp["ln"] * (h - mu) / sd), cfg)

        x = _maybe_remat(layer, cfg)(x)
    return x @ params["readout"]


# --------------------------------------------------------------------- #
FORWARDS = {"gat": gat_forward, "gin": gin_forward, "pna": pna_forward}
INITS = {"gat": gat_init, "gin": gin_init, "pna": pna_init}


def node_classification_loss(params, g, cfg: GNNConfig, forward=None):
    """Node-level CE; with ``graph_ids`` present (batched small graphs),
    mean-pools node logits per graph and classifies graphs instead
    (except GIN, whose forward already pools through its readout)."""
    fwd = forward or FORWARDS[cfg.arch]
    logits = fwd(params, g, cfg).astype(jnp.float32)
    if "graph_ids" in g and logits.shape[0] != g["labels"].shape[0]:
        pass  # GIN path: forward already pooled to graph level
    elif "graph_ids" in g:
        gid = g["graph_ids"]
        ng = g["n_graphs"]
        seg = jnp.where(gid < 0, ng, gid)
        tot = jax.ops.segment_sum(logits, seg, num_segments=ng + 1)[:ng]
        cnt = jax.ops.segment_sum(
            jnp.ones((logits.shape[0], 1), jnp.float32), seg,
            num_segments=ng + 1)[:ng]
        logits = tot / jnp.maximum(cnt, 1)
    labels = g["labels"] if "graph_ids" not in g else g["graph_labels"]
    mask = g.get("label_mask", jnp.ones(labels.shape, bool))
    lse = jax.nn.logsumexp(logits, axis=-1)
    ll = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    ce = jnp.where(mask, lse - ll, 0).sum() / jnp.maximum(mask.sum(), 1)
    return ce, {"ce": ce}


def param_specs(params, axes):
    """GNN params are tiny: replicate everywhere."""
    return jax.tree.map(lambda _: P(), params)
