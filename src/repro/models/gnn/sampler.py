"""Uniform neighbor sampler (GraphSAGE-style) for minibatch GNN training.

Host-side numpy over a CSR adjacency; produces fixed-shape padded
subgraph arrays so the device step compiles once.  This is the real data
path for the ``minibatch_lg`` shape (232k nodes / 114M edges with
batch=1024, fanout 15-10).
"""

from __future__ import annotations

import numpy as np


class CSRGraph:
    def __init__(self, n_nodes: int, edge_src, edge_dst):
        order = np.argsort(edge_dst, kind="stable")
        self.dst_sorted_src = np.asarray(edge_src)[order]
        counts = np.bincount(np.asarray(edge_dst), minlength=n_nodes)
        self.indptr = np.concatenate([[0], np.cumsum(counts)])
        self.n_nodes = n_nodes

    def in_neighbors(self, v: int):
        lo, hi = self.indptr[v], self.indptr[v + 1]
        return self.dst_sorted_src[lo:hi]


def sample_subgraph(
    g: CSRGraph,
    seeds: np.ndarray,
    fanouts: tuple[int, ...],
    rng: np.random.Generator,
):
    """k-hop uniform sampling.  Returns a padded merged subgraph:

    nodes      int32 [N_max]  original ids (-1 padding); seeds first
    edge_src   int32 [E_max]  indices into `nodes` (-1 padding)
    edge_dst   int32 [E_max]
    n_seeds    int
    with N_max = sum of frontier sizes, E_max = sum of seeds*fanout terms.
    """
    node_index: dict[int, int] = {}
    nodes: list[int] = []

    def local(v: int) -> int:
        if v not in node_index:
            node_index[v] = len(nodes)
            nodes.append(v)
        return node_index[v]

    for sd in seeds:
        local(int(sd))

    e_src: list[int] = []
    e_dst: list[int] = []
    frontier = [int(s) for s in seeds]
    n_max, e_max = subgraph_shapes(len(seeds), tuple(fanouts))

    for f in fanouts:
        nxt: list[int] = []
        for v in frontier:
            nbrs = g.in_neighbors(v)
            if len(nbrs) == 0:
                continue
            take = rng.choice(nbrs, size=min(f, len(nbrs)), replace=False)
            for u in take:
                e_src.append(local(int(u)))
                e_dst.append(node_index[v])
                nxt.append(int(u))
        frontier = nxt

    def pad(a, n, fill=-1):
        out = np.full((n,), fill, np.int32)
        out[: len(a)] = a
        return out

    return {
        "nodes": pad(nodes, n_max),
        "edge_src": pad(e_src, e_max),
        "edge_dst": pad(e_dst, e_max),
        "n_seeds": len(seeds),
    }


def subgraph_shapes(batch_nodes: int, fanouts: tuple[int, ...]):
    """Static (N_max, E_max) for a given sampling config."""
    n_max = batch_nodes
    e_max = 0
    level = batch_nodes
    for f in fanouts:
        e_max += level * f
        level *= f
        n_max += level
    return n_max, e_max
