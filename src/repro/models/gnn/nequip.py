"""NequIP-style E(3)-equivariant interatomic potential (l_max = 2).

Hardware/implementation adaptation (DESIGN.md §Adaptations): instead of
spherical-harmonic irreps + Clebsch-Gordan contractions (e3nn), features
live in *Cartesian tensor* form —

    l=0: scalars          [N, C]
    l=1: vectors          [N, C, 3]
    l=2: symmetric traceless matrices [N, C, 3, 3]

Tensor products become outer products / contractions / symmetrization,
which map onto plain batched einsums (MXU-friendly, no CG coefficient
tables or irregular segment sizes).  For l ≤ 2 this spans the same
function space as the spherical basis; rotation equivariance is exact
and property-tested (tests/test_gnn.py::test_nequip_equivariance).
Parity (O(3) vs SO(3)) is handled as in PaiNN: only even-parity products
are used, no cross products.

Message paths implemented (feature ⊗ edge-geometry -> output):
    s·1→s, s·Y1→v, s·Y2→t, v·Y1→s (dot), v·1→v, v·Y2→v (matvec),
    v·Y1→t (sym outer), t·1→t, t·Y1→v (matvec), t·Y2→s (double dot).
Each path is weighted per channel by a radial MLP over a Bessel basis
with a polynomial cutoff envelope (as in the paper).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models.common import dense_init

PATHS = ("ss", "sv", "st", "vs", "vv", "vt_mat", "vt_outer", "tt", "tv", "ts")


def bessel_basis(r, n_rbf: int, cutoff: float):
    """Bessel radial basis with smooth polynomial cutoff envelope."""
    r = jnp.maximum(r, 1e-6)
    n = jnp.arange(1, n_rbf + 1, dtype=r.dtype)
    b = jnp.sqrt(2.0 / cutoff) * jnp.sin(n * jnp.pi * r[:, None] / cutoff) / r[:, None]
    x = jnp.clip(r / cutoff, 0, 1)
    env = 1 - 10 * x**3 + 15 * x**4 - 6 * x**5      # smooth C^2 cutoff
    return b * env[:, None]


def _sym_traceless(m):
    s = 0.5 * (m + jnp.swapaxes(m, -1, -2))
    tr = jnp.trace(s, axis1=-2, axis2=-1)[..., None, None]
    eye = jnp.eye(3, dtype=m.dtype)
    return s - tr * eye / 3.0


@dataclasses.dataclass(frozen=True)
class NequIPConfig:
    name: str = "nequip"
    n_layers: int = 5
    channels: int = 32
    l_max: int = 2
    n_rbf: int = 8
    cutoff: float = 5.0
    n_species: int = 8
    radial_hidden: int = 64
    mesh_axes: tuple | None = None   # shard node-dim tensors over these
    remat: bool = False              # checkpoint each interaction layer


def _nshard(x, cfg):
    if cfg.mesh_axes is None:
        return x
    from jax.sharding import PartitionSpec as P
    spec = P(tuple(cfg.mesh_axes), *([None] * (x.ndim - 1)))
    return jax.lax.with_sharding_constraint(x, spec)


def init(rng, cfg: NequIPConfig):
    ks = jax.random.split(rng, cfg.n_layers * 8 + 3)
    c = cfg.channels
    layers = []
    for i in range(cfg.n_layers):
        k = ks[8 * i: 8 * (i + 1)]
        layers.append({
            # radial MLP: basis -> per-(path, channel) weights
            "r1": dense_init(k[0], (cfg.n_rbf, cfg.radial_hidden)),
            "r2": dense_init(k[1], (cfg.radial_hidden, len(PATHS) * c)),
            # self-interaction channel mixers per l
            "w_s": dense_init(k[2], (c, c)),
            "w_v": dense_init(k[3], (c, c)),
            "w_t": dense_init(k[4], (c, c)),
            # gate scalars: produce 2c extra scalars to gate v and t
            "w_gate": dense_init(k[5], (c, 2 * c)),
            "ln_s": jnp.ones((c,)),
        })
    return {
        "embed": dense_init(ks[-3], (cfg.n_species, cfg.channels)),
        "layers": layers,
        "out1": dense_init(ks[-2], (cfg.channels, cfg.channels)),
        "out2": dense_init(ks[-1], (cfg.channels, 1)),
    }


def _messages(s, v, t, lp, edge_src, edge_dst, rvec, cfg):
    """Compute per-edge path outputs and aggregate to destinations."""
    e_ok = (edge_src >= 0) & (edge_dst >= 0)
    si = jnp.maximum(edge_src, 0)
    r = jnp.linalg.norm(rvec, axis=-1)
    rhat = rvec / jnp.maximum(r, 1e-6)[:, None]
    y1 = rhat                                             # [E, 3]
    y2 = _sym_traceless(rhat[:, :, None] * rhat[:, None, :])  # [E, 3, 3]

    basis = bessel_basis(r, cfg.n_rbf, cfg.cutoff)
    w = jax.nn.silu(basis @ lp["r1"]) @ lp["r2"]          # [E, P*C]
    w = w.reshape(-1, len(PATHS), cfg.channels)
    w = jnp.where(e_ok[:, None, None], w, 0)
    W = {p: w[:, i] for i, p in enumerate(PATHS)}         # each [E, C]

    se, ve, te = s[si], v[si], t[si]                      # gathered src feats

    out_s = (W["ss"] * se
             + W["vs"] * jnp.einsum("eci,ei->ec", ve, y1)
             + W["ts"] * jnp.einsum("ecij,eij->ec", te, y2))
    out_v = (W["sv"][..., None] * y1[:, None, :]
             + W["vv"][..., None] * ve
             + W["vt_mat"][..., None] * jnp.einsum("ecij,ej->eci", te, y1[:, :])
             + W["tv"][..., None] * jnp.einsum("eij,ecj->eci", y2, ve))
    outer = _sym_traceless(ve[..., :, None] * y1[:, None, None, :])
    out_t = (W["st"][..., None, None] * y2[:, None, :, :]
             + W["vt_outer"][..., None, None] * outer
             + W["tt"][..., None, None] * te)

    n = s.shape[0]
    seg = jnp.where(e_ok, edge_dst, n)

    def agg(x):
        return jax.ops.segment_sum(x, seg, num_segments=n + 1)[:n]

    return agg(out_s), agg(out_v), agg(out_t)


def forward(params, g, cfg: NequIPConfig):
    """g: species [N] int32, pos [N,3], edge_src/edge_dst [E],
    optional graph_ids/n_graphs.  Returns per-graph energy [G]."""
    species = jnp.clip(g["species"], 0, cfg.n_species - 1)
    pos = g["pos"]
    n = species.shape[0]
    c = cfg.channels
    s = jnp.take(params["embed"], species, axis=0)        # [N, C]
    v = jnp.zeros((n, c, 3), s.dtype)
    t = jnp.zeros((n, c, 3, 3), s.dtype)

    e_ok = (g["edge_src"] >= 0) & (g["edge_dst"] >= 0)
    si = jnp.maximum(g["edge_src"], 0)
    di = jnp.maximum(g["edge_dst"], 0)
    rvec = jnp.where(e_ok[:, None], pos[si] - pos[di], 1.0)

    for lp in params["layers"]:
        def layer(svt, lp=lp):
            s, v, t = svt
            ms, mv, mt = _messages(s, v, t, lp, g["edge_src"],
                                   g["edge_dst"], rvec, cfg)
            # self-interaction + residual
            s_new = s + ms @ lp["w_s"]
            v_new = v + jnp.einsum("nci,cd->ndi", mv, lp["w_v"])
            t_new = t + jnp.einsum("ncij,cd->ndij", mt, lp["w_t"])
            # gate nonlinearity: scalars silu; v/t scaled by sigmoids
            gates = jax.nn.sigmoid(s_new @ lp["w_gate"])  # [N, 2C]
            s = _nshard(jax.nn.silu(s_new) * lp["ln_s"], cfg)
            v = _nshard(v_new * gates[:, :c, None], cfg)
            t = _nshard(t_new * gates[:, c:, None, None], cfg)
            return s, v, t

        fn = jax.checkpoint(layer) if cfg.remat else layer
        s, v, t = fn((s, v, t))

    e_node = jax.nn.silu(s @ params["out1"]) @ params["out2"]   # [N, 1]
    if "graph_ids" in g:
        gid = g["graph_ids"]
        ng = g["n_graphs"]
        return jax.ops.segment_sum(
            e_node[:, 0], jnp.where(gid < 0, ng, gid),
            num_segments=ng + 1)[:ng]
    return e_node[:, 0].sum()[None]


def energy_and_forces(params, g, cfg: NequIPConfig):
    def etot(pos):
        return forward(params, {**g, "pos": pos}, cfg).sum()

    e, neg_f = jax.value_and_grad(etot)(g["pos"])
    return e, -neg_f


def mse_loss(params, g, cfg: NequIPConfig):
    e = forward(params, g, cfg)
    target = g.get("energy", jnp.zeros_like(e))
    l = jnp.mean((e - target) ** 2)
    return l, {"mse": l}
