"""GNN zoo: message passing over edge lists via segment ops.

JAX has no sparse-matrix message passing beyond BCOO; the substrate here
is the edge-index formulation — ``gather(src) -> transform ->
segment_reduce(dst)`` — with the segment_reduce backend switchable
between XLA scatter and the Pallas one-hot-MXU kernel.
"""

from repro.models.gnn.message import gather_scatter, segment_softmax
