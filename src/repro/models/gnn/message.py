"""Message-passing primitives over (edge_src, edge_dst) index arrays.

Edges with src or dst < 0 are padding and contribute nothing.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.segment_reduce import ops as sr


def gather_scatter(x, edge_src, edge_dst, n_nodes: int,
                   transform=None, reduce: str = "sum",
                   backend: str = "xla"):
    """out[dst] = reduce over edges of transform(x[src])."""
    src_ok = edge_src >= 0
    msg = jnp.take(x, jnp.maximum(edge_src, 0), axis=0)
    msg = jnp.where(src_ok[:, None], msg, 0)
    if transform is not None:
        msg = transform(msg)
    dst = jnp.where(src_ok & (edge_dst >= 0), edge_dst, -1)
    if reduce == "sum":
        return sr.segment_sum(dst, msg, n_nodes, backend)
    if reduce == "mean":
        return sr.segment_mean(dst, msg, n_nodes, backend)
    if reduce in ("max", "min"):
        seg = jnp.where(dst < 0, n_nodes, dst)
        fn = jax.ops.segment_max if reduce == "max" else jax.ops.segment_min
        fill = -jnp.inf if reduce == "max" else jnp.inf
        out = fn(msg, seg, num_segments=n_nodes + 1)[:n_nodes]
        return jnp.where(jnp.isfinite(out), out, 0)
    raise ValueError(reduce)


def segment_softmax(scores, seg, n_segments: int):
    """Numerically-stable softmax of ``scores`` grouped by ``seg``.

    scores [E, H]; seg int32 [E] (-1 = padding -> weight 0).
    """
    seg_safe = jnp.where(seg < 0, n_segments, seg)
    mx = jax.ops.segment_max(scores, seg_safe, num_segments=n_segments + 1)
    mx = jnp.where(jnp.isfinite(mx), mx, 0)
    ex = jnp.exp(scores - mx[seg_safe])
    ex = jnp.where((seg >= 0)[:, None], ex, 0)
    den = jax.ops.segment_sum(ex, seg_safe, num_segments=n_segments + 1)
    return ex / jnp.maximum(den[seg_safe], 1e-16)


def degrees(edge_dst, n_nodes: int):
    ones = jnp.ones((edge_dst.shape[0], 1), jnp.float32)
    dst = jnp.where(edge_dst >= 0, edge_dst, n_nodes)
    return jax.ops.segment_sum(ones, dst, num_segments=n_nodes + 1)[:n_nodes, 0]
