"""Attention: GQA with RoPE/qk-norm, chunked-softmax prefill/train path and
KV-cache decode with sequence-sharded caches.

Design notes (TPU):
* Train/prefill uses an online-softmax scan over KV chunks so the [S, S]
  score matrix never materializes for long sequences (32k prefill).
* Decode computes one query position against a [S_max] cache; with the
  cache's sequence axis sharded over the 'model' mesh axis, GSPMD lowers
  the softmax reduction into partial-softmax + cross-shard combine —
  exactly flash-decoding's split-KV scheme, derived from shardings
  rather than hand-written collectives.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.common import apply_rope, constrain, rms_norm, rope_freqs

NEG_INF = -1e30


def _repeat_kv(k, n_rep: int):
    if n_rep == 1:
        return k
    b, s, h, d = k.shape
    return jnp.broadcast_to(
        k[:, :, :, None, :], (b, s, h, n_rep, d)
    ).reshape(b, s, h * n_rep, d)


def gqa_attention(
    q,             # [B, S, Hq, hd]
    k,             # [B, S, Hkv, hd]
    v,             # [B, S, Hkv, hd]
    *,
    causal: bool = True,
    chunk_size: int = 1024,
    window: int | None = None,   # sliding-window attention (beyond-paper opt)
    axes=None,
):
    """Online-softmax chunked attention; exact, O(S·chunk) memory.

    With ``axes``, q/k/v (and thus the score blocks) are head-sharded
    over tp — Megatron-style head parallelism.  The [B,H,S,chunk] fp32
    score block is the largest attention temporary; head sharding cuts
    it by the TP degree (GSPMD pads 56->64 heads on a 16-way axis).
    """
    b, s, hq, hd = q.shape
    hkv = k.shape[2]
    k = _repeat_kv(k, hq // hkv)
    v = _repeat_kv(v, hq // hkv)
    q = constrain(q, axes, "dp", None, "tp", None)
    k = constrain(k, axes, "dp", None, "tp", None)
    v = constrain(v, axes, "dp", None, "tp", None)
    scale = hd ** -0.5
    q = q * scale

    n_chunks = max(1, s // chunk_size)
    cs = s // n_chunks
    kc = k.reshape(b, n_chunks, cs, hq, hd)
    vc = v.reshape(b, n_chunks, cs, hq, hd)
    qpos = jnp.arange(s)

    def body(carry, xs):
        m, l, acc = carry
        kb, vb, c = xs
        kpos = c * cs + jnp.arange(cs)
        sc = jnp.einsum("bqhd,bkhd->bhqk", q, kb,
                        preferred_element_type=jnp.float32)
        mask = jnp.ones((s, cs), bool)
        if causal:
            mask &= qpos[:, None] >= kpos[None, :]
        if window is not None:
            mask &= qpos[:, None] - kpos[None, :] < window
        sc = jnp.where(mask[None, None], sc, NEG_INF)
        m_new = jnp.maximum(m, sc.max(axis=-1))
        p = jnp.exp(sc - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        acc_new = acc * corr[..., None] + jnp.einsum(
            "bhqk,bkhd->bhqd", p, vb.astype(jnp.float32))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hq, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, s), jnp.float32)
    a0 = jnp.zeros((b, hq, s, hd), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(n_chunks)))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.swapaxes(1, 2).astype(q.dtype)  # [B, S, Hq, hd]


def decode_attention(
    q,          # [B, 1, Hq, hd]
    k_cache,    # [B, S_max, Hkv, hd]
    v_cache,    # [B, S_max, Hkv, hd]
    length,     # int32 [B] — valid cache length per sequence
):
    """Single-position attention over the full cache (GSPMD splits the
    seq-axis reduction across 'model' shards = flash-decoding)."""
    b, smax, hkv, hd = k_cache.shape
    hq = q.shape[2]
    k_cache = _repeat_kv(k_cache, hq // hkv)
    v_cache = _repeat_kv(v_cache, hq // hkv)
    scale = hd ** -0.5
    sc = jnp.einsum("bqhd,bkhd->bhqk", q * scale, k_cache,
                    preferred_element_type=jnp.float32)
    pos = jnp.arange(smax)
    mask = pos[None, :] < length[:, None]            # [B, S]
    sc = jnp.where(mask[:, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v_cache.astype(jnp.float32))
    return out.astype(q.dtype)


def attention_block(
    x,                  # [B, S, d]
    p,                  # params dict: wq, wk, wv, wo (+ qnorm/knorm scales)
    cfg,
    positions=None,
    kv_cache=None,      # (k, v, length) for decode
    axes=None,
):
    """Full attention block shared by train/prefill/decode paths.

    Projection weights are stored with heads FLATTENED into the feature
    dim ([d, H*hd]) so the TP axis shards the 128-multiple flat dim —
    head counts like 56/40 don't divide a 16-way mesh axis, flat feature
    dims always do (argument shardings must divide exactly; GSPMD pads
    only internal constraints).
    """
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, hq, hd)
    k = (x @ p["wk"]).reshape(b, s, hkv, hd)
    v = (x @ p["wv"]).reshape(b, s, hkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    if positions is None:
        positions = jnp.arange(s)[None, :]
    cos, sin = rope_freqs(hd, cfg.rope_theta, positions)
    q = apply_rope(q, cos, sin)
    k = apply_rope(k, cos, sin)

    if kv_cache is not None:
        kc, vc, length = kv_cache
        # write the new K/V at position `length` (decode: s == 1)
        idx = length[:, None] + jnp.arange(s)[None, :]
        bidx = jnp.arange(b)[:, None]
        kc = kc.at[bidx, idx].set(k.astype(kc.dtype))
        vc = vc.at[bidx, idx].set(v.astype(vc.dtype))
        out = decode_attention(q, kc, vc, length + s)
        new_cache = (kc, vc, length + s)
    else:
        out = gqa_attention(
            q, k, v, causal=True, chunk_size=cfg.attn_chunk,
            window=cfg.attn_window, axes=axes)
        new_cache = (k, v, None)   # post-RoPE K/V for prefill cache capture

    y = out.reshape(b, s, hq * hd) @ p["wo"]
    return y, new_cache
