"""RecSys: Wide&Deep with sharded embedding tables + retrieval scoring."""
