"""Wide & Deep (Cheng et al. 2016) for CTR prediction.

Deep side: 40 sparse categorical fields -> 32-dim embeddings (one table
per field, row-sharded over the 'model' axis) concatenated with dense
features -> MLP 1024-512-256 -> logit.
Wide side: hashed cross features into one wide table -> summed logit.

The embedding lookup is the hot path; it routes through the
embedding_bag kernel layer (single-hot fields = bag size 1; the wide
side uses real multi-hot bags).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.kernels.embedding_bag import ops as eb
from repro.models.common import dense_init


@dataclasses.dataclass(frozen=True)
class WideDeepConfig:
    name: str = "wide-deep"
    n_sparse: int = 40
    vocab_per_field: int = 1_000_000
    embed_dim: int = 32
    n_dense: int = 13
    mlp: tuple = (1024, 512, 256)
    wide_vocab: int = 2_000_000
    n_wide_crosses: int = 16       # hashed cross features per example
    backend: str = "xla"
    dtype: Any = jnp.float32


def init(rng, cfg: WideDeepConfig):
    ks = jax.random.split(rng, 4 + len(cfg.mlp))
    # one [V, D] table per sparse field, stacked: [F, V, D]
    tables = jax.random.normal(
        ks[0], (cfg.n_sparse, cfg.vocab_per_field, cfg.embed_dim)) * 0.01
    wide = jax.random.normal(ks[1], (cfg.wide_vocab,)) * 0.01
    params = {"tables": tables, "wide": wide, "mlp": []}
    d = cfg.n_sparse * cfg.embed_dim + cfg.n_dense
    for i, h in enumerate(cfg.mlp):
        params["mlp"].append({
            "w": dense_init(ks[2 + i], (d, h)),
            "b": jnp.zeros((h,)),
        })
        d = h
    params["head"] = dense_init(ks[-1], (d, 1))
    params["bias"] = jnp.zeros(())
    return params


def forward(params, batch, cfg: WideDeepConfig):
    """batch: sparse_ids int32 [B, F], dense [B, n_dense],
    wide_ids int32 [B, n_crosses] (-1 padded multi-hot bags)."""
    ids = batch["sparse_ids"]                     # [B, F]
    b, f = ids.shape
    # per-field gather: einsum-free take over stacked tables
    fld = jnp.arange(f)[None, :].repeat(b, 0)     # [B, F]
    emb = params["tables"][fld, ids]              # [B, F, D]
    deep_in = jnp.concatenate(
        [emb.reshape(b, -1), batch["dense"]], axis=-1).astype(cfg.dtype)
    h = deep_in
    for lp in params["mlp"]:
        h = jax.nn.relu(h @ lp["w"] + lp["b"])
    deep_logit = (h @ params["head"])[:, 0]

    # wide: multi-hot bag sum over hashed cross ids
    wid = batch["wide_ids"]                       # [B, K], -1 padded
    bags = jnp.arange(b)[:, None].repeat(wid.shape[1], 1).reshape(-1)
    wide_logit = eb.embedding_bag(
        wid.reshape(-1), bags, params["wide"][:, None], b,
        backend=cfg.backend)[:, 0]

    return deep_logit + wide_logit + params["bias"]


def bce_loss(params, batch, cfg: WideDeepConfig):
    logit = forward(params, batch, cfg).astype(jnp.float32)
    y = batch["labels"].astype(jnp.float32)
    l = jnp.mean(jnp.maximum(logit, 0) - logit * y
                 + jnp.log1p(jnp.exp(-jnp.abs(logit))))
    return l, {"bce": l}


def param_specs(cfg: WideDeepConfig, axes):
    tp = axes.tp
    return {
        "tables": P(None, tp, None),   # row-shard each field's vocab
        "wide": P(tp),
        "mlp": [{"w": P(), "b": P()} for _ in cfg.mlp],
        "head": P(),
        "bias": P(),
    }


# --------------------------------------------------------------------- #
# Retrieval scoring: one query against a large candidate table.
# --------------------------------------------------------------------- #
def retrieval_score(user_vec, cand_table, top_k: int = 100):
    """user_vec [D], cand_table [N, D] (sharded over 'model') -> top-k.

    A single batched dot — GSPMD turns the sharded argmax/top-k into a
    local top-k + cross-shard merge.
    """
    scores = cand_table @ user_vec                # [N]
    vals, idx = jax.lax.top_k(scores, top_k)
    return vals, idx
