"""Assigned-architecture model zoo.

transformer  Dense + MoE decoder LMs (GQA, RoPE, qk-norm, SwiGLU),
             scan-over-layers, chunked-softmax attention, KV-cache decode.
gnn          GAT / GIN / PNA / NequIP over segment-op message passing.
recsys       Wide&Deep with row-sharded EmbeddingBag + retrieval scoring.

Every model exposes: ``init(rng, cfg)``, ``loss_fn`` / ``forward``,
``param_specs(cfg, axes)`` (PartitionSpecs for pjit) and
``input_specs(cfg, shape)`` (ShapeDtypeStructs for the dry-run).
"""
