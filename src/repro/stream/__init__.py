"""Stream substrate: generators, the fault-tolerant ingestion frontier,
and the chaos (fault-injection) harness."""

from repro.stream.generator import (
    DisorderConfig,
    StreamConfig,
    disordered_sources,
    random_walk_query,
    split_stream,
    synth_social_stream,
    synth_traffic_stream,
)
from repro.stream.ingest import (
    CallbackRegistry,
    IngestError,
    IngestFrontier,
    IngestStats,
    ListSource,
    MonotonicityError,
    ScriptedSource,
    SeqTracker,
    Source,
    SourceAdapter,
    SourceDisconnected,
    SourceEvent,
    merge_event_streams,
)
from repro.stream.chaos import ChaosConfig, ChaosDisconnect, ChaosSource
