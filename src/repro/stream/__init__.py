"""Stream substrate: synthetic edge-stream generators and windowing."""

from repro.stream.generator import (
    StreamConfig,
    synth_traffic_stream,
    synth_social_stream,
    random_walk_query,
)
