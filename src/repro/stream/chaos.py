"""Fault injection for the ingestion frontier: wrap any ``Source`` in
scripted chaos.

``ChaosSource`` sits between a real transport and its ``SourceAdapter``
and injects, from one seeded rng (fully reproducible):

* **disconnects** — ``poll`` raises ``ChaosDisconnect`` (a
  ``SourceDisconnected`` that is also a ``runtime.fault.
  SimulatedFailure``, so the same except-clauses the crash/restore
  harnesses use catch it); reconnects optionally **rewind** the resume
  cursor to replay already-delivered events (at-least-once transport);
* **duplicate delivery** — a recently delivered event is delivered
  again with its original seq;
* **reordering** — deliveries detour through a bounded shuffle pool, so
  events leave up to ``reorder_span`` positions late;
* **stalls** — ``poll`` returns nothing for a few rounds;
* **torn batches** — a batch is cut short and the connection dies, the
  tail redelivered only after reconnect-with-resume.

The differential harness (tests/test_ingest_chaos.py) proves the whole
point: a chaos-wrapped multi-source run produces the exact oracle match
multiset of the equivalent pre-ordered single-stream run, minus nothing
— every excluded delivery shows up in the dedup/late-drop counters.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.runtime.fault import SimulatedFailure
from repro.stream.ingest import Source, SourceDisconnected, SourceEvent


class ChaosDisconnect(SourceDisconnected, SimulatedFailure):
    """An injected transport failure (retryable, simulated)."""


@dataclass(frozen=True)
class ChaosConfig:
    """Scripted-fault probabilities, all drawn from ``seed``.

    Defaults are all-zero: a ``ChaosSource`` with the default config is
    a transparent pass-through (tested), so wrapping is always safe.
    """

    seed: int = 0
    p_disconnect: float = 0.0    # per poll: raise before delivering
    rewind: int = 4              # resume cursor rewind on reconnect
    p_duplicate: float = 0.0     # per delivery: re-deliver a recent event
    reorder_span: int = 0        # max shuffle-pool detour, in deliveries
    p_reorder: float = 0.0       # per delivery: detour through the pool
    p_stall: float = 0.0         # per poll: start a stall
    stall_len: int = 3           # empty polls per stall
    p_torn: float = 0.0          # per poll: cut the batch + die next poll


class ChaosSource(Source):
    """Wrap ``inner`` with scripted faults (``ChaosConfig``).

    Keeps the inner source's name (resume manifests key on it).  The
    shuffle pool and duplicate history are chaos-internal: a disconnect
    drops the pool on the floor (torn delivery), which is safe because
    the downstream adapter reconnects from its tracker floor — nothing
    undelivered can be sequenced below that floor.
    """

    HISTORY = 64      # recent deliveries eligible for duplicate delivery

    def __init__(self, inner: Source, cfg: ChaosConfig = ChaosConfig()):
        self.inner = inner
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        self.name = inner.name
        self._pool: list[SourceEvent] = []      # reorder detours
        self._history: list[SourceEvent] = []   # duplicate candidates
        self._stall_left = 0
        self._die_next_poll = False
        self.n_injected_disconnects = 0
        self.n_injected_duplicates = 0
        self.n_injected_stalls = 0
        self.n_injected_torn = 0

    def connect(self, resume_from: int = 0) -> None:
        self._pool.clear()
        self._die_next_poll = False
        self.inner.connect(
            resume_from=max(0, resume_from - self.cfg.rewind))

    def close(self) -> None:
        self.inner.close()

    @property
    def exhausted(self) -> bool:
        return self.inner.exhausted and not self._pool \
            and not self._die_next_poll

    def _disconnect(self, kind: str) -> None:
        self.n_injected_disconnects += 1
        raise ChaosDisconnect(f"chaos[{self.name}]: injected {kind}")

    def poll(self, max_events: int = 64) -> list[SourceEvent]:
        cfg, rng = self.cfg, self.rng
        if self._die_next_poll:
            self._die_next_poll = False
            self._disconnect("torn-batch disconnect")
        if self._stall_left > 0:
            self._stall_left -= 1
            return []
        if rng.random() < cfg.p_stall:
            self.n_injected_stalls += 1
            self._stall_left = cfg.stall_len
            return []
        if rng.random() < cfg.p_disconnect:
            self._disconnect("disconnect")
        incoming = self.inner.poll(max_events)
        out: list[SourceEvent] = []
        for ev in incoming:
            if cfg.reorder_span > 0 and rng.random() < cfg.p_reorder:
                self._pool.append(ev)       # detour: leaves late
            else:
                out.append(ev)
        # release detoured events, oldest-biased, bounding the detour
        while self._pool and (
                len(self._pool) > cfg.reorder_span or rng.random() < 0.5):
            out.append(self._pool.pop(0))
        dup_out: list[SourceEvent] = []
        for ev in out:
            dup_out.append(ev)
            self._history.append(ev)
            if rng.random() < cfg.p_duplicate and self._history:
                pick = self._history[rng.integers(len(self._history))]
                dup_out.append(pick)
                self.n_injected_duplicates += 1
        self._history = self._history[-self.HISTORY:]
        if dup_out and rng.random() < cfg.p_torn:
            cut = int(rng.integers(0, len(dup_out)))
            self.n_injected_torn += 1
            self._die_next_poll = True
            dup_out = dup_out[:cut]
        return dup_out
