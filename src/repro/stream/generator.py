"""Synthetic streaming-graph generators + query generation (paper §6.1-6.2).

Two stream families mirror the paper's datasets:

* ``synth_traffic_stream``  — CAIDA-like network traffic: a single vertex
  label ("IP"), heavy-tailed vertex popularity, edge labels drawn from a
  skewed "destination port" distribution (the paper's top-6 ports cover
  >50% of records).
* ``synth_social_stream``   — LSBench-like social stream: several vertex
  types (user, post, photo, gps) and predicate edge labels.

Query generation follows §6.2: a random walk over a prefix of the stream
induces the structure; the timing order is the *inherent* chronological
order of the walked edges restricted to walk order (``ε_i ≺ ε_j ⇔ i < j ∧
T(ε_i) < T(ε_j)``), which guarantees at least one embedding exists that
satisfies both structure and timing constraints.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.core.oracle import DataEdge
from repro.core.query import QueryGraph


@dataclass
class StreamConfig:
    n_edges: int = 10_000
    n_vertices: int = 500
    n_vertex_labels: int = 1
    n_edge_labels: int = 8
    zipf_a: float = 1.3          # vertex-popularity skew
    ts_step_max: int = 3         # timestamps advance by U{0..step_max}
    seed: int = 0


def _zipf_choice(rng: np.random.Generator, n: int, size: int, a: float):
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** (-a)
    p /= p.sum()
    return rng.choice(n, size=size, p=p)


def synth_traffic_stream(cfg: StreamConfig) -> list[DataEdge]:
    """CAIDA-like: one vertex label, skewed ports as edge labels."""
    rng = np.random.default_rng(cfg.seed)
    src = _zipf_choice(rng, cfg.n_vertices, cfg.n_edges, cfg.zipf_a)
    dst = _zipf_choice(rng, cfg.n_vertices, cfg.n_edges, cfg.zipf_a)
    # skewed destination-port labels (top ports dominate, cf. §6.1)
    el = _zipf_choice(rng, cfg.n_edge_labels, cfg.n_edges, 1.8)
    ts = np.cumsum(rng.integers(0, cfg.ts_step_max + 1, cfg.n_edges))
    vl = rng.integers(0, cfg.n_vertex_labels, cfg.n_vertices)
    out = []
    for i in range(cfg.n_edges):
        if src[i] == dst[i]:
            dst[i] = (dst[i] + 1) % cfg.n_vertices
        out.append(
            DataEdge(
                int(src[i]), int(dst[i]), int(ts[i]),
                int(vl[src[i]]), int(vl[dst[i]]), int(el[i]),
            )
        )
    return out


def synth_social_stream(cfg: StreamConfig) -> list[DataEdge]:
    """LSBench-like: typed vertices (user/post/photo/gps), predicate labels."""
    cfg2 = StreamConfig(**{**cfg.__dict__, "n_vertex_labels": max(4, cfg.n_vertex_labels)})
    return synth_traffic_stream(cfg2)


# --------------------------------------------------------------------- #
def random_walk_query(
    stream: list[DataEdge],
    n_query_edges: int,
    seed: int = 0,
    window: int | None = None,
) -> QueryGraph | None:
    """§6.2 query generation: random walk + inherent-timestamp timing order.

    Walks edge-adjacent edges within (optionally) one window span, then
    relabels walked data vertices as query vertices.  Returns None when
    the walk cannot reach the requested length from the sampled start.
    """
    rng = np.random.default_rng(seed)
    if window is not None:
        t0 = stream[rng.integers(0, max(1, len(stream) - 1))].ts
        pool = [e for e in stream if t0 <= e.ts < t0 + window]
    else:
        pool = list(stream)
    if not pool:
        return None
    # adjacency over pool edges (shared endpoint)
    start = pool[rng.integers(0, len(pool))]
    walked: list[DataEdge] = [start]
    touched = {start.src, start.dst}
    used = {(start.src, start.dst, start.ts)}
    for _ in range(n_query_edges - 1):
        cands = [
            e for e in pool
            if (e.src in touched or e.dst in touched)
            and (e.src, e.dst, e.ts) not in used
            and (e.src, e.dst) not in {(w.src, w.dst) for w in walked}
            and e.src != e.dst
        ]
        if not cands:
            return None
        e = cands[rng.integers(0, len(cands))]
        walked.append(e)
        touched |= {e.src, e.dst}
        used.add((e.src, e.dst, e.ts))
    # relabel data vertices -> query vertices
    vmap: dict[int, int] = {}
    vlabels: list[int] = []
    qedges: list[tuple[int, int]] = []
    elabels: list[int] = []
    for e in walked:
        for dv, lbl in ((e.src, e.src_label), (e.dst, e.dst_label)):
            if dv not in vmap:
                vmap[dv] = len(vlabels)
                vlabels.append(lbl)
        qedges.append((vmap[e.src], vmap[e.dst]))
        elabels.append(e.edge_label)
    prec = frozenset(
        (i, j)
        for i in range(len(walked))
        for j in range(len(walked))
        if i < j and walked[i].ts < walked[j].ts
    )
    return QueryGraph(
        n_vertices=len(vlabels),
        vertex_labels=tuple(vlabels),
        edges=tuple(qedges),
        edge_labels=tuple(elabels),
        prec=prec,
    )


# --------------------------------------------------------------------- #
# disorder / multi-source emission (one seeded traffic model shared by
# the ingest tests, the chaos example, and benchmarks/bench_ingest.py)
# --------------------------------------------------------------------- #
@dataclass(frozen=True)
class DisorderConfig:
    """Seeded out-of-order / multi-source delivery model.

    The all-default config is the identity: one source, canonical order,
    no duplicates (``disordered_sources(stream)`` == the input stream as
    one identity script) — existing callers are untouched.

    * ``n_sources``      split the stream across k sources (seeded
      assignment; each source keeps its events' chronological order);
    * ``disorder_frac``  fraction of deliveries displaced to arrive
      late, by a lateness drawn uniformly from ``1..max_delay``
      delivery positions (a bounded lateness distribution);
    * ``duplicate_rate`` fraction of deliveries re-delivered a few
      positions later with their original sequence number (transport
      duplicates: suppressed-and-counted downstream, never new events).
    """

    n_sources: int = 1
    disorder_frac: float = 0.0
    max_delay: int = 8
    duplicate_rate: float = 0.0
    seed: int = 0


def split_stream(stream: list[DataEdge], n_sources: int,
                 seed: int = 0) -> list[list[DataEdge]]:
    """Seeded partition of a stream across ``n_sources``, preserving
    each source's chronological order (events interleave ACROSS sources
    the way independent capture points would emit them)."""
    rng = np.random.default_rng(seed)
    owner = rng.integers(0, n_sources, len(stream))
    return [[e for e, o in zip(stream, owner) if o == s]
            for s in range(n_sources)]


def disordered_sources(
    stream: list[DataEdge],
    cfg: DisorderConfig = DisorderConfig(),
) -> list[list[tuple[int, DataEdge]]]:
    """Per-source delivery scripts ``[(seq, edge), ...]`` for
    ``repro.stream.ingest.ScriptedSource``: the stream split across
    ``cfg.n_sources``, each source's deliveries displaced and duplicated
    per the config.  ``seq`` is the source's canonical order — repeated
    seqs are duplicate deliveries, out-of-order seqs are reordering —
    so the scripts stay exactly reconciliable with the original stream.
    """
    rng = np.random.default_rng(cfg.seed)
    scripts = []
    for part in split_stream(stream, cfg.n_sources, cfg.seed):
        deliveries = list(enumerate(part))
        # bounded-lateness displacement: sort by (position + delay)
        if cfg.disorder_frac > 0 and cfg.max_delay > 0:
            late = rng.random(len(deliveries)) < cfg.disorder_frac
            delay = rng.integers(1, cfg.max_delay + 1, len(deliveries))
            order = np.argsort(
                np.arange(len(deliveries)) + np.where(late, delay, 0),
                kind="stable")
            deliveries = [deliveries[i] for i in order]
        if cfg.duplicate_rate > 0:
            out = []
            for d in deliveries:
                out.append(d)
                if rng.random() < cfg.duplicate_rate:
                    out.append(d)     # immediate re-delivery, same seq
            deliveries = out
        scripts.append(deliveries)
    return scripts


def to_batches(stream: list[DataEdge], batch_size: int):
    """Chop a DataEdge list into padded EdgeBatch-ready dicts."""
    out = []
    for i in range(0, len(stream), batch_size):
        chunk = stream[i : i + batch_size]
        pad = batch_size - len(chunk)
        get = lambda f: np.array(
            [getattr(e, f) for e in chunk] + [0] * pad, np.int32)
        out.append(
            dict(
                src=get("src"), dst=get("dst"), ts=get("ts"),
                src_label=get("src_label"), dst_label=get("dst_label"),
                edge_label=get("edge_label"),
                valid=np.array([True] * len(chunk) + [False] * pad),
            )
        )
    return out
