"""Fault-tolerant ingestion frontier: sources -> merge -> watermark -> engine.

The engine (``ContinuousSearchService``) consumes pre-ordered in-process
batches; production streams arrive over flaky transports, interleaved
across sources, late, and occasionally backwards.  This module is the
boundary that turns that traffic into the ordered, exactly-once stream
the paper assumes:

* ``Source``          the transport protocol: ``connect(resume_from)`` /
  ``poll(max_events)`` / ``close()``.  A transport failure raises
  ``SourceDisconnected``; events carry a per-source sequence cursor
  (``SourceEvent.seq``) so a reconnect can resume without loss.
  ``ScriptedSource`` replays a deterministic delivery script (seq may
  repeat = duplicate delivery, arrive out of order = reordering);
  ``ListSource`` is the identity script over a ``DataEdge`` list.
* ``SourceAdapter``   wraps a ``Source`` with bounded retry + exponential
  backoff (``repro.runtime.fault.RetryPolicy`` — the same policy object
  ``FaultTolerantLoop`` uses for restarts), reconnect-with-resume from
  the sequence cursor, and duplicate suppression with counted dedups
  (``SeqTracker``): every suppressed delivery is counted, never silent.
* ``IngestFrontier``  the deterministic k-way event-time merge + the
  watermark.  Merge ties break by the btengine ladder (SNIPPETS.md):
  event_time -> received_time (when a transport stamps one) ->
  deterministic event metadata (the full edge payload) -> source order
  -> sequence.  A bounded reorder buffer holds events until the
  watermark (min over live sources of max-event-time, minus
  ``allowed_lateness``) passes them; events arriving later than the
  allowed lateness are dropped AND counted (``n_late_dropped``,
  ``on("drop_late")``).  ``strict_event_time_monotonic=True`` is the
  fail-fast alternative: any per-source event-time regression raises
  ``MonotonicityError`` instead of being buffered.
* exactly-once resume: ``to_manifest()`` captures per-source ack cursors
  (contiguous floor + sparse extras for out-of-order emission) and the
  emit floor; it rides inside service checkpoints
  (``ContinuousSearchService._manifest()["ingest"]``), and
  ``IngestFrontier.resume(manifest, sources)`` reconnects every source
  at its cursor — replayed deliveries of already-ingested events are
  suppressed by the restored trackers, so a crash/restore through the
  ingest layer yields the exact match multiset of an uninterrupted run
  (tests/test_ingest_chaos.py).

``merge_event_streams`` is the offline k-way merge over already-ordered
lists (the same tie-break ladder, property-tested in
tests/test_ingest_merge.py).  ``CallbackRegistry`` is the subscription
surface: ``frontier.on("event" | "drop_late" | "drop_forced_gap" |
"duplicate" | "reconnect" | "stall", fn)``.

Everything here is host-side, deterministic Python: time and sleep are
injectable, jitter draws from a seeded rng, and the chaos harness
(``repro.stream.chaos``) scripts its faults from a seed — so every test
and benchmark over this layer is reproducible.
"""

from __future__ import annotations

import heapq
import time
from dataclasses import dataclass
from typing import Callable, Iterable

import numpy as np

from repro.core.oracle import DataEdge
from repro.runtime.fault import RetryPolicy

# adapter / source lifecycle states
CONNECTED = "connected"
RETRYING = "retrying"
FAILED = "failed"
EXHAUSTED = "exhausted"


class IngestError(RuntimeError):
    """Unrecoverable ingest failure (retry budget exhausted, bad resume)."""


class SourceDisconnected(RuntimeError):
    """Transient transport failure: the adapter reconnects with backoff."""


class MonotonicityError(IngestError):
    """strict_event_time_monotonic: a source's event time went backwards."""


@dataclass(frozen=True)
class SourceEvent:
    """One delivery from a transport.

    ``seq`` is the source's own sequence cursor — contiguous per source
    in canonical order, NOT necessarily in delivery order (reordering)
    and not necessarily unique across deliveries (duplicate delivery).
    ``recv_ts`` is the transport's received-time stamp when it has one;
    in-process replays leave it None and the merge ladder skips it.
    """

    edge: DataEdge
    seq: int
    recv_ts: int | None = None

    @property
    def ts(self) -> int:
        return self.edge.ts


class Source:
    """Transport protocol.  Implementations must be resumable: after
    ``connect(resume_from=c)``, every event with ``seq >= c`` that has
    not been delivered since that connect must (eventually) be delivered
    again; deliveries with ``seq < c`` are allowed (at-least-once) and
    suppressed downstream."""

    name: str = "source"

    def connect(self, resume_from: int = 0) -> None:
        raise NotImplementedError

    def poll(self, max_events: int = 64) -> list[SourceEvent]:
        raise NotImplementedError

    def close(self) -> None:
        pass

    @property
    def exhausted(self) -> bool:
        return False


class ScriptedSource(Source):
    """Deterministic transport: replays a delivery script of
    ``(seq, DataEdge)`` pairs in order.  The script may repeat seqs
    (duplicate delivery) and deliver them out of canonical order
    (reordering) — ``repro.stream.generator.disordered_sources`` builds
    such scripts from one seeded traffic model.

    ``connect(resume_from)`` rewinds to the earliest script position
    holding any ``seq >= resume_from``; earlier-seq entries after that
    position are simply delivered again (at-least-once) and suppressed
    by the adapter's tracker.
    """

    def __init__(self, name: str, script: list[tuple[int, DataEdge]]):
        self.name = name
        self._script = list(script)
        self._pos = 0
        self._connected = False

    def connect(self, resume_from: int = 0) -> None:
        self._pos = next(
            (i for i, (s, _) in enumerate(self._script) if s >= resume_from),
            len(self._script))
        self._connected = True

    def poll(self, max_events: int = 64) -> list[SourceEvent]:
        if not self._connected:
            raise SourceDisconnected(f"{self.name}: poll before connect")
        out = [SourceEvent(edge=e, seq=s)
               for s, e in self._script[self._pos:self._pos + max_events]]
        self._pos += len(out)
        return out

    def close(self) -> None:
        self._connected = False

    @property
    def exhausted(self) -> bool:
        return self._connected and self._pos >= len(self._script)


class ListSource(ScriptedSource):
    """The identity script: deliver a ``DataEdge`` list in order, seq =
    list index."""

    def __init__(self, name: str, edges: Iterable[DataEdge]):
        super().__init__(name, [(i, e) for i, e in enumerate(edges)])


class CallbackRegistry:
    """Subscription registry for ingest lifecycle events.

    Kinds: ``event`` (one emitted DataEdge), ``drop_late`` (source name,
    edge, seq), ``drop_forced_gap`` (source name, edge, seq: dropped
    because forced evictions advanced the emit floor past the watermark
    — capacity pressure, not user-visible lateness), ``duplicate``
    (source name, seq), ``reconnect`` (source name, attempt, delay_s),
    ``stall`` (source name, rounds), ``watermark`` (new watermark).
    Unknown kinds are rejected loudly — a typo'd subscription must not
    become a silent no-listener.
    """

    KINDS = ("event", "drop_late", "drop_forced_gap", "duplicate",
             "reconnect", "stall", "watermark")

    def __init__(self):
        self._subs: dict[str, list[Callable]] = {k: [] for k in self.KINDS}

    def on(self, kind: str, fn: Callable) -> Callable:
        if kind not in self._subs:
            raise ValueError(
                f"unknown callback kind {kind!r}; one of {self.KINDS}")
        self._subs[kind].append(fn)
        return fn

    def emit(self, kind: str, *args) -> None:
        for fn in self._subs[kind]:
            fn(*args)


class SeqTracker:
    """Which sequence numbers of one source have been seen/acked:
    a contiguous floor (all ``seq < floor`` seen) plus a sparse set of
    out-of-order extras above it.  ``add`` returns False for an
    already-seen seq (= duplicate delivery)."""

    def __init__(self, floor: int = 0, extras: Iterable[int] = ()):
        self.floor = floor
        self.extras = set(extras)
        self._compact()

    def _compact(self) -> None:
        while self.floor in self.extras:
            self.extras.discard(self.floor)
            self.floor += 1

    def add(self, seq: int) -> bool:
        if seq < self.floor or seq in self.extras:
            return False
        if seq == self.floor:
            self.floor += 1
            self._compact()
        else:
            self.extras.add(seq)
        return True

    def __contains__(self, seq: int) -> bool:
        return seq < self.floor or seq in self.extras

    def to_manifest(self) -> dict:
        return {"floor": self.floor, "extras": sorted(self.extras)}

    @classmethod
    def from_manifest(cls, man: dict) -> "SeqTracker":
        return cls(int(man["floor"]), (int(x) for x in man["extras"]))


class SourceAdapter:
    """One source behind retry/backoff, reconnect-with-resume, and
    counted duplicate suppression.

    ``pull(max_events)`` polls the source; a ``SourceDisconnected`` from
    ``poll`` (or ``connect``) triggers reconnect-with-resume from the
    tracker's floor, with delays from the shared ``RetryPolicy``
    (injectable ``sleep``; jitter from the seeded rng).  When the retry
    budget is exhausted the adapter enters ``FAILED`` and raises
    ``IngestError`` — a dead source is loud, never a silent stall.
    Deliveries whose seq the tracker has already seen are suppressed and
    counted in ``n_duplicates``.
    """

    def __init__(
        self,
        source: Source,
        retry: RetryPolicy | None = None,
        sleep: Callable[[float], None] = time.sleep,
        seed: int = 0,
        callbacks: CallbackRegistry | None = None,
        tracker: SeqTracker | None = None,
    ):
        self.source = source
        self.retry = retry if retry is not None else RetryPolicy()
        self.sleep = sleep
        self.rng = np.random.default_rng(seed)
        self.callbacks = callbacks
        self.seen = tracker if tracker is not None else SeqTracker()
        # acked = delivered DOWNSTREAM to the engine (or counted as a
        # late drop): the durable cursor that rides in checkpoints.
        # ``seen`` additionally covers pulled-but-unemitted events; it
        # is rebuilt from ``acked`` on resume (lost buffer = replayed).
        self.acked = SeqTracker(self.seen.floor, self.seen.extras)
        self.state = RETRYING
        self.high: int | None = None      # max event ts seen (watermark input)
        self.last_ts: int | None = None   # last pulled ts (strict mode)
        self.stall_rounds = 0
        self.n_events = 0
        self.n_duplicates = 0
        self.n_reconnects = 0
        self.n_retries = 0
        self._connect(initial=True)

    @property
    def name(self) -> str:
        return self.source.name

    @property
    def exhausted(self) -> bool:
        # FAILED is terminal for exhaustion: the retry budget is spent
        # and this adapter will never produce again, so it must not hold
        # ``IngestFrontier.exhausted`` open forever (a caller that
        # swallowed the IngestError and kept serving would busy-loop on
        # empty rounds).  It stays loud in ``stats()`` via its state and
        # ``n_failed_sources``.
        return self.state in (EXHAUSTED, FAILED) or (
            self.state == CONNECTED and self.source.exhausted)

    def _connect(self, initial: bool = False) -> None:
        attempt = 0
        while True:
            try:
                self.source.connect(resume_from=self.seen.floor)
                self.state = CONNECTED
                if not initial:
                    self.n_reconnects += 1
                return
            except SourceDisconnected:
                attempt += 1
                self._backoff(attempt)

    def _backoff(self, attempt: int) -> None:
        self.n_retries += 1
        if self.retry.exhausted(attempt):
            self.state = FAILED
            raise IngestError(
                f"source {self.name!r}: retry budget exhausted after "
                f"{attempt - 1} reconnect attempts")
        self.state = RETRYING
        delay = self.retry.delay(attempt, self.rng)
        if self.callbacks is not None:
            self.callbacks.emit("reconnect", self.name, attempt, delay)
        self.sleep(delay)

    def pull(self, max_events: int = 64) -> list[SourceEvent]:
        """Poll once (reconnecting through failures); returns the new,
        deduplicated deliveries."""
        if self.state == FAILED:
            raise IngestError(f"source {self.name!r} is failed")
        attempt = 0
        while True:
            try:
                raw = self.source.poll(max_events)
                break
            except SourceDisconnected:
                attempt += 1
                self._backoff(attempt)
                self._connect()
        out = []
        for ev in raw:
            if not self.seen.add(ev.seq):
                self.n_duplicates += 1
                if self.callbacks is not None:
                    self.callbacks.emit("duplicate", self.name, ev.seq)
                continue
            self.n_events += 1
            self.high = ev.ts if self.high is None else max(self.high, ev.ts)
            out.append(ev)
        if self.source.exhausted:
            self.state = EXHAUSTED
        self.stall_rounds = 0 if raw else self.stall_rounds + 1
        return out

    def ack(self, seq: int) -> None:
        self.acked.add(seq)


class IngestStats(dict):
    """Counters of the whole frontier (attribute access for ergonomics)."""

    __getattr__ = dict.__getitem__


def _ladder_key(ev: SourceEvent, src_idx: int):
    """The btengine tie-break ladder: event_time -> received_time (when
    stamped) -> deterministic event metadata (full edge payload) ->
    source order -> sequence.  Total and deterministic: two deliveries
    compare equal only if they are payload-identical, in which case
    either order is the same merged sequence."""
    e = ev.edge
    return (e.ts,
            0 if ev.recv_ts is None else ev.recv_ts,
            (e.src, e.dst, e.edge_label, e.src_label, e.dst_label),
            src_idx,
            ev.seq)


def merge_event_streams(
    streams: list[list[DataEdge]],
    strict_event_time_monotonic: bool = False,
) -> list[DataEdge]:
    """Offline deterministic k-way merge of per-source ordered lists.

    Each input list must be ordered by event time (``strict...=True``
    raises ``MonotonicityError`` on any regression; the default tolerates
    equal-ts plateaus and silently ACCEPTS unordered inputs the way a
    heap merge does — callers with disorder want ``IngestFrontier``).
    Ties across streams break by the ladder, so the merged order is
    independent of the order the streams are listed in (property-tested).
    """
    for si, s in enumerate(streams):
        for a, b in zip(s, s[1:]):
            if b.ts < a.ts:
                if strict_event_time_monotonic:
                    raise MonotonicityError(
                        f"stream {si}: event time regressed "
                        f"{a.ts} -> {b.ts}")
    heap = []
    for si, s in enumerate(streams):
        for i, e in enumerate(s):
            heap.append((_ladder_key(SourceEvent(e, i), si)[:3] + (i,), e))
    # source index is dropped from the sort key ABOVE the sequence so
    # listing order cannot leak into the merged order; payload-identical
    # ties are interchangeable anyway
    heap.sort(key=lambda t: t[0])
    return [e for _, e in heap]


# Internal "every source is done: drain the buffer" release bound.  Big
# enough that every real event timestamp is at-or-below it; it never
# leaves the frontier (``watermark()`` surfaces real timestamps or None).
_DRAIN = 2 ** 63 - 1


class IngestFrontier:
    """K-way event-time merge + watermarked reorder buffer over N
    fault-wrapped sources; the producer side of
    ``ContinuousSearchService.serve_frontier``.

    ``pump()`` pulls a round from every live source into the reorder
    buffer (heap on the ladder key); ``take_ready(limit)`` pops every
    buffered event at or below the watermark — min over live sources of
    their max seen event time, minus ``allowed_lateness`` — in merged
    order, advancing the emit floor.  An event arriving with
    ``ts < emit_floor`` is later than the allowed lateness: it is
    dropped and counted (never silent).  A source that stalls for more
    than ``stall_patience`` consecutive empty rounds stops holding the
    watermark back (counted + ``on("stall")``) until it produces again.
    If the buffer exceeds ``reorder_capacity`` the oldest events are
    force-emitted past the watermark (counted in ``n_forced``).
    """

    def __init__(
        self,
        sources: Iterable[Source | SourceAdapter],
        allowed_lateness: int = 0,
        reorder_capacity: int = 4096,
        strict_event_time_monotonic: bool = False,
        stall_patience: int = 8,
        retry: RetryPolicy | None = None,
        sleep: Callable[[float], None] = time.sleep,
        seed: int = 0,
        _resume: dict | None = None,
    ):
        if allowed_lateness < 0 or reorder_capacity < 1:
            raise ValueError(
                "need allowed_lateness >= 0 and reorder_capacity >= 1")
        self.allowed_lateness = allowed_lateness
        self.reorder_capacity = reorder_capacity
        self.strict = strict_event_time_monotonic
        self.stall_patience = stall_patience
        self.callbacks = CallbackRegistry()
        cursors = {} if _resume is None else {
            s["name"]: SeqTracker.from_manifest(s)
            for s in _resume["sources"]}
        self.adapters: list[SourceAdapter] = []
        for i, s in enumerate(sources):
            if isinstance(s, SourceAdapter):
                s.callbacks = self.callbacks
                self.adapters.append(s)
            else:
                self.adapters.append(SourceAdapter(
                    s, retry=retry, sleep=sleep, seed=seed + i,
                    callbacks=self.callbacks,
                    tracker=cursors.get(s.name)))
        names = [a.name for a in self.adapters]
        if len(set(names)) != len(names):
            raise IngestError(
                f"source names must be unique (resume cursors key on "
                f"them): {names}")
        if _resume is not None:
            missing = set(cursors) - set(names)
            if missing:
                raise IngestError(
                    f"resume manifest names sources not provided: "
                    f"{sorted(missing)}")
        self._heap: list[tuple[tuple, int, SourceEvent]] = []
        self.emit_floor: int | None = None
        self.n_emitted = 0
        self.n_late_dropped = 0
        self.n_dropped_forced_gap = 0
        self.n_forced = 0
        self.n_stalled_rounds = 0
        # monotone event-time watermark floor: the highest finite release
        # bound ever observed (persisted in the manifest, so a restored
        # frontier's clock can never regress below the checkpoint's)
        self._wm_floor: int | None = None
        if _resume is not None:
            self.emit_floor = _resume.get("emit_floor")
            self._wm_floor = _resume.get("watermark")
            c = _resume.get("counters", {})
            self.n_emitted = int(c.get("n_emitted", 0))
            self.n_late_dropped = int(c.get("n_late_dropped", 0))
            self.n_dropped_forced_gap = int(
                c.get("n_dropped_forced_gap", 0))
            self.n_forced = int(c.get("n_forced", 0))

    # ------------------------------------------------------------------ #
    def on(self, kind: str, fn: Callable) -> Callable:
        """Subscribe to ingest lifecycle events (``CallbackRegistry``)."""
        return self.callbacks.on(kind, fn)

    @property
    def buffered(self) -> int:
        return len(self._heap)

    @property
    def exhausted(self) -> bool:
        return not self._heap and all(a.exhausted for a in self.adapters)

    # ------------------------------------------------------------------ #
    def pump(self, max_per_source: int = 64) -> int:
        """One pull round over every live source; buffers (or late-drops)
        the new deliveries.  Returns how many entered the buffer."""
        n_in = 0
        for si, a in enumerate(self.adapters):
            if a.exhausted:                # includes terminal FAILED
                continue
            evs = a.pull(max_per_source)
            if not evs and a.stall_rounds == self.stall_patience + 1:
                self.callbacks.emit("stall", a.name, a.stall_rounds)
            if a.stall_rounds > self.stall_patience:
                self.n_stalled_rounds += 1
            for ev in evs:
                if self.strict and a.last_ts is not None \
                        and ev.ts < a.last_ts:
                    raise MonotonicityError(
                        f"source {a.name!r}: event time regressed "
                        f"{a.last_ts} -> {ev.ts} "
                        "(strict_event_time_monotonic)")
                a.last_ts = ev.ts
                if self.emit_floor is not None and ev.ts < self.emit_floor:
                    # the merged stream already advanced past this event
                    # time.  Dropped, counted, acked (accounted-for =
                    # consumed) — but attributed by CAUSE: at-or-below
                    # the watermark means the event really arrived later
                    # than the allowed lateness; above it means forced
                    # evictions (reorder-buffer capacity) advanced the
                    # emit floor past the watermark, which is capacity
                    # pressure, not user-visible lateness.
                    wm = self.watermark()
                    if wm is not None and ev.ts <= wm:
                        self.n_late_dropped += 1
                        kind = "drop_late"
                    else:
                        self.n_dropped_forced_gap += 1
                        kind = "drop_forced_gap"
                    a.ack(ev.seq)
                    self.callbacks.emit(kind, a.name, ev.edge, ev.seq)
                    continue
                heapq.heappush(self._heap, (_ladder_key(ev, si), si, ev))
                n_in += 1
        return n_in

    def _release_bound(self) -> int | None:
        """Internal release gate for ``take_ready``: min over live
        (non-exhausted, non-stalled-out) sources of the max event time
        seen, minus the allowed lateness.  None while any live source has
        produced nothing yet (nothing is safe to emit); the ``_DRAIN``
        sentinel when no live source remains (drain the buffer)."""
        highs = []
        for a in self.adapters:
            if a.exhausted:
                continue
            if a.stall_rounds > self.stall_patience:
                continue      # stalled out: stops holding the line back
            if a.high is None:
                return None   # a live source with no data yet: hold all
            highs.append(a.high)
        if not highs:
            return _DRAIN                 # every source done: drain
        return min(highs) - self.allowed_lateness

    def watermark(self) -> int | None:
        """The frontier's event-time watermark: a monotone, None-safe
        clock for stats, health hooks, and the engine's event-time tick
        input.  ``None`` until any release bound is known; thereafter the
        highest finite release bound observed — and, once every source
        is done, the emit floor (all events released ⇒ event time has
        advanced to everything emitted).  Never the internal ``_DRAIN``
        sentinel: downstream consumers see real event timestamps only.
        """
        b = self._release_bound()
        if b is not None and b != _DRAIN:
            if self._wm_floor is None or b > self._wm_floor:
                self._wm_floor = b
        elif b == _DRAIN and self.emit_floor is not None:
            if self._wm_floor is None or self.emit_floor > self._wm_floor:
                self._wm_floor = self.emit_floor
        return self._wm_floor

    def take_ready(self, limit: int | None = None) -> list[DataEdge]:
        """Pop emit-ready events in merged order: everything at or below
        the release bound, plus forced evictions while the buffer exceeds
        ``reorder_capacity``.  Advances the emit floor; acks each."""
        wm = self._release_bound()
        out: list[DataEdge] = []
        while self._heap and (limit is None or len(out) < limit):
            key, si, ev = self._heap[0]
            forced = len(self._heap) > self.reorder_capacity
            if not forced and (wm is None or ev.ts > wm):
                break
            heapq.heappop(self._heap)
            if forced and (wm is None or ev.ts > wm):
                self.n_forced += 1
            self.emit_floor = ev.ts if self.emit_floor is None \
                else max(self.emit_floor, ev.ts)
            self.adapters[si].ack(ev.seq)
            self.n_emitted += 1
            self.callbacks.emit("event", ev.edge)
            out.append(ev.edge)
        return out

    def drain(self, max_per_source: int = 64) -> list[DataEdge]:
        """Pump + take everything ready (offline convenience: loop this
        until ``exhausted`` to consume finite sources end-to-end)."""
        self.pump(max_per_source)
        return self.take_ready()

    # ------------------------------------------------------------------ #
    def stats(self) -> IngestStats:
        wm = self.watermark()
        highs = [a.high for a in self.adapters if a.high is not None]
        return IngestStats(
            n_sources=len(self.adapters),
            n_failed_sources=sum(
                1 for a in self.adapters if a.state == FAILED),
            n_emitted=self.n_emitted,
            n_late_dropped=self.n_late_dropped,
            n_dropped_forced_gap=self.n_dropped_forced_gap,
            n_duplicates=sum(a.n_duplicates for a in self.adapters),
            n_reconnects=sum(a.n_reconnects for a in self.adapters),
            n_retries=sum(a.n_retries for a in self.adapters),
            n_forced=self.n_forced,
            n_stalled_rounds=self.n_stalled_rounds,
            buffered=len(self._heap),
            watermark=wm,
            # how far the freshest data runs ahead of the watermark
            # (reorder/lateness slack held back by the slowest source)
            watermark_lag=(max(highs) - wm)
            if highs and wm is not None else 0,
            # how far forced evictions pushed releases past the
            # watermark (capacity pressure; 0 in healthy operation)
            window_staleness=max(0, self.emit_floor - wm)
            if wm is not None and self.emit_floor is not None else 0,
            emit_floor=self.emit_floor,
            by_source={a.name: {
                "state": a.state, "n_events": a.n_events,
                "n_duplicates": a.n_duplicates,
                "n_reconnects": a.n_reconnects, "cursor": a.acked.floor,
            } for a in self.adapters},
        )

    def publish_obs(self, obs) -> None:
        """Mirror the frontier's counters/gauges into a
        ``repro.obs.MetricsRegistry`` under ``ingest.*``.

        Called once per serve tick by ``serve_frontier`` when the
        service carries a registry.  Reads plain int attributes only
        (no ``IngestStats`` construction); counters use ``set_total``
        so a frontier resumed from a checkpoint (which restores its own
        counters from the same manifest the registry restores from)
        never double-counts.
        """
        obs.counter("ingest.n_emitted").set_total(self.n_emitted)
        obs.counter("ingest.n_late_dropped").set_total(self.n_late_dropped)
        obs.counter("ingest.n_dropped_forced_gap").set_total(
            self.n_dropped_forced_gap)
        obs.counter("ingest.n_forced").set_total(self.n_forced)
        obs.counter("ingest.n_duplicates").set_total(
            sum(a.n_duplicates for a in self.adapters))
        obs.counter("ingest.n_reconnects").set_total(
            sum(a.n_reconnects for a in self.adapters))
        wm = self._wm_floor
        if wm is not None:
            obs.gauge("ingest.watermark").set(wm)
            highs = [a.high for a in self.adapters if a.high is not None]
            obs.gauge("ingest.watermark_lag").set(
                max(highs) - wm if highs else 0)
            if self.emit_floor is not None:
                obs.gauge("ingest.window_staleness").set(
                    max(0, self.emit_floor - wm))
        obs.gauge("ingest.buffered").set(len(self._heap))

    # ------------------------------------------------------------------ #
    # checkpoint / resume
    # ------------------------------------------------------------------ #
    def to_manifest(self) -> dict:
        """JSON-serializable resume state: per-source ack cursors + the
        emit floor + drop accounting.  Reflects exactly what has been
        handed DOWNSTREAM (emitted or counted-dropped) — events still in
        the reorder buffer are deliberately not covered, so a restore
        replays them from their sources."""
        return {
            "sources": [
                {"name": a.name, **a.acked.to_manifest()}
                for a in self.adapters
            ],
            "emit_floor": self.emit_floor,
            # the event-time clock rides in the checkpoint so a restored
            # frontier (and the engines it feeds) can never regress below
            # the released floor — no re-expiry, no resurrection
            "watermark": self.watermark(),
            "counters": {
                "n_emitted": int(self.n_emitted),
                "n_late_dropped": int(self.n_late_dropped),
                "n_dropped_forced_gap": int(self.n_dropped_forced_gap),
                "n_forced": int(self.n_forced),
            },
        }

    @classmethod
    def resume(cls, manifest: dict, sources: Iterable[Source],
               **kwargs) -> "IngestFrontier":
        """Rebuild a frontier from a checkpoint manifest + fresh source
        transports: each source reconnects at its ack cursor, replayed
        already-consumed deliveries are suppressed by the restored
        trackers, and the emit floor / drop counters continue — the
        exactly-once resume path (tested differentially)."""
        return cls(sources, _resume=manifest, **kwargs)
