"""Typed stream records for the public API: events in, matches out.

The engine speaks dense ``int32`` label ids and padded ``EdgeBatch``
arrays; tenants speak domain tokens ("login", "xfer") and individual
timestamped events.  This module is the boundary:

* ``LabelVocab``   interns str/int label tokens into the id space the
  engine compares — ints identity-mapped, strings from ``STR_BASE`` up
  (checkpoint-serializable, so a restored session keeps speaking the
  same tokens, and raw ``DataEdge`` streams stay aligned with
  int-labeled patterns);
* ``Event``        one typed stream edge with an explicit timestamp;
* ``Match``        one reported match, bindings keyed by the pattern's
  vertex/edge *names* (hashable, so differential tests can treat match
  streams as multisets);
* ``EventBuffer``  batches events into the service's power-of-two padded
  chunk shapes (``quantize_pow2``) so ad-hoc ingest sizes produce a
  bounded set of jit specializations.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core.oracle import DataEdge
from repro.runtime.straggler import quantize_pow2

#: Vocabulary token carried by vertices/events that declare no label.
#: A pattern vertex without a label only matches unlabeled endpoints —
#: the engine has no vertex-label wildcard (edge labels DO have one:
#: a pattern edge with ``label=None`` matches any event label).
UNLABELED = "__unlabeled__"

#: String tokens intern at ids >= STR_BASE; integer tokens map to
#: THEMSELVES.  This keeps raw ``DataEdge`` streams (already in engine
#: label space, passed through untouched) exactly aligned with
#: int-labeled patterns — without identity mapping, ``label=2`` could
#: intern to engine id 0 depending on declaration order and raw streams
#: would silently compare mismatched ids.
STR_BASE = 1 << 20


class LabelVocab:
    """Label-token interning (JSON round-trippable).

    One vocab is shared by every pattern and every event of a session,
    so "login" means the same engine id on both sides.  Integer tokens
    ARE their engine id (identity — see ``STR_BASE``); string tokens get
    dense ids from ``STR_BASE`` up, so the two ranges never collide.
    Tokens must be ``str`` or non-negative ``int < STR_BASE`` — the
    vocab is persisted inside the checkpoint manifest, and negative ints
    would collide with the engine's edge-label wildcard (-1).
    """

    def __init__(self, tokens=()):
        self._ids: dict = {}
        self._tokens: list = []      # str tokens, id = STR_BASE + index
        for t in tokens:
            self.intern(t)

    def intern(self, token) -> int:
        if isinstance(token, bool) or not isinstance(token, (str, int)):
            raise TypeError(
                f"label tokens must be str or int, got {token!r} "
                "(they are persisted in checkpoint manifests)")
        if isinstance(token, int):
            if not 0 <= token < STR_BASE:
                raise ValueError(
                    f"int label tokens must be in [0, {STR_BASE}), got "
                    f"{token} (negative collides with the wildcard, "
                    "larger with the string-token range)")
            return token
        lid = self._ids.get(token)
        if lid is None:
            lid = STR_BASE + len(self._tokens)
            self._ids[token] = lid
            self._tokens.append(token)
        return lid

    def token(self, lid: int):
        return self._tokens[lid - STR_BASE] if lid >= STR_BASE else lid

    def __len__(self) -> int:
        return len(self._tokens)

    def __contains__(self, token) -> bool:
        if isinstance(token, int) and not isinstance(token, bool):
            return 0 <= token < STR_BASE
        return token in self._ids

    def to_json(self) -> list:
        return list(self._tokens)

    @classmethod
    def from_json(cls, tokens: list) -> "LabelVocab":
        return cls(tokens)


class Event(NamedTuple):
    """One stream edge: ``src --label--> dst`` at time ``ts``.

    ``src``/``dst`` are the caller's integer vertex ids; labels are
    vocab tokens (str or int) or ``None`` for unlabeled.
    """

    src: int
    dst: int
    ts: int
    label: object = None
    src_label: object = None
    dst_label: object = None


def to_data_edge(event, vocab: LabelVocab) -> DataEdge:
    """Lower an ``Event`` into engine space; ``DataEdge``s pass through
    untouched (they are already in engine label space)."""
    if isinstance(event, DataEdge):
        return event
    return DataEdge(
        src=int(event.src), dst=int(event.dst), ts=int(event.ts),
        src_label=vocab.intern(
            UNLABELED if event.src_label is None else event.src_label),
        dst_label=vocab.intern(
            UNLABELED if event.dst_label is None else event.dst_label),
        edge_label=vocab.intern(
            UNLABELED if event.label is None else event.label),
    )


def as_source(name: str, events, vocab: LabelVocab):
    """Lower a list of typed ``Event``s (or raw ``DataEdge``s) into a
    resumable ``repro.stream.ingest.ListSource`` for the ingestion
    frontier — the session-side source registration hook
    (``StreamSession.sources``).  The vocab translation happens here,
    once, so the frontier and engine speak dense ids only."""
    from repro.stream.ingest import ListSource
    return ListSource(name, [to_data_edge(e, vocab) for e in events])


class Match(NamedTuple):
    """One reported match, in the pattern's own vocabulary.

    ``vertices``: ``(vertex_name, data_vertex_id)`` pairs in authoring
    order; ``edges``: ``(edge_name, matched_edge_timestamp)`` pairs in
    authoring order.  NamedTuple of tuples → hashable, so match streams
    form multisets (``collections.Counter``) in differential tests.
    """

    vertices: tuple
    edges: tuple

    @property
    def bindings(self) -> dict:
        """``{vertex_name: data_vertex_id}``."""
        return dict(self.vertices)

    @property
    def times(self) -> dict:
        """``{edge_name: timestamp}`` of the matched stream edges."""
        return dict(self.edges)

    @property
    def ts(self) -> int:
        """Completion time: the newest matched edge's timestamp."""
        return max(t for _, t in self.edges)


class EventBuffer:
    """Batches events into the service's padded pow-2 chunk dicts.

    ``push`` returns a ready batch every ``batch_size`` events (``None``
    otherwise); ``flush`` pads the tail.  Every emitted chunk is padded
    to ``quantize_pow2`` length, so a session ingesting arbitrary-sized
    event lists still presents a bounded set of batch shapes to the
    jitted slot ticks.
    """

    def __init__(self, vocab: LabelVocab, batch_size: int = 64):
        if batch_size <= 0:
            raise ValueError("batch_size must be positive")
        self.vocab = vocab
        self.batch_size = batch_size
        self._pending: list[DataEdge] = []

    def __len__(self) -> int:
        return len(self._pending)

    def push(self, event) -> dict | None:
        self._pending.append(to_data_edge(event, self.vocab))
        if len(self._pending) >= self.batch_size:
            return self.flush()
        return None

    def flush(self) -> dict | None:
        """Emit the pending tail as one padded batch dict (or ``None``)."""
        if not self._pending:
            return None
        chunk, self._pending = self._pending, []
        width = quantize_pow2(len(chunk))
        pad = width - len(chunk)
        get = lambda f: np.array(
            [getattr(e, f) for e in chunk] + [0] * pad, np.int32)
        return dict(
            src=get("src"), dst=get("dst"), ts=get("ts"),
            src_label=get("src_label"), dst_label=get("dst_label"),
            edge_label=get("edge_label"),
            valid=np.array([True] * len(chunk) + [False] * pad),
        )
