"""Fluent, declarative timing-constrained pattern DSL.

The paper's interface is declarative: state a timing-constrained query
pattern, continuously receive matches.  ``Pattern`` is that statement —
named vertices, labelled edges, ``before`` timing constraints, one
sliding window::

    p = (Pattern("lateral-movement")
         .edge("a", "b", label="login")
         .edge("b", "c", label="xfer")
         .before(0, 1)          # login strictly precedes xfer
         .window(300))

Edges are referred to by authoring index (0, 1, ...) or by an explicit
``name=``; vertices are named strings and may carry labels (declared
inline at first mention via ``.vertex`` or left unlabeled).  ``build``
lowers the pattern into the internal ``QueryGraph`` *as authored* —
canonicalization (so differently-authored isomorphic patterns share one
compiled slot tick) is the planner's job (``repro.api.planner``).
"""

from __future__ import annotations

from repro.api.events import UNLABELED, LabelVocab
from repro.core.query import QueryGraph


class PatternError(ValueError):
    """A malformed pattern (caught at authoring/build time, not serving)."""


class Pattern:
    """Fluent builder for one timing-constrained continuous query."""

    def __init__(self, name: str | None = None):
        self.name = name
        self._vertices: list[str] = []          # first-mention order
        self._vertex_labels: dict[str, object] = {}
        self._edges: list[tuple[str, str, object]] = []   # (src, dst, label)
        self._edge_names: list[str] = []
        self._before: set[tuple[int, int]] = set()
        self._window: int | None = None

    # ------------------------------------------------------------------ #
    def vertex(self, name: str, label=None) -> "Pattern":
        """Declare a vertex, optionally labelled.  Re-declaring with a
        different label is an error (labels are identity, not hints)."""
        self._touch_vertex(name)
        if label is not None:
            prev = self._vertex_labels.get(name)
            if prev is not None and prev != label:
                raise PatternError(
                    f"vertex {name!r} relabelled: {prev!r} -> {label!r}")
            self._vertex_labels[name] = label
        return self

    def edge(self, src: str, dst: str, label=None, name: str | None = None,
             src_label=None, dst_label=None) -> "Pattern":
        """Add a directed pattern edge ``src -> dst``.

        ``label=None`` is a wildcard (matches any event label);
        ``src_label``/``dst_label`` are shorthand for ``.vertex`` calls.
        """
        if src == dst:
            raise PatternError(f"self-loop {src!r} -> {dst!r} not supported")
        if (src, dst) in {(s, d) for s, d, _ in self._edges}:
            raise PatternError(f"duplicate parallel edge {src!r} -> {dst!r}")
        self.vertex(src, src_label)
        self.vertex(dst, dst_label)
        ename = name if name is not None else f"e{len(self._edges)}"
        if ename in self._edge_names:
            raise PatternError(f"duplicate edge name {ename!r}")
        self._edges.append((src, dst, label))
        self._edge_names.append(ename)
        return self

    def before(self, first, second) -> "Pattern":
        """Timing constraint: edge ``first`` strictly precedes ``second``
        (by authoring index or ``name=``).  Transitive closure and
        strictness are validated at build."""
        self._before.add((self._edge_id(first), self._edge_id(second)))
        return self

    def window(self, span: int) -> "Pattern":
        """Sliding-window span in timestamp units."""
        if span <= 0:
            raise PatternError(f"window span must be positive, got {span}")
        self._window = int(span)
        return self

    # ------------------------------------------------------------------ #
    def _touch_vertex(self, name: str):
        if not isinstance(name, str) or not name:
            raise PatternError(f"vertex names must be non-empty str: {name!r}")
        if name not in self._vertices:
            self._vertices.append(name)

    def _edge_id(self, ref) -> int:
        if isinstance(ref, str):
            try:
                return self._edge_names.index(ref)
            except ValueError:
                raise PatternError(f"unknown edge name {ref!r} "
                                   f"(have {self._edge_names})") from None
        eid = int(ref)
        if not 0 <= eid < len(self._edges):
            raise PatternError(
                f"edge index {eid} out of range (have {len(self._edges)})")
        return eid

    # ------------------------------------------------------------------ #
    @property
    def n_edges(self) -> int:
        return len(self._edges)

    @property
    def vertex_names(self) -> tuple[str, ...]:
        """Vertex names in authoring (first-mention) order."""
        return tuple(self._vertices)

    @property
    def edge_names(self) -> tuple[str, ...]:
        return tuple(self._edge_names)

    @property
    def window_span(self) -> int | None:
        return self._window

    # ------------------------------------------------------------------ #
    def build(self, vocab: LabelVocab | None = None) -> tuple[QueryGraph, int]:
        """Lower to ``(QueryGraph, window)`` in authoring order.

        Label tokens intern through ``vocab`` (a fresh one if omitted —
        sessions always pass their own so patterns and events agree).
        ``QueryGraph`` validation applies: the ``before`` constraints
        must close into a strict partial order.
        """
        if not self._edges:
            raise PatternError("pattern has no edges")
        if self._window is None:
            raise PatternError(
                "pattern has no window — call .window(span); a continuous "
                "query without a window would never expire state")
        vocab = LabelVocab() if vocab is None else vocab
        vid = {name: i for i, name in enumerate(self._vertices)}
        vlabels = tuple(
            vocab.intern(self._vertex_labels.get(name, UNLABELED))
            for name in self._vertices)
        elabels = tuple(
            QueryGraph.WILDCARD if lbl is None else vocab.intern(lbl)
            for _, _, lbl in self._edges)
        try:
            q = QueryGraph(
                n_vertices=len(self._vertices),
                vertex_labels=vlabels,
                edges=tuple((vid[s], vid[d]) for s, d, _ in self._edges),
                edge_labels=elabels,
                prec=frozenset(self._before),
            )
        except ValueError as e:
            raise PatternError(f"invalid pattern: {e}") from e
        return q, self._window

    def __repr__(self) -> str:
        edges = ", ".join(
            f"{n}:{s}->{d}" + ("" if l is None else f"[{l!r}]")
            for (s, d, l), n in zip(self._edges, self._edge_names))
        return (f"Pattern({self.name or ''} {edges} "
                f"before={sorted(self._before)} window={self._window})")
