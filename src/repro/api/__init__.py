"""repro.api — the public, declarative surface of the system.

Users state timing-constrained patterns and continuously receive typed
matches from the edge stream; everything else (canonicalization,
slot-group packing, compiled-tick caching, coalescing, checkpoints) is
the machinery underneath:

    Pattern        fluent pattern DSL (edges, before-constraints, window)
    StreamSession  register -> Subscription -> ingest/serve -> restore
    Event / Match  typed stream records (label tokens, named bindings)

``repro.runtime.service`` stays the internal engine room — new code
should import from here.
"""

from repro.api.events import (
    STR_BASE,
    UNLABELED,
    Event,
    EventBuffer,
    LabelVocab,
    Match,
    to_data_edge,
)
from repro.api.pattern import Pattern, PatternError
from repro.api.planner import PatternPlan, compile_pattern
from repro.api.session import (
    ACTIVE,
    CLOSED,
    DEGRADED,
    AdmissionError,
    SessionStatus,
    StreamSession,
    Subscription,
)

__all__ = [
    "ACTIVE",
    "AdmissionError",
    "CLOSED",
    "DEGRADED",
    "Event",
    "EventBuffer",
    "LabelVocab",
    "Match",
    "Pattern",
    "PatternError",
    "PatternPlan",
    "STR_BASE",
    "SessionStatus",
    "StreamSession",
    "Subscription",
    "UNLABELED",
    "compile_pattern",
    "to_data_edge",
]
