"""``StreamSession``: the typed public facade over the serving stack.

One session = one edge stream + many standing patterns.  The full
lifecycle is first-class::

    sess = StreamSession(ckpt_dir="/ckpts")
    sub = sess.register(pattern)            # -> Subscription handle
    sess.serve(events, ckpt_every=50)       # production loop
    for m in sub.drain():                   # typed Match records
        ...
    # crash?  restart:
    sess = StreamSession.restore("/ckpts")  # same qids, same vocab
    sess.serve(events[sess.resume_offset:])

Everything below the facade is ``repro.runtime.service.
ContinuousSearchService`` — the session adds the parts the engine room
deliberately does not know about: the pattern DSL and canonicalizing
planner (isomorphic tenant patterns share one compiled slot tick), the
label vocabulary (string tokens on both the pattern and event side),
match translation back into the pattern's vertex/edge names, and
admission control off the engine's overflow counters (a structure whose
slot tables have already overflowed stops admitting new tenants instead
of silently dropping their partial matches).

With ``share_prefixes=True`` the engine additionally CSEs TC-subquery
prefixes across tenants (``repro.core.share``): tenants whose canonical
patterns share a prefix alias ONE set of device tables for it, advanced
once per tick.  ``Subscription.shared_prefix`` reports the dedup
(externalized depth, co-tenant count), and ``ServeInfo.
n_shared_prefix_ticks`` counts the per-tick shared-table advances.

Checkpoints written by a session carry the session's own state (vocab +
per-subscription pattern plans) inside the service manifest, so
``StreamSession.restore`` rebuilds the full typed surface — original
qids, same token ids, same match vocabularies.  Match callbacks are the
one thing that cannot persist; re-attach them on the restored handles.
"""

from __future__ import annotations

from collections import deque
from typing import NamedTuple

import numpy as np

from repro.api.events import (
    Event,
    EventBuffer,
    LabelVocab,
    Match,
    as_source,
    to_data_edge,
)
from repro.api.pattern import Pattern
from repro.api.planner import PatternPlan, compile_pattern
from repro.checkpoint import CheckpointError
from repro.core import join as J
from repro.core.query import QueryGraph
from repro.core.registry import plan_signature
from repro.obs import MetricsRegistry, to_prometheus
from repro.runtime.service import ContinuousSearchService
from repro.runtime.straggler import TickCoalescer

ACTIVE = "active"
DEGRADED = "degraded"      # overflow observed: matches may be incomplete
CLOSED = "closed"


class AdmissionError(RuntimeError):
    """Registration refused: the pattern's structural group is under
    capacity pressure (its slot tables have overflowed).  Serving a new
    tenant there would silently drop partial matches; pass
    ``force=True`` to register anyway, or grow the session capacities.
    """


class SessionStatus(NamedTuple):
    """Snapshot of a session's serving health (``StreamSession.status``)."""

    n_subscriptions: int
    n_edges_ingested: int
    n_ticks: int
    n_compiles: int
    degraded: tuple      # qids whose slot tables have overflowed
    # ingest-frontier health (None until a frontier serves this session)
    ingest: object = None          # IngestStats of the bound frontier
    n_late_dropped: int = 0        # frontier late drops (cumulative)
    n_duplicates: int = 0          # suppressed duplicate deliveries
    n_reconnects: int = 0          # source reconnects survived
    n_dropped_forced_gap: int = 0  # capacity-pressure drops (reorder
                                   # buffer forced past the watermark)
    watermark: int | None = None   # the frontier's event-time clock
    health: str = ACTIVE           # DEGRADED when overflow OR the
                                   # late-drop rate crosses the threshold
                                   # OR forced-gap drops occurred
                                   # (capacity pressure, never silent)


class Subscription:
    """Handle for one registered pattern: matches out, lifecycle in.

    Matches arrive either through ``on_match(match)`` (when set) or an
    internal queue read by ``drain()`` — the queue is bounded at
    ``MAX_PENDING`` (oldest dropped first, counted in ``n_dropped``), so
    a consumer that never drains cannot grow memory without bound.
    ``matches()`` reads the current window content.  All records are
    ``repro.api.events.Match`` — bindings keyed by the pattern's own
    vertex/edge names.
    """

    #: queue-mode backlog bound: past this, oldest un-drained matches
    #: are dropped (and counted) rather than growing memory forever
    MAX_PENDING = 1 << 16

    def __init__(self, session: "StreamSession", qid: int, plan: PatternPlan,
                 on_match=None):
        self.session = session
        self.qid = qid
        self.plan = plan
        self.on_match = on_match
        self._pending: deque[Match] = deque(maxlen=self.MAX_PENDING)
        self.n_delivered = 0
        self.n_dropped = 0
        self._closed = False
        # column index of each authored vertex/edge in the engine's
        # final match layout (through the canonical relabeling)
        eplan = session.service.registry.get(qid).plan
        vslot = {v: s for s, v in enumerate(eplan.final_vertex_layout)}
        epos = {e: s for s, e in enumerate(eplan.final_edge_layout)}
        self._vcols = tuple(vslot[c] for c in plan.vertex_map)
        self._ecols = tuple(epos[c] for c in plan.edge_map)

    # ------------------------------------------------------------------ #
    @property
    def name(self) -> str | None:
        return self.plan.name

    @property
    def query(self) -> QueryGraph:
        """The canonical compiled query (engine label space)."""
        return self.plan.query

    @property
    def window(self) -> int:
        return self.plan.window

    @property
    def n_overflow(self) -> int:
        """Cumulative engine-side overflow for this tenant's tables
        (including, under prefix sharing, its shared prefix chain)."""
        if self._closed:
            return 0
        return self.session.service.tenant_overflow(self.qid)

    @property
    def shared_prefix(self):
        """``SharedPrefixInfo`` (depth / co-tenants / epoch) when the
        session shares TC-subquery prefixes across tenants
        (``share_prefixes=True``), else None."""
        if self._closed:
            return None
        return self.session.service.shared_prefix(self.qid)

    @property
    def status(self) -> str:
        if self._closed:
            return CLOSED
        return DEGRADED if self.n_overflow else ACTIVE

    # ------------------------------------------------------------------ #
    def _match_from_row(self, b_row, t_row) -> Match:
        return Match(
            vertices=tuple(
                (n, int(b_row[c]))
                for n, c in zip(self.plan.vertex_names, self._vcols)),
            edges=tuple(
                (n, int(t_row[c]))
                for n, c in zip(self.plan.edge_names, self._ecols)),
        )

    def _match_from_key(self, key) -> Match:
        bind: dict[int, int] = {}
        times: dict[int, int] = {}
        for eid, (src, dst, ts) in key:
            u, v = self.plan.query.edges[eid]
            bind[u], bind[v], times[eid] = src, dst, ts
        return Match(
            vertices=tuple(
                (n, bind[c])
                for n, c in zip(self.plan.vertex_names, self.plan.vertex_map)),
            edges=tuple(
                (n, times[c])
                for n, c in zip(self.plan.edge_names, self.plan.edge_map)),
        )

    def _deliver(self, match: Match):
        self.n_delivered += 1
        if self.on_match is not None:
            self.on_match(match)
            return
        if len(self._pending) == self.MAX_PENDING:
            self.n_dropped += 1          # deque(maxlen) evicts the oldest
        self._pending.append(match)

    def _deliver_rows(self, bindings, ets):
        """Deliver engine match rows (the one translation/delivery path
        shared by ``ingest``, ``serve``, and ``StreamServer``)."""
        for b_row, t_row in zip(bindings, ets):
            self._deliver(self._match_from_row(b_row, t_row))
        return len(bindings)

    # ------------------------------------------------------------------ #
    def drain(self) -> list[Match]:
        """New matches reported since the last ``drain`` (queue mode —
        empty when an ``on_match`` callback is consuming them)."""
        out = list(self._pending)
        self._pending.clear()
        return out

    def matches(self) -> list[Match]:
        """All complete matches currently inside the window."""
        keys = self.session.service.matches(self.qid)
        return sorted((self._match_from_key(k) for k in keys))

    def close(self):
        """Unregister the pattern and drop its partial-match state."""
        self.session._close(self)

    def __repr__(self) -> str:
        return (f"Subscription(qid={self.qid}, name={self.name!r}, "
                f"status={self.status if not self._closed else CLOSED!r})")


class StreamSession:
    """Declarative serving session over one continuous edge stream."""

    def __init__(
        self,
        slots_per_group: int = 4,
        level_capacity: int = 2048,
        l0_capacity: int = 2048,
        max_new: int = 512,
        backend: str = J.JoinBackend.REF,
        max_out: int | None = None,
        ckpt_dir: str | None = None,
        keep_checkpoints: int = 8,
        tick_cache=None,
        share_prefixes: bool = False,
        late_drop_threshold: float = 0.01,
        mesh: dict | int | None = None,
        obs: MetricsRegistry | None = None,
        tracer=None,
        _service: ContinuousSearchService | None = None,
    ):
        if _service is None:
            common = dict(
                level_capacity=level_capacity,
                l0_capacity=l0_capacity,
                max_new=max_new,
                backend=backend,
                extract_matches=True,     # the facade's whole point
                max_out=max_out,
                ckpt_dir=ckpt_dir,
                keep_checkpoints=keep_checkpoints,
                tick_cache=tick_cache,
                enable_sharing=share_prefixes,
            )
            if mesh is not None:
                # replica-sharded serving: ``mesh`` is the replica count
                # or a dict of ShardedSearchService knobs (n_replicas,
                # slots_per_replica, placement); slot-group width then
                # comes from n_replicas * slots_per_replica, so
                # ``slots_per_group`` is ignored on this path.
                from repro.runtime.mesh import ShardedSearchService
                mesh_kw = ({"n_replicas": mesh} if isinstance(mesh, int)
                           else dict(mesh))
                _service = ShardedSearchService(**mesh_kw, **common)
            else:
                _service = ContinuousSearchService(
                    slots_per_group=slots_per_group, **common)
        self.service = _service
        # the session ALWAYS carries a metrics registry: status()/health
        # read the registry's ``ingest.*`` counters instead of a live
        # frontier's private ones, so drop-driven DEGRADED attribution
        # survives checkpoint/restore (the registry reloads its counter
        # history from the manifest) and both health paths — drop-rate
        # and forced-gap — share one source of truth.
        if self.service.obs is None:
            self.service.obs = obs if obs is not None else MetricsRegistry()
            self.service._register_obs_gauges()
        self.obs = self.service.obs
        if tracer is not None and self.service.tracer is None:
            self.service.tracer = tracer
        self.vocab = LabelVocab()
        self._subs: dict[int, Subscription] = {}
        self._coalescer: TickCoalescer | None = None
        # session health turns DEGRADED when the frontier's late-drop
        # rate (drops / delivered) crosses this; 0 disables the margin
        # (any drop degrades)
        self.late_drop_threshold = late_drop_threshold
        self._frontier = None
        # session state rides inside every service checkpoint manifest
        self.service.manifest_extra = self._api_manifest

    # ------------------------------------------------------------------ #
    def _api_manifest(self) -> dict:
        return {
            "api": {
                "vocab": self.vocab.to_json(),
                "subscriptions": {
                    str(qid): sub.plan.to_json()
                    for qid, sub in self._subs.items()
                },
            }
        }

    # ------------------------------------------------------------------ #
    def register(self, pattern: Pattern | PatternPlan, on_match=None,
                 force: bool = False) -> Subscription:
        """Register a standing pattern; returns its ``Subscription``.

        The pattern is canonicalized first, so any authoring of an
        already-served structure arms a free slot in an existing group —
        a pure device-data write, no XLA recompilation.  Admission
        control: if that structure's live slot tables have already
        overflowed, registration raises ``AdmissionError`` (the new
        tenant would silently lose matches) unless ``force=True``.
        """
        plan = (pattern if isinstance(pattern, PatternPlan)
                else compile_pattern(pattern, self.vocab))
        eplan = self.service.registry.compile(plan.query, plan.window)
        if not force:
            pressure = self.service.overflow_pressure(plan_signature(eplan))
            if pressure:
                raise AdmissionError(
                    f"structure of pattern {plan.name!r} is under capacity "
                    f"pressure ({pressure} overflowed appends); grow "
                    "level_capacity/max_new or pass force=True")
        qid = self.service.register(plan.query, plan.window, plan=eplan)
        sub = Subscription(self, qid, plan, on_match=on_match)
        self._subs[qid] = sub
        return sub

    def register_query(self, query: QueryGraph, window: int, plan=None,
                       name: str | None = None) -> Subscription:
        """Escape hatch: register a raw ``QueryGraph`` (or an exact
        pre-compiled ``ExecutionPlan``) under synthesized vertex/edge
        names.  NOT canonicalized — an exact plan must be served as
        given, so cross-authoring dedup does not apply here.
        """
        qid = self.service.register(query, window, plan=plan)
        sub = Subscription(self, qid, PatternPlan.identity(query, window,
                                                           name=name))
        self._subs[qid] = sub
        return sub

    def _close(self, sub: Subscription):
        if sub._closed:
            return
        self.service.unregister(sub.qid)
        self._subs.pop(sub.qid, None)
        sub._closed = True

    # ------------------------------------------------------------------ #
    def _dispatch(self, results) -> int:
        delivered = 0
        for qid, r in results.items():
            sub = self._subs.get(qid)
            if sub is None:
                continue
            valid = np.asarray(r.match_valid)
            if valid.any():
                delivered += sub._deliver_rows(
                    np.asarray(r.match_bindings)[valid],
                    np.asarray(r.match_ets)[valid])
        return delivered

    def ingest(self, events, batch_size: int = 64) -> int:
        """Deterministic fixed-chunk ingest (testing / replay path).

        ``events`` may be ``Event`` records (vocab-translated) or raw
        ``DataEdge``s (already in engine label space).  Batches are
        padded to power-of-two widths by ``EventBuffer``.  Returns the
        number of matches delivered; read them via ``Subscription.
        drain()`` / callbacks.  For production serving (adaptive
        coalescing, checkpoint cadence) use ``serve``.
        """
        buf = EventBuffer(self.vocab, batch_size)
        batches = [b for ev in events if (b := buf.push(ev)) is not None]
        tail = buf.flush()
        if tail is not None:
            batches.append(tail)
        delivered = 0
        for b in batches:
            delivered += self._dispatch(self.service.ingest(b))
        return delivered

    def serve(self, events, ckpt_every: int = 0, batch_size: int = 64,
              min_batch: int | None = None, max_batch: int | None = None,
              target_latency_ms: float = 50.0, on_tick=None,
              final_checkpoint: bool = True) -> dict:
        """The production loop: adaptive tick coalescing, periodic async
        checkpoints, backpressure off the slowest group.

        Delegates to ``ContinuousSearchService.serve_stream``; the AIMD
        coalescer state persists across ``serve`` calls (batch-size
        arguments seed only the first).  Matches route to each
        subscription (queue or callback); returns ``{subscription:
        n_new_matches}`` for the served span.  ``on_tick(ServeInfo)``
        surfaces per-tick latency and overflow counts for external
        monitoring.
        """
        edges = [to_data_edge(e, self.vocab) for e in events]

        def _on_match(qid, bindings, ets):
            sub = self._subs.get(qid)
            if sub is not None:
                sub._deliver_rows(bindings, ets)

        if self._coalescer is None:
            self._coalescer = TickCoalescer.seeded(
                batch_size, min_batch, max_batch, target_latency_ms)
        totals = self.service.serve_stream(
            edges, on_match=_on_match, on_tick=on_tick,
            ckpt_every=ckpt_every, coalescer=self._coalescer,
            final_checkpoint=final_checkpoint)
        return {self._subs[qid]: n for qid, n in totals.items()
                if qid in self._subs}

    # ------------------------------------------------------------------ #
    # ingestion frontier: sources in, watermark-ordered ticks out
    # ------------------------------------------------------------------ #
    def sources(self, named_events: dict, resume: dict | None = None,
                **frontier_kw):
        """Build an ``IngestFrontier`` over named event streams.

        ``named_events`` maps source name -> a list of typed ``Event``s
        / raw ``DataEdge``s (vocab-translated here), OR an already-built
        ``repro.stream.ingest`` ``Source`` (e.g. a chaos-wrapped one),
        passed through as-is.  ``resume`` is a restored ingest manifest
        (``session.restored_ingest``): sources reconnect at their ack
        cursors and replayed deliveries are suppressed — the
        exactly-once mid-stream resume.  Keyword args flow to
        ``IngestFrontier`` (``allowed_lateness``, ``retry``, ...).

        ``allowed_lateness`` is an END-TO-END event-time contract, not
        just a buffer knob: the frontier's watermark (min over live
        sources of max event time, minus the lateness) gates release
        AND drives every engine's window clock during
        ``serve_frontier``, so an event within the allowed lateness is
        guaranteed to find its still-unexpired join partners, and an
        event beyond it is rejected-and-counted, never half-joined.
        Larger lateness = more completeness, staler windows
        (``SessionStatus.ingest.window_staleness`` gauges the trade).
        """
        from repro.stream.ingest import IngestFrontier, Source
        srcs = [ev if isinstance(ev, Source) else
                as_source(name, ev, self.vocab)
                for name, ev in named_events.items()]
        if resume is not None:
            return IngestFrontier.resume(resume, srcs, **frontier_kw)
        return IngestFrontier(srcs, **frontier_kw)

    def serve_frontier(self, frontier, ckpt_every: int = 0,
                       batch_size: int = 64, min_batch: int | None = None,
                       max_batch: int | None = None,
                       target_latency_ms: float = 50.0, on_tick=None,
                       final_checkpoint: bool = True,
                       max_idle_rounds: int | None = None) -> dict:
        """Serve from an ingestion frontier: retry/dedup per source,
        deterministic k-way event-time merge, watermark-driven ticking.

        Same contract as ``serve`` otherwise: matches route to each
        subscription, the AIMD coalescer persists across calls, and
        checkpoints written during the loop embed the frontier's resume
        cursors AND its event-time watermark (see ``restored_ingest``) —
        a restored session resumes the same window clock, so nothing
        re-expires or resurrects.  Windows are EVENT-time here: the
        frontier's watermark drives engine admission/expiry every tick
        (``serve``'s in-process path keeps the classic max-ts clock).
        ``status()`` reports the frontier's late-drop / forced-gap /
        duplicate / reconnect accounting, turning DEGRADED when the
        late-drop rate crosses ``late_drop_threshold`` or any
        capacity-pressure (forced-gap) drop occurred — no event
        vanishes silently.
        """
        self._frontier = frontier

        def _on_match(qid, bindings, ets):
            sub = self._subs.get(qid)
            if sub is not None:
                sub._deliver_rows(bindings, ets)

        if self._coalescer is None:
            self._coalescer = TickCoalescer.seeded(
                batch_size, min_batch, max_batch, target_latency_ms)
        totals = self.service.serve_frontier(
            frontier, on_match=_on_match, on_tick=on_tick,
            ckpt_every=ckpt_every, coalescer=self._coalescer,
            final_checkpoint=final_checkpoint,
            max_idle_rounds=max_idle_rounds)
        return {self._subs[qid]: n for qid, n in totals.items()
                if qid in self._subs}

    @property
    def restored_ingest(self) -> dict | None:
        """Ingest resume manifest from the checkpoint this session was
        restored from (None on a fresh session): pass to ``sources(...,
        resume=...)`` to pick the stream back up exactly-once."""
        return self.service.restored_ingest

    # ------------------------------------------------------------------ #
    def subscriptions(self) -> list[Subscription]:
        return [self._subs[qid] for qid in sorted(self._subs)]

    def status(self) -> SessionStatus:
        svc = self.service
        degraded = tuple(qid for qid, s in sorted(self._subs.items())
                         if s.n_overflow > 0)
        # ONE source of truth for ingest health: the obs registry's
        # ``ingest.*`` counters.  A live frontier refreshes them first;
        # after a restore (no frontier bound yet) the restored counter
        # history still reports, so health never silently resets to
        # ACTIVE while the stream's drops persist.
        ing = None
        if self._frontier is not None:
            self._frontier.publish_obs(self.obs)
            ing = self._frontier.stats()
        n_late = self.obs.counter("ingest.n_late_dropped").value
        n_forced_gap = self.obs.counter("ingest.n_dropped_forced_gap").value
        n_emitted = self.obs.counter("ingest.n_emitted").value
        drop_rate = n_late / max(1, n_late + n_emitted)
        # forced-gap drops are capacity pressure (the reorder buffer
        # force-evicted past the watermark): any amount degrades health —
        # unlike user lateness, no threshold makes it acceptable
        health = DEGRADED if degraded \
            or drop_rate > self.late_drop_threshold \
            or n_forced_gap > 0 else ACTIVE
        return SessionStatus(
            n_subscriptions=len(self._subs),
            n_edges_ingested=svc.n_edges_ingested,
            n_ticks=svc.n_ticks,
            n_compiles=svc.n_compiles,
            degraded=degraded,
            ingest=ing,
            n_late_dropped=n_late,
            n_duplicates=int(self.obs.counter("ingest.n_duplicates").value),
            n_reconnects=int(self.obs.counter("ingest.n_reconnects").value),
            n_dropped_forced_gap=n_forced_gap,
            watermark=None if ing is None else ing.watermark,
            health=health,
        )

    def metrics(self) -> dict:
        """Flat snapshot of the session's obs registry (counters,
        gauges incl. collect-time callbacks, histogram percentiles)."""
        return self.obs.snapshot()

    def prometheus(self) -> str:
        """The session's metrics in Prometheus text exposition format
        (serve it from any HTTP endpoint you like)."""
        return to_prometheus(self.obs)

    @property
    def resume_offset(self) -> int:
        """Edges already consumed (slice the replay stream here)."""
        return self.service.n_edges_ingested

    # ------------------------------------------------------------------ #
    def checkpoint(self):
        """Snapshot the full session (engine state + vocab + patterns)
        asynchronously; returns the writer future."""
        return self.service.checkpoint()

    def close(self):
        """Flush pending checkpoint writes (subscriptions stay live —
        close them individually to unregister)."""
        if self.service.ckpt is not None:
            self.service.ckpt.wait()

    @classmethod
    def adopt(cls, service: ContinuousSearchService) -> "StreamSession":
        """Wrap an existing (possibly restored) service in a typed
        session.  Checkpointed api state (vocab + pattern plans) is
        rebuilt when present; tenants registered below the api layer get
        synthesized identity name maps (``v0..``/``e0..``).
        """
        extra = (service.manifest_extra
                 if isinstance(service.manifest_extra, dict) else {})
        api = extra.get("api", {})
        # cls() re-binds service.manifest_extra to the live session state,
        # replacing the frozen dict restored from the manifest
        sess = cls(_service=service)
        if api:
            sess.vocab = LabelVocab.from_json(api["vocab"])
        plans = {int(q): PatternPlan.from_json(pj)
                 for q, pj in api.get("subscriptions", {}).items()}
        for qid in service.registry.qids():
            plan = plans.get(qid)
            if plan is None:
                rq = service.registry.get(qid)
                plan = PatternPlan.identity(rq.query, rq.window)
            sess._subs[qid] = Subscription(sess, qid, plan)
        return sess

    @classmethod
    def restore(cls, ckpt_dir: str, step: int | None = None,
                tick_cache=None, backend: str | None = None,
                obs: MetricsRegistry | None = None) -> "StreamSession":
        """Rebuild a full session from the newest usable checkpoint:
        original qids, same label vocabulary, same pattern plans, zero
        recompiles for structures this process has already served.
        Match callbacks cannot persist — re-attach them on the restored
        ``Subscription`` handles.  The obs registry's counter history
        (drops, ticks, checkpoint latencies) reloads from the manifest,
        so ``status()`` health attribution survives the restore.
        """
        svc = ContinuousSearchService.restore(
            ckpt_dir, step=step, tick_cache=tick_cache, backend=backend,
            extract_matches=True,
            obs=obs if obs is not None else MetricsRegistry())
        extra = svc.manifest_extra if isinstance(svc.manifest_extra, dict) \
            else {}
        if extra.get("api") is None:
            raise CheckpointError(
                f"checkpoint under {ckpt_dir!r} was not written by a "
                "StreamSession (no api state in the manifest); restore it "
                "as a ContinuousSearchService instead")
        return cls.adopt(svc)

    def __repr__(self) -> str:
        return (f"StreamSession({len(self._subs)} subscriptions, "
                f"{self.service.n_edges_ingested} edges, "
                f"{self.service.n_ticks} ticks)")
