"""Pattern planner: lower + canonicalize a ``Pattern`` for serving.

The planner is the layer where cross-tenant sharing is decided: it
lowers a ``Pattern`` to its authored ``QueryGraph``, then rewrites it
into canonical form (``repro.core.canon``) so that *every* authoring of
the same structure — permuted vertex ids, reordered edges, renamed
vertices — compiles to the identical ``QueryGraph`` and therefore the
identical ``plan_signature``.  The service then packs such tenants into
one padded slot group under ONE compiled slot tick: registration of a
differently-authored isomorphic pattern is a pure device-data write.

``PatternPlan`` keeps the authored names alongside the canonical query,
so matches translate back into the tenant's vocabulary (vertex/edge
names), and round-trips through JSON for checkpoint manifests.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.api.events import LabelVocab
from repro.api.pattern import Pattern
from repro.core.canon import canonical_form
from repro.core.query import QueryGraph


@dataclass(frozen=True)
class PatternPlan:
    """One pattern, planned: canonical query + name translation tables.

    ``vertex_map[i]`` / ``edge_map[j]`` give the canonical vertex/edge id
    of the pattern's i-th vertex / j-th edge (authoring order);
    ``vertex_names`` / ``edge_names`` are the authored names in the same
    order.  ``query`` is canonical — feed it to the service, never the
    authored graph, or isomorphic tenants stop sharing compiled ticks.
    """

    name: str | None
    query: QueryGraph
    window: int
    vertex_names: tuple[str, ...]
    edge_names: tuple[str, ...]
    vertex_map: tuple[int, ...]
    edge_map: tuple[int, ...]

    # ------------------------------------------------------------------ #
    @classmethod
    def identity(cls, query: QueryGraph, window: int,
                 name: str | None = None) -> "PatternPlan":
        """Plan for a query registered BELOW the DSL (raw QueryGraph or
        exact ExecutionPlan): synthesized ``v0..``/``e0..`` names,
        identity maps, no canonical rewrite."""
        return cls(
            name=name, query=query, window=window,
            vertex_names=tuple(f"v{i}" for i in range(query.n_vertices)),
            edge_names=tuple(f"e{j}" for j in range(query.n_edges)),
            vertex_map=tuple(range(query.n_vertices)),
            edge_map=tuple(range(query.n_edges)),
        )

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "query": self.query.to_spec(),
            "window": int(self.window),
            "vertex_names": list(self.vertex_names),
            "edge_names": list(self.edge_names),
            "vertex_map": list(self.vertex_map),
            "edge_map": list(self.edge_map),
        }

    @classmethod
    def from_json(cls, spec: dict) -> "PatternPlan":
        return cls(
            name=spec.get("name"),
            query=QueryGraph.from_spec(spec["query"]),
            window=int(spec["window"]),
            vertex_names=tuple(spec["vertex_names"]),
            edge_names=tuple(spec["edge_names"]),
            vertex_map=tuple(int(v) for v in spec["vertex_map"]),
            edge_map=tuple(int(e) for e in spec["edge_map"]),
        )


def compile_pattern(pattern: Pattern, vocab: LabelVocab | None = None) -> PatternPlan:
    """Lower ``pattern`` through ``vocab`` and canonicalize it."""
    authored, window = pattern.build(vocab)
    canon = canonical_form(authored)
    return PatternPlan(
        name=pattern.name,
        query=canon.query,
        window=window,
        vertex_names=pattern.vertex_names,
        edge_names=pattern.edge_names,
        vertex_map=canon.vertex_map,
        edge_map=canon.edge_map,
    )
