"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never
touches jax device state (required so smoke tests see 1 CPU device while
the dry-run process sees 512 virtual ones).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2x16x16 = 512 across two pods."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def engine_axes(mesh) -> tuple[str, ...]:
    """The axes the streaming engine shards table capacity over."""
    return tuple(a for a in mesh.axis_names if a != "model") + ("model",)
