"""Continuous-query serving driver for the streaming engine.

The production loop: register continuous queries (compiled once), then
ingest edges tick by tick with adaptive batch coalescing (straggler /
backpressure control) and periodic state checkpoints (fault tolerance:
a restarted server restores its expansion lists and misses nothing that
is still inside the window).
"""

from __future__ import annotations

import time

import numpy as np
import jax

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.core.engine import build_tick
from repro.core.plan import ExecutionPlan, compile_plan
from repro.core.state import init_state, make_batch
from repro.runtime.straggler import TickCoalescer
from repro.stream.generator import to_batches


class StreamServer:
    def __init__(self, plan: ExecutionPlan, ckpt_dir: str | None = None,
                 extract_matches: bool = True):
        self.plan = plan
        self.tick = jax.jit(build_tick(plan, extract_matches=extract_matches))
        self.state = init_state(plan)
        self.coalescer = TickCoalescer(batch=64)
        self.ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
        self.ticks = 0
        if ckpt_dir and (last := latest_step(ckpt_dir)) is not None:
            self.state = restore_checkpoint(ckpt_dir, last, self.state)
            self.ticks = last

    def ingest(self, edges: list, on_match=None, ckpt_every: int = 0):
        """Feed DataEdges; returns total new matches reported."""
        total = 0
        i = 0
        batch_size = self.coalescer.batch
        while i < len(edges):
            chunk = edges[i:i + batch_size]
            i += len(chunk)
            b = to_batches(chunk, len(chunk))[0]
            t0 = time.perf_counter()
            self.state, res = self.tick(self.state, make_batch(**b))
            n_new = int(res.n_new_matches)
            total += n_new
            if n_new and on_match is not None:
                valid = np.asarray(res.match_valid)
                on_match(np.asarray(res.match_bindings)[valid],
                         np.asarray(res.match_ets)[valid])
            self.ticks += 1
            lat_ms = (time.perf_counter() - t0) * 1e3
            batch_size = self.coalescer.record(lat_ms, len(edges) - i)
            if self.ckpt and ckpt_every and self.ticks % ckpt_every == 0:
                self.ckpt.save(self.ticks, self.state)
        if self.ckpt:
            self.ckpt.wait()
        return total
