"""Single-query serving driver — a thin wrapper over the unified path.

There is ONE serving loop in this codebase:
``repro.runtime.service.ContinuousSearchService``, fronted by the
public ``repro.api.StreamSession`` facade.  ``StreamServer`` keeps the
historical single-query API (construct from an ExecutionPlan, feed
DataEdge lists, get an array-level ``on_match`` callback) but builds no
ticks and owns no loop of its own: it registers its one query as a
tenant of a one-slot service *through the api session*
(``StreamSession.adopt`` + ``register_query``, which also rides the
session's vocab/pattern state inside every checkpoint manifest) and
delegates ingest — adaptive tick coalescing, periodic async
checkpoints, power-of-two batch padding — to ``serve_stream``.  The
typed per-match surface is one call away: ``server.subscription``.

Fault tolerance comes from the service layer too: with ``ckpt_dir`` set,
a restarted ``StreamServer`` restores the full service (expansion lists,
tick/edge counters) from the newest usable checkpoint — torn files are
skipped — and misses nothing that is still inside the window.
"""

from __future__ import annotations

from repro.api import StreamSession, Subscription
from repro.checkpoint import (
    CheckpointError,
    checkpoint_steps,
    latest_step,
    load_manifest,
)
from repro.core import join as J
from repro.core.plan import ExecutionPlan
from repro.core.registry import plan_decomposition
from repro.runtime.service import ContinuousSearchService
from repro.runtime.straggler import TickCoalescer


class StreamServer:
    """One standing query served through the ``repro.api`` session path."""

    def __init__(self, plan: ExecutionPlan, ckpt_dir: str | None = None,
                 extract_matches: bool | None = None,
                 backend: str | None = None,
                 tick_cache=None):
        """``backend`` / ``extract_matches`` left unset mean: use the
        checkpointed values when restoring (REF / True when starting
        fresh) — passing them explicitly overrides either way."""
        lv = plan.subqueries[0].levels[0]
        l0_cap = plan.l0_joins[0].capacity if plan.l0_joins else lv.capacity
        self._coalescer = None       # AIMD state, persistent across ingests
        if ckpt_dir and checkpoint_steps(ckpt_dir):
            try:
                # restore validates (hashes) the chosen step exactly once
                service = ContinuousSearchService.restore(
                    ckpt_dir, tick_cache=tick_cache, backend=backend,
                    extract_matches=extract_matches)
            except CheckpointError as e:
                # fail loudly rather than silently starting fresh: a
                # fresh start here would break the miss-nothing guarantee
                last = latest_step(ckpt_dir)
                if last is not None and \
                        "service" not in load_manifest(ckpt_dir, last):
                    raise ValueError(
                        f"ckpt_dir {ckpt_dir!r} holds checkpoints without "
                        "a service manifest (legacy StreamServer or "
                        "foreign writer); clear the directory or restore "
                        "it manually") from e
                raise CheckpointError(
                    f"ckpt_dir {ckpt_dir!r} contains checkpoints but none "
                    "are usable (all torn/partial)") from e
            self.session = StreamSession.adopt(service)
            qids = service.registry.qids()
            if len(qids) != 1:
                raise ValueError(
                    f"checkpoint under {ckpt_dir!r} holds {len(qids)} "
                    "queries; restore it as a ContinuousSearchService")
            self.qid = qids[0]
            rq = service.registry.get(self.qid)
            if rq.query != plan.query or rq.window != plan.window:
                raise ValueError(
                    f"checkpoint under {ckpt_dir!r} holds a different "
                    f"query/window (checkpointed window={rq.window}, "
                    f"requested {plan.window})")
            # capacity / decomposition drift must be loud too: restore
            # always serves the checkpointed plan, so a caller who
            # recompiled (e.g. grew capacities after overflow) must not
            # silently keep the old tables
            r_lv = rq.plan.subqueries[0].levels[0]
            r_l0 = (rq.plan.l0_joins[0].capacity if rq.plan.l0_joins
                    else r_lv.capacity)
            if (r_lv.capacity, r_lv.max_new, r_l0) != \
                    (lv.capacity, lv.max_new, l0_cap) or \
                    plan_decomposition(rq.plan) != plan_decomposition(plan):
                raise ValueError(
                    f"checkpoint under {ckpt_dir!r} was written with "
                    "different plan capacities or decomposition; clear "
                    "the directory to serve the new plan from scratch")
        else:
            service = ContinuousSearchService(
                slots_per_group=1,
                level_capacity=lv.capacity,
                l0_capacity=l0_cap,
                max_new=lv.max_new,
                backend=J.JoinBackend.REF if backend is None else backend,
                extract_matches=(True if extract_matches is None
                                 else extract_matches),
                ckpt_dir=ckpt_dir,
                tick_cache=tick_cache,
            )
            self.session = StreamSession.adopt(service)
            # register the EXACT plan (a caller's custom decomposition
            # must be served, not re-derived; register_query skips
            # canonicalization for exactly that reason)
            self.qid = self.session.register_query(
                plan.query, plan.window, plan=plan).qid
        self.plan = self.service.registry.get(self.qid).plan

    # ------------------------------------------------------------------ #
    @property
    def service(self) -> ContinuousSearchService:
        return self.session.service

    @property
    def subscription(self) -> Subscription:
        """The typed api handle for this server's one query (named
        bindings, ``matches()``/``drain()``, overflow status)."""
        return self.session._subs[self.qid]

    @property
    def state(self):
        return self.service.state(self.qid)

    @property
    def ticks(self) -> int:
        return self.service.n_ticks

    @property
    def resume_offset(self) -> int:
        """Edges already consumed (slice your replay stream here after a
        restore)."""
        return self.service.n_edges_ingested

    def matches(self):
        return self.service.matches(self.qid)

    # ------------------------------------------------------------------ #
    def ingest(self, edges: list, on_match=None, ckpt_every: int = 0,
               batch_size: int = 64):
        """Feed DataEdges; returns total new matches reported.

        ``on_match(bindings, ets)`` receives raw engine arrays (the
        historical surface) and, when given, is the sole consumer of the
        matches.  Without it, matches route to the typed
        ``self.subscription`` surface instead — its ``on_match(Match)``
        callback if attached, else its ``drain()`` queue (bounded at
        ``Subscription.MAX_PENDING`` — drain regularly on long streams
        or the oldest matches are dropped and counted).  The
        adaptive batch-size (AIMD) state persists across ``ingest``
        calls, so a consumer feeding the server in repeated chunks keeps
        the batch size it converged to (``batch_size`` only seeds the
        first call)."""
        if self._coalescer is None:
            self._coalescer = TickCoalescer.seeded(batch_size)
        if on_match is not None:
            cb = lambda qid, bindings, ets: on_match(bindings, ets)
        elif self.service.extract_matches:
            sub = self.subscription
            cb = lambda qid, bindings, ets: sub._deliver_rows(bindings, ets)
        else:
            cb = None
        totals = self.service.serve_stream(
            edges, on_match=cb, ckpt_every=ckpt_every,
            coalescer=self._coalescer)
        return totals.get(self.qid, 0)
