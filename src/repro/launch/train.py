"""Training driver: fault-tolerant LM/GNN/recsys training on any mesh.

Wires together: config registry -> cell step functions -> data pipeline
-> FaultTolerantLoop (async checkpoints, restart recovery).  On a single
CPU host this trains the reduced configs end-to-end (examples/train_lm.py);
on a pod the same driver takes ``--arch`` and the production mesh.
"""

from __future__ import annotations

import argparse
import functools

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import AsyncCheckpointer, latest_step, restore_checkpoint
from repro.data.lm import lm_batch
from repro.launch.cells import make_lm_train_step
from repro.models import transformer as tfm
from repro.optim import AdamWConfig, adamw_init


def train_lm(
    cfg: tfm.LMConfig,
    n_steps: int = 200,
    batch: int = 8,
    seq: int = 64,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    log_every: int = 10,
    microbatches: int = 1,
    seed: int = 0,
):
    """Train a (reduced) LM; returns (params, list of losses)."""
    ocfg = AdamWConfig()
    params = tfm.init(jax.random.PRNGKey(seed), cfg)
    opt = adamw_init(params, ocfg)
    step_fn = jax.jit(make_lm_train_step(cfg, ocfg, microbatches, lr=3e-4))

    ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    start = 0
    if ckpt_dir and (last := latest_step(ckpt_dir)) is not None:
        state = restore_checkpoint(ckpt_dir, last, {"p": params, "o": opt})
        params, opt = state["p"], state["o"]
        start = last

    losses = []
    for i in range(start, n_steps):
        toks = jnp.asarray(lm_batch(i, batch, seq, cfg.vocab, seed))
        params, opt, loss, gnorm = step_fn(params, opt, toks)
        if i % log_every == 0 or i == n_steps - 1:
            losses.append((i, float(loss)))
            print(f"step {i:5d}  loss {float(loss):.4f}  "
                  f"gnorm {float(gnorm):.3f}", flush=True)
        if ckpt and (i + 1) % ckpt_every == 0:
            ckpt.save(i + 1, {"p": params, "o": opt})
    if ckpt:
        ckpt.wait()
    return params, losses


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--d-model", type=int, default=256)
    ap.add_argument("--layers", type=int, default=4)
    ap.add_argument("--vocab", type=int, default=512)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()
    cfg = tfm.LMConfig(
        name="driver-lm", n_layers=args.layers, d_model=args.d_model,
        n_heads=max(4, args.d_model // 64), n_kv_heads=2,
        head_dim=min(64, args.d_model // 4), d_ff=args.d_model * 4,
        vocab=args.vocab, dtype=jnp.float32, attn_chunk=args.seq,
        remat="none")
    train_lm(cfg, n_steps=args.steps, batch=args.batch, seq=args.seq,
             ckpt_dir=args.ckpt_dir)


if __name__ == "__main__":
    main()
