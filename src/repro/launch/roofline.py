"""Roofline term derivation from compiled dry-run artifacts.

Three terms per (arch × shape × mesh), in seconds (TPU v5e constants):

    compute    = HLO_FLOPs_per_device / 197e12        (bf16 peak / chip)
    memory     = HLO_bytes_per_device / 819e9         (HBM bw / chip)
    collective = bytes_on_wire_per_device / 50e9      (ICI per link)

FLOPs/bytes come from ``compiled.cost_analysis()`` (post-SPMD: per-device
program).  Collective bytes are NOT in cost_analysis — we parse the
optimized HLO text and sum per-op wire traffic with ring-algorithm
factors (documented in ``_wire_bytes``):

    all-reduce          2 x result bytes x (n-1)/n
    all-gather          1 x result bytes x (n-1)/n
    reduce-scatter      1 x operand bytes x (n-1)/n
    all-to-all          1 x result bytes x (n-1)/n
    collective-permute  1 x result bytes
"""

from __future__ import annotations

import re

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # bytes/s / chip
LINK_BW = 50e9           # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLL_RE = re.compile(
    r"=\s*(?P<rtype>\(?[\w\[\],{}\s/]*?\)?)\s*"
    r"(?P<op>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        # replica_groups=[G,S]<=[N]: G groups of size S
        return int(m.group(2))
    return 2  # conservative default


def collective_bytes(hlo_text: str) -> dict:
    """Per-device wire bytes by collective op kind, from optimized HLO."""
    out = {"all-reduce": 0.0, "all-gather": 0.0, "reduce-scatter": 0.0,
           "all-to-all": 0.0, "collective-permute": 0.0, "n_ops": 0}
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group("op")
        rbytes = _type_bytes(m.group("rtype"))
        n = max(_group_size(line), 1)
        ring = (n - 1) / n if n > 1 else 0.0
        if op == "all-reduce":
            wire = 2 * rbytes * ring
        elif op == "all-gather":
            wire = rbytes * ring
        elif op == "reduce-scatter":
            # result is the scattered piece; operand ~= result * n
            wire = rbytes * (n - 1)
        elif op == "all-to-all":
            wire = rbytes * ring
        else:  # collective-permute
            wire = rbytes
        out[op] += wire
        out["n_ops"] += 1
    out["total"] = sum(v for k, v in out.items()
                       if k not in ("n_ops", "total"))
    return out


def roofline_terms(cost: dict, coll: dict, n_chips: int,
                   model_flops: float | None = None) -> dict:
    flops_dev = float(cost.get("flops", 0.0))
    bytes_dev = float(cost.get("bytes accessed", 0.0))
    t_compute = flops_dev / PEAK_FLOPS
    t_memory = bytes_dev / HBM_BW
    t_coll = coll["total"] / LINK_BW
    terms = {"compute_s": t_compute, "memory_s": t_memory,
             "collective_s": t_coll}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    out = {
        **terms,
        "dominant": dom.replace("_s", ""),
        "bound_s": bound,
        "hlo_flops_per_device": flops_dev,
        "hlo_bytes_per_device": bytes_dev,
        "wire_bytes_per_device": coll["total"],
        "n_chips": n_chips,
    }
    if model_flops:
        hlo_total = flops_dev * n_chips
        out["model_flops"] = float(model_flops)
        out["useful_flops_ratio"] = (
            float(model_flops) / hlo_total if hlo_total else 0.0)
        # roofline fraction: useful work at peak vs. the binding term
        out["roofline_fraction"] = (
            (model_flops / n_chips / PEAK_FLOPS) / bound if bound else 0.0)
    return out
