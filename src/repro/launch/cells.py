"""Cell builders: (architecture × input shape × mesh) -> lowerable step.

A *cell* bundles everything the dry-run / drivers need:
  fn            the step function (train_step / serve_step / ...)
  args          ShapeDtypeStruct pytree (``input_specs()``: weak-type
                correct, shardable, no device allocation)
  in_shardings  NamedSharding pytree matching ``args``
  out_shardings NamedSharding / None pytree
  donate        arg indices donated (params/opt/caches)
  meta          model-FLOPs estimate terms for the roofline report

The same step constructors serve the per-arch smoke tests (reduced
configs, real arrays, no mesh).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import ARCHS, ArchSpec, ShapeSpec
from repro.models.common import MeshAxes
from repro.models import transformer as tfm
from repro.models.gnn import models as gnn
from repro.models.gnn import nequip as nq
from repro.models.gnn.sampler import subgraph_shapes
from repro.models.recsys import wide_deep as wd
from repro.optim import AdamWConfig, adamw_init, adamw_update
from repro.optim.adamw import state_specs as adamw_state_specs

SDS = jax.ShapeDtypeStruct
I32 = jnp.int32


@dataclasses.dataclass
class Cell:
    arch_id: str
    shape_name: str
    fn: Callable
    args: tuple
    in_shardings: Any
    out_shardings: Any
    donate: tuple
    meta: dict
    skip_reason: str | None = None


def _ns(mesh, spec_tree):
    if mesh is None:
        return None
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


# ===================================================================== #
# LM cells
# ===================================================================== #
def make_lm_train_step(cfg, ocfg: AdamWConfig, microbatches: int,
                       lr: float = 1e-4, axes=None):
    from repro.models.common import constrain

    def train_step(params, opt_state, tokens):
        gb, seq = tokens.shape
        acc_dtype = cfg.param_dtype

        def gloss(p, toks):
            (l, _), g = jax.value_and_grad(
                tfm.loss_fn, has_aux=True)(p, toks, cfg, axes)
            return l, g

        if microbatches > 1:
            mbs = tokens.reshape(microbatches, gb // microbatches, seq)
            mbs = constrain(mbs, axes, None, "dp", None)

            def micro(acc, toks):
                l, g = gloss(params, toks)
                acc = jax.tree.map(
                    lambda a, b: a + b.astype(a.dtype), acc, g)
                return acc, l

            acc0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)
            acc, losses = jax.lax.scan(micro, acc0, mbs)
            grads = jax.tree.map(lambda a: a / microbatches, acc)
            loss = losses.mean()
        else:
            loss, grads = gloss(params, tokens)
        params, opt_state, st = adamw_update(
            grads, opt_state, params, lr, ocfg)
        return params, opt_state, loss, st["grad_norm"]

    return train_step


def lm_param_flops(cfg) -> tuple[int, int]:
    """(total params, active params) — MoE counts top-k experts only."""
    d, f, L, v = cfg.d_model, cfg.d_ff, cfg.n_layers, cfg.vocab
    attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * cfg.head_dim \
        + cfg.n_heads * cfg.head_dim * d
    if cfg.moe:
        ffn_total = cfg.n_experts * 3 * d * f + d * cfg.n_experts
        ffn_active = cfg.moe_topk * 3 * d * f + d * cfg.n_experts
        if cfg.dense_residual:
            rf = cfg.residual_d_ff or f
            ffn_total += 3 * d * rf
            ffn_active += 3 * d * rf
    else:
        ffn_total = ffn_active = 3 * d * f
    total = L * (attn + ffn_total) + 2 * v * d
    active = L * (attn + ffn_active) + 2 * v * d
    return total, active


def _lm_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    cfg = arch.config
    axes = MeshAxes.for_mesh(mesh) if mesh else MeshAxes()
    ocfg = AdamWConfig(state_mode=arch.opt_state_mode)
    key = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(functools.partial(tfm.init, cfg=cfg), key)
    pspecs = tfm.param_specs(cfg, axes)
    total, active = lm_param_flops(cfg)
    gb, seq = shape.global_batch, shape.seq_len
    dp_size = (np.prod([mesh.shape[a] for a in
                        (axes.dp if isinstance(axes.dp, tuple) else (axes.dp,))])
               if mesh else 1)

    if shape.kind == "train":
        opt_sds = jax.eval_shape(lambda p: adamw_init(p, ocfg), params_sds)
        ospecs = adamw_state_specs(pspecs, params_sds, ocfg)
        tokens = SDS((gb, seq), I32)
        fn = make_lm_train_step(cfg, ocfg, shape.microbatches,
                                axes=axes if mesh else None)
        in_sh = (_ns(mesh, pspecs), _ns(mesh, ospecs),
                 _ns(mesh, P(axes.dp, None)))
        out_sh = (_ns(mesh, pspecs), _ns(mesh, ospecs),
                  _ns(mesh, P()), _ns(mesh, P()))
        meta = dict(model_flops=6 * active * gb * seq,
                    params_total=total, params_active=active,
                    tokens=gb * seq)
        return Cell(arch.arch_id, shape.name, fn,
                    (params_sds, opt_sds, tokens), in_sh, out_sh,
                    donate=(0, 1), meta=meta,
                    skip_reason=shape.skip_reason)

    if shape.kind == "prefill":
        tokens = SDS((gb, seq), I32)
        fn = functools.partial(tfm.prefill, cfg=cfg,
                               axes=axes if mesh else None)
        kv_out = P(None, axes.dp, axes.tp, None, None)
        in_sh = (_ns(mesh, pspecs), _ns(mesh, P(axes.dp, None)))
        out_sh = (_ns(mesh, P(axes.dp, axes.tp)),
                  _ns(mesh, kv_out), _ns(mesh, kv_out))
        meta = dict(model_flops=2 * active * gb * seq
                    + 2 * gb * cfg.n_layers * cfg.n_heads
                    * cfg.head_dim * seq * seq,   # attention term
                    params_total=total, tokens=gb * seq)
        return Cell(arch.arch_id, shape.name, fn, (params_sds, tokens),
                    in_sh, out_sh, donate=(), meta=meta,
                    skip_reason=shape.skip_reason)

    # decode: one token against a seq_len cache
    smax = seq
    cdt = jnp.bfloat16
    kc = SDS((cfg.n_layers, gb, smax, cfg.n_kv_heads, cfg.head_dim), cdt)
    vc = kc
    length = SDS((gb,), I32)
    tokens = SDS((gb, 1), I32)
    # Serving rule: weights stay 2D-sharded and STATIONARY; the tiny
    # per-token activations are replicated (tokens/length/logits carry no
    # dp sharding).  Sharding the decode batch over 'data' makes GSPMD
    # all-gather every layer's weights instead (66 GB of wire per token
    # at deepseek scale — EXPERIMENTS.md §Perf, decode iteration).
    if mesh and gb < dp_size:
        seq_axes = tuple(axes.dp if isinstance(axes.dp, tuple)
                         else (axes.dp,)) + (axes.tp,)
        kv_spec = P(None, None, seq_axes, None, None)
    else:
        kv_spec = P(None, axes.dp, axes.tp, None, None)
    tok_spec = P(None, None)
    len_spec = P(None)

    def fn(params, tokens, kc, vc, length):
        logits, (nk, nv, nl) = tfm.serve_step(
            params, tokens, (kc, vc, length), cfg)
        return logits, nk, nv, nl

    in_sh = (_ns(mesh, pspecs), _ns(mesh, tok_spec), _ns(mesh, kv_spec),
             _ns(mesh, kv_spec), _ns(mesh, len_spec))
    out_sh = (_ns(mesh, P(None, None)), _ns(mesh, kv_spec),
              _ns(mesh, kv_spec), _ns(mesh, len_spec))
    # decode model flops: 2*active per token + KV attention reads
    attn_flops = 4 * gb * cfg.n_layers * cfg.n_heads * cfg.head_dim * smax
    meta = dict(model_flops=2 * active * gb + attn_flops,
                params_total=total, tokens=gb,
                kv_bytes=2 * cfg.n_layers * gb * smax * cfg.n_kv_heads
                * cfg.head_dim * 2)
    return Cell(arch.arch_id, shape.name, fn,
                (params_sds, tokens, kc, vc, length), in_sh, out_sh,
                donate=(2, 3), meta=meta, skip_reason=shape.skip_reason)


# ===================================================================== #
# GNN cells
# ===================================================================== #
def _pad_up(x: int, m: int = 512) -> int:
    """Pad a sharded leading dim to a multiple of the largest mesh size
    (512) — argument shardings must divide exactly; padding slots carry
    -1 sentinels and contribute nothing."""
    return ((x + m - 1) // m) * m


def _graph_sds(shape: ShapeSpec, for_nequip: bool):
    ex = shape.extra
    if shape.name == "minibatch_lg":
        n, e = subgraph_shapes(ex["batch_nodes"], tuple(ex["fanout"]))
    elif shape.name == "molecule":
        n = ex["n_nodes"] * ex["batch"]
        e = ex["n_edges"] * ex["batch"]
    else:
        n, e = ex["n_nodes"], ex["n_edges"]
    e = _pad_up(e)
    g = {
        "edge_src": SDS((e,), I32),
        "edge_dst": SDS((e,), I32),
    }
    if for_nequip:
        g["species"] = SDS((n,), I32)
        g["pos"] = SDS((n, 3), jnp.float32)
    else:
        g["x"] = SDS((n, ex["d_feat"]), jnp.float32)
        g["labels"] = SDS((n,), I32)
    if shape.name == "molecule":
        g["graph_ids"] = SDS((n,), I32)
        if for_nequip:
            g["energy"] = SDS((ex["batch"],), jnp.float32)
        else:
            g["graph_labels"] = SDS((ex["batch"],), I32)
    elif for_nequip:
        g["energy"] = SDS((1,), jnp.float32)
    if shape.name == "minibatch_lg" and not for_nequip:
        g["label_mask"] = SDS((n,), jnp.bool_)
    return g, n, e


def _graph_specs(g, mesh, axes):
    """Edges sharded over every mesh axis (flat); node arrays replicated."""
    if mesh is None:
        return None
    all_axes = tuple(mesh.axis_names)
    spec = {}
    for k, v in g.items():
        if k.startswith("edge_"):
            spec[k] = P(all_axes)
        else:
            spec[k] = P(*([None] * v.ndim))
    return _ns(mesh, spec)


def make_gnn_train_step(cfg, loss, ocfg: AdamWConfig, lr: float = 1e-3):
    def train_step(params, opt_state, g):
        (l, _), grads = jax.value_and_grad(
            lambda p: loss(p, g, cfg), has_aux=True)(params)
        params, opt_state, st = adamw_update(
            grads, opt_state, params, lr, ocfg)
        return params, opt_state, l, st["grad_norm"]

    return train_step


def _gnn_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    is_nq = arch.family == "nequip"
    ex = shape.extra
    # full-batch-large shapes: shard node-dim activations over the whole
    # mesh and remat per layer (otherwise 20-80 GB/device of replicated
    # per-layer node tensors — EXPERIMENTS.md §Perf, GNN iteration)
    big = shape.name in ("ogb_products", "minibatch_lg")
    mesh_axes = tuple(mesh.axis_names) if (mesh and big) else None
    if is_nq:
        cfg = dataclasses.replace(
            arch.config, mesh_axes=mesh_axes, remat=big)
        init_fn = functools.partial(nq.init, cfg=cfg)
        loss = nq.mse_loss
    else:
        base = arch.config
        # mixed precision on the large shapes: bf16 activations halve the
        # gather/scatter transients of full-batch-large training
        cfg = dataclasses.replace(
            base, d_in=ex["d_feat"], n_classes=ex["n_classes"],
            mesh_axes=mesh_axes, remat=big,
            dtype=jnp.bfloat16 if big else base.dtype)
        init_fn = functools.partial(gnn.INITS[base.arch], cfg=cfg)
        loss = gnn.node_classification_loss
    ocfg = AdamWConfig(state_mode="fp32")
    params_sds = jax.eval_shape(init_fn, jax.random.PRNGKey(0))
    pspecs = jax.tree.map(lambda _: P(), params_sds)
    opt_sds = jax.eval_shape(lambda p: adamw_init(p, ocfg), params_sds)
    ospecs = adamw_state_specs(pspecs, params_sds, ocfg)

    g, n, e = _graph_sds(shape, is_nq)
    if shape.name == "molecule":
        g2 = dict(g)
        # n_graphs must be static: pass via closure
    axes = MeshAxes.for_mesh(mesh) if mesh else MeshAxes()

    ng = ex.get("batch", 1)

    def loss_with_static(p, graph, c):
        graph = dict(graph)
        if shape.name == "molecule":
            graph["n_graphs"] = ng
        return loss(p, graph, c)

    fn = make_gnn_train_step(cfg, loss_with_static, ocfg)
    in_sh = (_ns(mesh, pspecs), _ns(mesh, ospecs), _graph_specs(g, mesh, axes))
    out_sh = (_ns(mesh, pspecs), _ns(mesh, ospecs), _ns(mesh, P()),
              _ns(mesh, P()))
    d_h = getattr(cfg, "d_hidden", getattr(cfg, "channels", 32))
    layers = cfg.n_layers
    # model flops: fwd+bwd of per-edge message (2*d_h^2-ish) + node MLPs
    meta = dict(model_flops=6 * layers * (e * d_h * d_h + n * d_h * d_h),
                n_nodes=n, n_edges=e)
    return Cell(arch.arch_id, shape.name, fn, (params_sds, opt_sds, g),
                in_sh, out_sh, donate=(0, 1), meta=meta,
                skip_reason=shape.skip_reason)


# ===================================================================== #
# RecSys cells
# ===================================================================== #
def make_recsys_train_step(cfg, ocfg: AdamWConfig, lr: float = 1e-3):
    def train_step(params, opt_state, batch):
        (l, _), grads = jax.value_and_grad(
            wd.bce_loss, has_aux=True)(params, batch, cfg)
        params, opt_state, st = adamw_update(
            grads, opt_state, params, lr, ocfg)
        return params, opt_state, l, st["grad_norm"]

    return train_step


def _recsys_cell(arch: ArchSpec, shape: ShapeSpec, mesh) -> Cell:
    cfg = arch.config
    axes = MeshAxes.for_mesh(mesh) if mesh else MeshAxes()
    ocfg = AdamWConfig(state_mode="factored")
    b = shape.global_batch

    if shape.kind == "retrieval":
        nc = _pad_up(shape.extra["n_candidates"])
        user = SDS((cfg.embed_dim,), jnp.float32)
        cands = SDS((nc, cfg.embed_dim), jnp.float32)
        fn = functools.partial(wd.retrieval_score, top_k=100)
        all_axes = tuple(mesh.axis_names) if mesh else ()
        in_sh = (_ns(mesh, P(None)), _ns(mesh, P(all_axes, None)))
        out_sh = (_ns(mesh, P(None)), _ns(mesh, P(None)))
        meta = dict(model_flops=2 * nc * cfg.embed_dim, n_candidates=nc)
        return Cell(arch.arch_id, shape.name, fn, (user, cands), in_sh,
                    out_sh, donate=(), meta=meta,
                    skip_reason=shape.skip_reason)

    batch = {
        "sparse_ids": SDS((b, cfg.n_sparse), I32),
        "dense": SDS((b, cfg.n_dense), jnp.float32),
        "wide_ids": SDS((b, cfg.n_wide_crosses), I32),
        "labels": SDS((b,), I32),
    }
    bspec = {
        "sparse_ids": P(axes.dp, None), "dense": P(axes.dp, None),
        "wide_ids": P(axes.dp, None), "labels": P(axes.dp),
    }
    params_sds = jax.eval_shape(
        functools.partial(wd.init, cfg=cfg), jax.random.PRNGKey(0))
    pspecs = wd.param_specs(cfg, axes)
    mlp_flops = 0
    d = cfg.n_sparse * cfg.embed_dim + cfg.n_dense
    for h in cfg.mlp:
        mlp_flops += 2 * d * h
        d = h
    embed_bytes = cfg.n_sparse * cfg.embed_dim * 4

    if shape.kind == "train":
        opt_sds = jax.eval_shape(lambda p: adamw_init(p, ocfg), params_sds)
        ospecs = adamw_state_specs(pspecs, params_sds, ocfg)
        fn = make_recsys_train_step(cfg, ocfg)
        in_sh = (_ns(mesh, pspecs), _ns(mesh, ospecs), _ns(mesh, bspec))
        out_sh = (_ns(mesh, pspecs), _ns(mesh, ospecs), _ns(mesh, P()),
                  _ns(mesh, P()))
        meta = dict(model_flops=6 * b * mlp_flops // 2,
                    embed_bytes=3 * b * embed_bytes)
        return Cell(arch.arch_id, shape.name, fn,
                    (params_sds, opt_sds, batch), in_sh, out_sh,
                    donate=(0, 1), meta=meta, skip_reason=shape.skip_reason)

    fn = functools.partial(wd.forward, cfg=cfg)
    in_sh = (_ns(mesh, pspecs), _ns(mesh, bspec))
    out_sh = _ns(mesh, P(axes.dp))
    meta = dict(model_flops=b * mlp_flops, embed_bytes=b * embed_bytes)
    return Cell(arch.arch_id, shape.name, fn, (params_sds, batch), in_sh,
                out_sh, donate=(), meta=meta, skip_reason=shape.skip_reason)


# ===================================================================== #
def build_cell(arch_id: str, shape_name: str, mesh) -> Cell:
    arch = ARCHS[arch_id]
    shape = arch.shape(shape_name)
    if arch.family == "lm":
        return _lm_cell(arch, shape, mesh)
    if arch.family in ("gnn", "nequip"):
        return _gnn_cell(arch, shape, mesh)
    if arch.family == "recsys":
        return _recsys_cell(arch, shape, mesh)
    raise ValueError(arch.family)


def all_cells() -> list[tuple[str, str]]:
    out = []
    for aid, arch in ARCHS.items():
        for s in arch.shapes:
            out.append((aid, s.name))
    return out
