import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512 --xla_disable_hlo_passes=while-loop-invariant-code-motion,while-loop-expensive-invariant-code-motion"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the
production meshes and extract memory/cost/collective analyses.

The two lines above MUST stay first: jax locks the device count on first
init, and only this process should see 512 virtual CPU devices.

The extra ``--xla_disable_hlo_passes`` entries work around a CPU-backend
analysis artifact: XLA:CPU lowers bf16 dots via fp32 converts and its
while-loop invariant-code-motion then hoists a convert of the ENTIRE
remat carry stack out of the backward loop, double-charging it in fp32
(+11.6 GB/device at deepseek-33b scale).  TPU backends execute bf16 dots
natively, so neither the converts nor the hoist exist there.  Measured
in EXPERIMENTS.md §Perf iteration 0.

Usage:
    python -m repro.launch.dryrun --arch gat-cora --shape full_graph_sm
    python -m repro.launch.dryrun --all [--multi-pod] [--force]
Results: benchmarks/results/dryrun/<mesh>/<arch>__<shape>.json
"""

import argparse   # noqa: E402
import json       # noqa: E402
import time       # noqa: E402
import traceback  # noqa: E402

import jax        # noqa: E402

from repro.launch.cells import all_cells, build_cell       # noqa: E402
from repro.launch.mesh import make_production_mesh          # noqa: E402
from repro.launch import roofline as RL                     # noqa: E402

RESULTS_DIR = os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "benchmarks", "results",
    "dryrun")


def _bf16_emulation_bytes(hlo_text: str) -> int:
    """XLA:CPU lowers bf16 dots via fp32 operand copies; estimate the
    resulting fp32 'twin' buffers (an fp32 tensor whose shape also exists
    as bf16, >100 MB).  TPU backends execute bf16 natively, so the
    TPU-native peak estimate subtracts these (recorded, not hidden)."""
    import re as _re

    shapes = {"f32": set(), "bf16": set()}
    for m in _re.finditer(r"(f32|bf16)\[([\d,]+)\]", hlo_text):
        shapes[m.group(1)].add(m.group(2))
    total = 0
    for dims in shapes["f32"] & shapes["bf16"]:
        n = 1
        for d in dims.split(","):
            n *= int(d)
        if n * 4 > 100e6:
            total += n * 4
    return total


def _mem_dict(mem) -> dict:
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    out = {}
    for k in keys:
        v = getattr(mem, k, None)
        if v is not None:
            out[k] = int(v)
    out["peak_bytes_per_device"] = (
        out.get("argument_size_in_bytes", 0)
        + out.get("temp_size_in_bytes", 0)
        + out.get("output_size_in_bytes", 0)
        - out.get("alias_size_in_bytes", 0))
    return out


def _cost_dict(cost) -> dict:
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return {k: float(v) for k, v in dict(cost).items()
            if isinstance(v, (int, float))}


def run_cell(arch_id: str, shape_name: str, multi_pod: bool,
             out_dir: str, force: bool = False,
             include_skipped: bool = True) -> dict:
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(os.path.join(out_dir, mesh_name), exist_ok=True)
    path = os.path.join(out_dir, mesh_name, f"{arch_id}__{shape_name}.json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    rec = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
           "n_chips": int(n_chips)}
    t0 = time.time()
    try:
        cell = build_cell(arch_id, shape_name, mesh)
        rec["meta"] = {k: float(v) for k, v in cell.meta.items()}
        if cell.skip_reason:
            rec["skipped"] = cell.skip_reason
            rec["extra_cell"] = True   # we run it anyway, marked non-required
        with mesh:
            jitted = jax.jit(
                cell.fn,
                in_shardings=cell.in_shardings,
                out_shardings=cell.out_shardings,
                donate_argnums=cell.donate,
            )
            lowered = jitted.lower(*cell.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

            mem = compiled.memory_analysis()
            print(f"[{mesh_name}] {arch_id} x {shape_name}: "
                  f"memory_analysis: {mem}")
            cost = _cost_dict(compiled.cost_analysis())
            print(f"[{mesh_name}] {arch_id} x {shape_name}: cost_analysis "
                  f"flops={cost.get('flops', 0):.3e} "
                  f"bytes={cost.get('bytes accessed', 0):.3e}")
            hlo_text = compiled.as_text()
            coll = RL.collective_bytes(hlo_text)
            emu = _bf16_emulation_bytes(hlo_text)

        rec["memory"] = _mem_dict(mem)
        rec["memory"]["bf16_emulation_f32_bytes"] = int(emu)
        rec["memory"]["tpu_native_peak_estimate"] = max(
            rec["memory"]["peak_bytes_per_device"] - emu, 0)
        rec["cost"] = cost
        rec["collectives"] = coll
        rec["roofline"] = RL.roofline_terms(
            cost, coll, n_chips, cell.meta.get("model_flops"))
        rec["lower_s"] = round(t_lower, 2)
        rec["compile_s"] = round(t_compile, 2)
        rec["ok"] = True
    except Exception as e:  # noqa: BLE001 — record the failure, keep sweeping
        rec["ok"] = False
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["wall_s"] = round(time.time() - t0, 2)
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    status = "OK" if rec.get("ok") else f"FAIL ({rec.get('error', '')[:120]})"
    print(f"[{mesh_name}] {arch_id} x {shape_name}: {status} "
          f"({rec['wall_s']}s)", flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=RESULTS_DIR)
    args = ap.parse_args()

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    n_fail = 0
    if args.all:
        for mp in meshes:
            for arch_id, shape_name in all_cells():
                rec = run_cell(arch_id, shape_name, mp, args.out,
                               force=args.force)
                n_fail += 0 if rec.get("ok") or rec.get("skipped") else 1
    else:
        for mp in meshes:
            rec = run_cell(args.arch, args.shape, mp, args.out,
                           force=args.force)
            n_fail += 0 if rec.get("ok") or rec.get("skipped") else 1
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
