"""Launch layer: mesh construction, cell builders, dry-run, drivers."""
