"""repro: Time Constrained Continuous Subgraph Search over Streaming Graphs.

A production-grade JAX framework reproducing and extending Li, Zou, Özsu,
Zhao (PVLDB 2018): timing-order-constrained subgraph isomorphism over
streaming graphs — expansion lists, MS-tree compressed partial-match
storage, and a TPU-native batched-tick adaptation of the paper's
fine-grained-locking concurrency model.

Subpackages
-----------
api        THE public surface: declarative pattern DSL, canonicalizing
           planner, typed Event/Match records, StreamSession facade
           (register -> subscribe -> ingest/serve -> restore).
core       The paper's contribution: query compilation (TC decomposition,
           join-order selection, canonical forms) and the streaming match
           engine (tick()).
stream     Edge-stream generators, sliding-window bookkeeping.
models     Assigned architecture zoo (LM transformers, GNNs, recsys).
optim      AdamW (+ factored / quantized state), gradient compression.
checkpoint Pytree save/restore with mesh resharding.
runtime    Fault tolerance, elastic scaling, straggler mitigation.
kernels    Pallas TPU kernels (compat_join, segment_reduce, embedding_bag).
configs    One module per assigned architecture + paper query templates.
launch     Mesh construction, multi-pod dry-run, train/serve drivers.
"""

__version__ = "0.1.0"
