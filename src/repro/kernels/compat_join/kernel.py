"""Pallas TPU kernel for the compatibility join (paper Definitions 7/8).

The join predicate between a partial-match row ``a`` and a candidate row
``b`` is a conjunction over a *static* spec:

  * vertex slot pairs: equality where both slots hold the same query
    vertex, inequality everywhere else (isomorphism injectivity);
  * edge slot pairs: strict timestamp order where ≺ relates the edges;
  * optional window-span predicate (sliding-window liveness at the time
    of the combined match's last edge).

TPU mapping
-----------
This is VPU (vector-unit) integer work, not MXU work: the arithmetic
intensity comes from the CA×CB blow-up, while the inputs are narrow
int32 tables.  The kernel tiles the output [CA, CB] into (TA, TB) VMEM
blocks; each grid step loads a [TA, nv+ne] strip of A and a [TB, nv+ne]
strip of B (a few KB each), performs all slot-pair compares in
registers, and writes one int8 [TA, TB] block.  HBM traffic is therefore
O(CA·nv + CB·nv + CA·CB/1) bytes instead of the O(CA·CB·nv) a naive
broadcast materializes — same insight FlashAttention applies to softmax
attention, applied to the paper's join.

The REL/TREL specs are baked in as Python constants (kernel
specialization), so slot-pair loops fully unroll with zero control flow.
"""

from __future__ import annotations

import functools

import numpy as np
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


# VMEM tile sizes: (8, 128) is the fp32/int32 VREG tile on TPU; we use
# multiples that keep the three live blocks ((TA,K)+(TB,K)+(TA,TB)) well
# under 1 MB of VMEM while amortizing grid overhead.
TILE_A = 256
TILE_B = 256


def _kernel_body(
    bind_a_ref, ets_a_ref, valid_a_ref,
    bind_b_ref, ets_b_ref, valid_b_ref,
    out_ref,
    *, rel, trel, window,
):
    va = valid_a_ref[...]                    # int32 [TA]
    vb = valid_b_ref[...]                    # int32 [TB]
    m = (va[:, None] > 0) & (vb[None, :] > 0)  # bool [TA, TB]

    nva, nvb = rel.shape
    for i in range(nva):
        ai = bind_a_ref[:, i][:, None]       # [TA, 1]
        for j in range(nvb):
            bj = bind_b_ref[:, j][None, :]   # [1, TB]
            if rel[i, j]:
                m = m & (ai == bj)
            else:
                m = m & (ai != bj)

    nea, neb = trel.shape
    for i in range(nea):
        ti = ets_a_ref[:, i][:, None]
        for j in range(neb):
            if trel[i, j] == -1:
                m = m & (ti < ets_b_ref[:, j][None, :])
            elif trel[i, j] == 1:
                m = m & (ti > ets_b_ref[:, j][None, :])

    if window is not None:
        min_a = ets_a_ref[:, 0][:, None]
        max_a = ets_a_ref[:, 0][:, None]
        for i in range(1, nea):
            ti = ets_a_ref[:, i][:, None]
            min_a = jnp.minimum(min_a, ti)
            max_a = jnp.maximum(max_a, ti)
        min_b = ets_b_ref[:, 0][None, :]
        max_b = ets_b_ref[:, 0][None, :]
        for j in range(1, neb):
            tj = ets_b_ref[:, j][None, :]
            min_b = jnp.minimum(min_b, tj)
            max_b = jnp.maximum(max_b, tj)
        span = jnp.maximum(max_a, max_b) - jnp.minimum(min_a, min_b)
        m = m & (span < window)

    out_ref[...] = m.astype(jnp.int8)


def compat_mask_kernel(
    bind_a, ets_a, valid_a,        # [CA, NVA] i32, [CA, NEA] i32, [CA] i32
    bind_b, ets_b, valid_b,        # [CB, NVB] i32, [CB, NEB] i32, [CB] i32
    rel: tuple,                    # static: tuple-of-tuples bool
    trel: tuple,                   # static: tuple-of-tuples int
    window: int | None,
    interpret: bool = False,
):
    """Tiled pallas_call; CA/CB must be multiples of TILE_A/TILE_B."""
    ca, nva = bind_a.shape
    cb, nvb = bind_b.shape
    nea = ets_a.shape[1]
    neb = ets_b.shape[1]
    rel_np = np.array(rel, dtype=bool).reshape(nva, nvb)
    trel_np = np.array(trel, dtype=np.int8).reshape(nea, neb)

    grid = (ca // TILE_A, cb // TILE_B)
    body = functools.partial(
        _kernel_body, rel=rel_np, trel=trel_np, window=window)

    return pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_A, nva), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_A, nea), lambda i, j: (i, 0)),
            pl.BlockSpec((TILE_A,), lambda i, j: (i,)),
            pl.BlockSpec((TILE_B, nvb), lambda i, j: (j, 0)),
            pl.BlockSpec((TILE_B, neb), lambda i, j: (j, 0)),
            pl.BlockSpec((TILE_B,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((TILE_A, TILE_B), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((ca, cb), jnp.int8),
        interpret=interpret,
    )(bind_a, ets_a, valid_a, bind_b, ets_b, valid_b)
