"""Pallas TPU kernels for the compatibility join (paper Definitions 7/8).

The join predicate between a partial-match row ``a`` and a candidate row
``b`` is a conjunction over a *static* spec:

  * vertex slot pairs: equality where both slots hold the same query
    vertex, inequality everywhere else (isomorphism injectivity);
  * edge slot pairs: strict timestamp order where ≺ relates the edges;
  * optional window-span predicate (sliding-window liveness at the time
    of the combined match's last edge).

TPU mapping
-----------
This is VPU (vector-unit) integer work, not MXU work: the arithmetic
intensity comes from the CA×CB blow-up, while the inputs are narrow
int32 tables.  The kernels tile the [CA, CB] pair space into (TA, TB)
VMEM blocks; each grid step loads a [TA, nv+ne] strip of A and a
[TB, nv+ne] strip of B (a few KB each) and performs all slot-pair
compares in registers.  HBM traffic is therefore O(CA·nv + CB·nv +
outputs) bytes instead of the O(CA·CB·nv) a naive broadcast
materializes — the FlashAttention insight applied to the paper's join.

Dispatch rules (what runs where)
--------------------------------
Static kernel-specialization constants are ONLY the REL/TREL spec
matrices (tiny nested tuples — slot-pair loops fully unroll with zero
control flow), the tile sizes, and whether a window predicate exists.
Everything else is runtime data:

  * ``window`` is a traced scalar-prefetch input
    (``pltpu.PrefetchScalarGridSpec``), so per-slot runtime windows —
    as produced by ``repro.core.multi.build_slot_tick`` — never force a
    recompile and never fragment the jit cache.
  * Batched (vmapped) slot-group joins lower to ONE stacked
    ``pallas_call`` over a 3-D grid ``(slot, A-tile, B-tile)`` with
    ``[n_slots, C, nv]`` inputs; see the custom-vmap rule in ``ops.py``.
  * ``compat_mask_kernel``   -> int8 [CA, CB] compatibility mask.
  * ``compat_join_pairs_kernel`` -> fused mask + on-chip pair
    extraction: compacted ``(a_idx, b_idx)`` pairs plus the total match
    count, with NO [CA, CB] mask ever written to HBM.  A running SMEM
    counter carries the output cursor across the (sequential) grid
    steps; each tile emits its matches with a short dynamic-trip
    ``fori_loop`` (first-set-bit via a masked min over an on-tile
    linear iota).  Pairs are emitted in tile order, so callers get set
    semantics: the same pairs as mask+nonzero, exactly equal
    ``n_dropped``, but an unspecified keep-subset on overflow.

Tiling rules
------------
``choose_tiles(ca, cb)`` picks (TA, TB) adaptively: TA rounds CA up to
the int32 sublane (8) and TB rounds CB up to the lane width (128), both
capped at 256.  A 64-row delta join therefore runs as one 64×128 tile
instead of a padded 256×256 one (≈ 8× less wasted work on the common
small-delta case) while large tables still get the bandwidth-friendly
256×256 blocks, keeping the live blocks ((TA,K)+(TB,K)+(TA,TB)) well
under 1 MB of VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# Upper bounds for the adaptive tiles: (8, 128) is the int32 VREG tile
# on TPU; 256×256 keeps the three live blocks well under 1 MB of VMEM
# while amortizing grid overhead on large tables.
TILE_A = 256
TILE_B = 256

_SUBLANE = 8   # int32 second-to-last dim granularity
_LANE = 128    # last dim granularity


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def choose_tiles(ca: int, cb: int) -> tuple[int, int]:
    """Adaptive (TILE_A, TILE_B) from the actual table shapes.

    Rounds to hardware granularity ((8, 128) for int32) and caps at
    (TILE_A, TILE_B) so small deltas aren't padded up to a full
    256×256 tile.
    """
    ta = min(TILE_A, _ceil_to(max(ca, 1), _SUBLANE))
    tb = min(TILE_B, _ceil_to(max(cb, 1), _LANE))
    return ta, tb


def _tile_mask(ba, ea, va, bb, eb, vb, w, *, rel, trel):
    """The join predicate over one (TA, TB) tile, on register values.

    ``rel``/``trel`` are static nested tuples -> the loops fully unroll.
    ``w`` is a traced scalar (window span) or None (no window predicate).
    """
    m = (va[:, None] > 0) & (vb[None, :] > 0)    # bool [TA, TB]

    nva, nvb = len(rel), len(rel[0]) if rel else 0
    for i in range(nva):
        ai = ba[:, i][:, None]                   # [TA, 1]
        for j in range(nvb):
            bj = bb[:, j][None, :]               # [1, TB]
            if rel[i][j]:
                m = m & (ai == bj)
            else:
                m = m & (ai != bj)

    nea, neb = len(trel), len(trel[0]) if trel else 0
    for i in range(nea):
        ti = ea[:, i][:, None]
        for j in range(neb):
            if trel[i][j] == -1:
                m = m & (ti < eb[:, j][None, :])
            elif trel[i][j] == 1:
                m = m & (ti > eb[:, j][None, :])

    if w is not None:
        min_a = ea[:, 0][:, None]
        max_a = ea[:, 0][:, None]
        for i in range(1, ea.shape[1]):
            ti = ea[:, i][:, None]
            min_a = jnp.minimum(min_a, ti)
            max_a = jnp.maximum(max_a, ti)
        min_b = eb[:, 0][None, :]
        max_b = eb[:, 0][None, :]
        for j in range(1, eb.shape[1]):
            tj = eb[:, j][None, :]
            min_b = jnp.minimum(min_b, tj)
            max_b = jnp.maximum(max_b, tj)
        span = jnp.maximum(max_a, max_b) - jnp.minimum(min_a, min_b)
        m = m & (span < w)
    return m


# --------------------------------------------------------------------- #
# Compatibility mask kernels (int8 [CA, CB] output).
#
# ``batched`` in the stacked (3-D grid) kernels is a per-input tuple of
# six bools (bind_a, ets_a, valid_a, bind_b, ets_b, valid_b): inputs
# shared across slots — e.g. the slot tick's stream-edge operand — stay
# 2-D and are read once via an index_map that ignores the slot axis,
# instead of being broadcast S× through HBM.
# --------------------------------------------------------------------- #
def _read(ref, is_batched):
    """Squeeze the leading length-1 slot-block dim of a batched ref."""
    return ref[0] if is_batched else ref[...]


def _mask_body(
    w_ref,
    ba_ref, ea_ref, va_ref,
    bb_ref, eb_ref, vb_ref,
    out_ref,
    *, rel, trel, has_window, batched,
):
    if batched is None:          # unbatched 2-D grid
        s = 0
        flags = (False,) * 6
    else:                        # stacked 3-D grid; out always batched
        s = pl.program_id(0)
        flags = batched
    ba, ea, va, bb, eb, vb = (
        _read(r, f) for r, f in
        zip((ba_ref, ea_ref, va_ref, bb_ref, eb_ref, vb_ref), flags))
    w = w_ref[s] if has_window else None
    m = _tile_mask(ba, ea, va, bb, eb, vb, w, rel=rel, trel=trel)
    if batched is None:
        out_ref[...] = m.astype(jnp.int8)
    else:
        out_ref[0] = m.astype(jnp.int8)


def compat_mask_kernel(
    window,                        # int32 [1] (scalar prefetch; dummy if !has_window)
    bind_a, ets_a, valid_a,        # [CA, NVA] i32, [CA, NEA] i32, [CA] i32
    bind_b, ets_b, valid_b,        # [CB, NVB] i32, [CB, NEB] i32, [CB] i32
    *,
    rel: tuple,                    # static: nested tuples bool
    trel: tuple,                   # static: nested tuples int
    has_window: bool,
    tile_a: int,
    tile_b: int,
    interpret: bool = False,
):
    """Tiled pallas_call; CA/CB must be multiples of tile_a/tile_b."""
    ca, nva = bind_a.shape
    cb, nvb = bind_b.shape
    nea = ets_a.shape[1]
    neb = ets_b.shape[1]
    grid = (ca // tile_a, cb // tile_b)
    body = functools.partial(
        _mask_body, rel=rel, trel=trel, has_window=has_window, batched=None)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_a, nva), lambda i, j, w: (i, 0)),
            pl.BlockSpec((tile_a, nea), lambda i, j, w: (i, 0)),
            pl.BlockSpec((tile_a,), lambda i, j, w: (i,)),
            pl.BlockSpec((tile_b, nvb), lambda i, j, w: (j, 0)),
            pl.BlockSpec((tile_b, neb), lambda i, j, w: (j, 0)),
            pl.BlockSpec((tile_b,), lambda i, j, w: (j,)),
        ],
        out_specs=pl.BlockSpec((tile_a, tile_b), lambda i, j, w: (i, j)),
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((ca, cb), jnp.int8),
        interpret=interpret,
    )(window, bind_a, ets_a, valid_a, bind_b, ets_b, valid_b)


def _stacked_in_specs(batched, tile_a, tile_b, widths):
    """Per-input BlockSpecs for the stacked 3-D grid.

    ``batched[k]`` marks inputs with a leading [S] slot axis; inputs
    shared across slots keep their 2-D shape and an index_map that
    ignores the slot grid dim (read once, not broadcast S× in HBM).
    ``widths`` is (nva, nea, nvb, neb).
    """
    nva, nea, nvb, neb = widths
    # (block shape w/o leading dim, index_map w/o slot coordinate)
    base = [
        ((tile_a, nva), lambda s, i, j, w: (i, 0)),
        ((tile_a, nea), lambda s, i, j, w: (i, 0)),
        ((tile_a,), lambda s, i, j, w: (i,)),
        ((tile_b, nvb), lambda s, i, j, w: (j, 0)),
        ((tile_b, neb), lambda s, i, j, w: (j, 0)),
        ((tile_b,), lambda s, i, j, w: (j,)),
    ]
    specs = []
    for flag, (shape, idx) in zip(batched, base):
        if flag:
            specs.append(pl.BlockSpec(
                (1,) + shape,
                lambda s, i, j, w, idx=idx: (s,) + idx(s, i, j, w)))
        else:
            specs.append(pl.BlockSpec(shape, idx))
    return specs


def compat_mask_kernel_batched(
    window,                        # int32 [S] (scalar prefetch)
    bind_a, ets_a, valid_a,        # [S, CA, NVA] / [CA, NVA] etc.
    bind_b, ets_b, valid_b,        # [S, CB, NVB] / [CB, NVB] etc.
    *,
    rel: tuple,
    trel: tuple,
    has_window: bool,
    tile_a: int,
    tile_b: int,
    batched: tuple,                # static: which of the six inputs carry [S]
    n_slots: int,
    interpret: bool = False,
):
    """Stacked slot-group variant: ONE pallas_call over a 3-D grid
    (slot, A-tile, B-tile) — the batched rule for vmapped joins."""
    ca, nva = bind_a.shape[-2], bind_a.shape[-1]
    cb, nvb = bind_b.shape[-2], bind_b.shape[-1]
    nea = ets_a.shape[-1]
    neb = ets_b.shape[-1]
    grid = (n_slots, ca // tile_a, cb // tile_b)
    body = functools.partial(
        _mask_body, rel=rel, trel=trel, has_window=has_window,
        batched=batched)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=_stacked_in_specs(batched, tile_a, tile_b,
                                   (nva, nea, nvb, neb)),
        out_specs=pl.BlockSpec(
            (1, tile_a, tile_b), lambda s, i, j, w: (s, i, j)),
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_slots, ca, cb), jnp.int8),
        interpret=interpret,
    )(window, bind_a, ets_a, valid_a, bind_b, ets_b, valid_b)


# --------------------------------------------------------------------- #
# Fused mask + on-chip pair extraction kernels.
# --------------------------------------------------------------------- #
def _pairs_body(
    w_ref,
    ba_ref, ea_ref, va_ref,
    bb_ref, eb_ref, vb_ref,
    a_out, b_out, n_out,
    cnt_ref,
    *, rel, trel, has_window, batched, tile_a, tile_b, max_new,
):
    if batched is None:          # unbatched 2-D grid
        s = 0
        i, j = pl.program_id(0), pl.program_id(1)
        n_i, n_j = pl.num_programs(0), pl.num_programs(1)
        flags = (False,) * 6
    else:                        # stacked 3-D grid; outputs batched
        s = pl.program_id(0)
        i, j = pl.program_id(1), pl.program_id(2)
        n_i, n_j = pl.num_programs(1), pl.num_programs(2)
        flags = batched
    ba, ea, va, bb, eb, vb = (
        _read(r, f) for r, f in
        zip((ba_ref, ea_ref, va_ref, bb_ref, eb_ref, vb_ref), flags))

    # Grid steps are sequential; (i, j) == (0, 0) is each slot's first
    # visit — reset the running cursor and the (revisited) output block.
    @pl.when((i == 0) & (j == 0))
    def _init():
        cnt_ref[0] = 0
        if batched is not None:
            a_out[...] = jnp.full((1, max_new), -1, jnp.int32)
            b_out[...] = jnp.full((1, max_new), -1, jnp.int32)
        else:
            a_out[...] = jnp.full((max_new,), -1, jnp.int32)
            b_out[...] = jnp.full((max_new,), -1, jnp.int32)

    w = w_ref[s] if has_window else None
    m = _tile_mask(ba, ea, va, bb, eb, vb, w, rel=rel, trel=trel)
    n_tile = jnp.sum(m.astype(jnp.int32))
    base = cnt_ref[0]

    # Emit this tile's matches at out[base:base+n_emit] by repeatedly
    # taking the first set element (masked min over a linear iota) and
    # clearing it.  Trip count is the tile's match count (sparse joins:
    # usually 0), clipped to the remaining output capacity.
    rows = jax.lax.broadcasted_iota(jnp.int32, (tile_a, tile_b), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (tile_a, tile_b), 1)
    lin = rows * tile_b + cols
    sentinel = jnp.int32(tile_a * tile_b)
    n_emit = jnp.minimum(n_tile, jnp.maximum(max_new - base, 0))

    def emit(k, mm):
        masked = jnp.where(mm, lin, sentinel)
        first = jnp.min(masked)
        r = first // tile_b
        c = first - r * tile_b
        p = base + k
        if batched is not None:
            a_out[0, p] = i * tile_a + r
            b_out[0, p] = j * tile_b + c
        else:
            a_out[p] = i * tile_a + r
            b_out[p] = j * tile_b + c
        return mm & (masked != first)

    jax.lax.fori_loop(0, n_emit, emit, m)
    cnt_ref[0] = base + n_tile          # count ALL matches (overflow stat)

    @pl.when((i == n_i - 1) & (j == n_j - 1))
    def _fin():
        if batched is not None:
            n_out[0, 0] = cnt_ref[0]
        else:
            n_out[0] = cnt_ref[0]


def compat_join_pairs_kernel(
    window,                        # int32 [1] (scalar prefetch)
    bind_a, ets_a, valid_a,
    bind_b, ets_b, valid_b,
    *,
    rel: tuple,
    trel: tuple,
    has_window: bool,
    tile_a: int,
    tile_b: int,
    max_new: int,
    interpret: bool = False,
):
    """Fused join + compaction: returns (a_idx [max_new], b_idx [max_new],
    n_total [1]) with -1 fill — no [CA, CB] mask in HBM."""
    ca, nva = bind_a.shape
    cb, nvb = bind_b.shape
    nea = ets_a.shape[1]
    neb = ets_b.shape[1]
    grid = (ca // tile_a, cb // tile_b)
    body = functools.partial(
        _pairs_body, rel=rel, trel=trel, has_window=has_window,
        batched=None, tile_a=tile_a, tile_b=tile_b, max_new=max_new)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_a, nva), lambda i, j, w: (i, 0)),
            pl.BlockSpec((tile_a, nea), lambda i, j, w: (i, 0)),
            pl.BlockSpec((tile_a,), lambda i, j, w: (i,)),
            pl.BlockSpec((tile_b, nvb), lambda i, j, w: (j, 0)),
            pl.BlockSpec((tile_b, neb), lambda i, j, w: (j, 0)),
            pl.BlockSpec((tile_b,), lambda i, j, w: (j,)),
        ],
        out_specs=[
            pl.BlockSpec((max_new,), lambda i, j, w: (0,)),
            pl.BlockSpec((max_new,), lambda i, j, w: (0,)),
            pl.BlockSpec((1,), lambda i, j, w: (0,)),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((max_new,), jnp.int32),
            jax.ShapeDtypeStruct((max_new,), jnp.int32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(window, bind_a, ets_a, valid_a, bind_b, ets_b, valid_b)


def compat_join_pairs_kernel_batched(
    window,                        # int32 [S]
    bind_a, ets_a, valid_a,        # [S, CA, ...] / [CA, ...]
    bind_b, ets_b, valid_b,        # [S, CB, ...] / [CB, ...]
    *,
    rel: tuple,
    trel: tuple,
    has_window: bool,
    tile_a: int,
    tile_b: int,
    max_new: int,
    batched: tuple,                # static: which of the six inputs carry [S]
    n_slots: int,
    interpret: bool = False,
):
    """Stacked slot-group fused join: 3-D grid (slot, A-tile, B-tile);
    the SMEM cursor resets at each slot's first tile."""
    ca, nva = bind_a.shape[-2], bind_a.shape[-1]
    cb, nvb = bind_b.shape[-2], bind_b.shape[-1]
    nea = ets_a.shape[-1]
    neb = ets_b.shape[-1]
    grid = (n_slots, ca // tile_a, cb // tile_b)
    body = functools.partial(
        _pairs_body, rel=rel, trel=trel, has_window=has_window,
        batched=batched, tile_a=tile_a, tile_b=tile_b, max_new=max_new)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=_stacked_in_specs(batched, tile_a, tile_b,
                                   (nva, nea, nvb, neb)),
        out_specs=[
            pl.BlockSpec((1, max_new), lambda s, i, j, w: (s, 0)),
            pl.BlockSpec((1, max_new), lambda s, i, j, w: (s, 0)),
            pl.BlockSpec((1, 1), lambda s, i, j, w: (s, 0)),
        ],
        scratch_shapes=[pltpu.SMEM((1,), jnp.int32)],
    )
    return pl.pallas_call(
        body,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((n_slots, max_new), jnp.int32),
            jax.ShapeDtypeStruct((n_slots, max_new), jnp.int32),
            jax.ShapeDtypeStruct((n_slots, 1), jnp.int32),
        ],
        interpret=interpret,
    )(window, bind_a, ets_a, valid_a, bind_b, ets_b, valid_b)
