"""Pure-jnp oracle for the compat_join kernel (same code path the engine
uses as its reference backend)."""

from repro.core.join import compat_mask_ref


def compat_mask(bind_a, ets_a, valid_a, bind_b, ets_b, valid_b, rel, trel,
                window=None):
    return compat_mask_ref(
        bind_a, ets_a, valid_a, bind_b, ets_b, valid_b, rel, trel, window)
