"""Pure-jnp oracles for the compat_join kernels (same code paths the
engine uses as its reference backend)."""

from repro.core.join import compat_mask_ref, extract_pairs


def compat_mask(bind_a, ets_a, valid_a, bind_b, ets_b, valid_b, rel, trel,
                window=None):
    return compat_mask_ref(
        bind_a, ets_a, valid_a, bind_b, ets_b, valid_b, rel, trel, window)


def compat_join_pairs(bind_a, ets_a, valid_a, bind_b, ets_b, valid_b,
                      rel, trel, max_new, window=None):
    """Oracle for the fused kernel: materialize the mask, then extract.

    Keep-order is the mask's flattened row-major order; the fused kernel
    guarantees the same pair SET and the same ``n_dropped`` (tile-order
    emission — see ``ops.compat_join_pairs``).
    """
    mask = compat_mask_ref(
        bind_a, ets_a, valid_a, bind_b, ets_b, valid_b, rel, trel, window)
    return extract_pairs(mask, max_new)
