"""Public jit'd wrappers for the compat_join Pallas kernels.

Responsibilities:

* **Spec normalization cache** — ``normalize_spec`` converts the
  REL/TREL numpy matrices into hashable nested tuples ONCE per distinct
  spec (lru-cached by content), so repeated joins with the same spec
  reuse the *identical* static kernel key instead of rebuilding nested
  tuples per tick.
* **Adaptive tiling + padding** — tile sizes come from
  ``kernel.choose_tiles`` (shape-derived), and the capacity axes are
  padded to tile multiples with ``valid=0`` rows that never match.
* **Batched (vmapped) dispatch** — each op is wrapped in
  ``jax.custom_batching.custom_vmap``: an unvmapped call lowers to the
  2-D-grid kernel, while a vmapped call (the slot ticks of
  ``repro.core.multi``) lowers to ONE stacked 3-D-grid kernel over
  ``(slot, A-tile, B-tile)`` — one ``pallas_call`` per join for the
  whole slot group, with per-slot traced windows.  Operands shared
  across slots (e.g. the slot tick's stream-edge side) are NOT
  broadcast: they stay 2-D and the kernel's index_map ignores the slot
  grid dim, so the shared bytes are read once.
* **Traced window** — ``window`` is passed to the kernel as a
  scalar-prefetch input; changing it (or any slot's window) never
  recompiles.  Only *whether* a window predicate exists is static.

Ops:

``compat_mask``        -> bool [CA, CB]   (drop-in for
                          ``core.join.compat_mask_ref``)
``compat_join_pairs``  -> (a_idx, b_idx, pair_valid, n_dropped), the
                          fused equivalent of ``compat_mask`` +
                          ``core.join.extract_pairs`` with no [CA, CB]
                          mask materialized in HBM.  Pairs are emitted
                          in tile order: same pair SET and exact
                          ``n_dropped``; the keep-subset under overflow
                          is backend-defined.
"""

from __future__ import annotations

import functools

import numpy as np
import jax.numpy as jnp
from jax.custom_batching import custom_vmap

from repro.kernels.compat_join import kernel as K


# --------------------------------------------------------------------- #
# Spec normalization (lru-cached by content).
# --------------------------------------------------------------------- #
@functools.lru_cache(maxsize=1024)
def _spec_from_bytes(rel_bytes, rel_shape, trel_bytes, trel_shape):
    rel = np.frombuffer(rel_bytes, dtype=np.bool_).reshape(rel_shape)
    trel = np.frombuffer(trel_bytes, dtype=np.int8).reshape(trel_shape)
    return (tuple(map(tuple, rel.tolist())),
            tuple(map(tuple, trel.tolist())))


def normalize_spec(rel, trel):
    """Hashable nested-tuple ``(rel, trel)`` static kernel key.

    Cached by content so every tick that joins with the same spec gets
    back the *same* tuple objects — hash once, compare by identity —
    instead of rebuilding ``tuple(map(tuple, rel.tolist()))`` per call.
    """
    rel = np.ascontiguousarray(np.asarray(rel, dtype=np.bool_))
    trel = np.ascontiguousarray(np.asarray(trel, dtype=np.int8))
    return _spec_from_bytes(rel.tobytes(), rel.shape,
                            trel.tobytes(), trel.shape)


# --------------------------------------------------------------------- #
# Padding helpers.
# --------------------------------------------------------------------- #
def _pad_to(x, n, axis=0):
    pad = n - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


_ceil_to = K._ceil_to


def _as_window(window):
    """Traced 0-d int32 window (0 dummy when the predicate is off)."""
    if window is None:
        return jnp.zeros((), jnp.int32)
    return jnp.asarray(window, jnp.int32).reshape(())


def _prep_tables(bind, ets, valid, cap, axis):
    return (_pad_to(bind.astype(jnp.int32), cap, axis),
            _pad_to(ets.astype(jnp.int32), cap, axis),
            _pad_to(valid.astype(jnp.int32), cap, axis))


def _prep_stacked(args, in_batched, axis_size):
    """Pad/cast the six table args + window for the stacked kernel.

    Per-slot inputs pad along their row axis (1); inputs shared across
    slots stay 2-D — the kernel reads them once via an index_map that
    ignores the slot grid dim instead of broadcasting S× through HBM.
    Only the (tiny) window is materialized per-slot.
    """
    *tables, window = args
    flags = tuple(bool(b) for b in in_batched[:6])
    if not in_batched[6]:
        window = jnp.broadcast_to(window, (axis_size,))
    ca = tables[0].shape[-2]
    cb = tables[3].shape[-2]
    ta, tb = K.choose_tiles(ca, cb)
    cap, cbp = _ceil_to(max(ca, 1), ta), _ceil_to(max(cb, 1), tb)
    padded = [
        _pad_to(x.astype(jnp.int32), n, axis=1 if f else 0)
        for x, f, n in zip(tables, flags,
                           (cap, cap, cap, cbp, cbp, cbp))
    ]
    return (window.reshape(axis_size), padded, flags,
            dict(tile_a=ta, tile_b=tb), ca, cb)


# --------------------------------------------------------------------- #
# compat_mask: custom-vmap op per static (spec, has_window, interpret).
# --------------------------------------------------------------------- #
@functools.lru_cache(maxsize=None)
def _mask_op(rel, trel, has_window, interpret):
    @custom_vmap
    def op(bind_a, ets_a, valid_a, bind_b, ets_b, valid_b, window):
        ca, cb = bind_a.shape[0], bind_b.shape[0]
        ta, tb = K.choose_tiles(ca, cb)
        cap, cbp = _ceil_to(max(ca, 1), ta), _ceil_to(max(cb, 1), tb)
        a = _prep_tables(bind_a, ets_a, valid_a, cap, 0)
        b = _prep_tables(bind_b, ets_b, valid_b, cbp, 0)
        out = K.compat_mask_kernel(
            window.reshape(1), *a, *b,
            rel=rel, trel=trel, has_window=has_window,
            tile_a=ta, tile_b=tb, interpret=interpret)
        return out[:ca, :cb].astype(jnp.bool_)

    @op.def_vmap
    def _rule(axis_size, in_batched, *args):
        window, padded, flags, tiles, ca, cb = _prep_stacked(
            args, in_batched, axis_size)
        out = K.compat_mask_kernel_batched(
            window, *padded,
            rel=rel, trel=trel, has_window=has_window, **tiles,
            batched=flags, n_slots=axis_size, interpret=interpret)
        return out[:, :ca, :cb].astype(jnp.bool_), True

    return op


def compat_mask(bind_a, ets_a, valid_a, bind_b, ets_b, valid_b, rel, trel,
                window=None, interpret: bool = False):
    """Drop-in replacement for ``core.join.compat_mask_ref`` -> bool [CA, CB].

    ``window`` may be a Python int or a traced scalar (per-slot runtime
    windows); it is a scalar-prefetch kernel input, not a compile-time
    constant.  Under ``jax.vmap`` the op lowers to one stacked
    3-D-grid kernel for the whole batch.
    """
    rel_tt, trel_tt = normalize_spec(rel, trel)
    op = _mask_op(rel_tt, trel_tt, window is not None, bool(interpret))
    return op(bind_a, ets_a, valid_a, bind_b, ets_b, valid_b,
              _as_window(window))


# --------------------------------------------------------------------- #
# compat_join_pairs: fused mask + on-chip pair extraction.
# --------------------------------------------------------------------- #
@functools.lru_cache(maxsize=None)
def _pairs_op(rel, trel, max_new, has_window, interpret):
    @custom_vmap
    def op(bind_a, ets_a, valid_a, bind_b, ets_b, valid_b, window):
        ca, cb = bind_a.shape[0], bind_b.shape[0]
        ta, tb = K.choose_tiles(ca, cb)
        cap, cbp = _ceil_to(max(ca, 1), ta), _ceil_to(max(cb, 1), tb)
        a = _prep_tables(bind_a, ets_a, valid_a, cap, 0)
        b = _prep_tables(bind_b, ets_b, valid_b, cbp, 0)
        a_idx, b_idx, n_total = K.compat_join_pairs_kernel(
            window.reshape(1), *a, *b,
            rel=rel, trel=trel, has_window=has_window,
            tile_a=ta, tile_b=tb, max_new=max_new, interpret=interpret)
        return a_idx, b_idx, n_total[0]

    @op.def_vmap
    def _rule(axis_size, in_batched, *args):
        window, padded, flags, tiles, ca, cb = _prep_stacked(
            args, in_batched, axis_size)
        a_idx, b_idx, n_total = K.compat_join_pairs_kernel_batched(
            window, *padded,
            rel=rel, trel=trel, has_window=has_window, **tiles,
            max_new=max_new, batched=flags, n_slots=axis_size,
            interpret=interpret)
        return (a_idx, b_idx, n_total[:, 0]), (True, True, True)

    return op


def compat_join_pairs(bind_a, ets_a, valid_a, bind_b, ets_b, valid_b,
                      rel, trel, max_new: int, window=None,
                      interpret: bool = False):
    """Fused ``compat_mask`` + ``extract_pairs``: top-``max_new``
    (a, b) pairs of the join, computed on-chip with no [CA, CB] mask
    ever written to HBM.

    Returns ``(a_idx, b_idx, pair_valid, n_dropped)`` with the same
    contract as ``core.join.extract_pairs`` applied to the mask, except
    that pairs are emitted in tile order (set semantics; ``n_dropped``
    is exact, the keep-subset under overflow is backend-defined).
    """
    rel_tt, trel_tt = normalize_spec(rel, trel)
    op = _pairs_op(rel_tt, trel_tt, int(max_new), window is not None,
                   bool(interpret))
    a_raw, b_raw, n_total = op(
        bind_a, ets_a, valid_a, bind_b, ets_b, valid_b, _as_window(window))
    pair_valid = a_raw >= 0
    a_idx = jnp.maximum(a_raw, 0)
    b_idx = jnp.maximum(b_raw, 0)
    n_dropped = jnp.maximum(n_total - max_new, 0)
    return a_idx, b_idx, pair_valid, n_dropped
