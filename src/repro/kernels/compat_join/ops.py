"""Public jit'd wrapper for the compat_join Pallas kernel.

Handles: padding the capacity axes to tile multiples (padded rows carry
valid=0 so they never match), int32 casting of the bool valid masks, and
the interpret switch for CPU validation.
"""

from __future__ import annotations

import functools

import jax.numpy as jnp

from repro.kernels.compat_join.kernel import TILE_A, TILE_B, compat_mask_kernel


def _pad_to(x, n, axis=0):
    pad = n - x.shape[axis]
    if pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths)


def _ceil_to(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


def compat_mask(bind_a, ets_a, valid_a, bind_b, ets_b, valid_b, rel, trel,
                window=None, interpret: bool = False):
    """Drop-in replacement for ``core.join.compat_mask_ref`` -> bool [CA, CB]."""
    ca, cb = bind_a.shape[0], bind_b.shape[0]
    cap = _ceil_to(max(ca, 1), TILE_A)
    cbp = _ceil_to(max(cb, 1), TILE_B)

    out = compat_mask_kernel(
        _pad_to(bind_a.astype(jnp.int32), cap),
        _pad_to(ets_a.astype(jnp.int32), cap),
        _pad_to(valid_a.astype(jnp.int32), cap),
        _pad_to(bind_b.astype(jnp.int32), cbp),
        _pad_to(ets_b.astype(jnp.int32), cbp),
        _pad_to(valid_b.astype(jnp.int32), cbp),
        rel=tuple(map(tuple, rel.tolist())),
        trel=tuple(map(tuple, trel.tolist())),
        window=int(window) if window is not None else None,
        interpret=interpret,
    )
    return out[:ca, :cb].astype(jnp.bool_)
