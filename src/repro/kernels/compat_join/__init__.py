from repro.kernels.compat_join.ops import (
    compat_join_pairs,
    compat_mask,
    normalize_spec,
)
