from repro.kernels.compat_join.ops import compat_mask
