"""Pallas TPU kernel: EmbeddingBag (multi-hot gather + segment-sum).

JAX has no native EmbeddingBag; the TPU-native pattern is *scalar-
prefetched data-dependent BlockSpecs*: the flat id list is prefetched as
a scalar operand, and the embedding TABLE's index_map reads ids[i] — so
each grid step DMAs exactly the one table row it needs from HBM into
VMEM (rows pipeline across steps).  Bags are contiguous in the flat id
list (sorted by bag), so the output bag row is revisited consecutively
and accumulates in VMEM, FBGEMM-TBE style.

Grid: (total_ids,)
  table block [1, D]  — row chosen by ids[i] (data-dependent index map)
  out   block [1, D]  — row chosen by bag[i]; zeroed on first visit
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, bags_ref, first_ref, table_row_ref, out_row_ref):
    i = pl.program_id(0)

    @pl.when(first_ref[i] > 0)
    def _init():
        out_row_ref[...] = jnp.zeros_like(out_row_ref)

    @pl.when(ids_ref[i] >= 0)
    def _acc():
        out_row_ref[...] += table_row_ref[...]


def embedding_bag_kernel(
    ids: jnp.ndarray,     # int32 [T] flat ids, -1 = padding (skipped)
    bags: jnp.ndarray,    # int32 [T] bag id per flat id, sorted ascending
    first: jnp.ndarray,   # int32 [T] 1 where bags[i] != bags[i-1]
    table: jnp.ndarray,   # [V, D] float
    n_bags: int,
    interpret: bool = False,
):
    t = ids.shape[0]
    d = table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(t,),
        in_specs=[
            # table row picked by the prefetched id (clamped for padding)
            pl.BlockSpec(
                (1, d), lambda i, ids, bags, first: (jnp.maximum(ids[i], 0), 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, d), lambda i, ids, bags, first: (bags[i], 0)
        ),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((n_bags, d), table.dtype),
        interpret=interpret,
    )(ids, bags, first, table)
