"""Pure-jnp oracle for embedding_bag: take + segment_sum."""

import jax
import jax.numpy as jnp


def embedding_bag(ids, bags, table, n_bags):
    """sum-mode EmbeddingBag.

    ids: int32 [T] (-1 padding), bags: int32 [T], table: [V, D].
    Returns [n_bags, D].
    """
    rows = jnp.take(table, jnp.maximum(ids, 0), axis=0)
    rows = jnp.where((ids >= 0)[:, None], rows, 0)
    seg = jnp.where(ids >= 0, bags, n_bags)
    return jax.ops.segment_sum(rows, seg, num_segments=n_bags + 1)[:n_bags]
