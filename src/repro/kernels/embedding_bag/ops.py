"""Public EmbeddingBag wrapper: bag layout preparation + backend switch."""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.embedding_bag import ref
from repro.kernels.embedding_bag.kernel import embedding_bag_kernel


def embedding_bag(ids, bags, table, n_bags: int, backend: str = "xla"):
    """sum-mode EmbeddingBag over a flat (ids, bags) layout.

    ids  int32 [T]: table rows, -1 = padding (contributes zero)
    bags int32 [T]: destination bag per id, sorted ascending
    """
    if backend == "xla":
        return ref.embedding_bag(ids, bags, table, n_bags)
    ids = ids.astype(jnp.int32)
    bags = bags.astype(jnp.int32)
    first = jnp.concatenate(
        [jnp.ones((1,), jnp.int32),
         (bags[1:] != bags[:-1]).astype(jnp.int32)])
    return embedding_bag_kernel(
        ids, bags, first, table, n_bags,
        interpret=(backend == "pallas_interpret"))
