"""Pallas TPU kernels for the framework's compute hot spots.

compat_join     The paper's inner loop: compatibility join between a
                partial-match table and a candidate table (edge batch or
                delta rows).  Fuses the per-slot-pair compare/reduce so
                the [CA, CB, NV] broadcast never exists in HBM.
segment_reduce  GNN message passing: gather(edge src) -> segment reduce
                (sum/max/mean) over destination nodes.
embedding_bag   RecSys: fused multi-hot gather + segment-sum over huge
                embedding tables.

Each kernel ships: ``kernel.py`` (pl.pallas_call + BlockSpec tiling),
``ops.py`` (jit'd public wrapper with padding + interpret switch) and
``ref.py`` (pure-jnp oracle).  CPU CI validates via interpret=True; the
compiled path targets TPU v5e (VMEM tiles sized in kernel.py).
"""
