"""Pure-jnp oracle for segment_reduce: jax.ops.segment_sum semantics."""

import jax
import jax.numpy as jnp


def segment_sum(dst, msg, n_nodes):
    """Sum messages into their dst segment; negative ids are dropped."""
    return jax.ops.segment_sum(
        msg, jnp.where(dst < 0, n_nodes, dst), num_segments=n_nodes + 1
    )[:n_nodes]


def segment_mean(dst, msg, n_nodes, eps=1e-9):
    s = segment_sum(dst, msg, n_nodes)
    ones = jnp.ones((msg.shape[0], 1), msg.dtype)
    cnt = segment_sum(dst, ones, n_nodes)
    return s / jnp.maximum(cnt, eps)
