from repro.kernels.segment_reduce.ops import segment_sum, segment_mean
