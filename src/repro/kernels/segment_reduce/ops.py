"""Public wrappers for segment_reduce: padding + backend switch.

``backend``: "xla" uses jax.ops.segment_sum (XLA scatter — the fallback
and CPU path), "pallas"/"pallas_interpret" the blocked one-hot-MXU
kernel.  Both share the ref semantics; kernels/tests sweep agreement.
"""

from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.segment_reduce import ref
from repro.kernels.segment_reduce.kernel import TILE_E, TILE_N, segment_sum_kernel


def _ceil_to(x: int, m: int) -> int:
    return ((max(x, 1) + m - 1) // m) * m


def segment_sum(dst, msg, n_nodes: int, backend: str = "xla"):
    if backend == "xla":
        return ref.segment_sum(dst, msg, n_nodes)
    e = dst.shape[0]
    ep = _ceil_to(e, TILE_E)
    np_ = _ceil_to(n_nodes, TILE_N)
    dst_p = jnp.full((ep,), -1, jnp.int32).at[:e].set(
        jnp.where(dst < 0, -1, dst).astype(jnp.int32))
    msg_p = jnp.zeros((ep, msg.shape[1]), msg.dtype).at[:e].set(msg)
    out = segment_sum_kernel(
        dst_p, msg_p, np_, interpret=(backend == "pallas_interpret"))
    return out[:n_nodes]


def segment_mean(dst, msg, n_nodes: int, backend: str = "xla", eps=1e-9):
    s = segment_sum(dst, msg, n_nodes, backend)
    ones = jnp.ones((msg.shape[0], 1), msg.dtype)
    cnt = segment_sum(dst, ones, n_nodes, backend)
    return s / jnp.maximum(cnt, eps)
