"""Pallas TPU kernel: segment-sum of edge messages into node slots.

GNN message passing is scatter-add on GPU (atomics).  TPUs have no
scatter atomics — the TPU-native formulation is a *blocked one-hot
matmul*: for a node tile ``n`` and an edge tile ``e``,

    acc[n_tile] += onehot(dst[e_tile] == node_ids[n_tile]) @ msg[e_tile]

which runs on the MXU at full tile utilization.  This trades extra FLOPs
(the one-hot product) for perfectly regular memory traffic — the
standard GPU->TPU adaptation for sparse aggregation (DESIGN.md
§Adaptations).  The edge-block grid axis is the minor (sequential) axis,
so output tiles are revisited consecutively and accumulate in VMEM.

Grid:  (n_node_blocks, n_edge_blocks)   [edge axis minor]
Blocks: msg  [TE, D]  (VMEM)
        dst  [TE]     (VMEM, int32)
        out  [TN, D]  (VMEM accumulator, written once per node block)
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

TILE_E = 512
TILE_N = 256


def _kernel(dst_ref, msg_ref, out_ref, *, tile_n: int, acc_dtype):
    i = pl.program_id(0)   # node block
    j = pl.program_id(1)   # edge block (sequential/minor)

    @pl.when(j == 0)
    def _init():
        out_ref[...] = jnp.zeros_like(out_ref)

    dst = dst_ref[...]                                    # [TE] int32
    node_ids = i * tile_n + jax.lax.iota(jnp.int32, tile_n)
    onehot = (node_ids[:, None] == dst[None, :]).astype(acc_dtype)  # [TN, TE]
    msg = msg_ref[...].astype(acc_dtype)                  # [TE, D]
    out_ref[...] += jnp.dot(onehot, msg,
                            preferred_element_type=acc_dtype)


def segment_sum_kernel(
    dst: jnp.ndarray,   # int32 [E]   (segment id per edge; -1 = drop)
    msg: jnp.ndarray,   # [E, D] float
    n_nodes: int,
    interpret: bool = False,
):
    """E and n_nodes must be padded to TILE_E / TILE_N multiples."""
    e, d = msg.shape
    grid = (n_nodes // TILE_N, e // TILE_E)
    body = functools.partial(_kernel, tile_n=TILE_N, acc_dtype=jnp.float32)
    out = pl.pallas_call(
        body,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_E,), lambda i, j: (j,)),
            pl.BlockSpec((TILE_E, d), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((TILE_N, d), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_nodes, d), jnp.float32),
        interpret=interpret,
    )(dst, msg)
    return out.astype(msg.dtype)
