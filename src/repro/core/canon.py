"""Canonical forms for timing-constrained query graphs.

Two tenants rarely author the "same" pattern the same way: vertex ids
are arbitrary, edges are listed in whatever order the author thought of
them, and the timing order is stated over those arbitrary edge ids.  The
engine, however, buckets standing queries into padded slot groups by
*structural* plan signature (``repro.core.registry.plan_signature``), and
the decomposition / join-order heuristics consume edge ids directly — so
two isomorphic-modulo-relabeling queries can compile to differently-
ordered plans, land in different slot groups, and pay a needless XLA
compile each.

``canonical_form`` fixes the representation: it deterministically
relabels vertices and edges so that every member of an isomorphism class
maps to ONE canonical ``QueryGraph``.  The total order used to pick the
canonical representative compares *structure first, labels last*:

    (edges, closed precedence pairs, vertex labels, edge labels)

so the canonical EDGE ORDERING of two same-structure queries differs at
most by a structural automorphism — under which the unlabeled structure,
and therefore the compiled plan signature, is identical.  That is what
lets ``repro.api``'s planner map relabeled-isomorphic tenant patterns
onto one compiled slot tick.

The search enumerates vertex bijections restricted to Weisfeiler-Leman
style structural color classes (orbits refine fast on the paper's small,
timing-ordered queries); queries here are tiny (≤ ~10 edges), so the
residual within-class factorials are negligible.  A hard cap bounds the
worst case: pathologically symmetric queries beyond ``_MAX_PERMS``
candidate orderings fall back to a deterministic (but not relabeling-
invariant) refinement — still a valid relabeling, just without the
cross-authoring dedup guarantee.
"""

from __future__ import annotations

import functools
import itertools
from typing import NamedTuple

from repro.core.query import QueryGraph


class CanonicalForm(NamedTuple):
    """A canonical relabeling of a query graph.

    ``vertex_map[v]`` / ``edge_map[e]`` give the canonical id of original
    vertex ``v`` / original edge ``e``; ``query`` is the relabeled graph.
    """

    query: QueryGraph
    vertex_map: tuple[int, ...]
    edge_map: tuple[int, ...]


_MAX_PERMS = 40320          # 8! — cap on candidate vertex orderings
_WL_ROUNDS = 3


def _vertex_colors(q: QueryGraph) -> list:
    """Structure-only vertex invariants (labels deliberately excluded:
    they are runtime slot data and must not steer the canonical edge
    ordering, or same-structure / different-label queries would stop
    sharing compiled ticks)."""
    # edge invariant: position of the edge inside the timing order
    einv = [
        (sum(1 for i in range(q.n_edges) if q.precedes(i, e)),
         sum(1 for j in range(q.n_edges) if q.precedes(e, j)))
        for e in range(q.n_edges)
    ]
    color = [
        (tuple(sorted(einv[e] for e in range(q.n_edges) if q.edges[e][0] == v)),
         tuple(sorted(einv[e] for e in range(q.n_edges) if q.edges[e][1] == v)))
        for v in range(q.n_vertices)
    ]
    for _ in range(_WL_ROUNDS):
        nxt = []
        for v in range(q.n_vertices):
            outs = tuple(sorted(
                (einv[e], color[q.edges[e][1]])
                for e in range(q.n_edges) if q.edges[e][0] == v))
            ins = tuple(sorted(
                (einv[e], color[q.edges[e][0]])
                for e in range(q.n_edges) if q.edges[e][1] == v))
            nxt.append((color[v], outs, ins))
        if len(set(map(repr, nxt))) == len(set(map(repr, color))):
            break
        color = nxt
    return color


def _candidate_orders(q: QueryGraph):
    """Vertex orderings consistent with the color classes (classes in
    deterministic color order, all permutations within each class)."""
    colors = _vertex_colors(q)
    classes: dict[str, list[int]] = {}
    for v in range(q.n_vertices):
        classes.setdefault(repr(colors[v]), []).append(v)
    groups = [classes[c] for c in sorted(classes)]
    n_perms = 1
    for g in groups:
        for k in range(2, len(g) + 1):
            n_perms *= k
    if n_perms > _MAX_PERMS:
        # degenerate symmetry: refine deterministically by (label, id).
        # Not relabeling-invariant, but still a valid canonical-ish
        # relabeling — and unreachable for the paper's query sizes.
        order = [v for g in groups
                 for v in sorted(g, key=lambda v: (q.vertex_labels[v], v))]
        yield order
        return
    for combo in itertools.product(*(itertools.permutations(g) for g in groups)):
        yield [v for g in combo for v in g]


def _encode(q: QueryGraph, order: list[int]):
    """Relabel by ``order`` and encode as a comparable key.

    ``order[k]`` is the original vertex given canonical id ``k``.
    """
    perm = [0] * q.n_vertices            # original vid -> canonical vid
    for new, old in enumerate(order):
        perm[old] = new
    by_endpoint = sorted(
        range(q.n_edges),
        key=lambda e: (perm[q.edges[e][0]], perm[q.edges[e][1]]))
    emap = [0] * q.n_edges               # original eid -> canonical eid
    for new, old in enumerate(by_endpoint):
        emap[old] = new
    edges = tuple((perm[q.edges[e][0]], perm[q.edges[e][1]])
                  for e in by_endpoint)
    prec = tuple(sorted((emap[i], emap[j]) for i, j in q.prec))
    vlabels = tuple(q.vertex_labels[old] for old in order)
    elabels = tuple(q.edge_labels[e] for e in by_endpoint)
    key = (edges, prec, vlabels, elabels)
    return key, tuple(perm), tuple(emap)


@functools.lru_cache(maxsize=4096)
def canonical_form(q: QueryGraph) -> CanonicalForm:
    """Deterministic canonical relabeling of ``q``.

    Properties (property-tested in tests/test_api_props.py):

    * invariance — any vertex renumbering / edge reordering of ``q``
      yields the same canonical ``query``;
    * idempotence — ``canonical_form(canonical_form(q).query)`` is the
      identity relabeling;
    * structure-first — two queries differing only in labels get
      canonical edge orderings related by a structural automorphism, so
      their compiled plans share one ``plan_signature``.
    """
    best = None
    for order in _candidate_orders(q):
        enc = _encode(q, order)
        if best is None or enc[0] < best[0]:
            best = enc
    key, perm, emap = best
    edges, prec, vlabels, elabels = key
    canon = QueryGraph(
        n_vertices=q.n_vertices,
        vertex_labels=vlabels,
        edges=edges,
        edge_labels=elabels,
        prec=frozenset(prec),
    )
    return CanonicalForm(query=canon, vertex_map=perm, edge_map=emap)


def canonical_key(q: QueryGraph) -> tuple:
    """Hashable identity of ``q``'s isomorphism class (labels included)."""
    c = canonical_form(q).query
    return (c.edges, tuple(sorted(c.prec)), c.vertex_labels, c.edge_labels)
