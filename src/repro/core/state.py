"""Device-side state: fixed-capacity partial-match tables.

Two storage regimes, mirroring the paper's ablation:

* **MS-tree mode** (``LevelTable``, Section 4): each expansion-list item
  ``L_i^j`` stores only the *new* edge of each partial match — (src, dst,
  ts) — plus a parent pointer into ``L_i^{j-1}``.  A partial match is the
  root-to-node path, exactly the paper's trie-variant; full bindings are
  reconstructed transiently inside the tick by a parent-pointer gather
  chain (the "backtrack" of Section 4.2, vectorized).

* **IND mode** (Timing-IND baseline in the paper's §6.3): bindings and
  per-edge timestamps are stored denormalized.  The global expansion list
  ``L_0`` always stores denormalized rows (``L0Table``): its rows combine
  parents from *different shards* under data-parallel execution, so
  parent pointers would break shard locality (hardware adaptation,
  DESIGN.md §Adaptations).

All tables are NamedTuples of arrays — JAX pytrees, shard_map friendly.
The capacity axis is the sharded axis in distributed mode.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp

from repro.core.plan import ExecutionPlan

I32 = jnp.int32


class LevelTable(NamedTuple):
    """MS-tree node storage for one expansion-list item ``L_i^j``."""

    src: jnp.ndarray      # int32 [C]  data vertex matched to the level edge's src
    dst: jnp.ndarray      # int32 [C]
    ts: jnp.ndarray       # int32 [C]  timestamp of the matched data edge
    parent: jnp.ndarray   # int32 [C]  row in L_i^{j-1}; -1 at level 1
    valid: jnp.ndarray    # bool  [C]
    fresh: jnp.ndarray    # bool  [C]  appended during the current tick


class L0Table(NamedTuple):
    """Denormalized row storage for a global expansion-list item ``L_0^i``."""

    bindings: jnp.ndarray  # int32 [C, nv]
    ets: jnp.ndarray       # int32 [C, ne]  per-query-edge timestamps
    valid: jnp.ndarray     # bool  [C]
    fresh: jnp.ndarray     # bool  [C]


class EngineStats(NamedTuple):
    n_matches_total: jnp.ndarray    # int32 scalar
    n_overflow: jnp.ndarray         # int32 scalar: dropped appends (capacity)
    n_edges_processed: jnp.ndarray  # int32 scalar
    n_edges_discarded: jnp.ndarray  # int32 scalar: matched no query edge / pruned
    n_edges_rejected: jnp.ndarray   # int32 scalar: at-or-below the released
    #                                 event-time floor (watermark mode only)


class EngineState(NamedTuple):
    levels: tuple          # tuple[tuple[LevelTable, ...], ...]  per subquery
    l0: tuple              # tuple[L0Table, ...]  for join sites 2..k
    t_now: jnp.ndarray     # int32 scalar, current stream time
    stats: EngineStats


def _empty_level(capacity: int) -> LevelTable:
    c = capacity
    return LevelTable(
        src=jnp.zeros((c,), I32),
        dst=jnp.zeros((c,), I32),
        ts=jnp.zeros((c,), I32),
        parent=jnp.full((c,), -1, I32),
        valid=jnp.zeros((c,), jnp.bool_),
        fresh=jnp.zeros((c,), jnp.bool_),
    )


def _empty_l0(capacity: int, nv: int, ne: int) -> L0Table:
    return L0Table(
        bindings=jnp.zeros((capacity, nv), I32),
        ets=jnp.zeros((capacity, ne), I32),
        valid=jnp.zeros((capacity,), jnp.bool_),
        fresh=jnp.zeros((capacity,), jnp.bool_),
    )


def init_state(plan: ExecutionPlan, prefix_depth: int = 0,
               watermark: int | None = None) -> EngineState:
    """Empty tables for ``plan``.  With ``prefix_depth > 0`` (cross-tenant
    prefix sharing, ``repro.core.share``), subquery 0's first that-many
    levels live in a shared prefix table owned by the forest, so the
    per-tenant state holds only the suffix levels.

    ``watermark`` seeds the engine clock ``t_now``: a tenant registered
    mid-stream under event-time serving starts at the already-released
    floor instead of 0, so it can never admit an edge the frontier has
    already released past (no resurrection after crash/restore either —
    the service seeds restored-but-stateless engines the same way).
    """
    levels = tuple(
        tuple(_empty_level(lv.capacity)
              for lv in s.levels[(prefix_depth if si == 0 else 0):])
        for si, s in enumerate(plan.subqueries)
    )
    l0 = tuple(
        _empty_l0(js.capacity, len(js.vertex_layout), len(js.edge_layout))
        for js in plan.l0_joins
    )
    zero = jnp.zeros((), I32)
    t0 = jnp.zeros((), I32) if watermark is None \
        else jnp.asarray(watermark, I32)
    return EngineState(
        levels=levels,
        l0=l0,
        t_now=t0,
        stats=EngineStats(zero, zero, zero, zero, zero),
    )


class EdgeBatch(NamedTuple):
    """A tick's worth of stream edges (padded; ``valid`` marks real rows).

    Timestamps must be non-decreasing across consecutive ticks; within a
    tick they may interleave arbitrarily (the engine's level-ordered
    batched schedule, Section 5 adaptation, restores exact streaming-
    consistency semantics regardless of intra-tick order).
    """

    src: jnp.ndarray        # int32 [B] data vertex id
    dst: jnp.ndarray        # int32 [B]
    ts: jnp.ndarray         # int32 [B]
    src_label: jnp.ndarray  # int32 [B]
    dst_label: jnp.ndarray  # int32 [B]
    edge_label: jnp.ndarray  # int32 [B]
    valid: jnp.ndarray      # bool  [B]


def make_batch(src, dst, ts, src_label, dst_label, edge_label, valid=None) -> EdgeBatch:
    a = lambda x: jnp.asarray(x, I32)
    src = a(src)
    if valid is None:
        valid = jnp.ones(src.shape, jnp.bool_)
    return EdgeBatch(
        src, a(dst), a(ts), a(src_label), a(dst_label), a(edge_label),
        jnp.asarray(valid, jnp.bool_),
    )
