"""Cross-tenant prefix sharing: the ``SharedPrefixForest`` subsystem.

The engine answers each standing query by maintaining expansion lists
for its TC-subqueries; concurrent tenants overlap heavily in the
*prefixes* of those lists (the multi-query observation of StreamWorks /
PNNL's large-scale continuous subgraph queries — see PAPERS.md).  Until
now every tenant materialized and advanced its own tables, sharing only
the label-match phase and compiled XLA ticks.  This module adds
common-subexpression elimination across tenants at the TABLE level:

* ``prefix_chain(plan)`` slices subquery 0's timing sequence into its
  depth-1..m prefixes and keys each by ``canonical_key`` of the chain-
  renumbered prefix query (``repro.core.canon``) plus the window span —
  label-renamed / vertex-relabeled tenants hash to the SAME signature.
  Because a timing sequence is a ≺-chain, the chain renumbering (vertex
  ids by first appearance, edge ids by chain position) is *forced* by
  the isomorphism, so equal signatures imply literally identical prefix
  queries — and therefore bit-identical expansion-list tables.

* ``SharedPrefixForest`` is a refcounted trie of ``PrefixNode``s: one
  ``LevelTable`` per (prefix signature, epoch), advanced ONCE per tick
  by a dedicated prefix tick in depth order.  A tenant acquires the
  whole chain for its subquery 0 and its slot tick consumes the leaf's
  per-tick ``NodeView`` (``build_tick_body(prefix_depth=...)``), running
  only the suffix joins.  Partial overlap shares partially: a 3-chain
  tenant and a 2-chain tenant alias the depth-1/2 nodes and diverge at
  depth 3.

* *Epochs* keep per-tenant registration-time semantics exact: a node
  created at stream offset ``o`` contains precisely the partial matches
  a tenant registered at ``o`` would have built alone, so only tenants
  registered at the same offset may alias it.  This is what makes the
  sharing-enabled engine oracle-multiset-exact under churn — a tenant
  arriving mid-stream gets fresh nodes instead of inheriting history.

Node ticks are structural (labels and window are runtime inputs), so
they live in the process-wide ``SlotTickCache`` next to the slot ticks:
restore-after-crash re-arms the forest with cache hits, zero warm
recompiles.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import join as J
from repro.core.canon import canonical_key
from repro.core.engine import (
    _append_level,
    edge_match_mask,
    fold_level_host,
    matches_from_rows,
)
from repro.core.plan import ExecutionPlan
from repro.core.query import QueryGraph
from repro.core.state import EngineState, _empty_level

I32 = jnp.int32


class NodeView(NamedTuple):
    """A prefix node's per-tick export: the denormalized post-append view
    its consumers join against (suffix ticks and child nodes), plus the
    post-expiry validity the consumers cascade deletions from."""

    bind: jnp.ndarray         # int32 [C, nv]   pre-expiry, post-append
    ets: jnp.ndarray          # int32 [C, ne]
    valid: jnp.ndarray        # bool  [C]       pre-expiry
    fresh: jnp.ndarray        # bool  [C]       appended this tick
    valid_after: jnp.ndarray  # bool  [C]       post-expiry (cascaded)


class NodeState(NamedTuple):
    """Device state of one prefix node: one expansion-list level table."""

    table: object             # repro.core.state.LevelTable
    t_now: jnp.ndarray        # int32 scalar
    n_overflow: jnp.ndarray   # int32 scalar, cumulative dropped appends


class NodeSpec(NamedTuple):
    """Structural identity of a node tick (the SlotTickCache key part).

    ``parent_ne == 0`` marks a root (depth-1) node; labels and window are
    runtime inputs, so one compiled node tick serves every label/window
    variant of the same structure."""

    parent_nv: int            # prefix layout width at depth-1 (0 at root)
    parent_ne: int            # = depth - 1
    src_slot: int             # this edge's src slot in the parent layout
    dst_slot: int             # (-1 = new vertex)
    capacity: int
    max_new: int


class SharedPrefixInfo(NamedTuple):
    """Per-tenant sharing stats (``Subscription.shared_prefix``)."""

    depth: int                # externalized levels of subquery 0
    n_tenants: int            # tenants aliasing this tenant's leaf node
    epoch: int                # stream offset the node chain started at


class ForestStats(NamedTuple):
    n_nodes: int              # live prefix tables
    n_shared_nodes: int       # nodes aliased by more than one tenant
    n_tenants: int            # acquired (live) tenant handles
    table_bytes: int          # device bytes held by all node tables


class PrefixChain(NamedTuple):
    """Host-side description of a plan's shareable prefixes."""

    sigs: tuple               # per-depth signature (canonical_key, window)
    queries: tuple            # per-depth chain-renumbered QueryGraph
    depth: int                # = len(subquery 0 timing sequence)


def prefix_chain(plan: ExecutionPlan) -> PrefixChain:
    """Slice subquery 0's timing sequence into canonical prefixes.

    The depth-``j`` prefix query renumbers vertices by first appearance
    and edges by chain position with the chain precedence — a forced
    renumbering, so isomorphic prefixes produce *identical* graphs; the
    signature still goes through ``canonical_key`` so the dedup contract
    is exactly the planner's isomorphism-class identity.
    """
    q = plan.query
    seq = plan.subqueries[0].timing_sequence
    vmap: dict[int, int] = {}
    edges: list[tuple[int, int]] = []
    vlabels: list[int] = []
    elabels: list[int] = []
    sigs, queries = [], []
    for j, eid in enumerate(seq):
        u, v = q.edges[eid]
        for x in (u, v):
            if x not in vmap:
                vmap[x] = len(vmap)
                vlabels.append(q.vertex_labels[x])
        edges.append((vmap[u], vmap[v]))
        elabels.append(q.edge_labels[eid])
        pq = QueryGraph(
            n_vertices=len(vmap),
            vertex_labels=tuple(vlabels),
            edges=tuple(edges),
            edge_labels=tuple(elabels),
            prec=frozenset((i, i + 1) for i in range(j)),
        )
        queries.append(pq)
        sigs.append((canonical_key(pq), int(plan.window)))
    return PrefixChain(tuple(sigs), tuple(queries), len(seq))


def node_spec(plan: ExecutionPlan, j: int) -> NodeSpec:
    """Structural spec of the depth-``j+1`` node of ``plan``'s chain.
    Equal across every tenant sharing the depth-``j+1`` signature (the
    layout slot positions are forced by the chain renumbering)."""
    s0 = plan.subqueries[0]
    lv = s0.levels[j]
    return NodeSpec(
        parent_nv=len(s0.levels[j - 1].vertex_layout) if j else 0,
        parent_ne=j,
        src_slot=lv.src_slot,
        dst_slot=lv.dst_slot,
        capacity=lv.capacity,
        max_new=lv.max_new,
    )


def init_node_state(spec: NodeSpec) -> NodeState:
    # distinct zero buffers: donated ticks may not alias two arguments
    return NodeState(table=_empty_level(spec.capacity),
                     t_now=jnp.zeros((), I32),
                     n_overflow=jnp.zeros((), I32))


def build_node_tick(spec: NodeSpec, backend: str = J.JoinBackend.REF):
    """Compile the per-tick advance of one prefix node.

    Root:   ``tick(state, batch, esl, edl, eel, window, watermark=None)``
    Child:  ``tick(state, batch, parent_view, esl, edl, eel, window,
    watermark=None)``

    Both return ``(state, NodeView, n_overflow_this_tick)``.  The label
    scalars and the window are runtime inputs (same contract as the slot
    ticks), so the compiled tick — and its XLA traces — are shared by
    every same-structure node in the process.  Semantics mirror one
    level of ``build_tick_body`` exactly: append against the parent's
    post-append view (the batched image of the paper's lock wait-lists),
    export the pre-expiry view, expire at end of tick with the cascade
    from the parent's post-expiry validity.
    """

    def _advance_time(state, batch, window, watermark):
        # event-time mode (traced watermark): reject at-or-below the
        # already-released floor before the clock moves, then advance to
        # min(watermark, max batch ts) — the same clock rule as
        # ``build_tick_body``, so a shared prefix table expires in
        # lockstep with its tenants' suffix tables.  Tenants count their
        # own rejections; the node only masks.
        if watermark is not None:
            late = batch.valid & (batch.ts <= state.t_now - window)
            batch = batch._replace(valid=batch.valid & ~late)
        bt = jnp.where(batch.valid, batch.ts, jnp.iinfo(jnp.int32).min)
        if watermark is None:
            t_now = jnp.maximum(state.t_now, jnp.max(bt))
        else:
            t_now = jnp.maximum(
                state.t_now, jnp.minimum(watermark, jnp.max(bt)))
        table = state.table._replace(
            fresh=jnp.zeros_like(state.table.fresh))
        return t_now, table, batch

    if spec.parent_ne == 0:                      # depth-1 root
        def tick(state: NodeState, batch, esl, edl, eel, window,
                 watermark=None):
            t_now, table, batch = _advance_time(state, batch, window,
                                                watermark)
            em = edge_match_mask(batch, esl[None], edl[None], eel[None])[0]
            table, nd = _append_level(
                table, jnp.full_like(batch.src, -1),
                batch.src, batch.dst, batch.ts, em)
            bind = jnp.stack([table.src, table.dst], axis=1)
            ets = table.ts[:, None]
            lo = t_now - window
            valid_after = table.valid & (table.ts > lo)
            view = NodeView(bind, ets, table.valid, table.fresh, valid_after)
            return (NodeState(table._replace(valid=valid_after), t_now,
                              state.n_overflow + nd), view, nd)
        return tick

    rel = np.zeros((spec.parent_nv, 2), dtype=bool)
    if spec.src_slot >= 0:
        rel[spec.src_slot, 0] = True
    if spec.dst_slot >= 0:
        rel[spec.dst_slot, 1] = True
    trel = np.zeros((spec.parent_ne, 1), dtype=np.int8)
    trel[-1, 0] = -1                             # ≺-chain: last edge only

    def tick(state: NodeState, batch, parent: NodeView, esl, edl, eel,
             window, watermark=None):
        t_now, table, batch = _advance_time(state, batch, window, watermark)
        em = edge_match_mask(batch, esl[None], edl[None], eel[None])[0]
        bbind = jnp.stack([batch.src, batch.dst], axis=1)
        bets = batch.ts[:, None]
        a_idx, b_idx, pv, nd1 = J.join_pairs(
            parent.bind, parent.ets, parent.valid, bbind, bets, em,
            rel, trel, spec.max_new, window, backend)
        table, nd2 = _append_level(
            table, a_idx,
            jnp.take(batch.src, b_idx, mode="clip"),
            jnp.take(batch.dst, b_idx, mode="clip"),
            jnp.take(batch.ts, b_idx, mode="clip"),
            pv)
        p = jnp.maximum(table.parent, 0)
        own = []
        if spec.src_slot < 0:
            own.append(table.src[:, None])
        if spec.dst_slot < 0:
            own.append(table.dst[:, None])
        bind = jnp.concatenate([jnp.take(parent.bind, p, axis=0)] + own,
                               axis=1)
        ets = jnp.concatenate(
            [jnp.take(parent.ets, p, axis=0), table.ts[:, None]], axis=1)
        lo = t_now - window
        valid_after = (table.valid & (table.ts > lo)
                       & jnp.take(parent.valid_after, p, mode="clip"))
        view = NodeView(bind, ets, table.valid, table.fresh, valid_after)
        nd = nd1 + nd2
        return (NodeState(table._replace(valid=valid_after), t_now,
                          state.n_overflow + nd), view, nd)
    return tick


@dataclass(eq=False)
class PrefixNode:
    """One refcounted prefix table in the forest trie."""

    pid: int                           # stable id (checkpoint manifest key)
    depth: int                         # 1-based chain length
    sig: tuple                         # (canonical_key(prefix), window)
    epoch: int                         # stream offset at creation
    parent: "PrefixNode | None"
    spec: NodeSpec
    query: QueryGraph                  # chain-renumbered prefix query
    esl: jnp.ndarray                   # int32 scalars: this edge's labels
    edl: jnp.ndarray
    eel: jnp.ndarray
    window: jnp.ndarray                # int32 scalar
    tick: object                       # SlotTickCache-shared node tick
    state: NodeState
    refcount: int = 0

    @property
    def table_bytes(self) -> int:
        return sum(x.nbytes for x in jax.tree.leaves(self.state))


class SharedPrefixForest:
    """Refcounted registry of shared prefix tables, advanced once per
    tick.  Owned by one ``ContinuousSearchService``; node ticks come from
    the (usually process-wide) ``SlotTickCache``."""

    def __init__(self, tick_cache, backend: str = J.JoinBackend.REF,
                 jit: bool = True, donate: bool = False):
        self.tick_cache = tick_cache
        self.backend = backend
        self._jit = jit
        self.donate = donate
        self._by_key: dict[tuple, PrefixNode] = {}   # (sig, epoch) -> node
        self._next_pid = 0
        self._n_handles = 0

    def __len__(self) -> int:
        return len(self._by_key)

    def nodes(self) -> list[PrefixNode]:
        return sorted(self._by_key.values(), key=lambda n: n.pid)

    def states(self) -> list[NodeState]:
        return [n.state for n in self.nodes()]

    # ------------------------------------------------------------------ #
    def _new_node(self, plan: ExecutionPlan, j: int, sig: tuple,
                  query: QueryGraph, epoch: int,
                  parent: PrefixNode | None) -> PrefixNode:
        spec = node_spec(plan, j)
        eid = plan.subqueries[0].timing_sequence[j]
        node = PrefixNode(
            pid=self._next_pid,
            depth=j + 1,
            sig=sig,
            epoch=epoch,
            parent=parent,
            spec=spec,
            query=query,
            esl=jnp.asarray(plan.edge_src_label[eid], I32),
            edl=jnp.asarray(plan.edge_dst_label[eid], I32),
            eel=jnp.asarray(plan.edge_edge_label[eid], I32),
            window=jnp.asarray(plan.window, I32),
            tick=self.tick_cache.get_node(
                spec, backend=self.backend, jit=self._jit,
                donate=self.donate),
            state=init_node_state(spec),
        )
        self._next_pid += 1
        return node

    def acquire(self, plan: ExecutionPlan, epoch: int) -> PrefixNode:
        """Acquire the whole prefix chain of ``plan``'s subquery 0 at
        ``epoch``; returns the leaf node (depth = full subquery 0).
        Every node along the chain gains one reference; on failure
        nothing is retained (references taken on shallower nodes are
        rolled back), so a raising acquire can never orphan tables."""
        chain = prefix_chain(plan)
        parent = None
        try:
            for j in range(chain.depth):
                key = (chain.sigs[j], epoch)
                node = self._by_key.get(key)
                if node is None:
                    node = self._new_node(plan, j, chain.sigs[j],
                                          chain.queries[j], epoch, parent)
                    self._by_key[key] = node
                elif node.spec != node_spec(plan, j):
                    # unreachable by the chain-renumbering argument; loud
                    # beats a silently corrupt shared table
                    raise ValueError(
                        f"prefix signature collision at depth {j + 1}: "
                        f"{node.spec} vs {node_spec(plan, j)}")
                node.refcount += 1
                parent = node
        except Exception:
            node = parent
            while node is not None:       # roll back the partial chain
                node.refcount -= 1
                if node.refcount == 0:
                    del self._by_key[(node.sig, node.epoch)]
                node = node.parent
            raise
        self._n_handles += 1
        return parent

    def release(self, leaf: PrefixNode) -> None:
        """Release one tenant's chain; nodes dropping to zero references
        are freed (deepest first, so a parent never outlives a child's
        reference to it)."""
        node = leaf
        while node is not None:
            node.refcount -= 1
            if node.refcount == 0:
                del self._by_key[(node.sig, node.epoch)]
            node = node.parent
        self._n_handles -= 1

    def adopt(self, leaf: PrefixNode) -> PrefixNode:
        """Re-reference an existing chain (checkpoint-restore path: the
        nodes already exist with refcount 0)."""
        node = leaf
        while node is not None:
            node.refcount += 1
            node = node.parent
        self._n_handles += 1
        return leaf

    # ------------------------------------------------------------------ #
    def advance(self, batch, watermark=None):
        """One dedicated prefix tick: advance every node once, in depth
        order (parents before children).  Returns the per-node views and
        the per-node overflow scalars keyed by pid (device; the service
        attributes each tenant's chain overflow back onto its
        ``TickResult`` so results match the unshared engine's counters
        exactly).  ``watermark`` (None or a traced int32 scalar) selects
        the same clock mode the tenants' slot ticks run under — the
        service passes one value to both, keeping node and suffix expiry
        in lockstep."""
        views: dict[int, NodeView] = {}
        nds: dict[int, jnp.ndarray] = {}
        for node in sorted(self._by_key.values(),
                           key=lambda n: (n.depth, n.pid)):
            if node.parent is None:
                node.state, view, nd = node.tick(
                    node.state, batch, node.esl, node.edl, node.eel,
                    node.window, watermark)
            else:
                node.state, view, nd = node.tick(
                    node.state, batch, views[node.parent.pid],
                    node.esl, node.edl, node.eel, node.window, watermark)
            views[node.pid] = view
            nds[node.pid] = nd
        return views, nds

    @staticmethod
    def chain_tick_overflow(leaf: PrefixNode, nds: dict):
        """This tick's dropped appends along ``leaf``'s chain (device
        scalar) — what each aliasing tenant's own prefix tables would
        have dropped in an unshared run."""
        total, node = 0, leaf
        while node is not None:
            total = total + nds[node.pid]
            node = node.parent
        return total

    # ------------------------------------------------------------------ #
    def replica_refcounts(self, assignments, n_replicas: int) -> dict:
        """Deterministic per-replica partition of the forest's refcounts.

        Under mesh serving (``repro.runtime.mesh``) node tables are
        REPLICATED — every replica joins against the same broadcast
        view — but each aliasing tenant lives on exactly one replica, so
        the refcount of every node partitions deterministically by
        placement.  ``assignments`` is an iterable of ``(leaf, replica)``
        pairs, one per live tenant; returns ``{pid: [count per
        replica]}`` with ``sum(counts) == node.refcount`` for every node
        (the mesh checkpoint manifest records and re-verifies this)."""
        out: dict[int, list[int]] = {}
        for leaf, r in assignments:
            node = leaf
            while node is not None:
                counts = out.setdefault(node.pid, [0] * n_replicas)
                counts[r] += 1
                node = node.parent
        return out

    def chain_overflow(self, leaf: PrefixNode) -> int:
        """Cumulative dropped appends along one tenant's chain."""
        total, node = 0, leaf
        while node is not None:
            total += int(np.asarray(node.state.n_overflow))
            node = node.parent
        return total

    def total_overflow(self) -> int:
        return sum(int(np.asarray(n.state.n_overflow))
                   for n in self._by_key.values())

    def stats(self) -> ForestStats:
        nodes = list(self._by_key.values())
        return ForestStats(
            n_nodes=len(nodes),
            n_shared_nodes=sum(1 for n in nodes if n.refcount > 1),
            n_tenants=self._n_handles,
            table_bytes=sum(n.table_bytes for n in nodes),
        )

    def register_obs(self, obs) -> None:
        """Expose forest shape under ``share.*`` as collect-time
        callback gauges on a ``repro.obs.MetricsRegistry`` — evaluated
        only at snapshot time, never on the serve loop."""
        obs.register_gauge("share.n_nodes", lambda: self.stats().n_nodes)
        obs.register_gauge("share.n_shared_nodes",
                           lambda: self.stats().n_shared_nodes)
        obs.register_gauge("share.n_tenants",
                           lambda: self.stats().n_tenants)
        obs.register_gauge("share.table_bytes",
                           lambda: self.stats().table_bytes)

    # ------------------------------------------------------------------ #
    # checkpoint / restore
    # ------------------------------------------------------------------ #
    def to_manifest(self) -> dict:
        return {
            "next_pid": self._next_pid,
            "nodes": [
                {
                    "pid": n.pid,
                    "depth": n.depth,
                    "epoch": int(n.epoch),
                    "refcount": int(n.refcount),
                    "parent": None if n.parent is None else n.parent.pid,
                    "query": n.query.to_spec(),
                    "window": int(np.asarray(n.window)),
                    "spec": list(n.spec),
                    "labels": [int(np.asarray(n.esl)),
                               int(np.asarray(n.edl)),
                               int(np.asarray(n.eel))],
                }
                for n in self.nodes()
            ],
        }

    def restore_nodes(self, man: dict) -> dict[int, PrefixNode]:
        """Rebuild the trie skeleton from a checkpoint manifest: nodes
        come back with their pids/epochs/signatures, EMPTY state (the
        caller overwrites it from the npz) and refcount 0 (the caller
        re-adopts one chain per restored tenant and checks the counts
        against the manifest)."""
        by_pid: dict[int, PrefixNode] = {}
        for ent in sorted(man["nodes"], key=lambda e: e["depth"]):
            spec = NodeSpec(*ent["spec"])
            query = QueryGraph.from_spec(ent["query"])
            sig = (canonical_key(query), int(ent["window"]))
            parent = None if ent["parent"] is None else by_pid[ent["parent"]]
            esl, edl, eel = ent["labels"]
            node = PrefixNode(
                pid=int(ent["pid"]),
                depth=int(ent["depth"]),
                sig=sig,
                epoch=int(ent["epoch"]),
                parent=parent,
                spec=spec,
                query=query,
                esl=jnp.asarray(esl, I32),
                edl=jnp.asarray(edl, I32),
                eel=jnp.asarray(eel, I32),
                window=jnp.asarray(int(ent["window"]), I32),
                tick=self.tick_cache.get_node(
                    spec, backend=self.backend, jit=self._jit,
                    donate=self.donate),
                state=init_node_state(spec),
            )
            self._by_key[(sig, node.epoch)] = node
            by_pid[node.pid] = node
        self._next_pid = max(int(man["next_pid"]),
                             1 + max(by_pid, default=-1))
        return by_pid

    # ------------------------------------------------------------------ #
    # host-side reconstruction (result extraction / tests)
    # ------------------------------------------------------------------ #
    def host_table(self, leaf: PrefixNode):
        """Denormalized (bind, ets, valid) numpy arrays of ``leaf``'s
        table, reconstructed through the parent chain (root-first folds
        of the shared layout rule, ``engine.fold_level_host``)."""
        chain = []
        node = leaf
        while node is not None:
            chain.append(node)
            node = node.parent
        chain.reverse()
        acc = None
        for n in chain:
            acc = fold_level_host(acc, n.state.table,
                                  n.spec.src_slot, n.spec.dst_slot)
        bind, ets = acc
        return bind, ets, np.asarray(chain[-1].state.table.valid)


def shared_current_matches(plan: ExecutionPlan, leaf: PrefixNode,
                           forest: SharedPrefixForest,
                           state: EngineState):
    """``engine.current_matches`` for a prefix-shared tenant: fold the
    tenant's suffix levels on top of the shared table's reconstruction.
    Plans with L0 joins keep their denormalized final table locally, so
    those read straight from the suffix state."""
    if plan.l0_joins:
        from repro.core.engine import current_matches
        return current_matches(plan, state)
    s = plan.subqueries[0]
    depth = leaf.depth
    bind, ets, valid = forest.host_table(leaf)
    for ti, li in enumerate(range(depth, len(s.levels))):
        lv = s.levels[li]
        t = state.levels[0][ti]
        bind, ets = fold_level_host((bind, ets), t,
                                    lv.src_slot, lv.dst_slot)
        valid = np.asarray(t.valid)
    return matches_from_rows(plan, bind, ets, valid)
