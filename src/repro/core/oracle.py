"""Exact pure-Python reference engine — the correctness oracle for tests.

Enumerates *all* timing-order-constrained subgraph matches (Definition 4)
of a query over the current window content by plain backtracking.  It is
exponential and only used on tiny inputs; the device engine's state must
equal its output after every tick (tests/test_engine_oracle.py).
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.query import QueryGraph


@dataclass(frozen=True)
class DataEdge:
    src: int
    dst: int
    ts: int
    src_label: int
    dst_label: int
    edge_label: int = 0


def edge_matches(q: QueryGraph, eid: int, e: DataEdge) -> bool:
    u, v = q.edges[eid]
    if e.src == e.dst:
        return False  # query self-loops unsupported; injectivity forbids
    if q.vertex_labels[u] != e.src_label or q.vertex_labels[v] != e.dst_label:
        return False
    ql = q.edge_labels[eid]
    return ql == QueryGraph.WILDCARD or ql == e.edge_label


def enumerate_matches(q: QueryGraph, window: list[DataEdge]):
    """All matches of ``q`` over ``window``.

    Returns a set of frozensets of ``(query_edge_id, (src, dst, ts))`` —
    the same canonical form as ``engine.current_matches``.
    """
    m = q.n_edges
    results = set()
    binding: dict[int, int] = {}   # query vertex -> data vertex
    used_data_vertices: dict[int, int] = {}  # data vertex -> query vertex
    chosen: list[DataEdge | None] = [None] * m

    def ts_ok(eid: int, e: DataEdge) -> bool:
        for other in range(m):
            oe = chosen[other]
            if oe is None or other == eid:
                continue
            if q.precedes(other, eid) and not (oe.ts < e.ts):
                return False
            if q.precedes(eid, other) and not (e.ts < oe.ts):
                return False
        return True

    def bind_vertex(qv: int, dv: int) -> bool:
        if qv in binding:
            return binding[qv] == dv
        if dv in used_data_vertices:
            return False
        binding[qv] = dv
        used_data_vertices[dv] = qv
        return True

    def unbind(assigned: list[int]):
        for qv in assigned:
            dv = binding.pop(qv)
            used_data_vertices.pop(dv)

    def rec(eid: int):
        if eid == m:
            results.add(
                frozenset(
                    (k, (chosen[k].src, chosen[k].dst, chosen[k].ts))
                    for k in range(m)
                )
            )
            return
        u, v = q.edges[eid]
        for e in window:
            if not edge_matches(q, eid, e):
                continue
            if not ts_ok(eid, e):
                continue
            assigned: list[int] = []
            ok = True
            if u in binding:
                ok = binding[u] == e.src
            else:
                ok = bind_vertex(u, e.src)
                if ok:
                    assigned.append(u)
            if ok:
                if v in binding:
                    ok = binding[v] == e.dst
                else:
                    ok = bind_vertex(v, e.dst)
                    if ok:
                        assigned.append(v)
            if ok:
                chosen[eid] = e
                rec(eid + 1)
                chosen[eid] = None
            unbind(assigned)
        return

    rec(0)
    return results


class OracleEngine:
    """Sequential edge-at-a-time reference with a sliding window."""

    def __init__(self, q: QueryGraph, window: int):
        self.q = q
        self.window = window
        self.edges: list[DataEdge] = []
        self.t_now = 0
        self.n_rejected = 0

    def insert(self, e: DataEdge, watermark: int | None = None):
        """Insert one edge; slide the window.

        ``watermark=None`` is the processing-time clock (max ts seen).
        With a watermark (event-time replay, mirroring the engine's
        watermark mode): an edge at-or-below the already-released floor
        is rejected-and-counted before the clock moves, and the clock
        advances to ``min(watermark, e.ts)`` — bounded by the watermark
        so a force-evicted straggler cannot prematurely expire partials
        still inside ``allowed_lateness``.
        """
        if watermark is not None:
            if e.ts <= self.t_now - self.window:
                self.n_rejected += 1
                return
            self.t_now = max(self.t_now, min(watermark, e.ts))
        else:
            self.t_now = max(self.t_now, e.ts)
        lo = self.t_now - self.window
        self.edges = [x for x in self.edges if x.ts > lo]
        if e.ts > lo:
            self.edges.append(e)

    def matches(self):
        lo = self.t_now - self.window
        live = [x for x in self.edges if x.ts > lo]
        return enumerate_matches(self.q, live)
