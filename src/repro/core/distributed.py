"""Distributed execution of the streaming engine via shard_map.

Sharding model
--------------
Every partial-match table's capacity axis is sharded over the mesh's
engine axis (a flat view of ('pod','data') in production).  The edge
batch is replicated — ingest bandwidth is tiny next to table state.

Collectives per tick (the engine's roofline collective term):
  * 2·(k-1) all-gathers of compact delta rows (k = #TC-subqueries);
  * psums of scalar stats.
Everything else — label matching, expansion-list joins, MS-tree
reconstruction, expiry cascades — is shard-local by construction
(level-1 round-robin + parent-locality of appends).
"""

from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import join as J
from repro.core.compat import (
    shard_map as _shard_map,
    shard_map_compat_kwargs as _shard_map_compat_kwargs,
)
from repro.core.engine import build_tick
from repro.core.plan import ExecutionPlan
from repro.core.state import EngineState, init_state


def _state_specs(state: EngineState, axes) -> EngineState:
    """PartitionSpec pytree: shard every capacity axis, replicate scalars."""
    shard = P(axes)

    def spec_leaf(x):
        return shard if x.ndim >= 1 else P()

    return jax.tree.map(spec_leaf, state)


def build_sharded_tick(
    plan: ExecutionPlan,
    mesh: Mesh,
    axes=("data",),
    backend: str = J.JoinBackend.REF,
    extract_matches: bool = False,
    prefix_depth: int = 0,
):
    """Returns ``(tick, state)`` with ``tick`` jit-compiled under shard_map
    and ``state`` placed according to the sharding spec.

    ``axes`` may name one or more mesh axes; the capacity dimension is
    sharded over their product (e.g. ``('pod', 'data')`` on the
    multi-pod production mesh).

    With ``prefix_depth > 0`` the tick takes a shared-prefix
    ``NodeView`` (``repro.core.share``) as a third argument; the view is
    REPLICATED across shards — the forest node advances once outside the
    shard_map — and the engine body partitions its join output
    deterministically (see ``build_tick_body``).
    """
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    axes = tuple(axes)
    axis_name = axes if len(axes) > 1 else axes[0]

    inner = build_tick(
        plan,
        backend=backend,
        extract_matches=extract_matches,
        axis_name=axis_name,
        n_shards=n_shards,
        prefix_depth=prefix_depth,
    )

    state0 = init_state(plan, prefix_depth)
    specs = _state_specs(state0, axes)

    from repro.core.engine import TickResult
    from repro.core.state import EdgeBatch

    batch_specs = EdgeBatch(*(P() for _ in range(7)))
    out_res_specs = TickResult(
        n_new_matches=P(),
        n_overflow=P(),
        match_bindings=P(axes),
        match_ets=P(axes),
        match_valid=P(axes),
    )

    in_specs = (specs, batch_specs)
    if prefix_depth:
        from repro.core.share import NodeView
        in_specs = in_specs + (NodeView(P(), P(), P(), P(), P()),)

    tick = jax.jit(
        _shard_map(
            inner,
            mesh=mesh,
            in_specs=in_specs,
            out_specs=(specs, out_res_specs),
            **_shard_map_compat_kwargs(),
        )
    )

    state = jax.device_put(
        state0, jax.tree.map(lambda s: NamedSharding(mesh, s), specs))
    return tick, state
