"""Distributed execution of the streaming engine via shard_map.

Sharding model
--------------
Every partial-match table's capacity axis is sharded over the mesh's
engine axis (a flat view of ('pod','data') in production).  The edge
batch is replicated — ingest bandwidth is tiny next to table state.

Collectives per tick (the engine's roofline collective term):
  * 2·(k-1) all-gathers of compact delta rows (k = #TC-subqueries);
  * psums of scalar stats.
Everything else — label matching, expansion-list joins, MS-tree
reconstruction, expiry cascades — is shard-local by construction
(level-1 round-robin + parent-locality of appends).
"""

from __future__ import annotations

import inspect

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# JAX moved shard_map around across releases: 0.4.x ships it under
# jax.experimental.shard_map; newer versions expose jax.shard_map.
_shard_map = getattr(jax, "shard_map", None)
if _shard_map is None:  # pragma: no cover - version dependent
    from jax.experimental.shard_map import shard_map as _shard_map


def _shard_map_compat_kwargs() -> dict:
    """Disable replication/VMA checking under whichever name this JAX
    version uses (``check_vma`` on new JAX, ``check_rep`` on 0.4.x); the
    engine's out_specs mix replicated scalars with sharded tables, which
    the strict checker rejects on some versions."""
    try:
        params = inspect.signature(_shard_map).parameters
    except (TypeError, ValueError):  # pragma: no cover - builtin/odd callables
        return {}
    for name in ("check_vma", "check_rep"):
        if name in params:
            return {name: False}
    return {}

from repro.core import join as J
from repro.core.engine import build_tick
from repro.core.plan import ExecutionPlan
from repro.core.state import EngineState, init_state


def _state_specs(state: EngineState, axes) -> EngineState:
    """PartitionSpec pytree: shard every capacity axis, replicate scalars."""
    shard = P(axes)

    def spec_leaf(x):
        return shard if x.ndim >= 1 else P()

    return jax.tree.map(spec_leaf, state)


def build_sharded_tick(
    plan: ExecutionPlan,
    mesh: Mesh,
    axes=("data",),
    backend: str = J.JoinBackend.REF,
    extract_matches: bool = False,
):
    """Returns ``(tick, state)`` with ``tick`` jit-compiled under shard_map
    and ``state`` placed according to the sharding spec.

    ``axes`` may name one or more mesh axes; the capacity dimension is
    sharded over their product (e.g. ``('pod', 'data')`` on the
    multi-pod production mesh).
    """
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    axes = tuple(axes)
    axis_name = axes if len(axes) > 1 else axes[0]

    inner = build_tick(
        plan,
        backend=backend,
        extract_matches=extract_matches,
        axis_name=axis_name,
        n_shards=n_shards,
    )

    state0 = init_state(plan)
    specs = _state_specs(state0, axes)

    from repro.core.engine import TickResult
    from repro.core.state import EdgeBatch

    batch_specs = EdgeBatch(*(P() for _ in range(7)))
    out_res_specs = TickResult(
        n_new_matches=P(),
        n_overflow=P(),
        match_bindings=P(axes),
        match_ets=P(axes),
        match_valid=P(axes),
    )

    tick = jax.jit(
        _shard_map(
            inner,
            mesh=mesh,
            in_specs=(specs, batch_specs),
            out_specs=(specs, out_res_specs),
            **_shard_map_compat_kwargs(),
        )
    )

    state = jax.device_put(
        state0, jax.tree.map(lambda s: NamedSharding(mesh, s), specs))
    return tick, state
