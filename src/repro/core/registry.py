"""Standing-query registry + structural plan signatures.

``QueryRegistry`` owns the lifecycle of registered continuous queries:
qid allocation, compilation (``compile_plan``) with uniform capacities,
and the *structural signature* used by the service layer to bucket
queries into padded slot groups (``repro.core.multi.build_slot_tick``).

The signature captures everything ``build_tick_body`` closes over —
expansion-list level layouts, REL/TREL matrices, capacities, join specs
— and deliberately EXCLUDES the per-edge label arrays and the window
span, which are runtime slot data.  Two plans with equal signatures are
interchangeable under one compiled slot tick.
"""

from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.decompose import TCSubquery
from repro.core.plan import ExecutionPlan, compile_plan
from repro.core.query import QueryGraph


def plan_decomposition(plan: ExecutionPlan) -> list[tuple[int, ...]]:
    """The plan's (ordered) TC-subquery timing sequences — enough to
    recompile the SAME plan, bypassing the decomposition heuristics
    (checkpoint manifests round-trip plans through this)."""
    return [tuple(s.timing_sequence) for s in plan.subqueries]


def plan_signature(plan: ExecutionPlan) -> tuple:
    """Hashable structural fingerprint of an ExecutionPlan.

    Includes: per-subquery timing sequences and level specs (matched
    query edge, slot wiring, layouts, capacities), and per-L0-join REL /
    TREL matrices, new-vertex slots, layouts, and capacities.  Excludes:
    vertex/edge *labels* and the window span (runtime slot parameters).
    """
    subs = tuple(
        (
            s.timing_sequence,
            tuple(
                (lv.qedge, lv.src_slot, lv.dst_slot, lv.new_vertices,
                 lv.vertex_layout, lv.capacity, lv.max_new)
                for lv in s.levels
            ),
        )
        for s in plan.subqueries
    )
    joins = tuple(
        (js.rel.shape, js.rel.tobytes(), js.trel.shape, js.trel.tobytes(),
         js.b_new_vertex_slots, js.vertex_layout, js.edge_layout,
         js.capacity, js.max_new)
        for js in plan.l0_joins
    )
    return (subs, joins)


@dataclass
class RegisteredQuery:
    """One standing query: its graph, window, compiled plan, signature."""

    qid: int
    query: QueryGraph
    window: int
    plan: ExecutionPlan
    signature: tuple = field(repr=False)


class QueryRegistry:
    """qid -> compiled standing query, with structural grouping info.

    Capacities are uniform across registered queries (they are part of
    the structural signature, so differing capacities would fragment the
    slot groups for no benefit at this layer).
    """

    def __init__(self, level_capacity: int = 4096, l0_capacity: int = 4096,
                 max_new: int = 1024):
        self.level_capacity = level_capacity
        self.l0_capacity = l0_capacity
        self.max_new = max_new
        self._queries: dict[int, RegisteredQuery] = {}
        self._next_qid = 0

    # ------------------------------------------------------------------ #
    def compile(self, query: QueryGraph, window: int,
                decomposition=None) -> ExecutionPlan:
        """Compile with this registry's uniform capacities (host-side).

        ``decomposition``: optional ordered timing sequences (the
        ``plan_decomposition`` form) to reproduce an exact plan instead
        of re-running the decomposition/join-order heuristics.
        """
        if decomposition is not None:
            decomposition = [
                TCSubquery(frozenset(seq), tuple(seq))
                for seq in decomposition
            ]
        return compile_plan(
            query, window,
            decomposition=decomposition,
            level_capacity=self.level_capacity,
            l0_capacity=self.l0_capacity,
            max_new=self.max_new,
        )

    def register(self, query: QueryGraph, window: int,
                 plan: ExecutionPlan | None = None) -> int:
        """Register a standing query; with ``plan`` given, serve that
        EXACT plan (custom decomposition / capacities) instead of
        compiling one.

        Every plan — compiled here or supplied — must satisfy the
        paper's decomposition invariants (edge-disjoint cover, valid
        timing sequences, prefix-connected join order, coherent
        REL/TREL and prefix-chain slices); a violating plan raises
        ``repro.analysis.PlanInvariantError`` before any registry state
        is touched."""
        if plan is None:
            plan = self.compile(query, window)
        elif plan.query != query or plan.window != window:
            raise ValueError("plan does not match the given query/window")
        else:
            # capacities must be the registry's: checkpoint restore
            # recompiles from (query, window, decomposition) with the
            # registry's capacities, so divergent ones would not
            # round-trip (and would fragment slot groups for no benefit)
            level_caps = {(lv.capacity, lv.max_new)
                          for s in plan.subqueries for lv in s.levels}
            l0_caps = {(js.capacity, js.max_new) for js in plan.l0_joins}
            if level_caps != {(self.level_capacity, self.max_new)} or \
                    (l0_caps and
                     l0_caps != {(self.l0_capacity, self.max_new)}):
                raise ValueError(
                    "plan capacities differ from the registry's "
                    f"(level={self.level_capacity}, l0={self.l0_capacity}, "
                    f"max_new={self.max_new})")
        # fail-fast BEFORE qid allocation: a rejected plan must leave
        # the registry (and the service layers above it) untouched
        from repro.analysis.plan_check import verify_plan
        verify_plan(plan, symbol=f"register(window={window})")
        qid = self._next_qid
        self._next_qid += 1
        self._queries[qid] = RegisteredQuery(
            qid=qid, query=query, window=window, plan=plan,
            signature=plan_signature(plan),
        )
        return qid

    def adopt(self, qid: int, query: QueryGraph, window: int,
              decomposition=None) -> RegisteredQuery:
        """Re-insert a query under a FIXED qid (checkpoint-restore path):
        the restored service must hand tenants back their original ids.
        Bumps the qid allocator past ``qid`` so later ``register`` calls
        stay collision-free."""
        if qid in self._queries:
            raise ValueError(f"qid {qid} already registered")
        plan = self.compile(query, window, decomposition=decomposition)
        # restore path: a manifest carrying a corrupted decomposition
        # must fail restore, not serve wrong-semantics matches
        from repro.analysis.plan_check import verify_plan
        verify_plan(plan, symbol=f"adopt(qid={qid})")
        rq = RegisteredQuery(
            qid=qid, query=query, window=window, plan=plan,
            signature=plan_signature(plan),
        )
        self._queries[qid] = rq
        self._next_qid = max(self._next_qid, qid + 1)
        return rq

    def unregister(self, qid: int) -> RegisteredQuery:
        return self._queries.pop(qid)

    @property
    def next_qid(self) -> int:
        return self._next_qid

    # ------------------------------------------------------------------ #
    def get(self, qid: int) -> RegisteredQuery:
        return self._queries[qid]

    def qids(self) -> list[int]:
        return sorted(self._queries)

    def plans(self) -> list[ExecutionPlan]:
        """Active plans in qid order — the input to ``build_multi_tick``."""
        return [self._queries[q].plan for q in self.qids()]

    def __len__(self) -> int:
        return len(self._queries)

    def __contains__(self, qid: int) -> bool:
        return qid in self._queries
