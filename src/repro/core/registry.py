"""Standing-query registry + structural plan signatures.

``QueryRegistry`` owns the lifecycle of registered continuous queries:
qid allocation, compilation (``compile_plan``) with uniform capacities,
and the *structural signature* used by the service layer to bucket
queries into padded slot groups (``repro.core.multi.build_slot_tick``).

The signature captures everything ``build_tick_body`` closes over —
expansion-list level layouts, REL/TREL matrices, capacities, join specs
— and deliberately EXCLUDES the per-edge label arrays and the window
span, which are runtime slot data.  Two plans with equal signatures are
interchangeable under one compiled slot tick.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field

from repro.core.plan import ExecutionPlan, compile_plan
from repro.core.query import QueryGraph


def plan_signature(plan: ExecutionPlan) -> tuple:
    """Hashable structural fingerprint of an ExecutionPlan.

    Includes: per-subquery timing sequences and level specs (matched
    query edge, slot wiring, layouts, capacities), and per-L0-join REL /
    TREL matrices, new-vertex slots, layouts, and capacities.  Excludes:
    vertex/edge *labels* and the window span (runtime slot parameters).
    """
    subs = tuple(
        (
            s.timing_sequence,
            tuple(
                (lv.qedge, lv.src_slot, lv.dst_slot, lv.new_vertices,
                 lv.vertex_layout, lv.capacity, lv.max_new)
                for lv in s.levels
            ),
        )
        for s in plan.subqueries
    )
    joins = tuple(
        (js.rel.shape, js.rel.tobytes(), js.trel.shape, js.trel.tobytes(),
         js.b_new_vertex_slots, js.vertex_layout, js.edge_layout,
         js.capacity, js.max_new)
        for js in plan.l0_joins
    )
    return (subs, joins)


@dataclass
class RegisteredQuery:
    """One standing query: its graph, window, compiled plan, signature."""

    qid: int
    query: QueryGraph
    window: int
    plan: ExecutionPlan
    signature: tuple = field(repr=False)


class QueryRegistry:
    """qid -> compiled standing query, with structural grouping info.

    Capacities are uniform across registered queries (they are part of
    the structural signature, so differing capacities would fragment the
    slot groups for no benefit at this layer).
    """

    def __init__(self, level_capacity: int = 4096, l0_capacity: int = 4096,
                 max_new: int = 1024):
        self.level_capacity = level_capacity
        self.l0_capacity = l0_capacity
        self.max_new = max_new
        self._queries: dict[int, RegisteredQuery] = {}
        self._next_qid = itertools.count()

    # ------------------------------------------------------------------ #
    def register(self, query: QueryGraph, window: int) -> int:
        plan = compile_plan(
            query, window,
            level_capacity=self.level_capacity,
            l0_capacity=self.l0_capacity,
            max_new=self.max_new,
        )
        qid = next(self._next_qid)
        self._queries[qid] = RegisteredQuery(
            qid=qid, query=query, window=window, plan=plan,
            signature=plan_signature(plan),
        )
        return qid

    def unregister(self, qid: int) -> RegisteredQuery:
        return self._queries.pop(qid)

    # ------------------------------------------------------------------ #
    def get(self, qid: int) -> RegisteredQuery:
        return self._queries[qid]

    def qids(self) -> list[int]:
        return sorted(self._queries)

    def plans(self) -> list[ExecutionPlan]:
        """Active plans in qid order — the input to ``build_multi_tick``."""
        return [self._queries[q].plan for q in self.qids()]

    def __len__(self) -> int:
        return len(self._queries)

    def __contains__(self, qid: int) -> bool:
        return qid in self._queries
