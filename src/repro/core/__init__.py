"""Core of the paper's contribution: timing-constrained continuous subgraph search.

Layers
------
query       QueryGraph with a strict partial order ``prec`` over query edges.
canon       Canonical relabeling of query graphs: isomorphic-modulo-
            relabeling queries map to one representation (the api
            planner's cross-tenant sharing key).
decompose   TC-subquery enumeration (Alg. 5), greedy minimum-cardinality
            decomposition (Alg. 6), join-order selection (Def. 14).
plan        Compilation of a decomposed query into numeric join specs
            (REL vertex-compatibility matrices, TREL timing matrices,
            binding-slot layouts) consumed by the device engine.
state       Fixed-capacity device tables: per-level MS-tree SoA storage.
engine      ``tick()``: batched insert/expire with streaming consistency.
multi       Multi-query fusion: ``build_multi_tick`` (one label-match
            phase for N queries) and padded-slot ticks (vmapped over
            same-structure query slots; recompile-free registration).
registry    ``QueryRegistry``: standing-query lifecycle + structural
            plan signatures used to bucket queries into slot groups.
share       Cross-tenant prefix sharing: ``SharedPrefixForest`` CSEs
            TC-subquery prefixes across registered queries (refcounted
            shared expansion-list tables, advanced once per tick).
oracle      Exact pure-Python reference engine used as the test oracle.
sjtree      SJ-tree baseline (Choudhury et al. 2015) + timing post-filter.
distributed shard_map-wrapped tick for multi-device execution.
"""

from repro.core.canon import CanonicalForm, canonical_form, canonical_key
from repro.core.query import QueryGraph
from repro.core.decompose import decompose, tc_subqueries, join_order
from repro.core.plan import ExecutionPlan, compile_plan
from repro.core.multi import (
    MultiEngineState,
    build_multi_tick,
    build_slot_tick,
    init_multi_state,
)
from repro.core.registry import QueryRegistry, plan_signature
from repro.core.share import (
    ForestStats,
    SharedPrefixForest,
    SharedPrefixInfo,
    prefix_chain,
)
