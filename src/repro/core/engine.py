"""The streaming match engine: ``tick()``.

One tick ingests a batch of stream edges and advances every expansion
list, with semantics *exactly equal* to processing the edges one-by-one
in timestamp order (streaming consistency, Definition 13).

How the paper's concurrency design maps to TPU dataflow
-------------------------------------------------------
The paper runs one thread per edge and serializes conflicting accesses to
expansion-list items with per-item lock wait-lists ordered by timestamp
(Section 5.2).  On a TPU there are no threads or locks; the equivalent
schedule is *level-ordered batched processing*:

 1. Edges that match ``ε_j`` only ever write item ``L_i^j`` (Theorem 1) —
    so items are the paper's "resources" and our loop over levels visits
    each resource once per tick, in timing-sequence order.
 2. Within a TC-subquery the timing sequence is a ≺-chain, so the strict
    ``ts_parent < ts_edge`` predicate *is* the lock wait-list: a batch
    edge joins a same-tick parent row if and only if the sequential
    schedule would have processed that parent first.  (Theorem: batched
    tick ≡ sequential replay; property-tested in tests/test_engine_props.)
 3. Cross-subquery joins into ``L_0`` use delta joins — ``Δ(A)⋈B ∪
    A_old⋈Δ(B)`` — the incremental-view form of Algorithm 1 lines 11-22.
 4. Deletion cascades run level-ordered top-down, which is the pure-
    functional image of the paper's two-phase "partial removal"
    (Section 5.3): no reader can ever observe a half-deleted path because
    the tick is a pure function from state to state.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import join as J
from repro.core.plan import ExecutionPlan
from repro.core.state import (
    EdgeBatch,
    EngineState,
    EngineStats,
    L0Table,
    LevelTable,
)

I32 = jnp.int32

# Traced "watermark unknown" sentinel for event-time ticks: composes as
# the identity through ``max(t_now, min(watermark, max_batch_ts))``, so a
# tick fed NO_WATERMARK behaves like the frozen/processing-time clock
# without retracing (the watermark stays a traced scalar either way).
NO_WATERMARK = int(np.iinfo(np.int32).min)


class TickResult(NamedTuple):
    n_new_matches: jnp.ndarray     # int32 scalar
    n_overflow: jnp.ndarray       # int32 scalar (this tick)
    match_bindings: jnp.ndarray   # int32 [max_out, nv_total]
    match_ets: jnp.ndarray        # int32 [max_out, ne_total]
    match_valid: jnp.ndarray      # bool  [max_out]


class _View(NamedTuple):
    """Denormalized view of a table: what joins consume."""

    bind: jnp.ndarray   # int32 [C, nv]
    ets: jnp.ndarray    # int32 [C, ne]
    valid: jnp.ndarray  # bool [C]
    fresh: jnp.ndarray  # bool [C]


def _safe_slots(slots, ok, capacity):
    """Map ungranted slots to ``capacity`` so scatter mode='drop' skips them
    (negative indices would *wrap* in JAX)."""
    return jnp.where(ok, slots, capacity)


def _append_level(
    table: LevelTable,
    parent_idx,
    src,
    dst,
    ts,
    req_valid,
):
    """Scatter new MS-tree nodes into free slots; returns (table, n_drop)."""
    cap = table.valid.shape[0]
    slots, ok, n_drop = J.alloc_slots(table.valid, req_valid, req_valid.shape[0])
    s = _safe_slots(slots, ok, cap)
    return (
        LevelTable(
            src=table.src.at[s].set(src, mode="drop"),
            dst=table.dst.at[s].set(dst, mode="drop"),
            ts=table.ts.at[s].set(ts, mode="drop"),
            parent=table.parent.at[s].set(parent_idx, mode="drop"),
            valid=table.valid.at[s].set(True, mode="drop"),
            fresh=table.fresh.at[s].set(True, mode="drop"),
        ),
        n_drop,
    )


def _append_l0(table: L0Table, bindings, ets, req_valid):
    cap = table.valid.shape[0]
    slots, ok, n_drop = J.alloc_slots(table.valid, req_valid, req_valid.shape[0])
    s = _safe_slots(slots, ok, cap)
    return (
        L0Table(
            bindings=table.bindings.at[s].set(bindings, mode="drop"),
            ets=table.ets.at[s].set(ets, mode="drop"),
            valid=table.valid.at[s].set(True, mode="drop"),
            fresh=table.fresh.at[s].set(True, mode="drop"),
        ),
        n_drop,
    )


def _compact(view: _View, mask, size: int):
    """Gather up to ``size`` rows of ``view`` where ``mask``; returns a _View
    of static size plus the overflow count."""
    (idx,) = jnp.nonzero(mask, size=size, fill_value=-1)
    ok = idx >= 0
    safe = jnp.maximum(idx, 0)
    n_drop = jnp.maximum(jnp.sum(mask, dtype=I32) - size, 0)
    return (
        _View(
            bind=jnp.take(view.bind, safe, axis=0),
            ets=jnp.take(view.ets, safe, axis=0),
            valid=ok,
            fresh=ok,
        ),
        safe,
        n_drop,
    )


def edge_match_mask(batch: EdgeBatch, esl, edl, eel) -> jnp.ndarray:
    """Per-query-edge label match mask ``[n_qedges, B]``.

    ``esl`` / ``edl`` / ``eel`` are the query's per-edge src-vertex,
    dst-vertex, and edge label arrays (``eel < 0`` = wildcard).  They may
    be compile-time constants (single-query ``build_tick``) or traced
    runtime arrays (the multi-query fused / slot ticks), which is what
    lets a service register a same-shaped query without recompiling.
    """
    no_selfloop = batch.src != batch.dst
    return (
        batch.valid[None, :]
        & no_selfloop[None, :]
        & (batch.src_label[None, :] == esl[:, None])
        & (batch.dst_label[None, :] == edl[:, None])
        & ((eel[:, None] < 0) | (batch.edge_label[None, :] == eel[:, None]))
    )


def build_tick_body(
    plan: ExecutionPlan,
    backend: str = J.JoinBackend.REF,
    extract_matches: bool = True,
    max_out: int | None = None,
    axis_name: str | None = None,
    n_shards: int = 1,
    prefix_depth: int = 0,
):
    """Compile the *structural* part of ``plan`` into a tick body.

    Returns ``body(state, batch, ematch, window) -> (state, TickResult)``
    where ``ematch`` is the ``[n_qedges, B]`` label-match mask (see
    ``edge_match_mask``) and ``window`` the sliding-window span.  Both are
    runtime inputs: everything the body closes over — expansion-list
    layouts, REL/TREL matrices, capacities — depends only on the query's
    *structure* (shape + timing order), not on its labels.  The
    single-query ``build_tick``, the fused ``build_multi_tick``, and the
    padded-slot ``build_slot_tick`` (repro.core.multi) all share this
    body, which is what makes the multi-query oracle equivalence hold by
    construction.

    With ``prefix_depth > 0`` (cross-tenant prefix sharing,
    ``repro.core.share``), the first ``prefix_depth`` levels of subquery
    0's expansion list live in a shared prefix table advanced elsewhere,
    and the body signature becomes ``body(state, batch, ematch, window,
    prefix_view)``: ``state`` holds only subquery 0's *suffix* levels
    (``init_state(plan, prefix_depth)``) and ``prefix_view`` is the
    shared table's post-append view for this tick (``repro.core.share.
    NodeView``: denormalized bind/ets plus pre- and post-expiry
    validity).  Semantics per tenant are exactly those of the unshared
    body — the view IS what the local level-``prefix_depth`` recon would
    have been.

    Sharding composes with sharing (``axis_name`` AND ``prefix_depth``
    both set): the prefix view is REPLICATED per shard — the forest node
    advances once and its tables are broadcast, never partitioned — so
    any join whose left side is the replicated prefix produces identical
    pairs on every shard.  Those pairs are round-robined over shards by
    pair index before appending (deterministic refcount/row
    partitioning), and their drop counts — computed redundantly on every
    shard — accumulate in a separate bucket psum'd then divided by
    ``n_shards``.  Deeper suffix levels inherit parent-locality as
    usual.
    """
    if prefix_depth:
        if not (0 < prefix_depth <= len(plan.subqueries[0].levels)):
            raise ValueError(
                f"prefix_depth {prefix_depth} out of range for subquery 0 "
                f"({len(plan.subqueries[0].levels)} levels)")
    max_out = max_out or max(js.max_new for js in plan.l0_joins) if plan.l0_joins \
        else (max_out or plan.subqueries[0].levels[-1].max_new)

    # per-(subquery, level>=1) REL for the edge join
    level_rel: dict[tuple[int, int], np.ndarray] = {}
    for si, s in enumerate(plan.subqueries):
        for li in range(1, len(s.levels)):
            lv = s.levels[li]
            nv_prev = len(s.levels[li - 1].vertex_layout)
            rel = np.zeros((nv_prev, 2), dtype=bool)
            if lv.src_slot >= 0:
                rel[lv.src_slot, 0] = True
            if lv.dst_slot >= 0:
                rel[lv.dst_slot, 1] = True
            level_rel[(si, li)] = rel
    def _trel_chain(nea: int) -> np.ndarray:
        """Chain timing spec: only A's last edge must precede the new edge —
        the ≺-chain of a TC timing sequence makes the rest transitive."""
        t = np.zeros((nea, 1), dtype=np.int8)
        t[nea - 1, 0] = -1
        return t

    nv_final = len(plan.final_vertex_layout)
    ne_final = len(plan.final_edge_layout)

    def _expire(levels, l0, lo, prefix_valid_after=None):
        """End-of-tick deletion (paper §4.2): level-ordered top-down cascade
        over MS-tree parent pointers; L0 rows checked directly on their
        denormalized per-edge timestamps.  With a shared prefix
        (``prefix_depth > 0``), subquery 0's first retained level cascades
        from the shared prefix table's post-expiry validity instead of a
        local parent level."""
        new_levels = []
        for si, sub in enumerate(levels):
            out = []
            prev_valid = prefix_valid_after if si == 0 else None
            for t in sub:
                v = t.valid & (t.ts > lo)
                if prev_valid is not None:
                    v = v & jnp.take(prev_valid, jnp.maximum(t.parent, 0),
                                     mode="clip")
                out.append(t._replace(valid=v))
                prev_valid = v
            new_levels.append(tuple(out))
        new_l0 = tuple(
            t._replace(valid=t.valid & jnp.all(t.ets > lo, axis=1))
            for t in l0
        )
        return tuple(new_levels), new_l0

    def body(state: EngineState, batch: EdgeBatch, ematch, window,
             prefix_view=None, watermark=None):
        # -- 0. advance time; clear last tick's fresh marks ------------ #
        # NOTE: expiry is deferred to the END of the tick.  Mid-tick, the
        # window-span predicate inside every join plays the role of the
        # paper's two-phase partial removal (§5.3): a row that expires at
        # some intra-tick time is still joinable by earlier-timestamped
        # batch edges and already invisible to later ones.
        #
        # ``watermark=None`` (a Python-static choice, one trace each) is
        # the processing-time clock: t_now rides the max ts seen, so one
        # out-of-order edge jumps the window for everyone.  With a traced
        # ``watermark`` scalar (event-time mode, fed from the ingest
        # frontier), edges at-or-below the already-released floor are
        # rejected-and-counted before they can touch a table, and the
        # clock advances to min(watermark, max batch ts): bounded above
        # by the watermark so a force-evicted straggler cannot prematurely
        # expire partials still inside ``allowed_lateness``, and by the
        # batch max so release backlog (or an all-invalid batch — unarmed
        # slots, inactive queries) keeps the clock frozen exactly as the
        # sequential replay would.  INT32_MIN means "watermark unknown"
        # and degrades to the frozen/processing clock through the same
        # max/min composition — no branch on the traced value.
        rejected = jnp.zeros((), I32)
        if watermark is not None:
            late = batch.valid & (batch.ts <= state.t_now - window)
            rejected = jnp.sum(late, dtype=I32)
            keep = batch.valid & ~late
            batch = batch._replace(valid=keep)
            ematch = ematch & keep[None, :]
        bt = jnp.where(batch.valid, batch.ts, jnp.iinfo(jnp.int32).min)
        if watermark is None:
            t_now = jnp.maximum(state.t_now, jnp.max(bt))
        else:
            t_now = jnp.maximum(
                state.t_now, jnp.minimum(watermark, jnp.max(bt)))
        levels = tuple(
            tuple(t._replace(fresh=jnp.zeros_like(t.fresh)) for t in sub)
            for sub in state.levels
        )
        l0 = tuple(t._replace(fresh=jnp.zeros_like(t.fresh)) for t in state.l0)

        n_overflow = jnp.zeros((), I32)
        # drops computed on REPLICATED inputs (prefix-view joins under
        # sharding): every shard counts the same drop, so this bucket is
        # psum'd then divided by n_shards at the end of the tick
        n_overflow_repl = jnp.zeros((), I32)

        def _own_rows(n):
            """Round-robin shard ownership mask over a row/pair index."""
            my_shard = jax.lax.axis_index(axis_name)
            return (jnp.arange(n) % n_shards) == my_shard

        # -- 1. per-query-edge label match mask [n_qedges, B] ---------- #
        edge_used = jnp.any(ematch, axis=0)
        n_discard = jnp.sum(batch.valid & ~edge_used, dtype=I32)

        bbind = jnp.stack([batch.src, batch.dst], axis=1)  # [B, 2]
        bets = batch.ts[:, None]

        # round-robin ownership of level-1 appends across shards
        if axis_name is not None:
            my = jax.lax.axis_index(axis_name)
            own1 = (jnp.arange(batch.src.shape[0]) % n_shards) == my
        else:
            own1 = jnp.ones(batch.src.shape, jnp.bool_)

        # -- 2. subquery phase: level-ordered batched inserts ---------- #
        recons: list[list[_View]] = []
        new_levels = []
        for si, s in enumerate(plan.subqueries):
            sub = list(levels[si])
            sub_recons: list[_View] = []
            start = prefix_depth if si == 0 else 0
            if start:
                # subquery 0's first `prefix_depth` levels live in a
                # shared prefix table (repro.core.share); its post-append
                # view seeds the reconstruction chain exactly where the
                # local level-`start-1` recon would have
                sub_recons.append(_View(prefix_view.bind, prefix_view.ets,
                                        prefix_view.valid,
                                        prefix_view.fresh))
            for li in range(start, len(s.levels)):
                lv = s.levels[li]
                ti = li - start          # index into the (suffix) tables
                em = ematch[lv.qedge]
                if li == 0:
                    t, nd = _append_level(
                        sub[0], jnp.full_like(batch.src, -1),
                        batch.src, batch.dst, batch.ts, em & own1)
                    sub[0] = t
                    n_overflow += nd
                else:
                    prev = sub_recons[-1]
                    a_idx, b_idx, pv, nd1 = J.join_pairs(
                        prev.bind, prev.ets, prev.valid,
                        bbind, bets, em,
                        level_rel[(si, li)], _trel_chain(prev.ets.shape[1]),
                        lv.max_new, window, backend)
                    if axis_name is not None and li == start and start:
                        # left side is the replicated prefix view: every
                        # shard computed the same pairs — partition them
                        # deterministically so each lands exactly once
                        pv = pv & _own_rows(pv.shape[0])
                        n_overflow_repl += nd1
                    else:
                        n_overflow += nd1
                    t, nd2 = _append_level(
                        sub[ti], a_idx,
                        jnp.take(batch.src, b_idx, mode="clip"),
                        jnp.take(batch.dst, b_idx, mode="clip"),
                        jnp.take(batch.ts, b_idx, mode="clip"),
                        pv)
                    sub[ti] = t
                    n_overflow += nd2
                # reconstruct this level's denormalized view (post-append)
                t = sub[ti]
                if li == 0:
                    bind = jnp.stack([t.src, t.dst], axis=1)
                    ets = t.ts[:, None]
                else:
                    p = jnp.maximum(t.parent, 0)
                    prevv = sub_recons[-1]
                    cols = [jnp.take(prevv.bind, p, axis=0)]
                    own = []
                    if lv.src_slot < 0:
                        own.append(t.src[:, None])
                    if lv.dst_slot < 0:
                        own.append(t.dst[:, None])
                    bind = jnp.concatenate(cols + own, axis=1)
                    ets = jnp.concatenate(
                        [jnp.take(prevv.ets, p, axis=0), t.ts[:, None]], axis=1)
                sub_recons.append(_View(bind, ets, t.valid, t.fresh))
            recons.append(sub_recons)
            new_levels.append(tuple(sub))
        levels = tuple(new_levels)

        # -- 3. L_0 phase: delta joins across TC-subqueries ------------ #
        # When subquery 0 is FULLY prefixed its final view is the shared
        # (replicated) prefix table itself: its delta needs no gather,
        # and joins with it on the left produce replicated pairs that
        # must be ownership-partitioned before appending.
        a_repl = bool(prefix_depth) \
            and prefix_depth == len(plan.subqueries[0].levels)
        new_l0 = []
        a_view = recons[0][-1]  # L_0^1 ≡ P_1's final item (paper Fig. 8)
        for gi, js in enumerate(plan.l0_joins):
            b_view = recons[gi + 1][-1]
            tbl = l0[gi]
            d = js.max_new

            # J1: ΔA ⋈ B (old ∪ Δ)
            da, _, nd0 = _compact(a_view, a_view.fresh & a_view.valid, d)
            if a_repl:
                n_overflow_repl += nd0
            else:
                n_overflow += nd0
            if axis_name is not None and not a_repl:
                da = _View(*(
                    jax.lax.all_gather(x, axis_name, axis=0, tiled=True)
                    for x in da))
            a1, b1, pv1, nd1 = J.join_pairs(
                da.bind, da.ets, da.valid,
                b_view.bind, b_view.ets, b_view.valid,
                js.rel, js.trel, d, window, backend)
            nb = jnp.take(b_view.bind, b1, axis=0, mode="clip")
            out_bind1 = jnp.concatenate(
                [jnp.take(da.bind, a1, axis=0, mode="clip")]
                + ([nb[:, list(js.b_new_vertex_slots)]]
                   if js.b_new_vertex_slots else []),
                axis=1)
            out_ets1 = jnp.concatenate(
                [jnp.take(da.ets, a1, axis=0, mode="clip"),
                 jnp.take(b_view.ets, b1, axis=0, mode="clip")], axis=1)
            tbl, nd2 = _append_l0(tbl, out_bind1, out_ets1, pv1)

            # J2: A_old ⋈ ΔB
            db, _, nd3 = _compact(b_view, b_view.fresh & b_view.valid, d)
            if axis_name is not None:
                db = _View(*(
                    jax.lax.all_gather(x, axis_name, axis=0, tiled=True)
                    for x in db))
            a2, b2, pv2, nd4 = J.join_pairs(
                a_view.bind, a_view.ets, a_view.valid & ~a_view.fresh,
                db.bind, db.ets, db.valid,
                js.rel, js.trel, d, window, backend)
            if axis_name is not None and a_repl:
                # replicated A × gathered (replicated) ΔB: identical
                # pairs on every shard — partition before append
                pv2 = pv2 & _own_rows(pv2.shape[0])
                n_overflow_repl += nd4
            else:
                n_overflow += nd4
            nb2 = jnp.take(db.bind, b2, axis=0, mode="clip")
            out_bind2 = jnp.concatenate(
                [jnp.take(a_view.bind, a2, axis=0, mode="clip")]
                + ([nb2[:, list(js.b_new_vertex_slots)]]
                   if js.b_new_vertex_slots else []),
                axis=1)
            out_ets2 = jnp.concatenate(
                [jnp.take(a_view.ets, a2, axis=0, mode="clip"),
                 jnp.take(db.ets, b2, axis=0, mode="clip")], axis=1)
            tbl, nd5 = _append_l0(tbl, out_bind2, out_ets2, pv2)

            n_overflow += nd1 + nd2 + nd3 + nd5
            new_l0.append(tbl)
            a_view = _View(tbl.bindings, tbl.ets, tbl.valid, tbl.fresh)
            a_repl = False  # the L0 table itself is always sharded
        l0 = tuple(new_l0)

        # -- 4. emit (before end-of-tick expiry: a match created mid-tick
        #       is reported even if it expires within the same tick,
        #       matching sequential replay) --------------------------- #
        final = a_view
        new_mask = final.fresh & final.valid
        if axis_name is not None and a_repl:
            # fully-prefixed chain query: the final view is replicated —
            # partition emission so each match is reported exactly once
            new_mask = new_mask & _own_rows(new_mask.shape[0])
        n_new = jnp.sum(new_mask, dtype=I32)
        if axis_name is not None:
            n_new = jax.lax.psum(n_new, axis_name)
        if extract_matches:
            out, _, nd = _compact(final, new_mask, max_out)
            mb, me, mv = out.bind, out.ets, out.valid
            n_overflow += nd
        else:
            mb = jnp.zeros((max_out, nv_final), I32)
            me = jnp.zeros((max_out, ne_final), I32)
            mv = jnp.zeros((max_out,), jnp.bool_)

        # -- 5. end-of-tick expiry ------------------------------------- #
        levels, l0 = _expire(
            levels, l0, t_now - window,
            prefix_view.valid_after if prefix_depth else None)

        if axis_name is not None:
            n_overflow = jax.lax.psum(n_overflow, axis_name) \
                + jax.lax.psum(n_overflow_repl, axis_name) // n_shards
            n_discard = jax.lax.psum(n_discard, axis_name) // n_shards
        else:
            n_overflow = n_overflow + n_overflow_repl

        stats = EngineStats(
            n_matches_total=state.stats.n_matches_total + n_new,
            n_overflow=state.stats.n_overflow + n_overflow,
            n_edges_processed=state.stats.n_edges_processed
            + jnp.sum(batch.valid, dtype=I32),
            n_edges_discarded=state.stats.n_edges_discarded + n_discard,
            n_edges_rejected=state.stats.n_edges_rejected + rejected,
        )
        new_state = EngineState(levels=levels, l0=l0, t_now=t_now, stats=stats)
        return new_state, TickResult(n_new, n_overflow, mb, me, mv)

    return body


def build_tick(
    plan: ExecutionPlan,
    backend: str = J.JoinBackend.REF,
    extract_matches: bool = True,
    max_out: int | None = None,
    axis_name: str | None = None,
    n_shards: int = 1,
    prefix_depth: int = 0,
):
    """Compile ``plan`` into a jit-able ``tick(state, batch) -> (state, res)``.

    ``backend`` selects the compatibility-join implementation
    (``JoinBackend.REF`` pure jnp reference, ``JoinBackend.PALLAS`` TPU
    kernel, ``JoinBackend.PALLAS_INTERPRET`` CPU-interpreted kernel).
    ``extract_matches=False`` skips materializing result bindings
    (throughput mode).

    Distribution (``axis_name`` set, run under shard_map): every table's
    capacity axis is sharded.  Three design rules keep almost all work
    local:
      * level-1 appends are round-robined over shards by batch position;
      * a level-j row lands on its parent's shard, so MS-tree parent
        chains NEVER cross shards and reconstruction is collective-free;
      * L0 delta joins all-gather only the (small) per-tick delta rows,
        never the tables.  Scalar stats/results are psum'd.

    For serving many standing queries against one stream, see
    ``repro.core.multi.build_multi_tick`` (fused label-match phase) and
    ``repro.runtime.service`` (recompile-free registration).
    """
    body = build_tick_body(
        plan,
        backend=backend,
        extract_matches=extract_matches,
        max_out=max_out,
        axis_name=axis_name,
        n_shards=n_shards,
        prefix_depth=prefix_depth,
    )
    esl = jnp.asarray(plan.edge_src_label)
    edl = jnp.asarray(plan.edge_dst_label)
    eel = jnp.asarray(plan.edge_edge_label)
    window = plan.window

    if prefix_depth:
        def tick(state: EngineState, batch: EdgeBatch, prefix_view,
                 watermark=None):
            return body(state, batch, edge_match_mask(batch, esl, edl, eel),
                        window, prefix_view, watermark=watermark)
    else:
        def tick(state: EngineState, batch: EdgeBatch, watermark=None):
            return body(state, batch, edge_match_mask(batch, esl, edl, eel),
                        window, watermark=watermark)

    return tick


def fold_level_host(acc, table, src_slot: int, dst_slot: int):
    """One step of the host-side MS-tree denormalization: fold a level
    table's (src, dst, ts, parent) onto its parent level's accumulated
    ``(bind, ets)`` (``acc=None`` for a root level).  Own columns are
    appended only for NEGATIVE slots, src before dst — the single
    layout rule every host-side reconstruction must agree on
    (``current_matches`` and the shared-prefix paths in
    ``repro.core.share`` all route through here)."""
    src = np.asarray(table.src)[:, None]
    dst = np.asarray(table.dst)[:, None]
    ts = np.asarray(table.ts)[:, None]
    if acc is None:
        return np.concatenate([src, dst], axis=1), ts
    bind, ets = acc
    p = np.maximum(np.asarray(table.parent), 0)
    own = []
    if src_slot < 0:
        own.append(src)
    if dst_slot < 0:
        own.append(dst)
    return (np.concatenate([bind[p]] + own, axis=1),
            np.concatenate([ets[p], ts], axis=1))


def current_matches(plan: ExecutionPlan, state: EngineState):
    """All complete matches in the current window (host-side; for tests).

    Returns a set of frozensets of ``(query_edge_id, (src, dst, ts))``.
    """
    if plan.l0_joins:
        tbl = state.l0[-1]
        bind = np.asarray(tbl.bindings)
        ets = np.asarray(tbl.ets)
        valid = np.asarray(tbl.valid)
    else:
        # reconstruct the single subquery's final level on host
        s = plan.subqueries[0]
        sub = state.levels[0]
        acc = None
        for li, lv in enumerate(s.levels):
            acc = fold_level_host(acc, sub[li], lv.src_slot, lv.dst_slot)
        bind, ets = acc
        valid = np.asarray(sub[-1].valid)

    return matches_from_rows(plan, bind, ets, valid)


def matches_from_rows(plan: ExecutionPlan, bind, ets, valid):
    """Convert final-layout match rows to the canonical frozenset form
    shared with the oracle (host-side helper for ``current_matches`` and
    the shared-prefix reconstruction in ``repro.core.share``)."""
    q = plan.query
    vlayout = plan.final_vertex_layout
    elayout = plan.final_edge_layout
    out = set()
    for r in np.nonzero(valid)[0]:
        v_of = {vl: int(bind[r, i]) for i, vl in enumerate(vlayout)}
        t_of = {el: int(ets[r, i]) for i, el in enumerate(elayout)}
        match = frozenset(
            (e, (v_of[q.edges[e][0]], v_of[q.edges[e][1]], t_of[e]))
            for e in range(q.n_edges)
        )
        out.add(match)
    return out


@functools.partial(jax.jit, static_argnums=(0,))
def _noop(x):  # pragma: no cover - placeholder to keep jax import warm
    return x
