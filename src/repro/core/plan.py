"""Compile a (query, decomposition, join order) into a numeric ExecutionPlan.

The device engine works over fixed-capacity tables of *partial matches*.
Every join in the system — a new stream edge against expansion-list item
``L_i^{j-1}`` (Algorithm 1 line 8), or a TC-subquery delta against the
global list ``L_0`` (lines 16/20) — is an instance of one generic
compatibility join (Definitions 7/8):

    mask[a, b] = AND over vertex-slot pairs  (EQ where same query vertex,
                                              NEQ otherwise — isomorphism
                                              injectivity)
               & AND over edge-slot pairs    (ts_a < ts_b / ts_a > ts_b
                                              where ≺ relates the edges)

So the plan compiles to, per join site: a boolean REL matrix (same-query-
vertex), an int8 TREL matrix (timing order), and slot layouts describing
which query vertex / query edge each table column holds.

This file is host-side numpy; the arrays are closed over by the jitted
``tick`` as compile-time constants.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.decompose import TCSubquery, decompose, join_order
from repro.core.query import QueryGraph


@dataclass
class LevelSpec:
    """One item ``L_i^j`` of a TC-subquery's expansion list (Definition 11)."""

    qedge: int                      # global query edge id matched at this level
    src_v: int                      # query vertex ids of that edge
    dst_v: int
    src_slot: int                   # slot in the *previous* layout, -1 if new
    dst_slot: int
    new_vertices: tuple[int, ...]   # query vertices first bound at this level
    vertex_layout: tuple[int, ...]  # query vertex id per slot AFTER this level
    capacity: int = 0               # filled by compile_plan
    max_new: int = 0


@dataclass
class SubquerySpec:
    """Expansion list spec for one TC-subquery P_i."""

    timing_sequence: tuple[int, ...]
    levels: list[LevelSpec]

    @property
    def vertex_layout(self) -> tuple[int, ...]:
        return self.levels[-1].vertex_layout

    @property
    def edge_layout(self) -> tuple[int, ...]:
        return self.timing_sequence


@dataclass
class JoinSpec:
    """Generic compatibility-join spec between table A and table B."""

    rel: np.ndarray                     # bool [nvA, nvB]: True = same query vertex
    trel: np.ndarray                    # int8 [neA, neB]: -1 tsA<tsB, +1 tsA>tsB
    b_new_vertex_slots: tuple[int, ...]  # B slots appended to A's layout
    vertex_layout: tuple[int, ...]      # output layout (A ++ new B)
    edge_layout: tuple[int, ...]        # output edge layout (A ++ B)
    capacity: int = 0
    max_new: int = 0


@dataclass
class ExecutionPlan:
    """Everything ``tick()`` needs, as static metadata."""

    query: QueryGraph
    window: int
    subqueries: list[SubquerySpec]
    l0_joins: list[JoinSpec]            # k-1 entries (empty when k == 1)
    # label tables, for the per-batch query-edge match mask:
    edge_src_label: np.ndarray          # int32 [n_qedges]
    edge_dst_label: np.ndarray
    edge_edge_label: np.ndarray         # -1 = wildcard
    # bookkeeping
    decomposition_sizes: tuple[int, ...] = ()
    # mapping query-edge id -> (subquery index, level index)
    edge_site: dict[int, tuple[int, int]] = field(default_factory=dict)

    @property
    def n_subqueries(self) -> int:
        return len(self.subqueries)

    @property
    def final_vertex_layout(self) -> tuple[int, ...]:
        if self.l0_joins:
            return self.l0_joins[-1].vertex_layout
        return self.subqueries[0].vertex_layout

    @property
    def final_edge_layout(self) -> tuple[int, ...]:
        if self.l0_joins:
            return self.l0_joins[-1].edge_layout
        return self.subqueries[0].edge_layout


def _compile_subquery(q: QueryGraph, tc: TCSubquery) -> SubquerySpec:
    levels: list[LevelSpec] = []
    layout: list[int] = []
    for eid in tc.timing_sequence:
        u, v = q.edges[eid]
        src_slot = layout.index(u) if u in layout else -1
        dst_slot = layout.index(v) if v in layout else -1
        new_vs: list[int] = []
        if src_slot < 0:
            new_vs.append(u)
            layout.append(u)
        if dst_slot < 0:
            new_vs.append(v)
            layout.append(v)
        levels.append(
            LevelSpec(
                qedge=eid,
                src_v=u,
                dst_v=v,
                src_slot=src_slot,
                dst_slot=dst_slot,
                new_vertices=tuple(new_vs),
                vertex_layout=tuple(layout),
            )
        )
    return SubquerySpec(timing_sequence=tc.timing_sequence, levels=levels)


def _join_spec(
    q: QueryGraph,
    a_vertex_layout: tuple[int, ...],
    a_edge_layout: tuple[int, ...],
    b_vertex_layout: tuple[int, ...],
    b_edge_layout: tuple[int, ...],
) -> JoinSpec:
    nva, nvb = len(a_vertex_layout), len(b_vertex_layout)
    rel = np.zeros((nva, nvb), dtype=bool)
    for i, va in enumerate(a_vertex_layout):
        for j, vb in enumerate(b_vertex_layout):
            rel[i, j] = va == vb
    nea, neb = len(a_edge_layout), len(b_edge_layout)
    trel = np.zeros((nea, neb), dtype=np.int8)
    for i, ea in enumerate(a_edge_layout):
        for j, eb in enumerate(b_edge_layout):
            if q.precedes(ea, eb):
                trel[i, j] = -1
            elif q.precedes(eb, ea):
                trel[i, j] = 1
    new_slots = tuple(
        j for j, vb in enumerate(b_vertex_layout) if vb not in a_vertex_layout
    )
    out_vlayout = tuple(a_vertex_layout) + tuple(b_vertex_layout[j] for j in new_slots)
    out_elayout = tuple(a_edge_layout) + tuple(b_edge_layout)
    return JoinSpec(
        rel=rel,
        trel=trel,
        b_new_vertex_slots=new_slots,
        vertex_layout=out_vlayout,
        edge_layout=out_elayout,
    )


def compile_plan(
    q: QueryGraph,
    window: int,
    decomposition: list[TCSubquery] | None = None,
    level_capacity: int = 4096,
    l0_capacity: int = 4096,
    max_new: int = 1024,
) -> ExecutionPlan:
    """Compile ``q`` into an ExecutionPlan.

    ``window`` is the sliding-window span |W| in timestamp units.
    ``level_capacity`` / ``l0_capacity`` size the fixed device tables;
    ``max_new`` bounds appends per table per tick (overflow is counted,
    matching a production backpressure path, and is zero in all tests).
    """
    if decomposition is None:
        decomposition = join_order(q, decompose(q))
    subs = [_compile_subquery(q, tc) for tc in decomposition]
    for s in subs:
        for lv in s.levels:
            lv.capacity = level_capacity
            lv.max_new = max_new

    l0_joins: list[JoinSpec] = []
    if len(subs) > 1:
        a_vl: tuple[int, ...] = subs[0].vertex_layout
        a_el: tuple[int, ...] = subs[0].edge_layout
        for i in range(1, len(subs)):
            js = _join_spec(q, a_vl, a_el, subs[i].vertex_layout, subs[i].edge_layout)
            js.capacity = l0_capacity
            js.max_new = max_new
            l0_joins.append(js)
            a_vl, a_el = js.vertex_layout, js.edge_layout

    edge_site: dict[int, tuple[int, int]] = {}
    for si, s in enumerate(subs):
        for li, lv in enumerate(s.levels):
            edge_site[lv.qedge] = (si, li)

    n_qe = q.n_edges
    esl = np.array([q.vertex_labels[q.edges[e][0]] for e in range(n_qe)], np.int32)
    edl = np.array([q.vertex_labels[q.edges[e][1]] for e in range(n_qe)], np.int32)
    eel = np.array(list(q.edge_labels), np.int32)

    return ExecutionPlan(
        query=q,
        window=window,
        subqueries=subs,
        l0_joins=l0_joins,
        edge_src_label=esl,
        edge_dst_label=edl,
        edge_edge_label=eel,
        decomposition_sizes=tuple(len(t) for t in decomposition),
        edge_site=edge_site,
    )
