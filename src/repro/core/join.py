"""The generic compatibility join (Definitions 7/8) and table append helpers.

``compat_mask`` is the computational hot spot of the whole system: every
incoming edge is joined against expansion-list items, and TC-subquery
deltas are joined against the global list.  The pure-jnp implementation
here is the reference; ``repro.kernels.compat_join`` provides the Pallas
TPU kernel with identical semantics (selected via ``JoinBackend``).

Semantics of one (a, b) pair:
  * vertex slots:  rel[i, j]  => bind_a[a, i] == bind_b[b, j]
                   ~rel[i, j] => bind_a[a, i] != bind_b[b, j]   (injectivity)
  * edge slots:    trel[i, j] == -1 => ets_a[a, i] <  ets_b[b, j]
                   trel[i, j] == +1 => ets_a[a, i] >  ets_b[b, j]
  * both rows valid.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp


def compat_mask_ref(
    bind_a: jnp.ndarray,   # int32 [CA, NVA]
    ets_a: jnp.ndarray,    # int32 [CA, NEA]
    valid_a: jnp.ndarray,  # bool  [CA]
    bind_b: jnp.ndarray,   # int32 [CB, NVB]
    ets_b: jnp.ndarray,    # int32 [CB, NEB]
    valid_b: jnp.ndarray,  # bool  [CB]
    rel: np.ndarray,       # bool  [NVA, NVB]   (host constant)
    trel: np.ndarray,      # int8  [NEA, NEB]   (host constant)
    window: int | None = None,
) -> jnp.ndarray:          # bool [CA, CB]
    """Pure-jnp reference compatibility mask.

    Loops over the (tiny, static) slot-pair dimensions so no [CA, CB, NV]
    intermediate is ever materialized — each slot pair contributes one
    [CA, CB] comparison which XLA fuses.

    When ``window`` is given, adds the *window-span* predicate
    ``max(all ts) - min(all ts) < window``: the combined match must have
    been fully inside the sliding window at the moment its last edge
    arrived.  This is the dataflow image of the paper's §5.3 two-phase
    deletion — rows near expiry stay joinable for earlier-timestamped
    triggers and are invisible to later ones.
    """
    ca, cb = bind_a.shape[0], bind_b.shape[0]
    mask = valid_a[:, None] & valid_b[None, :]
    if window is not None:
        min_a = jnp.min(ets_a, axis=1)[:, None]
        max_a = jnp.max(ets_a, axis=1)[:, None]
        min_b = jnp.min(ets_b, axis=1)[None, :]
        max_b = jnp.max(ets_b, axis=1)[None, :]
        span = jnp.maximum(max_a, max_b) - jnp.minimum(min_a, min_b)
        mask = mask & (span < window)
    nva, nvb = rel.shape
    for i in range(nva):
        ai = bind_a[:, i][:, None]
        for j in range(nvb):
            bj = bind_b[:, j][None, :]
            if rel[i, j]:
                mask = mask & (ai == bj)
            else:
                mask = mask & (ai != bj)
    nea, neb = trel.shape
    for i in range(nea):
        ti = ets_a[:, i][:, None]
        for j in range(neb):
            if trel[i, j] == -1:
                mask = mask & (ti < ets_b[:, j][None, :])
            elif trel[i, j] == 1:
                mask = mask & (ti > ets_b[:, j][None, :])
    return mask


# --------------------------------------------------------------------- #
# Backend dispatch: pure-jnp reference vs Pallas kernel.
# --------------------------------------------------------------------- #
class JoinBackend:
    REF = "ref"
    PALLAS = "pallas"            # compiled TPU path
    PALLAS_INTERPRET = "pallas_interpret"  # kernel body interpreted on CPU


def compat_mask(bind_a, ets_a, valid_a, bind_b, ets_b, valid_b, rel, trel,
                window: int | None = None,
                backend: str = JoinBackend.REF) -> jnp.ndarray:
    if backend == JoinBackend.REF:
        return compat_mask_ref(
            bind_a, ets_a, valid_a, bind_b, ets_b, valid_b, rel, trel, window)
    from repro.kernels.compat_join import ops as cj_ops
    return cj_ops.compat_mask(
        bind_a, ets_a, valid_a, bind_b, ets_b, valid_b, rel, trel, window,
        interpret=(backend == JoinBackend.PALLAS_INTERPRET))


def join_pairs(bind_a, ets_a, valid_a, bind_b, ets_b, valid_b, rel, trel,
               max_new: int, window: int | None = None,
               backend: str = JoinBackend.REF):
    """Fused compatibility join + pair extraction (the engine's hot path).

    Returns ``(a_idx, b_idx, pair_valid, n_dropped)`` — the contract of
    ``extract_pairs`` applied to the join mask.  Under the REF backend
    this *is* ``compat_mask_ref`` + ``extract_pairs`` (bit-identical to
    the historical two-step path).  Under the Pallas backends it lowers
    to the fused ``compat_join_pairs`` kernel, which extracts compacted
    pairs on-chip and never materializes the [CA, CB] mask in HBM; the
    kernel emits pairs in tile order, so cross-backend equality is on
    the pair SET (and the exact ``n_dropped``), with a backend-defined
    keep-subset in the overflow case.
    """
    if backend == JoinBackend.REF:
        mask = compat_mask_ref(
            bind_a, ets_a, valid_a, bind_b, ets_b, valid_b, rel, trel, window)
        return extract_pairs(mask, max_new)
    from repro.kernels.compat_join import ops as cj_ops
    return cj_ops.compat_join_pairs(
        bind_a, ets_a, valid_a, bind_b, ets_b, valid_b, rel, trel,
        max_new, window=window,
        interpret=(backend == JoinBackend.PALLAS_INTERPRET))


# --------------------------------------------------------------------- #
# Mask -> (a_idx, b_idx) pair extraction and free-slot allocation.
# --------------------------------------------------------------------- #
def extract_pairs(mask: jnp.ndarray, max_new: int):
    """Top-``max_new`` (a, b) index pairs of a boolean join mask.

    Returns ``(a_idx, b_idx, pair_valid, n_dropped)`` with static length
    ``max_new``.  Uses a flattened ``nonzero`` with a static size; pairs
    beyond ``max_new`` are counted as dropped (overflow) — the production
    backpressure path.
    """
    flat = mask.reshape(-1)
    n_true = jnp.sum(flat, dtype=jnp.int32)
    (idx,) = jnp.nonzero(flat, size=max_new, fill_value=-1)
    pair_valid = idx >= 0
    cb = mask.shape[1]
    safe = jnp.maximum(idx, 0)
    a_idx = safe // cb
    b_idx = safe % cb
    n_dropped = jnp.maximum(n_true - max_new, 0)
    return a_idx, b_idx, pair_valid, n_dropped


def alloc_slots(valid: jnp.ndarray, need_valid: jnp.ndarray, max_new: int):
    """Allocate up to ``max_new`` free slots (``valid == False``).

    ``need_valid`` is the bool mask of requested appends (length max_new).
    Returns ``(slot_idx, ok, n_dropped)``: ``slot_idx`` is int32 of shape
    [max_new] (slot for each request, -1 when not granted), ``ok`` marks
    granted requests.  Requests beyond the number of free slots drop.
    """
    (free,) = jnp.nonzero(~valid, size=max_new, fill_value=-1)
    # compact requests: the i-th requested append takes the i-th free slot
    req_rank = jnp.cumsum(need_valid.astype(jnp.int32)) - 1
    slot_for_req = jnp.where(
        need_valid, jnp.take(free, jnp.clip(req_rank, 0, max_new - 1),
                             mode="clip"), -1)
    ok = need_valid & (slot_for_req >= 0)
    n_dropped = jnp.sum(need_valid & (slot_for_req < 0), dtype=jnp.int32)
    return slot_for_req, ok, n_dropped
