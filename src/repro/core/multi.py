"""Multi-query continuous search: one stream, many standing queries.

The paper evaluates one timing-constrained query against the stream; a
serving system holds *millions* of standing queries against the same
edges (cf. the multi-query framing of "Large-scale continuous subgraph
queries on streams" and StreamWorks, PAPERS.md).  Re-running the stream
once per query wastes the part of the work that is identical across
queries — the per-edge label scan — and pays one dispatch per query per
batch.  This module fuses N queries into one jit-able tick:

``build_multi_tick(plans)``
    Heterogeneous fusion.  All queries' label tables are concatenated so
    one ``edge_match_mask`` call produces a single ``[total_qedges, B]``
    mask per batch (instead of N separate scans); each query's slice
    feeds the shared tick body (``repro.core.engine.build_tick_body``).
    Per-query expansion-list state lives in one ``MultiEngineState``
    pytree and the tick returns one ``TickResult`` per query, so results
    are bit-identical to N independent ``build_tick`` runs (oracle
    cross-checked in tests/test_multi_query.py).

``build_slot_tick(template_plan, n_slots)``
    Homogeneous padded slots.  Every quantity the tick body closes over
    is *structural* (expansion-list layouts, REL/TREL matrices,
    capacities — see ``repro.core.registry.plan_signature``); the only
    per-query data are the three label arrays and the window span, which
    become runtime inputs stacked ``[n_slots, ...]``.  The body is
    ``jax.vmap``-ed over the slot axis, so registering / unregistering a
    query of an already-seen structure is a pure data update — **no
    recompilation** — which is what lets ``repro.runtime.service`` serve
    a changing query population at a fixed compile budget.

Backend note: both ticks accept the same ``backend`` as ``build_tick``
(``JoinBackend.REF`` / ``PALLAS`` / ``PALLAS_INTERPRET``), and ALL
variants — including the slot tick's traced per-slot windows — are
served by every backend.  The Pallas kernels take ``window`` as a
scalar-prefetch input (not a specialization constant), and the vmapped
slot-group joins batch into ONE stacked 3-D-grid ``pallas_call`` per
join (slot, A-tile, B-tile) via the custom-vmap rule in
``repro.kernels.compat_join.ops`` — no per-slot dispatch, and
registering a query never recompiles.  Parity with REF is enforced by
tests/test_slot_tick_pallas.py in interpret mode (CI is CPU-only);
the compiled ``PALLAS`` path — in particular the fused pair-emission
loop — has not yet been validated on real TPU hardware (see
ROADMAP.md), so prefer ``PALLAS_INTERPRET``/``REF`` until it has.
"""

from __future__ import annotations

from typing import NamedTuple, Sequence

import numpy as np
import jax
import jax.numpy as jnp

from repro.core import join as J
from repro.core.engine import (
    TickResult,
    build_tick_body,
    edge_match_mask,
)
from repro.core.plan import ExecutionPlan
from repro.core.state import EdgeBatch, EngineState, init_state

I32 = jnp.int32


# --------------------------------------------------------------------- #
# Heterogeneous fusion: build_multi_tick
# --------------------------------------------------------------------- #
class MultiEngineState(NamedTuple):
    """State for N fused queries: one pytree, jit/donate/shard friendly.

    ``queries`` holds one ``EngineState`` per plan (heterogeneous table
    shapes); ``active`` is a runtime bool per query — flipping it off
    stops a query's tables from growing without recompiling the tick.
    """

    queries: tuple          # tuple[EngineState, ...], parallel to plans
    active: jnp.ndarray     # bool [n_queries]


def init_multi_state(plans: Sequence[ExecutionPlan], active=None) -> MultiEngineState:
    if active is None:
        active = jnp.ones((len(plans),), jnp.bool_)
    return MultiEngineState(
        queries=tuple(init_state(p) for p in plans),
        active=jnp.asarray(active, jnp.bool_),
    )


def set_active(mstate: MultiEngineState, qi: int, value: bool) -> MultiEngineState:
    return mstate._replace(active=mstate.active.at[qi].set(value))


def reset_query(mstate: MultiEngineState, plans: Sequence[ExecutionPlan],
                qi: int) -> MultiEngineState:
    """Replace query ``qi``'s tables with empty ones (e.g. on re-arm)."""
    qs = list(mstate.queries)
    qs[qi] = init_state(plans[qi])
    return mstate._replace(queries=tuple(qs))


def build_multi_tick(
    plans: Sequence[ExecutionPlan],
    backend: str = J.JoinBackend.REF,
    extract_matches: bool = True,
    max_out: int | None = None,
):
    """Fuse ``plans`` into one ``tick(mstate, batch) -> (mstate, results)``.

    ``results`` is a tuple of per-query ``TickResult``s, index-parallel
    to ``plans``.  The per-edge label-match phase runs ONCE over the
    concatenated query-edge tables (one ``[total_qedges, B]`` mask);
    each query's expansion-list phase consumes its slice, multiplied by
    its ``active`` flag.  Semantics per query are exactly those of
    ``build_tick(plan)`` — same body, same mask slice.
    """
    plans = list(plans)
    if not plans:
        raise ValueError("build_multi_tick needs at least one plan")
    bodies = [
        build_tick_body(p, backend=backend, extract_matches=extract_matches,
                        max_out=max_out)
        for p in plans
    ]
    esl = jnp.concatenate([jnp.asarray(p.edge_src_label) for p in plans])
    edl = jnp.concatenate([jnp.asarray(p.edge_dst_label) for p in plans])
    eel = jnp.concatenate([jnp.asarray(p.edge_edge_label) for p in plans])
    offsets = np.cumsum([0] + [p.query.n_edges for p in plans])
    windows = [p.window for p in plans]

    def tick(mstate: MultiEngineState, batch: EdgeBatch, watermark=None):
        em_all = edge_match_mask(batch, esl, edl, eel)
        states, results = [], []
        for qi, body in enumerate(bodies):
            # an inactive query sees an all-invalid batch: no appends, no
            # stats drift (edges processed/discarded), frozen t_now —
            # which the watermark clock preserves by construction: an
            # all-invalid batch has max batch ts = INT32_MIN, and
            # min(watermark, that) never advances t_now
            act = mstate.active[qi]
            b_q = batch._replace(valid=batch.valid & act)
            em = em_all[offsets[qi]:offsets[qi + 1]] & act
            s, r = body(mstate.queries[qi], b_q, em, windows[qi],
                        watermark=watermark)
            states.append(s)
            results.append(r)
        return mstate._replace(queries=tuple(states)), tuple(results)

    return tick


# --------------------------------------------------------------------- #
# Homogeneous padded slots: build_slot_tick
# --------------------------------------------------------------------- #
class SlotParams(NamedTuple):
    """Runtime per-slot query data (everything non-structural)."""

    esl: jnp.ndarray     # int32 [S, n_qedges] query-edge src-vertex labels
    edl: jnp.ndarray     # int32 [S, n_qedges] dst-vertex labels
    eel: jnp.ndarray     # int32 [S, n_qedges] edge labels (-1 wildcard)
    window: jnp.ndarray  # int32 [S] sliding-window span per slot
    active: jnp.ndarray  # bool  [S]


class SlotState(NamedTuple):
    """State of one padded slot group: stacked engines + slot params."""

    engines: EngineState  # every leaf has a leading [S] slot axis
    params: SlotParams


def stack_states(states: Sequence[EngineState]) -> EngineState:
    """Stack homogeneous EngineStates along a new leading slot axis."""
    return jax.tree.map(lambda *xs: jnp.stack(xs), *states)


def init_slot_state(template_plan: ExecutionPlan, n_slots: int,
                    prefix_depth: int = 0) -> SlotState:
    nq = template_plan.query.n_edges
    return SlotState(
        engines=stack_states(
            [init_state(template_plan, prefix_depth)] * n_slots),
        params=SlotParams(
            esl=jnp.zeros((n_slots, nq), I32),
            edl=jnp.zeros((n_slots, nq), I32),
            eel=jnp.full((n_slots, nq), -1, I32),
            window=jnp.full((n_slots,), template_plan.window, I32),
            active=jnp.zeros((n_slots,), jnp.bool_),
        ),
    )


def write_slot(sstate: SlotState, template_plan: ExecutionPlan, k: int,
               plan: ExecutionPlan,
               empty: EngineState | None = None) -> SlotState:
    """Arm slot ``k`` with ``plan``'s labels/window; reset its tables.

    ``plan`` must share ``template_plan``'s structural signature
    (``repro.core.registry.plan_signature``) — the caller (service)
    guarantees this by construction.  Pure data writes: no recompile.
    Pass a cached ``empty = init_state(template_plan)`` to avoid
    re-materializing the full-capacity empty tables per churn event.
    """
    if empty is None:
        empty = init_state(template_plan)
    p = sstate.params
    return SlotState(
        engines=jax.tree.map(
            lambda full, e: full.at[k].set(e),
            sstate.engines, empty),
        params=SlotParams(
            esl=p.esl.at[k].set(jnp.asarray(plan.edge_src_label)),
            edl=p.edl.at[k].set(jnp.asarray(plan.edge_dst_label)),
            eel=p.eel.at[k].set(jnp.asarray(plan.edge_edge_label)),
            window=p.window.at[k].set(plan.window),
            active=p.active.at[k].set(True),
        ),
    )


def clear_slot(sstate: SlotState, template_plan: ExecutionPlan, k: int,
               empty: EngineState | None = None) -> SlotState:
    """Disarm slot ``k`` (unregister): deactivate + drop its tables."""
    if empty is None:
        empty = init_state(template_plan)
    return SlotState(
        engines=jax.tree.map(
            lambda full, e: full.at[k].set(e),
            sstate.engines, empty),
        params=sstate.params._replace(
            active=sstate.params.active.at[k].set(False)),
    )


def read_slot(sstate: SlotState, k: int) -> EngineState:
    """Unstack slot ``k``'s engine state (host-side result extraction)."""
    return jax.tree.map(lambda x: x[k], sstate.engines)


def build_slot_tick(
    template_plan: ExecutionPlan,
    backend: str = J.JoinBackend.REF,
    extract_matches: bool = True,
    max_out: int | None = None,
    prefix_depth: int = 0,
):
    """Compile a padded-slot tick for one structural template.

    Returns ``tick(sstate, batch) -> (sstate, results)`` where
    ``results`` is a ``TickResult`` whose leaves carry a leading slot
    axis.  The label-match phase evaluates all slots' masks in one shot
    from the stacked ``[S, n_qedges]`` label arrays; the structural body
    is vmapped over slots.  Inactive slots process nothing (their mask
    is zeroed) and their tables stay empty.

    With ``prefix_depth > 0`` (cross-tenant prefix sharing,
    ``repro.core.share``) the tick signature becomes ``tick(sstate,
    batch, prefix_view)``: every slot consumes the SAME shared prefix
    table view (vmap-broadcast), and the per-slot bodies run only the
    suffix joins.  Results and stats of unarmed slots are masked — the
    shared view is nonzero input even for slots that hold no tenant.

    Both variants accept a trailing ``watermark=None``: ``None`` keeps
    the legacy max-ts clock, a traced int32 scalar switches every slot
    to event-time admission/expiry (``repro.core.engine.NO_WATERMARK``
    is the traced "unknown" sentinel).  The watermark is vmap-broadcast;
    unarmed slots stay frozen because their all-invalid batch caps the
    clock advance at INT32_MIN.
    """
    body = build_tick_body(template_plan, backend=backend,
                           extract_matches=extract_matches, max_out=max_out,
                           prefix_depth=prefix_depth)

    if prefix_depth == 0:
        def one(engine, batch, esl, edl, eel, window, active, watermark):
            # unarmed slots see an all-invalid batch (no stats drift,
            # frozen t_now) in addition to the zeroed match mask; the
            # watermark clock keeps the freeze for free — an all-invalid
            # batch's max ts is INT32_MIN and min(watermark, ·) cannot
            # advance t_now, so no per-slot watermark masking is needed
            b_s = batch._replace(valid=batch.valid & active)
            em = edge_match_mask(b_s, esl, edl, eel) & active
            return body(engine, b_s, em, window, watermark=watermark)

        # a None watermark is an empty pytree, so the broadcast in_axes
        # serves both the legacy (None) and event-time (scalar) modes —
        # jit retraces once per mode, never per value
        vbody = jax.vmap(one, in_axes=(0, None, 0, 0, 0, 0, 0, None))

        def tick(sstate: SlotState, batch: EdgeBatch, watermark=None):
            p = sstate.params
            engines, results = vbody(
                sstate.engines, batch, p.esl, p.edl, p.eel, p.window,
                p.active, watermark)
            return sstate._replace(engines=engines), results

        return tick

    def one(engine, batch, esl, edl, eel, window, active, prefix_view,
            watermark):
        b_s = batch._replace(valid=batch.valid & active)
        em = edge_match_mask(b_s, esl, edl, eel) & active
        s, r = body(engine, b_s, em, window, prefix_view,
                    watermark=watermark)
        # a fully-shared subquery 0 feeds every slot the shared rows, so
        # unarmed slots must mask their outputs AND their stats (the
        # zeroed batch alone no longer freezes them)
        s = s._replace(stats=jax.tree.map(
            lambda new, old: jnp.where(active, new, old),
            s.stats, engine.stats))
        r = r._replace(
            n_new_matches=jnp.where(active, r.n_new_matches, 0),
            n_overflow=jnp.where(active, r.n_overflow, 0),
            match_valid=r.match_valid & active)
        return s, r

    vbody = jax.vmap(one, in_axes=(0, None, 0, 0, 0, 0, 0, None, None))

    def tick(sstate: SlotState, batch: EdgeBatch, prefix_view,
             watermark=None):
        p = sstate.params
        engines, results = vbody(
            sstate.engines, batch, p.esl, p.edl, p.eel, p.window,
            p.active, prefix_view, watermark)
        return sstate._replace(engines=engines), results

    return tick


# --------------------------------------------------------------------- #
# Compiled-tick cache: one build + jit per structural signature
# --------------------------------------------------------------------- #
class SlotTickCache:
    """Process-wide cache of compiled slot ticks, keyed by structure.

    ``build_slot_tick`` closes over only *structural* plan data (that is
    the whole point of ``plan_signature``), so ONE compiled — and, with
    ``jit=True``, jitted — tick can serve every slot group, in every
    ``ContinuousSearchService`` instance, whose template shares a
    signature.  Two consequences:

    * a group that overflows into a sibling group reuses the compiled
      tick instead of rebuilding an identical one;
    * a service restored after a crash (``ContinuousSearchService.
      restore``) re-arms all of its groups with cache *hits*: zero
      recompiles for structures this process has already served, and the
      shared jitted tick keeps its XLA trace cache, so the first
      post-restore batch of an already-seen shape does not retrace.

    ``donate=True`` jits with ``donate_argnums=(0,)``: the previous
    ``SlotState`` buffers are donated to each tick, so steady-state
    serving updates slot tables in place instead of copying them every
    tick (callers must treat the passed-in state as consumed — the
    service does).

    The cache is LRU-bounded (``max_entries``) so a long-lived server
    seeing many distinct structures over its lifetime does not leak
    compiled ticks without limit.  Eviction is always safe: live slot
    groups hold their own reference to their tick, so an evicted entry
    only means the NEXT group of that structure rebuilds.
    """

    def __init__(self, max_entries: int = 256):
        self.max_entries = max_entries
        self._ticks: dict[tuple, object] = {}   # insertion-ordered (LRU)
        self.n_builds = 0        # build_slot_tick invocations (cache misses)

    def __len__(self) -> int:
        return len(self._ticks)

    def ticks(self) -> list:
        """The cached (possibly jitted) tick callables."""
        return list(self._ticks.values())

    def _get(self, key, builder, jit: bool, donate: bool):
        tick = self._ticks.pop(key, None)
        if tick is None:
            tick = builder()
            if jit:
                tick = jax.jit(
                    tick, donate_argnums=(0,) if donate else ())
            self.n_builds += 1
        self._ticks[key] = tick                 # (re)insert at LRU tail
        while len(self._ticks) > self.max_entries:
            self._ticks.pop(next(iter(self._ticks)))
        return tick

    def get(
        self,
        template_plan: ExecutionPlan,
        backend: str = J.JoinBackend.REF,
        extract_matches: bool = True,
        max_out: int | None = None,
        jit: bool = True,
        donate: bool = False,
        prefix_depth: int = 0,
    ):
        from repro.core.registry import plan_signature

        key = (plan_signature(template_plan), backend, extract_matches,
               max_out, jit, donate, prefix_depth)
        return self._get(
            key,
            lambda: build_slot_tick(
                template_plan, backend=backend,
                extract_matches=extract_matches, max_out=max_out,
                prefix_depth=prefix_depth),
            jit, donate)

    def get_mesh(
        self,
        template_plan: ExecutionPlan,
        mesh,                                    # jax.sharding.Mesh
        slots_per_replica: int,
        backend: str = J.JoinBackend.REF,
        extract_matches: bool = True,
        max_out: int | None = None,
        donate: bool = True,
        prefix_depth: int = 0,
    ):
        """Compiled mesh slot tick (``repro.runtime.mesh``): the slot
        axis sharded over the mesh's replica axis.  Keyed by structure
        PLUS mesh identity (device ids + per-replica slot count), so a
        service restored onto the same mesh re-arms with cache hits —
        zero rebuilds, and the shared jitted tick keeps its XLA trace
        cache per replica."""
        from repro.core.registry import plan_signature
        from repro.runtime.mesh import build_mesh_slot_tick

        mesh_key = tuple(d.id for d in mesh.devices.flat)
        key = ("mesh", plan_signature(template_plan), mesh_key,
               slots_per_replica, backend, extract_matches, max_out,
               donate, prefix_depth)
        # the builder jits internally (one jit per watermark mode), so
        # _get must not wrap it again
        return self._get(
            key,
            lambda: build_mesh_slot_tick(
                template_plan, mesh, backend=backend,
                extract_matches=extract_matches, max_out=max_out,
                donate=donate, prefix_depth=prefix_depth),
            jit=False, donate=False)

    def get_node(
        self,
        spec,                                   # repro.core.share.NodeSpec
        backend: str = J.JoinBackend.REF,
        jit: bool = True,
        donate: bool = False,
    ):
        """Compiled prefix-node tick for one structural ``NodeSpec``
        (the forest's half of the cache's prefix dimension).  Labels and
        window are runtime inputs, so one entry serves every node of
        that structure — and restores re-arm forests with cache hits."""
        from repro.core.share import build_node_tick

        key = ("prefix_node", spec, backend, jit, donate)
        return self._get(
            key,
            lambda: build_node_tick(spec, backend=backend),
            jit, donate)

    def clear(self):
        self._ticks.clear()


GLOBAL_SLOT_TICK_CACHE = SlotTickCache()
