"""SJ-tree baseline (Choudhury et al., EDBT 2015) with timing post-filter.

The paper's main competitor: a left-deep subgraph-join tree that
maintains partial matches per node but (a) ignores timing constraints
during maintenance (post-processing filter only, as §6.3 describes) and
therefore (b) cannot prune discardable partial matches.

We express it through the same engine substrate: compile the plan against
a *prec-stripped* copy of the query — every edge becomes its own
singleton "TC-subquery", so each leaf stores all label-matching edges and
the left-deep internal nodes are exactly our L0 join chain — then filter
the emitted matches by the original timing order on the way out.  The
space blow-up relative to the timing-aware engine is the paper's headline
comparison (Figures 14-17).
"""

from __future__ import annotations

import numpy as np

from repro.core.decompose import TCSubquery
from repro.core.plan import ExecutionPlan, compile_plan
from repro.core.query import QueryGraph


def strip_timing(q: QueryGraph) -> QueryGraph:
    return QueryGraph(
        n_vertices=q.n_vertices,
        vertex_labels=q.vertex_labels,
        edges=q.edges,
        edge_labels=q.edge_labels,
        prec=frozenset(),
    )


def _prefix_connected_singleton_order(q: QueryGraph) -> list[TCSubquery]:
    """Left-deep leaf order: any prefix-connected permutation of edges."""
    order: list[int] = [0]
    bound = set(q.edges[0])
    remaining = set(range(1, q.n_edges))
    while remaining:
        nxt = next(
            e for e in sorted(remaining) if set(q.edges[e]) & bound
        )
        order.append(nxt)
        bound |= set(q.edges[nxt])
        remaining.discard(nxt)
    return [TCSubquery(frozenset({e}), (e,)) for e in order]


def compile_sjtree_plan(
    q: QueryGraph,
    window: int,
    level_capacity: int = 4096,
    l0_capacity: int = 4096,
    max_new: int = 1024,
) -> tuple[ExecutionPlan, np.ndarray]:
    """Returns (plan over prec-stripped query, postfilter TREL).

    The postfilter TREL is an int8 [ne, ne] matrix over the plan's final
    edge layout: entry (i, j) == -1 requires ts_i < ts_j (the ORIGINAL
    query's timing order).  Apply with ``timing_postfilter``.
    """
    qs = strip_timing(q)
    decomp = _prefix_connected_singleton_order(qs)
    plan = compile_plan(
        qs, window, decomposition=decomp,
        level_capacity=level_capacity, l0_capacity=l0_capacity,
        max_new=max_new)
    layout = plan.final_edge_layout
    ne = len(layout)
    trel = np.zeros((ne, ne), np.int8)
    for i, ei in enumerate(layout):
        for j, ej in enumerate(layout):
            if q.precedes(ei, ej):
                trel[i, j] = -1
    return plan, trel


def timing_postfilter(ets: np.ndarray, valid: np.ndarray, trel: np.ndarray):
    """Filter emitted matches by the original timing order (host-side)."""
    ok = valid.copy()
    ne = trel.shape[0]
    for i in range(ne):
        for j in range(ne):
            if trel[i, j] == -1:
                ok &= ets[:, i] < ets[:, j]
    return ok
