"""JAX version compatibility shims shared across the tree.

The only dance currently needed is ``shard_map``: newer JAX exposes it
as ``jax.shard_map`` and renamed the replication-check kwarg from
``check_rep`` to ``check_vma``; older versions only have
``jax.experimental.shard_map.shard_map``.  Both ``core.distributed``
(capacity-axis sharding) and ``runtime.mesh`` (slot/tenant-axis
sharding) need the same resolution, so it lives here exactly once.
"""

from __future__ import annotations

import inspect

import jax

shard_map = getattr(jax, "shard_map", None)
if shard_map is None:  # pragma: no cover - version dependent
    from jax.experimental.shard_map import shard_map


def shard_map_compat_kwargs() -> dict:
    """Kwargs disabling the replication checker, whatever it is called.

    Our shard-mapped ticks mix replicated outputs (psum-reduced stats)
    with sharded outputs (per-slot tables), which older checkers reject
    spuriously; probe the signature instead of pinning a JAX version.
    """
    try:
        params = inspect.signature(shard_map).parameters
    except (TypeError, ValueError):  # pragma: no cover - builtins/C fns
        return {}
    for name in ("check_vma", "check_rep"):
        if name in params:
            return {name: False}
    return {}
