"""TC-subquery enumeration, decomposition and join-order selection.

Implements the paper's query-compilation pipeline:

* ``tc_subqueries``  — Algorithm 5: enumerate all TC-subqueries of Q by
  dynamic programming over timing-chained, prefix-connected sequences.
* ``decompose``      — Algorithm 6: greedy minimum-cardinality cover of Q
  by edge-disjoint TC-subqueries (cost model of Theorem 5: the expected
  number of join operations per incoming edge grows with |D|, so |D| is
  minimized).
* ``join_order``     — Section 5.6: prefix-connected permutation of the
  decomposition maximizing the joint number (Definition 14) at each step.

All of this is host-side and runs once per continuous query registration.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.core.query import QueryGraph


@dataclass(frozen=True)
class TCSubquery:
    """A TC-subquery: an edge set plus one witness timing sequence."""

    edge_ids: frozenset[int]
    timing_sequence: tuple[int, ...]

    def __len__(self) -> int:
        return len(self.edge_ids)


def tc_subqueries(q: QueryGraph, max_enum: int = 200_000) -> list[TCSubquery]:
    """Algorithm 5: all TC-subqueries of ``q``.

    Iterative DFS (an explicit LIFO stack — ``queue.pop()`` takes the
    most recently pushed sequence) over timing sequences: a sequence
    ``(e_1..e_j)`` extends to ``(e_1..e_j, e_x)`` iff ``e_j ≺ e_x`` and
    ``e_x`` is adjacent to some edge already in the sequence
    (prefix-connectivity).  Dedups by edge *set*, keeping the first
    witness sequence found.

    The traversal order is deterministic and LOAD-BEARING: the
    first-witness sequence chosen for each edge set flows into
    ``plan_signature`` (slot-group sharing) and into checkpoint
    manifests (``plan_decomposition``), so changing the order — e.g.
    switching to the BFS the paper's prose suggests — would silently
    invalidate cross-process sharing and restored checkpoints.
    ``tests/test_query.py::test_tc_subquery_enumeration_deterministic``
    pins the exact enumeration for the paper's Figure-2 query.
    """
    seen_sets: dict[frozenset[int], tuple[int, ...]] = {}
    queue: list[tuple[int, ...]] = [(e,) for e in range(q.n_edges)]
    n_enum = 0
    while queue:
        seq = queue.pop()
        n_enum += 1
        if n_enum > max_enum:
            raise RuntimeError(
                f"TC-subquery enumeration exceeded {max_enum} sequences; "
                "query precedence structure too dense — supply a manual "
                "decomposition via plan.compile_plan(decomposition=...)"
            )
        eset = frozenset(seq)
        if eset not in seen_sets:
            seen_sets[eset] = seq
        last = seq[-1]
        for ex in range(q.n_edges):
            if ex in eset:
                continue
            if not q.precedes(last, ex):
                continue
            if not any(q.edges_adjacent(ex, e) for e in seq):
                continue
            new_set = eset | {ex}
            if new_set in seen_sets:
                continue
            queue.append(seq + (ex,))
    return [TCSubquery(s, wit) for s, wit in seen_sets.items()]


def decompose(q: QueryGraph) -> list[TCSubquery]:
    """Algorithm 6: greedy edge-disjoint cover of Q by TC-subqueries.

    Repeatedly picks the largest remaining TC-subquery that is edge-
    disjoint from everything already chosen.  Single edges are always
    TC-subqueries, so a cover always exists.
    """
    if not q.is_connected():
        raise ValueError("query graph must be connected")
    pool = sorted(
        tc_subqueries(q),
        key=lambda t: (-len(t), t.timing_sequence),
    )
    chosen: list[TCSubquery] = []
    covered: set[int] = set()
    for cand in pool:
        if covered >= set(range(q.n_edges)):
            break
        if cand.edge_ids & covered:
            continue
        chosen.append(cand)
        covered |= cand.edge_ids
    assert covered == set(range(q.n_edges)), "greedy cover failed to cover Q"
    return chosen


# ---------------------------------------------------------------------- #
def joint_number(q: QueryGraph, a_edges: frozenset[int], b_edges: frozenset[int]) -> int:
    """Definition 14: |common vertices| + |timing-related edge pairs|."""
    va = set(q.vertices_of(a_edges))
    vb = set(q.vertices_of(b_edges))
    n_v = len(va & vb)
    n_t = sum(
        1
        for ea in a_edges
        for eb in b_edges
        if q.precedes(ea, eb) or q.precedes(eb, ea)
    )
    return n_v + n_t


def _connected_to(q: QueryGraph, union_vs: set[int], cand: TCSubquery) -> bool:
    return bool(union_vs & set(q.vertices_of(cand.edge_ids)))


def join_order(q: QueryGraph, decomposition: list[TCSubquery]) -> list[TCSubquery]:
    """Section 5.6: prefix-connected order over D maximizing joint number.

    Greedy: the first two TC-subqueries are the connected pair with the
    largest joint number; each next pick is the TC-subquery connected to
    the union with the largest joint number against the union.
    """
    d = list(decomposition)
    if len(d) == 1:
        return d
    best_pair = None
    best_jn = -1
    for i in range(len(d)):
        for j in range(i + 1, len(d)):
            vi = set(q.vertices_of(d[i].edge_ids))
            vj = set(q.vertices_of(d[j].edge_ids))
            if not (vi & vj):
                continue
            jn = joint_number(q, d[i].edge_ids, d[j].edge_ids)
            if jn > best_jn:
                best_jn, best_pair = jn, (i, j)
    if best_pair is None:
        raise ValueError("decomposition is not connectable — query disconnected?")
    i, j = best_pair
    ordered = [d[i], d[j]]
    remaining = [t for k, t in enumerate(d) if k not in (i, j)]
    union_edges = set(d[i].edge_ids | d[j].edge_ids)
    while remaining:
        union_vs = set(q.vertices_of(union_edges))
        best_k, best_jn = None, -1
        for k, cand in enumerate(remaining):
            if not _connected_to(q, union_vs, cand):
                continue
            jn = joint_number(q, frozenset(union_edges), cand.edge_ids)
            if jn > best_jn:
                best_jn, best_k = jn, k
        if best_k is None:
            raise ValueError("no prefix-connected extension found")
        ordered.append(remaining.pop(best_k))
        union_edges |= ordered[-1].edge_ids
    return ordered


def expected_join_ops(q: QueryGraph, k: int) -> float:
    """Theorem 5 cost model: N = (|E(Q)| - 1 + k(k-1)/2) / d."""
    d = max(1, q.n_distinct_edge_labels())
    return (q.n_edges - 1 + k * (k - 1) / 2) / d
