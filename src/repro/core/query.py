"""Query graph with timing-order constraints (paper Definitions 1-5).

A query is a directed, vertex-labelled (optionally edge-labelled) graph
plus a strict partial order ``prec`` over its edges: ``(i, j) in prec``
means a data edge matching query edge ``i`` must carry a strictly smaller
timestamp than the data edge matching query edge ``j`` (Definition 3/4).

Everything in this module is host-side query *compilation* state: plain
Python / numpy, hashable, and cheap.  The device engine never sees these
objects — it sees the numeric ``ExecutionPlan`` compiled from them.
"""

from __future__ import annotations

import itertools
from dataclasses import dataclass, field


def _transitive_closure(n_edges: int, prec: frozenset[tuple[int, int]]) -> frozenset[tuple[int, int]]:
    """Floyd-Warshall style closure of the strict order over edge ids."""
    reach = [[False] * n_edges for _ in range(n_edges)]
    for i, j in prec:
        reach[i][j] = True
    for k in range(n_edges):
        rk = reach[k]
        for i in range(n_edges):
            if reach[i][k]:
                ri = reach[i]
                for j in range(n_edges):
                    if rk[j]:
                        ri[j] = True
    return frozenset(
        (i, j) for i in range(n_edges) for j in range(n_edges) if reach[i][j]
    )


@dataclass(frozen=True)
class QueryGraph:
    """Immutable query graph (Definition 3).

    Attributes
    ----------
    n_vertices:     number of query vertices (ids ``0..n_vertices-1``).
    vertex_labels:  label id per vertex.
    edges:          ``(src_vertex, dst_vertex)`` per query edge.
    edge_labels:    label id per query edge; ``WILDCARD`` matches any.
    prec:           strict partial order over edge ids, stored transitively
                    closed.  ``(i, j)``: edge i must precede edge j.
    """

    WILDCARD = -1

    n_vertices: int
    vertex_labels: tuple[int, ...]
    edges: tuple[tuple[int, int], ...]
    edge_labels: tuple[int, ...] = ()
    prec: frozenset[tuple[int, int]] = field(default_factory=frozenset)

    def __post_init__(self):
        if len(self.vertex_labels) != self.n_vertices:
            raise ValueError("vertex_labels length mismatch")
        if not self.edge_labels:
            object.__setattr__(
                self, "edge_labels", tuple(self.WILDCARD for _ in self.edges)
            )
        if len(self.edge_labels) != len(self.edges):
            raise ValueError("edge_labels length mismatch")
        for (u, v) in self.edges:
            if not (0 <= u < self.n_vertices and 0 <= v < self.n_vertices):
                raise ValueError(f"edge endpoint out of range: {(u, v)}")
            if u == v:
                raise ValueError("self-loops in query graphs are not supported")
        if len(set(self.edges)) != len(self.edges):
            raise ValueError("parallel duplicate query edges are not supported")
        closed = _transitive_closure(self.n_edges, frozenset(self.prec))
        for i, j in closed:
            if (j, i) in closed or i == j:
                raise ValueError("timing order is not a strict partial order")
        object.__setattr__(self, "prec", closed)

    # ------------------------------------------------------------------ #
    @property
    def n_edges(self) -> int:
        return len(self.edges)

    def precedes(self, i: int, j: int) -> bool:
        """True iff edge i must come strictly before edge j."""
        return (i, j) in self.prec

    def preq(self, eid: int) -> frozenset[int]:
        """Prerequisite edge set of ``eid`` (Definition 6): {e' ≺ e} ∪ {e}."""
        return frozenset(
            i for i in range(self.n_edges) if self.precedes(i, eid)
        ) | {eid}

    # ------------------------------------------------------------------ #
    def edges_adjacent(self, i: int, j: int) -> bool:
        """Two query edges are connected iff they share an endpoint (Def. 1)."""
        a, b = self.edges[i], self.edges[j]
        return bool(set(a) & set(b))

    def subquery_connected(self, edge_ids: tuple[int, ...]) -> bool:
        """Connectivity of the subquery induced by ``edge_ids``."""
        if not edge_ids:
            return False
        remaining = set(edge_ids)
        frontier = {edge_ids[0]}
        remaining.discard(edge_ids[0])
        while frontier:
            nxt = {
                e for e in remaining
                if any(self.edges_adjacent(e, f) for f in frontier)
            }
            remaining -= nxt
            frontier = nxt
        return not remaining

    def is_connected(self) -> bool:
        return self.subquery_connected(tuple(range(self.n_edges)))

    # ------------------------------------------------------------------ #
    def is_prefix_connected(self, seq: tuple[int, ...]) -> bool:
        """Definition 9: every prefix of ``seq`` induces a connected subquery."""
        bound: set[int] = set()
        for k, e in enumerate(seq):
            u, v = self.edges[e]
            if k > 0 and not ({u, v} & bound):
                return False
            bound.update((u, v))
        return True

    def is_timing_sequence(self, seq: tuple[int, ...]) -> bool:
        """Definition 10: prefix-connected AND consecutive edges chained by ≺."""
        if not self.is_prefix_connected(seq):
            return False
        return all(self.precedes(seq[k], seq[k + 1]) for k in range(len(seq) - 1))

    def is_tc_query(self) -> bool:
        """Exhaustive check (exponential; for tests / tiny queries only)."""
        return any(
            self.is_timing_sequence(perm)
            for perm in itertools.permutations(range(self.n_edges))
        )

    # ------------------------------------------------------------------ #
    def to_spec(self) -> dict:
        """JSON-serializable description (checkpoint manifests round-trip
        registered queries through this)."""
        return {
            "n_vertices": self.n_vertices,
            "vertex_labels": list(self.vertex_labels),
            "edges": [list(e) for e in self.edges],
            "edge_labels": list(self.edge_labels),
            "prec": sorted(list(p) for p in self.prec),
        }

    @classmethod
    def from_spec(cls, spec: dict) -> "QueryGraph":
        """Inverse of ``to_spec`` (prec re-closes transitively, a no-op
        for specs produced by ``to_spec``)."""
        return cls(
            n_vertices=int(spec["n_vertices"]),
            vertex_labels=tuple(int(v) for v in spec["vertex_labels"]),
            edges=tuple((int(u), int(v)) for u, v in spec["edges"]),
            edge_labels=tuple(int(l) for l in spec["edge_labels"]),
            prec=frozenset((int(i), int(j)) for i, j in spec["prec"]),
        )

    # ------------------------------------------------------------------ #
    def vertices_of(self, edge_ids) -> tuple[int, ...]:
        """Sorted vertex ids touched by ``edge_ids``."""
        vs: set[int] = set()
        for e in edge_ids:
            vs.update(self.edges[e])
        return tuple(sorted(vs))

    def n_distinct_edge_labels(self) -> int:
        return len(set(self.edge_labels))


# ---------------------------------------------------------------------- #
def example_paper_query() -> QueryGraph:
    """The running example of the paper (Figure 4), reconstructed from the
    §5.5 TCsub listing.

    Timing order (paper's 1-based ids): ε3 ≺ ε1 ≺ ε2 and ε6 ≺ ε5 ≺ ε4.
    Structure chosen so that TCsub(Q) is exactly the paper's ten entries
    — {ε6,ε5,ε4}, {ε3,ε1}, {ε5,ε4}, {ε6,ε5} and the six singletons —
    which requires ε3/ε1 adjacent but ε1/ε2 NOT adjacent.  The resulting
    decomposition is the paper's {{ε6,ε5,ε4}, {ε3,ε1}, {ε2}} (Figure 7).
    """
    #       v0 v1 v2 v3 v4
    labels = (0, 1, 2, 3, 4)
    edges = (
        (0, 1),  # ε1
        (2, 3),  # ε2 (not adjacent to ε1)
        (4, 0),  # ε3 (shares v0 with ε1)
        (1, 2),  # ε4
        (3, 1),  # ε5 (shares v1 with ε4, v3 with ε6)
        (4, 3),  # ε6
    )
    prec = frozenset({(2, 0), (0, 1), (5, 4), (4, 3)})
    return QueryGraph(5, labels, edges, prec=prec)
