"""Straggler mitigation for the streaming engine: adaptive tick coalescing.

On a pod, the tick latency is (join compute + delta all-gathers); a slow
shard (straggler) delays the barrier.  The paper's single-node answer is
more threads; the distributed answer is *backpressure-aware batching*:
if arrival rate exceeds tick throughput (queue depth grows), coalesce
more edges per tick — per-edge cost falls roughly linearly in batch
size until table-join compute dominates (see benchmarks/bench_concurrency).

``TickCoalescer`` is a tiny AIMD controller over the tick batch size,
mirroring how production stream processors (Flink/Dataflow) adapt bundle
sizes.  Host-side logic: deterministic given its input trace, unit-tested.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass
class TickCoalescer:
    min_batch: int = 32
    max_batch: int = 4096
    target_latency_ms: float = 50.0
    batch: int = 256
    _ema_latency: float = 0.0

    def record(self, tick_latency_ms: float, queue_depth: int) -> int:
        """Report the last tick; returns the batch size for the next one."""
        a = 0.3
        self._ema_latency = (1 - a) * self._ema_latency + a * tick_latency_ms
        if queue_depth > 2 * self.batch and \
                self._ema_latency < self.target_latency_ms:
            self.batch = min(self.max_batch, self.batch * 2)   # MI
        elif self._ema_latency > self.target_latency_ms:
            self.batch = max(self.min_batch, int(self.batch * 0.8))  # AD
        return self.batch
