"""Straggler mitigation for the streaming engine: adaptive tick coalescing.

On a pod, the tick latency is (join compute + delta all-gathers); a slow
shard (straggler) delays the barrier.  The paper's single-node answer is
more threads; the distributed answer is *backpressure-aware batching*:
if arrival rate exceeds tick throughput (queue depth grows), coalesce
more edges per tick — per-edge cost falls roughly linearly in batch
size until table-join compute dominates (see benchmarks/bench_concurrency).

``TickCoalescer`` is a tiny AIMD controller over the tick batch size,
mirroring how production stream processors (Flink/Dataflow) adapt bundle
sizes.  Host-side logic: deterministic given its input trace, unit- and
property-tested (tests/test_straggler_props.py).  The serving loop
(``ContinuousSearchService.serve_stream``) feeds it the per-tick
barrier latency — slot groups dispatch asynchronously and meet at one
barrier, so the slowest group inherently sets the pace — with
``quantize_pow2`` bounding how many distinct padded batch shapes (and
therefore jit specializations) the adaptive sizes can produce.  It also
feeds the tick's engine overflow count (``ServeInfo.n_overflow``): a
tick that dropped appends gets the batch halved regardless of latency,
closing the capacity-backpressure loop at the serve-loop level.
"""

from __future__ import annotations

import dataclasses


def quantize_pow2(n: int, lo: int = 8) -> int:
    """Round a chunk length up to the next power of two, at least ``lo``.

    Adaptive coalescing produces arbitrary chunk lengths; padding each to
    the next power of two keeps the set of batch shapes (and thus jit
    specializations per compiled tick) logarithmic in the batch range.
    """
    n = max(int(n), 1)
    return max(lo, 1 << (n - 1).bit_length())


@dataclasses.dataclass
class TickCoalescer:
    min_batch: int = 32
    max_batch: int = 4096
    target_latency_ms: float = 50.0
    batch: int = 256
    _ema_latency: float = 0.0
    # last decision taken by record()/record_idle(), for observability
    # ("overflow_md" | "queue_mi" | "latency_ad" | "hold" | "idle");
    # the serve loop mirrors it into the obs registry — the coalescer
    # itself stays dependency-free
    last_action: str = "hold"

    def __post_init__(self):
        if not (0 < self.min_batch <= self.max_batch):
            raise ValueError(
                f"need 0 < min_batch <= max_batch, got "
                f"{self.min_batch}..{self.max_batch}")
        self.batch = min(max(self.batch, self.min_batch), self.max_batch)

    @classmethod
    def seeded(cls, batch: int, min_batch: int | None = None,
               max_batch: int | None = None,
               target_latency_ms: float = 50.0) -> "TickCoalescer":
        """Coalescer that honors ``batch`` as the starting size: unset
        bounds are widened around it instead of clamping it to the
        dataclass defaults (so a small requested batch is served as
        requested, and a lone ``max_batch`` below the default
        ``min_batch`` cannot conflict)."""
        if max_batch is None:
            max_batch = max(cls.max_batch, batch)
        if min_batch is None:
            min_batch = min(cls.min_batch, batch, max_batch)
        return cls(batch=batch, min_batch=min_batch, max_batch=max_batch,
                   target_latency_ms=target_latency_ms)

    def record(self, tick_latency_ms: float, queue_depth: int,
               n_overflow: int = 0) -> int:
        """Report the last tick; returns the batch size for the next one.

        ``n_overflow`` is the tick's dropped-append count (``ServeInfo.
        n_overflow``): a non-zero value means the chunk produced more
        candidate partial matches than the fixed tables could absorb, so
        the controller halves the batch immediately — a capacity signal
        stronger than the latency AD step, and one that fires even when
        the tick is FAST (small tables overflow quickly and cheaply).
        Latency-based MI never overrides it within the same tick.
        """
        a = 0.3
        self._ema_latency = (1 - a) * self._ema_latency + a * tick_latency_ms
        if n_overflow > 0:
            self.batch = max(self.min_batch, self.batch // 2)  # capacity MD
            self.last_action = "overflow_md"
        elif queue_depth > 2 * self.batch and \
                self._ema_latency < self.target_latency_ms:
            self.batch = min(self.max_batch, self.batch * 2)   # MI
            self.last_action = "queue_mi"
        elif self._ema_latency > self.target_latency_ms:
            self.batch = max(self.min_batch, int(self.batch * 0.8))  # AD
            self.last_action = "latency_ad"
        else:
            self.last_action = "hold"
        return self.batch

    def record_idle(self) -> int:
        """Report an EMPTY serving round (watermark-driven serving:
        sources stalled or the reorder buffer is holding everything
        back, so there was no tick).  The batch must not move — idle
        rounds carry no latency or queue signal, and growing on them
        would let a stalled stream inflate the batch unboundedly — but
        the latency EMA decays toward zero so a long stall does not
        leave a stale overload reading that would shrink the batch on
        the first real tick afterwards.
        """
        self._ema_latency *= 0.7
        self.last_action = "idle"
        return self.batch
