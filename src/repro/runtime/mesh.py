"""Replica-sharded multi-tenant serving: the slot (tenant) axis on a mesh.

``repro.core.distributed`` shards ONE engine's capacity axis across
devices — the scale-up story for a single huge query.  This module is
the scale-OUT story for the serving layer: a ``ShardedSearchService``
keeps the whole ``ContinuousSearchService`` contract (register /
unregister / ingest / serve_stream / serve_frontier / checkpoint /
restore) but stacks each slot group ``n_replicas x slots_per_replica``
tenants high and shards the SLOT axis over a 1-D device mesh
``("replica",)`` via ``shard_map``:

* every ``SlotState`` leaf is partitioned ``P("replica")`` along its
  leading slot axis, so replica ``r`` owns the contiguous slot block
  ``[r*spr, (r+1)*spr)`` and materializes ONLY those tenants' tables;
* the edge batch is replicated (ingest bandwidth is tiny next to table
  state) and each replica's label scan covers only its own slots'
  ``[spr, n_qedges]`` label tables — the fan-out of the per-edge scan
  is the vmap over the local block, nothing crosses replicas;
* the tick body itself runs with ``axis_name=None`` — tenants are
  independent, so the hot loop has ZERO collectives; the only
  cross-replica traffic is three scalar reductions per tick
  (``MeshTickStats``: matched/overflow psums + a pmax watermark clock);
* a ``PlacementPolicy`` decides which replica each newly registered
  tenant lands on (round-robin, or load-balanced by tenant count and
  ``overflow_pressure``); the slot search inside the chosen replica's
  block is the existing ``_Group.free_slot(lo, hi)``.

Prefix sharing composes: the ``SharedPrefixForest`` node tables are
advanced once OUTSIDE the shard_map and their views enter replicated
(``P()``), exactly like the replicated-view contract of
``build_sharded_tick`` — each replica's suffix joins read the same
shared prefix rows.  ``SharedPrefixForest.replica_refcounts`` splits
each node's refcount by owning replica so checkpoint manifests record
(and restore verifies) the partition.

Checkpoints are sharded: each step writes ``step_N.shard<r>of<R>.npz``
(slot-sharded keys split along axis 0; forest tables + scalars
replicated into shard 0) plus one manifest.  ``restore`` reassembles
host-side, so a checkpoint written on an 8-replica mesh restores onto a
2-replica mesh (or vice versa): same-size meshes re-arm the exact slot
layout with zero recompiles; a different ``n_replicas`` takes the
repack path — every tenant is re-placed by the policy and its engine
table rows are spliced into its new slot (oracle-exact either way,
tests/test_mesh.py).

CPU testing: ``XLA_FLAGS=--xla_force_host_platform_device_count=8``
before importing jax gives an 8-virtual-device host mesh.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.checkpoint import (
    CheckpointError,
    checkpoint_steps,
    load_resolved_manifest,
    restore_checkpoint,
    validate_checkpoint,
)
from repro.core import join as J
from repro.core.compat import (
    shard_map as _shard_map,
    shard_map_compat_kwargs as _shard_map_compat_kwargs,
)
from repro.core.multi import (
    SlotTickCache,
    build_slot_tick,
    init_slot_state,
    write_slot,
)
from repro.core.plan import ExecutionPlan
from repro.core.query import QueryGraph
from repro.core.state import init_state
from repro.runtime.service import ContinuousSearchService, _Group

I32 = jnp.int32


class MeshTickStats(NamedTuple):
    """Per-tick scalar reductions across the replica axis (the mesh
    tick's third output; all int32 scalars, replicated)."""

    n_matches: jnp.ndarray    # psum of new matches over all replicas
    n_overflow: jnp.ndarray   # psum of dropped appends over all replicas
    t_clock: jnp.ndarray      # pmax of every replica's engine clock


# --------------------------------------------------------------------- #
# The sharded slot tick
# --------------------------------------------------------------------- #
def build_mesh_slot_tick(
    template_plan: ExecutionPlan,
    mesh,                                   # jax.sharding.Mesh, 1-D "replica"
    backend: str = J.JoinBackend.REF,
    extract_matches: bool = True,
    max_out: int | None = None,
    donate: bool = True,
    prefix_depth: int = 0,
):
    """Wrap ``build_slot_tick`` in ``shard_map`` over the replica axis.

    The returned callable keeps the slot tick's signature —
    ``tick(sstate, batch, watermark=None)``, or with ``prefix_depth``
    ``tick(sstate, batch, prefix_view, watermark=None)`` — but returns a
    THIRD output, ``MeshTickStats``.  ``sstate`` leaves are partitioned
    ``P("replica")`` along the leading slot axis (total slots =
    ``n_replicas * slots_per_replica``); batch, prefix view and
    watermark are replicated.  Inside the shard each replica runs the
    plain vmapped body over its local slot block — no collectives in the
    tick body, only the closing scalar psum/pmax.

    ``None`` vs traced watermark changes the argument pytree, so the two
    modes are two lazily-jitted shard_map programs behind one Python
    dispatcher (mirroring the single-device tick's one-retrace-per-mode
    behavior; a restored service re-arms with zero warm recompiles
    because ``SlotTickCache.get_mesh`` caches this whole dispatcher).
    """
    inner = build_slot_tick(
        template_plan, backend=backend, extract_matches=extract_matches,
        max_out=max_out, prefix_depth=prefix_depth)
    axis = "replica"
    donate_kw = {"donate_argnums": (0,)} if donate else {}
    compiled: dict[bool, object] = {}

    def _finish(sstate, res):
        stats = MeshTickStats(
            n_matches=jax.lax.psum(
                jnp.sum(res.n_new_matches).astype(I32), axis),
            n_overflow=jax.lax.psum(
                jnp.sum(res.n_overflow).astype(I32), axis),
            t_clock=jax.lax.pmax(jnp.max(sstate.engines.t_now), axis),
        )
        return sstate, res, stats

    def _build(has_wm: bool):
        # sstate/result specs are pytree prefixes: every leaf carries a
        # leading slot axis, partitioned over the replica axis
        state_spec, repl = P(axis), P()
        if prefix_depth == 0:
            if has_wm:
                def fn(sstate, batch, wm):
                    return _finish(*inner(sstate, batch, wm))
                in_specs = (state_spec, repl, repl)
            else:
                def fn(sstate, batch):
                    return _finish(*inner(sstate, batch))
                in_specs = (state_spec, repl)
        else:
            if has_wm:
                def fn(sstate, batch, view, wm):
                    return _finish(*inner(sstate, batch, view, wm))
                in_specs = (state_spec, repl, repl, repl)
            else:
                def fn(sstate, batch, view):
                    return _finish(*inner(sstate, batch, view))
                in_specs = (state_spec, repl, repl)
        out_specs = (state_spec, state_spec,
                     MeshTickStats(repl, repl, repl))
        return jax.jit(
            _shard_map(fn, mesh=mesh, in_specs=in_specs,
                       out_specs=out_specs,
                       **_shard_map_compat_kwargs()),
            **donate_kw)

    def _get(has_wm: bool):
        f = compiled.get(has_wm)
        if f is None:
            f = compiled[has_wm] = _build(has_wm)
        return f

    if prefix_depth == 0:
        def tick(sstate, batch, watermark=None):
            if watermark is None:
                return _get(False)(sstate, batch)
            return _get(True)(sstate, batch, watermark)
    else:
        def tick(sstate, batch, prefix_view, watermark=None):
            if watermark is None:
                return _get(False)(sstate, batch, prefix_view)
            return _get(True)(sstate, batch, prefix_view, watermark)

    return tick


# --------------------------------------------------------------------- #
# Placement policies
# --------------------------------------------------------------------- #
class PlacementPolicy:
    """Chooses the replica for each newly registered tenant.

    ``place`` returns a replica index in ``[0, svc.n_replicas)``; the
    service then searches that replica's slot block across the group
    list and opens a new group only when the block is full everywhere.
    Stateless policies restore trivially; ``RoundRobinPlacement``'s
    cursor is intentionally NOT persisted — post-restore placement
    starts fresh, which only affects future registrations.
    """

    name = "base"

    def place(self, svc: "ShardedSearchService", signature) -> int:
        raise NotImplementedError


class RoundRobinPlacement(PlacementPolicy):
    """Cycle through replicas in registration order."""

    name = "round_robin"

    def __init__(self):
        self._next = 0

    def place(self, svc, signature):
        r = self._next % svc.n_replicas
        self._next += 1
        return r


class LoadBalancedPlacement(PlacementPolicy):
    """Prefer the replica with the least overflow pressure, breaking
    ties by live tenant count then index.  Pressure is the cumulative
    dropped-append counter summed over the replica's slot block (one
    device read per live group — admission time, not per tick)."""

    name = "load_balanced"

    def place(self, svc, signature):
        pressure = svc.replica_pressure()
        load = svc.replica_load()
        return min(range(svc.n_replicas),
                   key=lambda r: (pressure[r], load[r], r))


_PLACEMENTS = {
    RoundRobinPlacement.name: RoundRobinPlacement,
    LoadBalancedPlacement.name: LoadBalancedPlacement,
}


def _resolve_placement(spec) -> PlacementPolicy:
    if spec is None:
        return RoundRobinPlacement()
    if isinstance(spec, PlacementPolicy):
        return spec
    try:
        return _PLACEMENTS[spec]()
    except KeyError:
        raise ValueError(
            f"unknown placement policy {spec!r} "
            f"(known: {sorted(_PLACEMENTS)})") from None


# --------------------------------------------------------------------- #
# The sharded service
# --------------------------------------------------------------------- #
class ShardedSearchService(ContinuousSearchService):
    """``ContinuousSearchService`` with the slot axis sharded on a mesh.

    Same API, same per-tenant semantics (differentially proven against
    the single-device service and the per-query oracle in
    tests/test_mesh.py); ``slots_per_group`` is derived as
    ``n_replicas * slots_per_replica`` and placement routes every
    registration to one replica's slot block.  Checkpoints are written
    as per-replica npz shards; ``restore(..., n_replicas=R')`` repacks
    onto a differently-sized mesh.
    """

    _MESH_SERVICE = True        # restore-dispatch marker (service.py)

    def __init__(
        self,
        n_replicas: int | None = None,
        slots_per_replica: int | None = None,
        placement=None,
        mesh: dict | None = None,
        **kw,
    ):
        # ``mesh`` is the manifest-config form (restore round-trip);
        # explicit arguments take precedence over it
        if mesh is not None:
            if n_replicas is None:
                n_replicas = mesh.get("n_replicas")
            if slots_per_replica is None:
                slots_per_replica = mesh.get("slots_per_replica")
            if placement is None:
                placement = mesh.get("placement")
        devices = jax.devices()
        if n_replicas is None:
            n_replicas = len(devices)
        if slots_per_replica is None:
            slots_per_replica = 4
        if not 1 <= n_replicas <= len(devices):
            raise ValueError(
                f"n_replicas={n_replicas} needs that many devices "
                f"(have {len(devices)}; on CPU set XLA_FLAGS="
                f"--xla_force_host_platform_device_count=N before "
                f"importing jax)")
        kw.pop("slots_per_group", None)   # derived, not configurable
        self.n_replicas = int(n_replicas)
        self.slots_per_replica = int(slots_per_replica)
        self.placement = _resolve_placement(placement)
        self.mesh = jax.make_mesh(
            (self.n_replicas,), ("replica",),
            devices=devices[:self.n_replicas])
        self.mesh_stats: dict[int, MeshTickStats] = {}  # gid -> last tick
        super().__init__(
            slots_per_group=self.n_replicas * self.slots_per_replica, **kw)

    # -------------------------------------------------------------- #
    # placement
    # -------------------------------------------------------------- #
    def replica_load(self) -> list[int]:
        """Live tenants per replica (host-side bookkeeping, no sync)."""
        load = [0] * self.n_replicas
        for _, k in self._location.values():
            load[k // self.slots_per_replica] += 1
        return load

    def replica_pressure(self) -> list[int]:
        """Cumulative dropped appends per replica, summed over every
        live group's slot block (slot-table counters only — shared
        prefix-chain drops are not replica-attributable)."""
        spr = self.slots_per_replica
        pressure = [0] * self.n_replicas
        for g in self._iter_groups():
            if g.idle:
                continue
            ov = np.asarray(g.sstate.engines.stats.n_overflow)
            per = ov.reshape(self.n_replicas, spr, -1).sum(axis=(1, 2))
            pressure = [p + int(v) for p, v in zip(pressure, per)]
        return pressure

    def _place(self, groups, plan, leaf, signature):
        r = self.placement.place(self, signature)
        spr = self.slots_per_replica
        for g in groups:
            k = g.free_slot(r * spr, (r + 1) * spr)
            if k is not None:
                return g, k
        g = self._new_group(plan, leaf)
        groups.append(g)
        return g, r * spr

    # -------------------------------------------------------------- #
    # groups / ticking
    # -------------------------------------------------------------- #
    def _new_group(self, template: ExecutionPlan, leaf=None) -> _Group:
        depth = 0 if leaf is None else leaf.depth
        before = self.tick_cache.n_builds
        tick = self.tick_cache.get_mesh(
            template, self.mesh, self.slots_per_replica,
            backend=self.backend, extract_matches=self.extract_matches,
            max_out=self.max_out, donate=self.donate, prefix_depth=depth)
        self.n_compiles += self.tick_cache.n_builds - before
        sstate = self._shard_state(
            init_slot_state(template, self.slots_per_group, depth))
        g = _Group(
            gid=self._next_gid,
            template=template,
            tick=tick,
            sstate=sstate,
            empty=init_state(template, depth),
            qids=[None] * self.slots_per_group,
            prefix=leaf,
            prefix_depth=depth,
        )
        self._next_gid += 1
        return g

    def _shard_state(self, sstate):
        """Place a SlotState's leaves slot-sharded over the replica axis."""
        return jax.device_put(sstate, NamedSharding(self.mesh, P("replica")))

    def _advance_group(self, g: _Group, batch, views=None, forest_nds=None,
                       watermark=None):
        # same flow as the base class, with the mesh tick's third output
        # (the psum/pmax scalars) stashed per group for observability
        if g.prefix is not None:
            g.sstate, res, mstats = g.tick(
                g.sstate, batch, views[g.prefix.pid], watermark)
            chain_nd = self.forest.chain_tick_overflow(g.prefix, forest_nds)
            res = res._replace(
                n_overflow=res.n_overflow
                + jnp.where(g.sstate.params.active, chain_nd, 0))
        else:
            g.sstate, res, mstats = g.tick(g.sstate, batch, watermark)
        self.mesh_stats[g.gid] = mstats
        return res

    def last_mesh_stats(self) -> dict[int, dict]:
        """Host values of every group's last-tick ``MeshTickStats``."""
        return {gid: {"n_matches": int(s.n_matches),
                      "n_overflow": int(s.n_overflow),
                      "t_clock": int(s.t_clock)}
                for gid, s in self.mesh_stats.items()}

    def _register_obs_gauges(self) -> None:
        super()._register_obs_gauges()
        obs = self.obs
        obs.gauge("mesh.n_replicas").set(self.n_replicas)
        obs.register_gauge(
            "mesh.replica_load_max", lambda: max(self.replica_load(),
                                                 default=0))
        obs.register_gauge(
            "mesh.replica_pressure_max",
            lambda: max(self.replica_pressure(), default=0))

    def _trace_tick_extras(self, tr) -> None:
        # the collectives run inside the jitted mesh tick; their psum/
        # pmax scalars are already on host-reachable device buffers
        # after the barrier, so reading them here adds no sync point
        for gid, s in self.last_mesh_stats().items():
            tr.event("mesh.collectives", gid=gid, **s)

    # -------------------------------------------------------------- #
    # checkpoint / restore
    # -------------------------------------------------------------- #
    def _manifest(self) -> dict:
        man = super()._manifest()
        cfg = man["config"]
        del cfg["slots_per_group"]      # derived from the mesh config
        cfg["mesh"] = {
            "n_replicas": self.n_replicas,
            "slots_per_replica": self.slots_per_replica,
            "placement": self.placement.name,
        }
        if self.forest is not None:
            spr = self.slots_per_replica
            assignments = [
                (leaf, self._location[qid][1] // spr)
                for qid, leaf in self._prefix_of.items()
            ]
            man["replica_refcounts"] = {
                str(pid): counts
                for pid, counts in self.forest.replica_refcounts(
                    assignments, self.n_replicas).items()
            }
        return man

    def _ckpt_save_kwargs(self) -> dict:
        # slot-stacked group states split along axis 0 into one npz per
        # replica; forest node tables (replicated inputs) and scalars
        # ride in shard 0
        replicated = ()
        if self.forest is not None:
            replicated = tuple(
                f"prefix{n.pid}" for n in self.forest.nodes())
        return {"n_shards": self.n_replicas, "replicated": replicated}

    @classmethod
    def restore(
        cls,
        ckpt_dir: str,
        step: int | None = None,
        tick_cache: SlotTickCache | None = None,
        backend: str | None = None,
        extract_matches: bool | None = None,
        n_replicas: int | None = None,
        placement=None,
        obs=None,
        tracer=None,
    ) -> "ShardedSearchService":
        """Rebuild a sharded service from its newest usable checkpoint.

        With ``n_replicas`` equal to the checkpointed mesh size (or
        omitted) the exact slot layout is re-armed — zero recompiles for
        meshes this process has served.  A DIFFERENT ``n_replicas``
        triggers the repack path: queries keep their qids, the placement
        policy re-places every tenant onto the new mesh, and each
        tenant's engine-table rows are spliced from its old slot into
        its new one (host-side reassembly of the per-replica shards
        makes the npz layout mesh-agnostic).
        """
        overrides = {}
        if backend is not None:
            overrides["backend"] = backend
        if extract_matches is not None:
            overrides["extract_matches"] = extract_matches
        if placement is not None:
            overrides["placement"] = placement
        if obs is not None:
            overrides["obs"] = obs
        if tracer is not None:
            overrides["tracer"] = tracer
        candidates = ([step] if step is not None
                      else list(reversed(checkpoint_steps(ckpt_dir))))
        last_err: CheckpointError | None = None
        for s in candidates:
            try:
                validate_checkpoint(ckpt_dir, s)
                man = load_resolved_manifest(ckpt_dir, s, "service")
                mesh_cfg = man["config"].get("mesh")
                if mesh_cfg is None:
                    raise CheckpointError(
                        f"step {s}: not a ShardedSearchService checkpoint")
                if (n_replicas is None
                        or n_replicas == mesh_cfg["n_replicas"]):
                    return cls._restore_step(ckpt_dir, s, tick_cache,
                                             overrides)
                return cls._restore_reshard(ckpt_dir, s, man, tick_cache,
                                            overrides, n_replicas)
            except CheckpointError as e:
                last_err = e
        raise CheckpointError(
            f"no usable sharded checkpoint under {ckpt_dir!r}"
        ) from last_err

    @classmethod
    def _restore_step(cls, ckpt_dir, step, tick_cache, overrides):
        svc = super()._restore_step(ckpt_dir, step, tick_cache, overrides)
        svc._verify_replica_refcounts(
            load_resolved_manifest(ckpt_dir, step, "service"), step)
        for g in svc._iter_groups():
            g.sstate = svc._shard_state(g.sstate)
        return svc

    def _verify_replica_refcounts(self, man, step) -> None:
        """Refcounts are rebuilt, not trusted: re-derive the per-replica
        partition from the restored slot layout and compare with what
        the manifest recorded."""
        want = man.get("replica_refcounts")
        if want is None or self.forest is None:
            return
        spr = self.slots_per_replica
        assignments = [(leaf, self._location[qid][1] // spr)
                       for qid, leaf in self._prefix_of.items()]
        got = {str(pid): counts
               for pid, counts in self.forest.replica_refcounts(
                   assignments, self.n_replicas).items()}
        if want != got:
            raise CheckpointError(
                f"step {step}: per-replica refcount partition disagrees "
                f"with the manifest (manifest {want}, rebuilt {got})")

    @classmethod
    def _restore_reshard(cls, ckpt_dir, step, man, tick_cache, overrides,
                         n_replicas):
        """Restore onto a mesh of a different size: re-place and splice."""
        config = dict(man["config"])
        mesh_cfg = dict(config.pop("mesh"))
        mesh_cfg["n_replicas"] = n_replicas
        svc = cls(ckpt_dir=ckpt_dir, tick_cache=tick_cache,
                  mesh=mesh_cfg, **{**config, **overrides})
        svc.manifest_extra = man.get("extra", {})
        svc.restored_ingest = man.get("ingest")
        for qid_s, ent in man["queries"].items():
            svc.registry.adopt(
                int(qid_s), QueryGraph.from_spec(ent["query"]),
                int(ent["window"]),
                decomposition=ent.get("decomposition"))
        by_pid = {}
        if svc.forest is not None and man.get("forest"):
            by_pid = svc.forest.restore_nodes(man["forest"])

        # old-layout like-tree: one full-size SlotState per old group
        groups = sorted(man["groups"].items(), key=lambda kv: int(kv[0]))
        like, templates, leaves = {}, {}, {}
        for gid_s, gspec in groups:
            template = svc.registry.compile(
                QueryGraph.from_spec(gspec["template_query"]),
                int(gspec["template_window"]),
                decomposition=gspec.get("template_decomposition"))
            pid = gspec.get("prefix_pid")
            leaf = None if pid is None else by_pid[int(pid)]
            depth = 0 if leaf is None else leaf.depth
            templates[gid_s], leaves[gid_s] = template, leaf
            like[gid_s] = init_slot_state(
                template, len(gspec["qids"]), depth)
        if svc.forest is not None and man.get("forest"):
            for n in svc.forest.nodes():
                like[f"prefix{n.pid}"] = n.state
        restored = restore_checkpoint(ckpt_dir, step, like)

        # re-place every tenant on the new mesh and splice its engine
        # rows out of the old slot; params are rewritten from its plan
        for gid_s, gspec in groups:
            old = jax.tree.map(jnp.asarray, restored[gid_s])
            leaf = leaves[gid_s]
            for k, qid in enumerate(gspec["qids"]):
                if qid is None:
                    continue
                qid = int(qid)
                rq = svc.registry.get(qid)
                gkey = (rq.signature, None if leaf is None else leaf.pid)
                gs = svc._groups.setdefault(gkey, [])
                group, k2 = svc._place(gs, rq.plan, leaf, rq.signature)
                group.sstate = write_slot(
                    group.sstate, group.template, k2, rq.plan,
                    empty=group.empty)
                group.sstate = group.sstate._replace(
                    engines=jax.tree.map(
                        lambda full, oldarr, k2=k2, k=k:
                            full.at[k2].set(oldarr[k]),
                        group.sstate.engines, old.engines))
                group.qids[k2] = qid
                svc._location[qid] = (group, k2)
                if leaf is not None:
                    svc._prefix_of[qid] = svc.forest.adopt(leaf)
        if svc.forest is not None and man.get("forest"):
            want = {int(e["pid"]): int(e["refcount"])
                    for e in man["forest"]["nodes"]}
            got = {n.pid: n.refcount for n in svc.forest.nodes()}
            if want != got:
                raise CheckpointError(
                    f"step {step}: forest refcounts disagree with the "
                    f"manifest after repack (manifest {want}, "
                    f"rebuilt {got})")
            for n in svc.forest.nodes():
                n.state = jax.tree.map(
                    jnp.asarray, restored[f"prefix{n.pid}"])
        for g in svc._iter_groups():
            g.sstate = svc._shard_state(g.sstate)
        counters = man["counters"]
        svc.n_edges_ingested = int(counters["n_edges_ingested"])
        svc.n_ticks = int(counters["n_ticks"])
        svc._ckpt_step = int(step)
        svc.registry._next_qid = max(
            svc.registry._next_qid, int(counters["next_qid"]))
        if svc.obs is not None and man.get("obs"):
            svc.obs.load_manifest(man["obs"])
        return svc
