"""Runtime layer: fault tolerance, elastic scaling, straggler mitigation."""

from repro.runtime.fault import FaultTolerantLoop, SimulatedFailure
from repro.runtime.straggler import TickCoalescer
