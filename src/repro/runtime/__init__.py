"""Runtime layer: multi-query serving, fault tolerance, elastic scaling,
straggler mitigation."""

from repro.runtime.fault import FaultTolerantLoop, SimulatedFailure
from repro.runtime.mesh import (
    LoadBalancedPlacement,
    MeshTickStats,
    PlacementPolicy,
    RoundRobinPlacement,
    ShardedSearchService,
    build_mesh_slot_tick,
)
from repro.runtime.service import ContinuousSearchService
from repro.runtime.straggler import TickCoalescer
