"""Fault-tolerant training/streaming loop.

The loop owns: periodic async checkpoints, restart-from-latest recovery,
and a bounded retry budget.  Failures surface as exceptions from the
step function (on a real cluster: device halo errors / missing-worker
errors surfaced by the runtime; here: ``SimulatedFailure`` injected by
tests).  Recovery = restore latest checkpoint and replay — steps are
deterministic functions of (state, step_index), so the recovered run is
bitwise-identical to an uninterrupted one (tested).
"""

from __future__ import annotations

import logging
from typing import Callable

from repro.checkpoint import (
    AsyncCheckpointer,
    CheckpointError,
    checkpoint_steps,
    restore_checkpoint,
    validate_checkpoint,
)

log = logging.getLogger(__name__)


class SimulatedFailure(RuntimeError):
    pass


class FaultTolerantLoop:
    def __init__(
        self,
        ckpt_dir: str,
        step_fn: Callable,            # (state, step_idx) -> state
        make_init_state: Callable,    # () -> state
        ckpt_every: int = 50,
        max_restarts: int = 5,
        mesh=None,
        specs=None,
    ):
        self.ckpt_dir = ckpt_dir
        self.step_fn = step_fn
        self.make_init_state = make_init_state
        self.ckpt_every = ckpt_every
        self.max_restarts = max_restarts
        self.mesh = mesh
        self.specs = specs
        self.ckpt = AsyncCheckpointer(ckpt_dir)
        self.restarts = 0

    def _resume(self):
        """Restore the newest USABLE checkpoint: torn/partial files (a
        crash mid-write, a bad disk) are skipped, falling back to the
        previous step rather than wedging recovery."""
        state = self.make_init_state()
        for step in reversed(checkpoint_steps(self.ckpt_dir)):
            try:
                validate_checkpoint(self.ckpt_dir, step)
                restored = restore_checkpoint(
                    self.ckpt_dir, step, state, self.mesh, self.specs)
            except CheckpointError as e:
                log.warning("skipping torn checkpoint step %d: %s", step, e)
                continue
            log.info("restored checkpoint at step %d", step)
            return restored, step
        return state, 0

    def run(self, n_steps: int):
        while True:
            state, start = self._resume()
            try:
                for i in range(start, n_steps):
                    state = self.step_fn(state, i)
                    done = i + 1
                    if done % self.ckpt_every == 0 or done == n_steps:
                        self.ckpt.save(done, state)
                self.ckpt.wait()
                return state
            except SimulatedFailure as e:  # pragma: no cover - loop logic
                self.ckpt.wait()
                self.restarts += 1
                if self.restarts > self.max_restarts:
                    raise RuntimeError("restart budget exhausted") from e
                log.warning("failure at restart=%d: %s — recovering",
                            self.restarts, e)
