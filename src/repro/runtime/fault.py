"""Fault-tolerant training/streaming loop + the shared retry policy.

The loop owns: periodic async checkpoints, restart-from-latest recovery,
and a bounded retry budget.  Failures surface as exceptions from the
step function (on a real cluster: device halo errors / missing-worker
errors surfaced by the runtime; here: ``SimulatedFailure`` injected by
tests).  Recovery = restore latest checkpoint and replay — steps are
deterministic functions of (state, step_index), so the recovered run is
bitwise-identical to an uninterrupted one (tested).

``RetryPolicy`` is the one place retry budgets and exponential backoff
live: ``FaultTolerantLoop`` restarts and the ingestion frontier's
source reconnects (``repro.stream.ingest``) consume the same policy
instead of each duplicating budget/backoff logic.  Delays are
deterministic given an ``rng`` (jitter draws from it), so tests can pin
schedules; ``sleep`` is injectable for the same reason.
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Callable

import numpy as np

from repro.checkpoint import (
    AsyncCheckpointer,
    CheckpointError,
    checkpoint_steps,
    restore_checkpoint,
    validate_checkpoint,
)

log = logging.getLogger(__name__)


class SimulatedFailure(RuntimeError):
    pass


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Bounded retries with exponential backoff + jitter.

    ``max_attempts`` counts RETRIES (recoveries), not first tries: a
    policy with ``max_attempts=3`` allows an operation to fail and be
    retried three times before the caller gives up.  ``delay(attempt)``
    is the backoff before retry number ``attempt`` (1-based):
    ``base_delay_s * multiplier**(attempt-1)`` capped at ``max_delay_s``,
    plus up to ``jitter_frac`` of itself drawn from ``rng`` (no rng:
    no jitter — fully deterministic).
    """

    max_attempts: int = 5
    base_delay_s: float = 0.05
    max_delay_s: float = 2.0
    multiplier: float = 2.0
    jitter_frac: float = 0.1

    def __post_init__(self):
        if self.max_attempts < 0 or self.base_delay_s < 0:
            raise ValueError("max_attempts and base_delay_s must be >= 0")
        if self.multiplier < 1.0:
            raise ValueError("multiplier must be >= 1 (backoff, not decay)")

    def delay(self, attempt: int, rng: np.random.Generator | None = None
              ) -> float:
        """Backoff in seconds before retry ``attempt`` (1-based)."""
        d = min(self.base_delay_s * self.multiplier ** max(0, attempt - 1),
                self.max_delay_s)
        if rng is not None and self.jitter_frac > 0 and d > 0:
            d += float(rng.uniform(0, self.jitter_frac * d))
        return d

    def exhausted(self, attempt: int) -> bool:
        return attempt > self.max_attempts


class FaultTolerantLoop:
    def __init__(
        self,
        ckpt_dir: str,
        step_fn: Callable,            # (state, step_idx) -> state
        make_init_state: Callable,    # () -> state
        ckpt_every: int = 50,
        max_restarts: int = 5,
        retry: RetryPolicy | None = None,
        sleep: Callable[[float], None] = time.sleep,
        mesh=None,
        specs=None,
    ):
        self.ckpt_dir = ckpt_dir
        self.step_fn = step_fn
        self.make_init_state = make_init_state
        self.ckpt_every = ckpt_every
        # restart budget and backoff share one policy with ingest
        # reconnects; the legacy ``max_restarts`` knob maps onto it
        # (zero base delay: restarts were always immediate here)
        self.retry = retry if retry is not None else RetryPolicy(
            max_attempts=max_restarts, base_delay_s=0.0)
        self.sleep = sleep
        self.mesh = mesh
        self.specs = specs
        self.ckpt = AsyncCheckpointer(ckpt_dir)
        self.restarts = 0

    @property
    def max_restarts(self) -> int:
        return self.retry.max_attempts

    def _resume(self):
        """Restore the newest USABLE checkpoint: torn/partial files (a
        crash mid-write, a bad disk) are skipped, falling back to the
        previous step rather than wedging recovery."""
        state = self.make_init_state()
        for step in reversed(checkpoint_steps(self.ckpt_dir)):
            try:
                validate_checkpoint(self.ckpt_dir, step)
                restored = restore_checkpoint(
                    self.ckpt_dir, step, state, self.mesh, self.specs)
            except CheckpointError as e:
                log.warning("skipping torn checkpoint step %d: %s", step, e)
                continue
            log.info("restored checkpoint at step %d", step)
            return restored, step
        return state, 0

    def run(self, n_steps: int):
        while True:
            state, start = self._resume()
            try:
                for i in range(start, n_steps):
                    state = self.step_fn(state, i)
                    done = i + 1
                    if done % self.ckpt_every == 0 or done == n_steps:
                        self.ckpt.save(done, state)
                self.ckpt.wait()
                return state
            except SimulatedFailure as e:  # pragma: no cover - loop logic
                self.ckpt.wait()
                self.restarts += 1
                if self.retry.exhausted(self.restarts):
                    raise RuntimeError("restart budget exhausted") from e
                log.warning("failure at restart=%d: %s — recovering",
                            self.restarts, e)
                self.sleep(self.retry.delay(self.restarts))
