"""Continuous-search service: the unified serving ENGINE for standing queries.

This is the internal engine room.  The public way to use the system is
``repro.api`` — a ``StreamSession`` facade (pattern DSL, canonicalizing
planner, typed Event/Match records, admission control) that drives this
class underneath; ``repro.launch.stream_serve.StreamServer`` is a thin
one-tenant wrapper over the same path.  Standing queries arrive and
leave while the edge stream flows; the service keeps the compile budget
fixed by bucketing queries into padded slot groups keyed by structural
signature, and owns the whole production loop: adaptive tick coalescing,
periodic async checkpoints, and fault-tolerant restore.

Registration / compile budget
-----------------------------
* ``register(query, window)`` compiles the query's ExecutionPlan (host-
  side numpy, cheap), looks up its structural signature
  (``repro.core.registry.plan_signature``), and arms a free slot in an
  existing group — a pure device-data write, **no XLA recompilation**.
  Compiled slot ticks live in a process-wide ``SlotTickCache`` keyed by
  signature, so even a never-seen *group* (overflow, or a restored
  server) only compiles when the *structure* is new to the process;
  ``n_compiles`` counts those builds for observability.
* ``unregister(qid)`` disarms the slot (again data-only).

Cross-tenant prefix sharing
---------------------------
With ``enable_sharing=True`` the service CSEs TC-subquery prefixes
across tenants (``repro.core.share``): each registration acquires a
refcounted chain of ``SharedPrefixForest`` nodes — one expansion-list
table per canonical prefix signature and registration epoch — and the
tenant's slot tick consumes the leaf's view, running only its suffix
joins.  The forest is advanced ONCE per tick by a dedicated prefix tick
regardless of how many tenants alias each node; slot groups gain a
prefix dimension (group key = structural signature × prefix node), and
checkpoints snapshot the forest (tables + refcounts + signatures) so a
restored service resumes sharing with zero warm recompiles.  Per-tenant
results are oracle-exact either way; see ``shared_prefix(qid)`` /
``forest_stats()`` and ``ServeInfo.n_shared_prefix_ticks``.

Serving
-------
* ``ingest(batch)`` advances every group's fused tick once and returns
  ``{qid: TickResult}`` — the low-level fixed-batch API.  Batches must
  keep a fixed shape (pad the tail; ``to_batches`` does) — a new batch
  size re-specializes the jitted ticks, as usual under JAX.
* ``serve_stream(edges, ...)`` is the production loop over a DataEdge
  list: a ``TickCoalescer`` adapts the chunk size to the measured
  per-tick barrier latency and queue depth (all groups dispatch
  asynchronously and meet at one barrier, so the slowest group
  inherently sets the pace — backpressure), chunks are padded to
  power-of-two shapes (``quantize_pow2``) to bound jit
  specializations, matches stream out through
  ``on_match(qid, bindings, ets)``, and every ``ckpt_every`` ticks the
  full service state is checkpointed asynchronously.
* With the default ``donate=True``, slot ticks are jitted with
  ``donate_argnums=(0,)``: each tick consumes the previous ``SlotState``
  buffers in place instead of copying the tables every tick.

Fault tolerance
---------------
``checkpoint()`` snapshots every group's ``SlotState`` pytree through
``repro.checkpoint.AsyncCheckpointer`` plus a JSON manifest of the whole
registry (qid -> query/window, slot layout, structural templates,
counters).  ``ContinuousSearchService.restore(ckpt_dir)`` rebuilds the
full multi-tenant server from the newest *usable* checkpoint — torn or
partial files are skipped — re-registering every query into the same
slot layout with the same qids, and re-arming the compiled ticks from
the ``SlotTickCache`` (zero recompiles for structures this process has
already served).  By the paper's timing-order semantics a restored
server misses nothing still inside the window: the differential test
(tests/test_service_restore.py) proves crash + restore reports exactly
the same match set as an uninterrupted run.

``backend`` selects the compatibility-join implementation for every
group's slot tick: ``JoinBackend.REF`` (pure jnp), ``PALLAS`` (fused
TPU kernels), or ``PALLAS_INTERPRET`` (the kernels interpreted on CPU,
for validation).  The compiled ``PALLAS`` path is interpret-parity-
tested only (CI has no TPU); validate on hardware before serving with
it (ROADMAP.md).

Example
-------
    svc = ContinuousSearchService(ckpt_dir="/ckpts")
    q1 = svc.register(chain_query, window=50)
    svc.serve_stream(edges, on_match=alert, ckpt_every=50)
    ...                                    # crash? restart:
    svc = ContinuousSearchService.restore("/ckpts")
    svc.serve_stream(edges[svc.n_edges_ingested:], on_match=alert)
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import NamedTuple

import numpy as np
import jax
import jax.numpy as jnp

from repro.checkpoint import (
    AsyncCheckpointer,
    CheckpointError,
    checkpoint_steps,
    dict_diff,
    load_resolved_manifest,
    restore_checkpoint,
    validate_checkpoint,
)
from repro.core import join as J
from repro.core.multi import (
    GLOBAL_SLOT_TICK_CACHE,
    SlotState,
    SlotTickCache,
    clear_slot,
    init_slot_state,
    read_slot,
    write_slot,
)
from repro.core.engine import TickResult, current_matches
from repro.core.plan import ExecutionPlan
from repro.core.query import QueryGraph
from repro.core.registry import (
    QueryRegistry,
    plan_decomposition,
    plan_signature,
)
from repro.core.share import (
    SharedPrefixForest,
    SharedPrefixInfo,
    shared_current_matches,
)
from repro.core.engine import NO_WATERMARK
from repro.core.state import EdgeBatch, EngineState, init_state, make_batch
from repro.obs import MetricsRegistry, Tracer
from repro.runtime.straggler import TickCoalescer, quantize_pow2
from repro.stream.generator import to_batches


class ServeInfo(NamedTuple):
    """Per-tick observability record passed to ``serve_stream``'s
    ``on_tick`` callback (after state update and any checkpoint)."""

    tick: int               # cumulative tick count (checkpoint step id)
    n_edges_ingested: int   # cumulative edges consumed after this tick
    chunk: int              # edges consumed by this tick
    latency_ms: float       # barrier latency of this tick (all groups)
    n_overflow: int = 0     # dropped appends this tick, summed over qids
                            # (shared-prefix drops attributed per tenant,
                            # matching the unshared engine's counters)
    n_shared_prefix_ticks: int = 0   # forest nodes advanced this tick
    # ingest-frontier observability (``serve_frontier`` only; the plain
    # ``serve_stream`` path leaves the defaults)
    watermark: int | None = None     # event-time watermark after this tick
    n_late_dropped: int = 0          # frontier late drops this tick
    n_duplicates: int = 0            # suppressed duplicate deliveries, tick
    n_reconnects: int = 0            # source reconnects this tick
    n_dropped_forced_gap: int = 0    # capacity-pressure drops this tick
    watermark_lag: int = 0           # freshest data ts − watermark
    window_staleness: int = 0        # emit floor − watermark (forced gap)


@dataclass(eq=False)       # identity semantics: fields hold device arrays
class _Group:
    """One slot group: compiled tick + device state + slot ownership."""

    gid: int                          # stable id (checkpoint manifest key)
    template: ExecutionPlan
    tick: object                      # jitted slot tick (SlotTickCache-shared)
    sstate: SlotState
    empty: EngineState                # cached init_state(template) for churn
    qids: list = field(default_factory=list)   # qid | None per slot
    prefix: object = None             # share.PrefixNode leaf | None
    prefix_depth: int = 0             # externalized subquery-0 levels

    def free_slot(self, lo: int = 0, hi: int | None = None) -> int | None:
        """First free slot in ``[lo, hi)`` (mesh placement restricts the
        search to one replica's contiguous slot block)."""
        hi = len(self.qids) if hi is None else hi
        for k in range(lo, hi):
            if self.qids[k] is None:
                return k
        return None

    @property
    def idle(self) -> bool:
        return all(q is None for q in self.qids)


class ContinuousSearchService:
    """Multi-tenant continuous subgraph search over one edge stream."""

    def __init__(
        self,
        slots_per_group: int = 4,
        level_capacity: int = 2048,
        l0_capacity: int = 2048,
        max_new: int = 512,
        backend: str = J.JoinBackend.REF,
        extract_matches: bool = True,
        max_out: int | None = None,
        jit: bool = True,
        donate: bool = True,
        ckpt_dir: str | None = None,
        keep_checkpoints: int = 8,
        tick_cache: SlotTickCache | None = None,
        enable_sharing: bool = False,
        compact_every: int = 1,
        obs: MetricsRegistry | None = None,
        tracer: Tracer | None = None,
    ):
        if backend not in (J.JoinBackend.REF, J.JoinBackend.PALLAS,
                           J.JoinBackend.PALLAS_INTERPRET):
            raise ValueError(f"unknown join backend: {backend!r}")
        self.slots_per_group = slots_per_group
        self.backend = backend
        self.extract_matches = extract_matches
        self.max_out = max_out
        self._jit = jit
        self.donate = donate and jit
        self.tick_cache = (GLOBAL_SLOT_TICK_CACHE if tick_cache is None
                           else tick_cache)
        self.ckpt_dir = ckpt_dir
        self.keep_checkpoints = keep_checkpoints
        self.ckpt = AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
        self.registry = QueryRegistry(
            level_capacity=level_capacity, l0_capacity=l0_capacity,
            max_new=max_new)
        # group key: (plan_signature, prefix-leaf pid | None) — sharing
        # adds a prefix dimension to the slot-group layout, since every
        # slot of one group consumes ONE broadcast prefix view
        self._groups: dict[tuple, list[_Group]] = {}
        self._location: dict[int, tuple[_Group, int]] = {}
        self.forest = (SharedPrefixForest(
            self.tick_cache, backend=backend, jit=jit,
            donate=self.donate) if enable_sharing else None)
        self._prefix_of: dict[int, object] = {}   # qid -> leaf PrefixNode
        self._next_gid = 0
        self._frontier = None        # IngestFrontier bound by serve_frontier
        self.restored_ingest = None  # ingest manifest from restore()
        self._ckpt_step = 0          # last step id written (monotonic)
        # incremental manifests: with compact_every > 1 only every K-th
        # checkpoint re-serializes the whole registry; the steps between
        # write structural DELTAS against the previous step's manifest
        # (O(churn) bytes instead of O(total tenants) — see
        # repro.checkpoint.dict_diff / load_resolved_manifest)
        if compact_every < 1:
            raise ValueError("compact_every must be >= 1")
        self.compact_every = compact_every
        self._last_manifest: dict | None = None   # resolved, last written
        self._last_man_step: int | None = None
        self._chain_len = 0          # delta steps since last compacted base
        self.n_compiles = 0          # build_slot_tick cache misses (this service)
        self.n_edges_ingested = 0
        self.n_ticks = 0
        # caller state carried inside every checkpoint manifest (the api
        # layer persists its vocab/pattern plans here); a dict, or a
        # zero-arg callable evaluated at checkpoint time
        self.manifest_extra: dict = {}
        # observability (repro.obs): both OFF by default, and every hot-
        # path call site is guarded with an identity check so the
        # disabled service allocates nothing per tick and emits no spans.
        # Runtime knobs, deliberately NOT in the checkpoint config — a
        # restored service chooses its own instrumentation; the
        # registry's counter/histogram history rides in the manifest.
        self.obs = obs
        self.tracer = tracer
        if obs is not None:
            self._register_obs_gauges()

    def _register_obs_gauges(self) -> None:
        """Collect-time callback gauges (snapshot cost, zero tick cost)."""
        obs = self.obs
        obs.register_gauge("tick.n_active", lambda: self.n_active)
        obs.register_gauge(
            "tick.n_groups",
            lambda: sum(len(gs) for gs in self._groups.values()))
        obs.register_gauge("tick.n_compiles", lambda: self.n_compiles)
        if self.ckpt is not None:
            obs.register_gauge("ckpt.stall_s", lambda: self.ckpt.stall_s)
        if self.forest is not None:
            self.forest.register_obs(obs)

    # ------------------------------------------------------------------ #
    @property
    def n_active(self) -> int:
        return len(self._location)

    def _iter_groups(self) -> list[_Group]:
        """All groups in stable gid order (manifest / serving order)."""
        return sorted((g for gs in self._groups.values() for g in gs),
                      key=lambda g: g.gid)

    def _new_group(self, template: ExecutionPlan, leaf=None) -> _Group:
        depth = 0 if leaf is None else leaf.depth
        before = self.tick_cache.n_builds
        tick = self.tick_cache.get(
            template, backend=self.backend,
            extract_matches=self.extract_matches, max_out=self.max_out,
            jit=self._jit, donate=self.donate, prefix_depth=depth)
        self.n_compiles += self.tick_cache.n_builds - before
        g = _Group(
            gid=self._next_gid,
            template=template,
            tick=tick,
            sstate=init_slot_state(template, self.slots_per_group, depth),
            empty=init_state(template, depth),
            qids=[None] * self.slots_per_group,
            prefix=leaf,
            prefix_depth=depth,
        )
        self._next_gid += 1
        return g

    def _place(self, groups: list, plan: ExecutionPlan, leaf,
               signature) -> tuple[_Group, int]:
        """Pick ``(group, slot)`` for a new tenant of this group key,
        allocating a fresh group when none has a free slot.  The single
        placement hook: ``repro.runtime.mesh`` overrides it to route the
        choice through a replica ``PlacementPolicy`` and restrict the
        slot search to the chosen replica's block."""
        for g in groups:
            k = g.free_slot()
            if k is not None:
                return g, k
        g = self._new_group(plan, leaf)
        groups.append(g)
        return g, 0

    # ------------------------------------------------------------------ #
    def register(self, query: QueryGraph, window: int,
                 plan: ExecutionPlan | None = None) -> int:
        """Add a standing query; returns its qid.

        Always a pure data write when a group with the same structural
        signature has a free slot; an overflowing (or never-seen)
        structure allocates one new group, whose compiled tick comes
        from the process-wide ``SlotTickCache`` — only a structure new
        to the whole process actually compiles.  Pass ``plan`` to serve
        an exact pre-compiled plan (custom decomposition) instead of
        letting the registry compile one.
        """
        qid = self.registry.register(query, window, plan=plan)
        rq = self.registry.get(qid)
        leaf, gkey = None, None
        try:
            if self.forest is not None:
                # acquire the prefix chain at the CURRENT stream offset:
                # only tenants registered at the same offset may alias a
                # node, so shared tables hold exactly the history each
                # tenant would have built alone (oracle-exact under churn)
                leaf = self.forest.acquire(rq.plan,
                                           epoch=self.n_edges_ingested)
                self._prefix_of[qid] = leaf
            gkey = (rq.signature, None if leaf is None else leaf.pid)
            groups = self._groups.setdefault(gkey, [])
            group, k = self._place(groups, rq.plan, leaf, rq.signature)
            group.sstate = write_slot(group.sstate, group.template, k,
                                      rq.plan, empty=group.empty)
        except Exception:
            # no half-registered tenant: a failure anywhere (chain
            # acquisition, tick compile, slot write) rolls the qid, any
            # acquired prefix references, and an empty group-key entry
            # back out
            self.registry.unregister(qid)
            if self._prefix_of.pop(qid, None) is not None:
                self.forest.release(leaf)
            if gkey is not None and not self._groups.get(gkey):
                self._groups.pop(gkey, None)
            raise
        group.qids[k] = qid
        self._location[qid] = (group, k)
        return qid

    def unregister(self, qid: int) -> None:
        """Drop a standing query and its partial-match state (data-only).

        A group whose slots all become empty is released, except that one
        idle group per structural signature is kept warm so a tenant of a
        recently-seen structure can re-register without re-initializing
        device tables.  Use ``drop_idle_groups()`` to reclaim the warm
        groups too (the compiled tick itself stays in the SlotTickCache).
        Under prefix sharing idle groups are dropped immediately: their
        prefix node is released with the last tenant, and a later tenant
        of the same structure starts a fresh epoch (fresh node), so the
        warm group could never be re-armed.
        """
        group, k = self._location.pop(qid)
        group.sstate = clear_slot(group.sstate, group.template, k,
                                  empty=group.empty)
        group.qids[k] = None
        self.registry.unregister(qid)
        leaf = self._prefix_of.pop(qid, None)
        if leaf is not None:
            self.forest.release(leaf)
        if group.idle:
            gkey = next(
                key for key, gs in self._groups.items() if group in gs)
            siblings = self._groups[gkey]
            n_idle = sum(1 for g in siblings if g.idle)
            if group.prefix is not None or n_idle > 1:
                siblings.remove(group)
                if not siblings:
                    del self._groups[gkey]

    def overflow_pressure(self, signature=None) -> int:
        """Cumulative dropped appends across active tenants — of one
        structural ``plan_signature``, or the whole service.

        The engine counts per-slot overflow passively; this is the
        admission-control read: a structure under pressure (> 0) has
        already lost partial matches at the current capacities, so the
        api layer refuses to admit more tenants of that structure.
        ONE device read per group (the stacked ``[S]`` overflow counters
        come back in a single transfer; unarmed slots hold zeros) —
        call at admission/status time, not per tick.  Under prefix
        sharing the shared tables drop appends on behalf of every
        aliasing tenant, so each live group's prefix-chain overflow
        counts toward its structure's pressure too.
        """
        if signature is not None:
            groups = [g for (sig, _), gs in self._groups.items()
                      if sig == signature for g in gs]
        else:
            groups = self._iter_groups()
        live = [g for g in groups if not g.idle]
        total = sum(
            int(np.asarray(g.sstate.engines.stats.n_overflow).sum())
            for g in live)
        if self.forest is not None:
            seen = set()
            for g in live:
                node = g.prefix
                while node is not None and node.pid not in seen:
                    seen.add(node.pid)
                    total += int(np.asarray(node.state.n_overflow))
                    node = node.parent
        return total

    def drop_idle_groups(self) -> int:
        """Release all fully-empty slot groups (device tables); returns
        how many were dropped.  Compiled ticks stay cached, so
        re-registering a dropped structure re-allocates tables only."""
        dropped = 0
        for sig in list(self._groups):
            keep = [g for g in self._groups[sig] if not g.idle]
            dropped += len(self._groups[sig]) - len(keep)
            if keep:
                self._groups[sig] = keep
            else:
                del self._groups[sig]
        return dropped

    # ------------------------------------------------------------------ #
    def _advance_forest(self, batch: EdgeBatch, watermark=None):
        """The dedicated prefix tick: every live forest node advances
        once per service tick, no matter how many tenants alias it.
        Returns the per-node views consumed by the groups' suffix ticks
        plus the nodes' per-tick overflow scalars by pid (device)."""
        if self.forest is None or not len(self.forest):
            return {}, {}
        return self.forest.advance(batch, watermark)

    def _advance_group(self, g: _Group, batch: EdgeBatch, views=None,
                       forest_nds=None, watermark=None):
        """One fused tick for one group.  With ``donate`` the previous
        sstate buffers are consumed — ``g.sstate`` is rebound before this
        returns, so no caller can observe the donated state.

        A shared-prefix group's result comes back with each slot's
        ``n_overflow`` raised by its chain's drops this tick: the shared
        table drops on behalf of every aliasing tenant, and per-tenant
        counters must read as the unshared engine's would.

        ``watermark`` (None or a traced int32 scalar) is handed straight
        to the slot tick: one value per service tick drives every
        tenant's event-time clock (``serve_frontier`` feeds the
        frontier's watermark; the offline paths pass None and keep the
        legacy max-ts clock).
        """
        if g.prefix is not None:
            g.sstate, res = g.tick(g.sstate, batch, views[g.prefix.pid],
                                   watermark)
            chain_nd = self.forest.chain_tick_overflow(g.prefix, forest_nds)
            res = res._replace(
                n_overflow=res.n_overflow
                + jnp.where(g.sstate.params.active, chain_nd, 0))
        else:
            g.sstate, res = g.tick(g.sstate, batch, watermark)
        return res

    def ingest(self, batch, watermark=None) -> dict[int, TickResult]:
        """Advance all standing queries by one batch of stream edges.

        ``batch`` is an EdgeBatch or a dict of arrays (``to_batches``
        output).  Returns a per-qid TickResult (unstacked views of each
        group's fused result).  ``watermark`` switches the engines to
        event-time admission/expiry (see ``repro.core.engine``); None
        keeps the legacy max-ts clock.
        """
        if not isinstance(batch, EdgeBatch):
            batch = make_batch(**batch)
        views, forest_nds = self._advance_forest(batch, watermark)
        out: dict[int, TickResult] = {}
        for g in self._iter_groups():
            if g.idle:
                continue
            res = self._advance_group(g, batch, views, forest_nds,
                                      watermark)
            for k, qid in enumerate(g.qids):
                if qid is not None:
                    out[qid] = jax.tree.map(lambda x, k=k: x[k], res)
        self.n_ticks += 1
        # count on host: batch.valid is a concrete input array, so this
        # adds no sync point against the async tick dispatches above
        self.n_edges_ingested += int(np.asarray(batch.valid).sum())
        return out

    # ------------------------------------------------------------------ #
    def serve_stream(
        self,
        edges: list,
        on_match=None,
        on_tick=None,
        ckpt_every: int = 0,
        batch_size: int = 64,
        min_batch: int | None = None,
        max_batch: int | None = None,
        target_latency_ms: float = 50.0,
        coalescer: TickCoalescer | None = None,
        final_checkpoint: bool = True,
    ) -> dict[int, int]:
        """Drive the service over a DataEdge list (the production loop).

        One ``TickCoalescer`` adapts the chunk size to the measured tick
        latency and queue depth; chunks are padded to power-of-two
        shapes so the adaptive sizes produce a bounded set of jit
        specializations.  Group ticks dispatch asynchronously and the
        loop blocks ONCE per tick: the measured latency is the barrier
        every group experiences, so the slowest group inherently sets
        the pace (backpressure).  ``on_match(qid, bindings, ets)`` fires
        for each tenant's new matches; ``on_tick(ServeInfo)`` fires
        after each tick's state update (and checkpoint, if due) — an
        exception raised from it leaves the last checkpoint consistent,
        which is how the crash/restore tests inject failures.  With
        ``ckpt_dir`` set and ``ckpt_every > 0`` the full service state
        is checkpointed asynchronously every that-many ticks, plus once
        at the end of the call if ticks advanced past the last written
        step (so returning implies the served span is durable); pending
        writes are flushed before returning.  A consumer feeding the
        stream in many small calls can pass ``final_checkpoint=False``
        to keep strictly-every-``ckpt_every`` cadence.

        Pass ``coalescer`` to carry AIMD state across calls (a consumer
        feeding the stream in repeated ``serve_stream`` invocations
        keeps its converged batch size); the batch_size/bounds/latency
        arguments then have no effect.

        Returns ``{qid: total new matches}`` over the served span.
        """
        if on_match is not None and not self.extract_matches:
            raise ValueError(
                "on_match requires a service with extract_matches=True")
        if ckpt_every and self.ckpt is None:
            raise ValueError(
                "ckpt_every requires a service with ckpt_dir set — "
                "without it every checkpoint would be a silent no-op")
        if coalescer is None:
            coalescer = TickCoalescer.seeded(
                batch_size, min_batch, max_batch, target_latency_ms)

        totals: dict[int, int] = {}
        i, n = 0, len(edges)
        while i < n:
            chunk = edges[i:i + coalescer.batch]
            queue_depth = n - (i + len(chunk))
            lat_ms, tick_overflow, n_shared = self._tick_chunk(
                chunk, on_match, totals)
            # overflow joins latency and queue depth as a throttle input:
            # dropped appends mean the tick was too big for the tables
            coalescer.record(lat_ms, queue_depth, tick_overflow)
            if self.obs is not None:
                self._observe_coalescer(coalescer)
            i += len(chunk)
            if self.ckpt and ckpt_every and self.n_ticks % ckpt_every == 0:
                self.checkpoint()
            if on_tick is not None:
                on_tick(ServeInfo(
                    tick=self.n_ticks,
                    n_edges_ingested=self.n_edges_ingested,
                    chunk=len(chunk),
                    latency_ms=lat_ms,
                    n_overflow=tick_overflow,
                    n_shared_prefix_ticks=n_shared,
                ))
        self._final_checkpoint(ckpt_every, final_checkpoint)
        return totals

    def _tick_chunk(self, chunk: list, on_match, totals: dict,
                    watermark=None) -> tuple[float, int, int]:
        """One production tick over ``chunk`` (a DataEdge list): pow-2
        padded batch, async group dispatch, ONE barrier, match delivery.
        Updates ``totals``/counters in place; returns (barrier latency
        ms, tick overflow, shared-prefix node count).  Shared by
        ``serve_stream`` (arrival-order chunks, ``watermark=None``) and
        ``serve_frontier`` (watermark-order chunks with the frontier's
        traced event-time watermark)."""
        tr = self.tracer
        if tr is not None:
            tr.next_tick()
        active = [g for g in self._iter_groups() if not g.idle]
        batch = make_batch(
            **to_batches(chunk, quantize_pow2(len(chunk)))[0])
        t0 = time.perf_counter()
        views, forest_nds = self._advance_forest(batch, watermark)
        if tr is None:
            results = [(g, self._advance_group(g, batch, views,
                                               forest_nds, watermark))
                       for g in active]
        else:
            # per-stage wall clocks via bare perf_counter reads + post-
            # hoc record(): the tracer-off branch above allocates no
            # span objects and reads no extra clocks
            tr.record("tick.forest",
                      (time.perf_counter() - t0) * 1e3, n_nodes=len(views))
            results = []
            for g in active:
                ts = time.perf_counter()
                results.append((g, self._advance_group(
                    g, batch, views, forest_nds, watermark)))
                tr.record("tick.slot_dispatch",
                          (time.perf_counter() - ts) * 1e3, gid=g.gid)
            tb = time.perf_counter()
        jax.block_until_ready(                              # the barrier
            [g.sstate for g in active]
            + ([] if self.forest is None else self.forest.states()))
        t_end = time.perf_counter()
        lat_ms = (t_end - t0) * 1e3
        if tr is not None:
            tr.record("tick.barrier", (t_end - tb) * 1e3)
            self._trace_tick_extras(tr)
        tick_overflow = 0
        n_matches = 0
        for g, res in results:
            for k, qid in enumerate(g.qids):
                if qid is None:
                    continue
                r = jax.tree.map(lambda x, k=k: x[k], res)
                n_new = int(r.n_new_matches)
                tick_overflow += int(r.n_overflow)
                n_matches += n_new
                totals[qid] = totals.get(qid, 0) + n_new
                if n_new and on_match is not None:
                    valid = np.asarray(r.match_valid)
                    on_match(qid,
                             np.asarray(r.match_bindings)[valid],
                             np.asarray(r.match_ets)[valid])
        if tr is not None:
            tr.record("tick.deliver",
                      (time.perf_counter() - t_end) * 1e3,
                      n_matches=n_matches)
        self.n_ticks += 1
        self.n_edges_ingested += len(chunk)
        obs = self.obs
        if obs is not None:
            obs.histogram("tick.latency_ms").observe(lat_ms)
            obs.counter("tick.n_ticks").inc()
            obs.counter("tick.n_edges").inc(len(chunk))
            obs.counter("tick.n_matches").inc(n_matches)
            obs.counter("tick.n_overflow").inc(tick_overflow)
            if views:
                obs.counter("share.n_prefix_ticks").inc(len(views))
        return lat_ms, tick_overflow, len(views)

    def _trace_tick_extras(self, tr: Tracer) -> None:
        """Tracer-on hook after the tick barrier — the mesh service
        emits its collective scalars here; base service has none."""

    def _observe_coalescer(self, coalescer: TickCoalescer) -> None:
        """Mirror the AIMD decision just taken into ``coalescer.*``
        (obs-on path only — callers guard on ``self.obs``)."""
        self.obs.counter(f"coalescer.{coalescer.last_action}").inc()
        self.obs.gauge("coalescer.batch").set(coalescer.batch)
        if self.tracer is not None:
            self.tracer.event("coalescer.decision",
                              action=coalescer.last_action,
                              batch=coalescer.batch)

    def _final_checkpoint(self, ckpt_every: int, final: bool) -> None:
        if self.ckpt:
            if ckpt_every and final and \
                    self.n_ticks % ckpt_every != 0 and \
                    self.n_ticks > self._ckpt_step:
                self.checkpoint()       # final end-of-call durability
            self.ckpt.wait()

    def serve_frontier(
        self,
        frontier,
        on_match=None,
        on_tick=None,
        ckpt_every: int = 0,
        batch_size: int = 64,
        min_batch: int | None = None,
        max_batch: int | None = None,
        target_latency_ms: float = 50.0,
        coalescer: TickCoalescer | None = None,
        final_checkpoint: bool = True,
        pump_size: int = 64,
        max_idle_rounds: int | None = None,
    ) -> dict[int, int]:
        """Drive the service from an ``IngestFrontier`` (the real-traffic
        production loop): sources -> retry/dedup -> k-way merge ->
        watermark -> tick.

        The coalescer ticks on WATERMARK ADVANCE, not arrival order:
        each round pumps every live source, takes the events the
        watermark has released (in deterministic merged event-time
        order, at most the coalescer's batch), and ticks only when
        something is ready — an all-sources stall is an idle round
        (``TickCoalescer.record_idle``), not a tick of garbage.  The
        frontier is bound to the service for the duration, so
        checkpoints written during the loop embed its resume state
        (per-source ack cursors + emit floor) in the manifest:
        ``ContinuousSearchService.restore`` surfaces it as
        ``restored_ingest`` and ``IngestFrontier.resume`` picks the
        stream back up exactly-once (replayed deliveries suppressed).

        Event-time end-to-end: each tick hands the frontier's
        ``watermark()`` to every engine as a traced scalar, so window
        admission and expiry follow EVENT time (what the sources
        produced) instead of processing order (what the reorder buffer
        happened to release) — a force-evicted straggler can no longer
        jump the window clock and prematurely expire every tenant's
        partials; ``allowed_lateness`` trades completeness against
        window staleness end-to-end.  The watermark rides in every
        checkpoint manifest, so a restored frontier + service resume the
        same clock (no re-expiry, no resurrection).

        ``ServeInfo`` gains the frontier fields: ``watermark``,
        ``watermark_lag`` / ``window_staleness`` gauges, and the
        per-tick ``n_late_dropped`` / ``n_dropped_forced_gap`` /
        ``n_duplicates`` / ``n_reconnects`` deltas — no event leaves the
        pipeline unaccounted.  ``max_idle_rounds`` bounds how many
        consecutive empty rounds to tolerate before returning (None:
        serve until every source is exhausted — a source whose retry
        budget is spent counts as exhausted, so a dead source can't spin
        this loop forever); the frontier stays resumable either way.
        Returns ``{qid: total new matches}``.
        """
        if on_match is not None and not self.extract_matches:
            raise ValueError(
                "on_match requires a service with extract_matches=True")
        if ckpt_every and self.ckpt is None:
            raise ValueError(
                "ckpt_every requires a service with ckpt_dir set — "
                "without it every checkpoint would be a silent no-op")
        if coalescer is None:
            coalescer = TickCoalescer.seeded(
                batch_size, min_batch, max_batch, target_latency_ms)
        totals: dict[int, int] = {}
        # stays bound after return, so later checkpoints (tenant churn,
        # shutdown) keep embedding the stream cursors — unbinding would
        # make a post-serve restore silently replay the whole stream
        self._frontier = frontier
        prev = frontier.stats()
        idle = 0
        while not frontier.exhausted:
            tr = self.tracer
            t_pump = time.perf_counter() if tr is not None else 0.0
            frontier.pump(pump_size)
            t_rel = time.perf_counter() if tr is not None else 0.0
            chunk = frontier.take_ready(limit=coalescer.batch)
            t_done = time.perf_counter() if tr is not None else 0.0
            if not chunk:
                idle += 1
                coalescer.record_idle()
                if self.obs is not None:
                    self._observe_coalescer(coalescer)
                if max_idle_rounds is not None and idle > max_idle_rounds:
                    break
                continue
            idle = 0
            # the frontier's event-time watermark drives every engine's
            # admission/expiry clock this tick.  Traced scalar (one jit
            # specialization for the whole event-time mode, not one per
            # value); NO_WATERMARK is the traced "unknown yet" identity.
            wm = frontier.watermark()
            wm_in = jnp.asarray(
                NO_WATERMARK if wm is None else wm, jnp.int32)
            lat_ms, tick_overflow, n_shared = self._tick_chunk(
                chunk, on_match, totals, wm_in)
            if tr is not None:
                # recorded after _tick_chunk so the spans carry this
                # tick's correlation id (next_tick advances in there)
                tr.record("ingest.pump", (t_rel - t_pump) * 1e3)
                tr.record("ingest.release", (t_done - t_rel) * 1e3,
                          n_released=len(chunk))
            coalescer.record(lat_ms, frontier.buffered, tick_overflow)
            if self.obs is not None:
                self._observe_coalescer(coalescer)
                frontier.publish_obs(self.obs)
            if self.ckpt and ckpt_every and \
                    self.n_ticks % ckpt_every == 0:
                self.checkpoint()
            if on_tick is not None:
                cur = frontier.stats()
                on_tick(ServeInfo(
                    tick=self.n_ticks,
                    n_edges_ingested=self.n_edges_ingested,
                    chunk=len(chunk),
                    latency_ms=lat_ms,
                    n_overflow=tick_overflow,
                    n_shared_prefix_ticks=n_shared,
                    watermark=cur.watermark,
                    n_late_dropped=cur.n_late_dropped
                    - prev.n_late_dropped,
                    n_duplicates=cur.n_duplicates - prev.n_duplicates,
                    n_reconnects=cur.n_reconnects - prev.n_reconnects,
                    n_dropped_forced_gap=cur.n_dropped_forced_gap
                    - prev.n_dropped_forced_gap,
                    watermark_lag=cur.watermark_lag,
                    window_staleness=cur.window_staleness,
                ))
                prev = cur
        self._final_checkpoint(ckpt_every, final_checkpoint)
        return totals

    # ------------------------------------------------------------------ #
    # checkpoint / restore
    # ------------------------------------------------------------------ #
    def _manifest(self) -> dict:
        """JSON-serializable description of everything that is NOT a
        device array: config, registry, slot layout, counters."""
        extra = (self.manifest_extra() if callable(self.manifest_extra)
                 else self.manifest_extra)
        return {
            "extra": extra,
            "config": {
                "slots_per_group": self.slots_per_group,
                "level_capacity": self.registry.level_capacity,
                "l0_capacity": self.registry.l0_capacity,
                "max_new": self.registry.max_new,
                "backend": self.backend,
                "extract_matches": self.extract_matches,
                "max_out": self.max_out,
                "jit": self._jit,
                "donate": self.donate,
                "keep_checkpoints": self.keep_checkpoints,
                "enable_sharing": self.forest is not None,
                "compact_every": self.compact_every,
            },
            "queries": {
                str(qid): {
                    "query": self.registry.get(qid).query.to_spec(),
                    "window": int(self.registry.get(qid).window),
                    # exact plan round-trip: restore bypasses the
                    # decomposition heuristics (custom plans survive)
                    "decomposition": [
                        list(seq) for seq in
                        plan_decomposition(self.registry.get(qid).plan)
                    ],
                }
                for qid in self.registry.qids()
            },
            # keyed by gid (not a list): stable keys make churn deltas
            # O(changed groups) under dict_diff instead of shifting every
            # downstream entry when a group is dropped
            "groups": {
                str(g.gid): {
                    "template_query": g.template.query.to_spec(),
                    "template_window": int(g.template.window),
                    "template_decomposition": [
                        list(seq) for seq in plan_decomposition(g.template)
                    ],
                    "qids": list(g.qids),
                    "prefix_pid": (None if g.prefix is None
                                   else g.prefix.pid),
                }
                for g in self._iter_groups()
            },
            "forest": (None if self.forest is None
                       else self.forest.to_manifest()),
            # ingest-frontier resume state (serve_frontier binds it):
            # per-source ack cursors + emit floor, so a restored service
            # can resume mid-stream exactly-once (IngestFrontier.resume)
            "ingest": (None if self._frontier is None
                       else self._frontier.to_manifest()),
            "counters": {
                "n_edges_ingested": int(self.n_edges_ingested),
                "n_ticks": int(self.n_ticks),
                "next_qid": int(self.registry.next_qid),
            },
            # obs registry history (counters + histogram buckets): a
            # restored service resumes its cumulative metrics, so e.g.
            # drop-driven health attribution survives restore
            "obs": (None if self.obs is None else self.obs.to_manifest()),
        }

    def _ckpt_tree(self) -> dict:
        tree = {str(g.gid): g.sstate for g in self._iter_groups()}
        if self.forest is not None:
            tree.update({f"prefix{n.pid}": n.state
                         for n in self.forest.nodes()})
        return tree

    def _ckpt_save_kwargs(self) -> dict:
        """Extra ``AsyncCheckpointer.save`` kwargs — the mesh service
        overrides this with per-replica shard splitting."""
        return {}

    def checkpoint(self, step: int | None = None):
        """Snapshot all groups' ``SlotState`` pytrees + the service
        manifest, asynchronously.  Returns the writer future (call
        ``self.ckpt.wait()`` to block on durability).

        Step ids are strictly monotonic even when the tick count has not
        advanced (e.g. a registry-only change checkpointed twice at the
        same tick): overwriting an existing step would put previously
        durable state at risk if a crash tore the rewrite.

        With ``compact_every > 1``, at most every K-th step carries the
        full manifest; the steps between write ``service_delta`` patches
        against the previous step (arrays are always complete — only the
        registry/layout metadata is incremental).  Restore replays the
        chain via ``load_resolved_manifest`` and falls back to the last
        compacted base if a link is torn.
        """
        if self.ckpt is None:
            raise ValueError("service was constructed without ckpt_dir")
        t0 = time.perf_counter() if (self.obs is not None
                                     or self.tracer is not None) else 0.0
        if step is None:
            step = max(self.n_ticks, self._ckpt_step + 1)
        self._ckpt_step = max(self._ckpt_step, step)
        man = self._manifest()
        if (self._last_manifest is not None
                and self._chain_len + 1 < self.compact_every):
            extra = {"service_delta": {
                "prev": self._last_man_step,
                "patch": dict_diff(self._last_manifest, man)}}
            self._chain_len += 1
        else:
            extra = {"service": man}
            self._chain_len = 0
        self._last_manifest = man
        self._last_man_step = step
        fut = self.ckpt.save(step, self._ckpt_tree(), extra=extra,
                             keep_last=self.keep_checkpoints,
                             **self._ckpt_save_kwargs())
        if self.obs is not None or self.tracer is not None:
            # the synchronous publish cost: manifest build + device_get
            # snapshot (the async file write is tracked by ckpt.stall_s)
            ms = (time.perf_counter() - t0) * 1e3
            if self.obs is not None:
                self.obs.histogram("ckpt.publish_ms").observe(ms)
                self.obs.counter("ckpt.n_checkpoints").inc()
            if self.tracer is not None:
                self.tracer.record("ckpt.publish", ms, step=int(step))
                self.tracer.flush()
        return fut

    @classmethod
    def restore(
        cls,
        ckpt_dir: str,
        step: int | None = None,
        tick_cache: SlotTickCache | None = None,
        backend: str | None = None,
        extract_matches: bool | None = None,
        obs: MetricsRegistry | None = None,
        tracer: Tracer | None = None,
    ) -> "ContinuousSearchService":
        """Rebuild a full multi-tenant service from a checkpoint.

        Uses the newest *usable* checkpoint (or ``step`` if given) —
        torn/partial checkpoints are skipped, falling back to the
        previous one.  Every query is re-registered under its original
        qid into its original slot, the structural templates are
        recompiled host-side, and the compiled slot ticks come from the
        ``SlotTickCache``: a structure this process has already served
        restores with zero recompiles.

        ``backend`` / ``extract_matches`` override the checkpointed
        config (both are serving-behavior knobs, independent of the
        persisted state layout); by default the checkpointed values are
        kept.
        """
        candidates = ([step] if step is not None
                      else list(reversed(checkpoint_steps(ckpt_dir))))
        overrides = {}
        if backend is not None:
            overrides["backend"] = backend
        if extract_matches is not None:
            overrides["extract_matches"] = extract_matches
        # instrumentation is a runtime knob (never in the checkpointed
        # config): the restored service adopts the caller's registry/
        # tracer, then reloads counter/histogram history from the
        # manifest inside _restore_step
        if obs is not None:
            overrides["obs"] = obs
        if tracer is not None:
            overrides["tracer"] = tracer
        last_err: CheckpointError | None = None
        for s in candidates:
            try:
                return cls._restore_step(ckpt_dir, s, tick_cache, overrides)
            except CheckpointError as e:
                last_err = e
        raise CheckpointError(
            f"no usable service checkpoint under {ckpt_dir!r}") from last_err

    @classmethod
    def _restore_step(cls, ckpt_dir, step, tick_cache, overrides):
        validate_checkpoint(ckpt_dir, step)   # torn pair / file -> skip
        # Resolves incremental ``service_delta`` chains back to the last
        # full manifest; torn links raise CheckpointError so the restore
        # candidate loop falls back to an older step.
        man = load_resolved_manifest(ckpt_dir, step, "service")
        config = dict(man["config"])
        if "mesh" in config and not hasattr(cls, "_MESH_SERVICE"):
            # Checkpoint was written by a ShardedSearchService but
            # restore() was called on the base class: delegate.
            from repro.runtime.mesh import ShardedSearchService
            return ShardedSearchService._restore_step(
                ckpt_dir, step, tick_cache, overrides)
        svc = cls(ckpt_dir=ckpt_dir, tick_cache=tick_cache,
                  **{**config, **overrides})
        svc.manifest_extra = man.get("extra", {})
        svc.restored_ingest = man.get("ingest")
        for qid_s, ent in man["queries"].items():
            svc.registry.adopt(
                int(qid_s), QueryGraph.from_spec(ent["query"]),
                int(ent["window"]),
                decomposition=ent.get("decomposition"))
        by_pid = {}
        if svc.forest is not None and man.get("forest"):
            by_pid = svc.forest.restore_nodes(man["forest"])
        like = {}
        for gid_s, gspec in sorted(man["groups"].items(),
                                   key=lambda kv: int(kv[0])):
            template = svc.registry.compile(
                QueryGraph.from_spec(gspec["template_query"]),
                int(gspec["template_window"]),
                decomposition=gspec.get("template_decomposition"))
            pid = gspec.get("prefix_pid")
            leaf = None if pid is None else by_pid[int(pid)]
            g = svc._new_group(template, leaf)
            g.gid = int(gid_s)
            g.qids = [None if q is None else int(q) for q in gspec["qids"]]
            gkey = (plan_signature(template),
                    None if leaf is None else leaf.pid)
            svc._groups.setdefault(gkey, []).append(g)
            for k, qid in enumerate(g.qids):
                if qid is not None:
                    svc._location[qid] = (g, k)
                    if leaf is not None:
                        # one chain of references per restored tenant —
                        # refcounts are rebuilt, not trusted blindly
                        svc._prefix_of[qid] = svc.forest.adopt(leaf)
            like[str(g.gid)] = g.sstate
        if svc.forest is not None and man.get("forest"):
            want = {int(e["pid"]): int(e["refcount"])
                    for e in man["forest"]["nodes"]}
            got = {n.pid: n.refcount for n in svc.forest.nodes()}
            if want != got:
                raise CheckpointError(
                    f"step {step}: forest refcounts disagree with the "
                    f"manifest (manifest {want}, rebuilt {got})")
            for n in svc.forest.nodes():
                like[f"prefix{n.pid}"] = n.state
        svc._next_gid = 1 + max(
            (int(gid) for gid in man["groups"]), default=-1)
        restored = restore_checkpoint(ckpt_dir, step, like)
        for g in svc._iter_groups():
            g.sstate = jax.tree.map(jnp.asarray, restored[str(g.gid)])
        if svc.forest is not None:
            for n in svc.forest.nodes():
                n.state = jax.tree.map(jnp.asarray,
                                       restored[f"prefix{n.pid}"])
        counters = man["counters"]
        svc.n_edges_ingested = int(counters["n_edges_ingested"])
        svc.n_ticks = int(counters["n_ticks"])
        svc._ckpt_step = int(step)
        svc.registry._next_qid = max(
            svc.registry._next_qid, int(counters["next_qid"]))
        if svc.obs is not None and man.get("obs"):
            svc.obs.load_manifest(man["obs"])
        return svc

    # ------------------------------------------------------------------ #
    def state(self, qid: int) -> EngineState:
        """This query's (unstacked) engine state (under prefix sharing:
        the suffix levels only — the shared prefix lives in the forest)."""
        group, k = self._location[qid]
        return read_slot(group.sstate, k)

    def matches(self, qid: int):
        """All complete matches currently in the query's window."""
        group, _ = self._location[qid]
        plan = self.registry.get(qid).plan
        if group.prefix is None:
            return current_matches(plan, self.state(qid))
        return shared_current_matches(plan, group.prefix, self.forest,
                                      self.state(qid))

    def stats(self, qid: int):
        return self.state(qid).stats

    # ------------------------------------------------------------------ #
    # prefix-sharing observability
    # ------------------------------------------------------------------ #
    def shared_prefix(self, qid: int) -> SharedPrefixInfo | None:
        """Sharing stats for one tenant, or None when the service runs
        unshared (``enable_sharing=False``)."""
        leaf = self._prefix_of.get(qid)
        if leaf is None:
            return None
        return SharedPrefixInfo(depth=leaf.depth, n_tenants=leaf.refcount,
                                epoch=leaf.epoch)

    def forest_stats(self):
        """Aggregate ``ForestStats`` of the shared-prefix forest (None
        when sharing is disabled)."""
        return None if self.forest is None else self.forest.stats()

    def tenant_overflow(self, qid: int) -> int:
        """Cumulative dropped appends affecting this tenant: its own
        suffix/L0 tables plus (under sharing) its prefix chain."""
        total = int(np.asarray(self.stats(qid).n_overflow))
        leaf = self._prefix_of.get(qid)
        if leaf is not None:
            total += self.forest.chain_overflow(leaf)
        return total
