"""Continuous-search service: register / unregister / ingest.

The serving front-end for the multi-query engine (repro.core.multi).
Standing queries arrive and leave while the edge stream flows; the
service keeps the compile budget fixed by bucketing queries into padded
slot groups keyed by structural signature:

* ``register(query, window)`` compiles the query's ExecutionPlan (host-
  side numpy, cheap), looks up its structural signature
  (``repro.core.registry.plan_signature``), and arms a free slot in an
  existing group — a pure device-data write, **no XLA recompilation**.
  Only a never-seen structure (or an overflowing group) triggers one
  ``build_slot_tick`` compile, which then serves ``slots_per_group``
  queries of that shape; ``n_compiles`` counts these for observability.
* ``unregister(qid)`` disarms the slot (again data-only).
* ``ingest(batch)`` advances every group's fused tick once and returns
  ``{qid: TickResult}`` for the registered queries.

Batches must keep a fixed shape (pad the tail; ``to_batches`` does) —
a new batch size re-specializes the jitted ticks, as usual under JAX.

``backend`` selects the compatibility-join implementation for every
group's slot tick: ``JoinBackend.REF`` (pure jnp), ``PALLAS`` (fused
TPU kernels — one stacked 3-D-grid join per slot group, per-slot
windows as scalar-prefetch inputs, on-chip pair extraction), or
``PALLAS_INTERPRET`` (the kernels interpreted on CPU, for validation).
Registration stays a pure data write under all backends.  Note the
compiled ``PALLAS`` path is interpret-parity-tested only (CI has no
TPU); validate on hardware before serving with it (ROADMAP.md).

Example
-------
    svc = ContinuousSearchService()
    q1 = svc.register(chain_query, window=50)
    for b in to_batches(stream, 64):
        results = svc.ingest(make_batch(**b))
        if int(results[q1].n_new_matches):
            ...  # alert
    svc.unregister(q1)
"""

from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np
import jax

from repro.core import join as J
from repro.core.multi import (
    SlotState,
    build_slot_tick,
    clear_slot,
    init_slot_state,
    read_slot,
    write_slot,
)
from repro.core.engine import TickResult, current_matches
from repro.core.plan import ExecutionPlan
from repro.core.query import QueryGraph
from repro.core.registry import QueryRegistry
from repro.core.state import EdgeBatch, EngineState, init_state, make_batch


@dataclass(eq=False)       # identity semantics: fields hold device arrays
class _Group:
    """One compiled slot tick + its device state and slot ownership."""

    template: ExecutionPlan
    tick: object                      # jitted slot tick
    sstate: SlotState
    empty: EngineState                # cached init_state(template) for churn
    qids: list = field(default_factory=list)   # qid | None per slot

    def free_slot(self) -> int | None:
        for k, qid in enumerate(self.qids):
            if qid is None:
                return k
        return None


class ContinuousSearchService:
    """Multi-tenant continuous subgraph search over one edge stream."""

    def __init__(
        self,
        slots_per_group: int = 4,
        level_capacity: int = 2048,
        l0_capacity: int = 2048,
        max_new: int = 512,
        backend: str = J.JoinBackend.REF,
        extract_matches: bool = True,
        max_out: int | None = None,
        jit: bool = True,
    ):
        if backend not in (J.JoinBackend.REF, J.JoinBackend.PALLAS,
                           J.JoinBackend.PALLAS_INTERPRET):
            raise ValueError(f"unknown join backend: {backend!r}")
        self.slots_per_group = slots_per_group
        self.backend = backend
        self.extract_matches = extract_matches
        self.max_out = max_out
        self._jit = jit
        self.registry = QueryRegistry(
            level_capacity=level_capacity, l0_capacity=l0_capacity,
            max_new=max_new)
        self._groups: dict[tuple, list[_Group]] = {}
        self._location: dict[int, tuple[_Group, int]] = {}
        self.n_compiles = 0          # build_slot_tick invocations (observability)
        self.n_edges_ingested = 0

    # ------------------------------------------------------------------ #
    @property
    def n_active(self) -> int:
        return len(self._location)

    def _new_group(self, template: ExecutionPlan) -> _Group:
        tick = build_slot_tick(
            template, backend=self.backend,
            extract_matches=self.extract_matches, max_out=self.max_out)
        if self._jit:
            tick = jax.jit(tick)
        self.n_compiles += 1
        return _Group(
            template=template,
            tick=tick,
            sstate=init_slot_state(template, self.slots_per_group),
            empty=init_state(template),
            qids=[None] * self.slots_per_group,
        )

    # ------------------------------------------------------------------ #
    def register(self, query: QueryGraph, window: int) -> int:
        """Add a standing query; returns its qid.

        Recompile-free when a group of the same structural signature has
        a free slot; otherwise compiles one new group for the signature.
        """
        qid = self.registry.register(query, window)
        rq = self.registry.get(qid)
        groups = self._groups.setdefault(rq.signature, [])
        group = next((g for g in groups if g.free_slot() is not None), None)
        if group is None:
            group = self._new_group(rq.plan)
            groups.append(group)
        k = group.free_slot()
        group.sstate = write_slot(group.sstate, group.template, k, rq.plan,
                                  empty=group.empty)
        group.qids[k] = qid
        self._location[qid] = (group, k)
        return qid

    def unregister(self, qid: int) -> None:
        """Drop a standing query and its partial-match state (data-only).

        A group whose slots all become empty is released, except that one
        idle group per structural signature is kept warm so a tenant of a
        recently-seen structure can re-register without recompiling.  Use
        ``drop_idle_groups()`` to reclaim the warm groups too.
        """
        group, k = self._location.pop(qid)
        group.sstate = clear_slot(group.sstate, group.template, k,
                                  empty=group.empty)
        group.qids[k] = None
        self.registry.unregister(qid)
        if all(q is None for q in group.qids):
            rq_sig = next(
                sig for sig, gs in self._groups.items() if group in gs)
            siblings = self._groups[rq_sig]
            n_idle = sum(
                1 for g in siblings if all(q is None for q in g.qids))
            if n_idle > 1:
                siblings.remove(group)

    def drop_idle_groups(self) -> int:
        """Release all fully-empty slot groups (compiled ticks + device
        tables); returns how many were dropped.  Re-registering a dropped
        structure recompiles one group."""
        dropped = 0
        for sig in list(self._groups):
            keep = [g for g in self._groups[sig]
                    if any(q is not None for q in g.qids)]
            dropped += len(self._groups[sig]) - len(keep)
            if keep:
                self._groups[sig] = keep
            else:
                del self._groups[sig]
        return dropped

    # ------------------------------------------------------------------ #
    def ingest(self, batch) -> dict[int, TickResult]:
        """Advance all standing queries by one batch of stream edges.

        ``batch`` is an EdgeBatch or a dict of arrays (``to_batches``
        output).  Returns a per-qid TickResult (unstacked views of each
        group's fused result).
        """
        if not isinstance(batch, EdgeBatch):
            batch = make_batch(**batch)
        out: dict[int, TickResult] = {}
        for groups in self._groups.values():
            for g in groups:
                if all(q is None for q in g.qids):
                    continue
                g.sstate, res = g.tick(g.sstate, batch)
                for k, qid in enumerate(g.qids):
                    if qid is not None:
                        out[qid] = jax.tree.map(lambda x, k=k: x[k], res)
        # count on host: batch.valid is a concrete input array, so this
        # adds no sync point against the async tick dispatches above
        self.n_edges_ingested += int(np.asarray(batch.valid).sum())
        return out

    # ------------------------------------------------------------------ #
    def state(self, qid: int) -> EngineState:
        """This query's (unstacked) engine state."""
        group, k = self._location[qid]
        return read_slot(group.sstate, k)

    def matches(self, qid: int):
        """All complete matches currently in the query's window."""
        return current_matches(self.registry.get(qid).plan, self.state(qid))

    def stats(self, qid: int):
        return self.state(qid).stats
