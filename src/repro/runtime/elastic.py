"""Elastic scaling: re-fit a running job onto a different mesh.

Mechanics: all state lives in pytrees with explicit PartitionSpec trees;
scaling up/down = checkpoint -> rebuild mesh -> restore with the new
NamedShardings (checkpoint.reshard does the placement).  The specs are
mesh-shape-agnostic (they name logical axes), so the same spec tree works
for 16x16, 2x16x16, or a degraded 15x16 donut — GSPMD handles uneven
tiling by padding.
"""

from __future__ import annotations

import jax

from repro.checkpoint import reshard


def scale_to_mesh(state, old_mesh, new_mesh, specs):
    """Move ``state`` (pytree on old_mesh) onto new_mesh under ``specs``."""
    del old_mesh  # the host round-trip is mesh-agnostic
    return reshard(state, new_mesh, specs)


def degraded_mesh(devices, shape, axis_names, drop: int = 0):
    """Build a mesh from the surviving device list (node-failure path):
    drops ``drop`` devices and re-folds the rest into the largest
    fitting mesh of the same axis structure."""
    import numpy as np

    devs = list(devices)[: len(devices) - drop]
    total = len(devs)
    # shrink the first axis to fit
    trailing = 1
    for s in shape[1:]:
        trailing *= s
    first = total // trailing
    if first < 1:
        raise ValueError("not enough devices for the requested mesh shape")
    new_shape = (first,) + tuple(shape[1:])
    used = first * trailing
    arr = np.array(devs[:used]).reshape(new_shape)
    return jax.sharding.Mesh(arr, axis_names)
