"""Checkpointing: pytree save/restore with mesh resharding + async writer.

Format: one ``step_<N>.npz`` per checkpoint (flattened key-path -> array)
plus a JSON manifest ``step_<N>.json`` carrying caller metadata (the
``extra`` dict — e.g. the ContinuousSearchService serialises its whole
registry/slot layout there).  Restore accepts a target mesh +
PartitionSpec tree, so a checkpoint written on one mesh restores onto any
other mesh (elastic scaling path — runtime/elastic.py round-trips
through here).

Crash consistency: both files are written to a temp name and published
with ``os.replace`` (atomic on POSIX), manifest first and the ``.npz``
last — the ``.npz`` is the commit point, so a visible checkpoint always
has a readable manifest.  A torn/partial checkpoint (truncated zip,
unparseable or missing manifest — e.g. files from a crashed writer or a
bad disk) is *skipped* by ``latest_step`` and surfaces from
``restore_checkpoint``/``load_manifest`` as ``CheckpointError`` so
recovery paths can fall back to the previous step instead of crashing.

The async writer snapshots to host memory synchronously (cheap: device->
host copy) and writes the file on a background thread, so the serving /
train loop never blocks on disk.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import threading
import time
import warnings
import zipfile
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import jax


SEP = "::"

# Torn-delta fallbacks observed by load_resolved_manifest in this
# process: every time a delta manifest chain cannot be replayed (a link
# pruned or torn) and the caller must fall back to an older compacted
# base, this counts it — silent fallback would hide retention bugs.
N_DELTA_FALLBACKS = 0


class CheckpointError(RuntimeError):
    """A checkpoint on disk is torn, partial, or unreadable."""


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _paths(ckpt_dir: str, step: int) -> tuple[str, str]:
    return (os.path.join(ckpt_dir, f"step_{step}.npz"),
            os.path.join(ckpt_dir, f"step_{step}.json"))


def _shard_path(ckpt_dir: str, step: int, r: int, n: int) -> str:
    return os.path.join(ckpt_dir, f"step_{step}.shard{r}of{n}.npz")


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


class _HashingWriter:
    """Write-through file wrapper that hashes bytes as they stream past.

    A naive hash-on-write breaks under ``zipfile``: with a seekable
    output it backpatches each member's local header (CRC/sizes) after
    writing the data, invalidating any running prefix hash.  This
    wrapper therefore *refuses to be seekable* (``tell`` raises, which
    makes ``zipfile`` wrap it in ``_Tellable`` and switch to purely
    sequential data-descriptor writes), so the bytes pass exactly once
    and the running sha256 equals a post-hoc hash of the file — without
    ``save_checkpoint`` re-reading the npz it just wrote.
    """

    def __init__(self, f):
        self._f = f
        self._h = hashlib.sha256()

    def write(self, data) -> int:
        # both update() and write() take buffer-protocol objects directly;
        # converting to bytes here would re-copy every checkpointed byte
        self._h.update(data)
        return self._f.write(data)

    def flush(self):
        self._f.flush()

    # presence of ``read`` makes np.savez treat this as file-like; both
    # read and tell raise so zipfile takes its non-seekable write path
    def read(self, *args):
        raise OSError("write-only hashing stream")

    def tell(self):
        raise OSError("non-seekable hashing stream")

    def seekable(self) -> bool:
        return False

    def hexdigest(self) -> str:
        return self._h.hexdigest()


def _write_npz_hashed(tmp_path: str, flat: dict) -> str:
    """Write ``flat`` to ``tmp_path``, returning the content sha256
    computed WHILE writing (no second pass): zipfile streams
    sequentially through the non-seekable wrapper."""
    with open(tmp_path, "wb") as f:
        hw = _HashingWriter(f)
        np.savez(hw, **flat)
    return hw.hexdigest()


def save_checkpoint(ckpt_dir: str, step: int, tree, extra: dict | None = None,
                    n_shards: int = 1, replicated: tuple = ()):
    """Publish checkpoint ``step`` atomically.

    With ``n_shards > 1`` (mesh serving, ``repro.runtime.mesh``) the
    arrays are split into per-replica files ``step_N.shard<r>of<R>.npz``:
    every key whose top-level name is NOT in ``replicated`` is split
    into ``n_shards`` contiguous axis-0 blocks (the NamedSharding layout
    of a sharded slot axis), one per file; replicated keys (e.g. shared
    prefix-forest tables) are stored once, in shard 0.  Shard 0 is
    published LAST and is the commit point — ``checkpoint_steps`` only
    lists a sharded step once shard 0 is visible, and the manifest
    carries every shard's content hash so ``validate_checkpoint`` proves
    the whole set belongs together.
    """
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    out, man_out = _paths(ckpt_dir, step)
    man_tmp = os.path.join(ckpt_dir, f".tmp_step_{step}.json")

    if n_shards <= 1:
        tmp = os.path.join(ckpt_dir, f".tmp_step_{step}.npz")
        digest = _write_npz_hashed(tmp, flat)
        # the manifest records the npz content hash: overwriting an
        # existing step is two replaces, and the hash is what ties the
        # PAIR together — a crash between them leaves a new manifest
        # with an old npz, which validate_checkpoint then rejects as
        # torn instead of silently restoring mismatched state
        manifest = {"step": step, "n_arrays": len(flat),
                    "npz_sha256": digest, **(extra or {})}
        with open(man_tmp, "w") as f:
            json.dump(manifest, f)
        os.replace(man_tmp, man_out)       # manifest published first ...
        os.replace(tmp, out)               # ... npz last: the commit point
        return out

    repl = set(replicated)
    shard_flats: list[dict] = [{} for _ in range(n_shards)]
    for key, arr in flat.items():
        if key.split(SEP, 1)[0] in repl or arr.ndim == 0:
            shard_flats[0][key] = arr
            continue
        if arr.shape[0] % n_shards:
            raise ValueError(
                f"cannot shard {key!r}: axis-0 size {arr.shape[0]} not "
                f"divisible by n_shards={n_shards}")
        block = arr.shape[0] // n_shards
        for r in range(n_shards):
            shard_flats[r][key] = arr[r * block:(r + 1) * block]

    tmps, digests = [], []
    for r in range(n_shards):
        tmp = os.path.join(ckpt_dir, f".tmp_step_{step}.shard{r}.npz")
        digests.append(_write_npz_hashed(tmp, shard_flats[r]))
        tmps.append(tmp)
    manifest = {"step": step, "n_arrays": len(flat),
                "shards": {"n": n_shards, "sha256": digests},
                **(extra or {})}
    with open(man_tmp, "w") as f:
        json.dump(manifest, f)
    os.replace(man_tmp, man_out)           # manifest first ...
    for r in range(n_shards - 1, -1, -1):  # ... shard 0 last: commit point
        os.replace(tmps[r], _shard_path(ckpt_dir, step, r, n_shards))
    return _shard_path(ckpt_dir, step, 0, n_shards)


def _delta_prev(manifest: dict) -> int | None:
    """The previous step a delta manifest chains to (``None`` if the
    manifest is self-contained)."""
    for k, v in manifest.items():
        if k.endswith("_delta") and isinstance(v, dict) and "prev" in v:
            return int(v["prev"])
    return None


def prune_checkpoints(ckpt_dir: str, keep_last: int) -> list[int]:
    """Delete all but the newest ``keep_last`` published checkpoints;
    returns the pruned step ids.  A long-lived serving loop checkpoints
    forever — without retention the directory grows without bound.

    Delta-chain aware: arrays (npz / shard files) of pruned steps always
    go, but a pruned step's JSON manifest survives while any KEPT step's
    delta chain still references it — deleting the link would tear every
    downstream delta manifest back to the last compacted base."""
    if keep_last <= 0:
        raise ValueError("keep_last must be positive")
    steps = checkpoint_steps(ckpt_dir)
    pruned, kept = steps[:-keep_last], steps[-keep_last:]
    needed: set[int] = set()
    for s in kept:
        cur: int | None = s
        while cur is not None and cur not in needed:
            needed.add(cur)
            try:
                cur = _delta_prev(load_manifest(ckpt_dir, cur))
            except CheckpointError:
                break
    for step in pruned:
        npz, _ = _paths(ckpt_dir, step)
        for path in [npz] + _shard_files(ckpt_dir, step):
            try:
                os.remove(path)
            except OSError:
                pass
    # manifest sweep: every JSON not referenced by a kept step's chain
    # goes — including manifests ORPHANED by earlier prunes (kept for a
    # chain that has since compacted away), so retention stays bounded
    keep_man = needed | set(kept)
    for f in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)\.json", f)
        if m and int(m.group(1)) not in keep_man:
            try:
                os.remove(os.path.join(ckpt_dir, f))
            except OSError:
                pass
    return pruned


def _shard_files(ckpt_dir: str, step: int) -> list[str]:
    if not os.path.isdir(ckpt_dir):
        return []
    pat = re.compile(rf"step_{step}\.shard\d+of\d+\.npz")
    return [os.path.join(ckpt_dir, f) for f in os.listdir(ckpt_dir)
            if pat.fullmatch(f)]


def checkpoint_steps(ckpt_dir: str) -> list[int]:
    """All steps with published arrays, ascending (not validated).  A
    sharded step counts once its shard-0 file — the commit point — is
    visible."""
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted({int(m.group(1)) for f in os.listdir(ckpt_dir)
                   if (m := re.fullmatch(
                       r"step_(\d+)(?:\.shard0of\d+)?\.npz", f))})


def validate_checkpoint(ckpt_dir: str, step: int) -> None:
    """Raise ``CheckpointError`` if checkpoint ``step`` is torn/partial.

    Checks: the JSON manifest exists and parses, and the ``.npz`` is
    byte-identical to what ``save_checkpoint`` wrote (``npz_sha256`` in
    the manifest — this both detects torn files AND proves the
    manifest/npz PAIR belongs together after a crash mid-overwrite of an
    existing step).  A manifest without a hash (foreign writer) falls
    back to a zip CRC scan; either way the npz is read once.
    """
    npz, man = _paths(ckpt_dir, step)
    try:
        with open(man) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointError(f"step {step}: bad manifest {man}: {e}") from e
    shards = manifest.get("shards")
    if shards is not None:
        n = int(shards["n"])
        for r, want in enumerate(shards["sha256"]):
            path = _shard_path(ckpt_dir, step, r, n)
            try:
                got = _sha256(path)
            except OSError as e:
                raise CheckpointError(
                    f"step {step}: missing shard {path}: {e}") from e
            if got != want:
                raise CheckpointError(
                    f"step {step}: shard {r}/{n} does not match its "
                    "manifest hash (torn write?)")
        return
    want = manifest.get("npz_sha256")
    try:
        if want is not None:
            if want != _sha256(npz):
                raise CheckpointError(
                    f"step {step}: manifest does not match {npz} "
                    "(torn write, or crash while overwriting the step?)")
        else:
            with zipfile.ZipFile(npz) as z:
                bad = z.testzip()
                if bad is not None:
                    raise CheckpointError(
                        f"step {step}: corrupt member {bad!r} in {npz}")
    except (zipfile.BadZipFile, OSError, EOFError) as e:
        raise CheckpointError(f"step {step}: torn archive {npz}: {e}") from e


def latest_step(ckpt_dir: str, validate: bool = True) -> int | None:
    """Newest *usable* checkpoint step (``None`` if there is none).

    With ``validate`` (default), torn/partial checkpoints are skipped, so
    a crash mid-write can never wedge the restore path on a bad file.
    """
    steps = checkpoint_steps(ckpt_dir)
    if not validate:
        return steps[-1] if steps else None
    for step in reversed(steps):
        try:
            validate_checkpoint(ckpt_dir, step)
            return step
        except CheckpointError:
            continue
    return None


def load_manifest(ckpt_dir: str, step: int) -> dict:
    """The JSON manifest written alongside ``step``'s arrays."""
    _, man = _paths(ckpt_dir, step)
    try:
        with open(man) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointError(f"step {step}: bad manifest {man}: {e}") from e


# --------------------------------------------------------------------- #
# Incremental manifests: base + per-step deltas
# --------------------------------------------------------------------- #
# A service with 10^5 tenants cannot re-serialize every query spec on
# every checkpoint step; instead it writes a full ("compacted") manifest
# every K steps and small structural diffs in between.  The patch
# format is JSON-safe and unambiguous:
#   {"__deleted__": true}   delete this key
#   {"__replace__": v}      set this key to the literal value v
#   any other dict          recurse (nested patch)
#   any non-dict value      set this key to the value
def dict_diff(old: dict, new: dict) -> dict:
    """Minimal patch such that ``apply_patch(old, patch) == new``."""
    patch: dict = {}
    for k in old:
        if k not in new:
            patch[k] = {"__deleted__": True}
    for k, v in new.items():
        if k in old:
            ov = old[k]
            if ov == v:
                continue
            if isinstance(ov, dict) and isinstance(v, dict):
                sub = dict_diff(ov, v)
                if sub:
                    patch[k] = sub
                continue
        patch[k] = {"__replace__": v} if isinstance(v, dict) else v
    return patch


def apply_patch(base: dict, patch: dict) -> dict:
    out = dict(base)
    for k, v in patch.items():
        if isinstance(v, dict):
            if v.get("__deleted__") is True and len(v) == 1:
                out.pop(k, None)
            elif "__replace__" in v and len(v) == 1:
                out[k] = v["__replace__"]
            else:
                out[k] = apply_patch(
                    out.get(k, {}) if isinstance(out.get(k), dict) else {}, v)
        else:
            out[k] = v
    return out


def load_resolved_manifest(ckpt_dir: str, step: int, key: str) -> dict:
    """Resolve ``manifest[key]`` at ``step``, replaying delta manifests.

    A manifest either carries the full payload under ``key`` (a
    compacted base) or ``{key}_delta = {"prev": step, "patch": {...}}``;
    the chain is walked back to the nearest base and the patches are
    applied forward.  A torn chain — a pruned or unreadable link — is
    counted in ``N_DELTA_FALLBACKS``, warned about, and raised as
    ``CheckpointError`` so restore candidate loops fall back (loudly) to
    the last compacted base still on disk.
    """
    global N_DELTA_FALLBACKS
    patches: list[dict] = []
    seen: set[int] = set()
    cur = step
    while True:
        if cur in seen:
            raise CheckpointError(
                f"step {step}: delta manifest chain loops at {cur}")
        seen.add(cur)
        try:
            man = load_manifest(ckpt_dir, cur)
        except CheckpointError:
            if patches:          # torn mid-chain, not just a bad head
                N_DELTA_FALLBACKS += 1
                warnings.warn(
                    f"checkpoint step {step}: delta chain torn at step "
                    f"{cur}; falling back (N_DELTA_FALLBACKS="
                    f"{N_DELTA_FALLBACKS})", stacklevel=2)
            raise
        if key in man:
            base = man[key]
            break
        delta = man.get(f"{key}_delta")
        if delta is None:
            raise CheckpointError(
                f"step {cur}: manifest has neither {key!r} nor "
                f"'{key}_delta'")
        patches.append(delta["patch"])
        cur = int(delta["prev"])
    for patch in reversed(patches):
        base = apply_patch(base, patch)
    return base


def _load_sharded(ckpt_dir: str, step: int) -> dict:
    """Reassemble a sharded checkpoint's arrays into one flat dict."""
    files = _shard_files(ckpt_dir, step)
    m = re.search(r"shard\d+of(\d+)\.npz", os.path.basename(files[0]))
    n = int(m.group(1))
    ds = []
    for r in range(n):
        path = _shard_path(ckpt_dir, step, r, n)
        try:
            ds.append(np.load(path))
        except (OSError, zipfile.BadZipFile, ValueError, EOFError) as e:
            raise CheckpointError(
                f"step {step}: unreadable shard {path}: {e}") from e
    out: dict = {}
    shard_keys = set(ds[1].files) if n > 1 else set()
    for key in ds[0].files:
        if key in shard_keys:
            out[key] = np.concatenate([d[key] for d in ds], axis=0)
        else:
            out[key] = ds[0][key]           # replicated: stored once
    return out


def restore_checkpoint(ckpt_dir: str, step: int, like_tree,
                       mesh=None, specs=None):
    """Restore into the structure of ``like_tree``.

    With ``mesh``+``specs``: device_put every leaf with its NamedSharding
    (this IS the reshard — numpy leaves place onto any mesh shape).
    Raises ``CheckpointError`` for a torn file (missing or corrupt zip)
    so callers can fall back to an older step.  A *missing array* or a
    *shape* mismatch raises ``ValueError`` instead: the npz publishes
    atomically, so either one means the caller's state schema drifted —
    a real config error that must be loud, not silently skipped.

    Sharded checkpoints (``save_checkpoint(n_shards=...)``) reassemble
    transparently: keys present in every shard concatenate along axis 0
    in shard order, shard-0-only keys are replicated values — the
    result is mesh-agnostic host arrays, so a checkpoint written on R
    replicas restores onto any mesh size.
    """
    npz, _ = _paths(ckpt_dir, step)
    try:
        data = np.load(npz)
    except (OSError, zipfile.BadZipFile, ValueError, EOFError) as e:
        shard0 = _shard_files(ckpt_dir, step)
        if not shard0:
            raise CheckpointError(
                f"step {step}: unreadable {npz}: {e}") from e
        data = _load_sharded(ckpt_dir, step)
    flat_like, tdef = jax.tree.flatten(like_tree)
    flat_keys = [
        SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(like_tree)[0]
    ]
    leaves = []
    for key, like in zip(flat_keys, flat_like):
        try:
            arr = data[key]
        except KeyError as e:
            raise ValueError(
                f"step {step}: array {key!r} missing from {npz} "
                "(state schema drift?)") from e
        if arr.shape != like.shape:
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {like.shape}")
        leaves.append(arr.astype(like.dtype))
    tree = jax.tree.unflatten(tdef, leaves)
    if mesh is not None and specs is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(
                x, jax.sharding.NamedSharding(mesh, s)), tree, specs)
    return tree


def reshard(tree, mesh, specs):
    """Move a (possibly differently-sharded) pytree onto ``mesh``."""
    host = jax.tree.map(np.asarray, jax.device_get(tree))
    return jax.tree.map(
        lambda x, s: jax.device_put(x, jax.sharding.NamedSharding(mesh, s)),
        host, specs)


class AsyncCheckpointer:
    """Non-blocking checkpoint writer (single background thread, FIFO)."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._lock = threading.Lock()
        self._pending = []
        # cumulative seconds wait() spent blocked on unfinished writer
        # futures — the serve loop's checkpoint-stall time (obs: the
        # ``ckpt.stall_s`` gauge; BENCH_serve reports it per run)
        self.stall_s = 0.0

    def save(self, step: int, tree, extra: dict | None = None,
             keep_last: int | None = None, n_shards: int = 1,
             replicated: tuple = ()):
        """With ``keep_last``, older checkpoints are pruned on the writer
        thread AFTER the new step publishes (single-thread FIFO pool, so
        the prune can never race ahead of the write).  ``n_shards`` /
        ``replicated`` pass through to ``save_checkpoint`` (per-replica
        shard files for mesh services)."""
        host = jax.tree.map(np.asarray, jax.device_get(tree))  # sync snapshot

        def _write():
            out = save_checkpoint(self.ckpt_dir, step, host, extra,
                                  n_shards=n_shards, replicated=replicated)
            if keep_last is not None:
                prune_checkpoints(self.ckpt_dir, keep_last)
            return out

        fut = self._pool.submit(_write)
        with self._lock:
            self._pending.append(fut)
        return fut

    def wait(self):
        with self._lock:
            pending, self._pending = self._pending, []
        blocked = [f for f in pending if not f.done()]
        t0 = time.perf_counter() if blocked else 0.0
        for f in pending:
            f.result()
        if blocked:
            self.stall_s += time.perf_counter() - t0
