"""Checkpointing: pytree save/restore with mesh resharding + async writer.

Format: one ``step_<N>.npz`` per checkpoint (flattened key-path -> array)
plus a tiny JSON manifest.  Restore accepts a target mesh + PartitionSpec
tree, so a checkpoint written on one mesh restores onto any other mesh
(elastic scaling path — runtime/elastic.py round-trips through here).

The async writer snapshots to host memory synchronously (cheap: device->
host copy) and writes the file on a background thread, so the train loop
never blocks on disk.
"""

from __future__ import annotations

import json
import os
import re
import threading
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import jax


SEP = "::"


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def save_checkpoint(ckpt_dir: str, step: int, tree, extra: dict | None = None):
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}.npz")
    out = os.path.join(ckpt_dir, f"step_{step}.npz")
    np.savez(tmp, **flat)
    os.replace(tmp, out)                       # atomic publish
    manifest = {"step": step, "n_arrays": len(flat), **(extra or {})}
    with open(os.path.join(ckpt_dir, f"step_{step}.json"), "w") as f:
        json.dump(manifest, f)
    return out


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(m.group(1)) for f in os.listdir(ckpt_dir)
             if (m := re.fullmatch(r"step_(\d+)\.npz", f))]
    return max(steps) if steps else None


def restore_checkpoint(ckpt_dir: str, step: int, like_tree,
                       mesh=None, specs=None):
    """Restore into the structure of ``like_tree``.

    With ``mesh``+``specs``: device_put every leaf with its NamedSharding
    (this IS the reshard — numpy leaves place onto any mesh shape).
    """
    data = np.load(os.path.join(ckpt_dir, f"step_{step}.npz"))
    flat_like, tdef = jax.tree.flatten(like_tree)
    flat_keys = [
        SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(like_tree)[0]
    ]
    leaves = []
    for key, like in zip(flat_keys, flat_like):
        arr = data[key]
        if arr.shape != like.shape:
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {like.shape}")
        leaves.append(arr.astype(like.dtype))
    tree = jax.tree.unflatten(tdef, leaves)
    if mesh is not None and specs is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(
                x, jax.sharding.NamedSharding(mesh, s)), tree, specs)
    return tree


def reshard(tree, mesh, specs):
    """Move a (possibly differently-sharded) pytree onto ``mesh``."""
    host = jax.tree.map(np.asarray, jax.device_get(tree))
    return jax.tree.map(
        lambda x, s: jax.device_put(x, jax.sharding.NamedSharding(mesh, s)),
        host, specs)


class AsyncCheckpointer:
    """Non-blocking checkpoint writer (single background thread, FIFO)."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._lock = threading.Lock()
        self._pending = []

    def save(self, step: int, tree, extra: dict | None = None):
        host = jax.tree.map(np.asarray, jax.device_get(tree))  # sync snapshot
        fut = self._pool.submit(
            save_checkpoint, self.ckpt_dir, step, host, extra)
        with self._lock:
            self._pending.append(fut)
        return fut

    def wait(self):
        with self._lock:
            pending, self._pending = self._pending, []
        for f in pending:
            f.result()
