"""Checkpointing: pytree save/restore with mesh resharding + async writer.

Format: one ``step_<N>.npz`` per checkpoint (flattened key-path -> array)
plus a JSON manifest ``step_<N>.json`` carrying caller metadata (the
``extra`` dict — e.g. the ContinuousSearchService serialises its whole
registry/slot layout there).  Restore accepts a target mesh +
PartitionSpec tree, so a checkpoint written on one mesh restores onto any
other mesh (elastic scaling path — runtime/elastic.py round-trips
through here).

Crash consistency: both files are written to a temp name and published
with ``os.replace`` (atomic on POSIX), manifest first and the ``.npz``
last — the ``.npz`` is the commit point, so a visible checkpoint always
has a readable manifest.  A torn/partial checkpoint (truncated zip,
unparseable or missing manifest — e.g. files from a crashed writer or a
bad disk) is *skipped* by ``latest_step`` and surfaces from
``restore_checkpoint``/``load_manifest`` as ``CheckpointError`` so
recovery paths can fall back to the previous step instead of crashing.

The async writer snapshots to host memory synchronously (cheap: device->
host copy) and writes the file on a background thread, so the serving /
train loop never blocks on disk.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import threading
import zipfile
from concurrent.futures import ThreadPoolExecutor

import numpy as np
import jax


SEP = "::"


class CheckpointError(RuntimeError):
    """A checkpoint on disk is torn, partial, or unreadable."""


def _flatten(tree):
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = SEP.join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        flat[key] = np.asarray(leaf)
    return flat


def _paths(ckpt_dir: str, step: int) -> tuple[str, str]:
    return (os.path.join(ckpt_dir, f"step_{step}.npz"),
            os.path.join(ckpt_dir, f"step_{step}.json"))


def _sha256(path: str) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for block in iter(lambda: f.read(1 << 20), b""):
            h.update(block)
    return h.hexdigest()


class _HashingWriter:
    """Write-through file wrapper that hashes bytes as they stream past.

    A naive hash-on-write breaks under ``zipfile``: with a seekable
    output it backpatches each member's local header (CRC/sizes) after
    writing the data, invalidating any running prefix hash.  This
    wrapper therefore *refuses to be seekable* (``tell`` raises, which
    makes ``zipfile`` wrap it in ``_Tellable`` and switch to purely
    sequential data-descriptor writes), so the bytes pass exactly once
    and the running sha256 equals a post-hoc hash of the file — without
    ``save_checkpoint`` re-reading the npz it just wrote.
    """

    def __init__(self, f):
        self._f = f
        self._h = hashlib.sha256()

    def write(self, data) -> int:
        # both update() and write() take buffer-protocol objects directly;
        # converting to bytes here would re-copy every checkpointed byte
        self._h.update(data)
        return self._f.write(data)

    def flush(self):
        self._f.flush()

    # presence of ``read`` makes np.savez treat this as file-like; both
    # read and tell raise so zipfile takes its non-seekable write path
    def read(self, *args):
        raise OSError("write-only hashing stream")

    def tell(self):
        raise OSError("non-seekable hashing stream")

    def seekable(self) -> bool:
        return False

    def hexdigest(self) -> str:
        return self._h.hexdigest()


def save_checkpoint(ckpt_dir: str, step: int, tree, extra: dict | None = None):
    os.makedirs(ckpt_dir, exist_ok=True)
    flat = _flatten(tree)
    out, man_out = _paths(ckpt_dir, step)
    tmp = os.path.join(ckpt_dir, f".tmp_step_{step}.npz")
    # hash WHILE writing (no second pass over the npz): zipfile streams
    # sequentially through the non-seekable wrapper
    with open(tmp, "wb") as f:
        hw = _HashingWriter(f)
        np.savez(hw, **flat)
    # the manifest records the npz content hash: overwriting an existing
    # step is two replaces, and the hash is what ties the PAIR together —
    # a crash between them leaves a new manifest with an old npz, which
    # validate_checkpoint then rejects as torn instead of silently
    # restoring mismatched state
    manifest = {"step": step, "n_arrays": len(flat),
                "npz_sha256": hw.hexdigest(), **(extra or {})}
    man_tmp = os.path.join(ckpt_dir, f".tmp_step_{step}.json")
    with open(man_tmp, "w") as f:
        json.dump(manifest, f)
    os.replace(man_tmp, man_out)               # manifest published first ...
    os.replace(tmp, out)                       # ... npz last: the commit point
    return out


def prune_checkpoints(ckpt_dir: str, keep_last: int) -> list[int]:
    """Delete all but the newest ``keep_last`` published checkpoints;
    returns the pruned step ids.  A long-lived serving loop checkpoints
    forever — without retention the directory grows without bound."""
    if keep_last <= 0:
        raise ValueError("keep_last must be positive")
    pruned = checkpoint_steps(ckpt_dir)[:-keep_last]
    for step in pruned:
        for path in _paths(ckpt_dir, step):
            try:
                os.remove(path)
            except OSError:
                pass
    return pruned


def checkpoint_steps(ckpt_dir: str) -> list[int]:
    """All steps with a published ``.npz``, ascending (not validated)."""
    if not os.path.isdir(ckpt_dir):
        return []
    return sorted(int(m.group(1)) for f in os.listdir(ckpt_dir)
                  if (m := re.fullmatch(r"step_(\d+)\.npz", f)))


def validate_checkpoint(ckpt_dir: str, step: int) -> None:
    """Raise ``CheckpointError`` if checkpoint ``step`` is torn/partial.

    Checks: the JSON manifest exists and parses, and the ``.npz`` is
    byte-identical to what ``save_checkpoint`` wrote (``npz_sha256`` in
    the manifest — this both detects torn files AND proves the
    manifest/npz PAIR belongs together after a crash mid-overwrite of an
    existing step).  A manifest without a hash (foreign writer) falls
    back to a zip CRC scan; either way the npz is read once.
    """
    npz, man = _paths(ckpt_dir, step)
    try:
        with open(man) as f:
            manifest = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointError(f"step {step}: bad manifest {man}: {e}") from e
    want = manifest.get("npz_sha256")
    try:
        if want is not None:
            if want != _sha256(npz):
                raise CheckpointError(
                    f"step {step}: manifest does not match {npz} "
                    "(torn write, or crash while overwriting the step?)")
        else:
            with zipfile.ZipFile(npz) as z:
                bad = z.testzip()
                if bad is not None:
                    raise CheckpointError(
                        f"step {step}: corrupt member {bad!r} in {npz}")
    except (zipfile.BadZipFile, OSError, EOFError) as e:
        raise CheckpointError(f"step {step}: torn archive {npz}: {e}") from e


def latest_step(ckpt_dir: str, validate: bool = True) -> int | None:
    """Newest *usable* checkpoint step (``None`` if there is none).

    With ``validate`` (default), torn/partial checkpoints are skipped, so
    a crash mid-write can never wedge the restore path on a bad file.
    """
    steps = checkpoint_steps(ckpt_dir)
    if not validate:
        return steps[-1] if steps else None
    for step in reversed(steps):
        try:
            validate_checkpoint(ckpt_dir, step)
            return step
        except CheckpointError:
            continue
    return None


def load_manifest(ckpt_dir: str, step: int) -> dict:
    """The JSON manifest written alongside ``step``'s arrays."""
    _, man = _paths(ckpt_dir, step)
    try:
        with open(man) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        raise CheckpointError(f"step {step}: bad manifest {man}: {e}") from e


def restore_checkpoint(ckpt_dir: str, step: int, like_tree,
                       mesh=None, specs=None):
    """Restore into the structure of ``like_tree``.

    With ``mesh``+``specs``: device_put every leaf with its NamedSharding
    (this IS the reshard — numpy leaves place onto any mesh shape).
    Raises ``CheckpointError`` for a torn file (missing or corrupt zip)
    so callers can fall back to an older step.  A *missing array* or a
    *shape* mismatch raises ``ValueError`` instead: the npz publishes
    atomically, so either one means the caller's state schema drifted —
    a real config error that must be loud, not silently skipped.
    """
    npz, _ = _paths(ckpt_dir, step)
    try:
        data = np.load(npz)
    except (OSError, zipfile.BadZipFile, ValueError, EOFError) as e:
        raise CheckpointError(f"step {step}: unreadable {npz}: {e}") from e
    flat_like, tdef = jax.tree.flatten(like_tree)
    flat_keys = [
        SEP.join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        for path, _ in jax.tree_util.tree_flatten_with_path(like_tree)[0]
    ]
    leaves = []
    for key, like in zip(flat_keys, flat_like):
        try:
            arr = data[key]
        except KeyError as e:
            raise ValueError(
                f"step {step}: array {key!r} missing from {npz} "
                "(state schema drift?)") from e
        if arr.shape != like.shape:
            raise ValueError(f"shape mismatch for {key}: "
                             f"{arr.shape} vs {like.shape}")
        leaves.append(arr.astype(like.dtype))
    tree = jax.tree.unflatten(tdef, leaves)
    if mesh is not None and specs is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(
                x, jax.sharding.NamedSharding(mesh, s)), tree, specs)
    return tree


def reshard(tree, mesh, specs):
    """Move a (possibly differently-sharded) pytree onto ``mesh``."""
    host = jax.tree.map(np.asarray, jax.device_get(tree))
    return jax.tree.map(
        lambda x, s: jax.device_put(x, jax.sharding.NamedSharding(mesh, s)),
        host, specs)


class AsyncCheckpointer:
    """Non-blocking checkpoint writer (single background thread, FIFO)."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._pool = ThreadPoolExecutor(max_workers=1)
        self._lock = threading.Lock()
        self._pending = []

    def save(self, step: int, tree, extra: dict | None = None,
             keep_last: int | None = None):
        """With ``keep_last``, older checkpoints are pruned on the writer
        thread AFTER the new step publishes (single-thread FIFO pool, so
        the prune can never race ahead of the write)."""
        host = jax.tree.map(np.asarray, jax.device_get(tree))  # sync snapshot

        def _write():
            out = save_checkpoint(self.ckpt_dir, step, host, extra)
            if keep_last is not None:
                prune_checkpoints(self.ckpt_dir, keep_last)
            return out

        fut = self._pool.submit(_write)
        with self._lock:
            self._pending.append(fut)
        return fut

    def wait(self):
        with self._lock:
            pending, self._pending = self._pending, []
        for f in pending:
            f.result()
