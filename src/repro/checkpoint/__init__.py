from repro.checkpoint.ckpt import (
    AsyncCheckpointer,
    CheckpointError,
    checkpoint_steps,
    latest_step,
    load_manifest,
    prune_checkpoints,
    reshard,
    restore_checkpoint,
    save_checkpoint,
    validate_checkpoint,
)
