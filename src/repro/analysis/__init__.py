"""Static analysis gate for the serving stack (``python -m repro.analysis``).

Three passes, one findings currency:

* ``ast_lint``     — tracing-hazard linter over jit-/pallas-reachable code;
* ``kernel_check`` — Pallas BlockSpec/tile/SMEM contracts proven over the
  reachable shape lattice, plus kernel-vs-ref abstract evaluation;
* ``plan_check``   — the paper's decomposition invariants, also enforced
  at ``QueryRegistry.register`` time via ``verify_plan``.
"""

from repro.analysis.findings import (
    ERROR, INFO, SEVERITIES, WARNING, Baseline, Finding, Report,
    load_baseline)
from repro.analysis.plan_check import (
    PlanInvariantError, check_plan, verify_corpus, verify_plan)

__all__ = [
    "ERROR", "INFO", "WARNING", "SEVERITIES",
    "Baseline", "Finding", "Report", "load_baseline",
    "PlanInvariantError", "check_plan", "verify_plan", "verify_corpus",
]
