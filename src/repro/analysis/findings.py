"""Findings, severities, baselines: the shared currency of all passes.

Every analysis pass (``ast_lint``, ``kernel_check``, ``plan_check``)
emits a flat list of ``Finding`` records.  A finding is identified for
baseline purposes by its *stable key* — pass, rule, file, and enclosing
symbol — deliberately excluding the line number, so unrelated edits that
shift lines do not invalidate suppressions.

Severities
----------
``error``    Violates a contract the stack depends on (would recompile
             per tick, crash under jit, read out of bounds, or serve a
             plan whose decomposition breaks the paper's semantics).
             CI fails on any non-baselined error; the shipped baseline
             must contain none (enforced by ``load_baseline``).
``warning``  A hazard or a missed optimization (e.g. a jitted tick
             threading large state without ``donate_argnums``).  Fails
             CI only under ``--error-on-findings``; may be baselined
             with a written justification.
``info``     Advisory (e.g. a registered query that is not in canonical
             form, so isomorphic authorings may not share a compiled
             tick).  Never fails CI and needs no baseline entry.

Suppression
-----------
Two mechanisms, both requiring an explicit trace:

* inline: a ``# analysis: ignore[RULE]`` comment on the flagged line
  (handled by ``ast_lint``; line-targeted hazards only);
* baseline: an entry in the repo-root ``analysis_baseline.json`` with a
  non-empty ``justification`` string, matched by stable key.
"""

from __future__ import annotations

import json
from dataclasses import dataclass, field

ERROR = "error"
WARNING = "warning"
INFO = "info"
SEVERITIES = (ERROR, WARNING, INFO)


@dataclass(frozen=True)
class Finding:
    """One analysis finding."""

    pass_name: str          # "lint" | "kernel" | "plan"
    rule: str               # e.g. "TRC101"
    severity: str           # ERROR / WARNING / INFO
    path: str               # repo-relative file ("" for synthetic plans)
    line: int               # 1-based line, 0 when not line-anchored
    symbol: str             # enclosing function / kernel / plan name
    message: str

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"unknown severity {self.severity!r}")

    @property
    def key(self) -> tuple[str, str, str, str]:
        """Stable identity used for baseline matching (no line number)."""
        return (self.pass_name, self.rule, self.path, self.symbol)

    def to_json(self) -> dict:
        return {
            "pass": self.pass_name,
            "rule": self.rule,
            "severity": self.severity,
            "path": self.path,
            "line": self.line,
            "symbol": self.symbol,
            "message": self.message,
        }

    def format(self) -> str:
        loc = f"{self.path}:{self.line}" if self.path else "<plan>"
        return (f"{loc}: {self.severity} {self.rule} [{self.symbol}] "
                f"{self.message}")


@dataclass
class Baseline:
    """Parsed ``analysis_baseline.json``: keyed suppressions."""

    entries: dict[tuple, str] = field(default_factory=dict)  # key -> why
    path: str = ""

    def suppresses(self, f: Finding) -> bool:
        return f.key in self.entries


def load_baseline(path: str) -> Baseline:
    """Load a baseline file; absent file = empty baseline.

    Enforces the shipping contract: every entry names a justification,
    and no entry may suppress an ERROR-severity finding (errors must be
    fixed, not baselined).
    """
    try:
        with open(path) as fh:
            doc = json.load(fh)
    except FileNotFoundError:
        return Baseline(path=path)
    entries: dict[tuple, str] = {}
    for ent in doc.get("suppressions", []):
        why = ent.get("justification", "").strip()
        if not why:
            raise ValueError(
                f"baseline entry {ent} has no justification "
                f"(required for every suppression)")
        if ent.get("severity") == ERROR:
            raise ValueError(
                f"baseline entry {ent} suppresses an error-severity "
                f"finding; errors must be fixed, not baselined")
        key = (ent["pass"], ent["rule"], ent["path"], ent["symbol"])
        entries[key] = why
    return Baseline(entries=entries, path=path)


@dataclass
class Report:
    """Aggregated output of an analysis run."""

    findings: list[Finding] = field(default_factory=list)
    suppressed: list[Finding] = field(default_factory=list)
    stats: dict = field(default_factory=dict)

    def split_by_baseline(self, baseline: Baseline) -> "Report":
        live = [f for f in self.findings if not baseline.suppresses(f)]
        gone = [f for f in self.findings if baseline.suppresses(f)]
        return Report(findings=live, suppressed=self.suppressed + gone,
                      stats=dict(self.stats))

    def by_severity(self) -> dict[str, int]:
        out = {s: 0 for s in SEVERITIES}
        for f in self.findings:
            out[f.severity] += 1
        return out

    def to_json(self) -> dict:
        return {
            "schema": "repro_analysis/v1",
            "stats": self.stats,
            "findings_by_severity": self.by_severity(),
            "findings": [f.to_json() for f in sorted(
                self.findings, key=lambda f: (f.path, f.line, f.rule))],
            "suppressed": [f.to_json() for f in sorted(
                self.suppressed, key=lambda f: (f.path, f.line, f.rule))],
        }
