"""Tracing-hazard linter: AST rules over jit- and pallas-reachable code.

The serving stack's recompile-free guarantees (one compiled slot tick
per structure, traced per-slot windows, scalar-prefetch kernel inputs)
are easy to break silently: a single Python ``int()`` on a traced value,
an ``np.*`` call inside a tick body, or a builder closing over a dynamic
value turns "zero recompiles" into "one recompile per tick" — or into a
``TracerBoolConversionError`` the first time an untested path runs under
jit.  This pass finds those hazards statically.

How traced scope is computed
----------------------------
1. **Roots.** A function is a traced root if it is (a) decorated with /
   wrapped in ``jax.jit`` (including ``functools.partial(jax.jit, ...)``
   decorators), (b) the kernel body of a ``pl.pallas_call`` (resolved
   through ``functools.partial``), (c) wrapped by ``custom_vmap`` /
   ``def_vmap``, or (d) defined inside a ``build_*`` / ``make_*``
   function — the repo-wide idiom for "returns a jit-able closure".
2. **Reachability.** Roots are closed over a project-wide call graph
   (names resolved through ``from repro.x import f`` / ``import
   repro.x as y`` aliases), so helpers like ``core.join.join_pairs``
   are analyzed in traced context even though they are plain functions.
3. **Taint.** Inside a *root*, positional parameters are traced values
   unless their name marks them static (keyword-only parameters — the
   kernel convention for specialization constants — and
   ``STATIC_PARAMS`` names like ``plan`` / ``rel`` / ``backend`` are
   never traced).  For *reachable* functions, parameter taint flows in
   from call sites, so e.g. ``_trel_chain(prev.ets.shape[1])`` — a
   static shape — does not taint the callee.  Taint dies at ``.shape``
   / ``.dtype`` / ``len()`` (static under jit) and propagates through
   assignments, tuple unpacking and arithmetic; ``zip()`` unpacking is
   tracked per-position so static flag tuples riding next to traced
   refs stay untainted.

Rules
-----
TRC101 error    Python ``int()``/``float()``/``bool()`` cast on a traced
                value (concretization error / silent host sync).
TRC102 error    ``np.*`` call on a traced value (host compute inside a
                traced computation; breaks jit and pallas lowering).
TRC103 error    Host sync on a traced value: ``.tolist()`` / ``.item()``
                / ``.block_until_ready()`` / ``jax.device_get``.
TRC104 error    Python control flow (``if`` / ``while`` / ternary /
                ``assert``) on a traced value (``x is None`` checks are
                exempt — identity, not value).
TRC105 warning  A ``build_*`` / ``make_*`` builder's inner traced
                function closes over a non-structural builder parameter
                — the value becomes a compile-time constant, so every
                distinct value recompiles (the exact bug class PR 2
                fixed by making ``window`` a runtime input).
TRC106 warning  ``jax.jit`` wrapping a ``build_*tick*`` product without
                ``donate_argnums`` — the tick threads its (large) state
                through every call, so not donating doubles steady-state
                table memory traffic.
TRC107 error    ``repro.obs`` span/metric emission (``.span`` /
                ``.record`` / ``.event`` / ``.observe`` / ``.inc`` /
                ``.next_tick``) inside a traced function — a host
                callback inside jit either fails to trace or silently
                runs once at trace time; all instrumentation must stay
                on the host side of the serve loop.  Only modules that
                import ``repro.obs`` are checked (the attribute names
                alone are too generic); the ``n_obs_sites`` census
                counts every emission site tree-wide either way.

Suppression: ``# analysis: ignore[TRC105]`` (or bare ``ignore``) on the
flagged line; severities and the baseline workflow are described in
``repro.analysis.findings``.
"""

from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field

from repro.analysis.findings import ERROR, WARNING, Finding

# Parameter names that are structural / static by convention everywhere
# in this repo: never treated as traced values, allowed as builder
# closures.  Keep sorted; additions need a matching idiom in src.
STATIC_PARAMS = frozenset({
    "self", "cls",
    # plan / spec structure
    "plan", "plans", "template_plan", "spec", "specs", "q", "query",
    # backend / mode switches
    "backend", "interpret", "jit", "donate", "extract_matches",
    # static shapes & capacities
    "capacity", "max_new", "max_out", "n_slots", "n_shards", "n_nodes",
    "n_bags", "size", "prefix_depth",
    # kernel specialization constants
    "rel", "trel", "has_window", "tile_a", "tile_b", "tile_n", "tile_e",
    "batched", "acc_dtype", "axis_name", "axis_size", "in_batched",
    # model / training configs (hashable static pytrees)
    "cfg", "ocfg", "config", "mesh", "microbatches",
})

_BUILDER_RE = re.compile(r"^(build|make)_")
_IGNORE_RE = re.compile(r"#\s*analysis:\s*ignore(?:\[([A-Za-z0-9_,\s]+)\])?")
_KILL_ATTRS = frozenset({"shape", "dtype", "ndim", "size", "nbytes"})
_KILL_CALLS = frozenset({"len", "range", "isinstance", "type", "repr",
                         "str", "enumerate"})
_CAST_CALLS = frozenset({"int", "float", "bool"})
_SYNC_ATTRS = frozenset({"tolist", "item", "block_until_ready"})
# repro.obs emission attributes (TRC107 + the n_obs_sites census).
# ``.set`` is deliberately excluded: too generic an attribute name to
# attribute to the obs layer from syntax alone.
_OBS_EMIT_ATTRS = frozenset({"span", "record", "event", "next_tick",
                             "observe", "inc", "set_total"})


@dataclass
class FuncInfo:
    """One analyzed function definition."""

    module: str                 # dotted module ("repro.core.engine")
    path: str                   # repo-relative file path
    qualname: str               # dotted within module ("build_tick.<tick>")
    node: ast.AST               # FunctionDef / AsyncFunctionDef
    parent: "FuncInfo | None"
    in_class: bool
    pos_params: tuple[str, ...]      # positional (incl. pos-or-kw + vararg)
    kwonly_params: tuple[str, ...]
    traced_root: bool = False
    seeded: bool = False        # positional params seeded as traced values
    shard_map_root: bool = False   # handed to a shard_map wrapper call
    traced: bool = False
    tainted_params: set[str] = field(default_factory=set)
    calls: list[tuple[str, ast.Call]] = field(default_factory=list)


@dataclass
class ModuleInfo:
    module: str
    path: str
    tree: ast.Module
    lines: list[str]
    # alias -> ("module", dotted) | ("func", (module, name))
    imports: dict[str, tuple] = field(default_factory=dict)
    functions: dict[str, FuncInfo] = field(default_factory=dict)  # qualname
    top_level: dict[str, FuncInfo] = field(default_factory=dict)  # name


# --------------------------------------------------------------------- #
# Collection
# --------------------------------------------------------------------- #
def _module_name(parent: str, path: str) -> str:
    """Dotted module for ``path`` relative to the dir containing the
    package root (src/repro/core/engine.py -> repro.core.engine)."""
    rel = os.path.relpath(path, parent).replace(os.sep, "/")
    parts = rel[:-3].split("/")            # strip .py
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def _collect_imports(tree: ast.Module) -> dict[str, tuple]:
    out: dict[str, tuple] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                out[a.asname or a.name.split(".")[0]] = (
                    "module", a.name if a.asname else a.name.split(".")[0])
        elif isinstance(node, ast.ImportFrom) and node.module:
            for a in node.names:
                if a.name == "*":
                    continue
                out[a.asname or a.name] = ("from", (node.module, a.name))
    return out


def _params(node) -> tuple[tuple[str, ...], tuple[str, ...]]:
    a = node.args
    pos = [p.arg for p in a.posonlyargs + a.args]
    if a.vararg:
        pos.append(a.vararg.arg)
    kw = [p.arg for p in a.kwonlyargs]
    return tuple(pos), tuple(kw)


def _collect_functions(mi: ModuleInfo) -> None:
    def visit(node, parent: FuncInfo | None, in_class: bool, prefix: str):
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                qual = f"{prefix}{child.name}"
                pos, kw = _params(child)
                fi = FuncInfo(module=mi.module, path=mi.path, qualname=qual,
                              node=child, parent=parent, in_class=in_class,
                              pos_params=pos, kwonly_params=kw)
                mi.functions[qual] = fi
                if parent is None and not in_class:
                    mi.top_level[child.name] = fi
                visit(child, fi, False, qual + ".")
            elif isinstance(child, ast.ClassDef):
                visit(child, parent, True, prefix + child.name + ".")
            else:
                visit(child, parent, in_class, prefix)

    visit(mi.tree, None, False, "")


def _resolves_to(mi: ModuleInfo, name: str, *targets: str) -> bool:
    """Does local alias ``name`` resolve to one of the given modules?"""
    ent = mi.imports.get(name)
    if ent is None:
        return name in targets
    if ent[0] == "module":
        top = ent[1].split(".")[0]
        return ent[1] in targets or top in targets
    mod, attr = ent[1]
    return f"{mod}.{attr}" in targets


def _is_numpy(mi: ModuleInfo, node: ast.expr) -> bool:
    return (isinstance(node, ast.Name)
            and _resolves_to(mi, node.id, "numpy", "np"))


def _is_jax_attr(mi: ModuleInfo, node: ast.expr, attr: str) -> bool:
    return (isinstance(node, ast.Attribute) and node.attr == attr
            and isinstance(node.value, ast.Name)
            and _resolves_to(mi, node.value.id, "jax"))


def _is_jit_expr(mi: ModuleInfo, node: ast.expr) -> bool:
    """``jax.jit`` / ``jit`` / ``functools.partial(jax.jit, ...)``."""
    if _is_jax_attr(mi, node, "jit"):
        return True
    if isinstance(node, ast.Name) and node.id == "jit":
        ent = mi.imports.get("jit")
        return bool(ent and ent[0] == "from" and ent[1][0] == "jax")
    if isinstance(node, ast.Call) and node.args:
        f = node.func
        is_partial = (isinstance(f, ast.Attribute) and f.attr == "partial") \
            or (isinstance(f, ast.Name) and f.id == "partial")
        return is_partial and _is_jit_expr(mi, node.args[0])
    return False


def _local_assign_value(fn_node, name: str) -> ast.expr | None:
    """Last simple ``name = <expr>`` assignment inside ``fn_node``."""
    found = None
    for node in ast.walk(fn_node):
        if isinstance(node, ast.Assign):
            for t in node.targets:
                if isinstance(t, ast.Name) and t.id == name:
                    found = node.value
    return found


def _resolve_callable_name(mi: ModuleInfo, scope, expr) -> str | None:
    """Resolve an expression to a local function qualname, looking
    through one level of ``functools.partial`` and local assignment."""
    if isinstance(expr, ast.Call):
        f = expr.func
        is_partial = (isinstance(f, ast.Attribute) and f.attr == "partial") \
            or (isinstance(f, ast.Name) and f.id == "partial")
        if is_partial and expr.args:
            return _resolve_callable_name(mi, scope, expr.args[0])
        return None
    if not isinstance(expr, ast.Name):
        return None
    # a function visible from this scope?
    for qual, fi in mi.functions.items():
        if qual.split(".")[-1] == expr.id:
            return qual
    val = _local_assign_value(scope, expr.id) if scope is not None else None
    if val is not None and not (isinstance(val, ast.Name)
                                and val.id == expr.id):
        return _resolve_callable_name(mi, scope, val)
    return None


# Attribute names that take a function and trace it (jax.vmap, lax.scan,
# pl.pallas_call, shard_map, custom batching, ...).
_TRACING_WRAPPERS = frozenset({
    "vmap", "pmap", "pallas_call", "scan", "while_loop", "fori_loop",
    "cond", "switch", "shard_map", "checkpoint", "remat",
    "custom_vmap", "grad", "value_and_grad",
})


def _own_returned_names(fn_node) -> set[str]:
    """Names appearing in ``return`` expressions of ``fn_node`` itself
    (nested function bodies excluded)."""
    out: set[str] = set()
    stack = list(ast.iter_child_nodes(fn_node))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            continue
        if isinstance(node, ast.Return) and node.value is not None:
            for sub in ast.walk(node.value):
                if isinstance(sub, ast.Name):
                    out.add(sub.id)
        stack.extend(ast.iter_child_nodes(node))
    return out


def _mark_roots(mi: ModuleInfo) -> None:
    for fi in mi.functions.values():
        for dec in fi.node.decorator_list:
            if _is_jit_expr(mi, dec):
                fi.traced_root = fi.seeded = True
            if isinstance(dec, ast.Name) and dec.id == "custom_vmap":
                fi.traced_root = fi.seeded = True
            if isinstance(dec, ast.Attribute) and dec.attr in (
                    "custom_vmap", "def_vmap"):
                fi.traced_root = fi.seeded = True
            # @pl.when(cond) wrapper decorators inside kernel bodies
            if isinstance(dec, ast.Call) and isinstance(
                    dec.func, ast.Attribute) and dec.func.attr == "when":
                fi.traced_root = True
        # Nested inside a build_* / make_* builder: part of the traced
        # computation (checked), but positional params are only *seeded*
        # as traced values if the builder returns the closure (or hands
        # it to a tracing wrapper, handled below) — build-time helpers
        # like engine._trel_chain take static args from their call
        # sites instead.
        p = fi.parent
        while p is not None:
            if _BUILDER_RE.match(p.qualname.split(".")[-1]):
                fi.traced_root = True
                break
            p = p.parent
        if (fi.parent is not None and fi.traced_root and not fi.seeded
                and _BUILDER_RE.match(
                    fi.parent.qualname.split(".")[-1])
                and fi.node.name in _own_returned_names(fi.parent.node)):
            fi.seeded = True

    # functions handed to jax.jit(...) or a tracing wrapper call.  The
    # leading-underscore strip covers import aliases like the compat
    # shim's ``shard_map as _shard_map`` (repro.core.compat consumers):
    # the aliased call must still mark its payload as a traced root.
    for node in ast.walk(mi.tree):
        if not isinstance(node, ast.Call) or not node.args:
            continue
        f = node.func
        wrap_name = None
        if isinstance(f, ast.Attribute):
            wrap_name = f.attr.lstrip("_")
        elif isinstance(f, ast.Name):
            wrap_name = f.id.lstrip("_")
        is_wrap = _is_jit_expr(mi, f) or wrap_name in _TRACING_WRAPPERS
        if not is_wrap:
            continue
        scope = _enclosing_function_node(mi, node)
        for arg in node.args:
            qual = _resolve_callable_name(mi, scope, arg)
            if qual is not None and qual in mi.functions:
                fi = mi.functions[qual]
                fi.traced_root = fi.seeded = True
                if wrap_name == "shard_map":
                    fi.shard_map_root = True


def _enclosing_function_node(mi: ModuleInfo, target) -> ast.AST | None:
    best = None
    for fi in mi.functions.values():
        for sub in ast.walk(fi.node):
            if sub is target:
                if best is None or _span(fi.node) < _span(best):
                    best = fi.node
                break
    return best


def _span(fn_node) -> int:
    return (fn_node.end_lineno or fn_node.lineno) - fn_node.lineno


# --------------------------------------------------------------------- #
# Taint
# --------------------------------------------------------------------- #
class _Taint:
    """Intra-procedural taint over local names of one function."""

    def __init__(self, mi: ModuleInfo, fi: FuncInfo):
        self.mi = mi
        self.fi = fi
        self.names: set[str] = set(fi.tainted_params)

    def expr(self, node) -> bool:
        if isinstance(node, ast.Name):
            return node.id in self.names
        if isinstance(node, ast.Attribute):
            if node.attr in _KILL_ATTRS:
                return False
            return self.expr(node.value)
        if isinstance(node, ast.Subscript):
            return self.expr(node.value)
        if isinstance(node, ast.Call):
            f = node.func
            if isinstance(f, ast.Name) and f.id in _KILL_CALLS:
                return False
            if isinstance(f, ast.Attribute) and f.attr in _KILL_ATTRS:
                return False
            args = list(node.args) + [k.value for k in node.keywords]
            return any(self.expr(a) for a in args) or self.expr(f)
        if isinstance(node, (ast.BinOp,)):
            return self.expr(node.left) or self.expr(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.expr(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.expr(v) for v in node.values)
        if isinstance(node, ast.Compare):
            return self.expr(node.left) or any(
                self.expr(c) for c in node.comparators)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(self.expr(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return self.expr(node.body) or self.expr(node.orelse)
        if isinstance(node, ast.Starred):
            return self.expr(node.value)
        if isinstance(node, (ast.GeneratorExp, ast.ListComp, ast.SetComp)):
            return self.expr(node.elt) or any(
                self.expr(g.iter) for g in node.generators)
        if isinstance(node, ast.DictComp):
            return (self.expr(node.key) or self.expr(node.value)
                    or any(self.expr(g.iter) for g in node.generators))
        return False

    def _bind_target(self, target, value_tainted: bool,
                     value: ast.expr | None = None) -> None:
        if isinstance(target, ast.Name):
            if value_tainted:
                self.names.add(target.id)
            return
        if isinstance(target, ast.Starred):
            self._bind_target(target.value, value_tainted)
            return
        if isinstance(target, (ast.Tuple, ast.List)):
            # zip() unpacking keeps per-position taint: static flag
            # tuples riding next to traced refs must stay untainted
            if (value is not None and isinstance(value, ast.Call)
                    and isinstance(value.func, ast.Name)
                    and value.func.id == "zip"
                    and len(value.args) == len(target.elts)):
                for t, a in zip(target.elts, value.args):
                    self._bind_target(t, self.expr(a))
                return
            for t in target.elts:
                self._bind_target(t, value_tainted)

    def run(self) -> None:
        """Two passes over the body (fixpoint for loop-carried taint)."""
        for _ in range(2):
            for node in ast.walk(self.fi.node):
                if isinstance(node, ast.Assign):
                    t = self.expr(node.value)
                    for tgt in node.targets:
                        self._bind_target(tgt, t, node.value)
                elif isinstance(node, ast.AugAssign):
                    if self.expr(node.value) or self.expr(node.target):
                        self._bind_target(node.target, True)
                elif isinstance(node, ast.AnnAssign) and node.value:
                    self._bind_target(node.target, self.expr(node.value),
                                      node.value)
                elif isinstance(node, ast.For):
                    self._bind_target(node.target, self.expr(node.iter),
                                      node.iter)
                elif isinstance(node, ast.comprehension):
                    self._bind_target(node.target, self.expr(node.iter),
                                      node.iter)
                elif isinstance(node, ast.NamedExpr):
                    self._bind_target(node.target, self.expr(node.value))


def _seed_root_taint(fi: FuncInfo) -> set[str]:
    return {p for p in fi.pos_params if p not in STATIC_PARAMS}


# --------------------------------------------------------------------- #
# Linter driver
# --------------------------------------------------------------------- #
class Linter:
    def __init__(self, root: str):
        self.root = root
        self.repo_root = os.path.dirname(os.path.dirname(
            os.path.abspath(root)))
        self.modules: dict[str, ModuleInfo] = {}
        self.findings: list[Finding] = []
        self.stats: dict = {}

    # ---------------- collection ---------------- #
    def load(self) -> None:
        for dirpath, _dirnames, filenames in sorted(os.walk(self.root)):
            for fn in sorted(filenames):
                if not fn.endswith(".py"):
                    continue
                path = os.path.join(dirpath, fn)
                with open(path) as fh:
                    src = fh.read()
                mod = _module_name(os.path.dirname(os.path.abspath(
                    self.root)), path)
                rel = os.path.relpath(path, self.repo_root)
                mi = ModuleInfo(module=mod, path=rel,
                                tree=ast.parse(src, filename=path),
                                lines=src.splitlines())
                mi.imports = _collect_imports(mi.tree)
                _collect_functions(mi)
                self.modules[mod] = mi

    def _resolve_call(self, mi: ModuleInfo, fi: FuncInfo,
                      node: ast.Call) -> FuncInfo | None:
        """Resolve a call target to a project FuncInfo (best effort)."""
        f = node.func
        if isinstance(f, ast.Name):
            # sibling nested function or module top-level
            scope = fi
            while scope is not None:
                cand = f"{scope.qualname}.{f.id}"
                if cand in mi.functions:
                    return mi.functions[cand]
                scope = scope.parent
            if f.id in mi.top_level:
                return mi.top_level[f.id]
            ent = mi.imports.get(f.id)
            if ent and ent[0] == "from":
                src_mod, name = ent[1]
                smi = self.modules.get(src_mod)
                if smi and name in smi.top_level:
                    return smi.top_level[name]
        elif isinstance(f, ast.Attribute) and isinstance(f.value, ast.Name):
            ent = mi.imports.get(f.value.id)
            if ent and ent[0] == "module":
                smi = self.modules.get(ent[1])
                if smi and f.attr in smi.top_level:
                    return smi.top_level[f.attr]
            if ent and ent[0] == "from":
                smi = self.modules.get(f"{ent[1][0]}.{ent[1][1]}")
                if smi and f.attr in smi.top_level:
                    return smi.top_level[f.attr]
        return None

    def _propagate(self) -> None:
        """Close tracedness + parameter taint over the call graph."""
        infos = [fi for mi in self.modules.values()
                 for fi in mi.functions.values()]
        for fi in infos:
            if fi.traced_root:
                fi.traced = True
                if fi.seeded:
                    fi.tainted_params = _seed_root_taint(fi)
        for _ in range(12):                      # small fixpoint
            changed = False
            for mi in self.modules.values():
                for fi in mi.functions.values():
                    # a def nested in traced scope is itself traced
                    if (not fi.traced and fi.parent is not None
                            and fi.parent.traced):
                        fi.traced = True
                        changed = True
                    if not fi.traced:
                        continue
                    taint = _Taint(mi, fi)
                    taint.run()
                    for node in ast.walk(fi.node):
                        if not isinstance(node, ast.Call):
                            continue
                        callee = self._resolve_call(mi, fi, node)
                        if callee is None or callee is fi:
                            continue
                        if not callee.traced:
                            callee.traced = True
                            changed = True
                        for i, a in enumerate(node.args):
                            if i >= len(callee.pos_params):
                                break
                            p = callee.pos_params[i]
                            if (p not in STATIC_PARAMS
                                    and p not in callee.tainted_params
                                    and taint.expr(a)):
                                callee.tainted_params.add(p)
                                changed = True
                        for kw in node.keywords:
                            if (kw.arg and kw.arg in callee.pos_params
                                    and kw.arg not in STATIC_PARAMS
                                    and kw.arg not in callee.tainted_params
                                    and taint.expr(kw.value)):
                                callee.tainted_params.add(kw.arg)
                                changed = True
            if not changed:
                break

    # ---------------- reporting ---------------- #
    def _ignored(self, mi: ModuleInfo, line: int, rule: str) -> bool:
        if not (1 <= line <= len(mi.lines)):
            return False
        m = _IGNORE_RE.search(mi.lines[line - 1])
        if not m:
            return False
        rules = m.group(1)
        if rules is None:
            return True
        return rule in {r.strip() for r in rules.split(",")}

    def _emit(self, mi: ModuleInfo, fi: FuncInfo, node, rule: str,
              severity: str, message: str) -> None:
        line = getattr(node, "lineno", fi.node.lineno)
        if self._ignored(mi, line, rule):
            return
        self.findings.append(Finding(
            pass_name="lint", rule=rule, severity=severity, path=mi.path,
            line=line, symbol=f"{mi.module}.{fi.qualname}", message=message))

    # ---------------- rules ---------------- #
    def _is_none_check(self, node) -> bool:
        """Trace-safe tests: identity (``x is None``) and string-key
        membership in a params dict (``"w3" in p`` checks keys, which
        are static structure under jit, not traced values)."""
        if isinstance(node, ast.Compare):
            if all(isinstance(op, (ast.Is, ast.IsNot)) for op in node.ops):
                return True
            return (all(isinstance(op, (ast.In, ast.NotIn))
                        for op in node.ops)
                    and isinstance(node.left, ast.Constant)
                    and isinstance(node.left.value, str))
        if isinstance(node, ast.UnaryOp) and isinstance(node.op, ast.Not):
            return self._is_none_check(node.operand)
        if isinstance(node, ast.BoolOp):
            return all(self._is_none_check(v) for v in node.values)
        return False

    def _check_traced_fn(self, mi: ModuleInfo, fi: FuncInfo) -> None:
        taint = _Taint(mi, fi)
        taint.run()
        own_nested = {f.node for q, f in mi.functions.items()
                      if f.parent is fi}
        for node in ast.walk(fi.node):
            if node in own_nested:
                continue                 # nested defs are checked on their own
            if isinstance(node, ast.Call):
                f = node.func
                args = list(node.args) + [k.value for k in node.keywords]
                any_tainted = any(taint.expr(a) for a in args)
                if (isinstance(f, ast.Name) and f.id in _CAST_CALLS
                        and any_tainted):
                    self._emit(mi, fi, node, "TRC101", ERROR,
                               f"Python {f.id}() on a traced value "
                               f"(concretizes under jit; host sync)")
                elif (isinstance(f, ast.Attribute)
                        and _is_numpy(mi, f.value) and any_tainted):
                    self._emit(mi, fi, node, "TRC102", ERROR,
                               f"np.{f.attr}() on a traced value (host "
                               f"compute inside traced scope; use jnp)")
                elif (isinstance(f, ast.Attribute)
                        and f.attr in _SYNC_ATTRS and taint.expr(f.value)):
                    self._emit(mi, fi, node, "TRC103", ERROR,
                               f".{f.attr}() on a traced value "
                               f"(device->host sync inside traced scope)")
                elif _is_jax_attr(mi, f, "device_get") and any_tainted:
                    self._emit(mi, fi, node, "TRC103", ERROR,
                               "jax.device_get on a traced value "
                               "(device->host sync inside traced scope)")
            elif isinstance(node, (ast.If, ast.While)):
                test = node.test
                if taint.expr(test) and not self._is_none_check(test):
                    kw = "while" if isinstance(node, ast.While) else "if"
                    self._emit(mi, fi, node, "TRC104", ERROR,
                               f"Python `{kw}` on a traced value (use "
                               f"jnp.where / lax.cond; traced bools "
                               f"cannot branch)")
            elif isinstance(node, ast.IfExp):
                if taint.expr(node.test) and not self._is_none_check(
                        node.test):
                    self._emit(mi, fi, node, "TRC104", ERROR,
                               "ternary on a traced value (use jnp.where)")
            elif isinstance(node, ast.Assert):
                if taint.expr(node.test) and not self._is_none_check(
                        node.test):
                    self._emit(mi, fi, node, "TRC104", ERROR,
                               "assert on a traced value (checkify or drop)")

    def _check_builder_closures(self, mi: ModuleInfo, fi: FuncInfo) -> None:
        """TRC105: inner traced fns closing over dynamic builder params."""
        if not _BUILDER_RE.match(fi.qualname.split(".")[-1]):
            return
        builder_params = [p for p in fi.pos_params + fi.kwonly_params
                          if p not in STATIC_PARAMS]
        if not builder_params:
            return
        inner = [f for f in mi.functions.values()
                 if f.parent is fi and f.traced]
        for child in inner:
            bound = set(child.pos_params) | set(child.kwonly_params)
            for sub in ast.walk(child.node):
                if isinstance(sub, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and sub is not child.node:
                    bound |= {a.arg for a in sub.args.args}
                if isinstance(sub, ast.Assign):
                    for t in sub.targets:
                        if isinstance(t, ast.Name):
                            bound.add(t.id)
            for sub in ast.walk(child.node):
                if (isinstance(sub, ast.Name)
                        and isinstance(sub.ctx, ast.Load)
                        and sub.id in builder_params
                        and sub.id not in bound):
                    self._emit(
                        mi, child, sub, "TRC105", WARNING,
                        f"traced closure captures builder parameter "
                        f"'{sub.id}' as a compile-time constant — every "
                        f"distinct value recompiles; make it a runtime "
                        f"input (cf. the PR-2 traced-window fix)")
                    break                         # one finding per capture

    @staticmethod
    def _imports_obs(mi: ModuleInfo) -> bool:
        for ent in mi.imports.values():
            if ent[0] == "module" and str(ent[1]).startswith("repro.obs"):
                return True
            if ent[0] == "from" and str(ent[1][0]).startswith("repro.obs"):
                return True
        return False

    def _check_obs_sites(self, mi: ModuleInfo) -> int:
        """TRC107 + census: ``repro.obs`` span/metric emission sites.

        Only modules importing ``repro.obs`` are scanned (the emission
        attribute names are too generic to attribute otherwise).
        Returns the module's site count; sites inside a TRACED function
        are host-callback-in-jit hazards and error."""
        if not self._imports_obs(mi):
            return 0
        n_sites = 0
        for fi in mi.functions.values():
            for node in ast.walk(fi.node):
                if not (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr in _OBS_EMIT_ATTRS):
                    continue
                n_sites += 1
                if fi.traced:
                    self._emit(
                        mi, fi, node, "TRC107", ERROR,
                        f"obs emission .{node.func.attr}() reachable "
                        f"from a traced root — host callbacks inside "
                        f"jit fail to trace or fire once at trace "
                        f"time; hoist instrumentation out of the "
                        f"traced computation")
        return n_sites

    def _check_jit_donation(self, mi: ModuleInfo) -> None:
        """TRC106: jax.jit over a build_*tick* product, no donate_argnums."""
        for fi in mi.functions.values():
            for node in ast.walk(fi.node):
                if not (isinstance(node, ast.Call)
                        and _is_jit_expr(mi, node.func) and node.args):
                    continue
                if any(k.arg == "donate_argnums" for k in node.keywords):
                    continue
                if self._wraps_tick(mi, fi.node, node.args[0]):
                    self._emit(
                        mi, fi, node, "TRC106", WARNING,
                        "jax.jit of a tick without donate_argnums: the "
                        "tick threads its full table state every call — "
                        "donate it (cf. SlotTickCache) or justify in the "
                        "baseline")

    def _wraps_tick(self, mi: ModuleInfo, scope, expr, depth: int = 0
                    ) -> bool:
        if depth > 4:
            return False
        if isinstance(expr, ast.Name):
            val = _local_assign_value(scope, expr.id)
            if val is not None:
                return self._wraps_tick(mi, scope, val, depth + 1)
            return False
        if isinstance(expr, ast.Call):
            f = expr.func
            name = f.id if isinstance(f, ast.Name) else (
                f.attr if isinstance(f, ast.Attribute) else "")
            if re.search(r"(build_.*tick|tick_body)", name):
                return True
            return any(self._wraps_tick(mi, scope, a, depth + 1)
                       for a in expr.args)
        return False

    # ---------------- entry ---------------- #
    def run(self) -> list[Finding]:
        self.load()
        for mi in self.modules.values():
            _mark_roots(mi)
        self._propagate()
        n_obs_sites = 0
        for mi in self.modules.values():
            for fi in mi.functions.values():
                if fi.traced:
                    self._check_traced_fn(mi, fi)
                self._check_builder_closures(mi, fi)
            self._check_jit_donation(mi)
            n_obs_sites += self._check_obs_sites(mi)
        self.findings.sort(key=lambda f: (f.path, f.line, f.rule))
        n_traced = sum(1 for mi in self.modules.values()
                       for fi in mi.functions.values() if fi.traced)
        self.stats = {
            "n_files": len(self.modules),
            "n_functions": sum(len(mi.functions)
                               for mi in self.modules.values()),
            "n_traced_functions": n_traced,
            # tick bodies entering XLA through a shard_map wrapper (the
            # mesh/distributed entry points) — coverage census proving
            # the sharded builders stay under TRC checks
            "n_shard_map_roots": sum(
                1 for mi in self.modules.values()
                for fi in mi.functions.values() if fi.shard_map_root),
            # repro.obs span/metric emission sites in obs-importing
            # modules — all proven host-side (any one reachable from a
            # traced root is a TRC107 error above)
            "n_obs_sites": n_obs_sites,
        }
        return self.findings


def lint_tree(root: str) -> tuple[list[Finding], dict]:
    """Lint every module under ``root`` (a package dir like src/repro)."""
    linter = Linter(root)
    findings = linter.run()
    return findings, linter.stats
