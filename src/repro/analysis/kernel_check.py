"""Kernel contract checker: static proofs over every ``pallas_call``.

ROADMAP item 5 (running the SMEM-cursor pair kernel on real TPUs) should
start from machine-checked contracts, not interpret-parity hope.  This
pass proves, for every kernel entry point in ``kernels/*/kernel.py`` and
over the *reachable shape lattice* — pow-2 capacities (the serving stack
quantizes every table axis with ``runtime.straggler.quantize_pow2``,
floor 8) × all ``choose_tiles`` outputs × slot-stack depths:

KC101  tile divisibility: the padded capacity each op wrapper feeds the
       kernel is an exact tile multiple and every grid extent is ≥ 1;
KC102  tile alignment: TA is a sublane (8) multiple and TB a lane (128)
       multiple — the int32 VREG granularity from the Pallas TPU guide;
KC103  index-map bounds: each BlockSpec's ``index_map`` (mirrored here,
       declaratively, from the kernel source) stays in bounds for every
       grid point — ``index*block + block <= padded array dim`` on every
       axis, including the data-dependent embedding-bag maps, which are
       proven by interval argument from their documented preconditions;
KC104  SMEM cursor safety for ``compat_join_pairs``: the emit clamp
       ``n_emit = min(n_tile, max(max_new - base, 0))`` implies every
       write lands strictly below ``max_new`` for any base in
       [0, CA·CB] and any per-tile count in [0, TA·TB] — checked
       algebraically at the interval extremes, after asserting the
       clamp expression is actually present in the kernel source;
KC105  kernel-vs-ref agreement: ``jax.eval_shape`` abstract evaluation
       of the public ops against the pure-jnp ``ref.py`` oracles (and
       their vmapped forms against the stacked 3-D-grid kernels) —
       identical output trees, shapes and dtypes, with zero FLOPs run.

KC100 (warning) flags any ``pallas_call`` site in a kernels package that
has no declarative contract here — new kernels must register one.

``jax.eval_shape`` does trace the kernel bodies (on CPU, no lowering,
no execution), so KC105 also catches rank/dtype bugs *inside* kernel
bodies, not just in the wrappers.
"""

from __future__ import annotations

import ast
import itertools
import os

import numpy as np

from repro.analysis.findings import ERROR, WARNING, Finding

# Entry points with a declarative contract below.  KC100 fires for any
# pallas_call in kernels/*/kernel.py outside these functions.
MODELED_ENTRY_POINTS = frozenset({
    "compat_mask_kernel", "compat_mask_kernel_batched",
    "compat_join_pairs_kernel", "compat_join_pairs_kernel_batched",
    "segment_sum_kernel", "embedding_bag_kernel",
})

# Reachable shape lattice.  Capacities are pow-2 (quantize_pow2, lo=8);
# slot-stack depths come from plan_signature grouping in core.multi.
CAPS_FULL = tuple(2 ** k for k in range(3, 13))          # 8 .. 4096
CAPS_FAST = (8, 64, 256, 4096)
SLOTS = (1, 2, 4, 8)
MAX_NEW = (64, 256, 1024, 4096)
WIDTHS = (1, 2, 3, 4)                                    # nv / ne columns

# Representative batched-flag sets for the stacked kernels: all-shared,
# all-per-slot, and each one-sided mix (the slot tick's stream-edge
# operand is the canonical shared side).
FLAG_SETS = (
    (False,) * 6,
    (True,) * 6,
    (True, True, True, False, False, False),
    (False, False, False, True, True, True),
)


def _finding(rule, severity, symbol, message, path="", line=0):
    return Finding(pass_name="kernel", rule=rule, severity=severity,
                   path=path, line=line, symbol=symbol, message=message)


# --------------------------------------------------------------------- #
# pallas_call site discovery (KC100 + n_pallas_sites)
# --------------------------------------------------------------------- #
def discover_pallas_sites(kernels_root: str) -> list[tuple[str, str, int]]:
    """All ``pallas_call`` sites in kernels/*/kernel.py as
    (repo-relative path, enclosing function name, line)."""
    sites = []
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(
        os.path.join(kernels_root, os.pardir))))
    for dirpath, _d, files in sorted(os.walk(kernels_root)):
        for fn in sorted(files):
            if fn != "kernel.py":
                continue
            path = os.path.join(dirpath, fn)
            with open(path) as fh:
                tree = ast.parse(fh.read(), filename=path)
            rel = os.path.relpath(path, repo_root)
            func_stack: list[str] = []

            def visit(node):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    func_stack.append(node.name)
                    for c in ast.iter_child_nodes(node):
                        visit(c)
                    func_stack.pop()
                    return
                if (isinstance(node, ast.Call)
                        and isinstance(node.func, ast.Attribute)
                        and node.func.attr == "pallas_call"):
                    sites.append((rel, func_stack[-1] if func_stack
                                  else "<module>", node.lineno))
                for c in ast.iter_child_nodes(node):
                    visit(c)

            visit(tree)
    return sites


# --------------------------------------------------------------------- #
# Declarative BlockSpec contracts (mirrored from kernel.py)
# --------------------------------------------------------------------- #
def _bounds_ok(grid, specs):
    """Exhaustively check index*block + block <= dim for every grid
    point.  ``specs`` is [(name, array_shape, block_shape, index_map)]
    with index_map taking the grid tuple and returning block indices."""
    bad = []
    for point in itertools.product(*(range(g) for g in grid)):
        for name, array_shape, block_shape, index_map in specs:
            idx = index_map(*point)
            for ax, (i, b, dim) in enumerate(
                    zip(idx, block_shape, array_shape)):
                if i < 0 or i * b + b > dim:
                    bad.append((name, point, ax, i, b, dim))
    return bad


def _compat_specs(cap, cbp, ta, tb, widths, max_new=None):
    """Unbatched 2-D-grid specs, mirroring compat_*_kernel."""
    nva, nea, nvb, neb = widths
    specs = [
        ("bind_a", (cap, nva), (ta, nva), lambda i, j: (i, 0)),
        ("ets_a", (cap, nea), (ta, nea), lambda i, j: (i, 0)),
        ("valid_a", (cap,), (ta,), lambda i, j: (i,)),
        ("bind_b", (cbp, nvb), (tb, nvb), lambda i, j: (j, 0)),
        ("ets_b", (cbp, neb), (tb, neb), lambda i, j: (j, 0)),
        ("valid_b", (cbp,), (tb,), lambda i, j: (j,)),
    ]
    if max_new is None:
        specs.append(("mask_out", (cap, cbp), (ta, tb),
                      lambda i, j: (i, j)))
    else:
        specs += [
            ("a_out", (max_new,), (max_new,), lambda i, j: (0,)),
            ("b_out", (max_new,), (max_new,), lambda i, j: (0,)),
            ("n_out", (1,), (1,), lambda i, j: (0,)),
        ]
    return specs


def _compat_specs_batched(n_slots, cap, cbp, ta, tb, widths, flags,
                          max_new=None):
    """Stacked 3-D-grid specs, mirroring _stacked_in_specs: batched
    inputs carry [S] and a slot-aware index_map; shared inputs keep the
    2-D map that ignores the slot coordinate."""
    nva, nea, nvb, neb = widths
    base = [
        ("bind_a", (cap, nva), (ta, nva), lambda s, i, j: (i, 0)),
        ("ets_a", (cap, nea), (ta, nea), lambda s, i, j: (i, 0)),
        ("valid_a", (cap,), (ta,), lambda s, i, j: (i,)),
        ("bind_b", (cbp, nvb), (tb, nvb), lambda s, i, j: (j, 0)),
        ("ets_b", (cbp, neb), (tb, neb), lambda s, i, j: (j, 0)),
        ("valid_b", (cbp,), (tb,), lambda s, i, j: (j,)),
    ]
    specs = []
    for flag, (name, shape, block, idx) in zip(flags, base):
        if flag:
            specs.append((name, (n_slots,) + shape, (1,) + block,
                          lambda s, i, j, idx=idx: (s,) + idx(s, i, j)))
        else:
            specs.append((name, shape, block, idx))
    if max_new is None:
        specs.append(("mask_out", (n_slots, cap, cbp), (1, ta, tb),
                      lambda s, i, j: (s, i, j)))
    else:
        specs += [
            ("a_out", (n_slots, max_new), (1, max_new),
             lambda s, i, j: (s, 0)),
            ("b_out", (n_slots, max_new), (1, max_new),
             lambda s, i, j: (s, 0)),
            ("n_out", (n_slots, 1), (1, 1), lambda s, i, j: (s, 0)),
        ]
    return specs


def check_tiles_and_bounds(fast: bool = False) -> list[Finding]:
    """KC101/KC102/KC103 over the reachable lattice for the compat
    kernels, plus the fixed-tile segment_reduce / embedding_bag grids."""
    from repro.kernels.compat_join.kernel import (
        _LANE, _SUBLANE, _ceil_to, choose_tiles)

    findings: list[Finding] = []
    caps = CAPS_FAST if fast else CAPS_FULL

    # --- compat_join: full choose_tiles lattice ---
    for ca, cb in itertools.product(caps, caps):
        ta, tb = choose_tiles(ca, cb)
        cap, cbp = _ceil_to(ca, ta), _ceil_to(cb, tb)
        sym = f"choose_tiles({ca},{cb})"
        if ta % _SUBLANE or tb % _LANE:
            findings.append(_finding(
                "KC102", ERROR, sym,
                f"tile ({ta},{tb}) not ({_SUBLANE},{_LANE})-aligned"))
        if cap % ta or cbp % tb or cap // ta < 1 or cbp // tb < 1:
            findings.append(_finding(
                "KC101", ERROR, sym,
                f"padded caps ({cap},{cbp}) not exact multiples of "
                f"tiles ({ta},{tb}) or empty grid"))
            continue
        widths = (2, 1, 1, 1)
        grid = (cap // ta, cbp // tb)
        bad = _bounds_ok(grid, _compat_specs(cap, cbp, ta, tb, widths))
        bad += _bounds_ok(grid, _compat_specs(cap, cbp, ta, tb, widths,
                                              max_new=MAX_NEW[0]))
        for n_slots, flags in itertools.product(
                SLOTS if not fast else SLOTS[:2],
                FLAG_SETS if not fast else FLAG_SETS[:2]):
            g3 = (n_slots,) + grid
            bad += _bounds_ok(g3, _compat_specs_batched(
                n_slots, cap, cbp, ta, tb, widths, flags))
            bad += _bounds_ok(g3, _compat_specs_batched(
                n_slots, cap, cbp, ta, tb, widths, flags,
                max_new=MAX_NEW[0]))
        for name, point, ax, i, b, dim in bad[:3]:
            findings.append(_finding(
                "KC103", ERROR, sym,
                f"index_map of {name} out of bounds at grid {point}: "
                f"axis {ax} block {i}*{b}+{b} > {dim}"))

    # --- segment_reduce: fixed 512/256 tiles, padded-multiple contract ---
    from repro.kernels.segment_reduce.kernel import TILE_E, TILE_N
    seg_lat = [(TILE_E * a, TILE_N * b, d)
               for a in (1, 4) for b in (1, 4) for d in (8, 128)]
    for e, n, d in seg_lat:
        grid = (n // TILE_N, e // TILE_E)
        sym = f"segment_sum_kernel(E={e},N={n},D={d})"
        if e % TILE_E or n % TILE_N or grid[0] < 1 or grid[1] < 1:
            findings.append(_finding(
                "KC101", ERROR, sym, "padded-multiple precondition "
                "violated inside the checker's own lattice"))
            continue
        specs = [
            ("dst", (e,), (TILE_E,), lambda i, j: (j,)),
            ("msg", (e, d), (TILE_E, d), lambda i, j: (j, 0)),
            ("out", (n, d), (TILE_N, d), lambda i, j: (i, 0)),
        ]
        for name, point, ax, i, b, dim in _bounds_ok(grid, specs)[:3]:
            findings.append(_finding(
                "KC103", ERROR, sym,
                f"index_map of {name} out of bounds at grid {point}"))

    # --- embedding_bag: data-dependent maps, interval proof ---
    # Preconditions (documented in kernel.py): ids in [-1, V-1] with the
    # map clamping to max(ids[i], 0); bags in [0, n_bags-1].
    for v, n_bags, d in ((16, 4, 8), (4096, 512, 64)):
        sym = f"embedding_bag_kernel(V={v},B={n_bags},D={d})"
        lo, hi = max(-1, 0), v - 1          # after clamp: [0, V-1]
        if not (0 <= lo and hi * 1 + 1 <= v):
            findings.append(_finding(
                "KC103", ERROR, sym,
                "clamped table index interval exceeds [0, V)"))
        if not (0 <= 0 and (n_bags - 1) * 1 + 1 <= n_bags):
            findings.append(_finding(
                "KC103", ERROR, sym,
                "bag output index interval exceeds [0, n_bags)"))
    return findings


# --------------------------------------------------------------------- #
# KC104: SMEM cursor interval proof
# --------------------------------------------------------------------- #
_CLAMP_EXPR = "jnp.minimum(n_tile, jnp.maximum(max_new - base, 0))"


def check_smem_cursor(fast: bool = False) -> list[Finding]:
    """Prove the pairs kernels' emit loop never writes at or beyond
    ``max_new``, for any cursor value the grid can produce."""
    import repro.kernels.compat_join.kernel as K
    findings: list[Finding] = []

    src = open(K.__file__).read()
    if _CLAMP_EXPR not in src:
        findings.append(_finding(
            "KC104", ERROR, "compat_join_pairs._pairs_body",
            f"emit clamp `{_CLAMP_EXPR}` not found in kernel source — "
            f"the SMEM cursor bound proof no longer applies"))
        return findings

    caps = CAPS_FAST if fast else CAPS_FULL
    for ca, cb in itertools.product(caps, caps):
        ta, tb = K.choose_tiles(ca, cb)
        cap, cbp = K._ceil_to(ca, ta), K._ceil_to(cb, tb)
        n_tile_max = ta * tb
        for max_new in MAX_NEW:
            # cursor extremes: 0, around the clamp knee, and the
            # absolute maximum (every pair of every tile matched)
            bases = {0, max(0, max_new - 1), max_new, max_new + 1,
                     cap * cbp}
            for base in bases:
                for n_tile in (0, 1, n_tile_max):
                    n_emit = min(n_tile, max(max_new - base, 0))
                    if n_emit > 0 and base + n_emit - 1 >= max_new:
                        findings.append(_finding(
                            "KC104", ERROR,
                            f"compat_join_pairs(ca={ca},cb={cb},"
                            f"max_new={max_new})",
                            f"cursor write base={base} k={n_emit - 1} "
                            f"reaches index {base + n_emit - 1} >= "
                            f"max_new={max_new}"))
    return findings


# --------------------------------------------------------------------- #
# KC105: kernel-vs-ref abstract evaluation agreement
# --------------------------------------------------------------------- #
def _tree_sig(tree):
    import jax
    return jax.tree.map(lambda x: (tuple(x.shape), str(x.dtype)), tree)


def check_kernel_ref_agreement(fast: bool = False) -> list[Finding]:
    """``jax.eval_shape`` the public ops against their ref oracles."""
    import jax
    import jax.numpy as jnp

    from repro.kernels.compat_join import ops as cj_ops
    from repro.kernels.compat_join import ref as cj_ref
    from repro.kernels.embedding_bag import kernel as eb_k
    from repro.kernels.embedding_bag import ref as eb_ref
    from repro.kernels.segment_reduce import kernel as sr_k
    from repro.kernels.segment_reduce import ref as sr_ref

    findings: list[Finding] = []
    S = jax.ShapeDtypeStruct
    i32 = jnp.int32

    def compare(sym, fk, fr, *args):
        try:
            got = _tree_sig(jax.eval_shape(fk, *args))
        except Exception as exc:                       # trace failure
            findings.append(_finding(
                "KC105", ERROR, sym,
                f"kernel path failed abstract evaluation: {exc!r}"))
            return
        want = _tree_sig(jax.eval_shape(fr, *args))
        if got != want:
            findings.append(_finding(
                "KC105", ERROR, sym,
                f"kernel/ref signature mismatch: {got} != {want}"))

    # compat_join: include a non-pow-2 point to exercise the padding path
    points = [(8, 8), (64, 128), (100, 37)]
    if not fast:
        points += [(256, 256), (1024, 512)]
    nva, nea, nvb, neb = 2, 2, 1, 1
    rel = np.zeros((nva, nvb), bool)
    rel[0, 0] = True
    trel = np.zeros((nea, neb), np.int8)
    trel[-1, 0] = -1
    for ca, cb in points:
        # valid is bool by contract (core.join.compat_mask_ref signature)
        a = (S((ca, nva), i32), S((ca, nea), i32), S((ca,), jnp.bool_))
        b = (S((cb, nvb), i32), S((cb, neb), i32), S((cb,), jnp.bool_))
        sym = f"compat_mask(ca={ca},cb={cb})"
        compare(sym,
                lambda *t: cj_ops.compat_mask(*t, rel, trel, window=30),
                lambda *t: cj_ref.compat_mask(*t, rel, trel, window=30),
                *a, *b)
        sym = f"compat_join_pairs(ca={ca},cb={cb})"
        compare(sym,
                lambda *t: cj_ops.compat_join_pairs(
                    *t, rel, trel, 256, window=30),
                lambda *t: cj_ref.compat_join_pairs(
                    *t, rel, trel, 256, window=30),
                *a, *b)

    # vmapped -> stacked 3-D-grid kernel (per-slot windows)
    for n_slots in (SLOTS[:2] if fast else SLOTS):
        ca, cb = 64, 128
        a = (S((n_slots, ca, nva), i32), S((n_slots, ca, nea), i32),
             S((n_slots, ca), jnp.bool_))
        b = (S((n_slots, cb, nvb), i32), S((n_slots, cb, neb), i32),
             S((n_slots, cb), jnp.bool_))
        w = S((n_slots,), i32)

        def k_mask(ba, ea, va, bb, eb, vb, win):
            return cj_ops.compat_mask(ba, ea, va, bb, eb, vb, rel, trel,
                                      window=win)

        def r_mask(ba, ea, va, bb, eb, vb, win):
            return cj_ref.compat_mask(ba, ea, va, bb, eb, vb, rel, trel,
                                      window=win)

        def k_pairs(ba, ea, va, bb, eb, vb, win):
            return cj_ops.compat_join_pairs(
                ba, ea, va, bb, eb, vb, rel, trel, 256, window=win)

        def r_pairs(ba, ea, va, bb, eb, vb, win):
            return cj_ref.compat_join_pairs(
                ba, ea, va, bb, eb, vb, rel, trel, 256, window=win)

        compare(f"vmap(compat_mask)(S={n_slots})",
                jax.vmap(k_mask), jax.vmap(r_mask), *a, *b, w)
        compare(f"vmap(compat_join_pairs)(S={n_slots})",
                jax.vmap(k_pairs), jax.vmap(r_pairs), *a, *b, w)

    # segment_reduce
    e, n, d = (512, 256, 8) if fast else (2048, 1024, 64)
    compare(f"segment_sum(E={e},N={n},D={d})",
            lambda dst, msg: sr_k.segment_sum_kernel(dst, msg, n),
            lambda dst, msg: sr_ref.segment_sum(dst, msg, n),
            S((e,), i32), S((e, d), jnp.float32))

    # embedding_bag (kernel takes the extra `first` marker input)
    t, v, nb, d = (16, 32, 4, 8) if fast else (128, 1024, 32, 64)
    compare(f"embedding_bag(T={t},V={v},B={nb},D={d})",
            lambda ids, bags, first, table: eb_k.embedding_bag_kernel(
                ids, bags, first, table, nb),
            lambda ids, bags, first, table: eb_ref.embedding_bag(
                ids, bags, table, nb),
            S((t,), i32), S((t,), i32), S((t,), i32),
            S((v, d), jnp.float32))
    return findings


# --------------------------------------------------------------------- #
# Entry point
# --------------------------------------------------------------------- #
def check_kernels(kernels_root: str | None = None, fast: bool = False
                  ) -> tuple[list[Finding], dict]:
    if kernels_root is None:
        kernels_root = os.path.join(
            os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
            "kernels")
    findings: list[Finding] = []
    sites = discover_pallas_sites(kernels_root)
    for path, func, line in sites:
        if func not in MODELED_ENTRY_POINTS:
            findings.append(Finding(
                pass_name="kernel", rule="KC100", severity=WARNING,
                path=path, line=line, symbol=func,
                message="pallas_call without a declarative contract in "
                        "repro.analysis.kernel_check — register its "
                        "BlockSpecs in MODELED_ENTRY_POINTS"))
    findings += check_tiles_and_bounds(fast=fast)
    findings += check_smem_cursor(fast=fast)
    findings += check_kernel_ref_agreement(fast=fast)
    stats = {"n_pallas_sites": len(sites)}
    return findings, stats
