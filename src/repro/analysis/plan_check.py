"""Plan invariant verifier: the paper's decomposition discipline, checked.

The correctness argument of Li et al. 2018 rests on structural
properties of the compiled plan — TC-subqueries that cover the query's
edges exactly once with timing-chained, prefix-connected sequences
(Algorithms 5–6, Definitions 9/10/14).  ``compile_plan`` produces such
plans for planner-chosen decompositions, but callers may also supply a
hand-built decomposition (``QueryRegistry.register(..., plan=...)``,
the sjtree ablations, restore paths) — and nothing verified them until
now.  ``verify_plan`` re-derives every invariant from the plan's own
``QueryGraph`` and fails fast with ``PlanInvariantError``; the CLI runs
whole-corpus sweeps over the planner's output.

Rules (all ERROR unless noted):

PC101  the decomposition is an edge-disjoint cover: the timing
       sequences partition {0..n_edges-1} with no overlap or repeat;
PC102  every timing sequence satisfies Definition 10: prefix-connected
       and consecutively ≺-chained (``QueryGraph.is_timing_sequence``);
PC103  the join order is prefix-connected (Definition 14): each
       subquery after the first shares a query vertex with the union of
       its predecessors, so every L0 join has at least one REL equality
       and never degenerates to a cross product;
PC104  level specs agree with a fresh ``_compile_subquery`` of the
       stored timing sequence (slot layouts cannot drift from the
       sequences they were compiled from);
PC105  every L0 ``JoinSpec``'s REL/TREL/layouts agree with a fresh
       ``_join_spec`` over the stored layouts;
PC106  ``edge_site`` is a consistent inverse of the level map and
       covers every query edge;
PC107  the per-edge label tables match the query's labels;
PC108  window and every capacity / max_new are positive;
PC109  each ``share.prefix_chain`` slice is itself a timing-chain
       prefix: per-depth queries are ≺-chains that extend one another
       edge-by-edge, and every signature carries the plan's window;
PC110  (info) the registered query is not ``canonical_form``'s fixed
       point — isomorphic authorings will not share a compiled tick
       until canonicalized (the api layer does this automatically).
"""

from __future__ import annotations

import numpy as np

from repro.analysis.findings import ERROR, INFO, Finding

__all__ = ["PlanInvariantError", "check_plan", "verify_plan",
           "verify_corpus"]


class PlanInvariantError(ValueError):
    """A compiled plan violates the paper's decomposition invariants."""

    def __init__(self, findings):
        self.findings = list(findings)
        msgs = "; ".join(f"{f.rule}: {f.message}" for f in self.findings)
        super().__init__(f"plan invariant violation: {msgs}")


def _f(rule, severity, symbol, message):
    return Finding(pass_name="plan", rule=rule, severity=severity,
                   path="", line=0, symbol=symbol, message=message)


def check_plan(plan, symbol: str = "plan") -> list[Finding]:
    """All invariant findings for one ``ExecutionPlan`` (never raises)."""
    from repro.core.canon import canonical_form
    from repro.core.plan import _compile_subquery, _join_spec
    from repro.core.decompose import TCSubquery
    from repro.core.share import prefix_chain

    q = plan.query
    out: list[Finding] = []
    seqs = [tuple(s.timing_sequence) for s in plan.subqueries]

    # PC101: edge-disjoint cover
    flat = [e for s in seqs for e in s]
    if sorted(flat) != list(range(q.n_edges)):
        out.append(_f("PC101", ERROR, symbol,
                      f"timing sequences {seqs} are not an edge-disjoint "
                      f"cover of {{0..{q.n_edges - 1}}}"))

    # PC102: each sequence is a valid timing sequence (Def. 10)
    for si, seq in enumerate(seqs):
        if not seq:
            out.append(_f("PC102", ERROR, symbol,
                          f"subquery {si} has an empty timing sequence"))
            continue
        if not all(0 <= e < q.n_edges for e in seq):
            out.append(_f("PC102", ERROR, symbol,
                          f"subquery {si} references unknown edges {seq}"))
            continue
        if not q.is_timing_sequence(seq):
            out.append(_f("PC102", ERROR, symbol,
                          f"subquery {si} sequence {seq} is not prefix-"
                          f"connected + consecutively ≺-chained "
                          f"(Def. 10)"))

    # PC103: prefix-connected join order (Def. 14)
    if len(seqs) > 1 and all(
            s and all(0 <= e < q.n_edges for e in s) for s in seqs):
        bound = set(q.vertices_of(seqs[0]))
        for si in range(1, len(seqs)):
            verts = set(q.vertices_of(seqs[si]))
            if not bound & verts:
                out.append(_f(
                    "PC103", ERROR, symbol,
                    f"join order not prefix-connected at subquery {si}: "
                    f"{seqs[si]} shares no vertex with the joined prefix "
                    f"(the L0 join would be a cross product)"))
            bound |= verts

    # PC104: level specs match a fresh compile of the stored sequence
    for si, s in enumerate(plan.subqueries):
        if not s.timing_sequence or not all(
                0 <= e < q.n_edges for e in s.timing_sequence):
            continue
        fresh = _compile_subquery(q, TCSubquery(
            frozenset(s.timing_sequence), tuple(s.timing_sequence)))
        if len(fresh.levels) != len(s.levels):
            out.append(_f("PC104", ERROR, symbol,
                          f"subquery {si}: {len(s.levels)} levels stored, "
                          f"{len(fresh.levels)} recompiled"))
            continue
        for li, (lv, ref) in enumerate(zip(s.levels, fresh.levels)):
            stored = (lv.qedge, lv.src_v, lv.dst_v, lv.src_slot,
                      lv.dst_slot, tuple(lv.new_vertices),
                      tuple(lv.vertex_layout))
            want = (ref.qedge, ref.src_v, ref.dst_v, ref.src_slot,
                    ref.dst_slot, tuple(ref.new_vertices),
                    tuple(ref.vertex_layout))
            if stored != want:
                out.append(_f(
                    "PC104", ERROR, symbol,
                    f"subquery {si} level {li} drifted from its timing "
                    f"sequence: stored {stored} != recompiled {want}"))

    # PC105: L0 join specs match fresh _join_spec over stored layouts
    if plan.l0_joins and len(plan.subqueries) == len(plan.l0_joins) + 1:
        a_vl = plan.subqueries[0].vertex_layout
        a_el = plan.subqueries[0].edge_layout
        for ji, js in enumerate(plan.l0_joins):
            b = plan.subqueries[ji + 1]
            ref = _join_spec(q, a_vl, a_el, b.vertex_layout, b.edge_layout)
            if (not np.array_equal(js.rel, ref.rel)
                    or not np.array_equal(js.trel, ref.trel)
                    or tuple(js.b_new_vertex_slots)
                    != tuple(ref.b_new_vertex_slots)
                    or tuple(js.vertex_layout) != tuple(ref.vertex_layout)
                    or tuple(js.edge_layout) != tuple(ref.edge_layout)):
                out.append(_f(
                    "PC105", ERROR, symbol,
                    f"L0 join {ji} REL/TREL/layouts disagree with "
                    f"_join_spec over the stored layouts"))
            a_vl, a_el = js.vertex_layout, js.edge_layout
    elif len(plan.l0_joins) != max(0, len(plan.subqueries) - 1):
        out.append(_f("PC105", ERROR, symbol,
                      f"{len(plan.l0_joins)} L0 joins for "
                      f"{len(plan.subqueries)} subqueries"))

    # PC106: edge_site is a consistent inverse of the level map
    sites = dict(plan.edge_site)
    for si, s in enumerate(plan.subqueries):
        for li, lv in enumerate(s.levels):
            if sites.pop(lv.qedge, None) != (si, li):
                out.append(_f(
                    "PC106", ERROR, symbol,
                    f"edge_site[{lv.qedge}] != ({si}, {li})"))
    if sites:
        out.append(_f("PC106", ERROR, symbol,
                      f"edge_site has orphan entries {sites}"))

    # PC107: label tables match the query
    esl = [q.vertex_labels[q.edges[e][0]] for e in range(q.n_edges)]
    edl = [q.vertex_labels[q.edges[e][1]] for e in range(q.n_edges)]
    eel = list(q.edge_labels)
    if (list(plan.edge_src_label) != esl or list(plan.edge_dst_label) != edl
            or list(plan.edge_edge_label) != eel):
        out.append(_f("PC107", ERROR, symbol,
                      "edge label tables do not match the query's labels"))

    # PC108: positive window / capacities
    if int(plan.window) <= 0:
        out.append(_f("PC108", ERROR, symbol,
                      f"window {plan.window} is not positive"))
    for si, s in enumerate(plan.subqueries):
        for li, lv in enumerate(s.levels):
            if lv.capacity <= 0 or lv.max_new <= 0:
                out.append(_f("PC108", ERROR, symbol,
                              f"subquery {si} level {li} capacity/"
                              f"max_new not positive"))
    for ji, js in enumerate(plan.l0_joins):
        if js.capacity <= 0 or js.max_new <= 0:
            out.append(_f("PC108", ERROR, symbol,
                          f"L0 join {ji} capacity/max_new not positive"))

    # PC109: prefix_chain slices are timing-chain prefixes, same window
    if not any(f.rule in ("PC101", "PC102") for f in out):
        chain = prefix_chain(plan)
        if chain.depth != len(plan.subqueries[0].timing_sequence) \
                or len(chain.queries) != chain.depth:
            out.append(_f("PC109", ERROR, symbol,
                          "prefix_chain depth disagrees with subquery 0"))
        prev = None
        for d, (pq, sig) in enumerate(zip(chain.queries, chain.sigs)):
            if sig[1] != int(plan.window):
                out.append(_f("PC109", ERROR, symbol,
                              f"depth-{d + 1} signature window {sig[1]} "
                              f"!= plan window {plan.window}"))
            if not pq.is_timing_sequence(tuple(range(pq.n_edges))):
                out.append(_f("PC109", ERROR, symbol,
                              f"depth-{d + 1} prefix query is not a "
                              f"timing chain"))
            if prev is not None and (
                    pq.edges[:prev.n_edges] != prev.edges
                    or pq.edge_labels[:prev.n_edges] != prev.edge_labels
                    or pq.vertex_labels[:prev.n_vertices]
                    != prev.vertex_labels):
                out.append(_f("PC109", ERROR, symbol,
                              f"depth-{d + 1} prefix does not extend the "
                              f"depth-{d} prefix edge-by-edge"))
            prev = pq

    # PC110 (info): not a canonical_form fixed point
    if canonical_form(q).query != q:
        out.append(_f(
            "PC110", INFO, symbol,
            "query is not in canonical form; isomorphic authorings "
            "will not share a compiled tick (the repro.api planner "
            "canonicalizes automatically)"))
    return out


def verify_plan(plan, symbol: str = "plan",
                raise_on_error: bool = True) -> list[Finding]:
    """Check ``plan``; raise ``PlanInvariantError`` on any ERROR finding
    (info findings never raise)."""
    findings = check_plan(plan, symbol=symbol)
    errors = [f for f in findings if f.severity == ERROR]
    if errors and raise_on_error:
        raise PlanInvariantError(errors)
    return findings


# --------------------------------------------------------------------- #
# Corpus sweep (CLI): every planner-produced plan must verify clean.
# --------------------------------------------------------------------- #
def _corpus_queries():
    from repro.core.query import QueryGraph, example_paper_query

    yield "paper_fig2", example_paper_query()
    # ≺-chain of growing length (the prefix-sharing workhorse)
    for n in (2, 3, 4):
        yield f"chain{n}", QueryGraph(
            n_vertices=n + 1,
            vertex_labels=tuple(range(n + 1)),
            edges=tuple((i, i + 1) for i in range(n)),
            edge_labels=(0,) * n,
            prec=frozenset((i, i + 1) for i in range(n - 1)),
        )
    # star: no precedence at all (all-singleton decomposition)
    yield "star4", QueryGraph(
        n_vertices=5, vertex_labels=(1, 0, 0, 0, 0),
        edges=((0, 1), (0, 2), (0, 3), (0, 4)),
        edge_labels=(-1,) * 4, prec=frozenset())
    # triangle with a full ≺-chain (single TC-subquery)
    yield "triangle_chain", QueryGraph(
        n_vertices=3, vertex_labels=(0, 0, 0),
        edges=((0, 1), (1, 2), (2, 0)), edge_labels=(0, 0, 0),
        prec=frozenset({(0, 1), (1, 2), (0, 2)}))
    # triangle, no precedence
    yield "triangle_free", QueryGraph(
        n_vertices=3, vertex_labels=(0, 1, 2),
        edges=((0, 1), (1, 2), (2, 0)), edge_labels=(0, 1, -1),
        prec=frozenset())


def verify_corpus() -> tuple[list[Finding], dict]:
    """Compile + verify the corpus; count plans checked."""
    from repro.core.plan import compile_plan

    findings: list[Finding] = []
    n = 0
    for name, q in _corpus_queries():
        for window in (25, 1000):
            plan = compile_plan(q, window)
            findings += check_plan(plan, symbol=f"{name}@w{window}")
            n += 1
    return findings, {"n_plans_verified": n}
