"""``python -m repro.analysis`` — run all three passes, emit a report.

Exit status: 1 if any non-baselined ERROR finding remains (always), or
any non-baselined WARNING under ``--error-on-findings``.  INFO findings
never affect the exit status.  The JSON report (``--json``) uses the
``repro_analysis/v1`` schema from ``repro.analysis.findings``.
"""

from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis.findings import (
    ERROR, WARNING, Report, load_baseline)

PASSES = ("lint", "kernel", "plan")


def _default_paths():
    here = os.path.dirname(os.path.abspath(__file__))   # src/repro/analysis
    pkg_root = os.path.dirname(here)                    # src/repro
    repo_root = os.path.dirname(os.path.dirname(pkg_root))
    return pkg_root, os.path.join(repo_root, "analysis_baseline.json")


def run_passes(root: str, passes=PASSES, fast: bool = False) -> Report:
    """Run the selected passes over the tree rooted at ``root``."""
    findings, stats = [], {}
    if "lint" in passes:
        from repro.analysis.ast_lint import lint_tree
        f, s = lint_tree(root)
        findings += f
        stats.update(s)
    if "kernel" in passes:
        from repro.analysis.kernel_check import check_kernels
        f, s = check_kernels(os.path.join(root, "kernels"), fast=fast)
        findings += f
        stats.update(s)
    if "plan" in passes:
        from repro.analysis.plan_check import verify_corpus
        f, s = verify_corpus()
        findings += f
        stats.update(s)
    return Report(findings=findings, stats=stats)


def main(argv=None) -> int:
    default_root, default_baseline = _default_paths()
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Static analysis gate: tracing-hazard lint, Pallas "
                    "kernel contracts, plan invariants.")
    ap.add_argument("--root", default=default_root,
                    help="package tree to analyze (default: src/repro)")
    ap.add_argument("--json", dest="json_path", default=None,
                    help="write the machine-readable report here")
    ap.add_argument("--baseline", default=default_baseline,
                    help="suppression baseline (default: repo-root "
                         "analysis_baseline.json)")
    ap.add_argument("--error-on-findings", action="store_true",
                    help="also fail on non-baselined warnings")
    ap.add_argument("--fast", action="store_true",
                    help="reduced kernel-checker lattice (tests)")
    ap.add_argument("--pass", dest="passes", action="append",
                    choices=PASSES, default=None,
                    help="run only this pass (repeatable)")
    args = ap.parse_args(argv)

    passes = tuple(args.passes) if args.passes else PASSES
    report = run_passes(args.root, passes=passes, fast=args.fast)
    baseline = load_baseline(args.baseline)
    report = report.split_by_baseline(baseline)

    by_sev = report.by_severity()
    for f in sorted(report.findings,
                    key=lambda f: (f.severity != ERROR, f.path, f.line)):
        print(f.format())
    print(f"repro.analysis: {by_sev[ERROR]} error(s), "
          f"{by_sev[WARNING]} warning(s), {by_sev['info']} info; "
          f"{len(report.suppressed)} baselined; stats={report.stats}")

    if args.json_path:
        with open(args.json_path, "w") as fh:
            json.dump(report.to_json(), fh, indent=1)
            fh.write("\n")

    if by_sev[ERROR] > 0:
        return 1
    if args.error_on_findings and by_sev[WARNING] > 0:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
