"""Gradient compression for the data-parallel all-reduce.

int8 quantized all-reduce with error feedback (1-bit-Adam family): each
step quantizes (grad + residual) to int8 with a per-tensor scale,
all-reduces the int8 payload (8x less ICI traffic than fp32/4x less
than bf16), dequantizes, and keeps the quantization error as residual
for the next step.  Exposed as a drop-in wrapper around the grad psum;
in jit-with-shardings mode the quantized tree is what crosses the dp
axis.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_tree(grads, residual=None):
    """-> (int8 tree, scale tree, new residual tree)."""
    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def q(g, r):
        x = g.astype(jnp.float32) + r
        s = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
        qi = jnp.clip(jnp.round(x / s), -127, 127).astype(jnp.int8)
        return qi, s, x - qi.astype(jnp.float32) * s

    flat_g, tdef = jax.tree.flatten(grads)
    flat_r = tdef.flatten_up_to(residual)
    qs, ss, rs = zip(*[q(g, r) for g, r in zip(flat_g, flat_r)])
    return (jax.tree.unflatten(tdef, list(qs)),
            jax.tree.unflatten(tdef, list(ss)),
            jax.tree.unflatten(tdef, list(rs)))


def dequantize_tree(q_tree, scale_tree):
    return jax.tree.map(
        lambda q, s: q.astype(jnp.float32) * s, q_tree, scale_tree)


def compressed_psum(grads, axis_name, residual=None):
    """Error-feedback int8 psum across ``axis_name`` (for shard_map DP)."""
    q, s, new_res = quantize_tree(grads, residual)
    q32 = jax.tree.map(lambda x: x.astype(jnp.int32), q)
    q_sum = jax.lax.psum(q32, axis_name)
    s_max = jax.lax.pmax(s, axis_name)   # conservative shared scale
    n = jax.lax.psum(1, axis_name)
    out = jax.tree.map(
        lambda qs_, sm: qs_.astype(jnp.float32) * sm / n, q_sum, s_max)
    return out, new_res
