"""AdamW with memory-scalable state variants.

State modes (per-arch config; the 480B-MoE single-pod budget needs them):
  * ``fp32``     — standard m, v in fp32 (12 B/param with fp32 master).
  * ``factored`` — Adafactor-style factored second moment for tensors
                   with >= 2 dims (row+col statistics), fp32 first
                   moment (≈8 B/param).
  * ``int8``     — first moment quantized to int8 with per-tensor scale,
                   factored second moment (≈5 B/param).

All states inherit the parameter's PartitionSpec (ZeRO-style: state is
sharded exactly like its parameter, so the optimizer update is fully
local — no optimizer collectives).
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    b1: float = 0.9
    b2: float = 0.999
    eps: float = 1e-8
    weight_decay: float = 0.01
    clip_norm: float = 1.0
    state_mode: str = "fp32"      # fp32 | factored | int8


def _factored_shape(shape):
    """Factor the last two dims; leading dims (layer stack) kept."""
    return shape[:-1], shape[:-2] + shape[-1:]


def _use_factored(x) -> bool:
    return x.ndim >= 2 and x.shape[-1] >= 8 and x.shape[-2] >= 8


def _stacked(x) -> bool:
    """Layer-stacked leaf (leading scan dim) -> chunked update + per-layer
    quantization scales."""
    return x.ndim >= 3 and x.shape[0] > 1


def adamw_init(params, cfg: AdamWConfig):
    def init_leaf(x):
        st = {}
        if cfg.state_mode in ("factored", "int8") and _use_factored(x):
            r, c = _factored_shape(x.shape)
            st["vr"] = jnp.zeros(r, jnp.float32)
            st["vc"] = jnp.zeros(c, jnp.float32)
        else:
            st["v"] = jnp.zeros(x.shape, jnp.float32)
        if cfg.state_mode == "int8":
            st["m_q"] = jnp.zeros(x.shape, jnp.int8)
            st["m_scale"] = jnp.zeros(
                (x.shape[0],) if _stacked(x) else (), jnp.float32)
        else:
            st["m"] = jnp.zeros(x.shape, jnp.float32)
        return st

    return {
        "leaves": jax.tree.map(init_leaf, params),
        "count": jnp.zeros((), jnp.int32),
    }


def global_norm(tree):
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree.leaves(tree)))


def adamw_update(grads, state, params, lr, cfg: AdamWConfig):
    count = state["count"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))

    b1, b2 = cfg.b1, cfg.b2
    c1 = 1 - b1 ** count.astype(jnp.float32)
    c2 = 1 - b2 ** count.astype(jnp.float32)

    def upd(g, st, p):
        g = g.astype(jnp.float32) * scale
        out = {}
        # second moment
        if "vr" in st:
            g2 = jnp.square(g) + 1e-30
            vr = b2 * st["vr"] + (1 - b2) * g2.mean(axis=-1)
            vc = b2 * st["vc"] + (1 - b2) * g2.mean(axis=-2)
            out["vr"], out["vc"] = vr, vc
            # rank-1 reconstruction (Adafactor): vr ⊗ vc / mean(vr)
            denom = jnp.maximum(vr.mean(axis=-1, keepdims=True), 1e-30)
            v_hat = (vr[..., :, None] * vc[..., None, :]) / denom[..., None]
        else:
            v = b2 * st["v"] + (1 - b2) * jnp.square(g)
            out["v"] = v
            v_hat = v
        # first moment
        if "m_q" in st:
            m_prev = st["m_q"].astype(jnp.float32) * st["m_scale"]
            m = b1 * m_prev + (1 - b1) * g
            s = jnp.maximum(jnp.max(jnp.abs(m)), 1e-12) / 127.0
            out["m_q"] = jnp.clip(jnp.round(m / s), -127, 127).astype(jnp.int8)
            out["m_scale"] = s
        else:
            m = b1 * st["m"] + (1 - b1) * g
            out["m"] = m
        step = (m / c1) / (jnp.sqrt(v_hat / c2) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) if p.ndim >= 2 else 0.0
        new_p = p.astype(jnp.float32) - lr * (step + decay)
        return new_p.astype(p.dtype), out

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_s = tdef.flatten_up_to(state["leaves"])
    new_p, new_s = [], []
    for g, st, p in zip(flat_g, flat_s, flat_p):
        if _stacked(p):
            # layer-stacked leaf: chunk the elementwise update over the
            # stack dim so only one layer's fp32 temporaries (g, m,
            # v_hat, step) are live at a time.  At 480B-MoE scale the
            # unchunked update holds ~5 fp32 copies of the largest leaf
            # (= +10 GB/device; EXPERIMENTS.md §Perf, optimizer iter).
            np_, ns_ = jax.lax.map(
                lambda args: upd(*args), (g, st, p))
        else:
            np_, ns_ = upd(g, st, p)
        new_p.append(np_)
        new_s.append(ns_)
    return (
        jax.tree.unflatten(tdef, new_p),
        {"leaves": jax.tree.unflatten(tdef, new_s), "count": count},
        {"grad_norm": gnorm},
    )


def state_specs(param_specs_tree, params, cfg: AdamWConfig):
    """Optimizer-state PartitionSpecs mirroring each parameter's spec."""
    from jax.sharding import PartitionSpec as P

    def leaf(spec, x):
        st = {}
        if cfg.state_mode in ("factored", "int8") and _use_factored(x):
            st["vr"] = P(*spec[:-1]) if spec else P()
            st["vc"] = P(*(spec[:-2] + spec[-1:])) if spec else P()
        else:
            st["v"] = spec
        if cfg.state_mode == "int8":
            st["m_q"] = spec
            st["m_scale"] = P(None) if _stacked(x) else P()
        else:
            st["m"] = spec
        return st

    return {
        "leaves": jax.tree.map(leaf, param_specs_tree, params),
        "count": P(),
    }
