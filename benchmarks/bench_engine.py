"""Paper experiment reproductions (Figures 14-21, scaled to CPU CI).

Methods compared (single-thread semantics, as in §6.3):
  Timing       — this work: expansion lists + MS-tree + timing pruning
  SJ-tree      — Choudhury et al.: no timing pruning, post-filter
  Rescan       — VF2-style re-enumeration per tick (Fan et al. regime)
  Timing-IND   — Timing's storage accounted without MS-tree sharing
Scales are reduced (CPU, 1 core) but the relative ordering — the paper's
claim — is preserved and asserted in tests/test_benchmarks.py.
"""

from __future__ import annotations

import time

import numpy as np
import jax

from benchmarks.common import bench_stream, state_bytes, write_csv
from repro.core import compile_plan
from repro.core.engine import build_tick, current_matches
from repro.core.oracle import enumerate_matches
from repro.core.query import QueryGraph
from repro.core.sjtree import compile_sjtree_plan
from repro.core.state import init_state, make_batch
from repro.stream.generator import (
    StreamConfig,
    random_walk_query,
    synth_traffic_stream,
    to_batches,
)

CAP = dict(level_capacity=1024, l0_capacity=1024, max_new=256)


def default_stream(n_edges=2500, seed=0):
    return synth_traffic_stream(StreamConfig(
        n_edges=n_edges, n_vertices=150, n_vertex_labels=3,
        n_edge_labels=4, seed=seed, ts_step_max=2))


def default_query(k=4, seed=3, stream=None):
    stream = stream or default_stream()
    for s in range(seed, seed + 60):
        q = random_walk_query(stream, k, seed=s, window=400)
        if q is not None and q.n_edges == k:
            return q
    raise RuntimeError("no query generated")


# ------------------------------------------------------------------ #
def throughput_vs_window(reduced=True):
    """Figure 14: throughput while varying |W|."""
    stream = default_stream(2500 if reduced else 20000)
    q = default_query(4, stream=stream)
    rows = []
    for w in (100, 200, 400) if reduced else (400, 800, 1600, 3200):
        plan = compile_plan(q, w, **CAP)
        eps_t, st = bench_stream(plan, stream, batch_size=64, max_batches=12)
        sj_plan, _ = compile_sjtree_plan(q, w, **CAP)
        eps_sj, st_sj = bench_stream(sj_plan, stream, batch_size=64,
                                     max_batches=12)
        rows.append([w, round(eps_t), round(eps_sj),
                     int(st.stats.n_matches_total),
                     int(st.stats.n_overflow), int(st_sj.stats.n_overflow)])
    return write_csv(
        "fig14_throughput_vs_window",
        ["window", "timing_eps", "sjtree_eps", "n_matches",
         "timing_overflow", "sjtree_overflow"], rows)


def throughput_vs_query_size(reduced=True):
    """Figure 15: throughput while varying |E(Q)|."""
    stream = default_stream(2500 if reduced else 20000)
    rows = []
    for k in (3, 4, 5):
        q = default_query(k, stream=stream)
        plan = compile_plan(q, 300, **CAP)
        eps_t, _ = bench_stream(plan, stream, batch_size=64, max_batches=12)
        sj_plan, _ = compile_sjtree_plan(q, 300, **CAP)
        eps_sj, _ = bench_stream(sj_plan, stream, batch_size=64, max_batches=12)
        rows.append([k, len(plan.subqueries), round(eps_t), round(eps_sj)])
    return write_csv(
        "fig15_throughput_vs_querysize",
        ["query_edges", "n_tc_subqueries", "timing_eps", "sjtree_eps"], rows)


def rescan_baseline(reduced=True):
    """The re-enumerate-per-snapshot baseline (VF2-from-scratch regime).

    Run at a window size where re-enumeration cost is visible — at toy
    windows Python enumeration beats the jitted tick's fixed dispatch
    overhead, inverting the asymptotics.
    """
    stream = default_stream(2000)
    q = default_query(4, stream=stream)
    w = 300
    plan = compile_plan(q, w, **CAP)
    eps_t, _ = bench_stream(plan, stream, batch_size=64, max_batches=12)
    # rescan: enumerate matches over the window after every batch
    window: list = []
    t0 = time.perf_counter()
    n = 0
    for i in range(0, 12 * 64, 64):
        chunk = stream[i:i + 64]
        window.extend(chunk)
        t_now = chunk[-1].ts
        window = [e for e in window if e.ts > t_now - w]
        enumerate_matches(q, window)
        n += len(chunk)
    eps_rescan = n / (time.perf_counter() - t0)
    return write_csv("tab_rescan_baseline",
                     ["method", "edges_per_sec"],
                     [["timing", round(eps_t)],
                      ["rescan_vf2", round(eps_rescan)]])


# ------------------------------------------------------------------ #
def space_vs_window(reduced=True):
    """Figures 16-17: average space cost across the stream."""
    stream = default_stream(1000)
    q = default_query(4, stream=stream)
    rows = []
    for w in (100, 200, 400):
        plan = compile_plan(q, w, **CAP)
        tick = jax.jit(build_tick(plan, extract_matches=False))
        state = init_state(plan)
        ms, ind, samples = 0, 0, 0
        for b in to_batches(stream, 64):
            state, _ = tick(state, make_batch(**b))
            ms += state_bytes(plan, state, "mstree")
            ind += state_bytes(plan, state, "ind")
            samples += 1
        sj_plan, _ = compile_sjtree_plan(q, w, **CAP)
        sj_tick = jax.jit(build_tick(sj_plan, extract_matches=False))
        sj_state = init_state(sj_plan)
        sj = 0
        for b in to_batches(stream, 64):
            sj_state, _ = sj_tick(sj_state, make_batch(**b))
            sj += state_bytes(sj_plan, sj_state, "ind")
        rows.append([w, ms // samples, ind // samples, sj // samples])
    return write_csv(
        "fig16_space_vs_window",
        ["window", "timing_mstree_bytes", "timing_ind_bytes",
         "sjtree_bytes"], rows)


# ------------------------------------------------------------------ #
def concurrency_scaling(reduced=True):
    """Figures 18-19: batched-tick scaling (TPU analogue of threads).

    The paper scales threads under fine-grained locking; the dataflow
    engine scales the number of edges processed per consistent tick.
    'All-locks' (serialize everything) corresponds to batch=1.
    """
    stream = default_stream(2500 if reduced else 30000)
    rows = []
    for k in (4, 6):
        q = default_query(k, stream=stream)
        plan = compile_plan(q, 300, **CAP)
        base, _ = bench_stream(plan, stream, batch_size=1,
                               warmup_batches=8, max_batches=128)
        for bs in (1, 4, 16, 64):
            eps, st = bench_stream(plan, stream, batch_size=bs,
                                   warmup_batches=max(2, 8 // bs),
                                   max_batches=max(8, 256 // bs))
            rows.append([k, bs, round(eps), round(eps / base, 2),
                         int(st.stats.n_matches_total)])
    return write_csv(
        "fig18_concurrency_scaling",
        ["query_edges", "tick_batch", "edges_per_sec",
         "speedup_vs_serial", "n_matches"], rows)


# ------------------------------------------------------------------ #
def optimization_ablations(reduced=True):
    """Figure 20: decomposition + join-order ablations."""
    from repro.core.decompose import TCSubquery, decompose, join_order, tc_subqueries

    stream = default_stream(2000)
    q = default_query(6, stream=stream)
    w = 300

    def run(decomp):
        plan = compile_plan(q, w, decomposition=decomp, **CAP)
        eps, st = bench_stream(plan, stream, batch_size=64, max_batches=20)
        space = state_bytes(plan, st, "mstree")
        return round(eps), space

    best = join_order(q, decompose(q))
    eps_opt, sp_opt = run(best)

    # Rand-D: singleton decomposition (a valid but unoptimized TC cover)
    singles = [TCSubquery(frozenset({e}), (e,)) for e in range(q.n_edges)]
    eps_rd, sp_rd = run(join_order(q, singles))

    # Rand-J: optimal decomposition, reversed-greedy join order
    rev = join_order(q, list(reversed(decompose(q))))
    eps_rj, sp_rj = run(rev)

    rows = [["timing(opt)", eps_opt, sp_opt],
            ["rand_decomposition", eps_rd, sp_rd],
            ["rand_join_order", eps_rj, sp_rj]]
    return write_csv("fig20_optimizations",
                     ["variant", "edges_per_sec", "space_bytes"], rows)


# ------------------------------------------------------------------ #
def selectivity(reduced=True):
    """Figure 21: answer counts vs window and query size."""
    stream = default_stream(2000)
    rows = []
    for k in (3, 4, 5):
        q = default_query(k, stream=stream)
        for w in (100, 200):
            plan = compile_plan(q, w, **CAP)
            tick = jax.jit(build_tick(plan, extract_matches=False))
            state = init_state(plan)
            for b in to_batches(stream, 64):
                state, _ = tick(state, make_batch(**b))
            rows.append([k, w, int(state.stats.n_matches_total)])
    return write_csv("fig21_selectivity",
                     ["query_edges", "window", "total_matches"], rows)
