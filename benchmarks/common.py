"""Shared benchmark utilities: timing, CSV output, engine runners,
space accounting for the MS-tree vs independent-storage comparison."""

from __future__ import annotations

import csv
import os
import time

import numpy as np
import jax

from repro.core.engine import build_tick
from repro.core.state import init_state, make_batch
from repro.stream.generator import to_batches

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "results")


def bench_stream(plan, stream, batch_size: int, extract: bool = False,
                 warmup_batches: int = 2, max_batches: int | None = None):
    """Run a stream through a fresh engine; returns (edges/sec, state).

    ``max_batches`` caps the timed region (rate extrapolates) so serial
    batch=1 sweeps stay affordable on the 1-core CI box.
    """
    tick = jax.jit(build_tick(plan, extract_matches=extract))
    state = init_state(plan)
    batches = [make_batch(**b) for b in to_batches(stream, batch_size)]
    # compile + warm
    for b in batches[:warmup_batches]:
        state, _ = tick(state, b)
    jax.block_until_ready(state.t_now)
    timed = batches[warmup_batches:]
    if max_batches is not None:
        timed = timed[:max_batches]
    t0 = time.perf_counter()
    n_edges = 0
    for b in timed:
        state, _ = tick(state, b)
        n_edges += int(np.asarray(b.valid).sum())
    jax.block_until_ready(state.t_now)
    dt = time.perf_counter() - t0
    rate = n_edges / max(dt, 1e-9)
    # drain the rest (untimed) so returned state covers the full stream
    for b in batches[warmup_batches + len(timed):]:
        state, _ = tick(state, b)
    return rate, state


# ------------------------------------------------------------------ #
# Space accounting (paper Figures 16-17).
# ------------------------------------------------------------------ #
_NODE_BYTES_MSTREE = 4 * 4 + 1         # src, dst, ts, parent, valid


def state_bytes(plan, state, mode: str = "mstree") -> int:
    """Live partial-match storage in bytes under a storage model.

    ``mstree``: each expansion-list node stores (src, dst, ts, parent).
    ``ind``:    each partial match stores full bindings + per-edge ts
                (the paper's Timing-IND / SJ-tree storage model).
    """
    total = 0
    for si, s in enumerate(plan.subqueries):
        for li, lv in enumerate(s.levels):
            n = int(np.asarray(state.levels[si][li].valid).sum())
            if mode == "mstree":
                total += n * _NODE_BYTES_MSTREE
            else:
                nv = len(lv.vertex_layout)
                total += n * ((nv + (li + 1)) * 4 + 1)
    for gi, js in enumerate(plan.l0_joins):
        n = int(np.asarray(state.l0[gi].valid).sum())
        nv, ne = len(js.vertex_layout), len(js.edge_layout)
        total += n * ((nv + ne) * 4 + 1)
    return total


def write_csv(name: str, header: list[str], rows: list):
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.csv")
    with open(path, "w", newline="") as f:
        w = csv.writer(f)
        w.writerow(header)
        w.writerows(rows)
    print(f"# {name}")
    print(",".join(header))
    for r in rows:
        print(",".join(str(x) for x in r))
    print()
    return path
