"""End-to-end serve-loop load benchmark: recorded-traffic replay through
the FULL production path, instrumented vs bare.

``BENCH_ingest.json`` scores the ingress path and ``BENCH_mesh.json``
the replica-sharded tick; this benchmark closes ROADMAP item 5 by
replaying recorded traffic — seeded background streams from
``stream/generator.py`` with the cybersec C2 exfiltration chains of
``examples/cybersec_c2_detection.py`` planted into them — through every
production layer at once: multi-source disordered delivery ->
``IngestFrontier`` (dedup + k-way merge + watermark) -> adaptive
``TickCoalescer`` -> sharded slot groups with ``enable_sharing=True``
(two identical C2 tenants CSE onto one prefix) -> async checkpoints on
a fixed cadence.

Each backend runs the SAME replay twice: bare (``obs=None``, the
default-off path) and instrumented (``MetricsRegistry`` + ``Tracer``
writing span JSONL).  The pair yields the zero-cost-when-off evidence
the obs layer promises:

* ``obs_overhead_ratio`` — instrumented wall / bare wall;
* ``extra_jit_builds`` — ``SlotTickCache.n_builds`` delta across the
  instrumented run (must be 0: metrics never add an XLA trace);
* ``matches_equal`` — per-qid match multisets identical on/off;
* p50/p99 tick latency DOGFOODED from the obs histogram on the
  instrumented row vs ``repro.obs.percentile`` over ``on_tick``
  latencies on the bare row — same nearest-rank math, two surfaces.

Every planted attack must be found (``n_attacks_found``), and the row
embeds watermark lag, checkpoint count and async-checkpoint stall time.

Output: ``BENCH_serve.json`` at the repo root (schema
``bench_serve/v1``).  ``--dry`` emits the same schema at tiny scale
(the CI smoke gate).
"""

from __future__ import annotations

import json
import os
import tempfile
import time

import jax
import numpy as np

from repro.core.join import JoinBackend
from repro.core.multi import SlotTickCache
from repro.core.oracle import DataEdge
from repro.core.query import QueryGraph
from repro.obs import MetricsRegistry, Tracer, percentile, summarize_trace
from repro.runtime.fault import RetryPolicy
from repro.runtime.service import ContinuousSearchService
from repro.stream.generator import (
    DisorderConfig, StreamConfig, disordered_sources, synth_traffic_stream)
from repro.stream.ingest import IngestFrontier, ScriptedSource

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(REPO_ROOT, "BENCH_serve.json")

CAP = dict(level_capacity=512, l0_capacity=512, max_new=128)
WINDOW = 60

# the Figure-1 exfiltration pattern (examples/cybersec_c2_detection.py),
# replicated at engine level so the benchmark has no example dependency.
# vertex labels 0=victim 1=web 2=malware 3=C&C; edge labels are ports.
VICTIM, WEB, MAL, CC = 0, 1, 2, 3
HTTP, DL, REG, CMD, EXFIL = 0, 1, 2, 3, 4


def _attack_query() -> QueryGraph:
    return QueryGraph(
        n_vertices=5,
        vertex_labels=(VICTIM, WEB, MAL, CC, CC),
        edges=((0, 1), (2, 0), (0, 3), (3, 0), (0, 4)),
        edge_labels=(HTTP, DL, REG, CMD, EXFIL),
        prec=frozenset({(0, 1), (1, 2), (2, 3), (3, 4)}),
    )


def _chain_query() -> QueryGraph:
    # a cheap background tenant: http fetch followed by a download
    return QueryGraph(3, (VICTIM, WEB, MAL), ((0, 1), (2, 1)),
                      edge_labels=(HTTP, DL), prec=frozenset({(0, 1)}))


def _plant_attacks(stream, n_attacks: int, n_vertices: int, rng):
    """Insert timing-ordered C2 chains into the background traffic."""
    out = list(stream)
    lo, hi = out[0].ts, out[-1].ts
    for _ in range(n_attacks):
        v, w, m, c, c2 = rng.choice(n_vertices, 5, replace=False) + n_vertices
        t0 = int(rng.integers(lo + 5, hi - 20))
        out.extend([
            DataEdge(int(v), int(w), t0, VICTIM, WEB, HTTP),
            DataEdge(int(m), int(v), t0 + 3, MAL, VICTIM, DL),
            DataEdge(int(v), int(c), t0 + 7, VICTIM, CC, REG),
            DataEdge(int(c), int(v), t0 + 11, CC, VICTIM, CMD),
            DataEdge(int(v), int(c2), t0 + 15, VICTIM, CC, EXFIL),
        ])
    out.sort(key=lambda e: e.ts)
    return out


def _frontier(stream, n_sources: int):
    cfg = DisorderConfig(n_sources=n_sources, disorder_frac=0.01,
                         max_delay=8, seed=23)
    scripts = disordered_sources(stream, cfg)
    return IngestFrontier(
        [ScriptedSource(f"s{i}", sc) for i, sc in enumerate(scripts)],
        allowed_lateness=64, sleep=lambda d: None,
        retry=RetryPolicy(base_delay_s=0.0, jitter_frac=0.0))


def _replay(backend: str, traffic, batch: int, n_sources: int,
            ckpt_every: int, tc: SlotTickCache, instrumented: bool):
    """One full-path run.  Returns the raw measurements for a row."""
    obs = MetricsRegistry() if instrumented else None
    trace_path = None
    tracer = None
    tmp = tempfile.TemporaryDirectory()
    if instrumented:
        trace_path = os.path.join(tmp.name, "trace.jsonl")
        tracer = Tracer(trace_path)
    svc = ContinuousSearchService(
        slots_per_group=4, backend=backend, tick_cache=tc,
        enable_sharing=True, ckpt_dir=tmp.name, compact_every=4,
        obs=obs, tracer=tracer, **CAP)
    # two identical C2 tenants (shared prefix) + one background chain
    c2_qids = [svc.register(_attack_query(), WINDOW),
               svc.register(_attack_query(), WINDOW)]
    svc.register(_chain_query(), WINDOW)

    lat: list[float] = []
    gauges = {"watermark_lag": 0}
    matches: dict[tuple, int] = {}

    def on_tick(i):
        lat.append(i.latency_ms)
        gauges["watermark_lag"] = max(gauges["watermark_lag"],
                                      i.watermark_lag)

    def on_match(qid, bindings, ets):
        for row in np.asarray(bindings):
            key = (qid, tuple(int(b) for b in row))
            matches[key] = matches.get(key, 0) + 1

    serve = dict(batch_size=batch, min_batch=batch, max_batch=batch,
                 on_tick=on_tick, on_match=on_match)
    builds_before = tc.n_builds
    fr = _frontier(traffic, n_sources)
    t0 = time.perf_counter()
    svc.serve_frontier(fr, ckpt_every=ckpt_every, **serve)
    svc.ckpt.wait()
    wall = time.perf_counter() - t0

    n_attacks_found = sum(n for (qid, _), n in matches.items()
                          if qid in c2_qids)
    out = {
        "wall_s": wall,
        "lat": list(lat),
        "n_ticks": len(lat),
        "matches": dict(matches),
        "n_attacks_found": n_attacks_found,
        "watermark_lag_max": int(gauges["watermark_lag"]),
        "extra_jit_builds": tc.n_builds - builds_before,
        "n_late_dropped": int(fr.stats().n_late_dropped),
        "ckpt_stall_s": round(svc.ckpt.stall_s, 4),
    }
    if instrumented:
        tracer.flush()
        tracer.close()
        h = obs.histogram("tick.latency_ms")
        out["obs_snapshot"] = obs.snapshot()
        out["obs_p50"] = round(h.quantile(0.5), 3)
        out["obs_p99"] = round(h.quantile(0.99), 3)
        out["obs_hist_count"] = h.count
        out["trace_summary"] = summarize_trace(trace_path)
    tmp.cleanup()
    return out


def bench_pair(backend: str, traffic, batch: int, n_sources: int,
               ckpt_every: int, n_attacks: int, n_edges: int) -> dict:
    """Bare + instrumented replays of the same traffic on one backend,
    sharing one SlotTickCache so the instrumented run's build delta is
    the no-extra-XLA-traces proof.

    The cache-warming pass replays the FULL traffic through a throwaway
    service first: watermark-gated release makes the chunk-size sequence
    ragged, so only an identical replay visits every traced shape — a
    short ordered prefix would leave compiles inside the timed runs.
    The jitted callables live in the shared ``SlotTickCache``, so both
    timed runs below start fully warm."""
    tc = SlotTickCache()
    _replay(backend, traffic, batch, n_sources,
            ckpt_every, tc, instrumented=False)
    bare = _replay(backend, traffic, batch, n_sources,
                   ckpt_every, tc, instrumented=False)
    inst = _replay(backend, traffic, batch, n_sources,
                   ckpt_every, tc, instrumented=True)

    if inst["obs_hist_count"] != inst["n_ticks"]:
        raise RuntimeError(
            f"obs histogram saw {inst['obs_hist_count']} ticks, serve "
            f"loop ran {inst['n_ticks']} — instrumentation lost data")
    if bare["n_attacks_found"] < 2 * n_attacks:
        raise RuntimeError(
            f"only {bare['n_attacks_found']} attack matches for "
            f"{n_attacks} planted chains x 2 tenants — full path "
            f"dropped planted traffic")

    tsumm = inst["trace_summary"]
    return {
        "bench": "serve_replay",
        "backend": backend,
        "batch": batch,
        "n_sources": n_sources,
        "n_edges": n_edges,
        "n_attacks_planted": n_attacks,
        "n_ticks": bare["n_ticks"],
        # bare row: the production default (obs off)
        "edges_per_s": round(n_edges / bare["wall_s"], 1),
        "ms_per_tick_p50": round(percentile(bare["lat"], 0.5), 3),
        "ms_per_tick_p99": round(percentile(bare["lat"], 0.99), 3),
        "watermark_lag_max": bare["watermark_lag_max"],
        "n_late_dropped": bare["n_late_dropped"],
        "n_attacks_found": bare["n_attacks_found"],
        "ckpt_stall_s": bare["ckpt_stall_s"],
        # instrumented row: same replay with obs registry + span tracer
        "instrumented": {
            "edges_per_s": round(n_edges / inst["wall_s"], 1),
            "ms_per_tick_p50": inst["obs_p50"],   # from the obs histogram
            "ms_per_tick_p99": inst["obs_p99"],
            "n_trace_spans": tsumm["n_spans"],
            "n_trace_ticks": tsumm["n_ticks"],
            "ckpt_stall_s": inst["ckpt_stall_s"],
            "n_checkpoints": int(
                inst["obs_snapshot"].get("ckpt.n_checkpoints", 0)),
        },
        # the zero-cost-when-off evidence
        "obs_overhead_ratio": round(inst["wall_s"] / bare["wall_s"], 3),
        "extra_jit_builds": inst["extra_jit_builds"],
        "matches_equal": bare["matches"] == inst["matches"],
    }


def bench_serve_json(reduced: bool = True, dry: bool = False) -> str:
    """Assemble and write ``BENCH_serve.json`` at the repo root."""
    if dry:
        n_bg, n_attacks, batch, n_sources, ckpt_every = 300, 3, 32, 2, 4
    elif reduced:
        n_bg, n_attacks, batch, n_sources, ckpt_every = 3000, 8, 64, 3, 8
    else:
        n_bg, n_attacks, batch, n_sources, ckpt_every = 12000, 12, 128, 4, 8

    rng = np.random.default_rng(7)
    background = synth_traffic_stream(StreamConfig(
        n_edges=n_bg, n_vertices=200, n_vertex_labels=4,
        n_edge_labels=5, seed=3, ts_step_max=1))
    traffic = _plant_attacks(background, n_attacks, 200, rng)
    n_edges = len(traffic)

    backends = [JoinBackend.REF, JoinBackend.PALLAS_INTERPRET]
    if jax.default_backend() == "tpu":
        backends.append(JoinBackend.PALLAS)

    results = [bench_pair(b, traffic, batch, n_sources,
                          ckpt_every, n_attacks, n_edges)
               for b in backends]
    doc = {
        "schema": "bench_serve/v1",
        "mode": "dry" if dry else ("reduced" if reduced else "full"),
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "note": ("recorded-traffic replay (seeded background + planted "
                 "C2 exfiltration chains) through the full path: "
                 "disordered sources -> ingest frontier -> coalescer -> "
                 "shared-prefix slot groups -> async checkpoints; each "
                 "backend runs bare and instrumented, and the pair "
                 "proves obs is free when off (no extra jit builds, "
                 "identical match multisets) and cheap when on"),
        "results": results,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# BENCH_serve.json -> {JSON_PATH} ({len(results)} rows)")
    for r in results:
        print(f"#   serve {r['backend']}: {r['edges_per_s']} e/s, "
              f"p50 {r['ms_per_tick_p50']} ms, "
              f"p99 {r['ms_per_tick_p99']} ms, "
              f"obs overhead {r['obs_overhead_ratio']}x "
              f"(+{r['extra_jit_builds']} builds), "
              f"{r['n_attacks_found']} attack matches")
    return JSON_PATH


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--dry", action="store_true")
    args = ap.parse_args()
    bench_serve_json(reduced=not args.full, dry=args.dry)
