"""Multi-query serving throughput: fused tick vs per-query passes.

Serving N standing queries naively means running the stream through N
independent single-query engines — N dispatches and N label scans per
batch.  ``build_multi_tick`` fuses them into one tick with a single
``[total_qedges, B]`` label-match phase; the padded-slot service adds
recompile-free registration on top.  This benchmark reports, per stream
family (synthetic traffic / social):

    fused_eps     stream edges/sec with all N queries fused in one tick
    baseline_eps  edges/sec serving the same N queries as N separate
                  single-query passes (total time = sum of per-query
                  times, i.e. the sum-of-single-query baseline)
    service_eps   edges/sec through ContinuousSearchService (slot groups)

Acceptance target (ISSUE 1): fused_eps >= baseline_eps on the traffic
stream — asserted at the bottom of main().

Run:  PYTHONPATH=src python -m benchmarks.bench_multiquery
"""

from __future__ import annotations

import time

import numpy as np
import jax

from benchmarks.common import write_csv
from repro.api import StreamSession
from repro.core import compile_plan
from repro.core.engine import build_tick
from repro.core.multi import build_multi_tick, init_multi_state
from repro.core.state import init_state, make_batch
from repro.runtime.service import ContinuousSearchService
from repro.stream.generator import (
    StreamConfig,
    random_walk_query,
    synth_social_stream,
    synth_traffic_stream,
    to_batches,
)

# Scales chosen so per-query join compute doesn't fully drown the shared
# work on the 1-core CI box.  The fused savings are the per-batch
# dispatch and the shared label scan, so the margin is modest (~4-5%
# measured clean) but consistent under the symmetric best-of-rounds
# methodology below; the join compute itself is identical per query.
CAP = dict(level_capacity=512, l0_capacity=512, max_new=128)
WINDOW = 60
BATCH = 64
WARMUP = 2
MAX_BATCHES = 24
# Interleaved best-of-N rounds: a background process stealing the CPU
# during one competitor's pass would otherwise decide the comparison.
ROUNDS = 3


def gen_queries(stream, n_queries: int, n_qedges: int = 3):
    """Distinct random-walk queries (paper §6.2) guaranteed >= 1 embedding."""
    out, seen = [], set()
    for seed in range(200):
        q = random_walk_query(stream, n_qedges, seed=seed, window=WINDOW)
        if q is None or q.n_edges != n_qedges:
            continue
        key = (q.vertex_labels, q.edges, q.edge_labels, q.prec)
        if key in seen:
            continue
        seen.add(key)
        out.append(q)
        if len(out) == n_queries:
            return out
    raise RuntimeError(f"only generated {len(out)}/{n_queries} queries")


def _timed_loop(tick, state, batches):
    """(seconds, final_state) over batches[WARMUP:][:MAX_BATCHES]."""
    for b in batches[:WARMUP]:
        state, _ = tick(state, b)
    jax.block_until_ready(state)
    timed = batches[WARMUP:WARMUP + MAX_BATCHES]
    t0 = time.perf_counter()
    for b in timed:
        state, _ = tick(state, b)
    jax.block_until_ready(state)
    n_edges = sum(int(np.asarray(b.valid).sum()) for b in timed)
    return time.perf_counter() - t0, n_edges


def bench_fused_vs_single(plans, batches):
    """(fused_eps, baseline_eps), measured PAIRED at batch granularity.

    For every timed batch the fused tick and all N single-query ticks
    run back-to-back, each under its own timer with a sync after —
    machine-load drift then hits both sides almost equally, where
    timing each competitor in its own multi-second segment lets a
    background blip decide the comparison.  The first round is a
    discard (post-compile lazy init lands there); the remaining ROUNDS
    accumulate.  Per-call sync is part of the measurement and of the
    point: serving N queries separately really does pay N dispatch+sync
    rounds per batch where the fused tick pays one.
    """
    mtick = jax.jit(build_multi_tick(plans, extract_matches=False))
    sticks = [jax.jit(build_tick(p, extract_matches=False)) for p in plans]
    tf = tb = 0.0
    n_total = 0
    for rnd in range(ROUNDS + 1):   # round 0 is the discard
        sf = init_multi_state(plans)
        ss = [init_state(p) for p in plans]
        for b in batches[:WARMUP]:
            sf, _ = mtick(sf, b)
            for i, tick in enumerate(sticks):
                ss[i], _ = tick(ss[i], b)
        jax.block_until_ready((sf, ss))
        for b in batches[WARMUP:WARMUP + MAX_BATCHES]:
            t0 = time.perf_counter()
            sf, _ = mtick(sf, b)
            jax.block_until_ready(sf)
            dt_f = time.perf_counter() - t0
            dt_b = 0.0
            for i, tick in enumerate(sticks):
                t0 = time.perf_counter()
                ss[i], _ = tick(ss[i], b)
                jax.block_until_ready(ss[i])
                dt_b += time.perf_counter() - t0
            if rnd > 0:
                tf += dt_f
                tb += dt_b
                n_total += int(np.asarray(b.valid).sum())
    return n_total / max(tf, 1e-9), n_total / max(tb, 1e-9)


def bench_service(queries, batches):
    # Slots provisioned to tenancy: random-walk queries rarely share a
    # structural signature, and a padded-but-empty slot still costs a
    # full vmap lane.  Headroom (slots_per_group > occupancy) trades
    # throughput for recompile-free churn; measure occupancy = 1 here.
    # Registration goes through the repro.api facade (adopt +
    # register_query: exact queries, no canonical rewrite) so the bench
    # exercises the public path; extract_matches=False keeps the
    # measurement about tick cost, not host-side match decode.
    svc = ContinuousSearchService(slots_per_group=1, extract_matches=False,
                                  **CAP)
    sess = StreamSession.adopt(svc)
    for q in queries:
        sess.register_query(q, WINDOW)

    def tick(_state, b):
        svc.ingest(b)
        # return the groups' device states so _timed_loop's
        # block_until_ready waits for the async tick dispatches
        return [g.sstate for gs in svc._groups.values() for g in gs], None

    dt, n = _timed_loop(tick, [g.sstate for gs in svc._groups.values()
                               for g in gs], batches)
    return n / max(dt, 1e-9), svc.n_compiles


def run_family(name: str, stream, n_queries: int):
    queries = gen_queries(stream, n_queries)
    plans = [compile_plan(q, WINDOW, **CAP) for q in queries]
    batches = [make_batch(**b) for b in to_batches(stream, BATCH)]
    fused, baseline = bench_fused_vs_single(plans, batches)
    service, n_compiles = bench_service(queries, batches)
    return dict(family=name, n_queries=n_queries, fused_eps=round(fused),
                baseline_eps=round(baseline), service_eps=round(service),
                fused_speedup=round(fused / max(baseline, 1e-9), 2),
                service_compiles=n_compiles)


def main(n_queries: int = 6, n_edges: int = 3000):
    traffic = synth_traffic_stream(StreamConfig(
        n_edges=n_edges, n_vertices=150, n_vertex_labels=3, n_edge_labels=4,
        seed=0, ts_step_max=2))
    social = synth_social_stream(StreamConfig(
        n_edges=n_edges, n_vertices=150, n_vertex_labels=4, n_edge_labels=6,
        seed=1, ts_step_max=2))

    rows = [
        run_family("traffic", traffic, n_queries),
        run_family("social", social, n_queries),
    ]
    header = list(rows[0].keys())
    write_csv("multiquery", header, [[r[h] for h in header] for r in rows])

    tr = rows[0]
    assert tr["fused_eps"] >= tr["baseline_eps"], (
        f"fused tick slower than sum-of-single baseline on traffic: "
        f"{tr['fused_eps']} < {tr['baseline_eps']}")
    print(f"OK: fused {tr['fused_eps']} e/s >= baseline "
          f"{tr['baseline_eps']} e/s (x{tr['fused_speedup']})")
    return rows


if __name__ == "__main__":
    main()
