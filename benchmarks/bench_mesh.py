"""Replica-sharded serving benchmark: does the mesh path scale tenants?

Measures ``ShardedSearchService`` end-to-end over ``serve_stream`` at a
FIXED per-replica tenant load while the replica count grows (1, 2, 4, 8
virtual CPU devices): per-tick wall cost, per-replica tick cost
(wall / n_replicas — the figure of merit on virtual devices, where all
replicas share the same physical cores), and edge throughput.  The
parity block compares the per-replica tick cost against a single-device
``ContinuousSearchService`` serving the SAME per-replica load — the
acceptance bar for the mesh runtime (sharding must not tax the slot
tick it wraps).

A second section measures checkpoint manifest growth: full (base)
manifest bytes vs incremental-delta bytes at two tenant scales with a
one-tenant churn per step — the O(churn)-not-O(tenants) evidence for
the delta-manifest path.

Output: ``BENCH_mesh.json`` at the repo root (schema ``bench_mesh/v1``).

Multi-device meshes need ``XLA_FLAGS=--xla_force_host_platform_device_
count=8`` set BEFORE jax initializes, and the harness process has long
since imported jax — so ``bench_mesh_json`` re-spawns this module as a
subprocess with the env pinned (``--child`` mode does the real work).
``--dry`` emits the same schema at tiny scale (the CI smoke gate).
"""

from __future__ import annotations

import argparse
import glob
import json
import os
import subprocess
import sys
import time

REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
JSON_PATH = os.path.join(REPO_ROOT, "BENCH_mesh.json")
N_DEVICES = 8
WINDOW = 40
CAP = dict(level_capacity=512, l0_capacity=512, max_new=128)


# --------------------------------------------------------------------- #
# parent: env-pinned subprocess launcher (the public entry point)
# --------------------------------------------------------------------- #
def bench_mesh_json(reduced: bool = True, dry: bool = False) -> str:
    """Write ``BENCH_mesh.json`` via a subprocess with 8 virtual devices."""
    mode = "dry" if dry else ("reduced" if reduced else "full")
    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    flags = env.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        env["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={N_DEVICES}"
        ).strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (REPO_ROOT, os.path.join(REPO_ROOT, "src"),
                    env.get("PYTHONPATH", "")) if p)
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_mesh", "--child", mode],
        env=env, cwd=REPO_ROOT)
    if proc.returncode != 0:
        raise RuntimeError(
            f"bench_mesh child failed with rc={proc.returncode}")
    if not (os.path.exists(JSON_PATH) and os.path.getsize(JSON_PATH)):
        raise RuntimeError(f"bench_mesh child wrote no {JSON_PATH}")
    return JSON_PATH


# --------------------------------------------------------------------- #
# child: runs on the 8-virtual-device mesh
# --------------------------------------------------------------------- #
def _chain3():
    from repro.core.query import QueryGraph
    return QueryGraph(4, (0, 1, 2, 0), ((0, 1), (1, 2), (2, 3)),
                      prec=frozenset({(0, 1), (1, 2)}))


def _serve_timed(svc, stream, batch, warm_ticks=2):
    """(per-tick latencies ms, wall seconds, timed edge count)."""
    lat = []
    serve = dict(batch_size=batch, min_batch=batch, max_batch=batch,
                 on_tick=lambda info: lat.append(info.latency_ms))
    svc.serve_stream(stream[:warm_ticks * batch], **serve)  # compile+warm
    lat.clear()
    t0 = time.perf_counter()
    svc.serve_stream(stream[warm_ticks * batch:], **serve)
    wall = time.perf_counter() - t0
    return lat, wall, len(stream) - warm_ticks * batch


def _tick_rows(replicas, spr, n_edges, batch):
    import jax

    from repro.core.join import JoinBackend
    from repro.core.multi import SlotTickCache
    from repro.obs import percentile
    from repro.runtime import ContinuousSearchService, ShardedSearchService
    from repro.stream.generator import StreamConfig, synth_traffic_stream

    stream = synth_traffic_stream(StreamConfig(
        n_edges=n_edges + 2 * batch, n_vertices=80, n_vertex_labels=8,
        n_edge_labels=4, seed=23, ts_step_max=2))

    # single-device baseline at the per-replica load: spr tenants of one
    # structure in one slot group, plain slot tick
    base = ContinuousSearchService(
        slots_per_group=spr, backend=JoinBackend.REF,
        tick_cache=SlotTickCache(), **CAP)
    for _ in range(spr):
        base.register(_chain3(), WINDOW)
    blat, bwall, bedges = _serve_timed(base, stream, batch)
    baseline = {
        "bench": "mesh_tick_baseline",
        "n_tenants": spr,
        "batch": batch,
        "n_ticks": len(blat),
        "edges_per_s": round(bedges / bwall, 1),
        "ms_per_tick_mean": round(sum(blat) / max(1, len(blat)), 3),
    }

    rows, parity = [], []
    for r in replicas:
        svc = ShardedSearchService(
            n_replicas=r, slots_per_replica=spr, backend=JoinBackend.REF,
            tick_cache=SlotTickCache(), **CAP)
        for _ in range(r * spr):
            svc.register(_chain3(), WINDOW)
        lat, wall, edges = _serve_timed(svc, stream, batch)
        mean = sum(lat) / max(1, len(lat))
        rows.append({
            "bench": "mesh_tick",
            "n_replicas": r,
            "slots_per_replica": spr,
            "n_tenants": r * spr,
            "batch": batch,
            "n_edges": edges,
            "n_ticks": len(lat),
            "edges_per_s": round(edges / wall, 1),
            "tenant_edges_per_s": round(r * spr * edges / wall, 1),
            "ms_per_tick_mean": round(mean, 3),
            "ms_per_tick_p50": round(percentile(lat, 0.5), 3),
            "ms_per_tick_per_replica": round(mean / r, 3),
        })
        parity.append({
            "n_replicas": r,
            "per_replica_vs_baseline": round(
                (mean / r) / max(baseline["ms_per_tick_mean"], 1e-9), 3),
        })
        del svc
    jax.clear_caches()
    return baseline, rows, parity


def _manifest_rows(scales):
    """Full-base vs delta manifest bytes at growing tenant counts with a
    one-tenant churn per checkpoint step (the O(churn) evidence)."""
    import tempfile

    from repro.core.multi import SlotTickCache
    from repro.runtime import ShardedSearchService

    caps = dict(level_capacity=64, l0_capacity=64, max_new=32)
    out = []
    for n_tenants in scales:
        with tempfile.TemporaryDirectory() as tmp:
            svc = ShardedSearchService(
                n_replicas=2, slots_per_replica=(n_tenants + 1) // 2,
                tick_cache=SlotTickCache(), ckpt_dir=tmp,
                compact_every=64, **caps)
            qids = [svc.register(_chain3(), WINDOW)
                    for _ in range(n_tenants)]

            def manifests():
                return {p: os.path.getsize(p)
                        for p in glob.glob(os.path.join(tmp, "step_*.json"))}

            svc.checkpoint()
            svc.ckpt.wait()
            base = manifests()
            (full_path, full_bytes), = base.items()
            assert "service" in json.load(open(full_path)), full_path

            svc.unregister(qids[0])                # one tenant churns
            svc.register(_chain3(), WINDOW)
            svc.checkpoint()
            svc.ckpt.wait()
            (delta_path, delta_bytes), = (
                (p, s) for p, s in manifests().items() if p not in base)
            assert "service_delta" in json.load(open(delta_path)), delta_path

            out.append({
                "n_tenants": n_tenants,
                "full_manifest_bytes": full_bytes,
                "delta_manifest_bytes": delta_bytes,
                "delta_over_full": round(delta_bytes / full_bytes, 4),
            })
    return out


def _child_main(mode: str) -> None:
    import jax

    assert len(jax.devices()) == N_DEVICES, jax.devices()
    if mode == "dry":
        replicas, spr, n_edges, batch = (1, 2), 2, 256, 32
        scales = (8, 16)
    elif mode == "reduced":
        replicas, spr, n_edges, batch = (1, 2, 4, 8), 4, 2048, 64
        scales = (16, 64)
    else:
        replicas, spr, n_edges, batch = (1, 2, 4, 8), 4, 8192, 128
        scales = (32, 128)

    baseline, rows, parity = _tick_rows(replicas, spr, n_edges, batch)
    manifest = _manifest_rows(scales)
    # the parity bar: at its best replica count the mesh's per-replica
    # tick cost must not exceed the single-device slot tick (shard_map
    # wrapper overhead amortizes as replicas grow; small R on virtual
    # devices pays a few % that the summary makes visible, not hidden)
    best = min(p["per_replica_vs_baseline"] for p in parity)

    doc = {
        "schema": "bench_mesh/v1",
        "mode": mode,
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "n_devices": len(jax.devices()),
        "note": ("replica-sharded serve_stream at fixed per-replica "
                 "tenant load on virtual CPU devices; "
                 "ms_per_tick_per_replica (wall/n_replicas) vs a "
                 "single-device service at the same per-replica load is "
                 "the parity figure; manifest rows show full-base vs "
                 "one-churn delta checkpoint manifest bytes"),
        "baseline": baseline,
        "results": rows,
        "parity": parity,
        "per_replica_best_vs_baseline": best,
        "manifest": manifest,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# BENCH_mesh.json -> {JSON_PATH} ({len(rows)} rows)")
    for row, p in zip(rows, parity):
        print(f"#   mesh_tick R={row['n_replicas']}: "
              f"{row['ms_per_tick_mean']}ms/tick "
              f"({row['ms_per_tick_per_replica']}ms/replica, "
              f"{p['per_replica_vs_baseline']}x baseline), "
              f"{row['edges_per_s']} edges/s")
    for m in manifest:
        print(f"#   manifest N={m['n_tenants']}: "
              f"full={m['full_manifest_bytes']}B "
              f"delta={m['delta_manifest_bytes']}B "
              f"({m['delta_over_full']}x)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--child", metavar="MODE",
                    choices=("dry", "reduced", "full"),
                    help="internal: run the benchmark in-process "
                         "(requires the 8-virtual-device env)")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--dry", action="store_true")
    args = ap.parse_args()
    if args.child:
        _child_main(args.child)
    else:
        bench_mesh_json(reduced=not args.full, dry=args.dry)


if __name__ == "__main__":
    main()
