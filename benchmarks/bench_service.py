"""Engine-level ``serve_stream`` tick benchmark per join backend.

The join-kernel trajectory (``BENCH_join.json``) scores isolated kernel
calls; this benchmark scores the SERVING LOOP the way production runs
it: a multi-tenant session (registered through the ``repro.api`` DSL so
isomorphic tenants share compiled ticks), pinned chunk sizes, the full
per-tick path — label scan, vmapped slot joins, match extraction, the
one barrier — measured per backend (REF vs PALLAS_INTERPRET; compiled
PALLAS rows appear when a TPU is attached).

Output: ``BENCH_tick.json`` at the repo root (schema ``bench_tick/v1``),
alongside ``BENCH_join.json``, so per-PR deltas of the end-to-end tick
cost are machine-trackable.  ``--dry`` emits the same schema at tiny
scale (the CI smoke gate).
"""

from __future__ import annotations

import json
import os
import time

import jax

from repro.api import Pattern, StreamSession
from repro.core.join import JoinBackend
from repro.core.multi import SlotTickCache
from repro.stream.generator import StreamConfig, synth_traffic_stream

JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_tick.json")

CAP = dict(level_capacity=512, l0_capacity=512, max_new=128)


def tenant_patterns(n_tenants: int, window: int = 40):
    """``n_tenants`` DSL patterns cycling over three structures, authored
    with per-tenant vertex names (the canonicalizing planner collapses
    them onto three compiled ticks regardless)."""
    out = []
    for i in range(n_tenants):
        a, b, c = f"a{i}", f"b{i}", f"c{i}"
        kind = i % 3
        p = Pattern(f"tenant-{i}")
        p.vertex(a, label=0).vertex(b, label=1).vertex(c, label=2)
        if kind == 0:       # timing-ordered 2-chain
            p.edge(a, b).edge(b, c).before(0, 1)
        elif kind == 1:     # triangle with a timing chain
            p.edge(a, b).edge(b, c).edge(c, a).before(0, 1).before(1, 2)
        else:               # fork, second edge first
            p.edge(a, b).edge(a, c).before(1, 0)
        out.append(p.window(window))
    return out


def bench_backend(backend: str, n_tenants: int, n_edges: int,
                  batch: int, warmup_ticks: int = 2) -> dict:
    stream = synth_traffic_stream(StreamConfig(
        n_edges=n_edges + warmup_ticks * batch, n_vertices=60,
        n_vertex_labels=3, n_edge_labels=4, seed=17, ts_step_max=2))
    tc = SlotTickCache()
    sess = StreamSession(slots_per_group=8, backend=backend,
                         tick_cache=tc, **CAP)
    # a discarding callback: per-match typed decode is part of the
    # serving cost being measured, but nothing may accumulate — an
    # undrained queue would grow (and GC-churn) inside the timed region
    for p in tenant_patterns(n_tenants):
        sess.register(p, on_match=lambda m: None)

    lat = []
    serve = dict(batch_size=batch, min_batch=batch, max_batch=batch,
                 on_tick=lambda i: lat.append(i.latency_ms))
    sess.serve(stream[:warmup_ticks * batch], **serve)   # compile + warm
    lat.clear()
    t0 = time.perf_counter()
    sess.serve(stream[warmup_ticks * batch:], **serve)
    wall = time.perf_counter() - t0

    lat_sorted = sorted(lat)
    return {
        "bench": "serve_tick",
        "backend": backend,
        "n_tenants": n_tenants,
        "n_groups": len(sess.service._iter_groups()),
        "n_compiles": sess.service.n_compiles,
        "batch": batch,
        "n_edges": n_edges,
        "n_ticks": len(lat),
        "edges_per_s": round(n_edges / wall, 1),
        "ms_per_tick_mean": round(sum(lat) / max(1, len(lat)), 3),
        "ms_per_tick_p50": round(lat_sorted[len(lat) // 2], 3) if lat else 0.0,
        "ms_per_tick_max": round(max(lat), 3) if lat else 0.0,
    }


def bench_tick_json(reduced: bool = True, dry: bool = False) -> str:
    """Assemble and write ``BENCH_tick.json`` at the repo root."""
    if dry:
        n_tenants, n_edges, batch = 3, 256, 32
    elif reduced:
        n_tenants, n_edges, batch = 9, 2048, 64
    else:
        n_tenants, n_edges, batch = 24, 16384, 128

    backends = [JoinBackend.REF, JoinBackend.PALLAS_INTERPRET]
    if jax.default_backend() == "tpu":
        backends.append(JoinBackend.PALLAS)

    results = [bench_backend(b, n_tenants, n_edges, batch) for b in backends]
    doc = {
        "schema": "bench_tick/v1",
        "mode": "dry" if dry else ("reduced" if reduced else "full"),
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "note": ("end-to-end serve_stream ticks (label scan + vmapped "
                 "slot joins + match extraction + barrier), multi-tenant "
                 "via the repro.api DSL; PALLAS_INTERPRET timings are "
                 "kernel-semantics validation, not TPU speed"),
        "results": results,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# BENCH_tick.json -> {JSON_PATH} ({len(results)} rows)")
    for r in results:
        print(f"#   serve_tick {r['backend']}: {r['edges_per_s']} e/s, "
              f"{r['ms_per_tick_mean']} ms/tick mean "
              f"({r['n_tenants']} tenants, {r['n_groups']} groups, "
              f"{r['n_compiles']} compiles)")
    return JSON_PATH


if __name__ == "__main__":
    bench_tick_json()
