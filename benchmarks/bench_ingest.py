"""Ingestion-frontier benchmark: sustained throughput + tick latency
under increasing delivery disorder.

``BENCH_tick.json`` scores the serving loop over a pre-ordered edge
list; this benchmark scores the PRODUCTION INGRESS path in front of it:
seeded multi-source delivery scripts (``disordered_sources``) feed
``ScriptedSource``s through the fault-tolerant frontier — per-source
dedup, deterministic k-way event-time merge, watermark-gated release —
into ``ContinuousSearchService.serve_frontier``.  Swept over the
disorder fraction (0%, 1%, 10% of deliveries displaced late, plus
transport duplicates at the 10% point), so the cost of the reorder
buffer and watermark machinery relative to the ordered fast path is
machine-trackable per PR.

Output: ``BENCH_ingest.json`` at the repo root (schema
``bench_ingest/v1``): sustained edges/s and p50/p99 tick latency per
(backend × disorder) cell, with the frontier's duplicate/late-drop
accounting embedded so a regression in EITHER speed or exactly-once
accounting trips the CI schema gate.  ``--dry`` emits the same schema
at tiny scale (the CI smoke gate).
"""

from __future__ import annotations

import json
import os
import time

import jax

from repro.core.join import JoinBackend
from repro.core.multi import SlotTickCache
from repro.core.query import QueryGraph
from repro.obs import percentile
from repro.runtime.fault import RetryPolicy
from repro.runtime.service import ContinuousSearchService
from repro.stream.generator import (
    DisorderConfig, StreamConfig, disordered_sources, synth_traffic_stream)
from repro.stream.ingest import IngestFrontier, ScriptedSource

JSON_PATH = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "BENCH_ingest.json")

CAP = dict(level_capacity=512, l0_capacity=512, max_new=128)
DISORDER_FRACS = (0.0, 0.01, 0.10)


def _queries():
    chain = QueryGraph(3, (0, 1, 2), ((0, 1), (1, 2)),
                       prec=frozenset({(0, 1)}))
    tri = QueryGraph(3, (0, 1, 2), ((0, 1), (1, 2), (2, 0)),
                     prec=frozenset({(0, 1), (1, 2)}))
    return [(chain, 30), (tri, 30)]


def _frontier(stream, disorder_frac: float, n_sources: int):
    cfg = DisorderConfig(
        n_sources=n_sources, disorder_frac=disorder_frac, max_delay=8,
        duplicate_rate=0.05 if disorder_frac >= 0.10 else 0.0, seed=23)
    scripts = disordered_sources(stream, cfg)
    return IngestFrontier(
        [ScriptedSource(f"s{i}", sc) for i, sc in enumerate(scripts)],
        allowed_lateness=64, sleep=lambda d: None,
        retry=RetryPolicy(base_delay_s=0.0, jitter_frac=0.0))


def bench_cell(backend: str, disorder_frac: float, n_edges: int,
               batch: int, n_sources: int, tc: SlotTickCache,
               warmup_edges: int) -> dict:
    stream = synth_traffic_stream(StreamConfig(
        n_edges=n_edges + warmup_edges, n_vertices=60, n_vertex_labels=3,
        n_edge_labels=4, seed=29, ts_step_max=2))
    svc = ContinuousSearchService(slots_per_group=4, backend=backend,
                                  tick_cache=tc, **CAP)
    for q, w in _queries():
        svc.register(q, w)

    lat = []
    gauges = {"watermark_lag": 0, "window_staleness": 0}

    def on_tick(i):
        lat.append(i.latency_ms)
        gauges["watermark_lag"] = max(gauges["watermark_lag"],
                                      i.watermark_lag)
        gauges["window_staleness"] = max(gauges["window_staleness"],
                                         i.window_staleness)

    serve = dict(batch_size=batch, min_batch=batch, max_batch=batch,
                 on_tick=on_tick)
    # compile + warm on the ordered prefix, then time the swept tail
    svc.serve_frontier(_frontier(stream[:warmup_edges], 0.0, n_sources),
                       **serve)
    lat.clear()
    gauges["watermark_lag"] = gauges["window_staleness"] = 0
    fr = _frontier(stream[warmup_edges:], disorder_frac, n_sources)
    t0 = time.perf_counter()
    svc.serve_frontier(fr, **serve)
    wall = time.perf_counter() - t0

    s = fr.stats()
    # the shared nearest-rank helper — same math every obs surface uses
    pick = lambda q: round(percentile(lat, q), 3)
    return {
        "bench": "ingest_frontier",
        "backend": backend,
        "disorder_frac": disorder_frac,
        "n_sources": n_sources,
        "batch": batch,
        "n_edges": n_edges,
        "n_ticks": len(lat),
        "edges_per_s": round(n_edges / wall, 1),
        "ms_per_tick_p50": pick(0.50),
        "ms_per_tick_p99": pick(0.99),
        "n_emitted": int(s.n_emitted),
        "n_duplicates": int(s.n_duplicates),
        "n_late_dropped": int(s.n_late_dropped),
        "n_dropped_forced_gap": int(s.n_dropped_forced_gap),
        # event-time health gauges (peak over the run): how far the
        # freshest data ran ahead of the watermark, and how far forced
        # evictions pushed the emit floor past it (0 = no capacity gap)
        "watermark_lag_max": int(gauges["watermark_lag"]),
        "window_staleness_max": int(gauges["window_staleness"]),
    }


def bench_ingest_json(reduced: bool = True, dry: bool = False) -> str:
    """Assemble and write ``BENCH_ingest.json`` at the repo root."""
    if dry:
        n_edges, batch, n_sources, warmup = 256, 32, 3, 64
    elif reduced:
        n_edges, batch, n_sources, warmup = 2048, 64, 3, 128
    else:
        n_edges, batch, n_sources, warmup = 16384, 128, 4, 256

    backends = [JoinBackend.REF, JoinBackend.PALLAS_INTERPRET]
    if jax.default_backend() == "tpu":
        backends.append(JoinBackend.PALLAS)

    tc = SlotTickCache()
    results = [bench_cell(b, frac, n_edges, batch, n_sources, tc, warmup)
               for b in backends for frac in DISORDER_FRACS]
    doc = {
        "schema": "bench_ingest/v2",
        "mode": "dry" if dry else ("reduced" if reduced else "full"),
        "jax_version": jax.__version__,
        "platform": jax.default_backend(),
        "note": ("serve_frontier over seeded multi-source delivery "
                 "scripts: per-source dedup + k-way event-time merge + "
                 "watermark release driving event-time window clocks, "
                 "swept over the fraction of deliveries displaced late; "
                 "duplicate/late-drop/forced-gap accounting plus peak "
                 "watermark-lag and window-staleness gauges embedded "
                 "per cell"),
        "results": results,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(doc, f, indent=1)
    print(f"# BENCH_ingest.json -> {JSON_PATH} ({len(results)} rows)")
    for r in results:
        print(f"#   ingest {r['backend']} disorder={r['disorder_frac']}: "
              f"{r['edges_per_s']} e/s, p50 {r['ms_per_tick_p50']} ms, "
              f"p99 {r['ms_per_tick_p99']} ms "
              f"({r['n_duplicates']} dups, {r['n_late_dropped']} late)")
    return JSON_PATH


if __name__ == "__main__":
    bench_ingest_json()
